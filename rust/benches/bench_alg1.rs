//! Algorithm 1 per-slot complexity — verifies the paper's
//! `O(M·(1 + |Jqu|·|V|·|Mlt|))` bound empirically: decision time scales
//! ~linearly in each of queue length, node count, and light-MS count.
//!
//! Run: `cargo bench --bench bench_alg1`.

use std::time::Duration;

use fmedge::benchkit::{bench_budget, print_table, BenchResult};
use fmedge::config::{ExperimentConfig, NUM_RESOURCES};
use fmedge::controller::{greedy_light_deployment, LightRequest, OnlineParams};
use fmedge::effcap::{GTable, GTableParams};
use fmedge::network::Topology;
use fmedge::rng::{Distribution, Gamma, Rng, Xoshiro256};
use fmedge::routing::DistanceMatrix;

struct Fixture {
    dm: DistanceMatrix,
    gtable: GTable,
    resources: Vec<[f64; NUM_RESOURCES]>,
    costs: Vec<(f64, f64, f64)>,
    nv: usize,
}

fn fixture(num_eds: usize, num_ess: usize, nl: usize) -> Fixture {
    let mut cfg = ExperimentConfig::paper_default();
    cfg.network.num_eds = num_eds;
    cfg.network.num_ess = num_ess;
    let mut rng = Xoshiro256::seed_from(9);
    let topo = Topology::generate(&cfg, &mut rng);
    let dm = DistanceMatrix::build(&topo, 1.0);
    let mut samples = Vec::new();
    let mut workloads = Vec::new();
    for i in 0..nl {
        samples.push(Gamma::new(1.5, 8.0 + i as f64).sample_n(&mut rng, 1024));
        workloads.push(1.0);
    }
    Fixture {
        nv: topo.num_nodes(),
        dm,
        gtable: GTable::build(&samples, &workloads, &GTableParams::default_paper()),
        resources: vec![[1.0, 0.2, 0.5, 0.1]; nl],
        costs: vec![(4.0, 1.0, 0.5); nl],
    }
}

fn queue(fx: &Fixture, n: usize, nl: usize) -> Vec<LightRequest> {
    let mut rng = Xoshiro256::seed_from(n as u64);
    (0..n)
        .map(|i| LightRequest {
            task_id: i as u64,
            light_idx: rng.next_below(nl as u64) as usize,
            from_node: rng.next_below(fx.nv as u64) as usize,
            payload_mb: rng.range_f64(0.2, 1.5),
            h: rng.range_f64(0.5, 20.0),
            deadline_slack_ms: 50.0,
        })
        .collect()
}

fn run_case(name: &str, fx: &Fixture, nl: usize, qlen: usize) -> BenchResult {
    let q = queue(fx, qlen, nl);
    let busy = vec![vec![0u32; nl]; fx.nv];
    let residual = vec![[16.0, 4.0, 8.0, 2.0]; fx.nv];
    let params = OnlineParams::from_config(&ExperimentConfig::paper_default().controller);
    bench_budget(name, Duration::from_millis(300), || {
        let d = greedy_light_deployment(
            &q,
            &busy,
            &residual,
            &fx.resources,
            &fx.costs,
            &fx.gtable,
            &fx.dm,
            &params,
        );
        std::hint::black_box(d.stats.objective);
    })
}

fn main() {
    let mut results = Vec::new();

    // Scaling in |Jqu| at the paper's network size.
    let fx = fixture(12, 4, 9);
    for qlen in [10usize, 40, 160, 640] {
        results.push(run_case(&format!("|Jqu|={qlen} (V=16, M=9)"), &fx, 9, qlen));
    }
    // Scaling in |V|.
    for (eds, ess) in [(6usize, 2usize), (12, 4), (24, 8), (48, 16)] {
        let fx = fixture(eds, ess, 9);
        results.push(run_case(
            &format!("V={} (|Jqu|=160, M=9)", eds + ess),
            &fx,
            9,
            160,
        ));
    }
    // Scaling in |Mlt|.
    for nl in [3usize, 9, 18] {
        let fx = fixture(12, 4, nl);
        results.push(run_case(&format!("M={nl} (V=16, |Jqu|=160)"), &fx, nl, 160));
    }
    print_table(
        "Algorithm 1 per-slot decision time — expect ~linear growth per axis (paper: O(M(1+|Jqu||V||Mlt|)))",
        &results,
    );
    // Budget context: a slot is 1 ms of simulated time; the decision must
    // stay well under typical deadline slack (tens of ms).
    println!("\ntarget: decision ≪ deadline slack (50–100 ms) at paper scale — see mean column.");
}
