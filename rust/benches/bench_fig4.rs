//! Fig. 4 — Proposal vs the PropAvg ablation under escalating system
//! loads (×1.0 / ×1.5 / ×2.0 arrival-mean multipliers): total and on-time
//! completion rates (bars ± std) and total system cost (markers).
//!
//! Run: `cargo bench --bench bench_fig4` (FMEDGE_TRIALS to override N).

use fmedge::baselines::{PropAvg, Proposal};
use fmedge::benchkit::print_data_table;
use fmedge::config::ExperimentConfig;
use fmedge::metrics::Summary;
use fmedge::sim::{run_trial, SimEnv, SimOptions, Strategy};

fn main() {
    let trials: usize = std::env::var("FMEDGE_TRIALS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(10);
    let mut cfg = ExperimentConfig::paper_default();
    cfg.sim.slots = 400;

    let mut rows = Vec::new();
    let mut csv =
        String::from("load,strategy,trial,completion_rate,on_time_rate,total_cost\n");
    for load in [1.0f64, 1.5, 2.0] {
        for name in ["Proposal", "PropAvg"] {
            let mut cr = Vec::new();
            let mut otr = Vec::new();
            let mut cost = Vec::new();
            for trial in 0..trials {
                let seed = cfg.sim.seed + trial as u64;
                let env = SimEnv::build(&cfg, seed);
                let mut s: Box<dyn Strategy> = match name {
                    "Proposal" => Box::new(Proposal::new()),
                    _ => Box::new(PropAvg::new()),
                };
                let mut opts = SimOptions::from_config(&cfg);
                opts.load_multiplier = load;
                let m = run_trial(&env, s.as_mut(), seed, &opts);
                csv.push_str(&format!(
                    "{load},{name},{trial},{:.6},{:.6},{:.2}\n",
                    m.completion_rate(),
                    m.on_time_rate(),
                    m.total_cost
                ));
                cr.push(m.completion_rate());
                otr.push(m.on_time_rate());
                cost.push(m.total_cost);
            }
            let scr = Summary::of(&cr);
            let sot = Summary::of(&otr);
            let sco = Summary::of(&cost);
            rows.push(vec![
                format!("×{load}"),
                name.to_string(),
                format!("{:.3}±{:.3}", scr.mean, scr.std),
                format!("{:.3}±{:.3}", sot.mean, sot.std),
                format!("{:.3}", scr.mean - sot.mean),
                format!("{:.0}", sco.mean),
            ]);
        }
    }
    print_data_table(
        "Fig. 4 — completion under escalating load (bars ± std; cost markers)",
        &[
            "load",
            "strategy",
            "total completion",
            "on-time completion",
            "total−on-time gap",
            "cost",
        ],
        &rows,
    );
    std::fs::create_dir_all("target").ok();
    std::fs::write("target/fig4.csv", csv).expect("write csv");
    println!("\nraw data -> target/fig4.csv");
    println!(
        "paper shape: both degrade with load; PropAvg stays slightly cheaper but\nits on-time rate falls faster and its total-vs-on-time gap widens."
    );
}
