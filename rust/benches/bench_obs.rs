//! O1 — observability overhead: the same faulted trial untraced, span
//! tracing only, and fully observed (spans + per-slot telemetry), on both
//! engines. The headline number is the observed-vs-untraced mean-time
//! ratio — the cost of `fmedge trace` — which stays small because the
//! hooks are `Option`-gated and allocate only when armed (the *disabled*
//! path is free by construction: the zero-overhead tests prove the
//! outputs bit-identical, this bench prices the *enabled* path).
//!
//! Run: `cargo bench --bench bench_obs` (FMEDGE_BENCH_ITERS to override;
//! `FMEDGE_BENCH_JSON=BENCH_obs.json` saves the perf-trajectory rows).

use fmedge::baselines::Proposal;
use fmedge::benchkit::{bench, fmt_duration, print_data_table, save_json};
use fmedge::config::ExperimentConfig;
use fmedge::des::{run_des_trial_faulted, run_des_trial_observed, DesOptions};
use fmedge::faults::{FaultEvent, FaultKind, FaultSchedule};
use fmedge::obs::Observer;
use fmedge::sim::{record_trace, run_trial_faulted, run_trial_observed, SimEnv, SimOptions};

fn zone_schedule(cfg: &ExperimentConfig, slot_ms: f64) -> FaultSchedule {
    let es = cfg.network.num_eds;
    FaultSchedule::from_events(vec![
        FaultEvent { time_ms: 30.0 * slot_ms, kind: FaultKind::NodeDown { node: es } },
        FaultEvent { time_ms: 32.0 * slot_ms, kind: FaultKind::NodeDown { node: es + 1 } },
        FaultEvent { time_ms: 70.0 * slot_ms, kind: FaultKind::NodeUp { node: es } },
        FaultEvent { time_ms: 72.0 * slot_ms, kind: FaultKind::NodeUp { node: es + 1 } },
    ])
}

fn main() {
    let iters: usize = std::env::var("FMEDGE_BENCH_ITERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(8);
    let mut cfg = ExperimentConfig::paper_default();
    cfg.sim.slots = 120;
    cfg.workload.num_users = 8;
    cfg.controller.effcap_samples = 512;
    cfg.sim.load_multiplier = 1.5;
    let seed = 61;
    let env = SimEnv::build(&cfg, seed);
    let opts = SimOptions::from_config(&cfg);
    let trace = record_trace(&env, seed, &opts);
    let schedule = zone_schedule(&cfg, opts.slot_ms);
    let dopts = DesOptions::from_sim(&opts);

    let mut rows = Vec::new();
    let headers = ["engine", "mode", "mean", "p95", "overhead vs off"];
    for engine in ["slotted", "des"] {
        let run = |obs_mode: u8| {
            // One closure per (engine, mode); the Observer is rebuilt per
            // iteration so recorder growth never compounds across runs.
            bench(&format!("{engine}/{obs_mode}"), 1, iters, || {
                let mut strat = Proposal::new();
                match (engine, obs_mode) {
                    ("slotted", 0) => {
                        run_trial_faulted(&env, &mut strat, seed, &opts, &trace, &schedule);
                    }
                    ("slotted", 1) => {
                        let mut obs = Observer::trace_only();
                        run_trial_observed(
                            &env, &mut strat, seed, &opts, &trace, &schedule, &mut obs,
                        );
                    }
                    ("slotted", _) => {
                        let mut obs = Observer::new();
                        run_trial_observed(
                            &env, &mut strat, seed, &opts, &trace, &schedule, &mut obs,
                        );
                    }
                    ("des", 0) => {
                        run_des_trial_faulted(&env, &mut strat, seed, &dopts, &trace, &schedule);
                    }
                    ("des", 1) => {
                        let mut obs = Observer::trace_only();
                        run_des_trial_observed(
                            &env, &mut strat, seed, &dopts, &trace, &schedule, &mut obs,
                        );
                    }
                    _ => {
                        let mut obs = Observer::new();
                        run_des_trial_observed(
                            &env, &mut strat, seed, &dopts, &trace, &schedule, &mut obs,
                        );
                    }
                }
            })
        };
        let off = run(0);
        let spans = run(1);
        let full = run(2);
        for (label, r) in [("off", &off), ("spans", &spans), ("spans+telemetry", &full)] {
            rows.push(vec![
                engine.to_string(),
                label.to_string(),
                fmt_duration(r.mean),
                fmt_duration(r.p95),
                format!("{:.3}x", r.mean_ns() / off.mean_ns()),
            ]);
        }
    }
    print_data_table("O1 — tracing/telemetry overhead per faulted trial", &headers, &rows);
    if let Ok(path) = std::env::var("FMEDGE_BENCH_JSON") {
        save_json(
            &path,
            "O1 — tracing/telemetry overhead per faulted trial",
            &headers,
            &rows,
        )
        .expect("write bench json");
        println!("\nbench rows saved to {path}");
    }
}
