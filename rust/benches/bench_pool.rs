//! P10 — elastic replica-pool overhead and scaling-event throughput.
//! Three layers:
//!
//! * **engine on/off**: the same faulted diurnal trial (both engines)
//!   with the pool tier off vs on — the off rows price the
//!   `Option`-gating overhead (target: indistinguishable from pre-pool),
//!   the on rows price shared-rate bookkeeping + policy stepping.
//! * **manager**: raw `PoolManager::step` throughput over a synthetic
//!   occupancy/backlog wave — scaling decisions/sec with warm-up queues
//!   and drain lists in play.
//!
//! Run: `cargo bench --bench bench_pool` (FMEDGE_BENCH_ITERS to
//! override; `FMEDGE_BENCH_JSON=BENCH_pool.json` saves the
//! perf-trajectory rows).

use fmedge::baselines::Proposal;
use fmedge::benchkit::{bench, fmt_duration, print_data_table, save_json};
use fmedge::config::ExperimentConfig;
use fmedge::des::{run_des_trial_faulted_in, DesArena, DesOptions};
use fmedge::pool::{Autoscale, PoolConfig, PoolManager};
use fmedge::scenarios::ScenarioSpec;
use fmedge::sim::{run_trial_faulted, SimEnv, SimOptions, Strategy};

fn main() {
    let iters: usize = std::env::var("FMEDGE_BENCH_ITERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(5);
    let headers = ["bench", "tasks", "mean", "p95", "note"];
    let mut rows = Vec::new();

    let mut cfg = ExperimentConfig::paper_default();
    cfg.workload.num_users = 16;
    cfg.controller.effcap_samples = 512;
    cfg.sim.slots = 200;
    let seed = 7u64;
    let env = SimEnv::build(&cfg, seed);
    let opts = SimOptions::from_config(&cfg);
    let cs = ScenarioSpec::by_name("diurnal")
        .expect("library scenario")
        .compile(&env, &opts, seed ^ 0xBE_0010);
    let mut pooled = opts.clone();
    pooled.pool = Some(PoolConfig::from_config(&cfg));

    // Engine rows: pool off vs on, slotted then DES, same paired fixture.
    let mut arena: DesArena = DesArena::new();
    for (name, pool_on, des) in [
        ("engine/slotted pool-off", false, false),
        ("engine/slotted pool-on", true, false),
        ("engine/des pool-off", false, true),
        ("engine/des pool-on", true, true),
    ] {
        let o = if pool_on { &pooled } else { &opts };
        let mut tasks = 0usize;
        let r = bench(name, 1, iters, || {
            let mut strategy: Box<dyn Strategy> = if pool_on {
                Box::new(Autoscale::new())
            } else {
                Box::new(Proposal::new())
            };
            let m = if des {
                run_des_trial_faulted_in(
                    &mut arena,
                    &env,
                    strategy.as_mut(),
                    seed,
                    &DesOptions::from_sim(o),
                    &cs.trace,
                    &cs.faults,
                )
            } else {
                run_trial_faulted(&env, strategy.as_mut(), seed, o, &cs.trace, &cs.faults)
            };
            tasks = m.total_tasks;
        });
        rows.push(vec![
            name.to_string(),
            tasks.to_string(),
            fmt_duration(r.mean),
            fmt_duration(r.p95),
            if pool_on { "elastic tier armed" } else { "gating overhead only" }.to_string(),
        ]);
    }

    // Manager row: raw scaling-decision throughput. A deterministic
    // occupancy wave drives grow, shrink, and scale-to-zero branches;
    // one "event" is one PoolManager::step call.
    let (nv, nl, steps) = (16usize, 4usize, 50_000usize);
    let mut scale_events = 0u64;
    let name = "manager/step wave";
    let r = bench(name, 1, iters, || {
        let mut pm = PoolManager::new(nv, nl, PoolConfig::from_config(&cfg), seed);
        let mut grown = Vec::new();
        for s in 0..steps {
            let now = s as f64 * 10.0;
            // Triangle wave: ramp occupancy 0..8 and back, per station.
            let phase = s % 32;
            let occ = if phase < 16 { phase as u32 / 2 } else { (31 - phase) as u32 / 2 };
            for v in 0..nv {
                for m in 0..nl {
                    pm.promote_ready_all(now);
                    pm.step(v, m, occ, occ / 2, now, &mut grown);
                }
            }
            pm.end_slot(10.0);
        }
        scale_events = pm.scale_events;
    });
    let calls = (steps * nv * nl) as f64;
    let cps = calls / (r.mean_ns() / 1e9);
    rows.push(vec![
        name.to_string(),
        format!("{scale_events} scale events"),
        fmt_duration(r.mean),
        fmt_duration(r.p95),
        format!("{cps:.3e} step calls/sec"),
    ]);

    let title = "pool perf — elastic tier on/off overhead and scaling throughput";
    print_data_table(title, &headers, &rows);
    if let Ok(path) = std::env::var("FMEDGE_BENCH_JSON") {
        save_json(&path, title, &headers, &rows).expect("save bench json");
        println!("\nbench rows saved to {path}");
    }
}
