//! Fig. 3 — violin-plot comparison of on-time completion rate and total
//! system cost across the four deployment strategies.
//!
//! Regenerates the figure's data: N independent trials per strategy on
//! freshly sampled Table-I environments; emits per-strategy summary rows,
//! the KDE violin series, and a CSV (`target/fig3.csv`) for plotting.
//!
//! Run: `cargo bench --bench bench_fig3` (FMEDGE_TRIALS to override N).

use fmedge::baselines::{GaStrategy, LbrrStrategy, PropAvg, Proposal};
use fmedge::benchkit::print_data_table;
use fmedge::config::ExperimentConfig;
use fmedge::metrics::{kde_violin, Summary};
use fmedge::sim::{run_trial, SimEnv, SimOptions, Strategy};

fn main() {
    let trials: usize = std::env::var("FMEDGE_TRIALS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(24);
    let mut cfg = ExperimentConfig::paper_default();
    cfg.sim.slots = 400;
    // Fig. 3's operating point: moderate contention. At very light load
    // every strategy (including deadline-agnostic LBRR) over-provisions
    // its way to ~99% on-time; the paper's regime separation appears once
    // capacity is contended (see bench_fig4 for the full load sweep).
    cfg.sim.load_multiplier = 1.4;

    let mut rows = Vec::new();
    let mut csv = String::from("strategy,trial,on_time_rate,completion_rate,total_cost\n");
    for name in ["Proposal", "PropAvg", "LBRR", "GA"] {
        let mut otr = Vec::new();
        let mut cost = Vec::new();
        for trial in 0..trials {
            let seed = cfg.sim.seed + trial as u64;
            let env = SimEnv::build(&cfg, seed);
            let mut s: Box<dyn Strategy> = match name {
                "Proposal" => Box::new(Proposal::new()),
                "PropAvg" => Box::new(PropAvg::new()),
                "LBRR" => Box::new(LbrrStrategy::new()),
                _ => Box::new(GaStrategy::new(16, 12)),
            };
            let m = run_trial(&env, s.as_mut(), seed, &SimOptions::from_config(&cfg));
            csv.push_str(&format!(
                "{name},{trial},{:.6},{:.6},{:.2}\n",
                m.on_time_rate(),
                m.completion_rate(),
                m.total_cost
            ));
            otr.push(m.on_time_rate());
            cost.push(m.total_cost);
        }
        let so = Summary::of(&otr);
        let sc = Summary::of(&cost);
        // Violin compactness: inter-quartile range over the median.
        let iqr = so.q75 - so.q25;
        rows.push(vec![
            name.to_string(),
            format!("{:.3}", so.mean),
            format!("{:.3}", so.median),
            format!("{:.3}", so.q25),
            format!("{:.3}", so.q75),
            format!("{:.3}", iqr),
            format!("{:.0}", sc.mean),
            format!("{:.0}", sc.std),
        ]);
        // Emit the violin density series (16-point summary for the log).
        let v = kde_violin(&otr, 16);
        let series: Vec<String> = v
            .grid
            .iter()
            .zip(&v.density)
            .map(|(g, d)| format!("{g:.2}:{d:.2}"))
            .collect();
        println!("violin[{name}] on-time density: {}", series.join(" "));
    }
    print_data_table(
        "Fig. 3 — on-time completion rate & total cost (distribution over trials)",
        &[
            "strategy", "mean", "median", "q25", "q75", "IQR", "cost mean", "cost std",
        ],
        &rows,
    );
    std::fs::create_dir_all("target").ok();
    std::fs::write("target/fig3.csv", csv).expect("write csv");
    println!("\nraw data -> target/fig3.csv");
    println!(
        "paper shape: Proposal compact & high (>84% on-time, moderate cost);\nLBRR low-cost/low-QoS; GA widest spread; PropAvg cheaper with a longer lower tail."
    );
}
