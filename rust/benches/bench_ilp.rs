//! P1 — static placement solver study (§III-A): solve time and objective
//! across methods (greedy cover, LP+rounding, exact branch-and-bound) and
//! the κ diversity trade-off the paper discusses after (16).
//!
//! Run: `cargo bench --bench bench_ilp`.

use std::time::{Duration, Instant};

use fmedge::benchkit::{bench_budget, fmt_duration, print_data_table, print_table};
use fmedge::config::ExperimentConfig;
use fmedge::ilp::NodeLpMode;
use fmedge::placement::{solve_static_placement, PlacementParams, QosScores, ScoreParams};
use fmedge::rng::Xoshiro256;
use fmedge::sim::SimEnv;
use fmedge::workload::WorkloadGenerator;

fn scores_for(cfg: &ExperimentConfig, seed: u64) -> (SimEnv, QosScores) {
    let env = SimEnv::build(cfg, seed);
    let gen = WorkloadGenerator::new(
        cfg,
        &env.app,
        &env.topo,
        &mut Xoshiro256::seed_from(env.users_seed),
    );
    let scores = QosScores::compute(
        &env.app,
        &env.topo,
        &env.dm,
        gen.users(),
        &ScoreParams::from_config(&cfg.controller),
    );
    (env, scores)
}

fn main() {
    let cfg = ExperimentConfig::paper_default();
    let (env, scores) = scores_for(&cfg, 7);
    let base = PlacementParams::from_config(&cfg, cfg.sim.slots);

    // --- method comparison: time + objective + support ------------------
    let mut rows = Vec::new();
    for (name, exact, fallback) in [
        ("greedy cover", false, true),
        ("LP + rounding (default)", false, false),
        ("exact B&B (warm-started)", true, false),
    ] {
        let mut p = base.clone();
        p.exact = exact;
        p.force_fallback = fallback;
        let t0 = Instant::now();
        let sol = solve_static_placement(&env.app, &env.topo, &scores, &p);
        let dt = t0.elapsed();
        rows.push(vec![
            name.to_string(),
            fmt_duration(dt),
            format!("{:.1}", sol.objective),
            format!("{}", sol.total_instances()),
            format!("{}", sol.support),
        ]);
    }
    print_data_table(
        "P1 — placement methods on the paper-scale instance (16 nodes × 6 core MSs)",
        &["method", "solve time", "objective (14)", "instances", "support"],
        &rows,
    );

    // --- warm-start A/B: per-node LP cost at equal node budget ----------
    // The before/after table for the revised-simplex warm-start change:
    // identical objectives are required; the speedup shows up in total
    // solve time and in time per branch-and-bound node.
    let mut rows = Vec::new();
    for (name, mode) in [
        ("dense rebuild (baseline)", NodeLpMode::DenseRebuild),
        ("warm revised (this PR)", NodeLpMode::WarmRevised),
    ] {
        let mut p = base.clone();
        p.exact = true;
        p.node_lp = mode;
        let t0 = Instant::now();
        let sol = solve_static_placement(&env.app, &env.topo, &scores, &p);
        let dt = t0.elapsed();
        let (nodes, lp_solves, warm, cold) = sol
            .stats
            .map(|s| (s.nodes_explored, s.lp_solves, s.warm_solves, s.cold_solves))
            .unwrap_or((0, 0, 0, 0));
        let per_node = if nodes > 0 {
            fmt_duration(dt / nodes as u32)
        } else {
            "-".to_string()
        };
        rows.push(vec![
            name.to_string(),
            fmt_duration(dt),
            per_node,
            format!("{nodes}"),
            format!("{lp_solves}"),
            format!("{warm}/{cold}"),
            format!("{:.1}", sol.objective),
        ]);
    }
    print_data_table(
        "P1b — exact B&B node-LP engine A/B (equal node budget; objectives must match)",
        &[
            "engine",
            "total",
            "time/node",
            "nodes",
            "LP solves",
            "warm/cold",
            "objective (14)",
        ],
        &rows,
    );

    // --- κ trade-off ------------------------------------------------------
    let mut rows = Vec::new();
    for kappa in [2usize, 4, 8, 12, 16, 20] {
        let mut p = base.clone();
        p.kappa = kappa;
        let sol = solve_static_placement(&env.app, &env.topo, &scores, &p);
        rows.push(vec![
            format!("{kappa}"),
            format!("{:.1}", sol.objective),
            format!("{}", sol.total_instances()),
            format!("{}", sol.support),
        ]);
    }
    print_data_table(
        "κ (C6) trade-off — diversity vs objective value",
        &["kappa", "objective (14)", "instances", "support"],
        &rows,
    );

    // --- scaling in network size (default pipeline) ----------------------
    let mut results = Vec::new();
    for (eds, ess) in [(6usize, 2usize), (12, 4), (24, 8), (48, 16)] {
        let mut cfg2 = cfg.clone();
        cfg2.network.num_eds = eds;
        cfg2.network.num_ess = ess;
        let (env2, scores2) = scores_for(&cfg2, 11);
        let p = PlacementParams::from_config(&cfg2, cfg2.sim.slots);
        results.push(bench_budget(
            &format!("LP+round V={}", eds + ess),
            Duration::from_millis(250),
            || {
                let s = solve_static_placement(&env2.app, &env2.topo, &scores2, &p);
                std::hint::black_box(s.objective);
            },
        ));
    }
    print_table("placement solve time vs network size", &results);
}
