//! D1 — DES hot-path throughput (EXPERIMENTS §P8). Two layers:
//!
//! * **calendar**: push + pop of a uniform-random event set on the
//!   production radix calendar vs the binary-heap reference. One event
//!   is one `schedule` + one `pop`; the PR-8 acceptance target is
//!   >= 1e7 events/sec single-thread on the radix row.
//! * **engine**: a full faulted trial, retained vs streaming metrics,
//!   with the `DesArena` reused across iterations — the steady-state
//!   shape the sweep orchestrator runs in, so allocation amortizes the
//!   same way here as there.
//!
//! Run: `cargo bench --bench bench_des` (FMEDGE_BENCH_ITERS /
//! FMEDGE_BENCH_EVENTS to override; `FMEDGE_BENCH_JSON=BENCH_des.json`
//! saves the perf-trajectory rows).

use fmedge::baselines::Proposal;
use fmedge::benchkit::{bench, fmt_duration, print_data_table, save_json};
use fmedge::config::ExperimentConfig;
use fmedge::des::{
    run_des_trial_faulted_in, DesArena, DesOptions, EventCalendar, EventKind, HeapCalendar,
    RadixCalendar,
};
use fmedge::faults::{FaultEvent, FaultKind, FaultSchedule};
use fmedge::rng::{Rng, Xoshiro256};
use fmedge::sim::{record_trace, SimEnv, SimOptions};

fn zone_schedule(cfg: &ExperimentConfig, slot_ms: f64) -> FaultSchedule {
    let es = cfg.network.num_eds;
    FaultSchedule::from_events(vec![
        FaultEvent { time_ms: 30.0 * slot_ms, kind: FaultKind::NodeDown { node: es } },
        FaultEvent { time_ms: 32.0 * slot_ms, kind: FaultKind::NodeDown { node: es + 1 } },
        FaultEvent { time_ms: 70.0 * slot_ms, kind: FaultKind::NodeUp { node: es } },
        FaultEvent { time_ms: 72.0 * slot_ms, kind: FaultKind::NodeUp { node: es + 1 } },
    ])
}

fn churn<C: EventCalendar + Default>(times: &[f64]) -> u64 {
    let mut cal = C::default();
    for &t in times {
        cal.schedule(t, EventKind::Decide);
    }
    let mut last = f64::NEG_INFINITY;
    while let Some(ev) = cal.pop() {
        debug_assert!(ev.time_ms >= last, "calendar must pop in order");
        last = ev.time_ms;
    }
    cal.processed()
}

fn main() {
    let iters: usize = std::env::var("FMEDGE_BENCH_ITERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(8);
    let n: usize = std::env::var("FMEDGE_BENCH_EVENTS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1_000_000);
    let headers = ["bench", "events", "mean", "p95", "events/sec"];
    let mut rows = Vec::new();

    // The time stream is generated once up front: the bench prices the
    // calendar, not the RNG.
    let mut rng = Xoshiro256::seed_from(0xBE7C);
    let times: Vec<f64> = (0..n).map(|_| rng.next_f64() * 10_000.0).collect();
    for (name, runner) in [
        ("calendar/radix push+pop", churn::<RadixCalendar> as fn(&[f64]) -> u64),
        ("calendar/heap push+pop", churn::<HeapCalendar> as fn(&[f64]) -> u64),
    ] {
        let r = bench(name, 1, iters, || {
            std::hint::black_box(runner(std::hint::black_box(&times)));
        });
        let evs = n as f64 / (r.mean_ns() / 1e9);
        rows.push(vec![
            name.to_string(),
            n.to_string(),
            fmt_duration(r.mean),
            fmt_duration(r.p95),
            format!("{evs:.3e}"),
        ]);
    }

    let mut cfg = ExperimentConfig::paper_default();
    cfg.sim.slots = 120;
    cfg.workload.num_users = 32;
    cfg.controller.effcap_samples = 512;
    cfg.sim.load_multiplier = 1.5;
    let seed = 61;
    let env = SimEnv::build(&cfg, seed);
    let opts = SimOptions::from_config(&cfg);
    let trace = record_trace(&env, seed, &opts);
    let schedule = zone_schedule(&cfg, opts.slot_ms);
    let mut arena: DesArena = DesArena::new();
    for streaming in [false, true] {
        let mut dopts = DesOptions::from_sim(&opts);
        dopts.streaming = streaming;
        let name = format!(
            "engine/faulted {}",
            if streaming { "streaming" } else { "retained" }
        );
        let mut events = 0u64;
        let r = bench(&name, 1, iters, || {
            let mut strat = Proposal::new();
            let m = run_des_trial_faulted_in(
                &mut arena, &env, &mut strat, seed, &dopts, &trace, &schedule,
            );
            events = m.des_events;
        });
        let evs = events as f64 / (r.mean_ns() / 1e9);
        rows.push(vec![
            name,
            events.to_string(),
            fmt_duration(r.mean),
            fmt_duration(r.p95),
            format!("{evs:.3e}"),
        ]);
    }

    let title = "D1 — calendar push/pop and DES engine throughput";
    print_data_table(title, &headers, &rows);
    if let Ok(path) = std::env::var("FMEDGE_BENCH_JSON") {
        save_json(&path, title, &headers, &rows).expect("write bench json");
        println!("\nbench rows saved to {path}");
    }
}
