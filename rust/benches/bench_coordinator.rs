//! C1 — serving-coordinator hot path: batcher throughput, end-to-end
//! request latency through the worker pool (with and without real PJRT
//! compute), and sustained throughput under open-loop load.
//!
//! Run: `make artifacts && cargo bench --bench bench_coordinator`.

use std::time::{Duration, Instant};

use fmedge::benchkit::{bench, print_data_table, print_table, save_json};
use fmedge::coordinator::{BatchPolicy, Batcher, Coordinator, Request, ServeConfig};
use fmedge::rng::{Rng, Xoshiro256};
use fmedge::runtime::shapes;

fn mk_request(id: u64, rng: &mut Xoshiro256) -> Request {
    let n = shapes::MSBLOCK_L * shapes::MSBLOCK_D;
    Request {
        id,
        data: (0..n).map(|_| rng.next_f64() as f32).collect(),
        submitted: Instant::now(),
        deadline_ms: 50.0,
    }
}

fn serve_run(real_compute: bool, requests: usize, rate_rps: f64) -> (f64, f64, f64, f64) {
    let coordinator = Coordinator::start(ServeConfig {
        workers: 3,
        real_compute,
        batch: BatchPolicy::default(),
        ..Default::default()
    })
    .expect("start");
    // Warm-up: let workers compile their executables off the clock.
    std::thread::sleep(Duration::from_millis(if real_compute { 400 } else { 50 }));
    let mut rng = Xoshiro256::seed_from(5);
    let gap = Duration::from_secs_f64(1.0 / rate_rps);
    for id in 0..requests as u64 {
        let _ = coordinator.submit(mk_request(id, &mut rng));
        std::thread::sleep(gap);
    }
    let report = coordinator.shutdown();
    (
        report.throughput_rps(),
        report.latency_ms.median,
        report.latency_ms.q75,
        report.batch_fill,
    )
}

fn main() {
    // --- batcher micro-benchmark -----------------------------------------
    let mut rng = Xoshiro256::seed_from(1);
    let reqs: Vec<Request> = (0..4096).map(|i| mk_request(i, &mut rng)).collect();
    let mut results = Vec::new();
    results.push(bench("batcher push/flush 4096 reqs", 3, 30, || {
        let mut b = Batcher::new(BatchPolicy {
            max_batch: shapes::MSBLOCK_B,
            max_wait: Duration::from_millis(2),
        });
        let mut batches = 0usize;
        for r in &reqs {
            if b.push(r.clone()).is_some() {
                batches += 1;
            }
        }
        std::hint::black_box(batches);
    }));
    print_table("coordinator micro-benchmarks", &results);

    // --- end-to-end serving ------------------------------------------------
    let mut rows = Vec::new();
    for (name, real, requests, rate) in [
        ("harness only (no compute)", false, 1200, 4000.0),
        ("PJRT msblock, light load", true, 400, 150.0),
        ("PJRT msblock, near saturation", true, 600, 400.0),
    ] {
        let (tput, p50, p75, fill) = serve_run(real, requests, rate);
        rows.push(vec![
            name.to_string(),
            format!("{rate:.0}"),
            format!("{tput:.0}"),
            format!("{p50:.2}"),
            format!("{p75:.2}"),
            format!("{fill:.2}"),
        ]);
    }
    let headers = [
        "case",
        "offered rps",
        "served rps",
        "p50 ms",
        "p75 ms",
        "batch fill",
    ];
    print_data_table("C1 — serving coordinator under open-loop load", &headers, &rows);
    // `FMEDGE_BENCH_JSON=BENCH_serve.json cargo bench --bench
    // bench_coordinator` records the rows as a perf-trajectory artifact.
    if let Ok(path) = std::env::var("FMEDGE_BENCH_JSON") {
        save_json(
            &path,
            "C1 — serving coordinator under open-loop load",
            &headers,
            &rows,
        )
        .expect("write bench json");
        println!("\nbench rows saved to {path}");
    }
    println!("\ntarget: harness overhead ≪ 1 ms median; PJRT path p50 in single-digit ms off saturation.");
}
