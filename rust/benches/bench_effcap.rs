//! E1 — effective-capacity model: estimator accuracy against the Gamma
//! closed form and g-table build throughput, native vs PJRT-accelerated
//! (the Layer-1 Pallas kernel through the AOT path).
//!
//! Run: `make artifacts && cargo bench --bench bench_effcap`.

use std::time::Duration;

use fmedge::benchkit::{bench_budget, print_data_table, print_table};
use fmedge::effcap::{effective_capacity, GTable, GTableParams};
use fmedge::rng::{Distribution, Gamma, Xoshiro256};
use fmedge::runtime::{EffCapAccel, Runtime};

fn main() {
    // --- accuracy vs the closed form -------------------------------------
    let g = Gamma::new(1.5, 10.0);
    let mut rng = Xoshiro256::seed_from(3);
    let samples = g.sample_n(&mut rng, 4096);
    let mut rows = Vec::new();
    for theta in [0.01, 0.1, 0.5, 1.0, 3.0, 10.0] {
        let est = effective_capacity(&samples, theta);
        let exact = g.effective_capacity(theta, 1.0);
        rows.push(vec![
            format!("{theta}"),
            format!("{est:.4}"),
            format!("{exact:.4}"),
            format!("{:.2}%", 100.0 * (est - exact).abs() / exact),
        ]);
    }
    print_data_table(
        "E1 — sampled Ê^c(θ) vs Gamma closed form k·ln(1+θs)/θ (S=4096)",
        &["theta", "estimate", "closed form", "rel err"],
        &rows,
    );

    // --- build throughput: native vs PJRT --------------------------------
    let params = GTableParams::default_paper();
    let mut samples9 = Vec::new();
    let mut workloads = Vec::new();
    for i in 0..9 {
        samples9.push(Gamma::new(1.2 + 0.1 * i as f64, 6.0).sample_n(&mut rng, 4096));
        workloads.push(1.0 + 0.1 * i as f64);
    }
    let mut results = Vec::new();
    results.push(bench_budget(
        "native g-table (9 MS × 16 y × 32 θ × 4096 samples)",
        Duration::from_millis(600),
        || {
            let t = GTable::build(&samples9, &workloads, &params);
            std::hint::black_box(t.delay(0, 1));
        },
    ));
    match Runtime::cpu(Runtime::default_dir()).and_then(|rt| EffCapAccel::load(&rt)) {
        Ok(accel) => {
            results.push(bench_budget(
                "PJRT g-table (same workload, AOT Pallas kernel)",
                Duration::from_millis(600),
                || {
                    let t = accel.build_gtable(&samples9, &workloads).expect("accel");
                    std::hint::black_box(t.delay(0, 1));
                },
            ));
        }
        Err(e) => println!("(PJRT path skipped: {e})"),
    }
    print_table("g-table build time", &results);
    println!("\ntarget (DESIGN.md §Perf): planning-time rebuild well under a second.");
}
