//! Determinism-lint integration tests (EXPERIMENTS §P9): every rule
//! fires on a minimal fixture and stays quiet on the blessed idiom,
//! inline allow directives suppress (and go stale loudly), baselines
//! round-trip, and — the gate the others exist for — the repo's own
//! tree lints clean against the checked-in baseline.

use fmedge::analysis::{lint_source, Baseline, Rule};

/// Findings for a fixture placed at a virtual path (the path keys the
/// module-scoped rules exactly as it does on disk).
fn findings(path: &str, src: &str) -> Vec<(Rule, u32)> {
    lint_source(path, src).into_iter().map(|f| (f.rule, f.line)).collect()
}

fn rules_fired(path: &str, src: &str) -> Vec<Rule> {
    findings(path, src).into_iter().map(|(r, _)| r).collect()
}

// --- hash-iter -----------------------------------------------------------

#[test]
fn hash_iter_fires_in_deterministic_module() {
    let src = "fn f() { let m: HashMap<u64, f64> = HashMap::new(); }\n";
    assert_eq!(rules_fired("rust/src/sim/fixture.rs", src), vec![Rule::HashIter]);
    // Same source outside the deterministic set: silent.
    assert!(rules_fired("rust/src/obs/fixture.rs", src).is_empty());
    assert!(rules_fired("rust/tests/fixture.rs", src).is_empty());
}

#[test]
fn hash_iter_skips_use_statements_including_groups() {
    let src = "use std::collections::HashMap;\n\
               use std::collections::{BinaryHeap, HashMap, HashSet};\n";
    assert!(rules_fired("rust/src/des/fixture.rs", src).is_empty());
}

#[test]
fn hash_iter_discharged_by_nearby_sort() {
    let src = "fn f(m: &HashMap<u64, f64>) -> Vec<u64> {\n\
                   let mut ids: Vec<u64> = m.keys().cloned().collect();\n\
                   ids.sort_unstable();\n\
                   ids\n\
               }\n";
    assert!(rules_fired("rust/src/sim/fixture.rs", src).is_empty());
}

#[test]
fn hash_iter_ignores_strings_and_comments() {
    let src = "// HashMap in a comment\n\
               fn f() -> &'static str { \"HashMap::new()\" }\n";
    assert!(rules_fired("rust/src/sim/fixture.rs", src).is_empty());
}

// --- wall-clock ----------------------------------------------------------

#[test]
fn wall_clock_fires_outside_allowlist_only() {
    let src = "fn f() { let t = std::time::Instant::now(); }\n";
    assert_eq!(rules_fired("rust/src/sim/fixture.rs", src), vec![Rule::WallClock]);
    assert_eq!(rules_fired("rust/src/obs/fixture.rs", src), vec![Rule::WallClock]);
    // The serving path, benches, and examples legitimately read the clock.
    assert!(rules_fired("rust/src/coordinator/fixture.rs", src).is_empty());
    assert!(rules_fired("rust/benches/fixture.rs", src).is_empty());
    assert!(rules_fired("examples/fixture.rs", src).is_empty());
    assert!(rules_fired("rust/src/main.rs", src).is_empty());

    let sys = "fn f() { let t = std::time::SystemTime::now(); }\n";
    assert_eq!(rules_fired("rust/src/metrics/fixture.rs", sys), vec![Rule::WallClock]);
}

// --- float-cmp -----------------------------------------------------------

#[test]
fn float_cmp_fires_on_panicking_comparators() {
    let unwrap = "fn f(v: &mut [f64]) { v.sort_by(|a, b| a.partial_cmp(b).unwrap()); }\n";
    assert_eq!(rules_fired("rust/src/metrics/fixture.rs", unwrap), vec![Rule::FloatCmp]);
    let expect =
        "fn f(v: &mut [f64]) { v.sort_by(|a, b| a.partial_cmp(b).expect(\"nan\")); }\n";
    assert_eq!(rules_fired("rust/src/sim/fixture.rs", expect), vec![Rule::FloatCmp]);
    // The rule is module-agnostic: a NaN panic in a test helper is still
    // a NaN panic.
    assert_eq!(rules_fired("rust/tests/fixture.rs", unwrap), vec![Rule::FloatCmp]);
}

#[test]
fn float_cmp_blesses_total_cmp_and_unwrap_or() {
    let src = "fn f(v: &mut [f64]) { v.sort_by(f64::total_cmp); }\n\
               fn g(a: f64, b: f64) -> std::cmp::Ordering {\n\
                   a.partial_cmp(&b).unwrap_or(std::cmp::Ordering::Equal)\n\
               }\n";
    assert!(rules_fired("rust/src/metrics/fixture.rs", src).is_empty());
}

// --- rng-discipline ------------------------------------------------------

#[test]
fn rng_discipline_fires_on_bare_literal_seeds() {
    let src = "fn f() { let mut rng = Xoshiro256::seed_from(42); }\n";
    assert_eq!(rules_fired("rust/src/sim/fixture.rs", src), vec![Rule::RngDiscipline]);
    // Outside the RNG-scoped modules the rule does not apply.
    assert!(rules_fired("rust/src/faults/fixture.rs", src).is_empty());
}

#[test]
fn rng_discipline_blesses_derived_seeds_and_test_regions() {
    let derived = "fn f(seed: u64) {\n\
                   let mut a = Xoshiro256::seed_from(seed ^ 0xE17E_5EED);\n\
                   let mut b = Xoshiro256::seed_from(stream_seed(seed, STREAM_ARRIVALS, 0));\n\
                   }\n";
    assert!(rules_fired("rust/src/scenarios/fixture.rs", derived).is_empty());
    // Pinned literal seeds are the point of a test.
    let tests = "#[cfg(test)]\n\
                 mod tests {\n\
                     #[test]\n\
                     fn pinned() { let mut rng = Xoshiro256::seed_from(7); }\n\
                 }\n";
    assert!(rules_fired("rust/src/sim/fixture.rs", tests).is_empty());
}

// --- unsafe-forbid -------------------------------------------------------

#[test]
fn unsafe_forbid_fires_everywhere() {
    let src = "fn f(p: *const u8) -> u8 { unsafe { *p } }\n";
    assert_eq!(rules_fired("rust/src/rng/fixture.rs", src), vec![Rule::UnsafeForbid]);
    assert_eq!(rules_fired("examples/fixture.rs", src), vec![Rule::UnsafeForbid]);
    // …but never from inside a string or comment.
    let masked = "// unsafe in prose\nfn f() -> &'static str { \"unsafe\" }\n";
    assert!(rules_fired("rust/src/rng/fixture.rs", masked).is_empty());
}

// --- allow directives ----------------------------------------------------

#[test]
fn allow_directive_suppresses_on_line_or_line_above() {
    let above = "// lint: allow(hash-iter): membership-only, never iterated\n\
                 fn f() { let m: HashMap<u64, f64> = HashMap::new(); }\n";
    assert!(rules_fired("rust/src/sim/fixture.rs", above).is_empty());
    let inline = "fn f() { let m: HashSet<u64> = HashSet::new(); } \
                  // lint: allow(hash-iter): membership-only\n";
    assert!(rules_fired("rust/src/sim/fixture.rs", inline).is_empty());
}

#[test]
fn reasonless_allow_suppresses_nothing_and_is_flagged() {
    let src = "// lint: allow(hash-iter)\n\
               fn f() { let m: HashMap<u64, f64> = HashMap::new(); }\n";
    let got = rules_fired("rust/src/sim/fixture.rs", src);
    assert!(got.contains(&Rule::HashIter), "finding must survive: {got:?}");
    assert!(got.contains(&Rule::StaleAllow), "directive must be flagged: {got:?}");
}

#[test]
fn stale_allow_fires_when_nothing_is_suppressed() {
    let src = "// lint: allow(wall-clock): leftover from a removed timer\n\
               fn f() { let x = 1; }\n";
    assert_eq!(rules_fired("rust/src/sim/fixture.rs", src), vec![Rule::StaleAllow]);
}

#[test]
fn wrong_rule_in_allow_does_not_suppress() {
    let src = "// lint: allow(wall-clock): wrong rule named\n\
               fn f() { let m: HashMap<u64, f64> = HashMap::new(); }\n";
    let got = rules_fired("rust/src/sim/fixture.rs", src);
    assert!(got.contains(&Rule::HashIter));
    assert!(got.contains(&Rule::StaleAllow));
}

// --- baseline ------------------------------------------------------------

#[test]
fn baseline_round_trips_and_filters() {
    let src = "fn f() { let m: HashMap<u64, f64> = HashMap::new(); }\n";
    let found = lint_source("rust/src/sim/fixture.rs", src);
    assert_eq!(found.len(), 1);
    let mut b = Baseline::from_findings(&found);
    assert_eq!(b.entries.len(), 1);
    b.entries[0].justification = "fixture: accepted for the round-trip test".to_string();

    let reparsed = Baseline::parse(&b.render()).expect("rendered baseline must parse");
    assert_eq!(reparsed.entries, b.entries);

    // Baselined finding is absorbed; an unrelated finding is new.
    let r = reparsed.filter(found);
    assert!(r.new.is_empty(), "baselined finding leaked: {:?}", r.new);
    assert_eq!(r.suppressed, 1);
    assert!(r.stale.is_empty());

    let other = lint_source(
        "rust/src/des/fixture.rs",
        "fn g() { let s: HashSet<u64> = HashSet::new(); }\n",
    );
    let r = reparsed.filter(other);
    assert_eq!(r.new.len(), 1, "unrelated finding must gate");
    assert_eq!(r.suppressed, 0);
    assert_eq!(r.stale.len(), 1, "unused entry must be reported stale");
}

#[test]
fn baseline_rejects_missing_justification_and_unknown_rules() {
    let no_why = "hash-iter @ rust/src/sim/x.rs @ let m = HashMap::new();\n";
    assert!(Baseline::parse(no_why).is_err(), "justification is mandatory");
    let bad_rule = "no-such-rule @ f.rs @ x # because\n";
    assert!(Baseline::parse(bad_rule).is_err());
    let comments_ok = "# a comment\n\n  # another\n";
    assert!(Baseline::parse(comments_ok).unwrap().entries.is_empty());
}

#[test]
fn baseline_matches_on_snippet_not_line_number() {
    // The same hazard, shifted three lines down by unrelated edits,
    // still matches its baseline entry.
    let v1 = "fn f() { let m: HashMap<u64, f64> = HashMap::new(); }\n";
    let v2 = "// new\n// comment\n// block\n\
              fn f() { let m: HashMap<u64, f64> = HashMap::new(); }\n";
    let mut b = Baseline::from_findings(&lint_source("rust/src/sim/fixture.rs", v1));
    b.entries[0].justification = "fixture".to_string();
    let r = b.filter(lint_source("rust/src/sim/fixture.rs", v2));
    assert!(r.new.is_empty(), "line shift must not invalidate the entry");
    assert_eq!(r.suppressed, 1);
}

// --- the repo gate -------------------------------------------------------

#[test]
fn repo_lints_clean_against_checked_in_baseline() {
    // Cargo runs integration tests with cwd = the `rust/` directory, so
    // the repo root is one level up — the same discovery `fmedge lint`
    // uses when invoked without --root.
    let root = fmedge::analysis::detect_root().expect("repo root");
    let baseline_path = root.join(fmedge::analysis::DEFAULT_BASELINE);
    let baseline = match std::fs::read_to_string(&baseline_path) {
        Ok(text) => Some(Baseline::parse(&text).expect("checked-in baseline must parse")),
        Err(_) => None,
    };
    let report = fmedge::analysis::run_lint(&root, baseline.as_ref()).expect("lint run");
    assert!(report.files > 0, "scan must find the crate sources");
    assert!(
        report.clean(),
        "the tree must lint clean — new findings:\n{}",
        report.render()
    );
    assert!(
        report.stale_baseline.is_empty(),
        "stale baseline entries: {:?}",
        report.stale_baseline
    );
}
