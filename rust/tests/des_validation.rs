//! Integration test for the acceptance criterion of the DES subsystem:
//! with the controller's admissions active, the measured per-light-service
//! delay-violation rate must respect the effective-capacity guarantee —
//! `P(sojourn > g_{m,ε}(y)) ≤ ε` — at ε = 0.05 across multiple seeds,
//! within a small Monte-Carlo tolerance.

use fmedge::baselines::Proposal;
use fmedge::config::ExperimentConfig;
use fmedge::des::{pool, run_des_trial, validate_bounds, DesOptions};
use fmedge::sim::{record_trace, run_trial_traced, SimEnv, SimOptions};

fn eps005_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::paper_default();
    cfg.controller.epsilon = 0.05;
    cfg.sim.slots = 200;
    cfg.workload.num_users = 8;
    cfg.controller.effcap_samples = 2048;
    cfg
}

#[test]
fn measured_violation_rates_respect_eps_005_across_seeds() {
    let cfg = eps005_cfg();
    let eps = cfg.controller.epsilon;
    let mut per_trial = Vec::new();
    let mut total_tasks = 0usize;
    for seed in [11u64, 23, 37] {
        let env = SimEnv::build(&cfg, seed);
        let opts = SimOptions::from_config(&cfg);
        let trace = record_trace(&env, seed, &opts);
        assert!(!trace.is_empty(), "seed {seed}: empty trace");
        total_tasks += trace.len();
        let m = run_des_trial(
            &env,
            &mut Proposal::new(),
            seed,
            &DesOptions::from_sim(&opts),
            &trace,
        );
        assert_eq!(m.total_tasks, trace.len());
        let vals = validate_bounds(&env.gtable, &m);
        // Per-seed, per-service check with sample-size-aware Monte-Carlo
        // slack (two binomial sigmas on top of a fixed margin): services
        // with enough executions must sit at or below eps + tolerance.
        for v in &vals {
            if v.samples >= 50 {
                let sigma = (eps * (1.0 - eps) / v.samples as f64).sqrt();
                assert!(
                    v.holds(0.05 + 2.0 * sigma),
                    "seed {seed} light {}: measured {:.4} vs eps {eps} over {} samples",
                    v.light_idx,
                    v.violation_rate(),
                    v.samples
                );
            }
        }
        per_trial.push(vals);
    }
    assert!(total_tasks > 100, "workload too small to be meaningful");

    // Pooled across seeds the estimate is much tighter: the Chernoff
    // bound is conservative, so the aggregate must clear eps with a
    // small tolerance only.
    let pooled = pool(&per_trial);
    let samples: usize = pooled.iter().map(|v| v.samples).sum();
    let violations: usize = pooled.iter().map(|v| v.violations).sum();
    assert!(samples > 300, "too few measured sojourns: {samples}");
    let aggregate = violations as f64 / samples as f64;
    assert!(
        aggregate <= eps + 0.02,
        "aggregate violation rate {aggregate:.4} exceeds eps {eps}"
    );
}

#[test]
fn paired_trace_on_time_rates_are_comparable_across_engines() {
    // The DES is the ground truth for the slotted engine's assumptions:
    // on the same trace both engines must admit identical workloads and
    // land in the same regime on the headline metric.
    let mut cfg = ExperimentConfig::paper_default();
    cfg.sim.slots = 150;
    cfg.workload.num_users = 8;
    cfg.controller.effcap_samples = 1024;
    let seed = 2026;
    let env = SimEnv::build(&cfg, seed);
    let opts = SimOptions::from_config(&cfg);
    let trace = record_trace(&env, seed, &opts);
    let slotted = run_trial_traced(&env, &mut Proposal::new(), seed, &opts, &trace);
    let des = run_des_trial(
        &env,
        &mut Proposal::new(),
        seed,
        &DesOptions::from_sim(&opts),
        &trace,
    );
    assert_eq!(slotted.total_tasks, des.total_tasks);
    assert_eq!(slotted.total_tasks, trace.len());
    assert!(slotted.completion_rate() > 0.5);
    assert!(des.completion_rate() > 0.5);
    assert!(
        (slotted.on_time_rate() - des.on_time_rate()).abs() < 0.45,
        "engines diverge: slotted {} vs DES {}",
        slotted.on_time_rate(),
        des.on_time_rate()
    );
}
