//! Cross-layer integration tests: the PJRT-compiled artifacts must agree
//! with the native Rust implementations, and the full pipeline (placement
//! → controller → simulation) must hold its invariants end-to-end.
//!
//! Requires `make artifacts` (the tests skip with a message otherwise —
//! CI runs them after the artifact step).

use fmedge::baselines::{LbrrStrategy, PropAvg, Proposal};
use fmedge::config::ExperimentConfig;
use fmedge::effcap::{GTable, GTableParams};
use fmedge::placement::{build_rows, QosScores, ScoreParams};
use fmedge::rng::{Distribution, Gamma, Xoshiro256};
use fmedge::runtime::{shapes, EffCapAccel, MsBlockAccel, QosAccel, Runtime};
use fmedge::sim::{run_trial, SimEnv, SimOptions};
use fmedge::workload::WorkloadGenerator;

fn runtime() -> Option<Runtime> {
    let dir = Runtime::default_dir();
    if !dir.join("effcap.hlo.txt").exists() {
        eprintln!("skipping: artifacts missing (run `make artifacts`)");
        return None;
    }
    Some(Runtime::cpu(dir).expect("PJRT CPU client"))
}

#[test]
fn pjrt_effcap_matches_native_gtable() {
    let Some(rt) = runtime() else { return };
    let accel = EffCapAccel::load(&rt).expect("load effcap artifact");

    let mut rng = Xoshiro256::seed_from(42);
    let mut samples = Vec::new();
    let mut workloads = Vec::new();
    for i in 0..9 {
        let g = Gamma::new(1.0 + 0.1 * i as f64, 5.0 + i as f64);
        samples.push(g.sample_n(&mut rng, shapes::EFFCAP_S));
        workloads.push(0.5 + 0.15 * i as f64);
    }

    // Native table with the artifact's exact parameters.
    let params = GTableParams {
        epsilon: shapes::EFFCAP_EPSILON,
        max_parallelism: shapes::EFFCAP_Y,
        theta_lo: 1e-3,
        theta_hi: 10.0,
        theta_n: shapes::EFFCAP_T,
        contention_alpha: shapes::EFFCAP_ALPHA,
    };
    let native = GTable::build(&samples, &workloads, &params);
    let accel_table = accel
        .build_gtable(&samples, &workloads)
        .expect("accel gtable");

    assert_eq!(native.num_ms(), accel_table.num_ms());
    for m in 0..native.num_ms() {
        for y in 1..=shapes::EFFCAP_Y {
            let a = native.delay(m, y);
            let b = accel_table.delay(m, y);
            assert!(
                (a - b).abs() / a.max(1e-9) < 2e-3,
                "g[{m}][{y}]: native {a} vs PJRT {b}"
            );
            let am = native.mean_delay(m, y);
            let bm = accel_table.mean_delay(m, y);
            assert!(
                (am - bm).abs() / am.max(1e-9) < 2e-3,
                "gmean[{m}][{y}]: native {am} vs PJRT {bm}"
            );
        }
    }
}

#[test]
fn pjrt_qos_matches_native_scores() {
    let Some(rt) = runtime() else { return };
    let accel = QosAccel::load(&rt).expect("load qos artifact");

    let cfg = ExperimentConfig::paper_default();
    let env = SimEnv::build(&cfg, 5);
    let gen = WorkloadGenerator::new(
        &cfg,
        &env.app,
        &env.topo,
        &mut Xoshiro256::seed_from(env.users_seed),
    );
    // The artifact bakes delta/lo/hi; use matching native params.
    let params = ScoreParams {
        delta: shapes::QOS_DELTA,
        urgency_cap: shapes::QOS_HI,
        uplink_samples: 512,
    };
    let rows = build_rows(&env.app, &env.topo, &env.dm, gen.users(), &params);
    assert!(rows.len() <= shapes::QOS_R, "row budget: {}", rows.len());
    let native = QosScores::compute_from_rows(
        &rows,
        env.topo.num_nodes(),
        env.app.catalog.num_core(),
        &params,
    );
    let pjrt = accel
        .scores(&rows, env.topo.num_nodes(), env.app.catalog.num_core())
        .expect("accel scores");
    for v in 0..env.topo.num_nodes() {
        for c in 0..env.app.catalog.num_core() {
            let (a, b) = (native.z_tilde[v][c], pjrt.z_tilde[v][c]);
            assert!(
                (a - b).abs() < 1e-3 + 1e-3 * a.abs(),
                "z~[{v}][{c}]: {a} vs {b}"
            );
            let (a, b) = (native.q[v][c], pjrt.q[v][c]);
            assert!(
                (a - b).abs() < 5e-3 + 2e-3 * a.abs(),
                "Q[{v}][{c}]: {a} vs {b}"
            );
        }
    }
}

#[test]
fn pjrt_msblock_is_deterministic_and_nontrivial() {
    let Some(rt) = runtime() else { return };
    let accel = MsBlockAccel::load(&rt).expect("load msblock artifact");
    let n = shapes::MSBLOCK_B * shapes::MSBLOCK_L * shapes::MSBLOCK_D;
    let x: Vec<f32> = (0..n).map(|i| ((i % 17) as f32 - 8.0) * 0.1).collect();
    let y1 = accel.forward(&x).expect("forward");
    let y2 = accel.forward(&x).expect("forward");
    assert_eq!(y1, y2, "PJRT execution must be deterministic");
    assert_eq!(y1.len(), n);
    let diff: f32 = x.iter().zip(&y1).map(|(a, b)| (a - b).abs()).sum();
    assert!(diff > 1.0, "block must transform its input");
    assert!(y1.iter().all(|v| v.is_finite()));
}

#[test]
fn pjrt_gtable_drives_a_full_trial() {
    let Some(rt) = runtime() else { return };
    let accel = EffCapAccel::load(&rt).expect("load effcap artifact");
    let mut cfg = ExperimentConfig::paper_default();
    cfg.sim.slots = 120;
    cfg.workload.num_users = 6;
    cfg.controller.effcap_samples = 1024;
    let env = SimEnv::build(&cfg, 9);
    let workloads: Vec<f64> = env
        .app
        .catalog
        .light_ids()
        .iter()
        .map(|&m| env.app.catalog.spec(m).workload_mb)
        .collect();
    let gtable = accel
        .build_gtable(&env.light_rate_samples, &workloads)
        .expect("accel gtable");
    let env = env.with_gtable(gtable);
    let m = run_trial(&env, &mut Proposal::new(), 9, &SimOptions::from_config(&cfg));
    assert!(m.total_tasks > 0);
    assert!(
        m.completion_rate() > 0.5,
        "PJRT-driven trial should complete tasks ({})",
        m.completion_rate()
    );
}

#[test]
fn proposal_beats_baselines_under_stress() {
    // The paper's headline ordering under load (Fig. 4 shape), one seed.
    let mut cfg = ExperimentConfig::paper_default();
    cfg.sim.slots = 300;
    let mut opts = SimOptions::from_config(&cfg);
    opts.load_multiplier = 1.5;
    let mut otr = |s: &mut dyn fmedge::sim::Strategy| {
        let env = SimEnv::build(&cfg, 33);
        run_trial(&env, s, 33, &opts).on_time_rate()
    };
    let prop = otr(&mut Proposal::new());
    let lbrr = otr(&mut LbrrStrategy::new());
    assert!(
        prop > lbrr,
        "proposal ({prop:.3}) must beat LBRR ({lbrr:.3}) under stress"
    );
}

#[test]
fn propavg_is_cheaper_but_not_better_on_time() {
    let mut cfg = ExperimentConfig::paper_default();
    cfg.sim.slots = 300;
    let mut opts = SimOptions::from_config(&cfg);
    opts.load_multiplier = 1.5;
    let mut run = |s: &mut dyn fmedge::sim::Strategy| {
        let env = SimEnv::build(&cfg, 44);
        run_trial(&env, s, 44, &opts)
    };
    let prop = run(&mut Proposal::new());
    let avg = run(&mut PropAvg::new());
    // Mean-value ablation under-provisions: never pays more.
    assert!(
        avg.total_cost <= prop.total_cost * 1.05,
        "PropAvg ({}) should not cost much more than the proposal ({})",
        avg.total_cost,
        prop.total_cost
    );
}
