//! Integration tests for the fault-injection subsystem: replay
//! determinism, zero-fault equivalence with the fault-free entry points,
//! and slotted-vs-DES agreement on a fixed schedule.

use fmedge::baselines::{LbrrStrategy, Proposal};
use fmedge::config::ExperimentConfig;
use fmedge::des::{run_des_trial, run_des_trial_faulted, DesOptions};
use fmedge::faults::{FaultEvent, FaultKind, FaultParams, FaultSchedule};
use fmedge::metrics::TrialMetrics;
use fmedge::sim::{record_trace, run_trial_faulted, run_trial_traced, SimEnv, SimOptions};

fn small_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::paper_default();
    cfg.sim.slots = 120;
    cfg.workload.num_users = 8;
    cfg.controller.effcap_samples = 512;
    cfg
}

/// Field-by-field identity on everything a trial measures (metrics do not
/// implement `PartialEq`; latency vectors make this byte-level in effect).
fn assert_identical(a: &TrialMetrics, b: &TrialMetrics, what: &str) {
    assert_eq!(a.total_tasks, b.total_tasks, "{what}: total_tasks");
    assert_eq!(a.completed, b.completed, "{what}: completed");
    assert_eq!(a.on_time, b.on_time, "{what}: on_time");
    assert_eq!(a.fault_drops, b.fault_drops, "{what}: fault_drops");
    assert_eq!(
        a.reroute_recovered, b.reroute_recovered,
        "{what}: reroute_recovered"
    );
    assert_eq!(a.retries, b.retries, "{what}: retries");
    assert_eq!(a.hedges, b.hedges, "{what}: hedges");
    assert_eq!(
        a.checkpoint_restores, b.checkpoint_restores,
        "{what}: checkpoint_restores"
    );
    assert_eq!(a.vq_residual, b.vq_residual, "{what}: vq_residual");
    assert!(
        (a.total_cost - b.total_cost).abs() < 1e-12,
        "{what}: total_cost {} vs {}",
        a.total_cost,
        b.total_cost
    );
    assert_eq!(
        a.latencies_ms.len(),
        b.latencies_ms.len(),
        "{what}: latency count"
    );
    for (i, (x, y)) in a.latencies_ms.iter().zip(&b.latencies_ms).enumerate() {
        assert!((x - y).abs() < 1e-12, "{what}: latency[{i}] {x} vs {y}");
    }
}

fn mid_trial_schedule(env: &SimEnv, opts: &SimOptions, rate: f64, seed: u64) -> FaultSchedule {
    FaultSchedule::generate(
        &env.topo,
        opts.slots,
        opts.slot_ms,
        env.app.catalog.num_core(),
        &FaultParams::from_rate(rate),
        seed,
    )
}

#[test]
fn fault_replay_is_deterministic_on_both_engines() {
    let cfg = small_cfg();
    let seed = 41;
    let env = SimEnv::build(&cfg, seed);
    let opts = SimOptions::from_config(&cfg);
    let trace = record_trace(&env, seed, &opts);
    let schedule = mid_trial_schedule(&env, &opts, 0.01, 77);
    assert!(!schedule.is_empty(), "rate 0.01 must generate events");

    let s1 = run_trial_faulted(&env, &mut Proposal::new(), seed, &opts, &trace, &schedule);
    let s2 = run_trial_faulted(&env, &mut Proposal::new(), seed, &opts, &trace, &schedule);
    assert_identical(&s1, &s2, "slotted");

    let dopts = DesOptions::from_sim(&opts);
    let d1 = run_des_trial_faulted(&env, &mut Proposal::new(), seed, &dopts, &trace, &schedule);
    let d2 = run_des_trial_faulted(&env, &mut Proposal::new(), seed, &dopts, &trace, &schedule);
    assert_identical(&d1, &d2, "des");
}

#[test]
fn zero_fault_schedule_changes_nothing() {
    // The acceptance criterion behind `fmedge faults --rates 0,...`: an
    // empty schedule reproduces the fault-free run exactly, per engine.
    let cfg = small_cfg();
    let seed = 43;
    let env = SimEnv::build(&cfg, seed);
    let opts = SimOptions::from_config(&cfg);
    let trace = record_trace(&env, seed, &opts);
    let empty = FaultSchedule::none();

    let plain = run_trial_traced(&env, &mut Proposal::new(), seed, &opts, &trace);
    let faulted = run_trial_faulted(&env, &mut Proposal::new(), seed, &opts, &trace, &empty);
    assert_identical(&plain, &faulted, "slotted zero-fault");

    let dopts = DesOptions::from_sim(&opts);
    let dplain = run_des_trial(&env, &mut Proposal::new(), seed, &dopts, &trace);
    let dfaulted = run_des_trial_faulted(&env, &mut Proposal::new(), seed, &dopts, &trace, &empty);
    assert_identical(&dplain, &dfaulted, "des zero-fault");
    assert_eq!(dplain.fault_drops, 0);
}

#[test]
fn both_engines_agree_on_a_fixed_schedule() {
    // The tentpole's paired check: identical admission and the same
    // regime on the headline metric when both engines replay one
    // handcrafted outage scenario (an ES dies mid-trial and recovers,
    // a link flaps, a replica fail-stops).
    let cfg = small_cfg();
    let seed = 47;
    let env = SimEnv::build(&cfg, seed);
    let opts = SimOptions::from_config(&cfg);
    let trace = record_trace(&env, seed, &opts);
    let es = cfg.network.num_eds; // first edge server
    let ms = opts.slot_ms;
    let schedule = FaultSchedule::from_events(vec![
        FaultEvent {
            time_ms: 30.0 * ms,
            kind: FaultKind::NodeDown { node: es },
        },
        FaultEvent {
            time_ms: 40.0 * ms,
            kind: FaultKind::LinkBandwidth { link: 0, factor: 0.3 },
        },
        FaultEvent {
            time_ms: 55.0 * ms,
            kind: FaultKind::CoreReplicaFail {
                node: es + 1,
                core_idx: 0,
            },
        },
        FaultEvent {
            time_ms: 60.0 * ms,
            kind: FaultKind::NodeUp { node: es },
        },
        FaultEvent {
            time_ms: 70.0 * ms,
            kind: FaultKind::LinkBandwidth { link: 0, factor: 1.0 },
        },
    ]);

    let dopts = DesOptions::from_sim(&opts);
    let slotted_base = run_trial_traced(&env, &mut Proposal::new(), seed, &opts, &trace);
    let des_base = run_des_trial(&env, &mut Proposal::new(), seed, &dopts, &trace);
    let slotted = run_trial_faulted(&env, &mut Proposal::new(), seed, &opts, &trace, &schedule);
    let des = run_des_trial_faulted(&env, &mut Proposal::new(), seed, &dopts, &trace, &schedule);
    assert_eq!(slotted.total_tasks, trace.len(), "paired admission");
    assert_eq!(des.total_tasks, trace.len(), "paired admission");
    assert!(slotted.completion_rate() > 0.3, "slotted must keep serving");
    assert!(des.completion_rate() > 0.3, "DES must keep serving");
    // The meaningful agreement check is baseline-relative: each engine's
    // *degradation* from its own no-fault run on this trace. The absolute
    // rates legitimately differ between engines (the DES measures real
    // queueing the slotted engine only bounds), but the damage a
    // mid-trial outage does must land in the same regime — a broken
    // fault path in either engine (e.g. silently losing or duplicating
    // work) shows up here long before it would trip an absolute bound.
    let slotted_drop = slotted_base.on_time_rate() - slotted.on_time_rate();
    let des_drop = des_base.on_time_rate() - des.on_time_rate();
    assert!(
        slotted_drop > -0.10 && des_drop > -0.10,
        "an outage must not improve an engine: slotted drop {slotted_drop}, DES drop {des_drop}"
    );
    assert!(
        (slotted_drop - des_drop).abs() < 0.35,
        "engines disagree on fault damage: slotted drop {slotted_drop} vs DES drop {des_drop}"
    );
    assert!(
        (slotted.on_time_rate() - des.on_time_rate()).abs() < 0.45,
        "engines diverge under faults: slotted {} vs DES {}",
        slotted.on_time_rate(),
        des.on_time_rate()
    );
    // Virtual queues still drain to empty with faults active.
    assert_eq!(slotted.vq_residual, 0);
    assert_eq!(des.vq_residual, 0);
}

#[test]
fn outages_do_not_improve_on_time_rate() {
    let cfg = small_cfg();
    let seed = 53;
    let env = SimEnv::build(&cfg, seed);
    let opts = SimOptions::from_config(&cfg);
    let trace = record_trace(&env, seed, &opts);
    let baseline = run_trial_traced(&env, &mut Proposal::new(), seed, &opts, &trace);
    let schedule = mid_trial_schedule(&env, &opts, 0.02, 101);
    let faulted = run_trial_faulted(&env, &mut Proposal::new(), seed, &opts, &trace, &schedule);
    assert_eq!(faulted.total_tasks, baseline.total_tasks);
    // Fault handling re-randomizes some service draws, so allow noise —
    // but a hostile schedule must not look materially better.
    assert!(
        faulted.on_time_rate() <= baseline.on_time_rate() + 0.10,
        "faults cannot help: {} vs baseline {}",
        faulted.on_time_rate(),
        baseline.on_time_rate()
    );
}

#[test]
fn fault_oblivious_baseline_survives_replay() {
    // LBRR never looks at the fault state; the engines must still refuse
    // its dead-node routing and finish the trial cleanly.
    let cfg = small_cfg();
    let seed = 59;
    let env = SimEnv::build(&cfg, seed);
    let opts = SimOptions::from_config(&cfg);
    let trace = record_trace(&env, seed, &opts);
    let schedule = mid_trial_schedule(&env, &opts, 0.02, 303);
    let slotted =
        run_trial_faulted(&env, &mut LbrrStrategy::new(), seed, &opts, &trace, &schedule);
    assert_eq!(slotted.total_tasks, trace.len());
    assert_eq!(slotted.vq_residual, 0);
    let des = run_des_trial_faulted(
        &env,
        &mut LbrrStrategy::new(),
        seed,
        &DesOptions::from_sim(&opts),
        &trace,
        &schedule,
    );
    assert_eq!(des.total_tasks, trace.len());
    assert_eq!(des.vq_residual, 0);
}
