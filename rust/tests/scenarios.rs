//! Integration tests for the scenario library: compile determinism,
//! trial-stream independence, and — the tentpole acceptance criterion —
//! the new scenario families (diurnal, MMPP, zone-outage, and friends)
//! replayed under BOTH engines with engine agreement asserted.

use fmedge::baselines::Proposal;
use fmedge::config::ExperimentConfig;
use fmedge::des::{run_des_trial_faulted, DesOptions};
use fmedge::faults::FaultKind;
use fmedge::metrics::TrialMetrics;
use fmedge::rng::stream_seed;
use fmedge::scenarios::{CompiledScenario, ScenarioSpec};
use fmedge::sim::{run_trial_faulted, SimEnv, SimOptions};

fn small_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::paper_default();
    cfg.sim.slots = 100;
    cfg.workload.num_users = 8;
    cfg.controller.effcap_samples = 512;
    cfg
}

fn build(seed: u64) -> (SimEnv, SimOptions) {
    let cfg = small_cfg();
    let env = SimEnv::build(&cfg, seed);
    let opts = SimOptions::from_config(&cfg);
    (env, opts)
}

fn assert_same_compile(a: &CompiledScenario, b: &CompiledScenario, what: &str) {
    assert_eq!(a.trace.len(), b.trace.len(), "{what}: trace length");
    for (x, y) in a.trace.arrivals().iter().zip(b.trace.arrivals()) {
        assert_eq!(x.id, y.id, "{what}");
        assert_eq!(x.user, y.user, "{what}");
        assert_eq!(x.ed, y.ed, "{what}");
        assert_eq!(x.slot, y.slot, "{what}");
        assert_eq!(x.snr.to_bits(), y.snr.to_bits(), "{what}");
        assert_eq!(
            x.uplink_delay_ms.to_bits(),
            y.uplink_delay_ms.to_bits(),
            "{what}"
        );
    }
    assert_eq!(a.faults.events(), b.faults.events(), "{what}: schedule");
    assert_eq!(a.user_moves, b.user_moves, "{what}: moves");
}

#[test]
fn every_library_scenario_compiles_deterministically() {
    let (env, opts) = build(61);
    for spec in ScenarioSpec::library() {
        let a = spec.compile(&env, &opts, 1234);
        let b = spec.compile(&env, &opts, 1234);
        assert_same_compile(&a, &b, &spec.name);
        assert!(!a.trace.is_empty(), "{}: empty trace", spec.name);
    }
}

#[test]
fn trial_streams_are_independent_of_preceding_trials() {
    // Regression for the sequential-reseed antipattern: trial k's
    // realization must not depend on how many trials ran before it.
    // The sweep derives every trial seed statelessly via stream_seed, so
    // compiling trials {0,1,2} first and then trial 3 must produce the
    // same trial-3 scenario as compiling trial 3 alone.
    let (env, opts) = build(62);
    let spec = ScenarioSpec::mmpp();
    let sweep_seed = 99u64;
    let cell = 5u64;

    // "Sequential" path: compile everything in order.
    let mut sequential = Vec::new();
    for trial in 0..4u64 {
        sequential.push(spec.compile(&env, &opts, stream_seed(sweep_seed, cell, trial)));
    }
    // "Direct" path: trial 3 alone, no predecessors.
    let direct = spec.compile(&env, &opts, stream_seed(sweep_seed, cell, 3));
    assert_same_compile(&sequential[3], &direct, "trial 3");

    // And the trials must actually differ from each other.
    let t0 = &sequential[0].trace;
    let t3 = &sequential[3].trace;
    let same = t0.len() == t3.len()
        && t0
            .arrivals()
            .iter()
            .zip(t3.arrivals())
            .all(|(x, y)| x.slot == y.slot && x.snr == y.snr);
    assert!(!same, "distinct trials must realize distinct traces");
}

/// Shared engine-agreement check: identical admission (both engines
/// replay the compiled trace verbatim), a sane completion floor, and
/// headline on-time rates in the same regime (the DES measures real
/// queueing the slotted engine only bounds, so exact equality is not
/// expected — gross divergence means one engine mishandled the
/// scenario's trace or schedule).
fn assert_engines_agree(spec: &ScenarioSpec, seed: u64) -> (TrialMetrics, TrialMetrics) {
    let (env, opts) = build(seed);
    let cs = spec.compile(&env, &opts, seed);
    assert!(!cs.trace.is_empty(), "{}: empty trace", spec.name);
    let slotted = run_trial_faulted(
        &env,
        &mut Proposal::new(),
        seed,
        &opts,
        &cs.trace,
        &cs.faults,
    );
    let des = run_des_trial_faulted(
        &env,
        &mut Proposal::new(),
        seed,
        &DesOptions::from_sim(&opts),
        &cs.trace,
        &cs.faults,
    );
    assert_eq!(
        slotted.total_tasks,
        cs.trace.len(),
        "{}: slotted admission",
        spec.name
    );
    assert_eq!(
        des.total_tasks,
        cs.trace.len(),
        "{}: DES admission",
        spec.name
    );
    assert!(
        slotted.completion_rate() > 0.3,
        "{}: slotted completion {}",
        spec.name,
        slotted.completion_rate()
    );
    assert!(
        des.completion_rate() > 0.3,
        "{}: DES completion {}",
        spec.name,
        des.completion_rate()
    );
    assert!(
        (slotted.on_time_rate() - des.on_time_rate()).abs() < 0.45,
        "{}: engines diverge — slotted {} vs DES {}",
        spec.name,
        slotted.on_time_rate(),
        des.on_time_rate()
    );
    (slotted, des)
}

#[test]
fn engines_agree_on_diurnal() {
    assert_engines_agree(&ScenarioSpec::diurnal(), 71);
}

#[test]
fn engines_agree_on_mmpp() {
    assert_engines_agree(&ScenarioSpec::mmpp(), 72);
}

#[test]
fn engines_agree_on_zone_outage() {
    let (slotted, des) = assert_engines_agree(&ScenarioSpec::zone_outage(), 73);
    // Fault damage must be in the same regime across engines too
    // (mirrors rust/tests/fault_injection.rs's baseline-relative check).
    let sd = slotted.fault_drops as f64 / slotted.total_tasks.max(1) as f64;
    let dd = des.fault_drops as f64 / des.total_tasks.max(1) as f64;
    assert!(
        (sd - dd).abs() < 0.25,
        "fault-drop fractions diverge: slotted {sd} vs DES {dd}"
    );
}

#[test]
fn engines_agree_on_mobility_and_flash_crowd() {
    assert_engines_agree(&ScenarioSpec::mobility(), 74);
    assert_engines_agree(&ScenarioSpec::flash_crowd(), 75);
}

#[test]
fn zone_outage_takes_whole_racks_down_and_recovers() {
    let (env, opts) = build(76);
    let cfg = small_cfg();
    let cs = ScenarioSpec::zone_outage().compile(&env, &opts, 77);
    // Over this horizon the template is stochastic; assert structural
    // invariants on whatever was generated.
    let mut down = std::collections::BTreeSet::new();
    let cap = ((cfg.network.num_ess - 1) / 2).max(1);
    for ev in cs.faults.events() {
        match ev.kind {
            FaultKind::NodeDown { node } => {
                assert!(node >= cfg.network.num_eds, "EDs never fault");
                assert!(down.insert(node), "double-down");
                assert!(down.len() <= cap, "backbone majority violated");
            }
            FaultKind::NodeUp { node } => {
                assert!(down.remove(&node));
            }
            other => panic!("zone template emitted {other:?}"),
        }
    }
    assert!(down.is_empty(), "unrecovered outages");
}

#[test]
fn rush_hour_composes_all_three_axes() {
    let (env, mut opts) = build(78);
    // The commuter axis flips every 100 slots — the arrival window must
    // reach past the first flip for any churn to be observable.
    opts.slots = 300;
    opts.arrival_cutoff = 250;
    let cs = ScenarioSpec::rush_hour().compile(&env, &opts, 79);
    // Non-stationary load curve…
    let min = cs.load_curve.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = cs.load_curve.iter().cloned().fold(0.0f64, f64::max);
    assert!(max > 1.2 && min < 0.8, "diurnal swing missing");
    // …commuter churn…
    assert!(cs.user_moves > 0, "no churn");
    // …and load-correlated fail-stop events.
    assert!(
        cs.faults
            .events()
            .iter()
            .all(|e| matches!(e.kind, FaultKind::CoreReplicaFail { .. })),
        "unexpected event kinds"
    );
}
