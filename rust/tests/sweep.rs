//! Integration tests for the sweep orchestrator: parallel output must be
//! bit-identical to serial, grids must be well-formed (no NaN/empty
//! cells), and the §P4 retained column must anchor at the rate-0
//! baseline.

use fmedge::config::ExperimentConfig;
use fmedge::exp::{run_sweep, Experiment, SweepConfig};

fn small_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::paper_default();
    cfg.workload.num_users = 8;
    cfg.controller.effcap_samples = 512;
    cfg
}

fn tiny_p4() -> SweepConfig {
    let mut sc = SweepConfig::for_experiment(Experiment::P4);
    sc.trials = 2;
    sc.slots = 60;
    sc.seed = 11;
    sc.loads = vec![1.0, 2.0];
    sc.rates = vec![0.0, 0.01];
    sc.strategies = vec!["proposal".into()];
    // Both engines: the DES rows exercise the per-cell arena reuse,
    // which must stay bit-identical across thread counts.
    sc.engines = vec!["slotted".into(), "des".into()];
    sc
}

#[test]
fn p4_parallel_is_bit_identical_to_serial() {
    let cfg = small_cfg();
    let mut sc = tiny_p4();
    sc.threads = 1;
    let serial = run_sweep(&cfg, &sc).expect("serial sweep");
    serial.validate().expect("well-formed");
    for threads in [2, 4] {
        sc.threads = threads;
        let par = run_sweep(&cfg, &sc).expect("parallel sweep");
        assert_eq!(
            serial.to_csv(),
            par.to_csv(),
            "threads={threads} must be bit-identical to serial"
        );
    }
}

#[test]
fn p4_grid_shape_and_retained_baseline() {
    let cfg = small_cfg();
    let mut sc = tiny_p4();
    sc.threads = 2;
    let table = run_sweep(&cfg, &sc).expect("sweep");
    table.validate().expect("well-formed");
    // engines(2) x loads(2) x strategies(1) x rates(2).
    assert_eq!(table.rows.len(), 8);
    let col = |name: &str| {
        table
            .headers
            .iter()
            .position(|h| h == name)
            .unwrap_or_else(|| panic!("missing column {name}"))
    };
    let (rate_c, ret_c, ot_c, tasks_c) = (
        col("fail_rate"),
        col("retained"),
        col("on_time_mean"),
        col("tasks"),
    );
    for row in &table.rows {
        let tasks: usize = row[tasks_c].parse().expect("tasks integer");
        assert!(tasks > 0, "a grid point admitted no tasks");
        let ot: f64 = row[ot_c].parse().expect("on-time number");
        assert!((0.0..=1.0).contains(&ot));
        if row[rate_c].parse::<f64>().unwrap() == 0.0 {
            assert_eq!(row[ret_c], "1.0000", "rate-0 anchors retained");
        } else {
            let r: f64 = row[ret_c].parse().expect("retained number");
            assert!(r > 0.0 && r <= 1.5, "implausible retained {r}");
        }
    }
}

#[test]
fn p5_runs_scenarios_under_both_engines_bit_identically() {
    let cfg = small_cfg();
    let mut sc = SweepConfig::for_experiment(Experiment::P5);
    sc.trials = 2;
    // 100 slots -> arrivals to slot 25: wide enough that the mobility
    // scenario's waypoint churn (mean dwell 40 slots, 8 users, summed
    // over both trials) registers moves with near-certainty.
    sc.slots = 100;
    sc.seed = 13;
    sc.scenarios = vec!["baseline".into(), "zone-outage".into(), "mobility".into()];
    sc.engines = vec!["slotted".into(), "des".into()];
    sc.strategies = vec!["proposal".into()];
    sc.threads = 1;
    let serial = run_sweep(&cfg, &sc).expect("serial p5");
    serial.validate().expect("well-formed");
    assert_eq!(serial.rows.len(), 3 * 2);
    sc.threads = 4;
    let par = run_sweep(&cfg, &sc).expect("parallel p5");
    assert_eq!(serial.to_csv(), par.to_csv(), "p5 parallel != serial");

    // Paired fixtures: both engines of one scenario admit the same tasks.
    let col = |name: &str| serial.headers.iter().position(|h| h == name).unwrap();
    let (scen_c, tasks_c, moves_c) = (col("scenario"), col("tasks"), col("user_moves"));
    for scen in ["baseline", "zone-outage", "mobility"] {
        let tasks: Vec<&str> = serial
            .rows
            .iter()
            .filter(|r| r[scen_c] == scen)
            .map(|r| r[tasks_c].as_str())
            .collect();
        assert_eq!(tasks.len(), 2);
        assert_eq!(tasks[0], tasks[1], "{scen}: engines saw different traces");
    }
    // The mobility scenario actually re-homed users; baseline did not.
    let moves_of = |scen: &str| -> usize {
        serial
            .rows
            .iter()
            .find(|r| r[scen_c] == scen)
            .unwrap()[moves_c]
            .parse()
            .unwrap()
    };
    assert_eq!(moves_of("baseline"), 0);
    assert!(moves_of("mobility") > 0);
}

#[test]
fn p2_tiny_grid_is_well_formed() {
    let cfg = small_cfg();
    let mut sc = SweepConfig::for_experiment(Experiment::P2);
    sc.trials = 1;
    sc.slots = 60;
    sc.seed = 17;
    sc.epsilons = vec![0.2];
    sc.threads = 1;
    let table = run_sweep(&cfg, &sc).expect("p2 sweep");
    table.validate().expect("well-formed");
    assert_eq!(table.rows.len(), 1);
    let col = |name: &str| table.headers.iter().position(|h| h == name).unwrap();
    let services: usize = table.rows[0][col("services")].parse().unwrap();
    let holding: usize = table.rows[0][col("holding")].parse().unwrap();
    assert!(services > 0);
    assert!(holding <= services);
}

#[test]
fn p1b_solution_columns_are_mode_invariant() {
    // Warm-started node LPs must not change the solved placement — only
    // the (wall-clock, excluded-from-bit-identity) solve_ms column may
    // differ between runs.
    let cfg = small_cfg();
    let mut sc = SweepConfig::for_experiment(Experiment::P1b);
    sc.trials = 1;
    sc.seed = 19;
    sc.threads = 2;
    let table = run_sweep(&cfg, &sc).expect("p1b sweep");
    table.validate().expect("well-formed");
    assert_eq!(table.rows.len(), 2, "one instance x two modes");
    let col = |name: &str| table.headers.iter().position(|h| h == name).unwrap();
    for name in ["objective", "instances", "support"] {
        let c = col(name);
        assert_eq!(
            table.rows[0][c], table.rows[1][c],
            "{name} differs between dense-rebuild and warm-revised"
        );
    }
}
