//! Observability integration tests (EXPERIMENTS §P7): the span-accounting
//! invariant (span components telescope exactly to the end-to-end sojourn,
//! both engines, retried/hedged tasks included), the zero-overhead gate
//! (tracing disabled => bit-identical outputs), and exporter sanity.

use fmedge::baselines::Proposal;
use fmedge::config::ExperimentConfig;
use fmedge::coordinator::{parse_fault_spec, ReplayConfig, ReplayServer, VirtualRequest};
use fmedge::des::{run_des_trial_faulted, run_des_trial_observed, DesOptions};
use fmedge::faults::{FaultEvent, FaultKind, FaultSchedule};
use fmedge::obs::{analyze, chrome_trace_json, spans_jsonl, Observer, SpanKind};
use fmedge::sim::{record_trace, run_trial_faulted, run_trial_observed, SimEnv, SimOptions};

fn small_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::paper_default();
    cfg.sim.slots = 120;
    cfg.workload.num_users = 8;
    cfg.controller.effcap_samples = 512;
    cfg
}

/// The §P6 zone outage: two edge servers dark mid-trial, a replica
/// fail-stop paired with a checkpoint restart. At 1.5x load and seed 61
/// both engines provably cancel in-flight stages (asserted below), so the
/// invariant tests cover retried and hedged tasks, not just clean runs.
fn zone_schedule(cfg: &ExperimentConfig, slot_ms: f64) -> FaultSchedule {
    let es = cfg.network.num_eds;
    let events = vec![
        FaultEvent { time_ms: 30.0 * slot_ms, kind: FaultKind::NodeDown { node: es } },
        FaultEvent { time_ms: 32.0 * slot_ms, kind: FaultKind::NodeDown { node: es + 1 } },
        FaultEvent {
            time_ms: 45.0 * slot_ms,
            kind: FaultKind::CoreReplicaFail { node: es + 2, core_idx: 0 },
        },
        FaultEvent {
            time_ms: 58.0 * slot_ms,
            kind: FaultKind::CoreReplicaRestart { node: es + 2, core_idx: 0 },
        },
        FaultEvent { time_ms: 70.0 * slot_ms, kind: FaultKind::NodeUp { node: es } },
        FaultEvent { time_ms: 72.0 * slot_ms, kind: FaultKind::NodeUp { node: es + 1 } },
    ];
    FaultSchedule::from_events(events)
}

struct Fixture {
    cfg: ExperimentConfig,
    env: SimEnv,
    opts: SimOptions,
    trace: fmedge::workload::Trace,
    schedule: FaultSchedule,
    seed: u64,
}

fn faulty_fixture() -> Fixture {
    let mut cfg = small_cfg();
    cfg.sim.load_multiplier = 1.5;
    let seed = 61;
    let env = SimEnv::build(&cfg, seed);
    let opts = SimOptions::from_config(&cfg);
    let trace = record_trace(&env, seed, &opts);
    let schedule = zone_schedule(&cfg, opts.slot_ms);
    Fixture { cfg, env, opts, trace, schedule, seed }
}

/// The span-accounting invariant for one observed run: every completed
/// task's component decomposition sums exactly to its end-to-end sojourn,
/// and the sorted per-task latencies match the engine's own latency
/// stream value for value.
fn assert_spans_telescope(obs: &Observer, env: &SimEnv, m: &fmedge::metrics::TrialMetrics, what: &str) {
    let rec = obs.trace.as_ref().expect("tracing armed");
    let rep = analyze(rec, Some(&env.gtable)).unwrap_or_else(|e| panic!("{what}: {e}"));
    assert_eq!(
        rep.tasks.len(),
        m.completed,
        "{what}: every completed task must decompose"
    );
    for tb in &rep.tasks {
        let sum: f64 = tb.parts.iter().sum();
        assert!(
            (sum - tb.latency_ms).abs() < 1e-6,
            "{what}: task {} components {sum} != sojourn {}",
            tb.task,
            tb.latency_ms
        );
        for (i, &p) in tb.parts.iter().enumerate() {
            assert!(
                p > -1e-9,
                "{what}: task {} component {i} is negative ({p})",
                tb.task
            );
        }
    }
    let mut span_lat: Vec<f64> = rep.tasks.iter().map(|t| t.latency_ms).collect();
    span_lat.sort_by(f64::total_cmp);
    assert_eq!(span_lat.len(), m.latencies_ms.len(), "{what}: latency count");
    for (a, b) in span_lat.iter().zip(&m.latencies_ms) {
        assert!(
            (a - b).abs() < 1e-6,
            "{what}: span latency {a} != engine latency {b}"
        );
    }
    // The fixture guarantees fault cancellations; the chain walk must
    // see them (retried tasks are where mis-accounting would hide).
    assert!(m.retries > 0, "{what}: fixture must force retries");
    assert!(
        rep.tasks.iter().any(|t| t.retried),
        "{what}: no decomposed task absorbed a retry"
    );
    // The g-table comparison has data for at least one light service.
    assert!(
        rep.budget.iter().any(|b| b.samples > 0),
        "{what}: budget rows must accumulate light executions"
    );
}

#[test]
fn span_sums_telescope_to_sojourn_slotted() {
    let f = faulty_fixture();
    let mut obs = Observer::new();
    let m = run_trial_observed(
        &f.env,
        &mut Proposal::new(),
        f.seed,
        &f.opts,
        &f.trace,
        &f.schedule,
        &mut obs,
    );
    assert!(m.completed > 0);
    assert_spans_telescope(&obs, &f.env, &m, "slotted");
}

#[test]
fn span_sums_telescope_to_sojourn_des() {
    let f = faulty_fixture();
    let mut obs = Observer::new();
    let m = run_des_trial_observed(
        &f.env,
        &mut Proposal::new(),
        f.seed,
        &DesOptions::from_sim(&f.opts),
        &f.trace,
        &f.schedule,
        &mut obs,
    );
    assert!(m.completed > 0);
    assert_spans_telescope(&obs, &f.env, &m, "des");
}

#[test]
fn disabled_tracing_is_bit_identical_on_both_engines() {
    // The zero-overhead gate: an observed run consumes no engine RNG and
    // reorders no events, so the *full* TrialMetrics (latency stream,
    // costs, per-service sojourn samples, every counter) is equal to the
    // unobserved run — and the unobserved faulted path itself is the
    // seed-era code path, untouched.
    let f = faulty_fixture();
    let plain = run_trial_faulted(
        &f.env,
        &mut Proposal::new(),
        f.seed,
        &f.opts,
        &f.trace,
        &f.schedule,
    );
    let mut obs = Observer::new();
    let observed = run_trial_observed(
        &f.env,
        &mut Proposal::new(),
        f.seed,
        &f.opts,
        &f.trace,
        &f.schedule,
        &mut obs,
    );
    assert_eq!(plain, observed, "slotted: observation must be pure");

    let dopts = DesOptions::from_sim(&f.opts);
    let plain =
        run_des_trial_faulted(&f.env, &mut Proposal::new(), f.seed, &dopts, &f.trace, &f.schedule);
    let mut obs = Observer::new();
    let observed = run_des_trial_observed(
        &f.env,
        &mut Proposal::new(),
        f.seed,
        &dopts,
        &f.trace,
        &f.schedule,
        &mut obs,
    );
    assert_eq!(plain, observed, "des: observation must be pure");
}

#[test]
fn observed_replay_server_is_bit_identical_and_spans_cover_faults() {
    let cfg = small_cfg();
    let (num_eds, num_ess) = (cfg.network.num_eds, cfg.network.num_ess);
    let schedule = parse_fault_spec("zone@40+30", num_eds, num_ess).expect("spec");
    let server = ReplayServer::new(
        ReplayConfig { workers: 4, ..Default::default() },
        &schedule,
        num_eds,
    );
    let arrivals: Vec<VirtualRequest> = (0..600)
        .map(|id| VirtualRequest { id, arrive_ms: id as f64 * 0.5, deadline_ms: 50.0 })
        .collect();
    let plain = server.run(&arrivals);
    let mut obs = Observer::trace_only();
    let observed = server.run_observed(&arrivals, &mut obs);
    assert_eq!(plain, observed, "serving path: observation must be pure");

    let rec = obs.trace.as_ref().unwrap();
    let spans = rec.all_spans();
    // Exactly one winning (non-cancelled) attempt per served request:
    // losers of a hedge race and outage-killed attempts are all cancelled.
    let winners = spans
        .iter()
        .filter(|s| matches!(s.kind, SpanKind::Serve | SpanKind::Hedge) && !s.cancelled)
        .count() as u64;
    assert_eq!(winners, plain.served, "one winning attempt per served request");
    assert!(plain.stats.retries > 0, "fixture must force retries");
    // Every outage kill truncates its attempt span; hedge losers add
    // cancelled spans on top (their count is workload-dependent).
    let cancelled = spans.iter().filter(|s| s.cancelled).count() as u64;
    assert!(
        cancelled >= plain.stats.retries,
        "cancelled spans ({cancelled}) must cover the {} outage kills",
        plain.stats.retries
    );
    assert_eq!(
        spans.iter().filter(|s| s.kind == SpanKind::Backoff).count() as u64,
        plain.stats.retries,
        "every retry pairs with one backoff span"
    );
    for s in &spans {
        assert!(
            s.end_ms >= s.start_ms - 1e-9,
            "span ends before it starts: {s:?}"
        );
    }
}

#[test]
fn exports_are_structurally_sound_and_telemetry_covers_every_slot() {
    let f = faulty_fixture();
    let mut obs = Observer::new();
    run_trial_observed(
        &f.env,
        &mut Proposal::new(),
        f.seed,
        &f.opts,
        &f.trace,
        &f.schedule,
        &mut obs,
    );
    let rec = obs.trace.as_ref().unwrap();
    assert!(rec.num_tasks() > 0);
    let spans = rec.all_spans();
    assert!(!spans.is_empty());

    let json = chrome_trace_json(rec);
    assert!(json.starts_with("{\"traceEvents\":["));
    assert!(json.contains("\"ph\":\"X\""));
    assert!(!json.contains("NaN") && !json.contains("inf"));
    assert_eq!(
        json.matches('{').count(),
        json.matches('}').count(),
        "unbalanced JSON braces"
    );

    let jsonl = spans_jsonl(rec);
    assert_eq!(jsonl.lines().count(), spans.len(), "one line per span");

    // Telemetry: one sample per slot, and a table that passes the same
    // NaN/empty gate the sweep artifacts do.
    let reg = obs.metrics.as_ref().unwrap();
    assert_eq!(
        reg.num_samples(),
        f.cfg.sim.slots,
        "one telemetry sample per slot"
    );
    let table = reg.to_table("telemetry");
    table.validate().expect("telemetry table must be publishable");
    assert_eq!(table.rows.len(), f.cfg.sim.slots);
}
