//! Integration tests for the elastic replica-pool tier (§P10): the
//! pool-off path must stay byte-identical (full `TrialMetrics` struct
//! equality) including across reused DES arenas that previously ran
//! pooled trials, pooled timelines must replay bit-identically, the p10
//! sweep must be thread-count-invariant, and both engines must agree on
//! pooled fixtures that actually exercise cold starts and scale-to-zero.

use fmedge::config::ExperimentConfig;
use fmedge::des::{run_des_trial_faulted_in, DesArena, DesOptions};
use fmedge::exp::{run_sweep, Experiment, SweepConfig};
use fmedge::pool::{Autoscale, PoolConfig};
use fmedge::scenarios::{CompiledScenario, ScenarioSpec};
use fmedge::sim::{run_trial_faulted, SimEnv, SimOptions};

fn small_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::paper_default();
    cfg.workload.num_users = 8;
    cfg.controller.effcap_samples = 512;
    cfg.sim.slots = 200;
    cfg
}

/// A compiled scenario fixture shared by every run of a test: same env,
/// same trace, same fault schedule — only the pool options vary.
fn fixture(scenario: &str, seed: u64) -> (SimEnv, SimOptions, CompiledScenario) {
    let cfg = small_cfg();
    let env = SimEnv::build(&cfg, seed);
    let opts = SimOptions::from_config(&cfg);
    let spec = ScenarioSpec::by_name(scenario).expect("library scenario");
    let cs = spec.compile(&env, &opts, seed ^ 0x10_57E5);
    (env, opts, cs)
}

fn pooled(opts: &SimOptions) -> SimOptions {
    let mut o = opts.clone();
    o.pool = Some(PoolConfig::from_config(&small_cfg()));
    o
}

#[test]
fn pool_off_slotted_replays_bit_identically() {
    let (env, opts, cs) = fixture("diurnal", 31);
    let a = run_trial_faulted(
        &env,
        &mut fmedge::baselines::Proposal::new(),
        31,
        &opts,
        &cs.trace,
        &cs.faults,
    );
    let b = run_trial_faulted(
        &env,
        &mut fmedge::baselines::Proposal::new(),
        31,
        &opts,
        &cs.trace,
        &cs.faults,
    );
    // Full-struct equality: histograms, sojourns, cost breakdowns, pool
    // counters (all zero off) — not just the headline rates.
    assert_eq!(a, b, "pool-off slotted trial must replay bit-identically");
    assert_eq!(a.cold_starts, 0);
    assert_eq!(a.pool_scale_events, 0);
    assert_eq!(a.pool_replica_slot_seconds, 0.0);
}

#[test]
fn pool_off_des_is_unaffected_by_a_prior_pooled_trial_in_the_arena() {
    let (env, opts, cs) = fixture("diurnal", 32);
    let dopts = DesOptions::from_sim(&opts);

    let mut fresh: DesArena = DesArena::new();
    let clean = run_des_trial_faulted_in(
        &mut fresh,
        &env,
        &mut fmedge::baselines::Proposal::new(),
        32,
        &dopts,
        &cs.trace,
        &cs.faults,
    );

    // Dirty the arena with a pooled trial (stale shared-rate columns,
    // different calendar shape), then rerun the pool-off config in it.
    let mut reused: DesArena = DesArena::new();
    let _ = run_des_trial_faulted_in(
        &mut reused,
        &env,
        &mut Autoscale::new(),
        32,
        &DesOptions::from_sim(&pooled(&opts)),
        &cs.trace,
        &cs.faults,
    );
    let after = run_des_trial_faulted_in(
        &mut reused,
        &env,
        &mut fmedge::baselines::Proposal::new(),
        32,
        &dopts,
        &cs.trace,
        &cs.faults,
    );
    assert_eq!(
        clean, after,
        "pool-off DES metrics must be byte-identical after a pooled trial reused the arena"
    );
}

#[test]
fn pooled_timelines_replay_bit_identically_across_arena_reuse() {
    let (env, opts, cs) = fixture("flash-crowd", 33);
    let dopts = DesOptions::from_sim(&pooled(&opts));

    let mut fresh: DesArena = DesArena::new();
    let a = run_des_trial_faulted_in(
        &mut fresh,
        &env,
        &mut Autoscale::new(),
        33,
        &dopts,
        &cs.trace,
        &cs.faults,
    );
    // Same config in an arena that already ran a *different* pooled
    // seed: grow/shrink/scale-to-zero event timelines must replay
    // bit-identically (full-struct equality covers the pool counters,
    // the size histogram, and the replica-slot-second accounting).
    let mut reused: DesArena = DesArena::new();
    let _ = run_des_trial_faulted_in(
        &mut reused,
        &env,
        &mut Autoscale::new(),
        777,
        &dopts,
        &cs.trace,
        &cs.faults,
    );
    let b = run_des_trial_faulted_in(
        &mut reused,
        &env,
        &mut Autoscale::new(),
        33,
        &dopts,
        &cs.trace,
        &cs.faults,
    );
    assert_eq!(a, b, "pooled DES trial must be bit-identical fresh vs reused arena");

    // And the slotted engine replays its own pooled timeline too.
    let sopts = pooled(&opts);
    let s1 = run_trial_faulted(&env, &mut Autoscale::new(), 33, &sopts, &cs.trace, &cs.faults);
    let s2 = run_trial_faulted(&env, &mut Autoscale::new(), 33, &sopts, &cs.trace, &cs.faults);
    assert_eq!(s1, s2, "pooled slotted trial must replay bit-identically");
}

#[test]
fn pooled_fixtures_exercise_cold_starts_and_scale_to_zero() {
    // Diurnal troughs + the post-cutoff drain give every pool an idle
    // window, so with min_replicas = 0 the tier must both cold-start
    // replicas on the peaks and drain whole pools on the troughs.
    let (env, opts, cs) = fixture("diurnal", 34);
    let sopts = pooled(&opts);
    let s = run_trial_faulted(&env, &mut Autoscale::new(), 34, &sopts, &cs.trace, &cs.faults);
    assert!(s.cold_starts > 0, "slotted: no cold starts exercised");
    assert!(s.pool_scale_events > 0, "slotted: pool never scaled");
    assert!(
        s.pool_scale_to_zero > 0,
        "slotted: scale-to-zero never fired over a diurnal horizon"
    );
    assert!(s.pool_replica_slot_seconds > 0.0);
    assert!(s.pool_size.count() > 0, "pool size must be sampled per slot");

    let mut arena: DesArena = DesArena::new();
    let d = run_des_trial_faulted_in(
        &mut arena,
        &env,
        &mut Autoscale::new(),
        34,
        &DesOptions::from_sim(&sopts),
        &cs.trace,
        &cs.faults,
    );
    assert!(d.cold_starts > 0, "des: no cold starts exercised");
    assert!(d.pool_scale_events > 0, "des: pool never scaled");
    assert!(
        d.pool_scale_to_zero > 0,
        "des: scale-to-zero never fired over a diurnal horizon"
    );

    // Engine agreement on the pooled fixture: same tolerance band the
    // fault-injection agreement tests use for headline rates.
    assert!(s.completed > 0 && d.completed > 0, "both engines must complete work");
    assert!(
        (s.on_time_rate() - d.on_time_rate()).abs() < 0.45,
        "pooled engines disagree: slotted {} vs des {}",
        s.on_time_rate(),
        d.on_time_rate()
    );
}

#[test]
fn p10_sweep_parallel_is_bit_identical_to_serial_and_well_formed() {
    let cfg = small_cfg();
    let mut sc = SweepConfig::for_experiment(Experiment::P10);
    sc.trials = 2;
    sc.slots = 80;
    sc.seed = 13;
    sc.loads = vec![1.0];
    sc.threads = 1;
    let serial = run_sweep(&cfg, &sc).expect("serial p10 sweep");
    serial.validate().expect("well-formed table");
    // scenarios(2) x engines(2) x loads(1) x modes(2).
    assert_eq!(serial.rows.len(), 8);
    let col = |name: &str| {
        serial
            .headers
            .iter()
            .position(|h| h == name)
            .unwrap_or_else(|| panic!("missing column {name}"))
    };
    let (mode_c, cold_c, rss_c, p95_c) = (
        col("mode"),
        col("cold_starts"),
        col("replica_slot_s"),
        col("pool_p95"),
    );
    for row in &serial.rows {
        if row[mode_c] == "autoscale" {
            assert!(row[cold_c].parse::<u64>().unwrap() > 0, "autoscale row without cold starts");
            assert!(row[rss_c].parse::<f64>().unwrap() > 0.0);
            assert_ne!(row[p95_c], "-", "autoscale row must report a pool p95");
        } else {
            assert_eq!(row[cold_c], "0", "fixed-y row must not cold-start");
            assert_eq!(row[p95_c], "-", "fixed-y row has no pool");
        }
    }
    for threads in [2, 4] {
        sc.threads = threads;
        let par = run_sweep(&cfg, &sc).expect("parallel p10 sweep");
        assert_eq!(
            serial.to_csv(),
            par.to_csv(),
            "p10 threads={threads} must be bit-identical to serial"
        );
    }
}
