//! End-to-end failover tests (EXPERIMENTS §P6): the serving path under a
//! seeded zone outage — bit-deterministic counters, zero silent drops,
//! and slotted-vs-DES agreement when the shared retry policy is active.

use fmedge::baselines::Proposal;
use fmedge::config::ExperimentConfig;
use fmedge::coordinator::{
    parse_fault_spec, FailoverPolicy, ReplayConfig, ReplayServer, VirtualRequest,
};
use fmedge::des::{run_des_trial_faulted, DesOptions};
use fmedge::faults::{FaultEvent, FaultKind, FaultSchedule};
use fmedge::metrics::TrialMetrics;
use fmedge::sim::{record_trace, run_trial_faulted, run_trial_traced, SimEnv, SimOptions};

fn small_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::paper_default();
    cfg.sim.slots = 120;
    cfg.workload.num_users = 8;
    cfg.controller.effcap_samples = 512;
    cfg
}

fn open_loop(n: u64, gap_ms: f64, deadline_ms: f64) -> Vec<VirtualRequest> {
    (0..n)
        .map(|id| VirtualRequest {
            id,
            arrive_ms: id as f64 * gap_ms,
            deadline_ms,
        })
        .collect()
}

#[test]
fn zone_outage_replay_is_bit_deterministic_with_zero_silent_drops() {
    // The acceptance criterion: under a seeded zone outage every accepted
    // request is completed (or provably payload-destroyed — the virtual
    // server holds no payloads, so: completed), the re-routed count is
    // positive, and two runs agree counter for counter.
    let cfg = small_cfg();
    let (num_eds, num_ess) = (cfg.network.num_eds, cfg.network.num_ess);
    let schedule = parse_fault_spec("zone@40+30", num_eds, num_ess).expect("spec");
    let server = ReplayServer::new(
        ReplayConfig { workers: 4, ..Default::default() },
        &schedule,
        num_eds,
    );
    let arrivals = open_loop(600, 0.5, 50.0);
    let a = server.run(&arrivals);
    let b = server.run(&arrivals);

    assert_eq!(a.stats, b.stats, "failover counters must be bit-stable");
    assert_eq!(a.served, b.served);
    assert_eq!(a.on_time, b.on_time);
    assert_eq!(a.latencies_ms, b.latencies_ms, "latency stream bit-stable");

    assert!(a.accepted > 0);
    assert_eq!(a.stats.abandoned, 0, "accepted work is never abandoned");
    assert_eq!(a.served, a.accepted, "every accepted request completes");
    assert!(
        a.stats.reroutes > 0,
        "a whole-zone outage must force re-routing: {}",
        a.stats.line()
    );
    assert!(a.stats.retries >= a.stats.reroutes);
    assert!(
        a.stats.checkpoint_restores > 0,
        "recovering workers rejoin from checkpoints: {}",
        a.stats.line()
    );
}

#[test]
fn degradation_sheds_new_admissions_never_accepted_work() {
    // Saturate a tiny queue during a long outage: the shed counter moves,
    // the abandoned counter does not.
    let cfg = small_cfg();
    let (num_eds, num_ess) = (cfg.network.num_eds, cfg.network.num_ess);
    let schedule = parse_fault_spec("zone@5+80", num_eds, num_ess).expect("spec");
    let server = ReplayServer::new(
        ReplayConfig {
            workers: 2,
            queue_capacity: 16,
            ..Default::default()
        },
        &schedule,
        num_eds,
    );
    let rep = server.run(&open_loop(400, 0.25, 40.0));
    assert!(rep.stats.shed > 0, "the tiny queue must shed: {}", rep.stats.line());
    assert_eq!(rep.stats.abandoned, 0, "shedding is for NEW work only");
    assert_eq!(rep.accepted, rep.served);
    assert_eq!(rep.accepted + rep.stats.shed, 400);
}

#[test]
fn single_server_outage_spec_reroutes_inflight_work() {
    let cfg = small_cfg();
    let (num_eds, num_ess) = (cfg.network.num_eds, cfg.network.num_ess);
    // es0 maps onto worker 0 of 2; work in flight there re-routes to 1.
    let schedule = parse_fault_spec("es0@10+20", num_eds, num_ess).expect("spec");
    let server = ReplayServer::new(
        ReplayConfig { workers: 2, ..Default::default() },
        &schedule,
        num_eds,
    );
    // Arrivals outpace the two-worker pool, so worker 0 is provably busy
    // when its outage lands.
    let rep = server.run(&open_loop(200, 0.6, 50.0));
    assert_eq!(rep.stats.abandoned, 0);
    assert!(rep.stats.retries > 0, "{}", rep.stats.line());
    assert!(rep.stats.reroutes > 0, "{}", rep.stats.line());
}

/// Zone outage over the simulation engines: two of the four edge servers
/// go dark mid-trial and recover; a replica fail-stop is paired with a
/// checkpoint restart.
fn zone_schedule(cfg: &ExperimentConfig, slot_ms: f64) -> FaultSchedule {
    let es = cfg.network.num_eds;
    let mut events = vec![
        FaultEvent { time_ms: 30.0 * slot_ms, kind: FaultKind::NodeDown { node: es } },
        FaultEvent { time_ms: 32.0 * slot_ms, kind: FaultKind::NodeDown { node: es + 1 } },
        FaultEvent {
            time_ms: 45.0 * slot_ms,
            kind: FaultKind::CoreReplicaFail { node: es + 2, core_idx: 0 },
        },
        FaultEvent {
            time_ms: 58.0 * slot_ms,
            kind: FaultKind::CoreReplicaRestart { node: es + 2, core_idx: 0 },
        },
        FaultEvent { time_ms: 70.0 * slot_ms, kind: FaultKind::NodeUp { node: es } },
        FaultEvent { time_ms: 72.0 * slot_ms, kind: FaultKind::NodeUp { node: es + 1 } },
    ];
    events.sort_by(|a, b| a.time_ms.total_cmp(&b.time_ms));
    FaultSchedule::from_events(events)
}

fn assert_counters_identical(a: &TrialMetrics, b: &TrialMetrics, what: &str) {
    assert_eq!(a.total_tasks, b.total_tasks, "{what}: total_tasks");
    assert_eq!(a.completed, b.completed, "{what}: completed");
    assert_eq!(a.on_time, b.on_time, "{what}: on_time");
    assert_eq!(a.fault_drops, b.fault_drops, "{what}: fault_drops");
    assert_eq!(a.retries, b.retries, "{what}: retries");
    assert_eq!(
        a.reroute_recovered, b.reroute_recovered,
        "{what}: reroute_recovered"
    );
    assert_eq!(a.hedges, b.hedges, "{what}: hedges");
    assert_eq!(
        a.checkpoint_restores, b.checkpoint_restores,
        "{what}: checkpoint_restores"
    );
}

#[test]
fn engines_replay_retry_policy_deterministically_under_zone_outage() {
    let mut cfg = small_cfg();
    // Enough concurrent work that the two-server outage is guaranteed to
    // catch stages in flight.
    cfg.sim.load_multiplier = 1.5;
    let seed = 61;
    let env = SimEnv::build(&cfg, seed);
    let opts = SimOptions::from_config(&cfg);
    let trace = record_trace(&env, seed, &opts);
    let schedule = zone_schedule(&cfg, opts.slot_ms);

    let s1 = run_trial_faulted(&env, &mut Proposal::new(), seed, &opts, &trace, &schedule);
    let s2 = run_trial_faulted(&env, &mut Proposal::new(), seed, &opts, &trace, &schedule);
    assert_counters_identical(&s1, &s2, "slotted");

    let dopts = DesOptions::from_sim(&opts);
    let d1 = run_des_trial_faulted(&env, &mut Proposal::new(), seed, &dopts, &trace, &schedule);
    let d2 = run_des_trial_faulted(&env, &mut Proposal::new(), seed, &dopts, &trace, &schedule);
    assert_counters_identical(&d1, &d2, "des");

    // The two-server outage cancels in-flight work on both engines; the
    // retry layer must recover it rather than drop it.
    assert!(
        s1.retries > 0,
        "slotted: outage must cancel in-flight stages (retries {})",
        s1.retries
    );
    assert!(
        d1.retries > 0,
        "des: outage must cancel in-flight stages (retries {})",
        d1.retries
    );
    assert!(
        s1.reroute_recovered > 0,
        "slotted: cancelled stages must re-route (recovered {})",
        s1.reroute_recovered
    );
    assert!(
        d1.reroute_recovered > 0,
        "des: cancelled stages must re-route (recovered {})",
        d1.reroute_recovered
    );

    // No silent drops: every admitted task is completed, payload-destroyed,
    // or aged out by the drop bound — the engines account for all of them
    // (vq_residual 0 already proves no controller-state leak).
    assert_eq!(s1.vq_residual, 0);
    assert_eq!(d1.vq_residual, 0);
    assert!(s1.completed + s1.fault_drops <= s1.total_tasks);
    assert!(d1.completed + d1.fault_drops <= d1.total_tasks);

    // Engine agreement on the damage, baseline-relative (same tolerances
    // as the fault-injection suite).
    let s_base = run_trial_traced(&env, &mut Proposal::new(), seed, &opts, &trace);
    let d_base = fmedge::des::run_des_trial(&env, &mut Proposal::new(), seed, &dopts, &trace);
    let s_drop = s_base.on_time_rate() - s1.on_time_rate();
    let d_drop = d_base.on_time_rate() - d1.on_time_rate();
    assert!(
        s_drop > -0.10 && d_drop > -0.10,
        "an outage must not improve an engine: slotted {s_drop}, des {d_drop}"
    );
    assert!(
        (s_drop - d_drop).abs() < 0.35,
        "engines disagree on fault damage: slotted {s_drop} vs des {d_drop}"
    );
}

#[test]
fn checkpoint_restart_restores_replica_capacity() {
    // The paired fail-stop + restart must register as a checkpoint
    // restore on both engines (the replica was killed while its node was
    // healthy, so the rejoin path runs).
    let cfg = small_cfg();
    let seed = 67;
    let env = SimEnv::build(&cfg, seed);
    let opts = SimOptions::from_config(&cfg);
    let trace = record_trace(&env, seed, &opts);
    let es = cfg.network.num_eds;
    // Kill one replica on every ES, restart them all later: whatever the
    // placement looks like, at least one kill (and thus one restart)
    // lands on a live replica.
    let mut events = Vec::new();
    for k in 0..cfg.network.num_ess {
        for core_idx in 0..env.app.catalog.num_core() {
            events.push(FaultEvent {
                time_ms: 20.0 * opts.slot_ms,
                kind: FaultKind::CoreReplicaFail { node: es + k, core_idx },
            });
            events.push(FaultEvent {
                time_ms: 50.0 * opts.slot_ms,
                kind: FaultKind::CoreReplicaRestart { node: es + k, core_idx },
            });
        }
    }
    let schedule = FaultSchedule::from_events(events);
    let s = run_trial_faulted(&env, &mut Proposal::new(), seed, &opts, &trace, &schedule);
    let d = run_des_trial_faulted(
        &env,
        &mut Proposal::new(),
        seed,
        &DesOptions::from_sim(&opts),
        &trace,
        &schedule,
    );
    assert!(
        s.checkpoint_restores > 0,
        "slotted: restart must restore a killed replica"
    );
    assert!(
        d.checkpoint_restores > 0,
        "des: restart must restore a killed replica"
    );
    assert_eq!(
        s.checkpoint_restores, d.checkpoint_restores,
        "both engines replay the same restart set"
    );
}

#[test]
fn failover_counters_stay_zero_without_faults() {
    // The inertness contract: with no fault schedule the retry layer
    // never fires — fault-free runs are byte-identical to pre-failover
    // behavior (the zero-fault equivalence test covers the full metric
    // identity; this pins the new counters specifically).
    let cfg = small_cfg();
    let seed = 71;
    let env = SimEnv::build(&cfg, seed);
    let opts = SimOptions::from_config(&cfg);
    assert_eq!(opts.failover, FailoverPolicy::default());
    let trace = record_trace(&env, seed, &opts);
    let m = run_trial_traced(&env, &mut Proposal::new(), seed, &opts, &trace);
    assert_eq!(m.retries, 0);
    assert_eq!(m.reroute_recovered, 0);
    assert_eq!(m.hedges, 0);
    assert_eq!(m.checkpoint_restores, 0);
    assert_eq!(m.fault_drops, 0);
}
