//! Property-based tests over the coordinator-facing invariants, using the
//! in-tree `testkit` (proptest substitute): routing, batching, deployment
//! and state management must hold for arbitrary generated inputs.

use fmedge::config::{ExperimentConfig, NUM_RESOURCES};
use fmedge::controller::{greedy_light_deployment, LightRequest, OnlineParams, VirtualQueues};
use fmedge::effcap::{EffCapEstimator, GTable, GTableParams};
use fmedge::graph::Dag;
use fmedge::lp::{LinProg, LpStatus, Relation};
use fmedge::metrics::{kde_violin, quantile, Summary};
use fmedge::rng::{Distribution, Gamma, Rng, Xoshiro256};
use fmedge::routing::DistanceMatrix;
use fmedge::testkit::{self, Gen};

// --------------------------------------------------------------- helpers --

struct Fixture {
    dm: DistanceMatrix,
    gtable: GTable,
    resources: Vec<[f64; NUM_RESOURCES]>,
    costs: Vec<(f64, f64, f64)>,
    nv: usize,
}

fn fixture() -> Fixture {
    let cfg = ExperimentConfig::paper_default();
    let mut rng = Xoshiro256::seed_from(1234);
    let topo = fmedge::network::Topology::generate(&cfg, &mut rng);
    let dm = DistanceMatrix::build(&topo, 1.0);
    let nl = 5;
    let mut samples = Vec::new();
    let mut workloads = Vec::new();
    for i in 0..nl {
        let g = Gamma::new(1.2 + 0.2 * i as f64, 4.0 + 2.0 * i as f64);
        samples.push(g.sample_n(&mut rng, 1024));
        workloads.push(0.5 + 0.3 * i as f64);
    }
    let gtable = GTable::build(&samples, &workloads, &GTableParams::default_paper());
    Fixture {
        nv: topo.num_nodes(),
        dm,
        gtable,
        resources: vec![[1.0, 0.2, 0.5, 0.1]; nl],
        costs: vec![(4.0, 1.0, 0.5); nl],
    }
}

/// Generator for a queue of light requests.
struct QueueGen {
    nv: usize,
    nl: usize,
}

impl Gen for QueueGen {
    type Value = Vec<(usize, usize, f64, f64)>; // (light_idx, node, payload, h)
    fn generate<R: Rng + ?Sized>(&self, rng: &mut R) -> Self::Value {
        let n = rng.range_usize(0, 40);
        (0..n)
            .map(|_| {
                (
                    rng.next_below(self.nl as u64) as usize,
                    rng.next_below(self.nv as u64) as usize,
                    rng.range_f64(0.1, 2.0),
                    rng.range_f64(0.5, 50.0),
                )
            })
            .collect()
    }
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out = Vec::new();
        if !v.is_empty() {
            out.push(v[..v.len() / 2].to_vec());
            let mut c = v.clone();
            c.pop();
            out.push(c);
        }
        out
    }
}

fn to_requests(raw: &[(usize, usize, f64, f64)]) -> Vec<LightRequest> {
    raw.iter()
        .enumerate()
        .map(|(i, &(m, v, mb, h))| LightRequest {
            task_id: i as u64,
            light_idx: m,
            from_node: v,
            payload_mb: mb,
            h,
            deadline_slack_ms: 50.0,
        })
        .collect()
}

// ------------------------------------------------------------ controller --

#[test]
fn prop_deployment_never_exceeds_capacity() {
    let fx = fixture();
    let gen = QueueGen { nv: fx.nv, nl: 5 };
    testkit::check(60, gen, |raw| {
        let queue = to_requests(raw);
        let busy = vec![vec![0u32; 5]; fx.nv];
        let residual = vec![[4.0, 1.0, 2.0, 0.5]; fx.nv];
        let d = greedy_light_deployment(
            &queue,
            &busy,
            &residual,
            &fx.resources,
            &fx.costs,
            &fx.gtable,
            &fx.dm,
            &OnlineParams::from_config(&ExperimentConfig::paper_default().controller),
        );
        for v in 0..fx.nv {
            for k in 0..NUM_RESOURCES {
                let used: f64 = (0..5)
                    .map(|m| fx.resources[m][k] * d.x[v][m] as f64)
                    .sum();
                if used > residual[v][k] + 1e-9 {
                    return false;
                }
            }
        }
        true
    });
}

#[test]
fn prop_assignments_target_deployed_instances() {
    let fx = fixture();
    let gen = QueueGen { nv: fx.nv, nl: 5 };
    testkit::check(60, gen, |raw| {
        let queue = to_requests(raw);
        let busy = vec![vec![0u32; 5]; fx.nv];
        let residual = vec![[8.0, 2.0, 4.0, 1.0]; fx.nv];
        let d = greedy_light_deployment(
            &queue,
            &busy,
            &residual,
            &fx.resources,
            &fx.costs,
            &fx.gtable,
            &fx.dm,
            &OnlineParams::from_config(&ExperimentConfig::paper_default().controller),
        );
        d.assignments.iter().enumerate().all(|(qi, a)| match a {
            None => true,
            Some(a) => {
                a.light_idx == queue[qi].light_idx
                    && d.x[a.node][a.light_idx] > 0
                    && a.y >= 1
                    && a.y as usize <= fx.gtable.max_parallelism()
            }
        })
    });
}

#[test]
fn prop_parallelism_accounting_is_consistent() {
    let fx = fixture();
    let gen = QueueGen { nv: fx.nv, nl: 5 };
    testkit::check(60, gen, |raw| {
        let queue = to_requests(raw);
        let busy = vec![vec![0u32; 5]; fx.nv];
        let residual = vec![[8.0, 2.0, 4.0, 1.0]; fx.nv];
        let d = greedy_light_deployment(
            &queue,
            &busy,
            &residual,
            &fx.resources,
            &fx.costs,
            &fx.gtable,
            &fx.dm,
            &OnlineParams::from_config(&ExperimentConfig::paper_default().controller),
        );
        // y[v][m] equals the number of assignments routed there, and never
        // exceeds instances × max parallelism (constraint C3 of (17)).
        let mut counted = vec![vec![0u32; 5]; fx.nv];
        for a in d.assignments.iter().flatten() {
            counted[a.node][a.light_idx] += 1;
        }
        for v in 0..fx.nv {
            for m in 0..5 {
                if counted[v][m] != d.y[v][m] {
                    return false;
                }
                if d.y[v][m] > d.x[v][m] * fx.gtable.max_parallelism() as u32 {
                    return false;
                }
            }
        }
        true
    });
}

#[test]
fn prop_virtual_queue_never_below_floor() {
    testkit::check(
        200,
        testkit::vec_of(
            testkit::pair_of(testkit::f64_in(0.0, 300.0), testkit::f64_in(10.0, 100.0)),
            0..50,
        ),
        |updates| {
            let mut q = VirtualQueues::new(0.7);
            for &(elapsed, deadline) in updates {
                q.update(1, elapsed, deadline);
                if q.value(1) < 0.7 - 1e-12 {
                    return false;
                }
            }
            true
        },
    );
}

// ---------------------------------------------------------------- effcap --

#[test]
fn prop_delay_bound_dominates_mean_and_decreases_in_epsilon() {
    testkit::check(
        40,
        testkit::pair_of(testkit::f64_in(0.8, 2.5), testkit::f64_in(2.0, 20.0)),
        |&(shape, scale)| {
            let mut rng = Xoshiro256::seed_from((shape * 1000.0) as u64);
            let samples = Gamma::new(shape, scale).sample_n(&mut rng, 2048);
            let est = EffCapEstimator::log_grid(1e-3, 10.0, 24);
            let mu = samples.iter().sum::<f64>() / samples.len() as f64;
            let d_strict = est.delay_bound(&samples, 1.0, 0.05);
            let d_loose = est.delay_bound(&samples, 1.0, 0.4);
            d_strict >= d_loose - 1e-12 && d_loose >= 1.0 / mu - 1e-9
        },
    );
}

// ------------------------------------------------------------- substrate --

#[test]
fn prop_lp_optimum_is_feasible() {
    // Random bounded LPs: the reported optimum satisfies every constraint.
    testkit::check(
        60,
        testkit::pair_of(testkit::usize_in(1, 6), testkit::u64_up_to(u64::MAX)),
        |&(n, seed)| {
            let mut rng = Xoshiro256::seed_from(seed);
            let mut lp = LinProg::minimize(n);
            let c: Vec<f64> = (0..n).map(|_| rng.range_f64(-2.0, 2.0)).collect();
            lp.set_objective(&c);
            let mut rows = Vec::new();
            for _ in 0..rng.range_usize(1, 8) {
                let coeffs: Vec<(usize, f64)> = (0..n)
                    .map(|j| (j, rng.range_f64(0.0, 3.0)))
                    .collect();
                let rhs = rng.range_f64(1.0, 20.0);
                lp.add_constraint(&coeffs, Relation::Le, rhs);
                rows.push((coeffs, rhs));
            }
            for j in 0..n {
                lp.set_upper_bound(j, rng.range_f64(1.0, 10.0));
            }
            match lp.solve() {
                Ok(sol) if sol.status == LpStatus::Optimal => {
                    sol.x.iter().all(|&x| x >= -1e-7)
                        && rows.iter().all(|(coeffs, rhs)| {
                            coeffs.iter().map(|&(j, a)| a * sol.x[j]).sum::<f64>()
                                <= rhs + 1e-6
                        })
                }
                Ok(_) => true, // infeasible/unbounded are legitimate
                Err(_) => false,
            }
        },
    );
}

#[test]
fn prop_dag_topo_order_is_consistent() {
    testkit::check(
        100,
        testkit::pair_of(testkit::usize_in(2, 12), testkit::u64_up_to(u64::MAX)),
        |&(n, seed)| {
            let mut rng = Xoshiro256::seed_from(seed);
            let mut dag = Dag::new(n);
            // Forward edges only => acyclic by construction.
            for i in 0..n - 1 {
                let succ = i + 1 + rng.next_below((n - 1 - i) as u64) as usize;
                let _ = dag.add_edge(i, succ);
            }
            let Ok(order) = dag.topo_order() else {
                return false;
            };
            let mut pos = vec![0; n];
            for (i, &x) in order.iter().enumerate() {
                pos[x] = i;
            }
            (0..n).all(|u| dag.children(u).iter().all(|&v| pos[u] < pos[v]))
        },
    );
}

#[test]
fn prop_quantiles_are_monotone_and_bounded() {
    testkit::check(
        150,
        testkit::vec_of(testkit::f64_in(-100.0, 100.0), 1..80),
        |xs| {
            let mut s = xs.clone();
            s.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let q1 = quantile(&s, 0.1);
            let q5 = quantile(&s, 0.5);
            let q9 = quantile(&s, 0.9);
            q1 <= q5 && q5 <= q9 && q1 >= s[0] - 1e-12 && q9 <= s[s.len() - 1] + 1e-12
        },
    );
}

#[test]
fn prop_kde_density_is_nonnegative_and_normalized() {
    testkit::check(
        40,
        testkit::vec_of(testkit::f64_in(0.0, 10.0), 2..60),
        |xs| {
            let v = kde_violin(xs, 256);
            if v.density.iter().any(|&d| d < 0.0) {
                return false;
            }
            let dx = v.grid[1] - v.grid[0];
            let integral: f64 = v.density.iter().sum::<f64>() * dx;
            (integral - 1.0).abs() < 0.05
        },
    );
}

#[test]
fn prop_summary_mean_between_min_max() {
    testkit::check(
        150,
        testkit::vec_of(testkit::f64_in(-50.0, 50.0), 1..60),
        |xs| {
            let s = Summary::of(xs);
            s.min <= s.mean + 1e-12 && s.mean <= s.max + 1e-12 && s.q25 <= s.q75
        },
    );
}
