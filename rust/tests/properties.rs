//! Property-based tests over the coordinator-facing invariants, using the
//! in-tree `testkit` (proptest substitute): routing, batching, deployment
//! and state management must hold for arbitrary generated inputs.

use fmedge::config::{ExperimentConfig, NUM_RESOURCES};
use fmedge::controller::{greedy_light_deployment, LightRequest, OnlineParams, VirtualQueues};
use fmedge::effcap::{EffCapEstimator, GTable, GTableParams};
use fmedge::graph::Dag;
use fmedge::ilp::{BnbOptions, IlpModel, IlpStatus, LinExpr, NodeLpMode, VarKind};
use fmedge::lp::{LinProg, LpStatus, Relation};
use fmedge::metrics::{kde_violin, quantile, Summary};
use fmedge::microservice::build_fig1_application;
use fmedge::placement::{solve_static_placement, PlacementParams, QosScores, ScoreParams};
use fmedge::rng::{Distribution, Gamma, Rng, Xoshiro256};
use fmedge::routing::DistanceMatrix;
use fmedge::testkit::{self, Gen};
use fmedge::workload::WorkloadGenerator;

// --------------------------------------------------------------- helpers --

struct Fixture {
    dm: DistanceMatrix,
    gtable: GTable,
    resources: Vec<[f64; NUM_RESOURCES]>,
    costs: Vec<(f64, f64, f64)>,
    nv: usize,
}

fn fixture() -> Fixture {
    let cfg = ExperimentConfig::paper_default();
    let mut rng = Xoshiro256::seed_from(1234);
    let topo = fmedge::network::Topology::generate(&cfg, &mut rng);
    let dm = DistanceMatrix::build(&topo, 1.0);
    let nl = 5;
    let mut samples = Vec::new();
    let mut workloads = Vec::new();
    for i in 0..nl {
        let g = Gamma::new(1.2 + 0.2 * i as f64, 4.0 + 2.0 * i as f64);
        samples.push(g.sample_n(&mut rng, 1024));
        workloads.push(0.5 + 0.3 * i as f64);
    }
    let gtable = GTable::build(&samples, &workloads, &GTableParams::default_paper());
    Fixture {
        nv: topo.num_nodes(),
        dm,
        gtable,
        resources: vec![[1.0, 0.2, 0.5, 0.1]; nl],
        costs: vec![(4.0, 1.0, 0.5); nl],
    }
}

/// Generator for a queue of light requests.
struct QueueGen {
    nv: usize,
    nl: usize,
}

impl Gen for QueueGen {
    type Value = Vec<(usize, usize, f64, f64)>; // (light_idx, node, payload, h)
    fn generate<R: Rng + ?Sized>(&self, rng: &mut R) -> Self::Value {
        let n = rng.range_usize(0, 40);
        (0..n)
            .map(|_| {
                (
                    rng.next_below(self.nl as u64) as usize,
                    rng.next_below(self.nv as u64) as usize,
                    rng.range_f64(0.1, 2.0),
                    rng.range_f64(0.5, 50.0),
                )
            })
            .collect()
    }
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out = Vec::new();
        if !v.is_empty() {
            out.push(v[..v.len() / 2].to_vec());
            let mut c = v.clone();
            c.pop();
            out.push(c);
        }
        out
    }
}

fn to_requests(raw: &[(usize, usize, f64, f64)]) -> Vec<LightRequest> {
    raw.iter()
        .enumerate()
        .map(|(i, &(m, v, mb, h))| LightRequest {
            task_id: i as u64,
            light_idx: m,
            from_node: v,
            payload_mb: mb,
            h,
            deadline_slack_ms: 50.0,
        })
        .collect()
}

// ------------------------------------------------------------ controller --

#[test]
fn prop_deployment_never_exceeds_capacity() {
    let fx = fixture();
    let gen = QueueGen { nv: fx.nv, nl: 5 };
    testkit::check(60, gen, |raw| {
        let queue = to_requests(raw);
        let busy = vec![vec![0u32; 5]; fx.nv];
        let residual = vec![[4.0, 1.0, 2.0, 0.5]; fx.nv];
        let d = greedy_light_deployment(
            &queue,
            &busy,
            &residual,
            &fx.resources,
            &fx.costs,
            &fx.gtable,
            &fx.dm,
            &OnlineParams::from_config(&ExperimentConfig::paper_default().controller),
        );
        for v in 0..fx.nv {
            for k in 0..NUM_RESOURCES {
                let used: f64 = (0..5)
                    .map(|m| fx.resources[m][k] * d.x[v][m] as f64)
                    .sum();
                if used > residual[v][k] + 1e-9 {
                    return false;
                }
            }
        }
        true
    });
}

#[test]
fn prop_assignments_target_deployed_instances() {
    let fx = fixture();
    let gen = QueueGen { nv: fx.nv, nl: 5 };
    testkit::check(60, gen, |raw| {
        let queue = to_requests(raw);
        let busy = vec![vec![0u32; 5]; fx.nv];
        let residual = vec![[8.0, 2.0, 4.0, 1.0]; fx.nv];
        let d = greedy_light_deployment(
            &queue,
            &busy,
            &residual,
            &fx.resources,
            &fx.costs,
            &fx.gtable,
            &fx.dm,
            &OnlineParams::from_config(&ExperimentConfig::paper_default().controller),
        );
        d.assignments.iter().enumerate().all(|(qi, a)| match a {
            None => true,
            Some(a) => {
                a.light_idx == queue[qi].light_idx
                    && d.x[a.node][a.light_idx] > 0
                    && a.y >= 1
                    && a.y as usize <= fx.gtable.max_parallelism()
            }
        })
    });
}

#[test]
fn prop_parallelism_accounting_is_consistent() {
    let fx = fixture();
    let gen = QueueGen { nv: fx.nv, nl: 5 };
    testkit::check(60, gen, |raw| {
        let queue = to_requests(raw);
        let busy = vec![vec![0u32; 5]; fx.nv];
        let residual = vec![[8.0, 2.0, 4.0, 1.0]; fx.nv];
        let d = greedy_light_deployment(
            &queue,
            &busy,
            &residual,
            &fx.resources,
            &fx.costs,
            &fx.gtable,
            &fx.dm,
            &OnlineParams::from_config(&ExperimentConfig::paper_default().controller),
        );
        // y[v][m] equals the number of assignments routed there, and never
        // exceeds instances × max parallelism (constraint C3 of (17)).
        let mut counted = vec![vec![0u32; 5]; fx.nv];
        for a in d.assignments.iter().flatten() {
            counted[a.node][a.light_idx] += 1;
        }
        for v in 0..fx.nv {
            for m in 0..5 {
                if counted[v][m] != d.y[v][m] {
                    return false;
                }
                if d.y[v][m] > d.x[v][m] * fx.gtable.max_parallelism() as u32 {
                    return false;
                }
            }
        }
        true
    });
}

#[test]
fn prop_virtual_queue_never_below_floor() {
    testkit::check(
        200,
        testkit::vec_of(
            testkit::pair_of(testkit::f64_in(0.0, 300.0), testkit::f64_in(10.0, 100.0)),
            0..50,
        ),
        |updates| {
            let mut q = VirtualQueues::new(0.7);
            for &(elapsed, deadline) in updates {
                q.update(1, elapsed, deadline);
                if q.value(1) < 0.7 - 1e-12 {
                    return false;
                }
            }
            true
        },
    );
}

// ---------------------------------------------------------------- effcap --

#[test]
fn prop_delay_bound_dominates_mean_and_decreases_in_epsilon() {
    testkit::check(
        40,
        testkit::pair_of(testkit::f64_in(0.8, 2.5), testkit::f64_in(2.0, 20.0)),
        |&(shape, scale)| {
            let mut rng = Xoshiro256::seed_from((shape * 1000.0) as u64);
            let samples = Gamma::new(shape, scale).sample_n(&mut rng, 2048);
            let est = EffCapEstimator::log_grid(1e-3, 10.0, 24);
            let mu = samples.iter().sum::<f64>() / samples.len() as f64;
            let d_strict = est.delay_bound(&samples, 1.0, 0.05);
            let d_loose = est.delay_bound(&samples, 1.0, 0.4);
            d_strict >= d_loose - 1e-12 && d_loose >= 1.0 / mu - 1e-9
        },
    );
}

// ------------------------------------------------------------- substrate --

#[test]
fn prop_lp_optimum_is_feasible() {
    // Random bounded LPs: the reported optimum satisfies every constraint.
    testkit::check(
        60,
        testkit::pair_of(testkit::usize_in(1, 6), testkit::u64_up_to(u64::MAX)),
        |&(n, seed)| {
            let mut rng = Xoshiro256::seed_from(seed);
            let mut lp = LinProg::minimize(n);
            let c: Vec<f64> = (0..n).map(|_| rng.range_f64(-2.0, 2.0)).collect();
            lp.set_objective(&c);
            let mut rows = Vec::new();
            for _ in 0..rng.range_usize(1, 8) {
                let coeffs: Vec<(usize, f64)> = (0..n)
                    .map(|j| (j, rng.range_f64(0.0, 3.0)))
                    .collect();
                let rhs = rng.range_f64(1.0, 20.0);
                lp.add_constraint(&coeffs, Relation::Le, rhs);
                rows.push((coeffs, rhs));
            }
            for j in 0..n {
                lp.set_upper_bound(j, rng.range_f64(1.0, 10.0));
            }
            match lp.solve() {
                Ok(sol) if sol.status == LpStatus::Optimal => {
                    sol.x.iter().all(|&x| x >= -1e-7)
                        && rows.iter().all(|(coeffs, rhs)| {
                            coeffs.iter().map(|&(j, a)| a * sol.x[j]).sum::<f64>()
                                <= rhs + 1e-6
                        })
                }
                Ok(_) => true, // infeasible/unbounded are legitimate
                Err(_) => false,
            }
        },
    );
}

/// Build a random bounded LP exercising all relation kinds plus native
/// lower/upper variable bounds. Every variable is boxed, so the LP is
/// never unbounded and both backends must agree on Optimal/Infeasible.
fn random_boxed_lp(n: usize, rng: &mut Xoshiro256) -> LinProg {
    let mut lp = LinProg::minimize(n);
    let c: Vec<f64> = (0..n).map(|_| rng.range_f64(-3.0, 3.0)).collect();
    lp.set_objective(&c);
    for j in 0..n {
        let lo = if rng.next_below(3) == 0 {
            rng.range_f64(0.0, 2.0)
        } else {
            0.0
        };
        let hi = lo + rng.range_f64(0.5, 8.0);
        if lo > 0.0 {
            lp.set_lower_bound(j, lo);
        }
        lp.set_upper_bound(j, hi);
    }
    for _ in 0..rng.range_usize(1, 6) {
        let coeffs: Vec<(usize, f64)> = (0..n)
            .map(|j| (j, rng.range_f64(0.2, 3.0)))
            .collect();
        match rng.next_below(5) {
            0 => lp.add_constraint(&coeffs, Relation::Ge, rng.range_f64(0.0, 4.0)),
            1 => lp.add_constraint(&coeffs, Relation::Eq, rng.range_f64(0.5, 6.0)),
            _ => lp.add_constraint(&coeffs, Relation::Le, rng.range_f64(2.0, 25.0)),
        }
    }
    lp
}

#[test]
fn prop_revised_simplex_matches_dense_on_random_lps() {
    // The acceptance bar: >= 100 random LPs where the warm-startable
    // revised simplex and the dense reference tableau agree on status and
    // optimal objective.
    testkit::check(
        150,
        testkit::pair_of(testkit::usize_in(1, 7), testkit::u64_up_to(u64::MAX)),
        |&(n, seed)| {
            let mut rng = Xoshiro256::seed_from(seed);
            let lp = random_boxed_lp(n, &mut rng);
            let (dense, fast) = match (lp.solve_dense(), lp.solve()) {
                (Ok(d), Ok(f)) => (d, f),
                _ => return false,
            };
            if dense.status != fast.status {
                return false;
            }
            if dense.status != LpStatus::Optimal {
                return true;
            }
            if (dense.objective - fast.objective).abs() > 1e-6 * (1.0 + dense.objective.abs()) {
                return false;
            }
            // The revised optimum must be a real point.
            fast.x.iter().all(|x| x.is_finite())
        },
    );
}

#[test]
fn prop_bnb_warm_start_matches_dense_rebuild() {
    // Random boxed MILPs solved to proven optimality under both node-LP
    // engines must agree on status and objective: warm-starting is a pure
    // performance change.
    testkit::check(
        50,
        testkit::pair_of(testkit::usize_in(2, 8), testkit::u64_up_to(u64::MAX)),
        |&(n, seed)| {
            let mut rng = Xoshiro256::seed_from(seed ^ 0x9e3779b97f4a7c15);
            let mut m = IlpModel::new();
            let vars: Vec<_> = (0..n)
                .map(|_| {
                    let kind = if rng.next_below(2) == 0 {
                        VarKind::Binary
                    } else {
                        VarKind::Integer {
                            ub: Some(1 + rng.next_below(4)),
                        }
                    };
                    m.add_var(kind, rng.range_f64(-5.0, 5.0))
                })
                .collect();
            for _ in 0..rng.range_usize(1, 3) {
                let terms: Vec<_> = vars
                    .iter()
                    .map(|&v| (v, rng.range_f64(0.0, 3.0)))
                    .collect();
                m.add_constraint(
                    LinExpr::from_terms(&terms),
                    Relation::Le,
                    rng.range_f64(1.0, 2.0 * n as f64),
                );
            }
            if rng.next_below(2) == 0 {
                let terms: Vec<_> = vars
                    .iter()
                    .map(|&v| (v, rng.range_f64(0.5, 2.0)))
                    .collect();
                m.add_constraint(
                    LinExpr::from_terms(&terms),
                    Relation::Ge,
                    rng.range_f64(0.0, 2.0),
                );
            }
            let solve_with = |mode: NodeLpMode| {
                m.solve(&BnbOptions {
                    node_lp: mode,
                    ..Default::default()
                })
            };
            let (warm, dense) = match (
                solve_with(NodeLpMode::WarmRevised),
                solve_with(NodeLpMode::DenseRebuild),
            ) {
                (Ok(w), Ok(d)) => (w, d),
                _ => return false,
            };
            if warm.status != dense.status {
                return false;
            }
            match warm.status {
                IlpStatus::Optimal => {
                    (warm.objective - dense.objective).abs()
                        <= 1e-6 * (1.0 + dense.objective.abs())
                        && m.is_feasible(&warm.x, 1e-6)
                }
                IlpStatus::Infeasible => true,
                // Boxed vars: never unbounded; node budget is generous.
                _ => false,
            }
        },
    );
}

#[test]
fn bnb_warm_start_is_objective_invariant_on_placement_instances() {
    // Exact static placement on (reduced-size) seed instances: the
    // warm-started engine must reproduce the dense-rebuild objective, so
    // warm-starting cannot change placement results.
    for seed in [4u64, 5, 6] {
        let mut cfg = ExperimentConfig::paper_default();
        cfg.network.num_eds = 4;
        cfg.network.num_ess = 2;
        let mut rng = Xoshiro256::seed_from(seed);
        let app = build_fig1_application(&cfg, &mut rng);
        let topo = fmedge::network::Topology::generate(&cfg, &mut rng);
        let gen = WorkloadGenerator::new(&cfg, &app, &topo, &mut rng);
        let dm = DistanceMatrix::build(&topo, 1.0);
        let scores = QosScores::compute(
            &app,
            &topo,
            &dm,
            gen.users(),
            &ScoreParams::from_config(&cfg.controller),
        );
        let mut p = PlacementParams::from_config(&cfg, cfg.sim.slots);
        p.exact = true;
        p.max_nodes = 20_000;
        p.node_lp = NodeLpMode::WarmRevised;
        let warm = solve_static_placement(&app, &topo, &scores, &p);
        p.node_lp = NodeLpMode::DenseRebuild;
        let dense = solve_static_placement(&app, &topo, &scores, &p);
        assert_eq!(
            warm.used_fallback, dense.used_fallback,
            "seed {seed}: engines disagree on ILP success"
        );
        assert!(
            (warm.objective - dense.objective).abs()
                <= 1e-6 * (1.0 + dense.objective.abs()),
            "seed {seed}: warm objective {} != dense objective {}",
            warm.objective,
            dense.objective
        );
    }
}

#[test]
fn prop_dag_topo_order_is_consistent() {
    testkit::check(
        100,
        testkit::pair_of(testkit::usize_in(2, 12), testkit::u64_up_to(u64::MAX)),
        |&(n, seed)| {
            let mut rng = Xoshiro256::seed_from(seed);
            let mut dag = Dag::new(n);
            // Forward edges only => acyclic by construction.
            for i in 0..n - 1 {
                let succ = i + 1 + rng.next_below((n - 1 - i) as u64) as usize;
                let _ = dag.add_edge(i, succ);
            }
            let Ok(order) = dag.topo_order() else {
                return false;
            };
            let mut pos = vec![0; n];
            for (i, &x) in order.iter().enumerate() {
                pos[x] = i;
            }
            (0..n).all(|u| dag.children(u).iter().all(|&v| pos[u] < pos[v]))
        },
    );
}

#[test]
fn prop_quantiles_are_monotone_and_bounded() {
    testkit::check(
        150,
        testkit::vec_of(testkit::f64_in(-100.0, 100.0), 1..80),
        |xs| {
            let mut s = xs.clone();
            s.sort_by(f64::total_cmp);
            let q1 = quantile(&s, 0.1);
            let q5 = quantile(&s, 0.5);
            let q9 = quantile(&s, 0.9);
            q1 <= q5 && q5 <= q9 && q1 >= s[0] - 1e-12 && q9 <= s[s.len() - 1] + 1e-12
        },
    );
}

#[test]
fn prop_kde_density_is_nonnegative_and_normalized() {
    testkit::check(
        40,
        testkit::vec_of(testkit::f64_in(0.0, 10.0), 2..60),
        |xs| {
            let v = kde_violin(xs, 256);
            if v.density.iter().any(|&d| d < 0.0) {
                return false;
            }
            let dx = v.grid[1] - v.grid[0];
            let integral: f64 = v.density.iter().sum::<f64>() * dx;
            (integral - 1.0).abs() < 0.05
        },
    );
}

#[test]
fn prop_summary_mean_between_min_max() {
    testkit::check(
        150,
        testkit::vec_of(testkit::f64_in(-50.0, 50.0), 1..60),
        |xs| {
            let s = Summary::of(xs);
            s.min <= s.mean + 1e-12 && s.mean <= s.max + 1e-12 && s.q25 <= s.q75
        },
    );
}
