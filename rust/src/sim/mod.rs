//! Slotted discrete-event simulation of the edge network (§IV's testbed).
//!
//! Each trial samples a concrete application, topology and user population
//! from the Table I ranges, lets a [`Strategy`] place core services once
//! and decide light deployments every slot, executes tasks with realized
//! random uplink/fading/service-rate draws, and reports the paper's
//! metrics (on-time completion rate, total cost).

mod engine;

pub use engine::{
    record_trace, run_trial, run_trial_faulted, run_trial_observed, run_trial_traced, SimEnv,
    SimOptions,
};
pub(crate) use engine::{
    critical_parent, parent_payloads, residual_after_busy, stage_inputs_destroyed, stage_ready,
};

use crate::controller::{LightDecision, LightRequest};
use crate::config::NUM_RESOURCES;
use crate::placement::{CorePlacement, QosScores};
use crate::rng::Xoshiro256;
use crate::routing::DistanceMatrix;

/// A deployment strategy under evaluation (the proposal or a baseline).
pub trait Strategy {
    /// Display name for reports.
    fn name(&self) -> &str;

    /// Static tier: place core microservices for the whole horizon.
    fn place_core(
        &mut self,
        env: &SimEnv,
        scores: &QosScores,
        rng: &mut Xoshiro256,
    ) -> CorePlacement;

    /// Dynamic tier: decide light instances/parallelism/routing for one
    /// slot. `busy` carries instances still processing; `residual` is the
    /// per-node capacity left for new instances; `dm` is the *current*
    /// routed-latency view — under fault injection it reflects outages
    /// and degraded links (unreachable pairs report infinite latency)
    /// and may differ from `env.dm`.
    #[allow(clippy::too_many_arguments)]
    fn decide_light(
        &mut self,
        env: &SimEnv,
        slot: usize,
        queue: &[LightRequest],
        busy: &[Vec<u32>],
        residual: &[[f64; NUM_RESOURCES]],
        dm: &DistanceMatrix,
        rng: &mut Xoshiro256,
    ) -> LightDecision;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::{GaStrategy, LbrrStrategy, Proposal, PropAvg};
    use crate::config::ExperimentConfig;

    fn small_cfg() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::paper_default();
        cfg.sim.slots = 60;
        cfg.workload.num_users = 8;
        cfg.controller.effcap_samples = 512;
        cfg
    }

    #[test]
    fn proposal_trial_completes_tasks() {
        let cfg = small_cfg();
        let env = SimEnv::build(&cfg, 11);
        let mut strat = Proposal::new();
        let m = run_trial(&env, &mut strat, 11, &SimOptions::from_config(&cfg));
        assert!(m.total_tasks > 0, "workload must generate tasks");
        assert!(
            m.completion_rate() > 0.5,
            "proposal should complete most tasks, got {}",
            m.completion_rate()
        );
        assert!(m.total_cost > 0.0);
        assert!(m.core_cost > 0.0);
    }

    #[test]
    fn same_seed_is_deterministic() {
        let cfg = small_cfg();
        let env = SimEnv::build(&cfg, 5);
        let opts = SimOptions::from_config(&cfg);
        let m1 = run_trial(&env, &mut Proposal::new(), 5, &opts);
        let m2 = run_trial(&env, &mut Proposal::new(), 5, &opts);
        assert_eq!(m1.total_tasks, m2.total_tasks);
        assert_eq!(m1.completed, m2.completed);
        assert_eq!(m1.on_time, m2.on_time);
        assert!((m1.total_cost - m2.total_cost).abs() < 1e-9);
    }

    #[test]
    fn all_strategies_run_without_panic() {
        let cfg = small_cfg();
        let env = SimEnv::build(&cfg, 7);
        let opts = SimOptions::from_config(&cfg);
        let strategies: Vec<Box<dyn Strategy>> = vec![
            Box::new(Proposal::new()),
            Box::new(PropAvg::new()),
            Box::new(LbrrStrategy::new()),
            Box::new(GaStrategy::new(12, 8)),
        ];
        for mut s in strategies {
            let m = run_trial(&env, s.as_mut(), 7, &opts);
            assert!(m.total_tasks > 0, "{}: no tasks", s.name());
        }
    }

    #[test]
    fn traced_replay_is_deterministic_and_paired() {
        let cfg = small_cfg();
        let env = SimEnv::build(&cfg, 19);
        let opts = SimOptions::from_config(&cfg);
        let trace = record_trace(&env, 19, &opts);
        assert!(!trace.is_empty(), "seed config must admit tasks");
        // Same trace, same strategy: identical outcomes.
        let m1 = run_trial_traced(&env, &mut Proposal::new(), 19, &opts, &trace);
        let m2 = run_trial_traced(&env, &mut Proposal::new(), 19, &opts, &trace);
        assert_eq!(m1.total_tasks, m2.total_tasks);
        assert_eq!(m1.on_time, m2.on_time);
        // Every strategy admits exactly the traced workload (paired).
        assert_eq!(m1.total_tasks, trace.len());
        let m3 = run_trial_traced(&env, &mut LbrrStrategy::new(), 19, &opts, &trace);
        assert_eq!(m3.total_tasks, trace.len());
    }

    #[test]
    fn higher_load_does_not_improve_on_time_rate() {
        let cfg = small_cfg();
        let env = SimEnv::build(&cfg, 13);
        let mut o1 = SimOptions::from_config(&cfg);
        o1.load_multiplier = 1.0;
        let mut o2 = o1.clone();
        o2.load_multiplier = 3.0;
        let m1 = run_trial(&env, &mut Proposal::new(), 13, &o1);
        let m2 = run_trial(&env, &mut Proposal::new(), 13, &o2);
        assert!(m2.total_tasks > m1.total_tasks);
        assert!(
            m2.on_time_rate() <= m1.on_time_rate() + 0.1,
            "3x load should not look better: {} vs {}",
            m2.on_time_rate(),
            m1.on_time_rate()
        );
    }

    #[test]
    fn virtual_queues_drain_to_empty_after_trial() {
        // Regression (VirtualQueues lifecycle): finished AND dropped tasks
        // must both be remove()d, so nothing is tracked after the horizon
        // drain even under overload where many tasks are dropped.
        let cfg = small_cfg();
        let env = SimEnv::build(&cfg, 31);
        let mut opts = SimOptions::from_config(&cfg);
        opts.load_multiplier = 3.0; // force drops
        let m = run_trial(&env, &mut Proposal::new(), 31, &opts);
        assert!(m.total_tasks > 0);
        assert_eq!(m.vq_residual, 0, "virtual-queue entries leaked");
    }

    #[test]
    fn latencies_are_positive_and_bounded() {
        let cfg = small_cfg();
        let env = SimEnv::build(&cfg, 17);
        let m = run_trial(
            &env,
            &mut Proposal::new(),
            17,
            &SimOptions::from_config(&cfg),
        );
        for &l in &m.latencies_ms {
            assert!(l > 0.0);
            assert!(l.is_finite());
        }
    }
}
