//! The trial engine: environment sampling + the slotted event loop.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};

use crate::config::{ExperimentConfig, NUM_RESOURCES};
use crate::coordinator::FailoverPolicy;
use crate::controller::{LightRequest, VirtualQueues};
use crate::effcap::{GTable, GTableParams};
use crate::faults::{DynamicTopology, FaultKind, FaultSchedule};
use crate::metrics::{CostBook, MetricsCollector, TaskOutcome, TrialMetrics};
use crate::microservice::{build_fig1_application, Application, MsClass};
use crate::network::Topology;
use crate::obs::{rec_mut, Observer};
use crate::placement::{QosScores, ScoreParams};
use crate::rng::Xoshiro256;
use crate::routing::{CoreRouter, DistanceMatrix, HopTable};
use crate::workload::{Trace, WorkloadGenerator};

use super::Strategy;

/// Sampled evaluation environment shared by all strategies of one trial
/// set: application, topology, users, and the effective-capacity tables.
pub struct SimEnv {
    pub cfg: ExperimentConfig,
    pub app: Application,
    pub topo: Topology,
    pub dm: DistanceMatrix,
    /// Hop-level decomposition of the same routes `dm` sums over — the
    /// DES replays transfers hop by hop and the totals match exactly.
    pub hops: HopTable,
    pub gtable: GTable,
    /// Raw rate samples per light MS (the PJRT path re-derives the g-table
    /// from these; kept for cross-checks).
    pub light_rate_samples: Vec<Vec<f64>>,
    /// Per light MS resource vectors (dense light index).
    pub light_resources: Vec<[f64; NUM_RESOURCES]>,
    /// Per light MS `(c_dp, c_mt, c_pl)`.
    pub light_costs: Vec<(f64, f64, f64)>,
    /// Per core MS `(c_dp, c_mt)` (dense core index).
    pub core_costs: Vec<(f64, f64)>,
    /// The sampled user population (shared across strategies).
    pub users_seed: u64,
}

impl SimEnv {
    /// Sample a full environment from the config at `seed`.
    pub fn build(cfg: &ExperimentConfig, seed: u64) -> Self {
        let mut rng = Xoshiro256::seed_from(seed ^ 0xE17E_5EED);
        let app = build_fig1_application(cfg, &mut rng);
        let topo = Topology::generate(cfg, &mut rng);
        let hops = HopTable::build(&topo, 1.0);
        let dm = DistanceMatrix::from_hops(&hops);

        let mut samples = Vec::new();
        let mut workloads = Vec::new();
        for &m in app.catalog.light_ids() {
            let spec = app.catalog.spec(m);
            samples.push(spec.rate.sample_n(&mut rng, cfg.controller.effcap_samples));
            workloads.push(spec.workload_mb);
        }
        let gtable = GTable::build(
            &samples,
            &workloads,
            &GTableParams::from_config(&cfg.controller),
        );
        let light_resources = app
            .catalog
            .light_ids()
            .iter()
            .map(|&m| app.catalog.spec(m).resources)
            .collect();
        let light_costs = app
            .catalog
            .light_ids()
            .iter()
            .map(|&m| {
                let s = app.catalog.spec(m);
                (s.cost_deploy, s.cost_maint, s.cost_parallel)
            })
            .collect();
        let core_costs = app
            .catalog
            .core_ids()
            .iter()
            .map(|&m| {
                let s = app.catalog.spec(m);
                (s.cost_deploy, s.cost_maint)
            })
            .collect();
        SimEnv {
            cfg: cfg.clone(),
            app,
            topo,
            dm,
            hops,
            gtable,
            light_rate_samples: samples,
            light_resources,
            light_costs,
            core_costs,
            users_seed: seed ^ 0x05E5,
        }
    }

    /// Replace the g-table (PJRT-accelerated builds inject theirs here).
    pub fn with_gtable(mut self, gtable: GTable) -> Self {
        self.gtable = gtable;
        self
    }
}

/// Trial options.
#[derive(Clone, Debug)]
pub struct SimOptions {
    pub slots: usize,
    pub slot_ms: f64,
    pub load_multiplier: f64,
    /// Tasks still unfinished this many deadlines past their own are
    /// dropped (prevents unbounded queues under overload).
    pub drop_after_deadlines: f64,
    /// Arrivals stop at this slot (the tail of the horizon drains the
    /// system so every admitted task gets a fair shot at its deadline).
    pub arrival_cutoff: usize,
    /// Retry/backoff + checkpoint policy replayed when a fault schedule
    /// is active. Inert (never consulted) on fault-free runs, so the
    /// zero-fault bit-identity invariant is unaffected.
    pub failover: FailoverPolicy,
    /// Elastic replica pools + shared-rate contention (EXPERIMENTS
    /// §P10): light capacity comes from a [`crate::pool::PoolManager`]
    /// scaled per slot, and in-flight executions progress at a per-slot
    /// shared rate set by the previous boundary's occupancy. `None`
    /// (the default) never enters the pool path — every number is
    /// byte-identical to the fixed-capacity engine.
    pub pool: Option<crate::pool::PoolConfig>,
}

impl SimOptions {
    pub fn from_config(cfg: &ExperimentConfig) -> Self {
        let slots = cfg.sim.slots;
        // Leave room for the longest deadline plus slack to drain.
        let drain = (1.5 * cfg.workload.deadline_ms.hi / cfg.sim.slot_ms).ceil() as usize;
        SimOptions {
            slots,
            slot_ms: cfg.sim.slot_ms,
            load_multiplier: cfg.sim.load_multiplier,
            drop_after_deadlines: 5.0,
            arrival_cutoff: slots.saturating_sub(drain).max(slots / 4).max(1),
            failover: FailoverPolicy::default(),
            pool: None,
        }
    }
}

/// Shared stage-readiness rule for both engines: a stage is dispatchable
/// once every DAG parent has completed and it has not been dispatched.
/// The slotted and DES engines must agree on this (and on
/// [`parent_payloads`]) for paired-trace comparisons to be meaningful —
/// keep the logic here, in one place.
pub(crate) fn stage_ready(
    app: &Application,
    task_type: usize,
    done: &[Option<f64>],
    dispatched: &[bool],
    local: usize,
) -> bool {
    if dispatched[local] || done[local].is_some() {
        return false;
    }
    let tt = &app.task_types[task_type];
    tt.dag.parents(local).iter().all(|&p| done[p].is_some())
}

/// Shared parent-payload rule: `(node, ready_ms, mb)` triples feeding a
/// stage. Source stages read the user payload at the ED once the uplink
/// lands (`input_ready_ms`).
pub(crate) fn parent_payloads(
    app: &Application,
    task_type: usize,
    done: &[Option<f64>],
    node: &[Option<usize>],
    ed: usize,
    input_ready_ms: f64,
    local: usize,
) -> Vec<(usize, f64, f64)> {
    let tt = &app.task_types[task_type];
    let parents = tt.dag.parents(local);
    if parents.is_empty() {
        vec![(ed, input_ready_ms, tt.input_mb)]
    } else {
        parents
            .iter()
            .map(|&p| {
                let spec = app.catalog.spec(tt.services[p]);
                (
                    node[p].expect("parent executed"),
                    done[p].expect("parent done"),
                    spec.output_mb,
                )
            })
            .collect()
    }
}

/// Shared critical-parent rule for span tracing: among a stage's parent
/// payloads, the one whose transfer lands last at `target` (ties keep
/// the first, matching the engines' arrival fold). Returns the parent's
/// local stage (`None` for source stages reading the user payload at the
/// ED), its ready time, and the landing time at `target`.
pub(crate) fn critical_parent(
    app: &Application,
    task_type: usize,
    local: usize,
    payloads: &[(usize, f64, f64)],
    target: usize,
    dm: &DistanceMatrix,
) -> (Option<usize>, f64, f64) {
    let parents = app.task_types[task_type].dag.parents(local);
    let mut best_i = 0usize;
    let mut best = f64::NEG_INFINITY;
    for (i, &(pn, pd, mb)) in payloads.iter().enumerate() {
        let a = pd + dm.latency(pn, target, mb);
        if a > best {
            best = a;
            best_i = i;
        }
    }
    (parents.get(best_i).copied(), payloads[best_i].1, best)
}

/// Shared input-survival rule for fault injection: a stage's inputs are
/// irrecoverably gone when any parent stage's output was destroyed (its
/// node died after the parent completed — recovery restores capacity,
/// not server-resident intermediate data). Source stages read the user
/// payload from the edge device, which retains it across outages: an
/// ED being down is a *wait* condition at dispatch, never destruction.
/// Both engines consult this one rule so paired fault replays agree on
/// what is recoverable.
pub(crate) fn stage_inputs_destroyed(
    app: &Application,
    task_type: usize,
    destroyed: &[bool],
    local: usize,
) -> bool {
    app.task_types[task_type]
        .dag
        .parents(local)
        .iter()
        .any(|&p| destroyed[p])
}

/// Shared residual-capacity rule: static residual minus the resources of
/// busy light instance-groups, floored at zero.
pub(crate) fn residual_after_busy(
    residual_static: &[[f64; NUM_RESOURCES]],
    light_resources: &[[f64; NUM_RESOURCES]],
    busy: &[Vec<u32>],
) -> Vec<[f64; NUM_RESOURCES]> {
    let mut residual = residual_static.to_vec();
    for (v, row) in busy.iter().enumerate() {
        for (m, &b) in row.iter().enumerate() {
            for k in 0..NUM_RESOURCES {
                residual[v][k] = (residual[v][k] - light_resources[m][k] * b as f64).max(0.0);
            }
        }
    }
    residual
}

/// Per-task runtime state.
struct RunTask {
    task_type: usize,
    arrival_ms: f64,
    deadline_ms: f64,
    uplink_ms: f64,
    ed: usize,
    /// Completion time per local DAG node.
    done: Vec<Option<f64>>,
    /// Executing network node per local DAG node.
    node: Vec<Option<usize>>,
    /// Local nodes already dispatched (running or queued for light).
    dispatched: Vec<bool>,
    /// Sequence of the outstanding completion event per stage. A fault
    /// that kills the execution clears this, making the in-flight event
    /// stale; a re-dispatch records a fresh sequence.
    ev_seq: Vec<Option<u64>>,
    /// A completed stage's output was lost with its node — permanent:
    /// node recovery does not restore it (see `stage_inputs_destroyed`).
    destroyed: Vec<bool>,
    /// Fault-cancelled dispatch attempts per stage (drives the backoff).
    attempts: Vec<u32>,
    /// Earliest re-dispatch time per stage (jittered exponential backoff
    /// after a fault cancellation; `0.0` = immediately eligible).
    retry_at: Vec<f64>,
    /// The stage's previous execution was cancelled by a fault; counted
    /// as a re-route recovery when it next dispatches successfully.
    rerouted: Vec<bool>,
    /// Standby hedged execution per stage: `(node, seq)`. Promoted to the
    /// primary if the primary's node dies; discarded when its own node
    /// dies or the primary completes first.
    hedge: Vec<Option<(usize, u64)>>,
}

impl RunTask {
    fn stage_ready(&self, app: &Application, local: usize) -> bool {
        stage_ready(app, self.task_type, &self.done, &self.dispatched, local)
    }

    /// Parent payload sources `(node, done_ms, mb)` of a local stage; for
    /// source stages this is the user's ED with the uplink-completed time.
    fn parent_payloads(&self, app: &Application, local: usize) -> Vec<(usize, f64, f64)> {
        parent_payloads(
            app,
            self.task_type,
            &self.done,
            &self.node,
            self.ed,
            self.arrival_ms + self.uplink_ms,
            local,
        )
    }
}

/// Completion event ordered by time.
#[derive(PartialEq)]
struct Event {
    time_ms: f64,
    task: u64,
    local: usize,
    /// Unique dispatch sequence; a fault that cancels the execution makes
    /// the task's recorded sequence diverge, so the event is ignored.
    seq: u64,
    /// Light busy accounting to release: `(node, light_idx, generation)`.
    /// The generation is matched against the station's — a node outage
    /// zeroes the busy count and bumps the generation, so stale releases
    /// from before the outage cannot underflow the revived station.
    release: Option<(usize, usize, u64)>,
}

impl Eq for Event {}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Event {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.time_ms
            .partial_cmp(&other.time_ms)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then_with(|| self.task.cmp(&other.task))
            .then_with(|| self.local.cmp(&other.local))
            .then_with(|| self.seq.cmp(&other.seq))
    }
}

/// An in-flight pooled light execution (slotted engine, §P10): nominal
/// remaining work advanced once per slot boundary at the shared rate its
/// station ran at over the elapsed interval. When the remaining work
/// hits zero the exact retrospective finish time is posted as a regular
/// completion [`Event`] carrying the dispatch `seq` (so fault staleness
/// works unchanged). `gen` is the station outage generation at dispatch
/// — a node death purges the run the same way it zeroes busy counts.
struct SlottedRun {
    task: u64,
    local: usize,
    node: usize,
    m: usize,
    start_ms: f64,
    remaining_ms: f64,
    seq: u64,
    gen: u64,
}

/// Record a realized workload trace for `env` at `seed`: the arrivals an
/// engine run would admit (Poisson draws per slot up to the cutoff, with
/// realized uplink SNR/delay stamped per task). Both the slotted engine
/// ([`run_trial_traced`]) and the DES engine replay the same trace for
/// paired engine-vs-engine comparisons.
pub fn record_trace(env: &SimEnv, seed: u64, opts: &SimOptions) -> Trace {
    let mut rng = Xoshiro256::seed_from(seed ^ 0x7124_CE00);
    let mut gen = WorkloadGenerator::new(
        &env.cfg,
        &env.app,
        &env.topo,
        &mut Xoshiro256::seed_from(env.users_seed),
    );
    let mut arrivals = Vec::new();
    for slot in 0..opts.slots.min(opts.arrival_cutoff) {
        arrivals.extend(gen.generate_slot(slot, opts.load_multiplier, &mut rng));
    }
    Trace::from_arrivals(arrivals)
}

/// Run one trial of `strategy` on `env`, drawing arrivals live.
pub fn run_trial(
    env: &SimEnv,
    strategy: &mut dyn Strategy,
    seed: u64,
    opts: &SimOptions,
) -> TrialMetrics {
    run_trial_inner(env, strategy, seed, opts, None, &FaultSchedule::none(), None)
}

/// Run one trial replaying a recorded [`Trace`] instead of drawing
/// arrivals — every strategy (and every engine) sees the same realized
/// workload.
pub fn run_trial_traced(
    env: &SimEnv,
    strategy: &mut dyn Strategy,
    seed: u64,
    opts: &SimOptions,
    trace: &Trace,
) -> TrialMetrics {
    run_trial_inner(env, strategy, seed, opts, Some(trace), &FaultSchedule::none(), None)
}

/// Run one traced trial while replaying a [`FaultSchedule`]: events are
/// applied at the first slot boundary at or after their timestamp. With
/// an empty schedule this is bit-identical to [`run_trial_traced`].
pub fn run_trial_faulted(
    env: &SimEnv,
    strategy: &mut dyn Strategy,
    seed: u64,
    opts: &SimOptions,
    trace: &Trace,
    faults: &FaultSchedule,
) -> TrialMetrics {
    run_trial_inner(env, strategy, seed, opts, Some(trace), faults, None)
}

/// Run one traced, faulted trial with an [`Observer`] attached: spans,
/// per-slot telemetry, and blame-attribution inputs are recorded without
/// consuming engine RNG or reordering events, so the returned metrics
/// are identical to [`run_trial_faulted`] on the same inputs (asserted
/// by the zero-overhead gate test).
pub fn run_trial_observed(
    env: &SimEnv,
    strategy: &mut dyn Strategy,
    seed: u64,
    opts: &SimOptions,
    trace: &Trace,
    faults: &FaultSchedule,
    obs: &mut Observer,
) -> TrialMetrics {
    run_trial_inner(env, strategy, seed, opts, Some(trace), faults, Some(obs))
}

fn run_trial_inner(
    env: &SimEnv,
    strategy: &mut dyn Strategy,
    seed: u64,
    opts: &SimOptions,
    trace: Option<&Trace>,
    faults: &FaultSchedule,
    mut obs: Option<&mut Observer>,
) -> TrialMetrics {
    let app = &env.app;
    let cfg = &env.cfg;
    let mut rng = Xoshiro256::seed_from(seed ^ 0x7A5C_0FFE);
    let mut gen = WorkloadGenerator::new(cfg, app, &env.topo, &mut Xoshiro256::seed_from(env.users_seed));

    // --- static tier -----------------------------------------------------
    let scores = QosScores::compute(
        app,
        &env.topo,
        &env.dm,
        gen.users(),
        &ScoreParams::from_config(&cfg.controller),
    );
    let placement = strategy.place_core(env, &scores, &mut rng);
    let mut core_router = CoreRouter::new(&placement.instances);
    let residual_static = placement.residual_capacity(app, &env.topo);

    let mut costs = CostBook::new();
    let core_dp: Vec<f64> = env.core_costs.iter().map(|c| c.0).collect();
    let core_mt: Vec<f64> = env.core_costs.iter().map(|c| c.1).collect();
    costs.charge_core_placement(&placement.instances, &core_dp, &core_mt, opts.slots);
    let light_dp: Vec<f64> = env.light_costs.iter().map(|c| c.0).collect();
    let light_mt: Vec<f64> = env.light_costs.iter().map(|c| c.1).collect();
    let light_pl: Vec<f64> = env.light_costs.iter().map(|c| c.2).collect();

    // --- dynamic state ---------------------------------------------------
    let nv = env.topo.num_nodes();
    let nl = app.catalog.num_light();
    let max_y = env.gtable.max_parallelism().max(1);
    // lint: allow(hash-iter): every order-sensitive walk sorts ids first
    let mut tasks: HashMap<u64, RunTask> = HashMap::new();
    let mut queues = VirtualQueues::new(cfg.controller.zeta);
    let mut events: BinaryHeap<Reverse<Event>> = BinaryHeap::new();
    // Light-stage wait queue: (task, local node).
    let mut light_queue: Vec<(u64, usize)> = Vec::new();
    // Active light executions per (v, m) — busy instances derive from it.
    let mut active_light = vec![vec![0u32; nl]; nv];
    let mut collector = MetricsCollector::new();

    // --- fault state ------------------------------------------------------
    // With an empty schedule none of this is ever touched and the run is
    // bit-identical to the fault-free path (same RNG stream, same events).
    let has_faults = !faults.is_empty();
    let mut dynt: Option<DynamicTopology> =
        has_faults.then(|| DynamicTopology::new(&env.topo, 1.0));
    let mut fault_cursor = 0usize;
    let mut node_up = vec![true; nv];
    // Busy-accounting generation per station; bumped when an outage zeroes
    // the count so stale release events cannot underflow it.
    let mut light_gen = vec![vec![0u64; nl]; nv];
    let mut next_seq: u64 = 0;
    // Checkpoint cadence in slots (>= 1 when enabled).
    let checkpoint_every = if opts.failover.checkpoint.enabled() {
        (opts.failover.checkpoint.period_ms / opts.slot_ms).ceil().max(1.0) as usize
    } else {
        0
    };

    let light_idx_of: Vec<Option<usize>> = (0..app.catalog.len())
        .map(|m| app.catalog.light_index(crate::microservice::MsId(m)))
        .collect();

    // --- elastic pools (§P10) --------------------------------------------
    // With `pool` off none of this is ever touched: the manager is absent,
    // the run registry stays empty, and the slot loop below takes the
    // exact fixed-capacity path (bit-identical output, no extra RNG).
    let pool_alpha = opts.pool.as_ref().map_or(1.0, |p| p.alpha);
    let mut pool_mgr = opts
        .pool
        .as_ref()
        .map(|pc| crate::pool::PoolManager::new(nv, nl, pc.clone(), seed));
    let mut pool_runs: Vec<SlottedRun> = Vec::new();
    let mut pool_grown: Vec<f64> = Vec::new();
    let mut pool_occ: Vec<Vec<u32>> = if pool_mgr.is_some() {
        vec![vec![0u32; nl]; nv]
    } else {
        Vec::new()
    };

    let mut finish_task =
        |id: u64,
         t: &RunTask,
         done_ms: Option<f64>,
         collector: &mut MetricsCollector,
         queues: &mut VirtualQueues,
         obs: &mut Option<&mut Observer>| {
            if let Some(r) = rec_mut(obs) {
                r.task_finished(id, done_ms);
            }
            collector.record(TaskOutcome {
                task_id: id,
                latency_ms: done_ms.map(|d| d - t.arrival_ms),
                deadline_ms: t.deadline_ms,
            });
            queues.remove(id);
        };

    for slot in 0..opts.slots {
        let now = slot as f64 * opts.slot_ms;
        let slot_end = now + opts.slot_ms;

        // 0. Apply fault events due by this slot boundary (the slotted
        //    engine quantizes the schedule to its decision cadence; the
        //    DES applies the same events at their exact timestamps).
        while fault_cursor < faults.len() && faults.events()[fault_cursor].time_ms <= now {
            let fev = faults.events()[fault_cursor];
            fault_cursor += 1;
            match fev.kind {
                FaultKind::NodeDown { node } => {
                    node_up[node] = false;
                    if let Some(d) = dynt.as_mut() {
                        d.apply_deferred(&fev.kind);
                    }
                    core_router.set_node_down(node);
                    for m in 0..nl {
                        active_light[node][m] = 0;
                        light_gen[node][m] += 1;
                    }
                    // The node's replica pools die with it; the gen bump
                    // above already purges its in-flight pooled runs.
                    if let Some(pm) = pool_mgr.as_mut() {
                        pm.fail_node(node);
                    }
                    // Completed outputs resident on the node are destroyed
                    // (permanently — recovery restores capacity, not
                    // data); in-flight executions are cancelled, their
                    // completion events go stale, and the dispatch scan
                    // below re-dispatches them (or drops tasks whose
                    // inputs died with the node).
                    for (id, t) in tasks.iter_mut() {
                        for local in 0..t.done.len() {
                            if t.done[local].is_some() {
                                if t.node[local] == Some(node) {
                                    t.destroyed[local] = true;
                                }
                                continue;
                            }
                            if t.node[local] == Some(node) && t.dispatched[local] {
                                // Primary execution dies with the node. A
                                // live hedged standby is promoted in place
                                // — the stage recovers without a retry
                                // cycle (its event carries the hedge seq).
                                if let Some((hn, hs)) =
                                    t.hedge[local].filter(|&(hn, _)| hn != node)
                                {
                                    t.node[local] = Some(hn);
                                    t.ev_seq[local] = Some(hs);
                                    t.hedge[local] = None;
                                    collector.record_reroute();
                                    if let Some(r) = rec_mut(&mut obs) {
                                        r.hedge_promoted(*id, local, now);
                                    }
                                    continue;
                                }
                                t.dispatched[local] = false;
                                t.node[local] = None;
                                t.ev_seq[local] = None;
                                t.hedge[local] = None;
                                // Retry with jittered exponential backoff
                                // (deterministic per (task, stage, attempt)
                                // — no engine RNG stream is consumed).
                                t.attempts[local] += 1;
                                t.rerouted[local] = true;
                                t.retry_at[local] = now
                                    + opts.failover.retry.backoff_ms(
                                        t.attempts[local],
                                        *id ^ ((local as u64) << 40),
                                    );
                                collector.record_retry();
                                if let Some(r) = rec_mut(&mut obs) {
                                    r.attempt_cancelled(*id, local, now, t.retry_at[local]);
                                }
                            } else if t.hedge[local].map(|(hn, _)| hn) == Some(node) {
                                // The standby died; the primary continues.
                                t.hedge[local] = None;
                                if let Some(r) = rec_mut(&mut obs) {
                                    r.hedge_dropped(*id, local, now);
                                }
                            }
                        }
                    }
                }
                FaultKind::NodeUp { node } => {
                    node_up[node] = true;
                    if let Some(d) = dynt.as_mut() {
                        d.apply_deferred(&fev.kind);
                    }
                    core_router.set_node_up(node, now);
                    if let Some(pm) = pool_mgr.as_mut() {
                        pm.node_restored(node);
                    }
                }
                FaultKind::CoreReplicaFail { node, core_idx } => {
                    core_router.kill_instance(node, core_idx);
                }
                FaultKind::CoreReplicaRestart { node, core_idx } => {
                    // Rejoin from the last checkpoint (fast clock) or cold.
                    // While the node itself is down the restart is folded
                    // into the node's own recovery instead.
                    if node_up[node] {
                        let cp = opts.failover.checkpoint;
                        if let Some(ready_ms) = core_router.rejoin(
                            node,
                            core_idx,
                            now,
                            cp.restore_ms,
                            cp.cold_start_ms,
                        ) {
                            collector.record_restore();
                            if let Some(r) = rec_mut(&mut obs) {
                                r.restore(node, now, ready_ms);
                            }
                        }
                    }
                }
                link_event => {
                    if let Some(d) = dynt.as_mut() {
                        d.apply_deferred(&link_event);
                    }
                }
            }
        }
        // One routing rebuild per boundary, however many events landed.
        if let Some(d) = dynt.as_mut() {
            d.commit();
        }
        // Periodic core-state checkpoints (only meaningful under faults:
        // the stamps exist to make replica restarts fast).
        if has_faults && opts.failover.checkpoint.enabled() && checkpoint_every > 0 {
            if slot % checkpoint_every == 0 {
                core_router.checkpoint(now);
            }
        }
        // The routed-latency view every consumer of this slot shares.
        let dm_cur: &DistanceMatrix = match &dynt {
            Some(d) => d.dm(),
            None => &env.dm,
        };

        // Pool advance (§P10): purge runs whose dispatch went stale, then
        // move every surviving in-flight execution forward across the
        // elapsed slot at the shared rate its station ran at over that
        // interval (occupancy and replica counts as of the previous
        // boundary — the same previous-boundary quantization the slotted
        // engine applies to faults). Finished runs post their exact
        // retrospective completion time as a regular event, drained in
        // step 2 below; warming replicas whose cold-start window closed
        // only join the pool *after* the interval they were absent from.
        if let Some(pm) = pool_mgr.as_mut() {
            pool_runs.retain(|r| {
                light_gen[r.node][r.m] == r.gen
                    && tasks
                        .get(&r.task)
                        .map_or(false, |t| t.ev_seq[r.local] == Some(r.seq))
            });
            if slot > 0 {
                for row in pool_occ.iter_mut() {
                    row.iter_mut().for_each(|c| *c = 0);
                }
                for r in &pool_runs {
                    pool_occ[r.node][r.m] += 1;
                }
                let lo_slot = now - opts.slot_ms;
                let mut i = 0;
                while i < pool_runs.len() {
                    let r = &mut pool_runs[i];
                    let div = crate::pool::shared_divisor(
                        pool_occ[r.node][r.m],
                        pm.active(r.node, r.m),
                        pool_alpha,
                    );
                    let lo = r.start_ms.max(lo_slot);
                    let dt = (now - lo).max(0.0);
                    // An empty pool (divisor = inf) stalls the run: it
                    // holds its remaining work until replicas return.
                    if div.is_finite() && dt > 0.0 {
                        let progress = dt / div;
                        if progress >= r.remaining_ms {
                            let fin = lo + r.remaining_ms * div;
                            events.push(Reverse(Event {
                                time_ms: fin,
                                task: r.task,
                                local: r.local,
                                seq: r.seq,
                                release: None,
                            }));
                            pool_runs.swap_remove(i);
                            continue;
                        }
                        r.remaining_ms -= progress;
                    }
                    i += 1;
                }
            }
            pm.promote_ready_all(now);
        }

        // 1. Arrivals (none past the cutoff: drain phase). A replayed
        //    trace is authoritative: its recorded slots are admitted
        //    verbatim and the live generator is bypassed.
        let arrivals = match trace {
            Some(tr) => tr.slot(slot).to_vec(),
            None if slot < opts.arrival_cutoff => {
                gen.generate_slot(slot, opts.load_multiplier, &mut rng)
            }
            None => Vec::new(),
        };
        for a in arrivals {
            let tt = &app.task_types[a.task_type.0];
            let n = tt.dag.len();
            tasks.insert(
                a.id.0,
                RunTask {
                    task_type: a.task_type.0,
                    arrival_ms: now,
                    deadline_ms: tt.deadline_ms,
                    uplink_ms: a.uplink_delay_ms,
                    ed: a.ed,
                    done: vec![None; n],
                    node: vec![None; n],
                    dispatched: vec![false; n],
                    ev_seq: vec![None; n],
                    destroyed: vec![false; n],
                    attempts: vec![0; n],
                    retry_at: vec![0.0; n],
                    rerouted: vec![false; n],
                    hedge: vec![None; n],
                },
            );
            if let Some(r) = rec_mut(&mut obs) {
                r.admit(
                    a.id.0,
                    a.task_type.0,
                    n,
                    tt.dag.sink().unwrap_or(n.saturating_sub(1)),
                    now,
                    tt.deadline_ms,
                    a.uplink_delay_ms,
                );
            }
        }

        // 2. Drain events due before the end of this slot. An event is
        //    acted on only if its dispatch sequence is still the stage's
        //    current one — a fault cancellation makes it stale. Busy
        //    releases are matched by station generation the same way.
        while let Some(Reverse(ev)) = events.peek() {
            if ev.time_ms > slot_end {
                break;
            }
            let Reverse(ev) = events.pop().unwrap();
            if let Some((v, m, gen)) = ev.release {
                if light_gen[v][m] == gen {
                    active_light[v][m] = active_light[v][m].saturating_sub(1);
                }
            }
            if let Some(t) = tasks.get_mut(&ev.task) {
                if t.ev_seq[ev.local] == Some(ev.seq) {
                    t.done[ev.local] = Some(ev.time_ms);
                    t.ev_seq[ev.local] = None;
                    if let Some(r) = rec_mut(&mut obs) {
                        r.stage_done(ev.task, ev.local, ev.time_ms);
                    }
                }
            }
        }

        // 3. Dispatch ready stages: core -> router now; light -> queue.
        let mut sink_done: Vec<(u64, f64)> = Vec::new();
        // Sorted ids: HashMap order is randomized and dispatch order feeds
        // the RNG stream — sorting keeps trials reproducible per seed.
        let mut task_ids: Vec<u64> = tasks.keys().cloned().collect();
        task_ids.sort_unstable();
        for id in &task_ids {
            let ready_locals: Vec<usize> = {
                let t = &tasks[id];
                let tt = &app.task_types[t.task_type];
                (0..tt.dag.len())
                    .filter(|&l| t.stage_ready(app, l))
                    .collect()
            };
            for local in ready_locals {
                if !tasks.contains_key(id) {
                    break; // dropped by a fault casualty below
                }
                let (ms_id, is_core, proc_ms, payloads) = {
                    let t = &tasks[id];
                    let tt = &app.task_types[t.task_type];
                    let ms_id = tt.services[local];
                    let spec = app.catalog.spec(ms_id);
                    (
                        ms_id,
                        spec.class == MsClass::Core,
                        spec.mean_proc_delay(),
                        t.parent_payloads(app, local),
                    )
                };
                // A stage whose input payload was destroyed by an outage
                // cannot execute: the task is an unrecoverable fault
                // casualty. An ED-down source input merely waits (the
                // device retains the user payload across outages).
                if has_faults {
                    let t = &tasks[id];
                    if stage_inputs_destroyed(app, t.task_type, &t.destroyed, local) {
                        let t = tasks.remove(id).unwrap();
                        collector.record_fault_drop();
                        finish_task(*id, &t, None, &mut collector, &mut queues, &mut obs);
                        break;
                    }
                    if !node_up[t.ed]
                        && app.task_types[t.task_type].dag.parents(local).is_empty()
                    {
                        continue; // wait for the user's ED to recover
                    }
                    if now < t.retry_at[local] {
                        continue; // backoff window after a cancellation
                    }
                }
                if is_core {
                    let ci = app
                        .catalog
                        .core_ids()
                        .iter()
                        .position(|&c| c == ms_id)
                        .expect("core id");
                    if let Some(asn) =
                        core_router.route_multi(ci, &payloads, proc_ms, now, dm_cur)
                    {
                        let seq = next_seq;
                        next_seq += 1;
                        // Hedged second attempt: a stage that already lost
                        // one execution to a fault and is close to its
                        // deadline books a standby replica too (promoted
                        // if the primary's node dies mid-execution).
                        let hedge_asn = if has_faults {
                            let t = &tasks[id];
                            let slack = t.arrival_ms + t.deadline_ms - now;
                            if t.rerouted[local]
                                && opts.failover.retry.should_hedge(slack, t.deadline_ms)
                            {
                                core_router
                                    .route_multi(ci, &payloads, proc_ms, now, dm_cur)
                                    .filter(|h| h.node != asn.node)
                            } else {
                                None
                            }
                        } else {
                            None
                        };
                        let t = tasks.get_mut(id).unwrap();
                        if has_faults && t.rerouted[local] {
                            t.rerouted[local] = false;
                            collector.record_reroute();
                        }
                        t.dispatched[local] = true;
                        t.node[local] = Some(asn.node);
                        t.ev_seq[local] = Some(seq);
                        events.push(Reverse(Event {
                            time_ms: asn.done_ms,
                            task: *id,
                            local,
                            seq,
                            release: None,
                        }));
                        if let Some(r) = rec_mut(&mut obs) {
                            let task_type = tasks[id].task_type;
                            let (from, ready, arrive) = critical_parent(
                                app, task_type, local, &payloads, asn.node, dm_cur,
                            );
                            r.core_dispatched(
                                *id,
                                local,
                                seq,
                                asn.node,
                                from,
                                ready,
                                arrive,
                                asn.start_ms,
                            );
                        }
                        if let Some(h) = hedge_asn {
                            let hseq = next_seq;
                            next_seq += 1;
                            tasks.get_mut(id).unwrap().hedge[local] =
                                Some((h.node, hseq));
                            collector.record_hedge();
                            if let Some(r) = rec_mut(&mut obs) {
                                let task_type = tasks[id].task_type;
                                let (from, ready, arrive) = critical_parent(
                                    app, task_type, local, &payloads, h.node, dm_cur,
                                );
                                r.hedge_dispatched(
                                    *id,
                                    local,
                                    hseq,
                                    h.node,
                                    from,
                                    ready,
                                    arrive,
                                    h.start_ms,
                                );
                            }
                            events.push(Reverse(Event {
                                time_ms: h.done_ms,
                                task: *id,
                                local,
                                seq: hseq,
                                release: None,
                            }));
                        }
                    }
                    // No instance: under faults every replica may be down
                    // or unreachable — the stage stays ready and retries
                    // next slot (fault-free, C2 guarantees >= 1).
                } else {
                    let t = tasks.get_mut(id).unwrap();
                    t.dispatched[local] = true;
                    light_queue.push((*id, local));
                }
            }
        }
        // Fault drops above may have left dangling queued stages.
        if has_faults {
            light_queue.retain(|(id, _)| tasks.contains_key(id));
            // Queued light work whose input payload was destroyed is
            // equally lost (unreachable-but-alive inputs keep waiting).
            let mut casualties: Vec<u64> = Vec::new();
            for &(id, local) in &light_queue {
                if let Some(t) = tasks.get(&id) {
                    if stage_inputs_destroyed(app, t.task_type, &t.destroyed, local) {
                        casualties.push(id);
                    }
                }
            }
            for id in casualties {
                if let Some(t) = tasks.remove(&id) {
                    collector.record_fault_drop();
                    finish_task(id, &t, None, &mut collector, &mut queues, &mut obs);
                }
            }
            light_queue.retain(|(id, _)| tasks.contains_key(id));
        }

        // 4. Build the controller queue and residual capacity. Pooled
        //    mode derives busy groups from live run occupancy instead of
        //    the fixed-capacity active counters (which it never touches).
        let busy: Vec<Vec<u32>> = if pool_mgr.is_some() {
            for row in pool_occ.iter_mut() {
                row.iter_mut().for_each(|c| *c = 0);
            }
            for r in &pool_runs {
                pool_occ[r.node][r.m] += 1;
            }
            pool_occ
                .iter()
                .map(|row| {
                    row.iter()
                        .map(|&a| (a as usize).div_ceil(max_y) as u32)
                        .collect()
                })
                .collect()
        } else {
            active_light
                .iter()
                .map(|row| {
                    row.iter()
                        .map(|&a| (a as usize).div_ceil(max_y) as u32)
                        .collect()
                })
                .collect()
        };
        let mut residual = residual_after_busy(&residual_static, &env.light_resources, &busy);
        if has_faults {
            // Dead nodes host nothing new.
            for (v, res) in residual.iter_mut().enumerate() {
                if !node_up[v] {
                    *res = [0.0; NUM_RESOURCES];
                }
            }
        }
        let requests: Vec<LightRequest> = light_queue
            .iter()
            .map(|&(id, local)| {
                let t = &tasks[&id];
                let tt = &app.task_types[t.task_type];
                let ms_id = tt.services[local];
                let m = light_idx_of[ms_id.0].expect("light idx");
                let payloads = t.parent_payloads(app, local);
                // Use the latest-finishing parent as the "from" node.
                let &(from, _, mb) = payloads
                    .iter()
                    .max_by(|a, b| a.1.total_cmp(&b.1))
                    .unwrap();
                LightRequest {
                    task_id: id,
                    light_idx: m,
                    from_node: from,
                    payload_mb: mb,
                    h: queues.value(id),
                    deadline_slack_ms: t.deadline_ms - (now - t.arrival_ms),
                }
            })
            .collect();

        // 5. Strategy decision + execution of assignments.
        let decision =
            strategy.decide_light(env, slot, &requests, &busy, &residual, dm_cur, &mut rng);
        debug_assert_eq!(decision.assignments.len(), requests.len());
        let mut still_waiting: Vec<(u64, usize)> = Vec::new();
        for (qi, &(id, local)) in light_queue.iter().enumerate() {
            match decision.assignments.get(qi).and_then(|a| *a) {
                Some(asn) => {
                    // A strategy oblivious to the fault state (LBRR's
                    // round-robin, GA's frozen plan) may route onto a dead
                    // or unreachable node — the engine refuses and the
                    // task waits for a later slot (or its age drop).
                    if has_faults && !node_up[asn.node] {
                        still_waiting.push((id, local));
                        continue;
                    }
                    let (arrival, proc) = {
                        let t = &tasks[&id];
                        let payloads = t.parent_payloads(app, local);
                        let arrival = payloads
                            .iter()
                            .map(|&(pn, pd, mb)| pd + dm_cur.latency(pn, asn.node, mb))
                            .fold(f64::NEG_INFINITY, f64::max);
                        let tt = &app.task_types[t.task_type];
                        let spec = app.catalog.spec(tt.services[local]);
                        // Realized contended rate: f / y^alpha.
                        let f = spec.rate.sample(&mut rng)
                            / (asn.y as f64).powf(cfg.controller.contention_alpha);
                        (arrival, spec.workload_mb / f.max(1e-9))
                    };
                    let start = arrival.max(now);
                    let done = start + proc;
                    if !done.is_finite() {
                        still_waiting.push((id, local));
                        continue;
                    }
                    let seq = next_seq;
                    next_seq += 1;
                    let t = tasks.get_mut(&id).unwrap();
                    if has_faults && t.rerouted[local] {
                        t.rerouted[local] = false;
                        collector.record_reroute();
                    }
                    t.node[local] = Some(asn.node);
                    t.ev_seq[local] = Some(seq);
                    if pool_mgr.is_some() {
                        // Pooled: the execution joins the shared-rate run
                        // registry with its nominal work; its completion
                        // event is posted only when the per-slot advance
                        // sees the work drain (stretched/shrunk by live
                        // station occupancy vs. warm replicas).
                        pool_runs.push(SlottedRun {
                            task: id,
                            local,
                            node: asn.node,
                            m: asn.light_idx,
                            start_ms: start,
                            remaining_ms: proc,
                            seq,
                            gen: light_gen[asn.node][asn.light_idx],
                        });
                    } else {
                        active_light[asn.node][asn.light_idx] += 1;
                        events.push(Reverse(Event {
                            time_ms: done,
                            task: id,
                            local,
                            seq,
                            release: Some((
                                asn.node,
                                asn.light_idx,
                                light_gen[asn.node][asn.light_idx],
                            )),
                        }));
                    }
                    if let Some(r) = rec_mut(&mut obs) {
                        let t = &tasks[&id];
                        let payloads = t.parent_payloads(app, local);
                        let (from, ready, _) = critical_parent(
                            app, t.task_type, local, &payloads, asn.node, dm_cur,
                        );
                        r.light_assigned_full(
                            id,
                            local,
                            seq,
                            asn.node,
                            asn.y,
                            asn.light_idx,
                            from,
                            ready,
                            arrival,
                            start,
                        );
                    }
                }
                None => still_waiting.push((id, local)),
            }
        }
        light_queue = still_waiting;

        // 6. Charge light costs for this slot. Pooled mode runs the
        //    scaling policy per station (sorted walk), bills actual
        //    pool sizes (warm + warming replicas price their cold
        //    starts via instantiation-on-increase), and counts only
        //    served executions as active parallelism.
        if let Some(pm) = pool_mgr.as_mut() {
            let mut backlog_m = vec![0u32; nl];
            for &(qid, qlocal) in &light_queue {
                if let Some(t) = tasks.get(&qid) {
                    let ms_id = app.task_types[t.task_type].services[qlocal];
                    if let Some(m) = light_idx_of[ms_id.0] {
                        backlog_m[m] += 1;
                    }
                }
            }
            for row in pool_occ.iter_mut() {
                row.iter_mut().for_each(|c| *c = 0);
            }
            for r in &pool_runs {
                pool_occ[r.node][r.m] += 1;
            }
            for v in 0..nv {
                for m in 0..nl {
                    pm.step(v, m, pool_occ[v][m], backlog_m[m], now, &mut pool_grown);
                    if !pool_grown.is_empty() {
                        if let Some(r) = rec_mut(&mut obs) {
                            for &ready in &pool_grown {
                                r.warmup(v, now, ready);
                            }
                        }
                    }
                }
            }
            pm.end_slot(opts.slot_ms);
            let x: Vec<Vec<u32>> = (0..nv)
                .map(|v| (0..nl).map(|m| pm.total(v, m)).collect())
                .collect();
            let served: Vec<Vec<u32>> = (0..nv)
                .map(|v| (0..nl).map(|m| pool_occ[v][m].min(pm.active(v, m))).collect())
                .collect();
            costs.charge_light_slot(&x, &served, &light_dp, &light_mt, &light_pl);
        } else {
            costs.charge_light_slot(&decision.x, &decision.y, &light_dp, &light_mt, &light_pl);
        }

        // Per-slot telemetry snapshot (observer-gated, read-only).
        if let Some(o) = obs.as_deref_mut() {
            if o.metrics.is_some() {
                let mut backlog = vec![0usize; nl];
                for &(qid, qlocal) in &light_queue {
                    if let Some(t) = tasks.get(&qid) {
                        let ms_id = app.task_types[t.task_type].services[qlocal];
                        if let Some(m) = light_idx_of[ms_id.0] {
                            backlog[m] += 1;
                        }
                    }
                }
                let committed_y: Vec<u32> = (0..nl)
                    .map(|m| decision.y.iter().map(|row| row[m]).max().unwrap_or(0))
                    .collect();
                let busy_groups: u32 = busy.iter().flat_map(|r| r.iter()).sum();
                let node_util = busy.iter().filter(|row| row.iter().any(|&b| b > 0)).count()
                    as f64
                    / nv.max(1) as f64;
                // Pool gauges ride the same row: pool sizes plus the
                // worst finite live shared-rate bound g_{m,eps} across
                // occupied stations (actual contention, not planned y).
                if let Some(pm) = pool_mgr.as_ref() {
                    let ctrl = &cfg.controller;
                    let est = crate::effcap::EffCapEstimator::log_grid(
                        ctrl.theta_lo,
                        ctrl.theta_hi,
                        ctrl.theta_n,
                    );
                    let mut worst = f64::NEG_INFINITY;
                    for v in 0..nv {
                        for (m, &ms_id) in app.catalog.light_ids().iter().enumerate() {
                            let occ = pool_occ[v][m];
                            if occ == 0 {
                                continue;
                            }
                            let g = crate::pool::live_delay_bound(
                                &est,
                                &env.light_rate_samples[m],
                                app.catalog.spec(ms_id).workload_mb,
                                ctrl.epsilon,
                                occ,
                                pm.active(v, m),
                                pool_alpha,
                            );
                            if g.is_finite() && g > worst {
                                worst = g;
                            }
                        }
                    }
                    o.set_pool_gauges(pm.active_total(), pm.warming_total(), worst);
                }
                o.sample_slot(
                    now,
                    &backlog,
                    &committed_y,
                    busy_groups,
                    node_util,
                    queues.total_backlog(),
                    &env.gtable,
                );
            }
        }

        // Debug telemetry (FMEDGE_DEBUG=1): queue health every 50 slots.
        if slot % 50 == 0 && std::env::var_os("FMEDGE_DEBUG").is_some() {
            let active: u32 = active_light.iter().flat_map(|r| r.iter()).sum();
            let assigned = decision.assignments.iter().filter(|a| a.is_some()).count();
            eprintln!(
                "[slot {slot}] in_flight={} light_queue={} assigned={assigned} active_light={active} added={}",
                tasks.len(),
                light_queue.len(),
                decision.stats.instances_added
            );
        }

        // 7. Task completion / dropping / queue updates.
        let mut ids: Vec<u64> = tasks.keys().cloned().collect();
        ids.sort_unstable();
        for id in ids {
            let t = &tasks[&id];
            let tt = &app.task_types[t.task_type];
            let sink = tt.dag.sink().expect("inverse tree sink");
            if let Some(done) = t.done[sink] {
                sink_done.push((id, done));
            } else {
                let age = slot_end - t.arrival_ms;
                if age > opts.drop_after_deadlines * t.deadline_ms {
                    let t = tasks.remove(&id).unwrap();
                    finish_task(id, &t, None, &mut collector, &mut queues, &mut obs);
                } else {
                    queues.update(id, age, t.deadline_ms);
                }
            }
        }
        for (id, done) in sink_done {
            let t = tasks.remove(&id).unwrap();
            finish_task(id, &t, Some(done), &mut collector, &mut queues, &mut obs);
        }
        // Dropped/finished tasks may still have queued light stages;
        // purge them so the controller never sees dangling work.
        light_queue.retain(|(id, _)| tasks.contains_key(id));
    }

    // Horizon end: everything in flight is incomplete. Drain in id order
    // — a raw `drain()` finished tasks in hash order, which reordered the
    // incomplete-latency samples between processes.
    let mut ids: Vec<u64> = tasks.keys().cloned().collect();
    ids.sort_unstable();
    for id in ids {
        let t = tasks.remove(&id).unwrap();
        finish_task(id, &t, None, &mut collector, &mut queues, &mut obs);
    }
    let _ = placement.objective;
    let mut metrics = collector.finish(&costs);
    // Lifecycle invariant: every admitted task was removed from the
    // virtual queues on its finish/drop path. Surfaced in the metrics so
    // regression tests can assert it stays zero on long trials.
    debug_assert!(
        queues.is_empty(),
        "virtual-queue leak: {} entries after drain",
        queues.len()
    );
    metrics.vq_residual = queues.len();
    if let Some(pm) = pool_mgr {
        metrics.cold_starts = pm.cold_starts;
        metrics.pool_scale_events = pm.scale_events;
        metrics.pool_scale_to_zero = pm.scale_to_zero_events;
        metrics.pool_replica_slot_seconds = pm.replica_slot_seconds;
        metrics.pool_size = pm.size_hist;
    }
    metrics
}
