//! Observability: per-task span tracing, time-series telemetry, and
//! deadline-miss blame attribution (EXPERIMENTS §P7).
//!
//! The paper's guarantee `P(delay > g_{m,ε}(y)) ≤ ε` is probabilistic;
//! `des::validate` says *whether* it holds but not *why* a given task
//! missed its deadline. This module answers that question natively:
//!
//! * [`TraceRecorder`] — one span per task stage (admission, queue wait,
//!   transfer, core/light exec at the committed `y`, retry backoff,
//!   hedges, checkpoint restores), with task/stage/attempt identifiers
//!   matching the engines' event-seq/token scheme. Exporters emit JSONL
//!   ([`spans_jsonl`]) and Chrome trace-event JSON ([`chrome_trace_json`])
//!   that opens directly in Perfetto (`fmedge trace --out trace.json`).
//! * [`MetricsRegistry`] — counters/gauges/histograms sampled per
//!   slot/epoch: per-light-service backlog, virtual-queue level,
//!   committed `y`, node utilization, and the live `g_{m,ε}(y)` budget,
//!   exported as a CSV [`crate::exp::Table`].
//! * [`analyze`] — a post-run analyzer that decomposes every completed
//!   task's sojourn into per-component delay (and every deadline miss
//!   into blame shares), and compares measured per-service sojourns
//!   against the effective-capacity budget (`fmedge trace --blame`).
//!
//! Everything is `Option`-gated: the engines thread `Option<&mut
//! Observer>` through the exact code path the untraced run takes,
//! consume no engine RNG, and never reorder events — with tracing
//! disabled, outputs are byte-identical (asserted by tests + CI smoke).

mod blame;
mod export;
mod span;
mod telemetry;

pub use blame::{analyze, render, BlameReport, BudgetRow, TaskBlame, COMPONENT_NAMES};
pub use export::{chrome_trace_json, spans_jsonl};
pub use span::{Span, SpanKind, StageAttempt, StageTrace, TaskTrace, TraceRecorder, INFRA_TASK};
pub use telemetry::{CounterId, GaugeId, HistId, MetricsRegistry};

use crate::effcap::GTable;

/// The engines' observability handle: both halves are optional, so a
/// caller can record spans without telemetry or vice versa.
#[derive(Clone, Debug, Default)]
pub struct Observer {
    pub trace: Option<TraceRecorder>,
    pub metrics: Option<MetricsRegistry>,
    series: Option<EngineSeries>,
    pool_series: Option<PoolSeries>,
}

/// Gauge handles for the per-slot engine snapshot, registered lazily on
/// the first sample (when the light-service count is known).
#[derive(Clone, Debug)]
struct EngineSeries {
    backlog: Vec<GaugeId>,
    committed_y: Vec<GaugeId>,
    g_budget: Vec<GaugeId>,
    busy_groups: GaugeId,
    node_util: GaugeId,
    vq_backlog: GaugeId,
}

/// Gauge handles for the elastic-pool snapshot (§P10), registered
/// lazily on the first pooled sample — unpooled runs never register
/// them, so the telemetry schema is unchanged when the pool is off.
#[derive(Clone, Debug)]
struct PoolSeries {
    replicas: GaugeId,
    warming: GaugeId,
    live_g_ms: GaugeId,
}

impl Observer {
    /// Record both spans and telemetry.
    pub fn new() -> Self {
        Observer {
            trace: Some(TraceRecorder::new()),
            metrics: Some(MetricsRegistry::new()),
            series: None,
            pool_series: None,
        }
    }

    /// Span tracing only (no per-slot telemetry rows).
    pub fn trace_only() -> Self {
        Observer {
            trace: Some(TraceRecorder::new()),
            metrics: None,
            series: None,
            pool_series: None,
        }
    }

    /// Set the elastic-pool gauges for the row the next
    /// [`Self::sample_slot`] call finalizes: total warm replicas,
    /// warming (cold-starting) replicas, and the worst finite live
    /// shared-rate delay bound across occupied stations (−1 when no
    /// station has a finite bound, mirroring the `g_ms` convention).
    /// Only pooled engines call this, so the telemetry schema is
    /// unchanged for every pre-existing run.
    pub fn set_pool_gauges(&mut self, replicas: u32, warming: u32, live_g_ms: f64) {
        let Some(reg) = self.metrics.as_mut() else {
            return;
        };
        let s = self.pool_series.get_or_insert_with(|| PoolSeries {
            replicas: reg.gauge("pool_replicas"),
            warming: reg.gauge("pool_warming"),
            live_g_ms: reg.gauge("pool_g_ms"),
        });
        reg.set(s.replicas, replicas as f64);
        reg.set(s.warming, warming as f64);
        reg.set(
            s.live_g_ms,
            if live_g_ms.is_finite() { live_g_ms } else { -1.0 },
        );
    }

    /// One per-slot (or per-tick) engine snapshot: per-light-service
    /// backlog and committed parallelism, core-group occupancy, node
    /// utilization, virtual-queue backlog, and the live effective-capacity
    /// budget `g_{m,ε}(y)` at the committed `y`.
    #[allow(clippy::too_many_arguments)]
    pub fn sample_slot(
        &mut self,
        now_ms: f64,
        backlog: &[usize],
        committed_y: &[u32],
        busy_groups: u32,
        node_util: f64,
        vq_backlog: f64,
        gtable: &GTable,
    ) {
        let Some(reg) = self.metrics.as_mut() else {
            return;
        };
        let series = self.series.get_or_insert_with(|| {
            let nl = backlog.len();
            EngineSeries {
                backlog: (0..nl).map(|m| reg.gauge(&format!("backlog_m{m}"))).collect(),
                committed_y: (0..nl).map(|m| reg.gauge(&format!("y_m{m}"))).collect(),
                g_budget: (0..nl).map(|m| reg.gauge(&format!("g_ms_m{m}"))).collect(),
                busy_groups: reg.gauge("busy_core_groups"),
                node_util: reg.gauge("node_util"),
                vq_backlog: reg.gauge("vq_backlog"),
            }
        });
        for (m, &b) in backlog.iter().enumerate() {
            reg.set(series.backlog[m], b as f64);
        }
        for (m, &y) in committed_y.iter().enumerate() {
            reg.set(series.committed_y[m], y as f64);
            let yy = (y.max(1) as usize).min(gtable.max_parallelism());
            let g = gtable.delay(m, yy);
            // A non-finite budget (no feasible capacity) is recorded as -1
            // so the CSV stays clean under `Table::validate`.
            reg.set(series.g_budget[m], if g.is_finite() { g } else { -1.0 });
        }
        reg.set(series.busy_groups, busy_groups as f64);
        reg.set(series.node_util, node_util);
        reg.set(series.vq_backlog, vq_backlog);
        reg.sample(now_ms);
    }
}

/// Reborrow helper: the recorder inside an optional observer handle, if
/// both are present. Keeps engine hook sites to one line.
pub fn rec_mut<'a>(obs: &'a mut Option<&mut Observer>) -> Option<&'a mut TraceRecorder> {
    obs.as_deref_mut().and_then(|o| o.trace.as_mut())
}
