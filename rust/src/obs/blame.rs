//! Deadline-miss blame attribution (`fmedge trace --blame`).
//!
//! Walks every completed task's critical-parent chain from the sink back
//! to the source, summing span segments plus inter-stage gaps into six
//! additive components. The decomposition telescopes *exactly* to the
//! end-to-end sojourn `done - arrival` — the §P7 span-accounting
//! invariant tests assert it on both engines, faults included — so the
//! per-component means over misses are a true budget breakdown, not an
//! approximation.
//!
//! When a [`GTable`] is supplied, every completed light execution's
//! measured station sojourn is additionally compared against the
//! effective-capacity budget `g_{m,ε}(y)` at its committed `y` — the
//! per-component "where is the bound loose/tight" report §P2 needed.

use std::collections::BTreeMap;

use super::span::{SpanKind, TraceRecorder};
use crate::effcap::GTable;

pub const N_COMPONENTS: usize = 6;

/// Component order used by `TaskBlame::parts` and the report tables.
pub const COMPONENT_NAMES: [&str; N_COMPONENTS] = [
    "uplink",
    "queue",
    "transfer",
    "core_exec",
    "light_exec",
    "disruption",
];

const UPLINK: usize = 0;
const QUEUE: usize = 1;
const TRANSFER: usize = 2;
const CORE_EXEC: usize = 3;
const LIGHT_EXEC: usize = 4;
const DISRUPTION: usize = 5;

fn component(kind: SpanKind) -> usize {
    match kind {
        SpanKind::Admission => UPLINK,
        SpanKind::QueueWait => QUEUE,
        SpanKind::Transfer => TRANSFER,
        SpanKind::CoreExec => CORE_EXEC,
        SpanKind::LightExec => LIGHT_EXEC,
        SpanKind::Backoff
        | SpanKind::Hedge
        | SpanKind::Restore
        | SpanKind::Serve
        | SpanKind::Warmup => DISRUPTION,
    }
}

/// One completed task's additive latency decomposition.
#[derive(Clone, Debug)]
pub struct TaskBlame {
    pub task: u64,
    pub latency_ms: f64,
    pub deadline_ms: f64,
    pub missed: bool,
    /// The task absorbed at least one fault cancellation.
    pub retried: bool,
    /// Per-component delay, ordered as [`COMPONENT_NAMES`]; sums to
    /// `latency_ms` exactly.
    pub parts: [f64; N_COMPONENTS],
}

/// Measured-vs-budget comparison for one light service.
#[derive(Clone, Debug)]
pub struct BudgetRow {
    pub light_idx: usize,
    pub samples: usize,
    /// Mean measured station sojourn (arrival at the node -> done).
    pub mean_sojourn_ms: f64,
    /// Mean `g_{m,ε}(y)` at the committed parallelism of each sample.
    pub mean_budget_ms: f64,
    /// Samples whose sojourn exceeded their budget.
    pub violations: usize,
}

/// The full post-run report.
#[derive(Clone, Debug)]
pub struct BlameReport {
    pub tasks: Vec<TaskBlame>,
    pub misses: usize,
    /// Per-component mean over deadline misses (zeros when none missed).
    pub miss_mean: [f64; N_COMPONENTS],
    /// Per-component mean over on-time tasks (zeros when none).
    pub ontime_mean: [f64; N_COMPONENTS],
    /// Per-light-service measured-vs-budget rows (empty without a g-table).
    pub budget: Vec<BudgetRow>,
}

/// Decompose every completed task in `rec`. Errs when a completed task's
/// recorded chain is inconsistent — that is an instrumentation bug the
/// invariant tests are meant to catch, never a data-dependent condition.
pub fn analyze(rec: &TraceRecorder, gtable: Option<&GTable>) -> Result<BlameReport, String> {
    let mut tasks_out = Vec::new();
    // light_idx -> (samples, sojourn sum, budget sum, violations)
    let mut budget_acc: BTreeMap<usize, (usize, f64, f64, usize)> = BTreeMap::new();

    for (&id, tt) in rec.tasks() {
        let Some(done) = tt.done_ms else {
            continue; // dropped or unfinished: no sojourn to decompose
        };
        let mut parts = [0.0; N_COMPONENTS];
        parts[UPLINK] += tt.uplink_ms;
        let mut retried = false;
        let mut cur = Some(tt.sink);
        let mut hops = 0usize;
        while let Some(s) = cur {
            hops += 1;
            if hops > tt.stages.len() + 1 {
                return Err(format!("task {id}: critical-parent chain does not terminate"));
            }
            let st = tt
                .stages
                .get(s)
                .ok_or_else(|| format!("task {id}: stage {s} out of range"))?;
            let fa = st.completed.as_ref().ok_or_else(|| {
                format!("task {id}: completed but stage {s} has no finalized attempt")
            })?;
            for &(kind, a, b) in &fa.segments {
                parts[component(kind)] += b - a;
            }
            // The gap between the critical parent finishing and this stage
            // becoming ready: re-dispatch delay after a cancellation when
            // the stage retried, otherwise scheduling wait.
            let prev_end = match fa.from {
                Some(p) => {
                    tt.stages
                        .get(p)
                        .and_then(|ps| ps.completed.as_ref())
                        .ok_or_else(|| {
                            format!("task {id}: stage {s} depends on unfinished stage {p}")
                        })?
                        .done_ms
                }
                None => tt.arrival_ms + tt.uplink_ms,
            };
            let gap = fa.ready_ms - prev_end;
            if st.retries > 0 {
                retried = true;
                parts[DISRUPTION] += gap;
            } else {
                parts[QUEUE] += gap;
            }
            if let (false, Some(m), Some(gt)) = (fa.is_core, fa.light_idx, gtable) {
                let sojourn = fa.done_ms - fa.arrive_ms;
                let yy = (fa.y.max(1) as usize).min(gt.max_parallelism());
                let budget = gt.delay(m, yy);
                if budget.is_finite() {
                    let e = budget_acc.entry(m).or_insert((0, 0.0, 0.0, 0));
                    e.0 += 1;
                    e.1 += sojourn;
                    e.2 += budget;
                    if sojourn > budget {
                        e.3 += 1;
                    }
                }
            }
            cur = fa.from;
        }
        let latency_ms = done - tt.arrival_ms;
        tasks_out.push(TaskBlame {
            task: id,
            latency_ms,
            deadline_ms: tt.deadline_ms,
            missed: latency_ms > tt.deadline_ms,
            retried,
            parts,
        });
    }

    let mut miss_mean = [0.0; N_COMPONENTS];
    let mut ontime_mean = [0.0; N_COMPONENTS];
    let (mut n_miss, mut n_ontime) = (0usize, 0usize);
    for tb in &tasks_out {
        let (acc, n) = if tb.missed {
            (&mut miss_mean, &mut n_miss)
        } else {
            (&mut ontime_mean, &mut n_ontime)
        };
        *n += 1;
        for (a, p) in acc.iter_mut().zip(&tb.parts) {
            *a += p;
        }
    }
    if n_miss > 0 {
        miss_mean.iter_mut().for_each(|a| *a /= n_miss as f64);
    }
    if n_ontime > 0 {
        ontime_mean.iter_mut().for_each(|a| *a /= n_ontime as f64);
    }
    let budget = budget_acc
        .into_iter()
        .map(|(m, (n, soj, bud, viol))| BudgetRow {
            light_idx: m,
            samples: n,
            mean_sojourn_ms: soj / n as f64,
            mean_budget_ms: bud / n as f64,
            violations: viol,
        })
        .collect();
    Ok(BlameReport {
        misses: n_miss,
        miss_mean,
        ontime_mean,
        budget,
        tasks: tasks_out,
    })
}

/// Human-readable report for `fmedge trace --blame`.
pub fn render(report: &BlameReport) -> String {
    let completed = report.tasks.len();
    let mut out = String::new();
    out.push_str(&format!(
        "blame: {completed} completed tasks, {} deadline misses ({:.1}%)\n",
        report.misses,
        if completed > 0 {
            100.0 * report.misses as f64 / completed as f64
        } else {
            0.0
        }
    ));
    let miss_total: f64 = report.miss_mean.iter().sum();
    out.push_str("  component    miss mean ms   share %   on-time mean ms\n");
    for (i, name) in COMPONENT_NAMES.iter().enumerate() {
        let share = if miss_total > 0.0 {
            100.0 * report.miss_mean[i] / miss_total
        } else {
            0.0
        };
        out.push_str(&format!(
            "  {name:<11} {:>12.3} {share:>9.1} {:>17.3}\n",
            report.miss_mean[i], report.ontime_mean[i]
        ));
    }
    if !report.budget.is_empty() {
        out.push_str("  measured light sojourn vs g_(m,eps)(y):\n");
        for row in &report.budget {
            out.push_str(&format!(
                "    m={:<2} samples {:>6}  sojourn {:>9.3} ms  budget {:>9.3} ms  \
                 violations {} ({:.2}%)\n",
                row.light_idx,
                row.samples,
                row.mean_sojourn_ms,
                row.mean_budget_ms,
                row.violations,
                100.0 * row.violations as f64 / row.samples.max(1) as f64
            ));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A two-stage chain with a retry: the decomposition must telescope
    /// exactly to `done - arrival`.
    #[test]
    fn decomposition_telescopes_exactly() {
        let mut r = TraceRecorder::new();
        r.admit(5, 0, 2, 1, 100.0, 25.0, 2.0);
        // Stage 0 (source, light): queue 3, transfer 1, wait 2, exec 6.
        r.light_pending(5, 0, 102.0);
        r.light_assigned(5, 0, 1, 0, 2, 0, None, 105.0, 106.0);
        r.light_started(5, 0, 108.0);
        r.stage_done(5, 0, 114.0);
        // Stage 1 (sink, core) retries once: cancelled at 118, backoff to
        // 121, re-dispatched ready at 121 with transfer to 122, exec to 130.
        r.core_dispatched(5, 1, 2, 3, Some(0), 114.0, 115.0, 116.0);
        r.attempt_cancelled(5, 1, 118.0, 121.0);
        r.core_dispatched(5, 1, 3, 4, Some(0), 121.0, 122.0, 123.0);
        r.stage_done(5, 1, 130.0);
        r.task_finished(5, Some(130.0));

        let rep = analyze(&r, None).expect("consistent chain");
        assert_eq!(rep.tasks.len(), 1);
        let tb = &rep.tasks[0];
        assert!(tb.retried);
        assert!(tb.missed, "latency 30 ms exceeds the 25 ms deadline");
        let sum: f64 = tb.parts.iter().sum();
        assert!(
            (sum - tb.latency_ms).abs() < 1e-9,
            "components {sum} != latency {}",
            tb.latency_ms
        );
        // The re-dispatch gap [114 done -> 121 ready] is disruption.
        assert!(tb.parts[DISRUPTION] >= 7.0 - 1e-9);
    }

    #[test]
    fn unfinished_tasks_are_skipped() {
        let mut r = TraceRecorder::new();
        r.admit(1, 0, 1, 0, 0.0, 50.0, 1.0);
        r.task_finished(1, None);
        let rep = analyze(&r, None).unwrap();
        assert!(rep.tasks.is_empty());
        assert_eq!(rep.misses, 0);
    }

    #[test]
    fn broken_chain_is_an_error() {
        let mut r = TraceRecorder::new();
        r.admit(2, 0, 1, 0, 0.0, 50.0, 1.0);
        // Completed without any finalized stage: instrumentation bug.
        r.task_finished(2, Some(10.0));
        assert!(analyze(&r, None).is_err());
    }

    #[test]
    fn render_mentions_every_component() {
        let mut r = TraceRecorder::new();
        r.admit(0, 0, 1, 0, 0.0, 1.0, 0.5);
        r.core_dispatched(0, 0, 1, 0, None, 0.5, 1.0, 1.0);
        r.stage_done(0, 0, 5.0);
        r.task_finished(0, Some(5.0));
        let rep = analyze(&r, None).unwrap();
        assert_eq!(rep.misses, 1);
        let txt = render(&rep);
        for name in COMPONENT_NAMES {
            assert!(txt.contains(name), "missing {name} in:\n{txt}");
        }
    }
}
