//! Span recording: per-task, per-stage, per-attempt timing.
//!
//! The recorder mirrors the engines' own bookkeeping: a stage has at most
//! one *pending* primary attempt (plus an optional hedged standby), and a
//! completed stage finalizes its pending attempt into a [`StageAttempt`]
//! whose `segments` tile `[ready_ms, done_ms]` contiguously — so summing
//! a task's segment durations plus the inter-stage gaps along the
//! critical-parent chain reproduces the end-to-end sojourn *exactly*
//! (the §P7 span-accounting invariant; see [`super::analyze`]).
//!
//! Cancelled attempts (fault casualties, losing hedges) are emitted as
//! standalone `cancelled` spans: they show real work in Perfetto but are
//! excluded from the additive decomposition, since the retry's wait is
//! already accounted as backoff/disruption time.

use std::collections::BTreeMap;

/// Sentinel task id for infrastructure spans (checkpoint restores) that
/// belong to a node, not a task.
pub const INFRA_TASK: u64 = u64::MAX;

/// What a span measures.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpanKind {
    /// Uplink: user payload in flight from the device to its ED.
    Admission,
    /// Waiting for a decision epoch / a free instance.
    QueueWait,
    /// Payload transfer between nodes.
    Transfer,
    /// Core-service execution (FIFO-serialized replica).
    CoreExec,
    /// Light-service execution at the committed parallelism `y`.
    LightExec,
    /// Retry backoff window after a fault cancellation.
    Backoff,
    /// Hedged standby execution (second attempt near the deadline).
    Hedge,
    /// Checkpoint restore of a core replica (infrastructure span).
    Restore,
    /// Serving-path request service (coordinator / replay server).
    Serve,
    /// Elastic-pool replica cold start (§P10): the warming window during
    /// which the replica is billed but serves nothing (infrastructure
    /// span, like [`SpanKind::Restore`]).
    Warmup,
}

impl SpanKind {
    pub fn name(self) -> &'static str {
        match self {
            SpanKind::Admission => "admission",
            SpanKind::QueueWait => "queue_wait",
            SpanKind::Transfer => "transfer",
            SpanKind::CoreExec => "core_exec",
            SpanKind::LightExec => "light_exec",
            SpanKind::Backoff => "backoff",
            SpanKind::Hedge => "hedge",
            SpanKind::Restore => "restore",
            SpanKind::Serve => "serve",
            SpanKind::Warmup => "warmup",
        }
    }

    /// Chrome trace-event category (drives Perfetto's row coloring).
    pub fn category(self) -> &'static str {
        match self {
            SpanKind::Admission => "task",
            SpanKind::QueueWait => "sched",
            SpanKind::Transfer => "net",
            SpanKind::CoreExec | SpanKind::LightExec => "exec",
            SpanKind::Backoff | SpanKind::Hedge | SpanKind::Restore | SpanKind::Warmup => "fault",
            SpanKind::Serve => "serve",
        }
    }
}

/// One flattened span (the export unit).
#[derive(Clone, Debug, PartialEq)]
pub struct Span {
    pub task: u64,
    /// Local DAG stage, `None` for task-level / infrastructure spans.
    pub stage: Option<usize>,
    /// Dispatch identifier: the slotted engine's event seq / the DES
    /// token, so a span can be joined back to engine internals.
    pub attempt: u64,
    pub kind: SpanKind,
    pub start_ms: f64,
    pub end_ms: f64,
    pub node: Option<usize>,
    /// Committed light parallelism (0 for core/non-exec spans).
    pub y: u32,
    /// The attempt was cancelled (fault casualty or losing hedge); its
    /// duration is real work but not part of the additive decomposition.
    pub cancelled: bool,
}

/// An in-flight dispatch attempt, finalized on stage completion.
#[derive(Clone, Debug)]
struct Pending {
    attempt: u64,
    node: Option<usize>,
    y: u32,
    light_idx: Option<usize>,
    from: Option<usize>,
    is_core: bool,
    is_hedge: bool,
    ready_ms: f64,
    depart_ms: Option<f64>,
    arrive_ms: Option<f64>,
    start_ms: Option<f64>,
}

/// The finalized attempt that completed a stage. `segments` tile
/// `[ready_ms, done_ms]` contiguously (transfer, waits, execution).
#[derive(Clone, Debug)]
pub struct StageAttempt {
    pub attempt: u64,
    pub node: usize,
    pub y: u32,
    /// Dense light index (None for core stages).
    pub light_idx: Option<usize>,
    pub is_core: bool,
    /// Critical parent: the local stage whose output arrived last (None
    /// for source stages reading the user payload at the ED).
    pub from: Option<usize>,
    pub ready_ms: f64,
    /// Payload arrival at the executing node (post-transfer).
    pub arrive_ms: f64,
    /// Execution start.
    pub start_ms: f64,
    pub done_ms: f64,
    /// Contiguous `(kind, start, end)` tiling of `[ready_ms, done_ms]`.
    pub segments: Vec<(SpanKind, f64, f64)>,
}

/// Per-stage record: the completed attempt plus retry bookkeeping.
#[derive(Clone, Debug, Default)]
pub struct StageTrace {
    /// Fault cancellations this stage absorbed before completing.
    pub retries: u32,
    pub completed: Option<StageAttempt>,
    primary: Option<Pending>,
    hedge: Option<Pending>,
}

/// Per-task record.
#[derive(Clone, Debug)]
pub struct TaskTrace {
    pub task_type: usize,
    /// Sink stage of the task DAG (the blame walk starts here).
    pub sink: usize,
    pub arrival_ms: f64,
    pub uplink_ms: f64,
    pub deadline_ms: f64,
    /// Sink completion time; `None` for dropped/unfinished tasks.
    pub done_ms: Option<f64>,
    pub stages: Vec<StageTrace>,
}

/// The span recorder both engines and the serving path write into.
#[derive(Clone, Debug, Default)]
pub struct TraceRecorder {
    tasks: BTreeMap<u64, TaskTrace>,
    extra: Vec<Span>,
}

fn clamp_ms(x: f64, lo: f64, hi: f64) -> f64 {
    x.max(lo).min(hi)
}

impl TraceRecorder {
    pub fn new() -> Self {
        TraceRecorder::default()
    }

    pub fn tasks(&self) -> &BTreeMap<u64, TaskTrace> {
        &self.tasks
    }

    pub fn num_tasks(&self) -> usize {
        self.tasks.len()
    }

    fn stage_mut(&mut self, task: u64, stage: usize) -> Option<&mut StageTrace> {
        self.tasks.get_mut(&task).and_then(|t| t.stages.get_mut(stage))
    }

    /// A task was admitted: uplink in flight, DAG of `n_stages` ahead.
    #[allow(clippy::too_many_arguments)]
    pub fn admit(
        &mut self,
        task: u64,
        task_type: usize,
        n_stages: usize,
        sink: usize,
        arrival_ms: f64,
        deadline_ms: f64,
        uplink_ms: f64,
    ) {
        self.tasks.insert(
            task,
            TaskTrace {
                task_type,
                sink,
                arrival_ms,
                uplink_ms,
                deadline_ms,
                done_ms: None,
                stages: vec![StageTrace::default(); n_stages],
            },
        );
    }

    /// A core stage was routed: transfer from the critical parent starts
    /// at `ready_ms`, lands at `arrive_ms`, execution at `start_ms`.
    #[allow(clippy::too_many_arguments)]
    pub fn core_dispatched(
        &mut self,
        task: u64,
        stage: usize,
        attempt: u64,
        node: usize,
        from: Option<usize>,
        ready_ms: f64,
        arrive_ms: f64,
        start_ms: f64,
    ) {
        let Some(st) = self.stage_mut(task, stage) else {
            return;
        };
        st.primary = Some(Pending {
            attempt,
            node: Some(node),
            y: 0,
            light_idx: None,
            from,
            is_core: true,
            is_hedge: false,
            ready_ms,
            depart_ms: None,
            arrive_ms: Some(arrive_ms),
            start_ms: Some(start_ms),
        });
    }

    /// A hedged standby was booked alongside the primary core attempt.
    #[allow(clippy::too_many_arguments)]
    pub fn hedge_dispatched(
        &mut self,
        task: u64,
        stage: usize,
        attempt: u64,
        node: usize,
        from: Option<usize>,
        ready_ms: f64,
        arrive_ms: f64,
        start_ms: f64,
    ) {
        let Some(st) = self.stage_mut(task, stage) else {
            return;
        };
        st.hedge = Some(Pending {
            attempt,
            node: Some(node),
            y: 0,
            light_idx: None,
            from,
            is_core: true,
            is_hedge: true,
            ready_ms,
            depart_ms: None,
            arrive_ms: Some(arrive_ms),
            start_ms: Some(start_ms),
        });
    }

    /// A light stage became ready and entered the controller queue (DES:
    /// the per-stage queue-wait clock starts here).
    pub fn light_pending(&mut self, task: u64, stage: usize, ready_ms: f64) {
        let Some(st) = self.stage_mut(task, stage) else {
            return;
        };
        st.primary = Some(Pending {
            attempt: 0,
            node: None,
            y: 0,
            light_idx: None,
            from: None,
            is_core: false,
            is_hedge: false,
            ready_ms,
            depart_ms: None,
            arrive_ms: None,
            start_ms: None,
        });
    }

    /// The controller assigned a queued light stage (DES: execution start
    /// arrives later via [`TraceRecorder::light_started`]).
    #[allow(clippy::too_many_arguments)]
    pub fn light_assigned(
        &mut self,
        task: u64,
        stage: usize,
        attempt: u64,
        node: usize,
        y: u32,
        light_idx: usize,
        from: Option<usize>,
        depart_ms: f64,
        arrive_ms: f64,
    ) {
        let Some(st) = self.stage_mut(task, stage) else {
            return;
        };
        let p = st.primary.get_or_insert_with(|| Pending {
            attempt: 0,
            node: None,
            y: 0,
            light_idx: None,
            from: None,
            is_core: false,
            is_hedge: false,
            ready_ms: depart_ms,
            depart_ms: None,
            arrive_ms: None,
            start_ms: None,
        });
        p.attempt = attempt;
        p.node = Some(node);
        p.y = y;
        p.light_idx = Some(light_idx);
        p.from = from;
        p.depart_ms = Some(depart_ms);
        p.arrive_ms = Some(arrive_ms);
    }

    /// Slotted one-shot light assignment: the whole timeline is known at
    /// the decision slot (transfer is modeled from payload-ready time, so
    /// `depart == ready`; post-arrival wait lands in the mid segment).
    #[allow(clippy::too_many_arguments)]
    pub fn light_assigned_full(
        &mut self,
        task: u64,
        stage: usize,
        attempt: u64,
        node: usize,
        y: u32,
        light_idx: usize,
        from: Option<usize>,
        ready_ms: f64,
        arrive_ms: f64,
        start_ms: f64,
    ) {
        let Some(st) = self.stage_mut(task, stage) else {
            return;
        };
        st.primary = Some(Pending {
            attempt,
            node: Some(node),
            y,
            light_idx: Some(light_idx),
            from,
            is_core: false,
            is_hedge: false,
            ready_ms,
            depart_ms: Some(ready_ms),
            arrive_ms: Some(arrive_ms),
            start_ms: Some(start_ms),
        });
    }

    /// A light execution entered service (DES station dequeue).
    pub fn light_started(&mut self, task: u64, stage: usize, now_ms: f64) {
        if let Some(st) = self.stage_mut(task, stage) {
            if let Some(p) = st.primary.as_mut() {
                p.start_ms = Some(now_ms);
            }
        }
    }

    /// The stage's current primary attempt completed at `now_ms`.
    pub fn stage_done(&mut self, task: u64, stage: usize, now_ms: f64) {
        let Some(st) = self.stage_mut(task, stage) else {
            return;
        };
        let disrupted = st.retries > 0;
        if let Some(p) = st.primary.take() {
            st.completed = Some(Self::finalize(&p, now_ms, disrupted));
        }
        let hedge = st.hedge.take();
        if let Some(h) = hedge {
            // The primary won; the standby's work was wasted but real.
            self.extra.push(Self::cancel_span(task, stage, &h, now_ms));
        }
    }

    /// A fault cancelled the stage's in-flight attempt; it will re-dispatch
    /// no earlier than `backoff_until_ms`.
    pub fn attempt_cancelled(&mut self, task: u64, stage: usize, now_ms: f64, backoff_until_ms: f64) {
        let Some(st) = self.stage_mut(task, stage) else {
            return;
        };
        st.retries += 1;
        let retries = st.retries;
        let primary = st.primary.take();
        let attempt = primary.as_ref().map_or(retries as u64, |p| p.attempt);
        if let Some(p) = primary {
            self.extra.push(Self::cancel_span(task, stage, &p, now_ms));
        }
        self.extra.push(Span {
            task,
            stage: Some(stage),
            attempt,
            kind: SpanKind::Backoff,
            start_ms: now_ms,
            end_ms: backoff_until_ms.max(now_ms),
            node: None,
            y: 0,
            cancelled: false,
        });
    }

    /// The primary's node died but a live hedged standby takes over in
    /// place (no retry cycle).
    pub fn hedge_promoted(&mut self, task: u64, stage: usize, now_ms: f64) {
        let Some(st) = self.stage_mut(task, stage) else {
            return;
        };
        let old = st.primary.take();
        if let Some(mut h) = st.hedge.take() {
            h.is_hedge = false;
            st.primary = Some(h);
        }
        if let Some(p) = old {
            self.extra.push(Self::cancel_span(task, stage, &p, now_ms));
        }
    }

    /// The hedged standby's own node died; the primary continues.
    pub fn hedge_dropped(&mut self, task: u64, stage: usize, now_ms: f64) {
        let Some(st) = self.stage_mut(task, stage) else {
            return;
        };
        let hedge = st.hedge.take();
        if let Some(h) = hedge {
            self.extra.push(Self::cancel_span(task, stage, &h, now_ms));
        }
    }

    /// A core replica restarted from checkpoint (or cold) on `node`.
    pub fn restore(&mut self, node: usize, at_ms: f64, ready_ms: f64) {
        self.extra.push(Span {
            task: INFRA_TASK,
            stage: None,
            attempt: 0,
            kind: SpanKind::Restore,
            start_ms: at_ms,
            end_ms: ready_ms.max(at_ms),
            node: Some(node),
            y: 0,
            cancelled: false,
        });
    }

    /// An elastic-pool replica started warming on `node` at `at_ms`,
    /// joining the pool at `ready_ms` (serves nothing until then).
    pub fn warmup(&mut self, node: usize, at_ms: f64, ready_ms: f64) {
        self.extra.push(Span {
            task: INFRA_TASK,
            stage: None,
            attempt: 0,
            kind: SpanKind::Warmup,
            start_ms: at_ms,
            end_ms: ready_ms.max(at_ms),
            node: Some(node),
            y: 0,
            cancelled: false,
        });
    }

    /// Terminal outcome: sink completion time, or `None` for a drop.
    pub fn task_finished(&mut self, task: u64, done_ms: Option<f64>) {
        if let Some(t) = self.tasks.get_mut(&task) {
            t.done_ms = done_ms;
        }
    }

    /// Append a pre-built span (serving-path instrumentation).
    pub fn push_raw(&mut self, span: Span) {
        self.extra.push(span);
    }

    fn cancel_span(task: u64, stage: usize, p: &Pending, now_ms: f64) -> Span {
        let kind = if p.is_hedge {
            SpanKind::Hedge
        } else if p.is_core {
            SpanKind::CoreExec
        } else {
            SpanKind::LightExec
        };
        let start = p.start_ms.or(p.arrive_ms).unwrap_or(p.ready_ms);
        Span {
            task,
            stage: Some(stage),
            attempt: p.attempt,
            kind,
            start_ms: start,
            end_ms: now_ms.max(start),
            node: p.node,
            y: p.y,
            cancelled: true,
        }
    }

    /// Tile `[ready, done]` with contiguous segments. Clamping keeps the
    /// tiling exact even if a recorded timestamp is out of order (a
    /// defensive guard — engines record monotone timelines).
    fn finalize(p: &Pending, done_ms: f64, disrupted: bool) -> StageAttempt {
        let ready = p.ready_ms.min(done_ms);
        // The wait between payload arrival and execution start is backoff
        // fallout when the stage had a cancelled attempt, queueing else.
        let mid = if disrupted {
            SpanKind::Backoff
        } else {
            SpanKind::QueueWait
        };
        let mut segments = Vec::with_capacity(4);
        let (arrive, start);
        if p.is_core {
            arrive = clamp_ms(p.arrive_ms.unwrap_or(ready), ready, done_ms);
            start = clamp_ms(p.start_ms.unwrap_or(arrive), arrive, done_ms);
            segments.push((SpanKind::Transfer, ready, arrive));
            segments.push((mid, arrive, start));
            segments.push((SpanKind::CoreExec, start, done_ms));
        } else {
            let depart = clamp_ms(p.depart_ms.unwrap_or(ready), ready, done_ms);
            arrive = clamp_ms(p.arrive_ms.unwrap_or(depart), depart, done_ms);
            start = clamp_ms(p.start_ms.unwrap_or(arrive), arrive, done_ms);
            segments.push((SpanKind::QueueWait, ready, depart));
            segments.push((SpanKind::Transfer, depart, arrive));
            segments.push((mid, arrive, start));
            segments.push((SpanKind::LightExec, start, done_ms));
        }
        StageAttempt {
            attempt: p.attempt,
            node: p.node.unwrap_or(0),
            y: p.y,
            light_idx: p.light_idx,
            is_core: p.is_core,
            from: p.from,
            ready_ms: ready,
            arrive_ms: arrive,
            start_ms: start,
            done_ms,
            segments,
        }
    }

    /// Flatten to export order: every completed stage's segments, one
    /// admission span per task, plus the raw/cancelled spans, sorted by
    /// start time (BTreeMap iteration keeps ties deterministic).
    pub fn all_spans(&self) -> Vec<Span> {
        let mut out = Vec::new();
        for (&id, t) in &self.tasks {
            out.push(Span {
                task: id,
                stage: None,
                attempt: 0,
                kind: SpanKind::Admission,
                start_ms: t.arrival_ms,
                end_ms: t.arrival_ms + t.uplink_ms,
                node: None,
                y: 0,
                cancelled: false,
            });
            for (local, st) in t.stages.iter().enumerate() {
                if let Some(fa) = &st.completed {
                    for &(kind, a, b) in &fa.segments {
                        out.push(Span {
                            task: id,
                            stage: Some(local),
                            attempt: fa.attempt,
                            kind,
                            start_ms: a,
                            end_ms: b,
                            node: Some(fa.node),
                            y: fa.y,
                            cancelled: false,
                        });
                    }
                }
            }
        }
        out.extend(self.extra.iter().cloned());
        out.sort_by(|a, b| {
            a.start_ms
                .partial_cmp(&b.start_ms)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then_with(|| a.task.cmp(&b.task))
                .then_with(|| {
                    a.end_ms
                        .partial_cmp(&b.end_ms)
                        .unwrap_or(std::cmp::Ordering::Equal)
                })
        });
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seg_sum(fa: &StageAttempt) -> f64 {
        fa.segments.iter().map(|&(_, a, b)| b - a).sum()
    }

    #[test]
    fn core_stage_segments_tile_ready_to_done() {
        let mut r = TraceRecorder::new();
        r.admit(7, 0, 2, 1, 100.0, 50.0, 3.0);
        r.core_dispatched(7, 0, 11, 2, None, 103.0, 105.5, 106.0);
        r.stage_done(7, 0, 110.0);
        let fa = r.tasks()[&7].stages[0].completed.as_ref().unwrap().clone();
        assert_eq!(fa.node, 2);
        assert_eq!(fa.attempt, 11);
        assert_eq!(fa.segments.len(), 3);
        assert!((seg_sum(&fa) - (110.0 - 103.0)).abs() < 1e-9);
        assert_eq!(fa.segments[0].0, SpanKind::Transfer);
        assert_eq!(fa.segments[2].0, SpanKind::CoreExec);
    }

    #[test]
    fn light_stage_records_queue_and_service() {
        let mut r = TraceRecorder::new();
        r.admit(1, 0, 1, 0, 0.0, 50.0, 1.0);
        r.light_pending(1, 0, 5.0);
        r.light_assigned(1, 0, 3, 4, 2, 0, None, 9.0, 9.5);
        r.light_started(1, 0, 12.0);
        r.stage_done(1, 0, 20.0);
        let fa = r.tasks()[&1].stages[0].completed.as_ref().unwrap().clone();
        assert_eq!(fa.y, 2);
        assert_eq!(fa.segments.len(), 4);
        // queue [5,9] + transfer [9,9.5] + wait [9.5,12] + exec [12,20]
        assert!((seg_sum(&fa) - 15.0).abs() < 1e-9);
        assert_eq!(fa.segments[0], (SpanKind::QueueWait, 5.0, 9.0));
        assert_eq!(fa.segments[3], (SpanKind::LightExec, 12.0, 20.0));
    }

    #[test]
    fn cancellation_marks_stage_disrupted_and_emits_backoff() {
        let mut r = TraceRecorder::new();
        r.admit(9, 0, 1, 0, 0.0, 50.0, 0.5);
        r.core_dispatched(9, 0, 1, 3, None, 1.0, 2.0, 2.5);
        r.attempt_cancelled(9, 0, 4.0, 10.0);
        r.core_dispatched(9, 0, 2, 5, None, 1.0, 11.0, 12.0);
        r.stage_done(9, 0, 15.0);
        let st = &r.tasks()[&9].stages[0];
        assert_eq!(st.retries, 1);
        let fa = st.completed.as_ref().unwrap();
        // Mid segment is attributed to the disruption, not queueing.
        assert!(fa.segments.iter().any(|&(k, _, _)| k == SpanKind::Backoff));
        let spans = r.all_spans();
        assert!(spans.iter().any(|s| s.kind == SpanKind::Backoff && !s.cancelled));
        assert!(spans.iter().any(|s| s.kind == SpanKind::CoreExec && s.cancelled));
    }

    #[test]
    fn hedge_promotion_swaps_primary() {
        let mut r = TraceRecorder::new();
        r.admit(2, 0, 1, 0, 0.0, 50.0, 0.0);
        r.core_dispatched(2, 0, 1, 0, None, 1.0, 2.0, 2.0);
        r.hedge_dispatched(2, 0, 2, 1, None, 1.0, 3.0, 3.0);
        r.hedge_promoted(2, 0, 5.0);
        r.stage_done(2, 0, 9.0);
        let fa = r.tasks()[&2].stages[0].completed.as_ref().unwrap();
        assert_eq!(fa.node, 1, "the hedge's node won");
        assert_eq!(fa.attempt, 2);
        let spans = r.all_spans();
        assert!(
            spans.iter().any(|s| s.cancelled && s.node == Some(0)),
            "dead primary emitted as a cancelled span"
        );
    }

    #[test]
    fn losing_hedge_is_emitted_cancelled() {
        let mut r = TraceRecorder::new();
        r.admit(3, 0, 1, 0, 0.0, 50.0, 0.0);
        r.core_dispatched(3, 0, 1, 0, None, 1.0, 2.0, 2.0);
        r.hedge_dispatched(3, 0, 2, 1, None, 1.0, 3.0, 3.0);
        r.stage_done(3, 0, 8.0);
        let spans = r.all_spans();
        assert!(spans.iter().any(|s| s.kind == SpanKind::Hedge && s.cancelled));
    }

    #[test]
    fn all_spans_sorted_by_start() {
        let mut r = TraceRecorder::new();
        r.admit(1, 0, 1, 0, 10.0, 50.0, 1.0);
        r.admit(0, 0, 1, 0, 0.0, 50.0, 1.0);
        r.core_dispatched(0, 0, 1, 0, None, 1.0, 2.0, 2.0);
        r.stage_done(0, 0, 5.0);
        let spans = r.all_spans();
        assert!(spans.windows(2).all(|w| w[0].start_ms <= w[1].start_ms));
    }
}
