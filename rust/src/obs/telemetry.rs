//! Time-series telemetry: a tiny metrics registry (counters, gauges,
//! histograms) with per-slot/per-epoch sampling, exported as a CSV
//! [`Table`] alongside the sweep artifacts.
//!
//! Registration returns a typed id, so the engine hot path updates by
//! index — no name hashing per slot. Sampling snapshots every counter
//! and gauge into one row; histograms aggregate across the whole run
//! and are summarized separately.

use crate::exp::Table;
use crate::metrics::Histogram;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CounterId(usize);

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct GaugeId(usize);

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct HistId(usize);

/// Counters, gauges, and histograms plus the sampled time series.
#[derive(Clone, Debug, Default)]
pub struct MetricsRegistry {
    counter_names: Vec<String>,
    counters: Vec<u64>,
    gauge_names: Vec<String>,
    gauges: Vec<f64>,
    hist_names: Vec<String>,
    hists: Vec<Histogram>,
    sample_times: Vec<f64>,
    samples: Vec<Vec<f64>>,
}

impl MetricsRegistry {
    pub fn new() -> Self {
        MetricsRegistry::default()
    }

    /// Register (or look up) a counter by name.
    pub fn counter(&mut self, name: &str) -> CounterId {
        if let Some(i) = self.counter_names.iter().position(|n| n == name) {
            return CounterId(i);
        }
        self.counter_names.push(name.to_string());
        self.counters.push(0);
        CounterId(self.counters.len() - 1)
    }

    /// Register (or look up) a gauge by name.
    pub fn gauge(&mut self, name: &str) -> GaugeId {
        if let Some(i) = self.gauge_names.iter().position(|n| n == name) {
            return GaugeId(i);
        }
        self.gauge_names.push(name.to_string());
        self.gauges.push(0.0);
        GaugeId(self.gauges.len() - 1)
    }

    /// Register (or look up) a histogram by name.
    pub fn histogram(&mut self, name: &str, hist: Histogram) -> HistId {
        if let Some(i) = self.hist_names.iter().position(|n| n == name) {
            return HistId(i);
        }
        self.hist_names.push(name.to_string());
        self.hists.push(hist);
        HistId(self.hists.len() - 1)
    }

    pub fn inc(&mut self, id: CounterId, by: u64) {
        self.counters[id.0] += by;
    }

    pub fn set(&mut self, id: GaugeId, value: f64) {
        self.gauges[id.0] = value;
    }

    pub fn observe(&mut self, id: HistId, value: f64) {
        self.hists[id.0].observe(value);
    }

    pub fn counter_value(&self, id: CounterId) -> u64 {
        self.counters[id.0]
    }

    pub fn gauge_value(&self, id: GaugeId) -> f64 {
        self.gauges[id.0]
    }

    pub fn hist(&self, id: HistId) -> &Histogram {
        &self.hists[id.0]
    }

    /// Snapshot every counter and gauge as one time-series row.
    pub fn sample(&mut self, now_ms: f64) {
        let mut row = Vec::with_capacity(self.counters.len() + self.gauges.len());
        row.extend(self.counters.iter().map(|&c| c as f64));
        row.extend(self.gauges.iter().copied());
        self.sample_times.push(now_ms);
        self.samples.push(row);
    }

    pub fn num_samples(&self) -> usize {
        self.sample_times.len()
    }

    /// The sampled series as a CSV-ready table: one row per sample,
    /// `time_ms` first, then counters and gauges in registration order.
    /// Rows taken before a late registration are zero-padded so the
    /// schema stays rectangular.
    pub fn to_table(&self, name: &str) -> Table {
        let mut headers: Vec<String> = vec!["time_ms".to_string()];
        headers.extend(self.counter_names.iter().cloned());
        headers.extend(self.gauge_names.iter().cloned());
        let header_refs: Vec<&str> = headers.iter().map(String::as_str).collect();
        let mut table = Table::new(name, &header_refs);
        let width = self.counters.len() + self.gauges.len();
        for (t, row) in self.sample_times.iter().zip(&self.samples) {
            let mut vals = Vec::with_capacity(width + 1);
            vals.push(*t);
            for i in 0..width {
                vals.push(row.get(i).copied().unwrap_or(0.0));
            }
            table.push_numeric_row(&vals);
        }
        table
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registration_is_idempotent() {
        let mut reg = MetricsRegistry::new();
        let a = reg.counter("done");
        let b = reg.counter("done");
        assert_eq!(a, b);
        reg.inc(a, 2);
        reg.inc(b, 3);
        assert_eq!(reg.counter_value(a), 5);
    }

    #[test]
    fn sampling_snapshots_counters_and_gauges() {
        let mut reg = MetricsRegistry::new();
        let c = reg.counter("events");
        let g = reg.gauge("backlog");
        reg.inc(c, 4);
        reg.set(g, 2.5);
        reg.sample(10.0);
        reg.inc(c, 1);
        reg.set(g, 1.0);
        reg.sample(20.0);
        let t = reg.to_table("telemetry");
        t.validate().expect("valid table");
        assert_eq!(t.headers, vec!["time_ms", "events", "backlog"]);
        assert_eq!(t.rows.len(), 2);
        assert_eq!(t.rows[0], vec!["10", "4", "2.5"]);
        assert_eq!(t.rows[1], vec!["20", "5", "1"]);
    }

    #[test]
    fn late_registration_pads_old_rows() {
        let mut reg = MetricsRegistry::new();
        let g = reg.gauge("a");
        reg.set(g, 1.0);
        reg.sample(0.0);
        let h = reg.gauge("b");
        reg.set(h, 7.0);
        reg.sample(1.0);
        let t = reg.to_table("telemetry");
        t.validate().expect("valid table");
        assert_eq!(t.rows[0], vec!["0", "1", "0"]);
        assert_eq!(t.rows[1], vec!["1", "1", "7"]);
    }

    #[test]
    fn histograms_aggregate() {
        let mut reg = MetricsRegistry::new();
        let h = reg.histogram("lat", Histogram::linear(0.0, 100.0, 10));
        reg.observe(h, 5.0);
        reg.observe(h, 50.0);
        assert_eq!(reg.hist(h).count(), 2);
    }
}
