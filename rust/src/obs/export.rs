//! Span exporters: Chrome trace-event JSON (opens directly in Perfetto /
//! `chrome://tracing`) and one-span-per-line JSONL. Both are hand-rolled
//! — the crate has no serde — and sanitize non-finite values so the
//! artifacts always parse.

use super::span::{Span, TraceRecorder, INFRA_TASK};

fn num(x: f64) -> f64 {
    if x.is_finite() {
        x
    } else {
        0.0
    }
}

fn write_chrome_event(out: &mut String, s: &Span) {
    // Infra spans (checkpoint restores) get their own pid row group.
    let (pid, tid) = if s.task == INFRA_TASK {
        (2u32, 0u64)
    } else {
        (1u32, s.task)
    };
    let stage = s.stage.map_or(-1, |v| v as i64);
    let node = s.node.map_or(-1, |v| v as i64);
    out.push_str(&format!(
        "{{\"name\":\"{}\",\"cat\":\"{}\",\"ph\":\"X\",\"ts\":{:.3},\"dur\":{:.3},\
         \"pid\":{pid},\"tid\":{tid},\"args\":{{\"stage\":{stage},\"attempt\":{},\
         \"node\":{node},\"y\":{},\"cancelled\":{}}}}}",
        s.kind.name(),
        s.kind.category(),
        num(s.start_ms) * 1000.0,
        num(s.end_ms - s.start_ms).max(0.0) * 1000.0,
        s.attempt,
        s.y,
        s.cancelled,
    ));
}

/// Chrome trace-event JSON (`ph: "X"` complete events, µs timestamps).
pub fn chrome_trace_json(rec: &TraceRecorder) -> String {
    let spans = rec.all_spans();
    let mut out = String::with_capacity(64 + spans.len() * 160);
    out.push_str("{\"traceEvents\":[");
    for (i, s) in spans.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        write_chrome_event(&mut out, s);
    }
    out.push_str("],\"displayTimeUnit\":\"ms\"}\n");
    out
}

/// One JSON object per line: the grep/jq-friendly artifact.
pub fn spans_jsonl(rec: &TraceRecorder) -> String {
    let spans = rec.all_spans();
    let mut out = String::with_capacity(spans.len() * 160);
    for s in &spans {
        let task = if s.task == INFRA_TASK {
            "null".to_string()
        } else {
            s.task.to_string()
        };
        let stage = s.stage.map_or("null".to_string(), |v| v.to_string());
        let node = s.node.map_or("null".to_string(), |v| v.to_string());
        out.push_str(&format!(
            "{{\"task\":{task},\"stage\":{stage},\"attempt\":{},\"kind\":\"{}\",\
             \"start_ms\":{:.6},\"end_ms\":{:.6},\"node\":{node},\"y\":{},\
             \"cancelled\":{}}}\n",
            s.attempt,
            s.kind.name(),
            num(s.start_ms),
            num(s.end_ms),
            s.y,
            s.cancelled,
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_recorder() -> TraceRecorder {
        let mut r = TraceRecorder::new();
        r.admit(0, 0, 1, 0, 0.0, 50.0, 1.5);
        r.core_dispatched(0, 0, 1, 2, None, 1.5, 2.0, 2.0);
        r.stage_done(0, 0, 7.0);
        r.task_finished(0, Some(7.0));
        r.restore(3, 10.0, 12.0);
        r
    }

    #[test]
    fn chrome_json_is_structurally_sound() {
        let s = chrome_trace_json(&sample_recorder());
        assert!(s.starts_with("{\"traceEvents\":["));
        assert!(s.trim_end().ends_with('}'));
        assert!(s.contains("\"ph\":\"X\""));
        assert!(s.contains("\"name\":\"core_exec\""));
        assert!(s.contains("\"name\":\"restore\""));
        assert!(!s.contains("NaN") && !s.contains("inf"));
        // Balanced braces — a cheap parse proxy without serde.
        let open = s.matches('{').count();
        let close = s.matches('}').count();
        assert_eq!(open, close, "unbalanced JSON braces");
    }

    #[test]
    fn jsonl_is_one_object_per_line() {
        let s = spans_jsonl(&sample_recorder());
        assert!(!s.is_empty());
        for line in s.lines() {
            assert!(line.starts_with('{') && line.ends_with('}'), "bad line: {line}");
        }
        // The infra restore span carries a null task id.
        assert!(s.contains("\"task\":null"));
    }

    #[test]
    fn non_finite_times_are_sanitized() {
        let mut r = TraceRecorder::new();
        r.push_raw(Span {
            task: 1,
            stage: None,
            attempt: 0,
            kind: super::super::SpanKind::Serve,
            start_ms: f64::NAN,
            end_ms: f64::INFINITY,
            node: None,
            y: 0,
            cancelled: false,
        });
        let s = chrome_trace_json(&r);
        assert!(!s.contains("NaN") && !s.contains("inf"));
    }
}
