//! The event calendar: a monotone priority queue of typed simulation
//! events, ordered by `(tick, insertion sequence)` — ties resolve FIFO,
//! so a run is reproducible bit-for-bit from its seed.
//!
//! Two implementations share the [`EventCalendar`] contract:
//!
//! * [`RadixCalendar`] — the production queue: a radix calendar queue
//!   (one "current tick" bucket plus 64 radix-distance buckets with a
//!   filled-bitmap) giving O(1) push and amortized O(1) pop. Event
//!   times are quantized to fixed-point ticks ([`TICKS_PER_MS`]) for
//!   *ordering only*; the exact `f64` time rides along untouched, so
//!   all downstream simulation arithmetic is unchanged.
//! * [`HeapCalendar`] — the original `BinaryHeap` ordered by the same
//!   `(tick, seq)` key. Kept as the reference implementation: the
//!   cross-calendar tests replay seeded faulty fixtures on both and
//!   assert identical event order and full-struct-equal metrics.
//!
//! Ordering contract: events on the same tick pop FIFO in scheduling
//! order. Two events whose `f64` times were exactly equal always share
//! a tick, so the old `(time, seq)` FIFO tie-break is preserved;
//! events whose times differ by less than one tick (~0.98 µs) also
//! share a tick and pop in scheduling order — handlers still see the
//! exact times, and since handlers only ever schedule at `now + dt`
//! with `dt ≥ 0`, tick order never runs backwards.

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::collections::VecDeque;

/// Fixed-point resolution of the calendar: ticks per millisecond. At
/// 1024 ticks/ms (≈0.98 µs) a `u64` tick space covers ~570 years of
/// simulated time, and quantization is an exact binary scale — times
/// that compare equal as `f64` always land on the same tick.
pub const TICKS_PER_MS: f64 = 1024.0;

/// Quantize an event time to its ordering tick.
#[inline]
pub fn time_to_tick(time_ms: f64) -> u64 {
    (time_ms * TICKS_PER_MS) as u64
}

/// Everything that can happen in the discrete-event simulation.
#[derive(Clone, Debug)]
pub enum EventKind {
    /// A task is admitted (trace replay): run state is created and the
    /// uplink transmission starts.
    Arrival { arrival: crate::workload::TaskArrival },
    /// The uplink finished; the task's source stages become ready at the
    /// user's edge device.
    UplinkDone { task: u64 },
    /// An intermediate hop of a light-stage payload transfer completed;
    /// the payload sits at an interior node of its route. `plan` is the
    /// transfer-plan slot and `pgen` its generation stamp: a fault
    /// cancellation frees the slot (bumping the generation), so stale
    /// transfer events no-op on an O(1) generation check.
    HopDone { plan: u32, pgen: u32 },
    /// The final transfer hop landed: the payload reached its assigned
    /// light station and joins the replica FIFO (or the batcher).
    /// Addressed like [`EventKind::HopDone`].
    StationJoin { plan: u32, pgen: u32 },
    /// A core stage finished executing. `token` pins the event to its
    /// dispatch: a fault cancellation bumps the stage token, so stale
    /// completion events no-op.
    CoreDone {
        task: u64,
        local: usize,
        node: usize,
        token: u64,
    },
    /// A light stage finished at station `(node, light_idx)`; `y` and
    /// `join_ms` carry the decision parallelism and station-join time for
    /// the sojourn record. `gen` is the station generation at service
    /// start — a node outage resets the station and bumps it, so the
    /// completion of an execution the outage killed is ignored.
    LightDone {
        task: u64,
        local: usize,
        node: usize,
        light_idx: usize,
        y: u32,
        join_ms: f64,
        gen: u64,
    },
    /// Invoke the deployment strategy over the pending light queue.
    Decide,
    /// Slot boundary: virtual-queue updates, drop checks, cost charging,
    /// queue-depth telemetry.
    Tick { slot: usize },
    /// A station batcher's age trigger fired.
    BatchFlush {
        node: usize,
        light_idx: usize,
        epoch: u64,
    },
    /// Apply entry `idx` of the trial's fault schedule at its exact
    /// timestamp (seeded into the calendar up front; absent without
    /// fault injection, keeping fault-free runs bit-identical).
    Fault { idx: usize },
    /// Re-dispatch a fault-cancelled stage once its jittered backoff
    /// window closes (scheduled only under fault injection). A no-op if
    /// the stage was meanwhile dispatched, completed, or its task
    /// dropped.
    Retry { task: u64, local: usize },
    /// A warming pool replica's cold-start window closed: promote it to
    /// warm at station `(node, light_idx)` and rebalance the station's
    /// shared rate (scheduled only with `DesOptions::pool` armed). A
    /// no-op if the warming entry was cancelled by a shrink or outage.
    PoolWarm { node: usize, light_idx: usize },
    /// A pooled light execution's projected completion under the shared
    /// rate. `run` is its `pool::SharedRate` slot and `rt` the reschedule
    /// token stamped at scheduling — occupancy changes reschedule the
    /// completion and bump the token, so superseded events no-op.
    PoolDone { run: u32, rt: u32 },
}

/// A scheduled event. `time_ms` is the exact time handlers run with;
/// `tick` is its fixed-point quantization, used only for ordering.
#[derive(Clone, Debug)]
pub struct Scheduled {
    pub time_ms: f64,
    tick: u64,
    seq: u64,
    pub kind: EventKind,
}

impl Scheduled {
    /// The fixed-point ordering tick ([`time_to_tick`] of `time_ms`,
    /// after watermark clamping).
    pub fn tick(&self) -> u64 {
        self.tick
    }

    /// Global insertion sequence (the FIFO tie-break within a tick).
    pub fn seq(&self) -> u64 {
        self.seq
    }
}

impl PartialEq for Scheduled {
    fn eq(&self, other: &Self) -> bool {
        self.seq == other.seq
    }
}
impl Eq for Scheduled {}
impl PartialOrd for Scheduled {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Scheduled {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.tick
            .cmp(&other.tick)
            .then_with(|| self.seq.cmp(&other.seq))
    }
}

/// The calendar contract both queue implementations satisfy. The DES
/// engine is generic over this, monomorphizing the hot loop per queue.
pub trait EventCalendar {
    /// Schedule `kind` at `time_ms` (clamped to the watermark so the
    /// calendar stays monotone under float round-off).
    fn schedule(&mut self, time_ms: f64, kind: EventKind);
    /// Pop the next event (earliest tick, FIFO among same-tick events).
    fn pop(&mut self) -> Option<Scheduled>;
    fn len(&self) -> usize;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
    /// Number of events dispatched so far.
    fn processed(&self) -> u64;
    /// Drop all queued events and reset counters, retaining allocations
    /// (arena reuse across trials).
    fn clear(&mut self);
}

/// The production calendar — see the module docs. Exported under the
/// historical name so existing call sites keep compiling.
pub type Calendar = RadixCalendar;

const RADIX_BUCKETS: usize = 64;

/// Radix calendar queue over fixed-point ticks.
///
/// Layout (after the xivc `EventQueue` exemplar, generalized from
/// `u32`/33 buckets to `u64`/65): `cur` holds events on the current
/// tick and is popped front-to-back; `buckets[d-1]` holds events whose
/// tick differs from the current tick in bit `d-1` as its highest
/// differing bit (`d = 64 - (cur_tick ^ tick).leading_zeros()`);
/// `filled` has bit `d-1` set when `buckets[d-1]` is non-empty. When
/// `cur` drains, the lowest non-empty bucket is redistributed around
/// its minimum tick (every event provably lands in a strictly lower —
/// and empty — bucket, or in `cur`).
///
/// Invariant: every bucket vector is sorted by `seq` (appends use a
/// globally monotone counter; redistribution drains a sorted source in
/// order into empty targets), so popping `cur` front-to-back yields
/// the global `(tick, seq)` order.
#[derive(Debug)]
pub struct RadixCalendar {
    /// Events on `cur_tick`, FIFO by `seq`; consumed via `pop_front`.
    cur: VecDeque<Scheduled>,
    buckets: [Vec<Scheduled>; RADIX_BUCKETS],
    /// Bit `b` set ⇔ `buckets[b]` is non-empty.
    filled: u64,
    cur_tick: u64,
    /// Exact time of the last popped event; scheduling earlier than
    /// this clamps forward (float round-off guard — the simulation
    /// never goes back).
    watermark: f64,
    seq: u64,
    processed: u64,
    len: usize,
}

impl Default for RadixCalendar {
    fn default() -> Self {
        Self {
            cur: VecDeque::new(),
            buckets: std::array::from_fn(|_| Vec::new()),
            filled: 0,
            cur_tick: 0,
            watermark: 0.0,
            seq: 0,
            processed: 0,
            len: 0,
        }
    }
}

impl RadixCalendar {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    fn place(&mut self, ev: Scheduled) {
        let x = ev.tick ^ self.cur_tick;
        if x == 0 {
            self.cur.push_back(ev);
        } else {
            let b = 63 - x.leading_zeros() as usize;
            self.buckets[b].push(ev);
            self.filled |= 1u64 << b;
        }
    }

    /// Refill `cur` from the lowest non-empty bucket. Its minimum tick
    /// becomes the current tick; redistributed events land in `cur` or
    /// in strictly lower (empty) buckets, so termination is immediate.
    fn reassign(&mut self) -> bool {
        if self.filled == 0 {
            return false;
        }
        let b = self.filled.trailing_zeros() as usize;
        let mut drained = std::mem::take(&mut self.buckets[b]);
        self.filled &= !(1u64 << b);
        self.cur_tick = drained.iter().map(|e| e.tick).min().expect("bucket filled");
        for ev in drained.drain(..) {
            self.place(ev);
        }
        // Hand the drained allocation back to the (now empty) bucket.
        self.buckets[b] = drained;
        true
    }
}

impl EventCalendar for RadixCalendar {
    fn schedule(&mut self, time_ms: f64, kind: EventKind) {
        debug_assert!(time_ms.is_finite(), "event time must be finite");
        let t = if time_ms < self.watermark {
            self.watermark
        } else {
            time_ms
        };
        // `max(cur_tick)` is belt-and-braces: the watermark's tick can
        // never trail the current tick (the last pop set both).
        let tick = time_to_tick(t).max(self.cur_tick);
        self.seq += 1;
        self.len += 1;
        self.place(Scheduled {
            time_ms: t,
            tick,
            seq: self.seq,
            kind,
        });
    }

    fn pop(&mut self) -> Option<Scheduled> {
        if self.cur.is_empty() && !self.reassign() {
            return None;
        }
        let ev = self.cur.pop_front().expect("reassign refilled cur");
        debug_assert!(ev.tick >= self.cur_tick, "calendar must be monotone");
        self.watermark = self.watermark.max(ev.time_ms);
        self.processed += 1;
        self.len -= 1;
        Some(ev)
    }

    fn len(&self) -> usize {
        self.len
    }

    fn processed(&self) -> u64 {
        self.processed
    }

    fn clear(&mut self) {
        self.cur.clear();
        for b in &mut self.buckets {
            b.clear();
        }
        self.filled = 0;
        self.cur_tick = 0;
        self.watermark = 0.0;
        self.seq = 0;
        self.processed = 0;
        self.len = 0;
    }
}

/// The original binary-heap calendar, ordered by the same `(tick,
/// seq)` key and applying the identical watermark clamp. Kept as the
/// reference implementation for cross-calendar bit-identity tests and
/// the `bench_des` baseline — not used on the production path.
#[derive(Debug, Default)]
pub struct HeapCalendar {
    heap: BinaryHeap<Reverse<Scheduled>>,
    seq: u64,
    watermark: f64,
    cur_tick: u64,
    processed: u64,
}

impl HeapCalendar {
    pub fn new() -> Self {
        Self::default()
    }
}

impl EventCalendar for HeapCalendar {
    fn schedule(&mut self, time_ms: f64, kind: EventKind) {
        debug_assert!(time_ms.is_finite(), "event time must be finite");
        let t = if time_ms < self.watermark {
            self.watermark
        } else {
            time_ms
        };
        let tick = time_to_tick(t).max(self.cur_tick);
        self.seq += 1;
        self.heap.push(Reverse(Scheduled {
            time_ms: t,
            tick,
            seq: self.seq,
            kind,
        }));
    }

    fn pop(&mut self) -> Option<Scheduled> {
        let Reverse(ev) = self.heap.pop()?;
        debug_assert!(ev.tick >= self.cur_tick, "calendar must be monotone");
        self.watermark = self.watermark.max(ev.time_ms);
        self.cur_tick = ev.tick;
        self.processed += 1;
        Some(ev)
    }

    fn len(&self) -> usize {
        self.heap.len()
    }

    fn processed(&self) -> u64 {
        self.processed
    }

    fn clear(&mut self) {
        self.heap.clear();
        self.seq = 0;
        self.watermark = 0.0;
        self.cur_tick = 0;
        self.processed = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{Rng, Xoshiro256};

    #[test]
    fn events_pop_in_time_order_fifo_on_ties() {
        let mut c = Calendar::new();
        c.schedule(5.0, EventKind::Decide);
        c.schedule(1.0, EventKind::UplinkDone { task: 1 });
        c.schedule(5.0, EventKind::Tick { slot: 0 });
        let e1 = c.pop().unwrap();
        assert_eq!(e1.time_ms, 1.0);
        let e2 = c.pop().unwrap();
        assert!(matches!(e2.kind, EventKind::Decide), "FIFO among ties");
        let e3 = c.pop().unwrap();
        assert!(matches!(e3.kind, EventKind::Tick { slot: 0 }));
        assert!(c.pop().is_none());
        assert_eq!(c.processed(), 3);
    }

    #[test]
    fn past_scheduling_clamps_to_watermark() {
        let mut c = Calendar::new();
        c.schedule(10.0, EventKind::Decide);
        c.pop().unwrap();
        c.schedule(3.0, EventKind::Tick { slot: 1 }); // in the past: clamps
        let e = c.pop().unwrap();
        assert_eq!(e.time_ms, 10.0);
    }

    /// Regression (fixed-point clamp): a past event clamped to the
    /// watermark must pop FIFO-*after* events already queued on the
    /// watermark tick — the clamp lands it on the same tick with a
    /// fresh (higher) sequence, never ahead of existing ties.
    #[test]
    fn clamped_event_pops_fifo_after_existing_ties_at_watermark() {
        let mut c = Calendar::new();
        c.schedule(10.0, EventKind::Decide);
        c.pop().unwrap(); // watermark now 10.0
        c.schedule(10.0, EventKind::Tick { slot: 7 }); // tie at the watermark
        c.schedule(3.0, EventKind::UplinkDone { task: 42 }); // past: clamps to 10.0
        let first = c.pop().unwrap();
        assert_eq!(first.time_ms, 10.0);
        assert!(
            matches!(first.kind, EventKind::Tick { slot: 7 }),
            "pre-existing tie at the watermark tick must pop before the clamped event"
        );
        let second = c.pop().unwrap();
        assert_eq!(second.time_ms, 10.0, "clamped to the watermark time");
        assert!(matches!(second.kind, EventKind::UplinkDone { task: 42 }));
        assert!(second.seq() > first.seq());
    }

    /// Same scenario on the reference heap — the two implementations
    /// must agree on the clamp-then-tie order.
    #[test]
    fn heap_calendar_clamps_identically() {
        let mut c = HeapCalendar::new();
        c.schedule(10.0, EventKind::Decide);
        c.pop().unwrap();
        c.schedule(10.0, EventKind::Tick { slot: 7 });
        c.schedule(3.0, EventKind::UplinkDone { task: 42 });
        assert!(matches!(c.pop().unwrap().kind, EventKind::Tick { slot: 7 }));
        let e = c.pop().unwrap();
        assert_eq!(e.time_ms, 10.0);
        assert!(matches!(e.kind, EventKind::UplinkDone { task: 42 }));
    }

    /// Randomized interleaving of pushes and pops: the radix queue and
    /// the reference heap must emit the identical event sequence —
    /// same times, same insertion sequence numbers, same ticks.
    #[test]
    fn radix_matches_heap_on_random_interleaving() {
        let mut rng = Xoshiro256::seed_from(0xCA1E_17DA);
        let mut radix = RadixCalendar::new();
        let mut heap = HeapCalendar::new();
        let mut now = 0.0f64;
        for step in 0..20_000u64 {
            if rng.next_f64() < 0.55 || radix.is_empty() {
                // Mix of future offsets, exact ties, sub-tick jitter,
                // and occasional past times (exercising the clamp).
                let dt = match step % 7 {
                    0 => 0.0,
                    1 => rng.next_f64() * 1e-4,
                    2 => -(rng.next_f64() * 5.0),
                    _ => rng.next_f64() * 50.0,
                };
                let t = now + dt;
                radix.schedule(t, EventKind::Tick { slot: step as usize });
                heap.schedule(t, EventKind::Tick { slot: step as usize });
            } else {
                let a = radix.pop().unwrap();
                let b = heap.pop().unwrap();
                assert_eq!(a.seq(), b.seq(), "divergent order at step {step}");
                assert_eq!(a.time_ms, b.time_ms);
                assert_eq!(a.tick(), b.tick());
                now = a.time_ms;
            }
            assert_eq!(radix.len(), heap.len());
        }
        while let Some(a) = radix.pop() {
            let b = heap.pop().unwrap();
            assert_eq!(a.seq(), b.seq());
            assert_eq!(a.time_ms, b.time_ms);
        }
        assert!(heap.pop().is_none());
        assert_eq!(radix.processed(), heap.processed());
    }

    /// Exact-equal `f64` times always share a tick, so old FIFO ties
    /// survive quantization; and tick order never inverts `dt ≥ 0`
    /// scheduling.
    #[test]
    fn quantization_preserves_equal_time_ties() {
        let t = 123.456_789_f64;
        assert_eq!(time_to_tick(t), time_to_tick(t));
        let mut c = Calendar::new();
        for slot in 0..100 {
            c.schedule(t, EventKind::Tick { slot });
        }
        for slot in 0..100 {
            let e = c.pop().unwrap();
            assert!(matches!(e.kind, EventKind::Tick { slot: s } if s == slot));
        }
    }

    /// `clear` retains nothing observable: a cleared calendar replays a
    /// fresh one's sequence exactly (arena reuse across trials).
    #[test]
    fn clear_resets_to_fresh_state() {
        let mut c = Calendar::new();
        c.schedule(4.0, EventKind::Decide);
        c.schedule(9.0, EventKind::Decide);
        c.pop().unwrap();
        c.clear();
        assert!(c.is_empty());
        assert_eq!(c.processed(), 0);
        c.schedule(2.0, EventKind::Tick { slot: 3 });
        let e = c.pop().unwrap();
        assert_eq!(e.time_ms, 2.0);
        assert_eq!(e.seq(), 1, "sequence restarts after clear");
    }
}
