//! The event calendar: a monotone priority queue of typed simulation
//! events, ordered by `(time, insertion sequence)` — ties resolve FIFO,
//! so a run is reproducible bit-for-bit from its seed.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Everything that can happen in the discrete-event simulation.
#[derive(Clone, Debug)]
pub enum EventKind {
    /// A task is admitted (trace replay): run state is created and the
    /// uplink transmission starts.
    Arrival { arrival: crate::workload::TaskArrival },
    /// The uplink finished; the task's source stages become ready at the
    /// user's edge device.
    UplinkDone { task: u64 },
    /// An intermediate hop of a light-stage payload transfer completed;
    /// the payload sits at an interior node of its route. `token` pins the
    /// event to the dispatch that scheduled it: a fault cancellation bumps
    /// the stage token, so stale transfer events no-op.
    HopDone { task: u64, local: usize, token: u64 },
    /// The final transfer hop landed: the payload reached its assigned
    /// light station and joins the replica FIFO (or the batcher).
    StationJoin { task: u64, local: usize, token: u64 },
    /// A core stage finished executing. `token` pins the event to its
    /// dispatch (see [`EventKind::HopDone`]).
    CoreDone {
        task: u64,
        local: usize,
        node: usize,
        token: u64,
    },
    /// A light stage finished at station `(node, light_idx)`; `y` and
    /// `join_ms` carry the decision parallelism and station-join time for
    /// the sojourn record. `gen` is the station generation at service
    /// start — a node outage resets the station and bumps it, so the
    /// completion of an execution the outage killed is ignored.
    LightDone {
        task: u64,
        local: usize,
        node: usize,
        light_idx: usize,
        y: u32,
        join_ms: f64,
        gen: u64,
    },
    /// Invoke the deployment strategy over the pending light queue.
    Decide,
    /// Slot boundary: virtual-queue updates, drop checks, cost charging,
    /// queue-depth telemetry.
    Tick { slot: usize },
    /// A station batcher's age trigger fired.
    BatchFlush {
        node: usize,
        light_idx: usize,
        epoch: u64,
    },
    /// Apply entry `idx` of the trial's fault schedule at its exact
    /// timestamp (seeded into the calendar up front; absent without
    /// fault injection, keeping fault-free runs bit-identical).
    Fault { idx: usize },
    /// Re-dispatch a fault-cancelled stage once its jittered backoff
    /// window closes (scheduled only under fault injection). A no-op if
    /// the stage was meanwhile dispatched, completed, or its task
    /// dropped.
    Retry { task: u64, local: usize },
}

/// A scheduled event.
#[derive(Clone, Debug)]
pub struct Scheduled {
    pub time_ms: f64,
    seq: u64,
    pub kind: EventKind,
}

impl PartialEq for Scheduled {
    fn eq(&self, other: &Self) -> bool {
        self.seq == other.seq
    }
}
impl Eq for Scheduled {}
impl PartialOrd for Scheduled {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Scheduled {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.time_ms
            .partial_cmp(&other.time_ms)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then_with(|| self.seq.cmp(&other.seq))
    }
}

/// Monotone event calendar.
#[derive(Debug, Default)]
pub struct Calendar {
    heap: BinaryHeap<Reverse<Scheduled>>,
    seq: u64,
    /// Time of the last popped event; scheduling earlier than this clamps
    /// forward (float round-off guard — the simulation never goes back).
    watermark: f64,
    processed: u64,
}

impl Calendar {
    pub fn new() -> Self {
        Self::default()
    }

    /// Schedule `kind` at `time_ms` (clamped to the watermark so the
    /// calendar stays monotone under float round-off).
    pub fn schedule(&mut self, time_ms: f64, kind: EventKind) {
        debug_assert!(time_ms.is_finite(), "event time must be finite");
        let t = if time_ms < self.watermark {
            self.watermark
        } else {
            time_ms
        };
        self.seq += 1;
        self.heap.push(Reverse(Scheduled {
            time_ms: t,
            seq: self.seq,
            kind,
        }));
    }

    /// Pop the next event (earliest time, FIFO among ties).
    pub fn pop(&mut self) -> Option<Scheduled> {
        let Reverse(ev) = self.heap.pop()?;
        debug_assert!(ev.time_ms >= self.watermark, "calendar must be monotone");
        self.watermark = ev.time_ms;
        self.processed += 1;
        Some(ev)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Number of events dispatched so far.
    pub fn processed(&self) -> u64 {
        self.processed
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_pop_in_time_order_fifo_on_ties() {
        let mut c = Calendar::new();
        c.schedule(5.0, EventKind::Decide);
        c.schedule(1.0, EventKind::UplinkDone { task: 1 });
        c.schedule(5.0, EventKind::Tick { slot: 0 });
        let e1 = c.pop().unwrap();
        assert_eq!(e1.time_ms, 1.0);
        let e2 = c.pop().unwrap();
        assert!(matches!(e2.kind, EventKind::Decide), "FIFO among ties");
        let e3 = c.pop().unwrap();
        assert!(matches!(e3.kind, EventKind::Tick { slot: 0 }));
        assert!(c.pop().is_none());
        assert_eq!(c.processed(), 3);
    }

    #[test]
    fn past_scheduling_clamps_to_watermark() {
        let mut c = Calendar::new();
        c.schedule(10.0, EventKind::Decide);
        c.pop().unwrap();
        c.schedule(3.0, EventKind::Tick { slot: 1 }); // in the past: clamps
        let e = c.pop().unwrap();
        assert_eq!(e.time_ms, 10.0);
    }
}
