//! Light-service stations: per-(node, service) replica groups with real
//! FIFO queues, concurrency caps derived from the controller's instance
//! decisions, and optional sim-time batching.
//!
//! Storage is struct-of-arrays — one parallel vector per field,
//! indexed `node * num_light + light_idx` — so the hot counters
//! (`cap` / `in_service` / `in_flight`) pack contiguously and the
//! per-tick busy scans stream through three flat `u32` arrays instead
//! of striding over per-replica structs. The station set is reusable
//! across trials via [`LightStations::reset`] (clears, keeps buffers).
//!
//! Core services need no station type of their own — the existing
//! [`crate::routing::CoreRouter`] already models per-instance FIFO
//! serialization through its `busy_until` clocks, and the DES reuses it.

use std::collections::VecDeque;

use crate::coordinator::{BatchPolicy, Batcher};

/// A task waiting at (or being served by) a station.
#[derive(Clone, Debug)]
pub struct Waiting {
    pub task: u64,
    /// Local DAG node of the task executing here.
    pub local: usize,
    /// Realized (sampled) service time, drawn at assignment.
    pub proc_ms: f64,
    /// Parallelism level the controller committed to.
    pub y: u32,
    /// When the payload joined the station (sojourn starts here).
    pub join_ms: f64,
}

/// Outcome of a station join.
pub enum Joined {
    /// Begin serving these now (the engine schedules their completions).
    Start(Vec<Waiting>),
    /// Parked in the replica FIFO until a service slot frees.
    Queued,
    /// Parked in the batcher; `Some((t, epoch))` asks the engine to
    /// schedule a batch-flush event at absolute time `t`.
    Batched(Option<(f64, u64)>),
}

/// All light stations of one trial, indexed `(node, dense light idx)`.
/// Struct-of-arrays: field `i` of station `(v, m)` is `field[v * nl + m]`.
#[derive(Debug, Default)]
pub struct LightStations {
    nv: usize,
    nl: usize,
    max_y: usize,
    /// Concurrent-service cap: instances × max parallelism from the most
    /// recent decision, floored at the running work plus one group's
    /// drain capacity while commitments remain (see `on_decision`).
    cap: Vec<u32>,
    in_service: Vec<u32>,
    /// Assigned-but-not-completed tasks (the controller's busy signal —
    /// mirrors the slotted engine's `active_light`).
    in_flight: Vec<u32>,
    fifo: Vec<VecDeque<Waiting>>,
    batcher: Vec<Option<Batcher<Waiting>>>,
    /// Age-window epoch: a batch-flush event is valid only for the
    /// window it was scheduled in.
    epoch: Vec<u64>,
    /// Outage generation: bumped when the hosting node fails, so
    /// completion events of executions the failure killed are ignored.
    gen: Vec<u64>,
}

impl LightStations {
    pub fn new(nv: usize, nl: usize, max_y: usize, batching: Option<BatchPolicy>) -> Self {
        let mut st = LightStations::default();
        st.reset(nv, nl, max_y, batching);
        st
    }

    /// An empty station set (placeholder until the first
    /// [`LightStations::reset`] — used by the reusable DES arena).
    pub fn empty() -> Self {
        LightStations::default()
    }

    /// Re-dimension and clear for a fresh trial, retaining the parallel
    /// vectors' allocations where dimensions allow.
    pub fn reset(&mut self, nv: usize, nl: usize, max_y: usize, batching: Option<BatchPolicy>) {
        let n = nv * nl;
        self.nv = nv;
        self.nl = nl;
        self.max_y = max_y.max(1);
        self.cap.clear();
        self.cap.resize(n, 0);
        self.in_service.clear();
        self.in_service.resize(n, 0);
        self.in_flight.clear();
        self.in_flight.resize(n, 0);
        for f in &mut self.fifo {
            f.clear();
        }
        self.fifo.resize_with(n, VecDeque::new);
        self.batcher.clear();
        self.batcher.resize_with(n, || batching.map(Batcher::new));
        self.epoch.clear();
        self.epoch.resize(n, 0);
        self.gen.clear();
        self.gen.resize(n, 0);
    }

    #[inline]
    fn idx(&self, v: usize, m: usize) -> usize {
        v * self.nl + m
    }

    /// Start `w` if a service slot is free at station `i`, else park it
    /// in the FIFO.
    fn try_start(&mut self, i: usize, w: Waiting) -> Option<Waiting> {
        if self.in_service[i] < self.cap[i] {
            self.in_service[i] += 1;
            Some(w)
        } else {
            self.fifo[i].push_back(w);
            None
        }
    }

    /// Release a batch into service, FIFO-parking what exceeds the cap.
    fn release(&mut self, i: usize, batch: Vec<Waiting>) -> Vec<Waiting> {
        let mut started = Vec::with_capacity(batch.len());
        for w in batch {
            if let Some(w) = self.try_start(i, w) {
                started.push(w);
            }
        }
        started
    }

    /// Apply a controller decision's instance counts: update caps and
    /// start FIFO work that newly fits. Returns the started entries as
    /// `(node, light_idx, waiting)`.
    ///
    /// The cap is the decided capacity, floored at (a) `in_service` —
    /// running work is never preempted — and (b) *one* instance-group's
    /// worth while commitments remain, so a strategy that zeroes a
    /// station with outstanding work cannot strand its FIFO (the group
    /// stays alive and drains at its own rate). Crucially the floor is
    /// NOT the whole backlog: queued work above the cap keeps waiting,
    /// which is exactly the FIFO queueing this engine exists to measure.
    pub fn on_decision(&mut self, x: &[Vec<u32>]) -> Vec<(usize, usize, Waiting)> {
        let mut started = Vec::new();
        let max_y = self.max_y as u32;
        for v in 0..self.nv {
            for m in 0..self.nl {
                let i = self.idx(v, m);
                let decided = x[v][m].saturating_mul(max_y);
                let drain_floor = if self.in_flight[i] > 0 { max_y } else { 0 };
                self.cap[i] = decided.max(self.in_service[i]).max(drain_floor);
                while self.in_service[i] < self.cap[i] {
                    match self.fifo[i].pop_front() {
                        Some(w) => {
                            self.in_service[i] += 1;
                            started.push((v, m, w));
                        }
                        None => break,
                    }
                }
            }
        }
        started
    }

    /// Register an assignment decided by the controller (payload may
    /// still be in transfer).
    pub fn note_assigned(&mut self, v: usize, m: usize) {
        let i = self.idx(v, m);
        self.in_flight[i] += 1;
    }

    /// The assignment never reached the station (task dropped mid-
    /// transfer): release its busy accounting.
    pub fn abort_assignment(&mut self, v: usize, m: usize) {
        let i = self.idx(v, m);
        self.in_flight[i] = self.in_flight[i].saturating_sub(1);
    }

    /// A payload arrived at its station.
    pub fn join(&mut self, v: usize, m: usize, w: Waiting, now_ms: f64) -> Joined {
        let i = self.idx(v, m);
        if self.batcher[i].is_some() {
            let was_empty = self.batcher[i].as_ref().unwrap().is_empty();
            match self.batcher[i].as_mut().unwrap().push_at(w, now_ms) {
                Some(batch) => Joined::Start(self.release(i, batch)),
                None => {
                    if was_empty {
                        self.epoch[i] += 1;
                        let deadline = self.batcher[i]
                            .as_ref()
                            .unwrap()
                            .age_deadline_ms()
                            .expect("non-empty batcher has an age window");
                        Joined::Batched(Some((deadline, self.epoch[i])))
                    } else {
                        Joined::Batched(None)
                    }
                }
            }
        } else {
            match self.try_start(i, w) {
                Some(w) => Joined::Start(vec![w]),
                None => Joined::Queued,
            }
        }
    }

    /// An age-trigger batch-flush event fired; stale epochs are ignored.
    /// A matching epoch means the event belongs to the *current* age
    /// window (size flushes open a fresh epoch), so the batch is drained
    /// unconditionally — re-deriving the age here could round down under
    /// f64 addition and strand the window forever.
    pub fn age_flush(&mut self, v: usize, m: usize, epoch: u64, _now_ms: f64) -> Vec<Waiting> {
        let i = self.idx(v, m);
        if self.epoch[i] != epoch {
            return Vec::new();
        }
        match self.batcher[i].as_mut().and_then(Batcher::flush) {
            Some(batch) => self.release(i, batch),
            None => Vec::new(),
        }
    }

    /// Outage generation of station `(v, m)` — stamped into `LightDone`
    /// events so completions of executions killed by a node failure are
    /// recognizably stale.
    pub fn gen(&self, v: usize, m: usize) -> u64 {
        self.gen[v * self.nl + m]
    }

    /// Fault injection: the hosting node died. Every station on it loses
    /// its queue, batcher contents, and in-service work; caps drop to
    /// zero (a fresh controller decision re-opens capacity after
    /// recovery) and the generation advances so in-flight completion
    /// events go stale. The engine is responsible for re-dispatching or
    /// dropping the affected tasks — it can enumerate them from its own
    /// per-task state, so nothing is returned here.
    pub fn fail_node(&mut self, v: usize) {
        for m in 0..self.nl {
            let i = self.idx(v, m);
            self.cap[i] = 0;
            self.in_service[i] = 0;
            self.in_flight[i] = 0;
            self.fifo[i].clear();
            if let Some(b) = self.batcher[i].as_mut() {
                let _ = b.flush();
            }
            self.epoch[i] += 1;
            self.gen[i] += 1;
        }
    }

    /// A service completed: free the slot, promote the FIFO head if one
    /// fits (the engine schedules its completion; its service starts now).
    pub fn complete(&mut self, v: usize, m: usize) -> Option<Waiting> {
        let i = self.idx(v, m);
        self.in_service[i] = self.in_service[i].saturating_sub(1);
        self.in_flight[i] = self.in_flight[i].saturating_sub(1);
        if self.in_service[i] < self.cap[i] {
            if let Some(w) = self.fifo[i].pop_front() {
                self.in_service[i] += 1;
                return Some(w);
            }
        }
        None
    }

    /// Controller busy signal: instance-groups still working, per
    /// `(node, light idx)` — `ceil(in_flight / max_y)`, exactly the
    /// slotted engine's convention. Writes into `out` so per-tick and
    /// per-decision calls reuse one scratch matrix.
    pub fn busy_into(&self, out: &mut Vec<Vec<u32>>) {
        out.resize_with(self.nv, Vec::new);
        for (v, row) in out.iter_mut().enumerate() {
            row.clear();
            row.extend((0..self.nl).map(|m| {
                let f = self.in_flight[v * self.nl + m] as usize;
                f.div_ceil(self.max_y) as u32
            }));
        }
    }

    /// Allocating convenience wrapper over [`LightStations::busy_into`].
    pub fn busy_matrix(&self) -> Vec<Vec<u32>> {
        let mut out = Vec::new();
        self.busy_into(&mut out);
        out
    }

    /// Assigned-but-uncompleted work per `(node, light idx)` — the
    /// continuous-time counterpart of the slotted decision's `y[v][m]`
    /// (concurrent tasks), used for per-slot parallelism cost charging.
    pub fn in_flight_into(&self, out: &mut Vec<Vec<u32>>) {
        out.resize_with(self.nv, Vec::new);
        for (v, row) in out.iter_mut().enumerate() {
            row.clear();
            row.extend((0..self.nl).map(|m| self.in_flight[v * self.nl + m]));
        }
    }

    /// Allocating convenience wrapper over [`LightStations::in_flight_into`].
    pub fn in_flight_matrix(&self) -> Vec<Vec<u32>> {
        let mut out = Vec::new();
        self.in_flight_into(&mut out);
        out
    }

    /// Tasks parked in FIFOs and batchers across all stations.
    pub fn waiting_total(&self) -> usize {
        self.fifo.iter().map(VecDeque::len).sum::<usize>()
            + self
                .batcher
                .iter()
                .map(|b| b.as_ref().map_or(0, Batcher::len))
                .sum::<usize>()
    }

    /// Tasks assigned but not yet completed, across all stations.
    pub fn in_flight_total(&self) -> usize {
        self.in_flight.iter().map(|&f| f as usize).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn w(task: u64) -> Waiting {
        Waiting {
            task,
            local: 0,
            proc_ms: 1.0,
            y: 1,
            join_ms: 0.0,
        }
    }

    #[test]
    fn fifo_queues_when_over_cap() {
        let mut st = LightStations::new(2, 1, 2, None);
        // one instance, max_y 2 => cap 2
        let started = st.on_decision(&[vec![1], vec![0]]);
        assert!(started.is_empty());
        st.note_assigned(0, 0);
        st.note_assigned(0, 0);
        st.note_assigned(0, 0);
        assert!(matches!(st.join(0, 0, w(1), 0.0), Joined::Start(v) if v.len() == 1));
        assert!(matches!(st.join(0, 0, w(2), 0.0), Joined::Start(v) if v.len() == 1));
        assert!(matches!(st.join(0, 0, w(3), 0.0), Joined::Queued));
        assert_eq!(st.waiting_total(), 1);
        // completion promotes the FIFO head
        let next = st.complete(0, 0).expect("queued task starts");
        assert_eq!(next.task, 3);
        assert_eq!(st.waiting_total(), 0);
        // busy: 3 assigned, 1 completed => 2 in flight => ceil(2/2)=1 group
        assert_eq!(st.busy_matrix()[0][0], 1);
    }

    #[test]
    fn cap_never_drops_below_commitments() {
        let mut st = LightStations::new(1, 1, 4, None);
        st.on_decision(&[vec![1]]);
        for _ in 0..4 {
            st.note_assigned(0, 0);
        }
        // controller zeroes the station while work is still committed
        st.on_decision(&[vec![0]]);
        assert!(matches!(st.join(0, 0, w(1), 0.0), Joined::Start(_)));
        assert_eq!(st.busy_matrix()[0][0], 1);
    }

    #[test]
    fn decision_does_not_promote_backlog_beyond_capacity() {
        let mut st = LightStations::new(1, 1, 2, None);
        st.on_decision(&[vec![1]]); // one instance, max_y 2 => cap 2
        for _ in 0..6 {
            st.note_assigned(0, 0);
        }
        assert!(matches!(st.join(0, 0, w(1), 0.0), Joined::Start(_)));
        assert!(matches!(st.join(0, 0, w(2), 0.0), Joined::Start(_)));
        for t in 3..=6 {
            assert!(matches!(st.join(0, 0, w(t), 0.0), Joined::Queued));
        }
        // Re-deciding the same x must NOT inflate the cap to the backlog:
        // the queue above capacity is real queueing to be measured.
        let started = st.on_decision(&[vec![1]]);
        assert!(started.is_empty(), "backlog must stay queued at capacity");
        assert_eq!(st.waiting_total(), 4);
        // Completions drain the FIFO one service slot at a time.
        assert!(st.complete(0, 0).is_some());
        assert_eq!(st.waiting_total(), 3);
    }

    #[test]
    fn abort_releases_busy_accounting() {
        let mut st = LightStations::new(1, 1, 4, None);
        st.on_decision(&[vec![1]]);
        st.note_assigned(0, 0);
        assert_eq!(st.busy_matrix()[0][0], 1);
        st.abort_assignment(0, 0);
        assert_eq!(st.busy_matrix()[0][0], 0);
        assert_eq!(st.in_flight_total(), 0);
    }

    #[test]
    fn batcher_flushes_on_size_and_age() {
        let mut st = LightStations::new(1, 1, 8, Some(BatchPolicy::with_wait_ms(2, 5.0)));
        st.on_decision(&[vec![1]]);
        st.note_assigned(0, 0);
        st.note_assigned(0, 0);
        st.note_assigned(0, 0);
        // first join opens an age window
        match st.join(0, 0, w(1), 10.0) {
            Joined::Batched(Some((t, epoch))) => {
                assert_eq!(t, 15.0);
                assert_eq!(epoch, 1);
                // stale epoch is ignored
                assert!(st.age_flush(0, 0, epoch + 1, 20.0).is_empty());
                // valid epoch flushes the batch
                let started = st.age_flush(0, 0, epoch, 15.0);
                assert_eq!(started.len(), 1);
            }
            _ => panic!("first join must open an age window"),
        }
        // size trigger: second window fills to max_batch
        assert!(matches!(st.join(0, 0, w(2), 16.0), Joined::Batched(Some(_))));
        match st.join(0, 0, w(3), 16.5) {
            Joined::Start(v) => assert_eq!(v.len(), 2),
            _ => panic!("size trigger must flush"),
        }
    }

    #[test]
    fn fail_node_clears_state_and_bumps_generation() {
        let mut st = LightStations::new(2, 1, 2, None);
        st.on_decision(&[vec![1], vec![0]]);
        for _ in 0..4 {
            st.note_assigned(0, 0);
        }
        assert!(matches!(st.join(0, 0, w(1), 0.0), Joined::Start(_)));
        assert!(matches!(st.join(0, 0, w(2), 0.0), Joined::Start(_)));
        assert!(matches!(st.join(0, 0, w(3), 0.0), Joined::Queued));
        let g0 = st.gen(0, 0);
        st.fail_node(0);
        assert_eq!(st.gen(0, 0), g0 + 1);
        assert_eq!(st.waiting_total(), 0, "FIFO lost with the node");
        assert_eq!(st.in_flight_total(), 0, "busy accounting released");
        assert_eq!(st.busy_matrix()[0][0], 0);
        // A completion of pre-failure work is stale by generation; the
        // engine checks gen() and never calls complete() for it. New work
        // after recovery behaves normally once a decision re-opens caps.
        let started = st.on_decision(&[vec![1], vec![0]]);
        assert!(started.is_empty());
        st.note_assigned(0, 0);
        assert!(matches!(st.join(0, 0, w(9), 5.0), Joined::Start(_)));
    }

    #[test]
    fn decision_growth_promotes_fifo() {
        let mut st = LightStations::new(1, 1, 1, None);
        st.on_decision(&[vec![1]]);
        st.note_assigned(0, 0);
        st.note_assigned(0, 0);
        assert!(matches!(st.join(0, 0, w(1), 0.0), Joined::Start(_)));
        assert!(matches!(st.join(0, 0, w(2), 0.0), Joined::Queued));
        let started = st.on_decision(&[vec![2]]);
        assert_eq!(started.len(), 1);
        assert_eq!(started[0].2.task, 2);
    }

    #[test]
    fn reset_reuses_buffers_for_a_fresh_trial() {
        let mut st = LightStations::new(2, 2, 2, None);
        st.on_decision(&[vec![1, 1], vec![1, 1]]);
        st.note_assigned(1, 1);
        assert!(matches!(st.join(1, 1, w(1), 0.0), Joined::Start(_)));
        st.fail_node(0);
        st.reset(2, 2, 2, None);
        assert_eq!(st.in_flight_total(), 0);
        assert_eq!(st.waiting_total(), 0);
        assert_eq!(st.gen(0, 0), 0, "generations restart");
        assert_eq!(st.busy_matrix(), vec![vec![0, 0], vec![0, 0]]);
    }
}
