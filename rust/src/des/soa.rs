//! Struct-of-arrays hot state for the DES engine.
//!
//! At metro scale (10^6 concurrent users) the per-task bookkeeping is
//! the memory- and cache-bound part of the hot loop. This module
//! replaces the seed engine's `HashMap<u64, DesTask>` (one heap struct
//! per task, eight `Vec`s per struct) and `HashMap<(u64, usize),
//! TransferPlan>` with index-based storage:
//!
//! * [`TaskArena`] — per-task scalars in parallel vectors addressed by
//!   a slot index, per-stage state in flat arrays addressed by a span
//!   `(base, len)`, and an O(1) `id → slot` map exploiting the fact
//!   that [`crate::workload::WorkloadGenerator`] issues dense
//!   sequential task ids from 0. Freed slots and spans recycle through
//!   free lists; slots carry generation stamps so recycled storage is
//!   never mistaken for its previous tenant.
//! * [`PlanSlab`] — transfer plans in a generation-stamped slab;
//!   calendar events carry `(slot, generation)` so the token staleness
//!   guard is a single comparison instead of a hash probe.
//!
//! The flat per-stage arrays keep the exact element types the shared
//! `crate::sim` rules take (`&[Option<f64>]`, `&[bool]`, …), so a span
//! slice feeds `stage_ready` / `parent_payloads` /
//! `stage_inputs_destroyed` with no translation layer — the engines
//! keep consulting one copy of the semantics.

/// Sentinel in the `id → slot` map: task absent.
const NO_SLOT: u32 = u32::MAX;

/// Per-task state, struct-of-arrays. All `pub` fields are engine-hot
/// storage addressed by the slot index returned from [`TaskArena::insert`]
/// / [`TaskArena::slot`]; per-stage fields are addressed by the span
/// range from [`TaskArena::span`].
#[derive(Debug, Default)]
pub struct TaskArena {
    /// `id → slot` (dense ids from 0; `NO_SLOT` = not live).
    slot_of: Vec<u32>,
    /// Ids below this are all freed — live-id scans start here.
    min_live_id: usize,
    live: usize,
    free: Vec<u32>,

    // Per-slot scalars.
    pub id: Vec<u64>,
    pub task_type: Vec<u32>,
    pub arrival_ms: Vec<f64>,
    pub deadline_ms: Vec<f64>,
    pub uplink_ms: Vec<f64>,
    pub ed: Vec<u32>,
    /// Lyapunov virtual-queue value `H_j` (same update rule as
    /// `controller::VirtualQueues`, stored in-arena so the controller
    /// read is an indexed load instead of a hash probe).
    pub vq: Vec<f64>,
    /// Whether `vq` has been updated by a slot tick at least once.
    /// `controller::VirtualQueues::total_backlog` sums only tasks that
    /// were ever `update()`d (the map is insert-on-update); telemetry
    /// parity requires the same filter here.
    pub vq_tracked: Vec<bool>,
    base: Vec<u32>,
    nstages: Vec<u32>,

    // Flat per-stage arrays, addressed by `base..base + nstages`.
    // Element types match the shared `crate::sim` rule signatures.
    pub done: Vec<Option<f64>>,
    pub node: Vec<Option<usize>>,
    pub dispatched: Vec<bool>,
    pub destroyed: Vec<bool>,
    pub rerouted: Vec<bool>,
    /// Per-stage dispatch token: bumped on every dispatch and on every
    /// fault cancellation, so calendar events from a superseded
    /// dispatch are recognizably stale.
    pub token: Vec<u64>,
    pub attempts: Vec<u32>,
    pub retry_at: Vec<f64>,
    /// Standby hedged execution per stage: `(node, token)`.
    pub hedge: Vec<Option<(usize, u64)>>,

    /// Recycled spans, bucketed by length (DAGs are small: a handful of
    /// distinct stage counts per application).
    span_free: Vec<Vec<u32>>,
}

impl TaskArena {
    pub fn new() -> Self {
        Self::default()
    }

    /// Number of live tasks.
    pub fn live(&self) -> usize {
        self.live
    }

    /// O(1) lookup: the slot of a live task, if any.
    #[inline]
    pub fn slot(&self, id: u64) -> Option<u32> {
        match self.slot_of.get(id as usize) {
            Some(&s) if s != NO_SLOT => Some(s),
            _ => None,
        }
    }

    #[inline]
    pub fn contains(&self, id: u64) -> bool {
        self.slot(id).is_some()
    }

    /// The per-stage span of `slot` into the flat arrays.
    #[inline]
    pub fn span(&self, slot: u32) -> std::ops::Range<usize> {
        let b = self.base[slot as usize] as usize;
        b..b + self.nstages[slot as usize] as usize
    }

    #[inline]
    pub fn nstages(&self, slot: u32) -> usize {
        self.nstages[slot as usize] as usize
    }

    /// Insert a task, returning its slot. Ids must be unique while live
    /// (the generator's are globally unique).
    #[allow(clippy::too_many_arguments)]
    pub fn insert(
        &mut self,
        id: u64,
        task_type: usize,
        arrival_ms: f64,
        deadline_ms: f64,
        uplink_ms: f64,
        ed: usize,
        nstages: usize,
        vq0: f64,
    ) -> u32 {
        debug_assert!(!self.contains(id), "duplicate live task id {id}");
        let base = self.alloc_span(nstages);
        let slot = match self.free.pop() {
            Some(s) => {
                let i = s as usize;
                self.id[i] = id;
                self.task_type[i] = task_type as u32;
                self.arrival_ms[i] = arrival_ms;
                self.deadline_ms[i] = deadline_ms;
                self.uplink_ms[i] = uplink_ms;
                self.ed[i] = ed as u32;
                self.vq[i] = vq0;
                self.vq_tracked[i] = false;
                self.base[i] = base;
                self.nstages[i] = nstages as u32;
                s
            }
            None => {
                let s = self.id.len() as u32;
                self.id.push(id);
                self.task_type.push(task_type as u32);
                self.arrival_ms.push(arrival_ms);
                self.deadline_ms.push(deadline_ms);
                self.uplink_ms.push(uplink_ms);
                self.ed.push(ed as u32);
                self.vq.push(vq0);
                self.vq_tracked.push(false);
                self.base.push(base);
                self.nstages.push(nstages as u32);
                s
            }
        };
        let idx = id as usize;
        if idx >= self.slot_of.len() {
            self.slot_of.resize(idx + 1, NO_SLOT);
        }
        self.slot_of[idx] = slot;
        self.live += 1;
        slot
    }

    /// Free a live task's slot and span (recycled for later inserts).
    pub fn remove(&mut self, id: u64) {
        let slot = self.slot(id).expect("removing a task that is not live");
        self.slot_of[id as usize] = NO_SLOT;
        let n = self.nstages[slot as usize] as usize;
        self.free_span(self.base[slot as usize], n);
        self.free.push(slot);
        self.live -= 1;
    }

    /// Iterate live task ids in ascending id order, calling `f(id,
    /// slot)`. Ascending-id iteration is the determinism contract the
    /// seed engine bought with a per-tick `sort_unstable` over a
    /// `HashMap`'s keys; here the `id → slot` map *is* the sorted
    /// index, so the walk is a linear scan from the first live id.
    pub fn for_each_live<F: FnMut(u64, u32)>(&mut self, mut f: F) {
        while self.min_live_id < self.slot_of.len() && self.slot_of[self.min_live_id] == NO_SLOT {
            self.min_live_id += 1;
        }
        for idx in self.min_live_id..self.slot_of.len() {
            let s = self.slot_of[idx];
            if s != NO_SLOT {
                f(idx as u64, s);
            }
        }
    }

    /// Collect live ids in ascending order into `out` (for walks that
    /// mutate the arena mid-iteration).
    pub fn live_ids_into(&mut self, out: &mut Vec<u64>) {
        out.clear();
        self.for_each_live(|id, _| out.push(id));
    }

    /// First possibly-live id (advances past the freed prefix). With
    /// [`TaskArena::id_upper`] this brackets an open-coded live walk
    /// for callers that mutate per-stage state mid-iteration.
    pub fn first_live_id(&mut self) -> usize {
        while self.min_live_id < self.slot_of.len() && self.slot_of[self.min_live_id] == NO_SLOT {
            self.min_live_id += 1;
        }
        self.min_live_id
    }

    /// One past the largest id ever inserted.
    pub fn id_upper(&self) -> usize {
        self.slot_of.len()
    }

    /// Total Lyapunov backlog over live tasks whose queue was ever
    /// ticked — exactly `VirtualQueues::total_backlog` semantics.
    pub fn vq_total(&self) -> f64 {
        let mut sum = 0.0;
        for idx in self.min_live_id..self.slot_of.len() {
            let s = self.slot_of[idx];
            if s != NO_SLOT && self.vq_tracked[s as usize] {
                sum += self.vq[s as usize];
            }
        }
        sum
    }

    fn alloc_span(&mut self, n: usize) -> u32 {
        if let Some(list) = self.span_free.get_mut(n) {
            if let Some(base) = list.pop() {
                let r = base as usize..base as usize + n;
                self.done[r.clone()].fill(None);
                self.node[r.clone()].fill(None);
                self.dispatched[r.clone()].fill(false);
                self.destroyed[r.clone()].fill(false);
                self.rerouted[r.clone()].fill(false);
                self.token[r.clone()].fill(0);
                self.attempts[r.clone()].fill(0);
                self.retry_at[r.clone()].fill(0.0);
                self.hedge[r].fill(None);
                return base;
            }
        }
        let base = self.done.len() as u32;
        self.done.resize(base as usize + n, None);
        self.node.resize(base as usize + n, None);
        self.dispatched.resize(base as usize + n, false);
        self.destroyed.resize(base as usize + n, false);
        self.rerouted.resize(base as usize + n, false);
        self.token.resize(base as usize + n, 0);
        self.attempts.resize(base as usize + n, 0);
        self.retry_at.resize(base as usize + n, 0.0);
        self.hedge.resize(base as usize + n, None);
        base
    }

    fn free_span(&mut self, base: u32, n: usize) {
        if self.span_free.len() <= n {
            self.span_free.resize_with(n + 1, Vec::new);
        }
        self.span_free[n].push(base);
    }

    /// Reset to empty, retaining every allocation (arena reuse across
    /// trials in a sweep cell).
    pub fn clear(&mut self) {
        self.slot_of.clear();
        self.min_live_id = 0;
        self.live = 0;
        self.free.clear();
        self.id.clear();
        self.task_type.clear();
        self.arrival_ms.clear();
        self.deadline_ms.clear();
        self.uplink_ms.clear();
        self.ed.clear();
        self.vq.clear();
        self.vq_tracked.clear();
        self.base.clear();
        self.nstages.clear();
        self.done.clear();
        self.node.clear();
        self.dispatched.clear();
        self.destroyed.clear();
        self.rerouted.clear();
        self.token.clear();
        self.attempts.clear();
        self.retry_at.clear();
        self.hedge.clear();
        for l in &mut self.span_free {
            l.clear();
        }
    }
}

/// Transfer plans in a generation-stamped slab. A plan is created per
/// light assignment and freed when the payload joins its station, when
/// its task is cancelled, or when its destination node dies; the
/// generation bump at free makes any in-flight `HopDone`/`StationJoin`
/// event stale with one comparison.
#[derive(Debug, Default)]
pub struct PlanSlab {
    pub task: Vec<u64>,
    pub local: Vec<u32>,
    pub node: Vec<u32>,
    pub light_idx: Vec<u32>,
    pub y: Vec<u32>,
    pub proc_ms: Vec<f64>,
    /// Remaining hop-completion times (absolute ms; the last entry is
    /// the station join). Inner vectors recycle their capacity.
    pub hop_times: Vec<Vec<f64>>,
    pub next: Vec<u32>,
    gen: Vec<u32>,
    live: Vec<bool>,
    free: Vec<u32>,
    live_count: usize,
}

impl PlanSlab {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn live(&self) -> usize {
        self.live_count
    }

    /// Allocate a plan slot (its `hop_times` vector comes back cleared,
    /// capacity retained). Returns `(slot, generation)` for the events.
    #[allow(clippy::too_many_arguments)]
    pub fn alloc(
        &mut self,
        task: u64,
        local: usize,
        node: usize,
        light_idx: usize,
        y: u32,
        proc_ms: f64,
    ) -> (u32, u32) {
        let slot = match self.free.pop() {
            Some(s) => {
                let i = s as usize;
                self.task[i] = task;
                self.local[i] = local as u32;
                self.node[i] = node as u32;
                self.light_idx[i] = light_idx as u32;
                self.y[i] = y;
                self.proc_ms[i] = proc_ms;
                self.hop_times[i].clear();
                self.next[i] = 0;
                self.live[i] = true;
                s
            }
            None => {
                let s = self.task.len() as u32;
                self.task.push(task);
                self.local.push(local as u32);
                self.node.push(node as u32);
                self.light_idx.push(light_idx as u32);
                self.y.push(y);
                self.proc_ms.push(proc_ms);
                self.hop_times.push(Vec::new());
                self.next.push(0);
                self.gen.push(0);
                self.live.push(true);
                s
            }
        };
        self.live_count += 1;
        (slot, self.gen[slot as usize])
    }

    /// O(1) staleness check: the plan is live and the event's
    /// generation matches.
    #[inline]
    pub fn is_live(&self, slot: u32, gen: u32) -> bool {
        let i = slot as usize;
        i < self.live.len() && self.live[i] && self.gen[i] == gen
    }

    /// Free a plan slot, bumping its generation (in-flight events for
    /// it become stale).
    pub fn remove(&mut self, slot: u32) {
        let i = slot as usize;
        debug_assert!(self.live[i], "double free of plan slot {slot}");
        self.live[i] = false;
        self.gen[i] = self.gen[i].wrapping_add(1);
        self.free.push(slot);
        self.live_count -= 1;
    }

    /// Free every live plan headed to `node`, calling `f(plan_slot)`
    /// first (node-outage cancellation: payloads toward a dead station
    /// never land).
    pub fn remove_toward<F: FnMut(u32)>(&mut self, node: usize, mut f: F) {
        for i in 0..self.live.len() {
            if self.live[i] && self.node[i] == node as u32 {
                f(i as u32);
                self.live[i] = false;
                self.gen[i] = self.gen[i].wrapping_add(1);
                self.free.push(i as u32);
                self.live_count -= 1;
            }
        }
    }

    /// Reset to empty, retaining allocations (including the per-slot
    /// `hop_times` capacities).
    pub fn clear(&mut self) {
        self.task.clear();
        self.local.clear();
        self.node.clear();
        self.light_idx.clear();
        self.y.clear();
        self.proc_ms.clear();
        for h in &mut self.hop_times {
            h.clear();
        }
        self.hop_times.clear();
        self.next.clear();
        self.gen.clear();
        self.live.clear();
        self.free.clear();
        self.live_count = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arena_insert_lookup_remove_recycles_slots_and_spans() {
        let mut a = TaskArena::new();
        let s0 = a.insert(0, 1, 10.0, 100.0, 2.0, 3, 4, 0.5);
        let s1 = a.insert(1, 0, 11.0, 100.0, 2.0, 4, 4, 0.5);
        assert_eq!(a.live(), 2);
        assert_eq!(a.slot(0), Some(s0));
        assert_eq!(a.slot(1), Some(s1));
        let r0 = a.span(s0);
        a.done[r0.start] = Some(42.0);
        a.remove(0);
        assert_eq!(a.slot(0), None);
        assert_eq!(a.live(), 1);
        // Same stage count → the freed slot and span recycle, scrubbed.
        let s2 = a.insert(2, 1, 12.0, 100.0, 2.0, 5, 4, 0.5);
        assert_eq!(s2, s0, "slot recycled");
        let r2 = a.span(s2);
        assert_eq!(r2, r0, "span recycled");
        assert!(a.done[r2].iter().all(|d| d.is_none()), "span scrubbed");
    }

    #[test]
    fn arena_iterates_live_ids_in_ascending_order() {
        let mut a = TaskArena::new();
        for id in 0..10u64 {
            a.insert(id, 0, 0.0, 1.0, 0.0, 0, 2, 0.0);
        }
        for id in [0u64, 1, 4, 7] {
            a.remove(id);
        }
        let mut seen = Vec::new();
        a.for_each_live(|id, _| seen.push(id));
        assert_eq!(seen, vec![2, 3, 5, 6, 8, 9]);
        // The freed prefix is skipped permanently.
        assert!(a.min_live_id >= 2);
    }

    #[test]
    fn arena_clear_retains_nothing_observable() {
        let mut a = TaskArena::new();
        a.insert(5, 0, 0.0, 1.0, 0.0, 0, 3, 0.0);
        a.clear();
        assert_eq!(a.live(), 0);
        assert_eq!(a.slot(5), None);
        let s = a.insert(0, 0, 0.0, 1.0, 0.0, 0, 3, 0.0);
        assert_eq!(s, 0, "slots restart from zero after clear");
        assert_eq!(a.span(s), 0..3);
    }

    #[test]
    fn vq_total_sums_only_ticked_tasks() {
        let mut a = TaskArena::new();
        let s0 = a.insert(0, 0, 0.0, 1.0, 0.0, 0, 1, 0.5);
        let _s1 = a.insert(1, 0, 0.0, 1.0, 0.0, 0, 1, 0.5);
        assert_eq!(a.vq_total(), 0.0, "never-ticked queues are invisible");
        a.vq[s0 as usize] = 3.0;
        a.vq_tracked[s0 as usize] = true;
        assert!((a.vq_total() - 3.0).abs() < 1e-12);
        a.remove(0);
        assert_eq!(a.vq_total(), 0.0, "removed tasks drop out of the sum");
    }

    #[test]
    fn plan_slab_generation_makes_stale_events_noop() {
        let mut p = PlanSlab::new();
        let (s, g) = p.alloc(7, 1, 2, 0, 4, 9.0);
        assert!(p.is_live(s, g));
        p.hop_times[s as usize].push(15.0);
        p.remove(s);
        assert!(!p.is_live(s, g), "freed plan is stale");
        let (s2, g2) = p.alloc(8, 0, 3, 1, 2, 1.0);
        assert_eq!(s2, s, "slot recycled");
        assert_ne!(g2, g, "generation bumped");
        assert!(p.is_live(s2, g2));
        assert!(!p.is_live(s, g), "old stamp still stale after reuse");
        assert!(p.hop_times[s2 as usize].is_empty(), "hops cleared");
    }

    #[test]
    fn plan_slab_removes_toward_dead_node() {
        let mut p = PlanSlab::new();
        let (a, _) = p.alloc(1, 0, 5, 0, 1, 1.0);
        let (b, _) = p.alloc(2, 0, 6, 0, 1, 1.0);
        let (c, _) = p.alloc(3, 0, 5, 1, 1, 1.0);
        let mut doomed = Vec::new();
        p.remove_toward(5, |s| doomed.push(s));
        assert_eq!(doomed, vec![a, c]);
        assert_eq!(p.live(), 1);
        assert!(p.is_live(b, 0));
    }
}
