//! Discrete-event queueing simulation (`des`): measured ground truth for
//! the paper's probabilistic QoS claims.
//!
//! The slotted trial engine ([`crate::sim`]) *assumes* the effective-
//! capacity bound `g_{m,ε}(y)` when it executes light services; this
//! subsystem replays the exact same [`crate::workload::Trace`] in
//! continuous time with real queues and *measures* instead:
//!
//! * [`calendar`] — a monotone event calendar (arrival, uplink-complete,
//!   hop-transfer-complete, station-join, service-complete, controller
//!   decision, slot tick, batch-flush), FIFO among time ties, fully
//!   deterministic per seed. The production queue is a radix calendar
//!   over quantized ticks; the binary heap survives as a reference
//!   implementation the bit-identity tests replay against.
//! * [`soa`] — struct-of-arrays hot state: live tasks in a slot-indexed
//!   [`soa::TaskArena`] (O(1) id→slot, no hashing on the event path) and
//!   in-flight transfer plans in a generation-stamped [`soa::PlanSlab`].
//! * [`stations`] — per-(node, light-service) replica stations with FIFO
//!   queues, concurrency caps from the controller's instance decisions,
//!   and optional sim-time batching through the coordinator's
//!   [`crate::coordinator::Batcher`]. Core services reuse
//!   [`crate::routing::CoreRouter`]'s per-instance busy clocks.
//! * [`engine`] — the event loop. Any [`crate::sim::Strategy`] runs
//!   unmodified: it is invoked event-driven (immediately when light work
//!   becomes ready, plus every slot boundary) and its decisions set
//!   station capacities. Light service times are *sampled* from each
//!   service's rate distribution at the controller's committed
//!   parallelism; transfers replay the [`crate::routing::HopTable`] hop
//!   chain whose total equals the analytic `DistanceMatrix` latency.
//! * [`validate`] — empirical delay-violation rates and CCDFs per light
//!   service against `g_{m,ε}(y)`: the paper's guarantee holds iff
//!   `P(sojourn > g_{m,ε}(y)) ≤ ε`.
//!
//! `examples/validate_bounds.rs` runs both engines on a paired trace and
//! prints the comparison; `fmedge des` is the CLI entry point.

mod calendar;
mod engine;
pub mod soa;
mod stations;
pub mod validate;

pub use calendar::{
    Calendar, EventCalendar, EventKind, HeapCalendar, RadixCalendar, Scheduled,
};
pub use engine::{
    run_des_trial, run_des_trial_faulted, run_des_trial_faulted_in, run_des_trial_observed,
    run_des_trial_recorded, DesArena, DesOptions, TaskRecord,
};
pub use stations::{Joined, LightStations, Waiting};
pub use validate::{pool, report, sojourn_ccdf, validate_bounds, ServiceValidation};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::{LbrrStrategy, Proposal};
    use crate::config::ExperimentConfig;
    use crate::effcap::{GTable, GTableParams};
    use crate::graph::Dag;
    use crate::latency;
    use crate::microservice::{
        Application, Catalog, MsClass, MsId, MsSpec, RateModel, TaskType, TaskTypeId,
    };
    use crate::network::Topology;
    use crate::rng::Xoshiro256;
    use crate::routing::{DistanceMatrix, HopTable};
    use crate::sim::{record_trace, run_trial_traced, SimEnv, SimOptions};
    use crate::workload::{TaskArrival, TaskId, Trace};

    fn small_cfg() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::paper_default();
        cfg.sim.slots = 80;
        cfg.workload.num_users = 8;
        cfg.controller.effcap_samples = 512;
        cfg
    }

    #[test]
    fn des_trial_completes_tasks() {
        let cfg = small_cfg();
        let env = SimEnv::build(&cfg, 21);
        let opts = SimOptions::from_config(&cfg);
        let trace = record_trace(&env, 21, &opts);
        let m = run_des_trial(
            &env,
            &mut Proposal::new(),
            21,
            &DesOptions::from_sim(&opts),
            &trace,
        );
        assert_eq!(m.total_tasks, trace.len());
        assert!(
            m.completion_rate() > 0.5,
            "DES under the proposal should complete most tasks, got {}",
            m.completion_rate()
        );
        assert!(m.total_cost > 0.0);
        // DES actually measured light executions.
        let measured: usize = m.service_obs.iter().map(|o| o.samples.len()).sum();
        assert!(measured > 0, "no sojourns measured");
        assert!(m.queue_depth.count() > 0, "no queue-depth samples");
    }

    #[test]
    fn des_same_seed_is_deterministic() {
        let cfg = small_cfg();
        let env = SimEnv::build(&cfg, 22);
        let opts = SimOptions::from_config(&cfg);
        let trace = record_trace(&env, 22, &opts);
        let d = DesOptions::from_sim(&opts);
        let m1 = run_des_trial(&env, &mut Proposal::new(), 22, &d, &trace);
        let m2 = run_des_trial(&env, &mut Proposal::new(), 22, &d, &trace);
        assert_eq!(m1.total_tasks, m2.total_tasks);
        assert_eq!(m1.completed, m2.completed);
        assert_eq!(m1.on_time, m2.on_time);
        assert!((m1.total_cost - m2.total_cost).abs() < 1e-9);
        let s1: Vec<usize> = m1.service_obs.iter().map(|o| o.samples.len()).collect();
        let s2: Vec<usize> = m2.service_obs.iter().map(|o| o.samples.len()).collect();
        assert_eq!(s1, s2);
    }

    #[test]
    fn des_and_slotted_run_the_same_paired_trace() {
        let cfg = small_cfg();
        let env = SimEnv::build(&cfg, 23);
        let opts = SimOptions::from_config(&cfg);
        let trace = record_trace(&env, 23, &opts);
        let slotted = run_trial_traced(&env, &mut Proposal::new(), 23, &opts, &trace);
        let des = run_des_trial(
            &env,
            &mut Proposal::new(),
            23,
            &DesOptions::from_sim(&opts),
            &trace,
        );
        assert_eq!(slotted.total_tasks, des.total_tasks, "paired admission");
        assert!(des.completion_rate() > 0.5);
        // Both engines should be in the same ballpark on the headline
        // metric under moderate load (DES is finer-grained, not wildly
        // different).
        assert!(
            (slotted.on_time_rate() - des.on_time_rate()).abs() < 0.45,
            "slotted {} vs DES {}",
            slotted.on_time_rate(),
            des.on_time_rate()
        );
    }

    #[test]
    fn des_virtual_queues_drain_to_empty_after_trial() {
        // Regression (VirtualQueues lifecycle): a task that is dropped —
        // including mid-transfer — must release its virtual-queue entry;
        // run under overload so drops actually happen.
        let mut cfg = small_cfg();
        cfg.sim.load_multiplier = 3.0;
        let env = SimEnv::build(&cfg, 26);
        let opts = SimOptions::from_config(&cfg);
        let trace = record_trace(&env, 26, &opts);
        let m = run_des_trial(
            &env,
            &mut Proposal::new(),
            26,
            &DesOptions::from_sim(&opts),
            &trace,
        );
        assert!(m.total_tasks > 0);
        assert_eq!(m.vq_residual, 0, "virtual-queue entries leaked");
    }

    #[test]
    fn des_strategies_run_without_panic() {
        let cfg = small_cfg();
        let env = SimEnv::build(&cfg, 24);
        let opts = SimOptions::from_config(&cfg);
        let trace = record_trace(&env, 24, &opts);
        let d = DesOptions::from_sim(&opts);
        let m = run_des_trial(&env, &mut LbrrStrategy::new(), 24, &d, &trace);
        assert_eq!(m.total_tasks, trace.len());
    }

    #[test]
    fn des_with_batching_still_completes() {
        let cfg = small_cfg();
        let env = SimEnv::build(&cfg, 25);
        let opts = SimOptions::from_config(&cfg);
        let trace = record_trace(&env, 25, &opts);
        let mut d = DesOptions::from_sim(&opts);
        d.batching = Some(crate::coordinator::BatchPolicy::with_wait_ms(4, 0.5));
        let m = run_des_trial(&env, &mut Proposal::new(), 25, &d, &trace);
        assert_eq!(m.total_tasks, trace.len());
        assert!(
            m.completion_rate() > 0.4,
            "batched DES should still complete tasks, got {}",
            m.completion_rate()
        );
    }

    /// Build a hand-made environment whose every rate is deterministic
    /// (zero variance) plus a single-task trace — the analytic latency
    /// recursion and the DES must then agree exactly.
    fn deterministic_env() -> (SimEnv, Trace) {
        let mut cfg = ExperimentConfig::paper_default();
        cfg.workload.num_users = 1;
        cfg.app.num_task_types = 1;
        cfg.controller.kappa = 2;
        cfg.controller.eta = 0.01; // cheap deployments: always serve
        let mut rng = Xoshiro256::seed_from(4242);
        let topo = Topology::generate(&cfg, &mut rng);
        let hops = HopTable::build(&topo, 1.0);
        let dm = DistanceMatrix::from_hops(&hops);

        let mut cat = Catalog::new();
        cat.push(MsSpec {
            id: MsId(0),
            name: "core-src".into(),
            class: MsClass::Core,
            resources: [2.0, 1.0, 2.0, 1.0],
            workload_mb: 4.0,
            output_mb: 0.8,
            rate: RateModel::Deterministic(8.0),
            cost_deploy: 20.0,
            cost_maint: 4.0,
            cost_parallel: 0.0,
        });
        cat.push(MsSpec {
            id: MsId(1),
            name: "light-mid".into(),
            class: MsClass::Light,
            resources: [0.5, 0.1, 0.5, 0.1],
            workload_mb: 1.0,
            output_mb: 0.6,
            rate: RateModel::Deterministic(5.0),
            cost_deploy: 4.0,
            cost_maint: 1.0,
            cost_parallel: 0.5,
        });
        cat.push(MsSpec {
            id: MsId(2),
            name: "core-sink".into(),
            class: MsClass::Core,
            resources: [2.0, 1.0, 2.0, 1.0],
            workload_mb: 6.0,
            output_mb: 0.3,
            rate: RateModel::Deterministic(12.0),
            cost_deploy: 20.0,
            cost_maint: 4.0,
            cost_parallel: 0.0,
        });
        let mut dag = Dag::new(3);
        dag.add_edge(0, 1).unwrap();
        dag.add_edge(1, 2).unwrap();
        let tt = TaskType {
            id: TaskTypeId(0),
            dag,
            services: vec![MsId(0), MsId(1), MsId(2)],
            deadline_ms: 500.0,
            input_mb: 1.5,
        };
        let app = Application::new(cat, vec![tt]);

        let samples = vec![vec![5.0; 128]];
        let gtable = GTable::build(
            &samples,
            &[1.0],
            &GTableParams::from_config(&cfg.controller),
        );
        let env = SimEnv {
            cfg: cfg.clone(),
            app,
            topo,
            dm,
            hops,
            gtable,
            light_rate_samples: samples,
            light_resources: vec![[0.5, 0.1, 0.5, 0.1]],
            light_costs: vec![(4.0, 1.0, 0.5)],
            core_costs: vec![(20.0, 4.0), (20.0, 4.0)],
            users_seed: 7,
        };
        let trace = Trace::from_arrivals(vec![TaskArrival {
            id: TaskId(0),
            user: 0,
            ed: 0,
            task_type: TaskTypeId(0),
            slot: 0,
            snr: 20.0,
            uplink_delay_ms: 2.25,
        }]);
        (env, trace)
    }

    #[test]
    fn deterministic_single_task_matches_analytic_completion_times() {
        // Property (satellite): zero-variance service times, zero
        // contention, single task => DES end-to-end latency equals the
        // eq. 4/5 recursion on the realized assignment, to 1e-9.
        let (env, trace) = deterministic_env();
        let opts = DesOptions {
            slots: 600,
            slot_ms: 1.0,
            drop_after_deadlines: 50.0,
            batching: None,
            failover: crate::coordinator::FailoverPolicy::default(),
            streaming: false,
            pool: None,
        };
        let (m, records) = run_des_trial_recorded(&env, &mut Proposal::new(), 77, &opts, &trace);
        assert_eq!(m.total_tasks, 1);
        assert_eq!(m.completed, 1, "single task must complete");
        let rec = &records[0];
        let lat = rec.latency_ms.expect("completed");

        let tt = &env.app.task_types[0];
        let assignment: Vec<usize> = rec
            .stage_node
            .iter()
            .map(|n| n.expect("all stages executed"))
            .collect();
        let proc: Vec<f64> = (0..3)
            .map(|i| {
                let s = env.app.catalog.spec(tt.services[i]);
                s.workload_mb / s.rate.mean()
            })
            .collect();
        let out: Vec<f64> = (0..3)
            .map(|i| env.app.catalog.spec(tt.services[i]).output_mb)
            .collect();
        // The analytic recursion folds the ED->source transfer into the
        // uplink term (its transfer closure only sees DAG edges).
        let uplink_eff = 2.25 + env.dm.latency(0, assignment[0], tt.input_mb);
        let expected = latency::end_to_end(
            &tt.dag,
            &out,
            uplink_eff,
            &assignment,
            &proc,
            |a, b, mb| env.dm.latency(a, b, mb),
        );
        assert!(
            (lat - expected).abs() < 1e-9,
            "DES {lat} vs analytic {expected}"
        );
        // And the stage completion times agree too.
        let times = latency::completion_times(
            &tt.dag,
            &out,
            uplink_eff,
            &assignment,
            &proc,
            |a, b, mb| env.dm.latency(a, b, mb),
        );
        for (i, t) in times.iter().enumerate() {
            let got = rec.stage_done[i].expect("done") - rec.arrival_ms;
            assert!(
                (got - t).abs() < 1e-9,
                "stage {i}: DES {got} vs analytic {t}"
            );
        }
    }

    /// Seeded faulty fixture exercising retries, hedges, and a zone
    /// outage: two edge servers go dark mid-trial and recover, with a
    /// replica fail-stop/restart pair, under enough load that stages are
    /// provably in flight when the outage lands.
    fn faulty_fixture(seed: u64) -> (SimEnv, Trace, DesOptions, crate::faults::FaultSchedule) {
        use crate::faults::{FaultEvent, FaultKind, FaultSchedule};
        let mut cfg = small_cfg();
        cfg.sim.load_multiplier = 1.5;
        let env = SimEnv::build(&cfg, seed);
        let opts = SimOptions::from_config(&cfg);
        let trace = record_trace(&env, seed, &opts);
        let es = cfg.network.num_eds;
        let slot_ms = opts.slot_ms;
        let events = vec![
            FaultEvent { time_ms: 20.0 * slot_ms, kind: FaultKind::NodeDown { node: es } },
            FaultEvent { time_ms: 22.0 * slot_ms, kind: FaultKind::NodeDown { node: es + 1 } },
            FaultEvent {
                time_ms: 35.0 * slot_ms,
                kind: FaultKind::CoreReplicaFail { node: es + 2, core_idx: 0 },
            },
            FaultEvent {
                time_ms: 48.0 * slot_ms,
                kind: FaultKind::CoreReplicaRestart { node: es + 2, core_idx: 0 },
            },
            FaultEvent { time_ms: 55.0 * slot_ms, kind: FaultKind::NodeUp { node: es } },
            FaultEvent { time_ms: 57.0 * slot_ms, kind: FaultKind::NodeUp { node: es + 1 } },
        ];
        (env, trace, DesOptions::from_sim(&opts), FaultSchedule::from_events(events))
    }

    #[test]
    fn radix_calendar_replays_heap_calendar_bit_identically_under_faults() {
        // The tentpole's correctness contract: the radix queue is a pure
        // drop-in for the reference heap — same (time, seq) pop order, so
        // the seeded faulty replay (retries + hedges + zone outage) must
        // produce full-struct-equal TrialMetrics and unchanged
        // des::validate results on both.
        let (env, trace, dopts, schedule) = faulty_fixture(61);
        let mut radix = DesArena::<RadixCalendar>::new();
        let mut heap = DesArena::<HeapCalendar>::new();
        let r = run_des_trial_faulted_in(
            &mut radix, &env, &mut Proposal::new(), 61, &dopts, &trace, &schedule,
        );
        let h = run_des_trial_faulted_in(
            &mut heap, &env, &mut Proposal::new(), 61, &dopts, &trace, &schedule,
        );
        assert!(r.retries > 0, "fixture must exercise the retry path");
        assert_eq!(r, h, "radix and heap calendars diverged");
        let vr = validate_bounds(&env.gtable, &r);
        let vh = validate_bounds(&env.gtable, &h);
        for (a, b) in vr.iter().zip(&vh) {
            assert_eq!(a.samples, b.samples);
            assert_eq!(a.violations, b.violations);
            assert_eq!(a.holds(0.0), b.holds(0.0));
        }
    }

    #[test]
    fn arena_reuse_across_trials_is_bit_identical_to_fresh() {
        // exp::run_cells keeps one DesArena per worker cell and reuses it
        // for every trial (clear, don't drop). A trial run into a dirty
        // arena must equal the same trial into a fresh one.
        let (env, trace, dopts, schedule) = faulty_fixture(62);
        let mut reused = DesArena::<Calendar>::new();
        // Dirty the arena with a different-seed trial first.
        let _ = run_des_trial_faulted_in(
            &mut reused, &env, &mut Proposal::new(), 99, &dopts, &trace, &schedule,
        );
        let dirty = run_des_trial_faulted_in(
            &mut reused, &env, &mut Proposal::new(), 62, &dopts, &trace, &schedule,
        );
        let mut fresh = DesArena::<Calendar>::new();
        let clean = run_des_trial_faulted_in(
            &mut fresh, &env, &mut Proposal::new(), 62, &dopts, &trace, &schedule,
        );
        assert_eq!(dirty, clean, "arena reuse changed trial output");
    }

    #[test]
    fn streaming_metrics_agree_with_retained_on_a_real_trial() {
        // Same seeded trial, streaming on vs off: identical counts and
        // costs, no retained buffers, and the bound validation reaches
        // the same verdict from the streamed aggregates.
        let (env, trace, dopts, schedule) = faulty_fixture(63);
        let mut sopts = dopts.clone();
        sopts.streaming = true;
        let ret = run_des_trial_faulted(&env, &mut Proposal::new(), 63, &dopts, &trace, &schedule);
        let st = run_des_trial_faulted(&env, &mut Proposal::new(), 63, &sopts, &trace, &schedule);
        assert_eq!(st.total_tasks, ret.total_tasks);
        assert_eq!(st.completed, ret.completed);
        assert_eq!(st.on_time, ret.on_time);
        assert_eq!(st.total_cost, ret.total_cost);
        assert_eq!(st.retries, ret.retries);
        assert_eq!(st.fault_drops, ret.fault_drops);
        assert_eq!(st.des_events, ret.des_events, "event stream must be unchanged");
        assert!(st.latencies_ms.is_empty(), "streaming retains no raw latencies");
        assert!(st.service_obs.iter().all(|o| o.samples.is_empty()));
        assert_eq!(st.latency_hist.count(), ret.latency_hist.count());
        // Validation: violation counts match the retained recomputation
        // exactly (the same g-table values were compared either way).
        let vr = validate_bounds(&env.gtable, &ret);
        let vs = validate_bounds(&env.gtable, &st);
        for (a, b) in vr.iter().zip(&vs) {
            assert_eq!(a.samples, b.samples);
            assert_eq!(a.violations, b.violations);
            assert!((a.mean_sojourn_ms - b.mean_sojourn_ms).abs() < 1e-9);
            assert!((a.mean_bound_ms - b.mean_bound_ms).abs() < 1e-9);
        }
        // Percentiles answer from the histogram, close to the exact ones.
        if ret.completed > 0 {
            let p = ret.latency_percentile(0.5);
            let q = st.latency_percentile(0.5);
            assert!(q > 0.0 && (p - q).abs() / p < 0.25, "p50 exact {p} vs hist {q}");
        }
    }

    #[test]
    fn validation_layer_reports_on_seed_config() {
        let cfg = small_cfg();
        let env = SimEnv::build(&cfg, 29);
        let opts = SimOptions::from_config(&cfg);
        let trace = record_trace(&env, 29, &opts);
        let m = run_des_trial(
            &env,
            &mut Proposal::new(),
            29,
            &DesOptions::from_sim(&opts),
            &trace,
        );
        let vals = validate_bounds(&env.gtable, &m);
        assert_eq!(vals.len(), env.app.catalog.num_light());
        let total: usize = vals.iter().map(|v| v.samples).sum();
        assert!(total > 0, "no light executions measured");
        let text = report(&vals);
        assert!(text.contains("measured"));
        // The paper-default eps = 0.2; a Chernoff-true bound should hold
        // comfortably in aggregate.
        let violations: usize = vals.iter().map(|v| v.violations).sum();
        let rate = violations as f64 / total as f64;
        assert!(
            rate <= env.gtable.params_epsilon + 0.05,
            "aggregate violation rate {rate} vs eps {}",
            env.gtable.params_epsilon
        );
    }
}
