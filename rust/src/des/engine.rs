//! The continuous-time engine: replays a [`Trace`] through real queues.
//!
//! Where the slotted engine advances in `slot_ms` quanta and *assumes*
//! the effective-capacity bound for light-service delays, this engine is
//! a classic discrete-event simulation: a monotone calendar of arrival /
//! uplink / hop-transfer / service events, per-instance FIFO serialization
//! for core services (via [`CoreRouter`]'s busy clocks), and per-replica
//! FIFO stations with *sampled* service times for light services. The
//! deployment [`Strategy`] runs unmodified: it is invoked event-driven —
//! immediately when light work becomes ready, plus at every slot boundary
//! — and its instance decisions set the station concurrency caps.
//!
//! Semantics shared with the slotted engine (so paired traces compare
//! apples to apples): transfers follow the [`crate::routing::HopTable`] routes whose
//! summed latency equals `DistanceMatrix::latency` exactly; light service
//! times are drawn as `a_m / (f / y^alpha)` at the controller's committed
//! parallelism; busy accounting is `ceil(in_flight / Y)` instance groups.
//! What differs is what the paper's bound is *about*: here tasks may
//! actually wait in FIFO queues, and every light execution yields a
//! measured sojourn `(y, wait + service)` for `des::validate`.

use std::collections::HashMap;

use crate::config::NUM_RESOURCES;
use crate::controller::{LightRequest, VirtualQueues};
use crate::coordinator::{BatchPolicy, FailoverPolicy};
use crate::faults::{DynamicTopology, FaultKind, FaultSchedule};
use crate::metrics::{CostBook, MetricsCollector, TaskOutcome, TrialMetrics};
use crate::microservice::{Application, MsClass};
use crate::obs::{Observer, TraceRecorder};
use crate::placement::{QosScores, ScoreParams};
use crate::routing::{CoreRouter, DistanceMatrix};
use crate::rng::Xoshiro256;
use crate::sim::{SimEnv, SimOptions, Strategy};
use crate::workload::{Trace, WorkloadGenerator};

use super::calendar::{Calendar, EventKind};
use super::stations::{Joined, LightStations, Waiting};

/// DES run options.
#[derive(Clone, Debug)]
pub struct DesOptions {
    /// Horizon in slots (the calendar runs to `slots * slot_ms`).
    pub slots: usize,
    /// Controller tick period (ms) — the strategy's decision cadence.
    pub slot_ms: f64,
    /// Tasks unfinished this many deadlines past their own are dropped.
    pub drop_after_deadlines: f64,
    /// Optional station batching: arrivals at a light station accumulate
    /// and flush on size or (simulated) age.
    pub batching: Option<BatchPolicy>,
    /// Retry/backoff + checkpoint policy replayed under faults — the
    /// same object the slotted engine and the serving coordinator use,
    /// so agreement extends to retried executions. Inert without faults.
    pub failover: FailoverPolicy,
}

impl DesOptions {
    pub fn from_sim(o: &SimOptions) -> Self {
        DesOptions {
            slots: o.slots,
            slot_ms: o.slot_ms,
            drop_after_deadlines: o.drop_after_deadlines,
            batching: None,
            failover: o.failover,
        }
    }

    pub fn from_config(cfg: &crate::config::ExperimentConfig) -> Self {
        Self::from_sim(&SimOptions::from_config(cfg))
    }
}

/// Per-task execution record (optional output for validation tooling).
#[derive(Clone, Debug)]
pub struct TaskRecord {
    pub id: u64,
    pub task_type: usize,
    pub arrival_ms: f64,
    pub deadline_ms: f64,
    /// Completion time of each local DAG stage (ms, absolute).
    pub stage_done: Vec<Option<f64>>,
    /// Network node that executed each stage.
    pub stage_node: Vec<Option<usize>>,
    /// End-to-end latency; `None` for dropped/unfinished tasks.
    pub latency_ms: Option<f64>,
}

/// Task runtime state.
struct DesTask {
    task_type: usize,
    arrival_ms: f64,
    deadline_ms: f64,
    uplink_ms: f64,
    ed: usize,
    done: Vec<Option<f64>>,
    node: Vec<Option<usize>>,
    dispatched: Vec<bool>,
    /// Per-stage dispatch token: bumped on every dispatch and on every
    /// fault cancellation, so calendar events from a superseded dispatch
    /// are recognizably stale.
    token: Vec<u64>,
    /// A completed stage's output was lost with its node — permanent:
    /// recovery restores capacity, not data (shared rule:
    /// [`crate::sim`]'s `stage_inputs_destroyed`).
    destroyed: Vec<bool>,
    /// Fault-cancelled dispatch attempts per stage (drives the backoff).
    attempts: Vec<u32>,
    /// Earliest re-dispatch time per stage after a fault cancellation.
    retry_at: Vec<f64>,
    /// Cancelled by a fault; counted as a re-route recovery on the next
    /// successful dispatch (or hedge promotion).
    rerouted: Vec<bool>,
    /// Standby hedged execution per stage: `(node, token)`. Promoted if
    /// the primary's node dies; dropped when its own node dies or the
    /// primary completes first.
    hedge: Vec<Option<(usize, u64)>>,
}

impl DesTask {
    /// Delegates to the engine-shared rule ([`crate::sim`]'s
    /// `stage_ready`) so paired runs can never disagree on readiness.
    fn stage_ready(&self, app: &Application, local: usize) -> bool {
        crate::sim::stage_ready(app, self.task_type, &self.done, &self.dispatched, local)
    }

    /// Parent payload sources `(node, done_ms, mb)`; source stages read
    /// the user payload at the ED once the uplink lands. Shared with the
    /// slotted engine.
    fn parent_payloads(&self, app: &Application, local: usize) -> Vec<(usize, f64, f64)> {
        crate::sim::parent_payloads(
            app,
            self.task_type,
            &self.done,
            &self.node,
            self.ed,
            self.arrival_ms + self.uplink_ms,
            local,
        )
    }
}

/// An assigned light payload in transit: the remaining hop-completion
/// times (absolute ms; the last entry is the station join). Kept outside
/// the task map so a dropped task's transfer can still release its busy
/// accounting when it lands.
struct TransferPlan {
    node: usize,
    light_idx: usize,
    y: u32,
    proc_ms: f64,
    hop_times: Vec<f64>,
    next: usize,
    /// Dispatch token of the stage when the plan was made; hop events
    /// carry it so a plan created by a later re-dispatch is never driven
    /// by a stale event.
    token: u64,
}

struct Des<'a> {
    env: &'a SimEnv,
    opts: &'a DesOptions,
    /// The replayed fault schedule ([`EventKind::Fault`] indexes into it).
    faults: &'a FaultSchedule,
    /// Fault-aware network view; `None` without fault injection (the
    /// fault-free path stays bit-identical to pre-fault builds).
    dynt: Option<DynamicTopology>,
    node_up: Vec<bool>,
    /// Stages cancelled by the current same-timestamp fault batch,
    /// re-dispatched once the batch's routing rebuild has committed.
    fault_resets: Vec<(u64, usize)>,
    rng: Xoshiro256,
    cal: Calendar,
    tasks: HashMap<u64, DesTask>,
    plans: HashMap<(u64, usize), TransferPlan>,
    queues: VirtualQueues,
    /// Light work awaiting a controller assignment: `(task, local)`.
    pending: Vec<(u64, usize)>,
    decide_scheduled: bool,
    stations: LightStations,
    core_router: CoreRouter,
    residual_static: Vec<[f64; NUM_RESOURCES]>,
    collector: MetricsCollector,
    costs: CostBook,
    light_idx_of: Vec<Option<usize>>,
    light_dp: Vec<f64>,
    light_mt: Vec<f64>,
    light_pl: Vec<f64>,
    horizon_ms: f64,
    record: bool,
    records: Vec<TaskRecord>,
    /// Optional observability handle; `None` leaves every hook site on
    /// the exact untraced code path (no RNG, no event reordering).
    obs: Option<&'a mut Observer>,
}

impl<'a> Des<'a> {
    /// The span recorder, if an observer with tracing is attached.
    fn rec(&mut self) -> Option<&mut TraceRecorder> {
        self.obs.as_deref_mut().and_then(|o| o.trace.as_mut())
    }

    fn request_decide(&mut self, now: f64) {
        if !self.decide_scheduled {
            self.decide_scheduled = true;
            self.cal.schedule(now, EventKind::Decide);
        }
    }

    fn finish_task(&mut self, id: u64, t: DesTask, done_ms: Option<f64>) {
        if let Some(r) = self.rec() {
            r.task_finished(id, done_ms);
        }
        let latency_ms = done_ms.map(|d| d - t.arrival_ms);
        self.collector.record(TaskOutcome {
            task_id: id,
            latency_ms,
            deadline_ms: t.deadline_ms,
        });
        self.queues.remove(id);
        if self.record {
            self.records.push(TaskRecord {
                id,
                task_type: t.task_type,
                arrival_ms: t.arrival_ms,
                deadline_ms: t.deadline_ms,
                stage_done: t.done,
                stage_node: t.node,
                latency_ms,
            });
        }
    }

    fn handle_arrival(&mut self, a: crate::workload::TaskArrival, now: f64) {
        let app = &self.env.app;
        // A trace recorded under a different application would silently
        // skew every paired metric — fail loudly instead (the slotted
        // engine panics on the same mismatch).
        assert!(
            a.task_type.0 < app.task_types.len(),
            "trace task {} has task type {} but the application defines {}",
            a.id.0,
            a.task_type.0,
            app.task_types.len()
        );
        let n = app.task_types[a.task_type.0].dag.len();
        let deadline_ms = app.task_types[a.task_type.0].deadline_ms;
        self.tasks.insert(
            a.id.0,
            DesTask {
                task_type: a.task_type.0,
                arrival_ms: now,
                deadline_ms,
                uplink_ms: a.uplink_delay_ms,
                ed: a.ed,
                done: vec![None; n],
                node: vec![None; n],
                dispatched: vec![false; n],
                token: vec![0; n],
                destroyed: vec![false; n],
                attempts: vec![0; n],
                retry_at: vec![0.0; n],
                rerouted: vec![false; n],
                hedge: vec![None; n],
            },
        );
        let sink = app.task_types[a.task_type.0]
            .dag
            .sink()
            .unwrap_or(n.saturating_sub(1));
        if let Some(r) = self.rec() {
            r.admit(a.id.0, a.task_type.0, n, sink, now, deadline_ms, a.uplink_delay_ms);
        }
        self.cal
            .schedule(now + a.uplink_delay_ms, EventKind::UplinkDone { task: a.id.0 });
    }

    fn ready_stages(&self, id: u64) -> Vec<usize> {
        let app = &self.env.app;
        match self.tasks.get(&id) {
            None => Vec::new(),
            Some(t) => {
                let tt = &app.task_types[t.task_type];
                (0..tt.dag.len())
                    .filter(|&l| t.stage_ready(app, l))
                    .collect()
            }
        }
    }

    fn handle_uplink_done(&mut self, id: u64, now: f64) {
        for local in self.ready_stages(id) {
            self.dispatch_stage(id, local, now);
        }
    }

    /// Dispatch a ready stage: core stages route immediately to the
    /// completion-minimizing placed instance (FIFO per instance via the
    /// router's busy clocks); light stages enter the controller queue.
    /// Under faults, a stage whose input payload died with its node drops
    /// the task (unrecoverable casualty).
    fn dispatch_stage(&mut self, id: u64, local: usize, now: f64) {
        let env = self.env;
        let app = &env.app;
        let (ms_id, is_core, proc_ms, payloads) = {
            let t = match self.tasks.get(&id) {
                Some(t) => t,
                None => return,
            };
            let tt = &app.task_types[t.task_type];
            let ms_id = tt.services[local];
            let spec = app.catalog.spec(ms_id);
            (
                ms_id,
                spec.class == MsClass::Core,
                spec.mean_proc_delay(),
                t.parent_payloads(app, local),
            )
        };
        if self.dynt.is_some() {
            let t = &self.tasks[&id];
            // Destroyed inputs are unrecoverable; a down ED merely delays
            // the source stage (the device retains the user payload).
            if crate::sim::stage_inputs_destroyed(app, t.task_type, &t.destroyed, local) {
                let t = self.tasks.remove(&id).unwrap();
                self.collector.record_fault_drop();
                self.finish_task(id, t, None);
                return;
            }
            if !self.node_up[t.ed] && app.task_types[t.task_type].dag.parents(local).is_empty()
            {
                return; // retried at the next tick once the ED recovers
            }
            if now < t.retry_at[local] {
                return; // backoff window; the Retry event re-dispatches
            }
        }
        if is_core {
            let ci = app
                .catalog
                .core_ids()
                .iter()
                .position(|&c| c == ms_id)
                .expect("core id");
            let dm = match &self.dynt {
                Some(d) => d.dm(),
                None => &env.dm,
            };
            if let Some(asn) = self
                .core_router
                .route_multi(ci, &payloads, proc_ms, now, dm)
            {
                // Hedged second attempt: a stage that already lost one
                // execution to a fault and is near its deadline books a
                // standby replica on a *different* node; it is promoted
                // if the primary's node dies mid-execution.
                let hedge_asn = if self.dynt.is_some() {
                    let t = &self.tasks[&id];
                    let slack = t.arrival_ms + t.deadline_ms - now;
                    if t.rerouted[local]
                        && self.opts.failover.retry.should_hedge(slack, t.deadline_ms)
                    {
                        self.core_router
                            .route_multi(ci, &payloads, proc_ms, now, dm)
                            .filter(|h| h.node != asn.node)
                    } else {
                        None
                    }
                } else {
                    None
                };
                // Critical-parent span data must be derived while the
                // routed dm view is still borrowed (it lives in self).
                let trace_pre = self.obs.is_some().then(|| {
                    let t = &self.tasks[&id];
                    let primary = crate::sim::critical_parent(
                        app, t.task_type, local, &payloads, asn.node, dm,
                    );
                    let hedge = hedge_asn.as_ref().map(|h| {
                        crate::sim::critical_parent(
                            app, t.task_type, local, &payloads, h.node, dm,
                        )
                    });
                    (primary, hedge)
                });
                let t = self.tasks.get_mut(&id).unwrap();
                if t.rerouted[local] {
                    t.rerouted[local] = false;
                    self.collector.record_reroute();
                }
                t.dispatched[local] = true;
                t.node[local] = Some(asn.node);
                t.token[local] += 1;
                let token = t.token[local];
                self.cal.schedule(
                    asn.done_ms,
                    EventKind::CoreDone {
                        task: id,
                        local,
                        node: asn.node,
                        token,
                    },
                );
                if let Some(((from, ready, arrive), _)) = trace_pre {
                    if let Some(r) = self.rec() {
                        r.core_dispatched(
                            id,
                            local,
                            token,
                            asn.node,
                            from,
                            ready,
                            arrive,
                            asn.start_ms,
                        );
                    }
                }
                if let Some(h) = hedge_asn {
                    // The hedge carries token + 1; only a promotion (the
                    // primary's node dying) makes it the live token.
                    let t = self.tasks.get_mut(&id).unwrap();
                    let htoken = token + 1;
                    t.hedge[local] = Some((h.node, htoken));
                    self.collector.record_hedge();
                    self.cal.schedule(
                        h.done_ms,
                        EventKind::CoreDone {
                            task: id,
                            local,
                            node: h.node,
                            token: htoken,
                        },
                    );
                    if let Some((_, Some((from, ready, arrive)))) = trace_pre {
                        if let Some(r) = self.rec() {
                            r.hedge_dispatched(
                                id,
                                local,
                                htoken,
                                h.node,
                                from,
                                ready,
                                arrive,
                                h.start_ms,
                            );
                        }
                    }
                }
            }
            // No instance: every replica may be down or unreachable under
            // faults — the stage stays undispatched and is retried when
            // the next decision or recovery comes around (see tick).
        } else {
            let t = self.tasks.get_mut(&id).unwrap();
            t.dispatched[local] = true;
            self.pending.push((id, local));
            if let Some(r) = self.rec() {
                r.light_pending(id, local, now);
            }
            self.request_decide(now);
        }
    }

    /// A stage finished: record it, complete the task at the sink, and
    /// dispatch any children that became ready.
    fn handle_stage_done(&mut self, id: u64, local: usize, node: usize, now: f64) {
        let app = &self.env.app;
        let is_sink = {
            let t = match self.tasks.get_mut(&id) {
                Some(t) => t,
                None => return, // dropped while executing
            };
            t.done[local] = Some(now);
            t.node[local] = Some(node);
            app.task_types[t.task_type].dag.sink() == Some(local)
        };
        if let Some(r) = self.rec() {
            r.stage_done(id, local, now);
        }
        if is_sink {
            let t = self.tasks.remove(&id).unwrap();
            self.finish_task(id, t, Some(now));
            return;
        }
        let children: Vec<usize> = {
            let t = &self.tasks[&id];
            app.task_types[t.task_type]
                .dag
                .children(local)
                .iter()
                .filter(|&&c| t.stage_ready(app, c))
                .cloned()
                .collect()
        };
        for c in children {
            self.dispatch_stage(id, c, now);
        }
    }

    /// Begin serving `w` at station `(v, m)`: completion scheduled after
    /// its sampled service time, stamped with the station's current
    /// outage generation.
    fn start_service(&mut self, v: usize, m: usize, w: Waiting, now: f64) {
        if let Some(r) = self.rec() {
            r.light_started(w.task, w.local, now);
        }
        let gen = self.stations.gen(v, m);
        self.cal.schedule(
            now + w.proc_ms,
            EventKind::LightDone {
                task: w.task,
                local: w.local,
                node: v,
                light_idx: m,
                y: w.y,
                join_ms: w.join_ms,
                gen,
            },
        );
    }

    fn handle_hop_done(&mut self, id: u64, local: usize, token: u64) {
        let plan = match self.plans.get_mut(&(id, local)) {
            Some(p) => p,
            None => return,
        };
        if plan.token != token {
            return; // stale event from a cancelled dispatch
        }
        plan.next += 1;
        let i = plan.next;
        debug_assert!(i < plan.hop_times.len());
        let t = plan.hop_times[i];
        let kind = if i + 1 == plan.hop_times.len() {
            EventKind::StationJoin { task: id, local, token }
        } else {
            EventKind::HopDone { task: id, local, token }
        };
        self.cal.schedule(t, kind);
    }

    fn handle_station_join(&mut self, id: u64, local: usize, token: u64, now: f64) {
        match self.plans.get(&(id, local)) {
            Some(p) if p.token == token => {}
            _ => return, // stale event from a cancelled dispatch
        }
        let plan = self.plans.remove(&(id, local)).unwrap();
        if !self.tasks.contains_key(&id) {
            // Dropped mid-transfer: never joins, release the commitment.
            self.stations.abort_assignment(plan.node, plan.light_idx);
            return;
        }
        let w = Waiting {
            task: id,
            local,
            proc_ms: plan.proc_ms,
            y: plan.y,
            join_ms: now,
        };
        match self.stations.join(plan.node, plan.light_idx, w, now) {
            Joined::Start(list) => {
                for w in list {
                    self.start_service(plan.node, plan.light_idx, w, now);
                }
            }
            Joined::Queued => {}
            Joined::Batched(Some((t, epoch))) => {
                self.cal.schedule(
                    t,
                    EventKind::BatchFlush {
                        node: plan.node,
                        light_idx: plan.light_idx,
                        epoch,
                    },
                );
            }
            Joined::Batched(None) => {}
        }
    }

    fn handle_batch_flush(&mut self, node: usize, light_idx: usize, epoch: u64, now: f64) {
        let started = self.stations.age_flush(node, light_idx, epoch, now);
        for w in started {
            self.start_service(node, light_idx, w, now);
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn handle_light_done(
        &mut self,
        id: u64,
        local: usize,
        node: usize,
        light_idx: usize,
        y: u32,
        join_ms: f64,
        gen: u64,
        now: f64,
    ) {
        if self.stations.gen(node, light_idx) != gen {
            return; // the execution died with its node
        }
        // The measured quantity the g-bound is about: wait + service.
        self.collector.record_sojourn(light_idx, y, now - join_ms);
        if let Some(next) = self.stations.complete(node, light_idx) {
            self.start_service(node, light_idx, next, now);
        }
        self.handle_stage_done(id, local, node, now);
    }

    /// Invoke the deployment strategy on the pending light queue.
    fn handle_decide(&mut self, strategy: &mut dyn Strategy, now: f64) {
        self.decide_scheduled = false;
        {
            let tasks = &self.tasks;
            self.pending.retain(|(id, _)| tasks.contains_key(id));
        }
        if self.dynt.is_some() {
            // Queued work whose input payload was destroyed is an
            // unrecoverable casualty — drop before building requests
            // (unreachable-but-alive inputs keep waiting).
            let app = &self.env.app;
            let mut casualties: Vec<u64> = Vec::new();
            for &(id, local) in &self.pending {
                if let Some(t) = self.tasks.get(&id) {
                    if crate::sim::stage_inputs_destroyed(app, t.task_type, &t.destroyed, local)
                    {
                        casualties.push(id);
                    }
                }
            }
            for id in casualties {
                if let Some(t) = self.tasks.remove(&id) {
                    self.collector.record_fault_drop();
                    self.finish_task(id, t, None);
                }
            }
            let tasks = &self.tasks;
            self.pending.retain(|(id, _)| tasks.contains_key(id));
        }
        if self.pending.is_empty() {
            return;
        }
        let env = self.env;
        let app = &env.app;
        let slot = ((now / self.opts.slot_ms).floor() as usize)
            .min(self.opts.slots.saturating_sub(1));

        let busy = self.stations.busy_matrix();
        let mut residual =
            crate::sim::residual_after_busy(&self.residual_static, &env.light_resources, &busy);
        if self.dynt.is_some() {
            for (v, res) in residual.iter_mut().enumerate() {
                if !self.node_up[v] {
                    *res = [0.0; NUM_RESOURCES];
                }
            }
        }
        let requests: Vec<LightRequest> = self
            .pending
            .iter()
            .map(|&(id, local)| {
                let t = &self.tasks[&id];
                let tt = &app.task_types[t.task_type];
                let ms_id = tt.services[local];
                let m = self.light_idx_of[ms_id.0].expect("light idx");
                let payloads = t.parent_payloads(app, local);
                let &(from, _, mb) = payloads
                    .iter()
                    .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
                    .unwrap();
                LightRequest {
                    task_id: id,
                    light_idx: m,
                    from_node: from,
                    payload_mb: mb,
                    h: self.queues.value(id),
                    deadline_slack_ms: t.deadline_ms - (now - t.arrival_ms),
                }
            })
            .collect();

        let decision = {
            let dm: &DistanceMatrix = match &self.dynt {
                Some(d) => d.dm(),
                None => &env.dm,
            };
            strategy.decide_light(env, slot, &requests, &busy, &residual, dm, &mut self.rng)
        };
        debug_assert_eq!(decision.assignments.len(), requests.len());

        // New instance counts may free FIFO'd work immediately.
        let promoted = self.stations.on_decision(&decision.x);
        for (v, m, w) in promoted {
            self.start_service(v, m, w, now);
        }

        let alpha = env.cfg.controller.contention_alpha;
        let pending = std::mem::take(&mut self.pending);
        let mut still = Vec::new();
        for (qi, (id, local)) in pending.into_iter().enumerate() {
            let asn = match decision.assignments.get(qi).and_then(|a| *a) {
                Some(a) => a,
                None => {
                    still.push((id, local));
                    continue;
                }
            };
            // A fault-oblivious strategy may route onto a dead node; the
            // engine refuses and the work keeps waiting.
            if self.dynt.is_some() && !self.node_up[asn.node] {
                still.push((id, local));
                continue;
            }
            // Sampled contended service time — same draw semantics as the
            // slotted engine.
            let (proc_ms, critical, mb, arrive, obs_pre) = {
                let dm: &DistanceMatrix = match &self.dynt {
                    Some(d) => d.dm(),
                    None => &env.dm,
                };
                let t = &self.tasks[&id];
                let tt = &app.task_types[t.task_type];
                let spec = app.catalog.spec(tt.services[local]);
                let f = spec.rate.sample(&mut self.rng) / (asn.y as f64).powf(alpha);
                let payloads = t.parent_payloads(app, local);
                let &(pn, pd, mb) = payloads
                    .iter()
                    .max_by(|a, b| {
                        let la = a.1 + dm.latency(a.0, asn.node, a.2);
                        let lb = b.1 + dm.latency(b.0, asn.node, b.2);
                        la.partial_cmp(&lb).unwrap()
                    })
                    .unwrap();
                let arrive = pd + dm.latency(pn, asn.node, mb);
                let obs_pre = self.obs.is_some().then(|| {
                    crate::sim::critical_parent(app, t.task_type, local, &payloads, asn.node, dm)
                });
                (spec.workload_mb / f.max(1e-9), (pn, pd), mb, arrive, obs_pre)
            };
            // No surviving route from the payload to the chosen node:
            // keep waiting (links may recover; the age drop bounds it).
            if !arrive.is_finite() {
                still.push((id, local));
                continue;
            }
            let t = self.tasks.get_mut(&id).unwrap();
            if t.rerouted[local] {
                // A fault-cancelled execution has found a surviving
                // replica: recovered, not dropped.
                t.rerouted[local] = false;
                self.collector.record_reroute();
            }
            t.node[local] = Some(asn.node);
            t.token[local] += 1;
            let token = t.token[local];
            self.stations.note_assigned(asn.node, asn.light_idx);

            // Hop-by-hop transfer of the latest-arriving parent payload:
            // hops that analytically completed while the request waited
            // are skipped (the transfer overlapped the controller wait,
            // matching the slotted engine's `max(arrival, now)`).
            let (pn, pd) = critical;
            let mut hop_times = Vec::new();
            let mut cum = pd;
            let hops = match &self.dynt {
                Some(d) => d.hops(),
                None => &env.hops,
            };
            for h in hops.hops(pn, asn.node) {
                cum += h.latency(mb);
                if cum > now {
                    hop_times.push(cum);
                }
            }
            if hop_times.is_empty() {
                self.plans.insert(
                    (id, local),
                    TransferPlan {
                        node: asn.node,
                        light_idx: asn.light_idx,
                        y: asn.y,
                        proc_ms,
                        hop_times: vec![now],
                        next: 0,
                        token,
                    },
                );
                self.cal
                    .schedule(now, EventKind::StationJoin { task: id, local, token });
            } else {
                let first = hop_times[0];
                let single = hop_times.len() == 1;
                self.plans.insert(
                    (id, local),
                    TransferPlan {
                        node: asn.node,
                        light_idx: asn.light_idx,
                        y: asn.y,
                        proc_ms,
                        hop_times,
                        next: 0,
                        token,
                    },
                );
                let kind = if single {
                    EventKind::StationJoin { task: id, local, token }
                } else {
                    EventKind::HopDone { task: id, local, token }
                };
                self.cal.schedule(first, kind);
            }
            if let Some((from, _, _)) = obs_pre {
                if let Some(r) = self.rec() {
                    r.light_assigned(
                        id,
                        local,
                        token,
                        asn.node,
                        asn.y,
                        asn.light_idx,
                        from,
                        now,
                        arrive.max(now),
                    );
                }
            }
        }
        self.pending = still;
    }

    /// A fault-cancelled stage's backoff window closed: re-dispatch if it
    /// is still waiting (the per-tick rescan may have beaten us to it, or
    /// the task may have finished or been dropped meanwhile).
    fn handle_retry(&mut self, id: u64, local: usize, now: f64) {
        let ready = match self.tasks.get(&id) {
            Some(t) => t.stage_ready(&self.env.app, local),
            None => return,
        };
        if ready {
            self.dispatch_stage(id, local, now);
        }
    }

    /// Apply fault-schedule entry `idx` at its exact timestamp. Schedule
    /// entries sharing one timestamp pop consecutively (they are seeded
    /// first, in index order), so state changes are applied per event but
    /// the routing rebuild and the cancelled-stage re-dispatch run once
    /// per timestamp group — after its last entry.
    fn handle_fault(&mut self, idx: usize, now: f64) {
        let fev = self.faults.events()[idx];
        match fev.kind {
            FaultKind::NodeDown { node } => {
                self.node_up[node] = false;
                if let Some(d) = self.dynt.as_mut() {
                    d.apply_deferred(&fev.kind);
                }
                self.core_router.set_node_down(node);
                self.stations.fail_node(node);
                // Payloads in transit toward the dead station never land.
                let doomed: Vec<(u64, usize)> = self
                    .plans
                    .iter()
                    .filter(|(_, p)| p.node == node)
                    .map(|(&k, _)| k)
                    .collect();
                for k in &doomed {
                    self.plans.remove(k);
                }
                // Completed outputs resident on the node are destroyed
                // (permanent — recovery restores capacity, not data);
                // in-flight executions are cancelled and their stages
                // re-dispatch after the batch commit (dispatch drops
                // tasks whose inputs died with the node).
                let retry = self.opts.failover.retry;
                // Trace events collected during the cancellation walk and
                // applied after it (the recorder can't be borrowed while
                // `tasks` is): (task, stage, kind, backoff_until).
                let tracing = self.obs.as_ref().map_or(false, |o| o.trace.is_some());
                let mut trace_ev: Vec<(u64, usize, u8, f64)> = Vec::new();
                for (&id, t) in self.tasks.iter_mut() {
                    for local in 0..t.done.len() {
                        if t.done[local].is_some() {
                            if t.node[local] == Some(node) {
                                t.destroyed[local] = true;
                            }
                            continue;
                        }
                        if t.node[local] == Some(node) && t.dispatched[local] {
                            // Primary execution dies with the node. A live
                            // hedged standby is promoted in place: its
                            // token becomes the stage's live token, so its
                            // CoreDone completes the stage and the dead
                            // primary's event goes stale.
                            if let Some((hn, ht)) =
                                t.hedge[local].filter(|&(hn, _)| hn != node)
                            {
                                t.node[local] = Some(hn);
                                t.token[local] = ht;
                                t.hedge[local] = None;
                                self.collector.record_reroute();
                                if tracing {
                                    trace_ev.push((id, local, 0, 0.0));
                                }
                                continue;
                            }
                            t.dispatched[local] = false;
                            t.node[local] = None;
                            // Skip past any booked hedge token so a stale
                            // hedge event can never match a later dispatch.
                            t.token[local] =
                                t.token[local].max(t.hedge[local].map_or(0, |(_, ht)| ht)) + 1;
                            t.hedge[local] = None;
                            // Jittered exponential backoff, deterministic
                            // per (task, stage, attempt) — the engine RNG
                            // stream is never consumed.
                            t.attempts[local] += 1;
                            t.rerouted[local] = true;
                            t.retry_at[local] = now
                                + retry.backoff_ms(
                                    t.attempts[local],
                                    id ^ ((local as u64) << 40),
                                );
                            self.collector.record_retry();
                            self.fault_resets.push((id, local));
                            if tracing {
                                trace_ev.push((id, local, 1, t.retry_at[local]));
                            }
                        } else if t.hedge[local].map(|(hn, _)| hn) == Some(node) {
                            // The standby died; the primary continues.
                            t.hedge[local] = None;
                            if tracing {
                                trace_ev.push((id, local, 2, 0.0));
                            }
                        }
                    }
                }
                if !trace_ev.is_empty() {
                    // Sorted for determinism: the cancellation walk visits
                    // a HashMap in arbitrary order.
                    trace_ev.sort_by(|a, b| a.0.cmp(&b.0).then(a.1.cmp(&b.1)));
                    if let Some(r) = self.rec() {
                        for (tid, local, kind, until) in trace_ev {
                            match kind {
                                0 => r.hedge_promoted(tid, local, now),
                                1 => r.attempt_cancelled(tid, local, now, until),
                                _ => r.hedge_dropped(tid, local, now),
                            }
                        }
                    }
                }
            }
            FaultKind::NodeUp { node } => {
                self.node_up[node] = true;
                if let Some(d) = self.dynt.as_mut() {
                    d.apply_deferred(&fev.kind);
                }
                self.core_router.set_node_up(node, now);
            }
            FaultKind::CoreReplicaFail { node, core_idx } => {
                self.core_router.kill_instance(node, core_idx);
            }
            FaultKind::CoreReplicaRestart { node, core_idx } => {
                // Rejoin from the last checkpoint (fast clock) or cold.
                // While the node itself is down the restart is folded into
                // the node's own recovery instead.
                if self.node_up[node] {
                    let cp = self.opts.failover.checkpoint;
                    if let Some(ready_ms) = self.core_router.rejoin(
                        node,
                        core_idx,
                        now,
                        cp.restore_ms,
                        cp.cold_start_ms,
                    ) {
                        self.collector.record_restore();
                        if let Some(r) = self.rec() {
                            r.restore(node, now, ready_ms);
                        }
                    }
                }
            }
            link_event => {
                if let Some(d) = self.dynt.as_mut() {
                    d.apply_deferred(&link_event);
                }
            }
        }
        let group_continues = self
            .faults
            .events()
            .get(idx + 1)
            .map_or(false, |next| next.time_ms == fev.time_ms);
        if !group_continues {
            if let Some(d) = self.dynt.as_mut() {
                d.commit();
            }
            // Sorted for determinism: calendar sequence numbers are
            // assigned in schedule order, and the cancellation loop above
            // walks a HashMap.
            let mut resets = std::mem::take(&mut self.fault_resets);
            resets.sort_unstable();
            for (id, local) in resets {
                // Re-dispatch after the backoff window, not immediately:
                // the jittered delay spreads the retry burst a zone
                // outage would otherwise synchronize.
                let at = self.tasks[&id].retry_at[local].max(now);
                self.cal.schedule(at, EventKind::Retry { task: id, local });
            }
        }
    }

    /// Slot boundary: virtual-queue aging, drop checks, per-slot cost
    /// charging, queue-depth telemetry, and a decision retry for work the
    /// controller previously declined.
    fn handle_tick(&mut self, slot: usize, now: f64) {
        // Periodic core-state checkpoints (only meaningful under faults:
        // the stamps exist to make replica restarts fast). Same cadence
        // arithmetic as the slotted engine.
        let cp = self.opts.failover.checkpoint;
        if self.dynt.is_some() && cp.enabled() {
            let every = (cp.period_ms / self.opts.slot_ms).ceil().max(1.0) as usize;
            if slot % every == 0 {
                self.core_router.checkpoint(now);
            }
        }
        let slot_end = now + self.opts.slot_ms;
        let mut ids: Vec<u64> = self.tasks.keys().cloned().collect();
        ids.sort_unstable();
        for id in ids {
            let (age, deadline) = {
                let t = &self.tasks[&id];
                (slot_end - t.arrival_ms, t.deadline_ms)
            };
            if age > self.opts.drop_after_deadlines * deadline {
                let t = self.tasks.remove(&id).unwrap();
                self.finish_task(id, t, None);
            } else {
                self.queues.update(id, age, deadline);
            }
        }
        {
            let tasks = &self.tasks;
            self.pending.retain(|(id, _)| tasks.contains_key(id));
        }
        // Under faults a core stage can fail to route (all replicas down
        // or unreachable): it stays ready-but-undispatched and is retried
        // each tick until a replica or route comes back.
        if self.dynt.is_some() {
            let app = &self.env.app;
            let mut retry: Vec<(u64, usize)> = Vec::new();
            for (&id, t) in &self.tasks {
                let tt = &app.task_types[t.task_type];
                for local in 0..tt.dag.len() {
                    if t.stage_ready(app, local) {
                        retry.push((id, local));
                    }
                }
            }
            retry.sort_unstable();
            for (id, local) in retry {
                self.dispatch_stage(id, local, now);
            }
        }
        // Per-slot light cost: maintenance on busy instance-groups,
        // parallelism on in-flight work (eq. 7 under continuous time).
        let x_now = self.stations.busy_matrix();
        let y_now = self.stations.in_flight_matrix();
        self.costs
            .charge_light_slot(&x_now, &y_now, &self.light_dp, &self.light_mt, &self.light_pl);
        self.collector.record_queue_depth(self.pending.len() + self.stations.waiting_total());
        // Per-tick telemetry snapshot (observer-gated, read-only).
        if self.obs.as_ref().map_or(false, |o| o.metrics.is_some()) {
            let env = self.env;
            let nl = env.app.catalog.num_light();
            let mut backlog = vec![0usize; nl];
            for &(pid, plocal) in &self.pending {
                if let Some(t) = self.tasks.get(&pid) {
                    let ms_id = env.app.task_types[t.task_type].services[plocal];
                    if let Some(m) = self.light_idx_of[ms_id.0] {
                        backlog[m] += 1;
                    }
                }
            }
            let committed_y: Vec<u32> = (0..nl)
                .map(|m| y_now.iter().map(|row| row[m]).max().unwrap_or(0))
                .collect();
            let busy_groups: u32 = x_now.iter().flat_map(|r| r.iter()).sum();
            let node_util = x_now.iter().filter(|row| row.iter().any(|&b| b > 0)).count()
                as f64
                / x_now.len().max(1) as f64;
            let vq = self.queues.total_backlog();
            if let Some(o) = self.obs.as_deref_mut() {
                o.sample_slot(now, &backlog, &committed_y, busy_groups, node_util, vq, &env.gtable);
            }
        }
        if !self.pending.is_empty() {
            self.request_decide(now);
        }
    }
}

/// Run one DES trial of `strategy` over a recorded trace.
pub fn run_des_trial(
    env: &SimEnv,
    strategy: &mut dyn Strategy,
    seed: u64,
    opts: &DesOptions,
    trace: &Trace,
) -> TrialMetrics {
    let none = FaultSchedule::none();
    run_des_inner(env, strategy, seed, opts, trace, false, &none, None).0
}

/// Like [`run_des_trial`], additionally returning per-task execution
/// records (stage nodes and completion times) for validation tooling.
pub fn run_des_trial_recorded(
    env: &SimEnv,
    strategy: &mut dyn Strategy,
    seed: u64,
    opts: &DesOptions,
    trace: &Trace,
) -> (TrialMetrics, Vec<TaskRecord>) {
    let none = FaultSchedule::none();
    run_des_inner(env, strategy, seed, opts, trace, true, &none, None)
}

/// Run one DES trial while replaying a [`FaultSchedule`] at its exact
/// event timestamps. With an empty schedule this is bit-identical to
/// [`run_des_trial`].
pub fn run_des_trial_faulted(
    env: &SimEnv,
    strategy: &mut dyn Strategy,
    seed: u64,
    opts: &DesOptions,
    trace: &Trace,
    faults: &FaultSchedule,
) -> TrialMetrics {
    run_des_inner(env, strategy, seed, opts, trace, false, faults, None).0
}

/// Like [`run_des_trial_faulted`], with an [`Observer`] attached: spans,
/// per-tick telemetry, and blame-attribution inputs are recorded without
/// consuming engine RNG or reordering the calendar, so the returned
/// metrics are identical to the unobserved run on the same inputs
/// (asserted by the zero-overhead gate test).
pub fn run_des_trial_observed(
    env: &SimEnv,
    strategy: &mut dyn Strategy,
    seed: u64,
    opts: &DesOptions,
    trace: &Trace,
    faults: &FaultSchedule,
    obs: &mut Observer,
) -> TrialMetrics {
    run_des_inner(env, strategy, seed, opts, trace, false, faults, Some(obs)).0
}

#[allow(clippy::too_many_arguments)]
fn run_des_inner(
    env: &SimEnv,
    strategy: &mut dyn Strategy,
    seed: u64,
    opts: &DesOptions,
    trace: &Trace,
    record: bool,
    faults: &FaultSchedule,
    obs: Option<&mut Observer>,
) -> (TrialMetrics, Vec<TaskRecord>) {
    let app = &env.app;
    let cfg = &env.cfg;
    let mut rng = Xoshiro256::seed_from(seed ^ 0xDE5E_7E17);
    let gen = WorkloadGenerator::new(
        cfg,
        app,
        &env.topo,
        &mut Xoshiro256::seed_from(env.users_seed),
    );

    // Static tier — identical to the slotted engine.
    let scores = QosScores::compute(
        app,
        &env.topo,
        &env.dm,
        gen.users(),
        &ScoreParams::from_config(&cfg.controller),
    );
    let placement = strategy.place_core(env, &scores, &mut rng);
    let core_router = CoreRouter::new(&placement.instances);
    let residual_static = placement.residual_capacity(app, &env.topo);

    let mut costs = CostBook::new();
    let core_dp: Vec<f64> = env.core_costs.iter().map(|c| c.0).collect();
    let core_mt: Vec<f64> = env.core_costs.iter().map(|c| c.1).collect();
    costs.charge_core_placement(&placement.instances, &core_dp, &core_mt, opts.slots);

    let nv = env.topo.num_nodes();
    let nl = app.catalog.num_light();
    let max_y = env.gtable.max_parallelism().max(1);
    let mut collector = MetricsCollector::new();
    collector.enable_service_obs(nl);

    let light_idx_of: Vec<Option<usize>> = (0..app.catalog.len())
        .map(|m| app.catalog.light_index(crate::microservice::MsId(m)))
        .collect();

    let has_faults = !faults.is_empty();
    let mut d = Des {
        env,
        opts,
        faults,
        dynt: has_faults.then(|| DynamicTopology::new(&env.topo, 1.0)),
        node_up: vec![true; nv],
        fault_resets: Vec::new(),
        rng,
        cal: Calendar::new(),
        tasks: HashMap::new(),
        plans: HashMap::new(),
        queues: VirtualQueues::new(cfg.controller.zeta),
        pending: Vec::new(),
        decide_scheduled: false,
        stations: LightStations::new(nv, nl, max_y, opts.batching),
        core_router,
        residual_static,
        collector,
        costs,
        light_idx_of,
        light_dp: env.light_costs.iter().map(|c| c.0).collect(),
        light_mt: env.light_costs.iter().map(|c| c.1).collect(),
        light_pl: env.light_costs.iter().map(|c| c.2).collect(),
        horizon_ms: opts.slots as f64 * opts.slot_ms,
        record,
        records: Vec::new(),
        obs,
    };

    // Seed the calendar. Fault events go in first so that, at equal
    // timestamps, the fault applies before the slot tick and before
    // arrivals — matching the slotted engine's start-of-slot application.
    for (idx, fev) in faults.events().iter().enumerate() {
        if fev.time_ms <= d.horizon_ms {
            d.cal.schedule(fev.time_ms, EventKind::Fault { idx });
        }
    }
    // Trace arrivals (slots beyond the horizon are ignored) and one
    // controller tick per slot.
    for slot in 0..opts.slots {
        let t = slot as f64 * opts.slot_ms;
        for a in trace.slot(slot) {
            d.cal.schedule(t, EventKind::Arrival { arrival: a.clone() });
        }
        d.cal.schedule(t, EventKind::Tick { slot });
    }

    while let Some(ev) = d.cal.pop() {
        if ev.time_ms > d.horizon_ms {
            break;
        }
        let now = ev.time_ms;
        match ev.kind {
            EventKind::Arrival { arrival } => d.handle_arrival(arrival, now),
            EventKind::UplinkDone { task } => d.handle_uplink_done(task, now),
            EventKind::HopDone { task, local, token } => d.handle_hop_done(task, local, token),
            EventKind::StationJoin { task, local, token } => {
                d.handle_station_join(task, local, token, now)
            }
            EventKind::CoreDone {
                task,
                local,
                node,
                token,
            } => {
                // Stale when the dispatch was cancelled by a fault.
                let valid = d
                    .tasks
                    .get(&task)
                    .map_or(false, |t| t.token[local] == token && t.done[local].is_none());
                if valid {
                    d.handle_stage_done(task, local, node, now)
                }
            }
            EventKind::LightDone {
                task,
                local,
                node,
                light_idx,
                y,
                join_ms,
                gen,
            } => d.handle_light_done(task, local, node, light_idx, y, join_ms, gen, now),
            EventKind::Decide => d.handle_decide(strategy, now),
            EventKind::Tick { slot } => d.handle_tick(slot, now),
            EventKind::BatchFlush {
                node,
                light_idx,
                epoch,
            } => d.handle_batch_flush(node, light_idx, epoch, now),
            EventKind::Fault { idx } => d.handle_fault(idx, now),
            EventKind::Retry { task, local } => d.handle_retry(task, local, now),
        }
    }

    if std::env::var_os("FMEDGE_DEBUG").is_some() {
        eprintln!(
            "[des] events={} unfinished={} pending={} station_wait={}",
            d.cal.processed(),
            d.tasks.len(),
            d.pending.len(),
            d.stations.waiting_total()
        );
    }

    // Horizon end: everything still in flight is incomplete.
    let mut ids: Vec<u64> = d.tasks.keys().cloned().collect();
    ids.sort_unstable();
    for id in ids {
        let t = d.tasks.remove(&id).unwrap();
        d.finish_task(id, t, None);
    }
    let _ = placement.objective;
    let Des {
        collector,
        costs,
        records,
        queues,
        ..
    } = d;
    debug_assert!(
        queues.is_empty(),
        "virtual-queue leak: {} entries after drain",
        queues.len()
    );
    let mut metrics = collector.finish(&costs);
    metrics.vq_residual = queues.len();
    (metrics, records)
}
