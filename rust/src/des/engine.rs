//! The continuous-time engine: replays a [`Trace`] through real queues.
//!
//! Where the slotted engine advances in `slot_ms` quanta and *assumes*
//! the effective-capacity bound for light-service delays, this engine is
//! a classic discrete-event simulation: a monotone calendar of arrival /
//! uplink / hop-transfer / service events, per-instance FIFO serialization
//! for core services (via [`CoreRouter`]'s busy clocks), and per-replica
//! FIFO stations with *sampled* service times for light services. The
//! deployment [`Strategy`] runs unmodified: it is invoked event-driven —
//! immediately when light work becomes ready, plus at every slot boundary
//! — and its instance decisions set the station concurrency caps.
//!
//! Semantics shared with the slotted engine (so paired traces compare
//! apples to apples): transfers follow the [`crate::routing::HopTable`] routes whose
//! summed latency equals `DistanceMatrix::latency` exactly; light service
//! times are drawn as `a_m / (f / y^alpha)` at the controller's committed
//! parallelism; busy accounting is `ceil(in_flight / Y)` instance groups.
//! What differs is what the paper's bound is *about*: here tasks may
//! actually wait in FIFO queues, and every light execution yields a
//! measured sojourn `(y, wait + service)` for `des::validate`.
//!
//! The hot-loop storage is metro-scale (see [`super::soa`]): tasks live
//! in a [`TaskArena`] (struct-of-arrays, O(1) id→slot), transfer plans
//! in a generation-stamped [`PlanSlab`], and the calendar is the radix
//! queue from [`super::calendar`]. All of it sits in a [`DesArena`] that
//! can be reused across trials (clear, don't drop) — `exp::run_cells`
//! does exactly that — with reuse guaranteed bit-identical to a fresh
//! arena. The engine itself is generic over [`EventCalendar`], so the
//! cross-calendar tests replay the same trial on the reference heap.

use crate::config::NUM_RESOURCES;
use crate::controller::LightRequest;
use crate::coordinator::{BatchPolicy, FailoverPolicy};
use crate::faults::{DynamicTopology, FaultKind, FaultSchedule};
use crate::metrics::{CostBook, MetricsCollector, TaskOutcome, TrialMetrics};
use crate::microservice::MsClass;
use crate::obs::{Observer, TraceRecorder};
use crate::placement::{QosScores, ScoreParams};
use crate::routing::{CoreRouter, DistanceMatrix};
use crate::rng::Xoshiro256;
use crate::sim::{SimEnv, SimOptions, Strategy};
use crate::workload::{Trace, WorkloadGenerator};

use super::calendar::{Calendar, EventCalendar, EventKind};
use super::soa::{PlanSlab, TaskArena};
use super::stations::{Joined, LightStations, Waiting};

/// DES run options.
#[derive(Clone, Debug)]
pub struct DesOptions {
    /// Horizon in slots (the calendar runs to `slots * slot_ms`).
    pub slots: usize,
    /// Controller tick period (ms) — the strategy's decision cadence.
    pub slot_ms: f64,
    /// Tasks unfinished this many deadlines past their own are dropped.
    pub drop_after_deadlines: f64,
    /// Optional station batching: arrivals at a light station accumulate
    /// and flush on size or (simulated) age.
    pub batching: Option<BatchPolicy>,
    /// Retry/backoff + checkpoint policy replayed under faults — the
    /// same object the slotted engine and the serving coordinator use,
    /// so agreement extends to retried executions. Inert without faults.
    pub failover: FailoverPolicy,
    /// Stream metrics instead of retaining them: per-completion
    /// histogram/counter accumulation replaces the per-task outcome and
    /// per-execution sojourn buffers, so collector memory stays flat at
    /// 10^6 users. Aggregate `TrialMetrics` fields are unchanged;
    /// raw-sample fields (`latencies_ms`, `ServiceObs::samples`) come
    /// back empty and percentile/validation queries fall back to the
    /// streamed histograms. Default off (bit-identical legacy output).
    pub streaming: bool,
    /// Elastic replica pools + shared-rate contention (EXPERIMENTS
    /// §P10): light stations become processor-sharing pools whose warm
    /// replica counts a [`crate::pool::PoolManager`] scales per tick,
    /// and in-flight completions are rescheduled as occupancy changes.
    /// `None` (the default) never enters the pool path — every number
    /// is byte-identical to the fixed-capacity engine.
    pub pool: Option<crate::pool::PoolConfig>,
}

impl DesOptions {
    pub fn from_sim(o: &SimOptions) -> Self {
        DesOptions {
            slots: o.slots,
            slot_ms: o.slot_ms,
            drop_after_deadlines: o.drop_after_deadlines,
            batching: None,
            failover: o.failover,
            streaming: false,
            pool: o.pool.clone(),
        }
    }

    pub fn from_config(cfg: &crate::config::ExperimentConfig) -> Self {
        Self::from_sim(&SimOptions::from_config(cfg))
    }
}

/// Per-task execution record (optional output for validation tooling).
#[derive(Clone, Debug)]
pub struct TaskRecord {
    pub id: u64,
    pub task_type: usize,
    pub arrival_ms: f64,
    pub deadline_ms: f64,
    /// Completion time of each local DAG stage (ms, absolute).
    pub stage_done: Vec<Option<f64>>,
    /// Network node that executed each stage.
    pub stage_node: Vec<Option<usize>>,
    /// End-to-end latency; `None` for dropped/unfinished tasks.
    pub latency_ms: Option<f64>,
}

/// Reusable engine storage: the task arena, transfer-plan slab, event
/// calendar, stations, and scratch buffers, all of which retain their
/// allocations across trials. `exp::run_cells` keeps one per worker
/// cell; reuse is bit-identical to a fresh arena (every trial starts
/// with a full reset).
#[derive(Default)]
pub struct DesArena<C = Calendar> {
    tasks: TaskArena,
    plans: PlanSlab,
    cal: C,
    pending: Vec<(u64, usize)>,
    stations: LightStations,
    records: Vec<TaskRecord>,
    busy_scratch: Vec<Vec<u32>>,
    y_scratch: Vec<Vec<u32>>,
    /// Shared-rate run bookkeeping for pooled trials; untouched (and
    /// never read) when `DesOptions::pool` is off.
    shared_rate: crate::pool::SharedRate,
}

impl<C: Default> DesArena<C> {
    pub fn new() -> Self {
        Self::default()
    }
}

impl<C: EventCalendar> DesArena<C> {
    /// Reset to the empty state, retaining allocations. Called at the
    /// top of every trial, so a reused arena and a fresh one are
    /// trivially indistinguishable.
    fn reset(&mut self) {
        self.tasks.clear();
        self.plans.clear();
        self.cal.clear();
        self.pending.clear();
        self.records.clear();
        // `stations` is re-dimensioned inside the run (needs nv/nl);
        // the scratch matrices are overwritten before every read.
    }
}

struct Des<'a, C: EventCalendar> {
    env: &'a SimEnv,
    opts: &'a DesOptions,
    /// The replayed fault schedule ([`EventKind::Fault`] indexes into it).
    faults: &'a FaultSchedule,
    /// Fault-aware network view; `None` without fault injection (the
    /// fault-free path stays bit-identical to pre-fault builds).
    dynt: Option<DynamicTopology>,
    node_up: Vec<bool>,
    /// Stages cancelled by the current same-timestamp fault batch,
    /// re-dispatched once the batch's routing rebuild has committed.
    fault_resets: Vec<(u64, usize)>,
    rng: Xoshiro256,
    cal: &'a mut C,
    t: &'a mut TaskArena,
    plans: &'a mut PlanSlab,
    /// Virtual-queue floor (`VirtualQueues::new(zeta)` semantics; the
    /// queue values themselves live in the arena's `vq` column).
    zeta: f64,
    /// Light work awaiting a controller assignment: `(task, local)`.
    pending: &'a mut Vec<(u64, usize)>,
    decide_scheduled: bool,
    stations: &'a mut LightStations,
    core_router: CoreRouter,
    residual_static: Vec<[f64; NUM_RESOURCES]>,
    collector: MetricsCollector,
    costs: CostBook,
    light_idx_of: Vec<Option<usize>>,
    light_dp: Vec<f64>,
    light_mt: Vec<f64>,
    light_pl: Vec<f64>,
    horizon_ms: f64,
    record: bool,
    records: &'a mut Vec<TaskRecord>,
    /// Optional observability handle; `None` leaves every hook site on
    /// the exact untraced code path (no RNG, no event reordering).
    obs: Option<&'a mut Observer>,
    busy_scratch: &'a mut Vec<Vec<u32>>,
    y_scratch: &'a mut Vec<Vec<u32>>,
    /// Elastic pools (§P10); `None` keeps every handler on the exact
    /// fixed-capacity station path.
    pool_mgr: Option<crate::pool::PoolManager>,
    sr: &'a mut crate::pool::SharedRate,
    /// Member-id scratch for shared-rate reschedules.
    pool_scratch: Vec<u32>,
    /// Ready-time scratch for `PoolManager::step`.
    pool_grown: Vec<f64>,
}

impl<'a, C: EventCalendar> Des<'a, C> {
    /// The span recorder, if an observer with tracing is attached.
    fn rec(&mut self) -> Option<&mut TraceRecorder> {
        self.obs.as_deref_mut().and_then(|o| o.trace.as_mut())
    }

    fn request_decide(&mut self, now: f64) {
        if !self.decide_scheduled {
            self.decide_scheduled = true;
            self.cal.schedule(now, EventKind::Decide);
        }
    }

    /// Shared readiness rule over the arena's span slices.
    fn stage_ready(&self, slot: u32, local: usize) -> bool {
        let r = self.t.span(slot);
        crate::sim::stage_ready(
            &self.env.app,
            self.t.task_type[slot as usize] as usize,
            &self.t.done[r.clone()],
            &self.t.dispatched[r],
            local,
        )
    }

    /// Record the task's outcome (and optional execution record) and
    /// free its arena slot.
    fn finish_task(&mut self, id: u64, done_ms: Option<f64>) {
        if let Some(r) = self.rec() {
            r.task_finished(id, done_ms);
        }
        let slot = self.t.slot(id).expect("finishing a task that is not live");
        let i = slot as usize;
        let arrival_ms = self.t.arrival_ms[i];
        let deadline_ms = self.t.deadline_ms[i];
        let latency_ms = done_ms.map(|d| d - arrival_ms);
        self.collector.record(TaskOutcome {
            task_id: id,
            latency_ms,
            deadline_ms,
        });
        if self.record {
            let r = self.t.span(slot);
            self.records.push(TaskRecord {
                id,
                task_type: self.t.task_type[i] as usize,
                arrival_ms,
                deadline_ms,
                stage_done: self.t.done[r.clone()].to_vec(),
                stage_node: self.t.node[r].to_vec(),
                latency_ms,
            });
        }
        self.t.remove(id);
    }

    fn handle_arrival(&mut self, a: crate::workload::TaskArrival, now: f64) {
        let env = self.env;
        let app = &env.app;
        // A trace recorded under a different application would silently
        // skew every paired metric — fail loudly instead (the slotted
        // engine panics on the same mismatch).
        assert!(
            a.task_type.0 < app.task_types.len(),
            "trace task {} has task type {} but the application defines {}",
            a.id.0,
            a.task_type.0,
            app.task_types.len()
        );
        let n = app.task_types[a.task_type.0].dag.len();
        let deadline_ms = app.task_types[a.task_type.0].deadline_ms;
        self.t.insert(
            a.id.0,
            a.task_type.0,
            now,
            deadline_ms,
            a.uplink_delay_ms,
            a.ed,
            n,
            self.zeta,
        );
        let sink = app.task_types[a.task_type.0]
            .dag
            .sink()
            .unwrap_or(n.saturating_sub(1));
        if let Some(r) = self.rec() {
            r.admit(a.id.0, a.task_type.0, n, sink, now, deadline_ms, a.uplink_delay_ms);
        }
        self.cal
            .schedule(now + a.uplink_delay_ms, EventKind::UplinkDone { task: a.id.0 });
    }

    fn handle_uplink_done(&mut self, id: u64, now: f64) {
        let nst = match self.t.slot(id) {
            Some(s) => self.t.nstages(s),
            None => return,
        };
        // Check-then-dispatch per stage: a dispatch only flips its own
        // stage's `dispatched` flag (or drops the task, ending the
        // walk), so interleaving is equivalent to an upfront ready list.
        for local in 0..nst {
            let ready = match self.t.slot(id) {
                Some(s) => self.stage_ready(s, local),
                None => break,
            };
            if ready {
                self.dispatch_stage(id, local, now);
            }
        }
    }

    /// Dispatch a ready stage: core stages route immediately to the
    /// completion-minimizing placed instance (FIFO per instance via the
    /// router's busy clocks); light stages enter the controller queue.
    /// Under faults, a stage whose input payload died with its node drops
    /// the task (unrecoverable casualty).
    fn dispatch_stage(&mut self, id: u64, local: usize, now: f64) {
        let env = self.env;
        let app = &env.app;
        let slot = match self.t.slot(id) {
            Some(s) => s,
            None => return,
        };
        let i = slot as usize;
        let task_type = self.t.task_type[i] as usize;
        let tt = &app.task_types[task_type];
        let ms_id = tt.services[local];
        let spec = app.catalog.spec(ms_id);
        let is_core = spec.class == MsClass::Core;
        let proc_ms = spec.mean_proc_delay();
        let r = self.t.span(slot);
        let payloads = crate::sim::parent_payloads(
            app,
            task_type,
            &self.t.done[r.clone()],
            &self.t.node[r.clone()],
            self.t.ed[i] as usize,
            self.t.arrival_ms[i] + self.t.uplink_ms[i],
            local,
        );
        if self.dynt.is_some() {
            // Destroyed inputs are unrecoverable; a down ED merely delays
            // the source stage (the device retains the user payload).
            if crate::sim::stage_inputs_destroyed(app, task_type, &self.t.destroyed[r.clone()], local)
            {
                self.collector.record_fault_drop();
                self.finish_task(id, None);
                return;
            }
            if !self.node_up[self.t.ed[i] as usize] && tt.dag.parents(local).is_empty() {
                return; // retried at the next tick once the ED recovers
            }
            if now < self.t.retry_at[r.start + local] {
                return; // backoff window; the Retry event re-dispatches
            }
        }
        if is_core {
            let ci = app
                .catalog
                .core_ids()
                .iter()
                .position(|&c| c == ms_id)
                .expect("core id");
            let dm = match &self.dynt {
                Some(d) => d.dm(),
                None => &env.dm,
            };
            if let Some(asn) = self
                .core_router
                .route_multi(ci, &payloads, proc_ms, now, dm)
            {
                let bl = r.start + local;
                // Hedged second attempt: a stage that already lost one
                // execution to a fault and is near its deadline books a
                // standby replica on a *different* node; it is promoted
                // if the primary's node dies mid-execution.
                let hedge_asn = if self.dynt.is_some() {
                    let slack = self.t.arrival_ms[i] + self.t.deadline_ms[i] - now;
                    if self.t.rerouted[bl]
                        && self
                            .opts
                            .failover
                            .retry
                            .should_hedge(slack, self.t.deadline_ms[i])
                    {
                        self.core_router
                            .route_multi(ci, &payloads, proc_ms, now, dm)
                            .filter(|h| h.node != asn.node)
                    } else {
                        None
                    }
                } else {
                    None
                };
                // Critical-parent span data must be derived while the
                // routed dm view is still borrowed (it lives in self).
                let trace_pre = self.obs.is_some().then(|| {
                    let primary = crate::sim::critical_parent(
                        app, task_type, local, &payloads, asn.node, dm,
                    );
                    let hedge = hedge_asn.as_ref().map(|h| {
                        crate::sim::critical_parent(
                            app, task_type, local, &payloads, h.node, dm,
                        )
                    });
                    (primary, hedge)
                });
                if self.t.rerouted[bl] {
                    self.t.rerouted[bl] = false;
                    self.collector.record_reroute();
                }
                self.t.dispatched[bl] = true;
                self.t.node[bl] = Some(asn.node);
                self.t.token[bl] += 1;
                let token = self.t.token[bl];
                self.cal.schedule(
                    asn.done_ms,
                    EventKind::CoreDone {
                        task: id,
                        local,
                        node: asn.node,
                        token,
                    },
                );
                if let Some(((from, ready, arrive), _)) = trace_pre {
                    if let Some(rr) = self.rec() {
                        rr.core_dispatched(
                            id,
                            local,
                            token,
                            asn.node,
                            from,
                            ready,
                            arrive,
                            asn.start_ms,
                        );
                    }
                }
                if let Some(h) = hedge_asn {
                    // The hedge carries token + 1; only a promotion (the
                    // primary's node dying) makes it the live token.
                    let htoken = token + 1;
                    self.t.hedge[bl] = Some((h.node, htoken));
                    self.collector.record_hedge();
                    self.cal.schedule(
                        h.done_ms,
                        EventKind::CoreDone {
                            task: id,
                            local,
                            node: h.node,
                            token: htoken,
                        },
                    );
                    if let Some((_, Some((from, ready, arrive)))) = trace_pre {
                        if let Some(rr) = self.rec() {
                            rr.hedge_dispatched(
                                id,
                                local,
                                htoken,
                                h.node,
                                from,
                                ready,
                                arrive,
                                h.start_ms,
                            );
                        }
                    }
                }
            }
            // No instance: every replica may be down or unreachable under
            // faults — the stage stays undispatched and is retried when
            // the next decision or recovery comes around (see tick).
        } else {
            self.t.dispatched[r.start + local] = true;
            self.pending.push((id, local));
            if let Some(rr) = self.rec() {
                rr.light_pending(id, local, now);
            }
            self.request_decide(now);
        }
    }

    /// A stage finished: record it, complete the task at the sink, and
    /// dispatch any children that became ready.
    fn handle_stage_done(&mut self, id: u64, local: usize, node: usize, now: f64) {
        let env = self.env;
        let app = &env.app;
        let slot = match self.t.slot(id) {
            Some(s) => s,
            None => return, // dropped while executing
        };
        let task_type = self.t.task_type[slot as usize] as usize;
        let bl = self.t.span(slot).start + local;
        self.t.done[bl] = Some(now);
        self.t.node[bl] = Some(node);
        if let Some(r) = self.rec() {
            r.stage_done(id, local, now);
        }
        if app.task_types[task_type].dag.sink() == Some(local) {
            self.finish_task(id, Some(now));
            return;
        }
        let kids = app.task_types[task_type].dag.children(local);
        for &c in kids.iter() {
            let ready = match self.t.slot(id) {
                Some(s) => self.stage_ready(s, c),
                None => break, // dropped by an earlier child's dispatch
            };
            if ready {
                self.dispatch_stage(id, c, now);
            }
        }
    }

    /// Begin serving `w` at station `(v, m)`: completion scheduled after
    /// its sampled service time, stamped with the station's current
    /// outage generation.
    fn start_service(&mut self, v: usize, m: usize, w: Waiting, now: f64) {
        if let Some(r) = self.rec() {
            r.light_started(w.task, w.local, now);
        }
        let gen = self.stations.gen(v, m);
        self.cal.schedule(
            now + w.proc_ms,
            EventKind::LightDone {
                task: w.task,
                local: w.local,
                node: v,
                light_idx: m,
                y: w.y,
                join_ms: w.join_ms,
                gen,
            },
        );
    }

    fn handle_hop_done(&mut self, plan: u32, pgen: u32) {
        if !self.plans.is_live(plan, pgen) {
            return; // stale event from a cancelled dispatch
        }
        let p = plan as usize;
        self.plans.next[p] += 1;
        let i = self.plans.next[p] as usize;
        debug_assert!(i < self.plans.hop_times[p].len());
        let t = self.plans.hop_times[p][i];
        let kind = if i + 1 == self.plans.hop_times[p].len() {
            EventKind::StationJoin { plan, pgen }
        } else {
            EventKind::HopDone { plan, pgen }
        };
        self.cal.schedule(t, kind);
    }

    fn handle_station_join(&mut self, plan: u32, pgen: u32, now: f64) {
        if !self.plans.is_live(plan, pgen) {
            return; // stale event from a cancelled dispatch
        }
        let p = plan as usize;
        let id = self.plans.task[p];
        let local = self.plans.local[p] as usize;
        let node = self.plans.node[p] as usize;
        let light_idx = self.plans.light_idx[p] as usize;
        let y = self.plans.y[p];
        let proc_ms = self.plans.proc_ms[p];
        self.plans.remove(plan);
        if self.pool_mgr.is_some() {
            // Pooled trial: the payload joins processor sharing (the
            // stations never booked a commitment, so a dropped task
            // simply never joins).
            if self.t.contains(id) {
                self.pool_join(id, local, node, light_idx, y, proc_ms, now);
            }
            return;
        }
        if !self.t.contains(id) {
            // Dropped mid-transfer: never joins, release the commitment.
            self.stations.abort_assignment(node, light_idx);
            return;
        }
        let w = Waiting {
            task: id,
            local,
            proc_ms,
            y,
            join_ms: now,
        };
        match self.stations.join(node, light_idx, w, now) {
            Joined::Start(list) => {
                for w in list {
                    self.start_service(node, light_idx, w, now);
                }
            }
            Joined::Queued => {}
            Joined::Batched(Some((t, epoch))) => {
                self.cal.schedule(
                    t,
                    EventKind::BatchFlush {
                        node,
                        light_idx,
                        epoch,
                    },
                );
            }
            Joined::Batched(None) => {}
        }
    }

    fn handle_batch_flush(&mut self, node: usize, light_idx: usize, epoch: u64, now: f64) {
        let started = self.stations.age_flush(node, light_idx, epoch, now);
        for w in started {
            self.start_service(node, light_idx, w, now);
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn handle_light_done(
        &mut self,
        id: u64,
        local: usize,
        node: usize,
        light_idx: usize,
        y: u32,
        join_ms: f64,
        gen: u64,
        now: f64,
    ) {
        if self.stations.gen(node, light_idx) != gen {
            return; // the execution died with its node
        }
        // The measured quantity the g-bound is about: wait + service.
        self.collector.record_sojourn(light_idx, y, now - join_ms);
        if let Some(next) = self.stations.complete(node, light_idx) {
            self.start_service(node, light_idx, next, now);
        }
        self.handle_stage_done(id, local, node, now);
    }

    /// Recompute station `(v, m)`'s shared-rate speed for `replicas`
    /// warm replicas and reschedule every member's completion at its new
    /// ETA (superseded `PoolDone` events go stale via the bumped token).
    /// Caller settles the station to `now` first. A stalled station
    /// (zero replicas) schedules nothing — the next warm-up or policy
    /// step picks its members back up.
    fn pool_resched(&mut self, v: usize, m: usize, now: f64, replicas: u32) {
        self.sr.rebalance(v, m, replicas);
        let mut tmp = std::mem::take(&mut self.pool_scratch);
        tmp.clear();
        tmp.extend_from_slice(self.sr.members(v, m));
        for &run in tmp.iter() {
            let rt = self.sr.bump(run);
            if let Some(eta) = self.sr.eta(run) {
                self.cal
                    .schedule(now + eta, EventKind::PoolDone { run, rt });
            }
        }
        self.pool_scratch = tmp;
    }

    /// [`Self::pool_resched`] at the pool manager's current warm count.
    fn pool_rebalance(&mut self, v: usize, m: usize, now: f64) {
        let replicas = self.pool_mgr.as_ref().map_or(0, |pm| pm.active(v, m));
        self.pool_resched(v, m, now, replicas);
    }

    /// Pooled station join: the payload enters processor sharing
    /// immediately (no FIFO wait — contention shows up as stretched
    /// service instead), which reschedules every co-located completion.
    fn pool_join(
        &mut self,
        id: u64,
        local: usize,
        node: usize,
        light_idx: usize,
        y: u32,
        proc_ms: f64,
        now: f64,
    ) {
        if let Some(r) = self.rec() {
            r.light_started(id, local, now);
        }
        self.sr.settle(node, light_idx, now);
        self.sr.join(id, local, node, light_idx, y, now, proc_ms);
        self.pool_rebalance(node, light_idx, now);
    }

    /// A pooled execution's completion event landed (and is still the
    /// run's live schedule): record the measured sojourn, shrink the
    /// station's occupancy — speeding up the survivors — and walk the
    /// DAG exactly like a station completion.
    fn handle_pool_done(&mut self, run: u32, rt: u32, now: f64) {
        if !self.sr.is_live(run, rt) {
            return; // rescheduled or killed with its node
        }
        let (v, m) = self.sr.station_of(run);
        self.sr.settle(v, m, now);
        let (id, local, node, light_idx, y, join_ms) = self.sr.complete(run);
        self.collector.record_sojourn(light_idx, y, now - join_ms);
        self.pool_rebalance(node, light_idx, now);
        self.handle_stage_done(id, local, node, now);
    }

    /// A warming replica's cold-start window closed: promote it and
    /// rebalance (a no-op for warm-ups cancelled by shrink or outage).
    fn handle_pool_warm(&mut self, node: usize, light_idx: usize, now: f64) {
        let fired = self
            .pool_mgr
            .as_mut()
            .map_or(false, |pm| pm.warm_fire(node, light_idx, now));
        if fired {
            self.sr.settle(node, light_idx, now);
            self.pool_rebalance(node, light_idx, now);
        }
    }

    /// Invoke the deployment strategy on the pending light queue.
    fn handle_decide(&mut self, strategy: &mut dyn Strategy, now: f64) {
        self.decide_scheduled = false;
        {
            let t: &TaskArena = self.t;
            self.pending.retain(|(id, _)| t.contains(*id));
        }
        if self.dynt.is_some() {
            // Queued work whose input payload was destroyed is an
            // unrecoverable casualty — drop before building requests
            // (unreachable-but-alive inputs keep waiting).
            let env = self.env;
            let app = &env.app;
            let mut casualties: Vec<u64> = Vec::new();
            for &(id, local) in self.pending.iter() {
                if let Some(slot) = self.t.slot(id) {
                    let r = self.t.span(slot);
                    if crate::sim::stage_inputs_destroyed(
                        app,
                        self.t.task_type[slot as usize] as usize,
                        &self.t.destroyed[r],
                        local,
                    ) {
                        casualties.push(id);
                    }
                }
            }
            for id in casualties {
                if self.t.contains(id) {
                    self.collector.record_fault_drop();
                    self.finish_task(id, None);
                }
            }
            let t: &TaskArena = self.t;
            self.pending.retain(|(id, _)| t.contains(*id));
        }
        if self.pending.is_empty() {
            return;
        }
        let env = self.env;
        let app = &env.app;
        let slot = ((now / self.opts.slot_ms).floor() as usize)
            .min(self.opts.slots.saturating_sub(1));

        if self.pool_mgr.is_some() {
            // Pooled busy view: live occupancy in the same instance-group
            // units the stations report, so strategies are none the wiser.
            let max_y = env.gtable.max_parallelism().max(1);
            self.sr.busy_into(self.busy_scratch, max_y);
        } else {
            self.stations.busy_into(self.busy_scratch);
        }
        let mut residual = crate::sim::residual_after_busy(
            &self.residual_static,
            &env.light_resources,
            &self.busy_scratch[..],
        );
        if self.dynt.is_some() {
            for (v, res) in residual.iter_mut().enumerate() {
                if !self.node_up[v] {
                    *res = [0.0; NUM_RESOURCES];
                }
            }
        }
        let requests: Vec<LightRequest> = self
            .pending
            .iter()
            .map(|&(id, local)| {
                let s = self.t.slot(id).expect("pending task is live");
                let i = s as usize;
                let r = self.t.span(s);
                let task_type = self.t.task_type[i] as usize;
                let tt = &app.task_types[task_type];
                let ms_id = tt.services[local];
                let m = self.light_idx_of[ms_id.0].expect("light idx");
                let payloads = crate::sim::parent_payloads(
                    app,
                    task_type,
                    &self.t.done[r.clone()],
                    &self.t.node[r],
                    self.t.ed[i] as usize,
                    self.t.arrival_ms[i] + self.t.uplink_ms[i],
                    local,
                );
                let &(from, _, mb) = payloads
                    .iter()
                    .max_by(|a, b| a.1.total_cmp(&b.1))
                    .unwrap();
                LightRequest {
                    task_id: id,
                    light_idx: m,
                    from_node: from,
                    payload_mb: mb,
                    h: self.t.vq[i],
                    deadline_slack_ms: self.t.deadline_ms[i] - (now - self.t.arrival_ms[i]),
                }
            })
            .collect();

        let decision = {
            let dm: &DistanceMatrix = match &self.dynt {
                Some(d) => d.dm(),
                None => &env.dm,
            };
            strategy.decide_light(
                env,
                slot,
                &requests,
                &self.busy_scratch[..],
                &residual,
                dm,
                &mut self.rng,
            )
        };
        debug_assert_eq!(decision.assignments.len(), requests.len());

        // New instance counts may free FIFO'd work immediately. Pooled
        // trials have no FIFO: capacity is the pool manager's business.
        if self.pool_mgr.is_none() {
            let promoted = self.stations.on_decision(&decision.x);
            for (v, m, w) in promoted {
                self.start_service(v, m, w, now);
            }
        }

        let alpha = env.cfg.controller.contention_alpha;
        let pending = std::mem::take(&mut *self.pending);
        let mut still = Vec::new();
        for (qi, (id, local)) in pending.into_iter().enumerate() {
            let asn = match decision.assignments.get(qi).and_then(|a| *a) {
                Some(a) => a,
                None => {
                    still.push((id, local));
                    continue;
                }
            };
            // A fault-oblivious strategy may route onto a dead node; the
            // engine refuses and the work keeps waiting.
            if self.dynt.is_some() && !self.node_up[asn.node] {
                still.push((id, local));
                continue;
            }
            let s = self.t.slot(id).expect("pending task is live");
            let i = s as usize;
            let r = self.t.span(s);
            let task_type = self.t.task_type[i] as usize;
            // Sampled contended service time — same draw semantics as the
            // slotted engine.
            let (proc_ms, critical, mb, arrive, obs_pre) = {
                let dm: &DistanceMatrix = match &self.dynt {
                    Some(d) => d.dm(),
                    None => &env.dm,
                };
                let tt = &app.task_types[task_type];
                let spec = app.catalog.spec(tt.services[local]);
                let f = spec.rate.sample(&mut self.rng) / (asn.y as f64).powf(alpha);
                let payloads = crate::sim::parent_payloads(
                    app,
                    task_type,
                    &self.t.done[r.clone()],
                    &self.t.node[r.clone()],
                    self.t.ed[i] as usize,
                    self.t.arrival_ms[i] + self.t.uplink_ms[i],
                    local,
                );
                let &(pn, pd, mb) = payloads
                    .iter()
                    .max_by(|a, b| {
                        let la = a.1 + dm.latency(a.0, asn.node, a.2);
                        let lb = b.1 + dm.latency(b.0, asn.node, b.2);
                        la.total_cmp(&lb)
                    })
                    .unwrap();
                let arrive = pd + dm.latency(pn, asn.node, mb);
                let obs_pre = self.obs.is_some().then(|| {
                    crate::sim::critical_parent(app, task_type, local, &payloads, asn.node, dm)
                });
                (spec.workload_mb / f.max(1e-9), (pn, pd), mb, arrive, obs_pre)
            };
            // No surviving route from the payload to the chosen node:
            // keep waiting (links may recover; the age drop bounds it).
            if !arrive.is_finite() {
                still.push((id, local));
                continue;
            }
            let bl = r.start + local;
            if self.t.rerouted[bl] {
                // A fault-cancelled execution has found a surviving
                // replica: recovered, not dropped.
                self.t.rerouted[bl] = false;
                self.collector.record_reroute();
            }
            self.t.node[bl] = Some(asn.node);
            self.t.token[bl] += 1;
            let token = self.t.token[bl];
            if self.pool_mgr.is_none() {
                self.stations.note_assigned(asn.node, asn.light_idx);
            }

            // Hop-by-hop transfer of the latest-arriving parent payload:
            // hops that analytically completed while the request waited
            // are skipped (the transfer overlapped the controller wait,
            // matching the slotted engine's `max(arrival, now)`).
            let (pn, pd) = critical;
            let (pslot, pgen) =
                self.plans
                    .alloc(id, local, asn.node, asn.light_idx, asn.y, proc_ms);
            {
                let hops = match &self.dynt {
                    Some(d) => d.hops(),
                    None => &env.hops,
                };
                let mut cum = pd;
                for h in hops.hops(pn, asn.node) {
                    cum += h.latency(mb);
                    if cum > now {
                        self.plans.hop_times[pslot as usize].push(cum);
                    }
                }
            }
            let nh = self.plans.hop_times[pslot as usize].len();
            if nh == 0 {
                self.plans.hop_times[pslot as usize].push(now);
                self.cal
                    .schedule(now, EventKind::StationJoin { plan: pslot, pgen });
            } else {
                let first = self.plans.hop_times[pslot as usize][0];
                let kind = if nh == 1 {
                    EventKind::StationJoin { plan: pslot, pgen }
                } else {
                    EventKind::HopDone { plan: pslot, pgen }
                };
                self.cal.schedule(first, kind);
            }
            if let Some((from, _, _)) = obs_pre {
                if let Some(rr) = self.rec() {
                    rr.light_assigned(
                        id,
                        local,
                        token,
                        asn.node,
                        asn.y,
                        asn.light_idx,
                        from,
                        now,
                        arrive.max(now),
                    );
                }
            }
        }
        *self.pending = still;
    }

    /// A fault-cancelled stage's backoff window closed: re-dispatch if it
    /// is still waiting (the per-tick rescan may have beaten us to it, or
    /// the task may have finished or been dropped meanwhile).
    fn handle_retry(&mut self, id: u64, local: usize, now: f64) {
        let ready = match self.t.slot(id) {
            Some(s) => self.stage_ready(s, local),
            None => return,
        };
        if ready {
            self.dispatch_stage(id, local, now);
        }
    }

    /// Apply fault-schedule entry `idx` at its exact timestamp. Schedule
    /// entries sharing one timestamp pop consecutively (they are seeded
    /// first, in index order), so state changes are applied per event but
    /// the routing rebuild and the cancelled-stage re-dispatch run once
    /// per timestamp group — after its last entry.
    fn handle_fault(&mut self, idx: usize, now: f64) {
        let fev = self.faults.events()[idx];
        match fev.kind {
            FaultKind::NodeDown { node } => {
                self.node_up[node] = false;
                if let Some(d) = self.dynt.as_mut() {
                    d.apply_deferred(&fev.kind);
                }
                self.core_router.set_node_down(node);
                self.stations.fail_node(node);
                if let Some(pm) = self.pool_mgr.as_mut() {
                    // Replicas die with their node; pooled executions
                    // there go stale (their cancelled stages re-dispatch
                    // through the walk below, same as station mode).
                    pm.fail_node(node);
                    self.sr.kill_node(node);
                }
                // Payloads in transit toward the dead station never land
                // (freeing the plan makes their events stale).
                self.plans.remove_toward(node, |_| {});
                // Completed outputs resident on the node are destroyed
                // (permanent — recovery restores capacity, not data);
                // in-flight executions are cancelled and their stages
                // re-dispatch after the batch commit (dispatch drops
                // tasks whose inputs died with the node).
                let retry = self.opts.failover.retry;
                let tracing = self.obs.as_ref().map_or(false, |o| o.trace.is_some());
                let mut trace_ev: Vec<(u64, usize, u8, f64)> = Vec::new();
                // Ascending-id walk (the seed's HashMap walk visited an
                // arbitrary order; every per-stage effect is local to its
                // stage, so the end state is identical).
                for idn in self.t.first_live_id()..self.t.id_upper() {
                    let id = idn as u64;
                    let slot = match self.t.slot(id) {
                        Some(s) => s,
                        None => continue,
                    };
                    let r = self.t.span(slot);
                    for local in 0..(r.end - r.start) {
                        let bl = r.start + local;
                        if self.t.done[bl].is_some() {
                            if self.t.node[bl] == Some(node) {
                                self.t.destroyed[bl] = true;
                            }
                            continue;
                        }
                        if self.t.node[bl] == Some(node) && self.t.dispatched[bl] {
                            // Primary execution dies with the node. A live
                            // hedged standby is promoted in place: its
                            // token becomes the stage's live token, so its
                            // CoreDone completes the stage and the dead
                            // primary's event goes stale.
                            if let Some((hn, ht)) =
                                self.t.hedge[bl].filter(|&(hn, _)| hn != node)
                            {
                                self.t.node[bl] = Some(hn);
                                self.t.token[bl] = ht;
                                self.t.hedge[bl] = None;
                                self.collector.record_reroute();
                                if tracing {
                                    trace_ev.push((id, local, 0, 0.0));
                                }
                                continue;
                            }
                            self.t.dispatched[bl] = false;
                            self.t.node[bl] = None;
                            // Skip past any booked hedge token so a stale
                            // hedge event can never match a later dispatch.
                            self.t.token[bl] = self.t.token[bl]
                                .max(self.t.hedge[bl].map_or(0, |(_, ht)| ht))
                                + 1;
                            self.t.hedge[bl] = None;
                            // Jittered exponential backoff, deterministic
                            // per (task, stage, attempt) — the engine RNG
                            // stream is never consumed.
                            self.t.attempts[bl] += 1;
                            self.t.rerouted[bl] = true;
                            self.t.retry_at[bl] = now
                                + retry.backoff_ms(
                                    self.t.attempts[bl],
                                    id ^ ((local as u64) << 40),
                                );
                            self.collector.record_retry();
                            self.fault_resets.push((id, local));
                            if tracing {
                                trace_ev.push((id, local, 1, self.t.retry_at[bl]));
                            }
                        } else if self.t.hedge[bl].map(|(hn, _)| hn) == Some(node) {
                            // The standby died; the primary continues.
                            self.t.hedge[bl] = None;
                            if tracing {
                                trace_ev.push((id, local, 2, 0.0));
                            }
                        }
                    }
                }
                if !trace_ev.is_empty() {
                    // The walk is already id-ordered; the sort keeps the
                    // recorder contract explicit (and stable under any
                    // future storage change).
                    trace_ev.sort_by(|a, b| a.0.cmp(&b.0).then(a.1.cmp(&b.1)));
                    if let Some(r) = self.rec() {
                        for (tid, local, kind, until) in trace_ev {
                            match kind {
                                0 => r.hedge_promoted(tid, local, now),
                                1 => r.attempt_cancelled(tid, local, now, until),
                                _ => r.hedge_dropped(tid, local, now),
                            }
                        }
                    }
                }
            }
            FaultKind::NodeUp { node } => {
                self.node_up[node] = true;
                if let Some(d) = self.dynt.as_mut() {
                    d.apply_deferred(&fev.kind);
                }
                self.core_router.set_node_up(node, now);
                if let Some(pm) = self.pool_mgr.as_mut() {
                    // Capacity returns, replicas don't: the policy
                    // regrows the node's pools from demand.
                    pm.node_restored(node);
                }
            }
            FaultKind::CoreReplicaFail { node, core_idx } => {
                self.core_router.kill_instance(node, core_idx);
            }
            FaultKind::CoreReplicaRestart { node, core_idx } => {
                // Rejoin from the last checkpoint (fast clock) or cold.
                // While the node itself is down the restart is folded into
                // the node's own recovery instead.
                if self.node_up[node] {
                    let cp = self.opts.failover.checkpoint;
                    if let Some(ready_ms) = self.core_router.rejoin(
                        node,
                        core_idx,
                        now,
                        cp.restore_ms,
                        cp.cold_start_ms,
                    ) {
                        self.collector.record_restore();
                        if let Some(r) = self.rec() {
                            r.restore(node, now, ready_ms);
                        }
                    }
                }
            }
            link_event => {
                if let Some(d) = self.dynt.as_mut() {
                    d.apply_deferred(&link_event);
                }
            }
        }
        let group_continues = self
            .faults
            .events()
            .get(idx + 1)
            .map_or(false, |next| next.time_ms == fev.time_ms);
        if !group_continues {
            if let Some(d) = self.dynt.as_mut() {
                d.commit();
            }
            // Sorted for determinism: calendar sequence numbers are
            // assigned in schedule order, and resets accumulate across
            // every entry of the timestamp group.
            let mut resets = std::mem::take(&mut self.fault_resets);
            resets.sort_unstable();
            for (id, local) in resets {
                // Re-dispatch after the backoff window, not immediately:
                // the jittered delay spreads the retry burst a zone
                // outage would otherwise synchronize.
                let s = self.t.slot(id).expect("reset task is live");
                let at = self.t.retry_at[self.t.span(s).start + local].max(now);
                self.cal.schedule(at, EventKind::Retry { task: id, local });
            }
        }
    }

    /// Slot boundary: virtual-queue aging, drop checks, per-slot cost
    /// charging, queue-depth telemetry, and a decision retry for work the
    /// controller previously declined.
    fn handle_tick(&mut self, slot: usize, now: f64) {
        // Periodic core-state checkpoints (only meaningful under faults:
        // the stamps exist to make replica restarts fast). Same cadence
        // arithmetic as the slotted engine.
        let cp = self.opts.failover.checkpoint;
        if self.dynt.is_some() && cp.enabled() {
            let every = (cp.period_ms / self.opts.slot_ms).ceil().max(1.0) as usize;
            if slot % every == 0 {
                self.core_router.checkpoint(now);
            }
        }
        let slot_end = now + self.opts.slot_ms;
        let drop_after = self.opts.drop_after_deadlines;
        for idn in self.t.first_live_id()..self.t.id_upper() {
            let id = idn as u64;
            let s = match self.t.slot(id) {
                Some(s) => s,
                None => continue,
            };
            let i = s as usize;
            let age = slot_end - self.t.arrival_ms[i];
            let deadline = self.t.deadline_ms[i];
            if age > drop_after * deadline {
                self.finish_task(id, None);
            } else {
                // `VirtualQueues::update`: H ← max(H + experienced −
                // deadline, ζ), marking the queue as tracked.
                self.t.vq[i] = (self.t.vq[i] + age - deadline).max(self.zeta);
                self.t.vq_tracked[i] = true;
            }
        }
        {
            let t: &TaskArena = self.t;
            self.pending.retain(|(id, _)| t.contains(*id));
        }
        // Under faults a core stage can fail to route (all replicas down
        // or unreachable): it stays ready-but-undispatched and is retried
        // each tick until a replica or route comes back.
        if self.dynt.is_some() {
            for idn in self.t.first_live_id()..self.t.id_upper() {
                let id = idn as u64;
                let nst = match self.t.slot(id) {
                    Some(s) => self.t.nstages(s),
                    None => continue,
                };
                for local in 0..nst {
                    let ready = match self.t.slot(id) {
                        Some(s) => self.stage_ready(s, local),
                        None => break,
                    };
                    if ready {
                        self.dispatch_stage(id, local, now);
                    }
                }
            }
        }
        if self.pool_mgr.is_some() {
            self.pool_tick(now);
        } else {
            // Per-slot light cost: maintenance on busy instance-groups,
            // parallelism on in-flight work (eq. 7 under continuous time).
            self.stations.busy_into(self.busy_scratch);
            self.stations.in_flight_into(self.y_scratch);
            self.costs.charge_light_slot(
                &self.busy_scratch[..],
                &self.y_scratch[..],
                &self.light_dp,
                &self.light_mt,
                &self.light_pl,
            );
            self.collector
                .record_queue_depth(self.pending.len() + self.stations.waiting_total());
        }
        // Per-tick telemetry snapshot (observer-gated, read-only).
        if self.obs.as_ref().map_or(false, |o| o.metrics.is_some()) {
            let env = self.env;
            let nl = env.app.catalog.num_light();
            let mut backlog = vec![0usize; nl];
            for &(pid, plocal) in self.pending.iter() {
                if let Some(s) = self.t.slot(pid) {
                    let task_type = self.t.task_type[s as usize] as usize;
                    let ms_id = env.app.task_types[task_type].services[plocal];
                    if let Some(m) = self.light_idx_of[ms_id.0] {
                        backlog[m] += 1;
                    }
                }
            }
            let committed_y: Vec<u32> = (0..nl)
                .map(|m| self.y_scratch.iter().map(|row| row[m]).max().unwrap_or(0))
                .collect();
            let busy_groups: u32 = self.busy_scratch.iter().flat_map(|r| r.iter()).sum();
            let node_util = self
                .busy_scratch
                .iter()
                .filter(|row| row.iter().any(|&b| b > 0))
                .count() as f64
                / self.busy_scratch.len().max(1) as f64;
            let vq = self.t.vq_total();
            if let Some(pm) = self.pool_mgr.as_ref() {
                // Pool snapshot + the live `g_{m,ε}` of the §P10 story:
                // the paper's delay-bound machinery evaluated at the
                // worst actual occupancy/replica ratio instead of the
                // committed `y`.
                let alpha = self.opts.pool.as_ref().map_or(1.0, |p| p.alpha);
                let ctrl = &env.cfg.controller;
                let est = crate::effcap::EffCapEstimator::log_grid(
                    ctrl.theta_lo,
                    ctrl.theta_hi,
                    ctrl.theta_n,
                );
                let mut worst = f64::NEG_INFINITY;
                for v in 0..self.node_up.len() {
                    for (m, &ms_id) in env.app.catalog.light_ids().iter().enumerate() {
                        let occ = self.sr.occupancy(v, m);
                        if occ == 0 {
                            continue;
                        }
                        let g = crate::pool::live_delay_bound(
                            &est,
                            &env.light_rate_samples[m],
                            env.app.catalog.spec(ms_id).workload_mb,
                            ctrl.epsilon,
                            occ,
                            pm.active(v, m),
                            alpha,
                        );
                        if g.is_finite() && g > worst {
                            worst = g;
                        }
                    }
                }
                if let Some(o) = self.obs.as_deref_mut() {
                    o.set_pool_gauges(pm.active_total(), pm.warming_total(), worst);
                }
            }
            if let Some(o) = self.obs.as_deref_mut() {
                o.sample_slot(now, &backlog, &committed_y, busy_groups, node_util, vq, &env.gtable);
            }
        }
        if !self.pending.is_empty() {
            self.request_decide(now);
        }
    }

    /// Pooled slot boundary: step the scaling policy per station in
    /// sorted `(node, service)` order, schedule `PoolWarm` events for
    /// grown replicas, reschedule stations whose draining replicas
    /// retired (the survivors speed up), then charge deployment cost on
    /// the pool state — instance column `x` = warm + warming replicas
    /// (instantiation-on-increase prices every cold start), parallelism
    /// column `y` = executions actually being served.
    fn pool_tick(&mut self, now: f64) {
        let nl = self.env.app.catalog.num_light();
        let nv = self.node_up.len();
        // Station-attributed backlog: pending light work by service.
        let mut backlog = vec![0u32; nl];
        for &(pid, plocal) in self.pending.iter() {
            if let Some(s) = self.t.slot(pid) {
                let task_type = self.t.task_type[s as usize] as usize;
                let ms_id = self.env.app.task_types[task_type].services[plocal];
                if let Some(m) = self.light_idx_of[ms_id.0] {
                    backlog[m] += 1;
                }
            }
        }
        let mut pm = self.pool_mgr.take().expect("pool_tick without a pool");
        let mut grown = std::mem::take(&mut self.pool_grown);
        for v in 0..nv {
            for m in 0..nl {
                let in_flight = self.sr.occupancy(v, m);
                let retired = pm.step(v, m, in_flight, backlog[m], now, &mut grown);
                for &ready in grown.iter() {
                    self.cal
                        .schedule(ready, EventKind::PoolWarm { node: v, light_idx: m });
                    if let Some(r) = self.rec() {
                        r.warmup(v, now, ready);
                    }
                }
                if retired > 0 {
                    self.sr.settle(v, m, now);
                    self.pool_resched(v, m, now, pm.active(v, m));
                }
            }
        }
        pm.end_slot(self.opts.slot_ms);
        // Cost columns from the pool state, in the scratch matrices the
        // telemetry snapshot also reads.
        self.busy_scratch.resize(nv, Vec::new());
        self.y_scratch.resize(nv, Vec::new());
        for v in 0..nv {
            self.busy_scratch[v].clear();
            self.busy_scratch[v].resize(nl, 0);
            self.y_scratch[v].clear();
            self.y_scratch[v].resize(nl, 0);
            for m in 0..nl {
                self.busy_scratch[v][m] = pm.total(v, m);
                self.y_scratch[v][m] = self.sr.occupancy(v, m).min(pm.active(v, m));
            }
        }
        self.costs.charge_light_slot(
            &self.busy_scratch[..],
            &self.y_scratch[..],
            &self.light_dp,
            &self.light_mt,
            &self.light_pl,
        );
        // Processor sharing has no station FIFO: the depth is the
        // controller backlog alone.
        self.collector.record_queue_depth(self.pending.len());
        self.pool_grown = grown;
        self.pool_mgr = Some(pm);
    }
}

/// Run one DES trial of `strategy` over a recorded trace.
pub fn run_des_trial(
    env: &SimEnv,
    strategy: &mut dyn Strategy,
    seed: u64,
    opts: &DesOptions,
    trace: &Trace,
) -> TrialMetrics {
    let none = FaultSchedule::none();
    let mut arena = DesArena::<Calendar>::default();
    run_des_inner(&mut arena, env, strategy, seed, opts, trace, false, &none, None).0
}

/// Like [`run_des_trial`], additionally returning per-task execution
/// records (stage nodes and completion times) for validation tooling.
pub fn run_des_trial_recorded(
    env: &SimEnv,
    strategy: &mut dyn Strategy,
    seed: u64,
    opts: &DesOptions,
    trace: &Trace,
) -> (TrialMetrics, Vec<TaskRecord>) {
    let none = FaultSchedule::none();
    let mut arena = DesArena::<Calendar>::default();
    run_des_inner(&mut arena, env, strategy, seed, opts, trace, true, &none, None)
}

/// Run one DES trial while replaying a [`FaultSchedule`] at its exact
/// event timestamps. With an empty schedule this is bit-identical to
/// [`run_des_trial`].
pub fn run_des_trial_faulted(
    env: &SimEnv,
    strategy: &mut dyn Strategy,
    seed: u64,
    opts: &DesOptions,
    trace: &Trace,
    faults: &FaultSchedule,
) -> TrialMetrics {
    let mut arena = DesArena::<Calendar>::default();
    run_des_inner(&mut arena, env, strategy, seed, opts, trace, false, faults, None).0
}

/// [`run_des_trial_faulted`] into a caller-owned [`DesArena`]: the
/// storage (arena, slab, calendar, stations, scratch) is reset and
/// reused instead of reallocated, which is what a sweep cell running
/// many trials wants. Also the cross-calendar test entry — instantiate
/// the arena with [`super::calendar::HeapCalendar`] to replay a trial
/// on the reference queue.
pub fn run_des_trial_faulted_in<C: EventCalendar>(
    arena: &mut DesArena<C>,
    env: &SimEnv,
    strategy: &mut dyn Strategy,
    seed: u64,
    opts: &DesOptions,
    trace: &Trace,
    faults: &FaultSchedule,
) -> TrialMetrics {
    run_des_inner(arena, env, strategy, seed, opts, trace, false, faults, None).0
}

/// Like [`run_des_trial_faulted`], with an [`Observer`] attached: spans,
/// per-tick telemetry, and blame-attribution inputs are recorded without
/// consuming engine RNG or reordering the calendar, so the returned
/// metrics are identical to the unobserved run on the same inputs
/// (asserted by the zero-overhead gate test).
pub fn run_des_trial_observed(
    env: &SimEnv,
    strategy: &mut dyn Strategy,
    seed: u64,
    opts: &DesOptions,
    trace: &Trace,
    faults: &FaultSchedule,
    obs: &mut Observer,
) -> TrialMetrics {
    let mut arena = DesArena::<Calendar>::default();
    run_des_inner(&mut arena, env, strategy, seed, opts, trace, false, faults, Some(obs)).0
}

#[allow(clippy::too_many_arguments)]
fn run_des_inner<C: EventCalendar>(
    arena: &mut DesArena<C>,
    env: &SimEnv,
    strategy: &mut dyn Strategy,
    seed: u64,
    opts: &DesOptions,
    trace: &Trace,
    record: bool,
    faults: &FaultSchedule,
    obs: Option<&mut Observer>,
) -> (TrialMetrics, Vec<TaskRecord>) {
    arena.reset();
    let app = &env.app;
    let cfg = &env.cfg;
    let rng = Xoshiro256::seed_from(seed ^ 0xDE5E_7E17);
    let mut place_rng = rng.clone();
    let gen = WorkloadGenerator::new(
        cfg,
        app,
        &env.topo,
        &mut Xoshiro256::seed_from(env.users_seed),
    );

    // Static tier — identical to the slotted engine.
    let scores = QosScores::compute(
        app,
        &env.topo,
        &env.dm,
        gen.users(),
        &ScoreParams::from_config(&cfg.controller),
    );
    let placement = strategy.place_core(env, &scores, &mut place_rng);
    let core_router = CoreRouter::new(&placement.instances);
    let residual_static = placement.residual_capacity(app, &env.topo);

    let mut costs = CostBook::new();
    let core_dp: Vec<f64> = env.core_costs.iter().map(|c| c.0).collect();
    let core_mt: Vec<f64> = env.core_costs.iter().map(|c| c.1).collect();
    costs.charge_core_placement(&placement.instances, &core_dp, &core_mt, opts.slots);

    let nv = env.topo.num_nodes();
    let nl = app.catalog.num_light();
    let max_y = env.gtable.max_parallelism().max(1);
    let mut collector = MetricsCollector::new();
    collector.enable_service_obs(nl);
    if opts.streaming {
        // Per-(service, y) delay bounds, snapshotted so violations can
        // be counted at record time instead of from retained samples.
        let bounds: Vec<Vec<f64>> = (0..nl)
            .map(|m| (0..=max_y).map(|y| env.gtable.delay(m, y)).collect())
            .collect();
        collector.enable_streaming(bounds);
    }

    let light_idx_of: Vec<Option<usize>> = (0..app.catalog.len())
        .map(|m| app.catalog.light_index(crate::microservice::MsId(m)))
        .collect();

    arena.stations.reset(nv, nl, max_y, opts.batching);
    let DesArena {
        tasks,
        plans,
        cal,
        pending,
        stations,
        records,
        busy_scratch,
        y_scratch,
        shared_rate,
    } = arena;

    // Elastic pools (§P10): fresh manager per trial, shared-rate table
    // reset in place (a reused arena is bit-identical to a fresh one).
    // With `pool` off neither is ever touched.
    let pool_mgr = opts.pool.as_ref().map(|pc| {
        shared_rate.reset(nv, nl, pc.alpha);
        crate::pool::PoolManager::new(nv, nl, pc.clone(), seed)
    });

    let has_faults = !faults.is_empty();
    let mut d = Des {
        env,
        opts,
        faults,
        dynt: has_faults.then(|| DynamicTopology::new(&env.topo, 1.0)),
        node_up: vec![true; nv],
        fault_resets: Vec::new(),
        rng: place_rng,
        cal,
        t: tasks,
        plans,
        zeta: cfg.controller.zeta,
        pending,
        decide_scheduled: false,
        stations,
        core_router,
        residual_static,
        collector,
        costs,
        light_idx_of,
        light_dp: env.light_costs.iter().map(|c| c.0).collect(),
        light_mt: env.light_costs.iter().map(|c| c.1).collect(),
        light_pl: env.light_costs.iter().map(|c| c.2).collect(),
        horizon_ms: opts.slots as f64 * opts.slot_ms,
        record,
        records,
        obs,
        busy_scratch,
        y_scratch,
        pool_mgr,
        sr: shared_rate,
        pool_scratch: Vec::new(),
        pool_grown: Vec::new(),
    };

    // Seed the calendar. Fault events go in first so that, at equal
    // timestamps, the fault applies before the slot tick and before
    // arrivals — matching the slotted engine's start-of-slot application.
    for (idx, fev) in faults.events().iter().enumerate() {
        if fev.time_ms <= d.horizon_ms {
            d.cal.schedule(fev.time_ms, EventKind::Fault { idx });
        }
    }
    // Trace arrivals (slots beyond the horizon are ignored) and one
    // controller tick per slot.
    for slot in 0..opts.slots {
        let t = slot as f64 * opts.slot_ms;
        for a in trace.slot(slot) {
            d.cal.schedule(t, EventKind::Arrival { arrival: a.clone() });
        }
        d.cal.schedule(t, EventKind::Tick { slot });
    }

    while let Some(ev) = d.cal.pop() {
        if ev.time_ms > d.horizon_ms {
            break;
        }
        let now = ev.time_ms;
        match ev.kind {
            EventKind::Arrival { arrival } => d.handle_arrival(arrival, now),
            EventKind::UplinkDone { task } => d.handle_uplink_done(task, now),
            EventKind::HopDone { plan, pgen } => d.handle_hop_done(plan, pgen),
            EventKind::StationJoin { plan, pgen } => d.handle_station_join(plan, pgen, now),
            EventKind::CoreDone {
                task,
                local,
                node,
                token,
            } => {
                // Stale when the dispatch was cancelled by a fault.
                let valid = d.t.slot(task).map_or(false, |s| {
                    let bl = d.t.span(s).start + local;
                    d.t.token[bl] == token && d.t.done[bl].is_none()
                });
                if valid {
                    d.handle_stage_done(task, local, node, now)
                }
            }
            EventKind::LightDone {
                task,
                local,
                node,
                light_idx,
                y,
                join_ms,
                gen,
            } => d.handle_light_done(task, local, node, light_idx, y, join_ms, gen, now),
            EventKind::Decide => d.handle_decide(strategy, now),
            EventKind::Tick { slot } => d.handle_tick(slot, now),
            EventKind::BatchFlush {
                node,
                light_idx,
                epoch,
            } => d.handle_batch_flush(node, light_idx, epoch, now),
            EventKind::Fault { idx } => d.handle_fault(idx, now),
            EventKind::Retry { task, local } => d.handle_retry(task, local, now),
            EventKind::PoolWarm { node, light_idx } => d.handle_pool_warm(node, light_idx, now),
            EventKind::PoolDone { run, rt } => d.handle_pool_done(run, rt, now),
        }
    }

    if std::env::var_os("FMEDGE_DEBUG").is_some() {
        eprintln!(
            "[des] events={} unfinished={} pending={} station_wait={}",
            d.cal.processed(),
            d.t.live(),
            d.pending.len(),
            d.stations.waiting_total()
        );
    }

    // Horizon end: everything still in flight is incomplete (ascending
    // id order, like the seed's sorted drain).
    for idn in d.t.first_live_id()..d.t.id_upper() {
        let id = idn as u64;
        if d.t.contains(id) {
            d.finish_task(id, None);
        }
    }
    let _ = placement.objective;
    let Des {
        collector,
        costs,
        t,
        cal,
        records,
        pool_mgr,
        ..
    } = d;
    debug_assert!(
        t.live() == 0,
        "task-arena leak: {} live tasks after drain",
        t.live()
    );
    let mut metrics = collector.finish(&costs);
    metrics.vq_residual = t.live();
    metrics.des_events = cal.processed();
    if let Some(pm) = pool_mgr {
        metrics.cold_starts = pm.cold_starts;
        metrics.pool_scale_events = pm.scale_events;
        metrics.pool_scale_to_zero = pm.scale_to_zero_events;
        metrics.pool_replica_slot_seconds = pm.replica_slot_seconds;
        metrics.pool_size = pm.size_hist;
    }
    (metrics, std::mem::take(records))
}
