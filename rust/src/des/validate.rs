//! Measured-vs-analytic bound validation.
//!
//! The controller's QoS machinery promises that a light service deployed
//! at parallelism `y` exceeds the delay bound `g_{m,ε}(y)` with
//! probability at most ε. The DES engine measures what actually happened
//! — per-execution sojourn `(y, wait + service)` samples — and this layer
//! turns them into per-service empirical violation rates and CCDF points:
//! the paper's guarantee holds iff `P(sojourn > g_{m,ε}(y)) ≤ ε` for
//! every light service.

use crate::effcap::GTable;
use crate::metrics::TrialMetrics;

/// Empirical bound check for one light service.
#[derive(Clone, Debug)]
pub struct ServiceValidation {
    /// Dense light-MS index.
    pub light_idx: usize,
    /// Number of measured executions.
    pub samples: usize,
    /// Executions whose sojourn exceeded `g_{m,ε}(y)` at their own `y`.
    pub violations: usize,
    /// The ε the bound was built for.
    pub epsilon: f64,
    /// Mean measured sojourn (ms).
    pub mean_sojourn_ms: f64,
    /// Mean bound across the same executions (ms).
    pub mean_bound_ms: f64,
    /// Worst observed sojourn (ms).
    pub max_sojourn_ms: f64,
}

impl ServiceValidation {
    /// Empirical `P(sojourn > g_{m,ε}(y))`.
    pub fn violation_rate(&self) -> f64 {
        if self.samples == 0 {
            0.0
        } else {
            self.violations as f64 / self.samples as f64
        }
    }

    /// Does the guarantee hold within `tolerance` (slack for Monte-Carlo
    /// noise at finite sample sizes)?
    pub fn holds(&self, tolerance: f64) -> bool {
        self.violation_rate() <= self.epsilon + tolerance
    }
}

/// Compare every measured sojourn in `metrics` against the g-table bound
/// at its own decision parallelism. Services with no executions yield a
/// zero-sample entry (trivially holding).
///
/// Streaming trials retain no raw samples; the per-execution comparison
/// already happened at record time ([`crate::metrics::ServiceObs::record_streamed`]
/// looked up the bound at each execution's y), so the same validation is
/// answered from the streamed aggregates.
pub fn validate_bounds(gtable: &GTable, metrics: &TrialMetrics) -> Vec<ServiceValidation> {
    metrics
        .service_obs
        .iter()
        .enumerate()
        .map(|(m, obs)| {
            if obs.samples.is_empty() && obs.sojourn.count() > 0 {
                // Streaming mode: aggregates only.
                let n = obs.sojourn.count() as usize;
                return ServiceValidation {
                    light_idx: m,
                    samples: n,
                    violations: obs.violations as usize,
                    epsilon: gtable.params_epsilon,
                    mean_sojourn_ms: obs.sojourn.mean(),
                    mean_bound_ms: obs.sum_bound_ms / n as f64,
                    max_sojourn_ms: obs.sojourn.max(),
                };
            }
            let mut violations = 0usize;
            let mut sum_s = 0.0;
            let mut sum_g = 0.0;
            let mut max_s = 0.0f64;
            for &(y, sojourn) in &obs.samples {
                let g = gtable.delay(m, y as usize);
                if sojourn > g {
                    violations += 1;
                }
                sum_s += sojourn;
                sum_g += g;
                max_s = max_s.max(sojourn);
            }
            let n = obs.samples.len();
            ServiceValidation {
                light_idx: m,
                samples: n,
                violations,
                epsilon: gtable.params_epsilon,
                mean_sojourn_ms: if n > 0 { sum_s / n as f64 } else { 0.0 },
                mean_bound_ms: if n > 0 { sum_g / n as f64 } else { 0.0 },
                max_sojourn_ms: max_s,
            }
        })
        .collect()
}

/// Pool several trials' validations (same g-table) into one per-service
/// aggregate — the multi-seed acceptance check.
pub fn pool(per_trial: &[Vec<ServiceValidation>]) -> Vec<ServiceValidation> {
    let nl = per_trial.iter().map(Vec::len).max().unwrap_or(0);
    (0..nl)
        .map(|m| {
            let mut samples = 0usize;
            let mut violations = 0usize;
            let mut sum_s = 0.0;
            let mut sum_g = 0.0;
            let mut max_s = 0.0f64;
            let mut epsilon = 0.0;
            for trial in per_trial {
                if let Some(v) = trial.get(m) {
                    samples += v.samples;
                    violations += v.violations;
                    sum_s += v.mean_sojourn_ms * v.samples as f64;
                    sum_g += v.mean_bound_ms * v.samples as f64;
                    max_s = max_s.max(v.max_sojourn_ms);
                    epsilon = v.epsilon;
                }
            }
            ServiceValidation {
                light_idx: m,
                samples,
                violations,
                epsilon,
                mean_sojourn_ms: if samples > 0 { sum_s / samples as f64 } else { 0.0 },
                mean_bound_ms: if samples > 0 { sum_g / samples as f64 } else { 0.0 },
                max_sojourn_ms: max_s,
            }
        })
        .collect()
}

/// Empirical CCDF of one service's sojourns evaluated at `t` ms:
/// `P(sojourn > t)` — exact from raw samples; bin-resolution from the
/// sojourn histogram when the trial streamed (no retained samples).
pub fn sojourn_ccdf(metrics: &TrialMetrics, light_idx: usize, t: f64) -> f64 {
    match metrics.service_obs.get(light_idx) {
        None => 0.0,
        Some(obs) => {
            if obs.samples.is_empty() {
                return obs.sojourn.ccdf(t);
            }
            let above = obs.samples.iter().filter(|&&(_, s)| s > t).count();
            above as f64 / obs.samples.len() as f64
        }
    }
}

/// Formatted per-service table for CLI / example output.
pub fn report(validations: &[ServiceValidation]) -> String {
    let mut s = String::new();
    s.push_str(
        "light  samples  violations  measured   eps     mean sojourn  mean bound  max sojourn  status\n",
    );
    for v in validations {
        s.push_str(&format!(
            "m={:<4} {:>7}  {:>10}  {:>8.4}  {:>6.3}  {:>10.3}ms  {:>8.3}ms  {:>9.3}ms  {}\n",
            v.light_idx,
            v.samples,
            v.violations,
            v.violation_rate(),
            v.epsilon,
            v.mean_sojourn_ms,
            v.mean_bound_ms,
            v.max_sojourn_ms,
            if v.holds(0.0) { "OK" } else { "VIOLATED" }
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::effcap::{GTable, GTableParams};
    use crate::metrics::MetricsCollector;

    fn flat_gtable(bound: f64, eps: f64) -> GTable {
        // One light service, constant bound across y.
        GTable::from_rows(vec![vec![bound; 4]], vec![vec![bound; 4]], eps, 1.0)
    }

    fn metrics_with(samples: Vec<(u32, f64)>) -> crate::metrics::TrialMetrics {
        let mut c = MetricsCollector::new();
        c.enable_service_obs(1);
        for (y, s) in samples {
            c.record_sojourn(0, y, s);
        }
        c.finish(&crate::metrics::CostBook::default())
    }

    #[test]
    fn violation_rate_counts_exceedances() {
        let gt = flat_gtable(10.0, 0.2);
        let m = metrics_with(vec![(1, 5.0), (2, 9.0), (1, 11.0), (3, 20.0)]);
        let v = validate_bounds(&gt, &m);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].samples, 4);
        assert_eq!(v[0].violations, 2);
        assert!((v[0].violation_rate() - 0.5).abs() < 1e-12);
        assert!(!v[0].holds(0.1));
        assert!(v[0].holds(0.31));
        assert_eq!(v[0].max_sojourn_ms, 20.0);
    }

    #[test]
    fn empty_service_trivially_holds() {
        let gt = flat_gtable(10.0, 0.2);
        let m = metrics_with(vec![]);
        let v = validate_bounds(&gt, &m);
        assert_eq!(v[0].samples, 0);
        assert!(v[0].holds(0.0));
    }

    #[test]
    fn streaming_trials_validate_from_aggregates() {
        // Same samples through a streaming collector (bounds snapshotted
        // the way the DES engine does it): validate_bounds must agree
        // with the retained-sample path, and the CCDF must come from the
        // histogram instead of returning 0.
        let gt = flat_gtable(10.0, 0.2);
        let samples = vec![(1u32, 5.0), (2, 9.0), (1, 11.0), (3, 20.0)];
        let retained = validate_bounds(&gt, &metrics_with(samples.clone()));
        let mut c = MetricsCollector::new();
        c.enable_service_obs(1);
        let bounds = vec![(0..=4).map(|y| gt.delay(0, y)).collect::<Vec<_>>()];
        c.enable_streaming(bounds);
        for &(y, s) in &samples {
            c.record_sojourn(0, y, s);
        }
        let m = c.finish(&crate::metrics::CostBook::default());
        assert!(m.service_obs[0].samples.is_empty());
        let streamed = validate_bounds(&gt, &m);
        assert_eq!(streamed[0].samples, retained[0].samples);
        assert_eq!(streamed[0].violations, retained[0].violations);
        assert_eq!(streamed[0].max_sojourn_ms, retained[0].max_sojourn_ms);
        assert!((streamed[0].mean_sojourn_ms - retained[0].mean_sojourn_ms).abs() < 1e-12);
        assert!((streamed[0].mean_bound_ms - retained[0].mean_bound_ms).abs() < 1e-12);
        assert!(sojourn_ccdf(&m, 0, 10.0) > 0.0, "CCDF from the histogram");
    }

    #[test]
    fn pooling_aggregates_counts() {
        let gt = flat_gtable(10.0, 0.2);
        let a = validate_bounds(&gt, &metrics_with(vec![(1, 5.0), (1, 15.0)]));
        let b = validate_bounds(&gt, &metrics_with(vec![(1, 5.0), (1, 5.0)]));
        let pooled = pool(&[a, b]);
        assert_eq!(pooled[0].samples, 4);
        assert_eq!(pooled[0].violations, 1);
        assert!((pooled[0].violation_rate() - 0.25).abs() < 1e-12);
    }

    #[test]
    fn ccdf_from_raw_samples() {
        let m = metrics_with(vec![(1, 1.0), (1, 2.0), (1, 3.0), (1, 4.0)]);
        assert!((sojourn_ccdf(&m, 0, 2.5) - 0.5).abs() < 1e-12);
        assert_eq!(sojourn_ccdf(&m, 0, 100.0), 0.0);
        assert_eq!(sojourn_ccdf(&m, 5, 1.0), 0.0);
    }

    #[test]
    fn bounds_built_from_samples_hold_at_their_epsilon() {
        // End-to-end statistical check of the estimator itself: draw
        // Gamma service rates, build the table, then measure violation
        // frequency of fresh draws against g at several parallelism
        // levels — must be ≤ eps (plus MC slack).
        use crate::rng::{Distribution, Gamma, Xoshiro256};
        let g = Gamma::new(1.7, 9.0);
        let mut rng = Xoshiro256::seed_from(99);
        let train = g.sample_n(&mut rng, 8192);
        let a_m = 1.3;
        let mut params = GTableParams::default_paper();
        params.epsilon = 0.05;
        let gt = GTable::build(&[train], &[a_m], &params);
        for y in [1usize, 2, 4] {
            let bound = gt.delay(0, y);
            let scale = (y as f64).powf(params.contention_alpha);
            let mut viol = 0usize;
            let n = 20000;
            for _ in 0..n {
                let service = a_m * scale / g.sample(&mut rng).max(1e-12);
                if service > bound {
                    viol += 1;
                }
            }
            let rate = viol as f64 / n as f64;
            assert!(
                rate <= params.epsilon + 0.02,
                "y={y}: measured {rate} > eps {}",
                params.epsilon
            );
        }
    }
}
