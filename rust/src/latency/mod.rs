//! End-to-end latency model (§II-B, eqs. 1–5).
//!
//! A task's latency is the recursive DAG completion time: uplink delay to
//! its first node, then per-hop transmission + propagation delays between
//! assigned nodes, plus each service's processing delay, with every
//! service waiting for all of its DAG parents (eq. 4).

use crate::graph::Dag;
use crate::microservice::{Application, TaskType};
use crate::network::Topology;

/// Node assignment of one task: `assignment[i]` = network node executing
/// the task DAG's local node `i` (the routing path `P_j`).
pub type Assignment = Vec<usize>;

/// Per-service realized processing delays (ms), local-node indexed.
pub type ProcDelays = Vec<f64>;

/// Recursive completion-time calculator for one task (eqs. 4–5).
///
/// * `uplink_ms` — `τ_ul`, eq. (1), realized at arrival.
/// * `assignment` — node executing each local DAG node.
/// * `proc_ms` — processing delay `τ_pc` of each local node (deterministic
///   for core services; for light services, the caller supplies either the
///   realized random delay (simulation ground truth) or the QoS bound
///   `g_{m,ε}(y)` (controller's estimate)).
/// * `transfer` — callable `(from_node, to_node, mb) -> latency`, eq. (2);
///   inject the topology's routed latency or a mock in tests.
///
/// Returns per-node completion times `T_j(v_i)`; the end-to-end latency is
/// the sink's entry — eq. (5).
pub fn completion_times<F>(
    dag: &Dag,
    output_mb: &[f64],
    uplink_ms: f64,
    assignment: &Assignment,
    proc_ms: &ProcDelays,
    mut transfer: F,
) -> Vec<f64>
where
    F: FnMut(usize, usize, f64) -> f64,
{
    let order = dag.topo_order().expect("task graphs are DAGs");
    let n = dag.len();
    debug_assert_eq!(assignment.len(), n);
    debug_assert_eq!(proc_ms.len(), n);
    debug_assert_eq!(output_mb.len(), n);
    let mut t = vec![0.0f64; n];
    for &i in &order {
        let parents = dag.parents(i);
        if parents.is_empty() {
            // Source services ingest the user payload: T = τ_ul + τ_pc.
            t[i] = uplink_ms + proc_ms[i];
        } else {
            let mut ready = f64::NEG_INFINITY;
            for &p in parents {
                let tr = transfer(assignment[p], assignment[i], output_mb[p]);
                ready = ready.max(t[p] + tr);
            }
            t[i] = ready + proc_ms[i];
        }
    }
    t
}

/// End-to-end latency `T^E2E_j` (eq. 5): completion time at the DAG sink.
pub fn end_to_end<F>(
    dag: &Dag,
    output_mb: &[f64],
    uplink_ms: f64,
    assignment: &Assignment,
    proc_ms: &ProcDelays,
    transfer: F,
) -> f64
where
    F: FnMut(usize, usize, f64) -> f64,
{
    let t = completion_times(dag, output_mb, uplink_ms, assignment, proc_ms, transfer);
    let sink = dag.sink().expect("task DAGs have a unique sink");
    t[sink]
}

/// Mean-value latency profile of a task type (§III-A): all random variables
/// replaced by their means, services placed at their *latency-nearest*
/// feasible node unknown at profiling time — so this profiles processing
/// chains only plus an optional fixed network penalty per hop.
#[derive(Clone, Debug)]
pub struct MeanProfile {
    /// Mean processing delay of each local node (ms).
    pub proc_ms: Vec<f64>,
    /// Sum of mean processing delays of each node's descendants — the
    /// `d^su` term of §III-A.
    pub succ_ms: Vec<f64>,
    /// Critical-path (longest chain) processing latency from any source to
    /// each node, *excluding* the node itself — the network-free part of
    /// `d^pr`.
    pub pred_ms: Vec<f64>,
}

impl MeanProfile {
    /// Build from a task type using mean service rates.
    pub fn of(app: &Application, tt: &TaskType) -> Self {
        let n = tt.dag.len();
        let proc_ms: Vec<f64> = (0..n)
            .map(|i| app.catalog.spec(tt.services[i]).mean_proc_delay())
            .collect();
        let mut succ_ms = vec![0.0; n];
        for i in 0..n {
            succ_ms[i] = tt
                .dag
                .descendants(i)
                .into_iter()
                .map(|d| proc_ms[d])
                .sum();
        }
        let order = tt.dag.topo_order().expect("DAG");
        let mut pred_ms = vec![0.0f64; n];
        for &i in &order {
            for &p in tt.dag.parents(i) {
                let cand = pred_ms[p] + proc_ms[p];
                if cand > pred_ms[i] {
                    pred_ms[i] = cand;
                }
            }
        }
        MeanProfile {
            proc_ms,
            succ_ms,
            pred_ms,
        }
    }
}

/// Routed transfer function over a topology (shortest-latency multi-hop),
/// the default `transfer` argument in production paths.
pub fn routed_transfer(topo: &Topology) -> impl FnMut(usize, usize, f64) -> f64 + '_ {
    move |a, b, mb| topo.route_latency(a, b, mb)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;
    use crate::graph::Dag;
    use crate::microservice::build_fig1_application;
    use crate::rng::Xoshiro256;

    fn chain3() -> Dag {
        let mut d = Dag::new(3);
        d.add_edge(0, 1).unwrap();
        d.add_edge(1, 2).unwrap();
        d
    }

    #[test]
    fn chain_latency_sums() {
        let dag = chain3();
        let out = [1.0, 1.0, 1.0];
        // uplink 2, proc 1 each, transfer 0.5 per hop
        let t = completion_times(&dag, &out, 2.0, &vec![0, 1, 2], &vec![1.0; 3], |a, b, _| {
            if a == b {
                0.0
            } else {
                0.5
            }
        });
        assert!((t[0] - 3.0).abs() < 1e-12);
        assert!((t[1] - 4.5).abs() < 1e-12);
        assert!((t[2] - 6.0).abs() < 1e-12);
    }

    #[test]
    fn colocated_services_skip_transfer() {
        let dag = chain3();
        let out = [1.0, 1.0, 1.0];
        let t = end_to_end(&dag, &out, 0.0, &vec![5, 5, 5], &vec![1.0; 3], |a, b, _| {
            assert_eq!(a, b);
            0.0
        });
        assert!((t - 3.0).abs() < 1e-12);
    }

    #[test]
    fn fusion_waits_for_slowest_parent() {
        // 0 -> 2 <- 1 ; parent 1 is slower.
        let mut dag = Dag::new(3);
        dag.add_edge(0, 2).unwrap();
        dag.add_edge(1, 2).unwrap();
        let out = [0.5, 2.0, 1.0];
        let proc = vec![1.0, 5.0, 2.0];
        let t = completion_times(&dag, &out, 1.0, &vec![0, 1, 2], &proc, |_, _, mb| mb);
        // parent0 done at 2, +transfer 0.5 => 2.5 ; parent1 done at 6, +2 => 8
        assert!((t[2] - (8.0 + 2.0)).abs() < 1e-12);
    }

    #[test]
    fn e2e_equals_sink_completion() {
        let dag = chain3();
        let out = [1.0; 3];
        let asn = vec![0, 0, 0];
        let proc = vec![1.0, 2.0, 3.0];
        let t = completion_times(&dag, &out, 0.5, &asn, &proc, |_, _, _| 0.0);
        let e = end_to_end(&dag, &out, 0.5, &asn, &proc, |_, _, _| 0.0);
        assert_eq!(e, t[2]);
    }

    #[test]
    fn transfer_uses_parent_output_size() {
        let dag = chain3();
        let out = [3.0, 7.0, 1.0];
        let mut seen = Vec::new();
        let _ = completion_times(&dag, &out, 0.0, &vec![0, 1, 2], &vec![0.0; 3], |_, _, mb| {
            seen.push(mb);
            0.0
        });
        assert_eq!(seen, vec![3.0, 7.0]);
    }

    #[test]
    fn mean_profile_consistency() {
        let cfg = ExperimentConfig::paper_default();
        let mut rng = Xoshiro256::seed_from(42);
        let app = build_fig1_application(&cfg, &mut rng);
        for tt in &app.task_types {
            let p = MeanProfile::of(&app, tt);
            let sink = tt.dag.sink().unwrap();
            // sink has no descendants
            assert_eq!(p.succ_ms[sink], 0.0);
            // sources have no predecessors
            for s in tt.dag.sources() {
                assert_eq!(p.pred_ms[s], 0.0);
            }
            // critical path through the sink >= any single proc delay on it
            let total_chain = p.pred_ms[sink] + p.proc_ms[sink];
            let (cp, _) = tt.dag.critical_path(|i| p.proc_ms[i]);
            assert!((total_chain - cp).abs() < 1e-9);
            // all positive
            assert!(p.proc_ms.iter().all(|&d| d > 0.0));
        }
    }
}
