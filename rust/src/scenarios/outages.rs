//! Correlated fault templates: higher-level failure patterns that compile
//! to plain [`FaultSchedule`]s, so both engines replay them through the
//! existing fault layer with no engine changes.
//!
//! [`FaultSchedule::generate`] draws *independent* per-node/per-link
//! faults; real edge deployments fail in correlated ways — a rack power
//! event takes a whole zone of servers down at once, one link failure
//! overloads its neighbors into a cascade, and overload itself makes
//! fail-stop more likely. Every template preserves the schedule
//! invariants the engines rely on (documented on
//! [`FaultSchedule::generate`]): only edge servers suffer node outages,
//! at most `(num_es - 1) / 2` (min 1) servers are down concurrently so a
//! backbone majority survives, every in-horizon outage has its recovery
//! emitted, and no node/link is double-downed.

use crate::faults::{geometric_slots, FaultEvent, FaultKind, FaultParams, FaultSchedule};
use crate::network::Topology;
use crate::rng::{Rng, Xoshiro256};

/// A correlated-failure family.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FaultTemplate {
    /// No faults: compiles to the empty schedule.
    None,
    /// The independent mix of [`FaultSchedule::generate`] at one headline
    /// rate (see [`FaultParams::from_rate`]).
    Independent { rate: f64 },
    /// Zone/rack-correlated outages: edge servers are partitioned into
    /// `zones` contiguous racks; when a rack suffers an outage, *all* of
    /// its servers go down together (truncated to the backbone-majority
    /// cap) and recover together.
    ZoneOutage {
        zones: usize,
        /// Per-zone outage probability per slot.
        zone_outage_per_slot: f64,
        /// Mean outage duration in slots (geometric, at least one).
        mean_outage_slots: f64,
    },
    /// Cascading link failures: a spontaneous link failure spreads to
    /// adjacent (endpoint-sharing) live links with probability
    /// `cascade_p` per neighbor, up to `max_depth` waves, all failing at
    /// the same instant with independent recovery times.
    CascadingLinks {
        trigger_per_slot: f64,
        cascade_p: f64,
        max_depth: usize,
        mean_outage_slots: f64,
    },
    /// Load-correlated core-replica fail-stop: the per-slot fail-stop
    /// probability is `base_rate` scaled by the scenario's realized
    /// arrival multiplier at that slot — overload makes failure likelier,
    /// exactly when it hurts most.
    LoadCorrelated { base_rate: f64 },
}

impl FaultTemplate {
    /// Compile to a replayable schedule. `load_curve[t]` is the realized
    /// arrival multiplier of the owning scenario (consumed by
    /// [`FaultTemplate::LoadCorrelated`]; slots past its end count as 1).
    /// Deterministic per seed, independent of any engine RNG stream.
    pub fn compile(
        &self,
        topo: &Topology,
        slots: usize,
        slot_ms: f64,
        num_core: usize,
        load_curve: &[f64],
        seed: u64,
    ) -> FaultSchedule {
        match *self {
            FaultTemplate::None => FaultSchedule::none(),
            FaultTemplate::Independent { rate } => FaultSchedule::generate(
                topo,
                slots,
                slot_ms,
                num_core,
                &FaultParams::from_rate(rate),
                seed,
            ),
            FaultTemplate::ZoneOutage {
                zones,
                zone_outage_per_slot,
                mean_outage_slots,
            } => compile_zone_outage(
                topo,
                slots,
                slot_ms,
                zones,
                zone_outage_per_slot,
                mean_outage_slots,
                seed,
            ),
            FaultTemplate::CascadingLinks {
                trigger_per_slot,
                cascade_p,
                max_depth,
                mean_outage_slots,
            } => compile_cascading_links(
                topo,
                slots,
                slot_ms,
                trigger_per_slot,
                cascade_p,
                max_depth,
                mean_outage_slots,
                seed,
            ),
            FaultTemplate::LoadCorrelated { base_rate } => compile_load_correlated(
                topo, slots, slot_ms, num_core, base_rate, load_curve, seed,
            ),
        }
    }
}

fn compile_zone_outage(
    topo: &Topology,
    slots: usize,
    slot_ms: f64,
    zones: usize,
    rate: f64,
    mean_outage_slots: f64,
    seed: u64,
) -> FaultSchedule {
    let mut rng = Xoshiro256::seed_from(seed ^ 0x20E0_07A6);
    let ess: Vec<usize> = topo.ess().collect();
    if ess.is_empty() || rate <= 0.0 {
        return FaultSchedule::none();
    }
    let zones = zones.clamp(1, ess.len());
    // Contiguous racks: zone z owns ESs [z*n/Z, (z+1)*n/Z).
    let members: Vec<&[usize]> = (0..zones)
        .map(|z| &ess[z * ess.len() / zones..(z + 1) * ess.len() / zones])
        .collect();
    let cap = ((ess.len().saturating_sub(1)) / 2).max(1);

    let mut events = Vec::new();
    let mut node_until = vec![0usize; topo.num_nodes()];
    let mut zone_until = vec![0usize; zones];
    let mut down_now = 0usize;
    for slot in 0..slots {
        let t = slot as f64 * slot_ms;
        // Recoveries due at this boundary free capacity first (slot 0 is
        // excluded: an until of 0 means "never down").
        for &v in &ess {
            if slot > 0 && node_until[v] == slot {
                node_until[v] = 0;
                down_now -= 1;
                events.push(FaultEvent {
                    time_ms: t,
                    kind: FaultKind::NodeUp { node: v },
                });
            }
        }
        for z in 0..zones {
            if zone_until[z] > slot || members[z].is_empty() {
                continue;
            }
            if rng.next_f64() < rate {
                let dur = geometric_slots(&mut rng, mean_outage_slots);
                zone_until[z] = slot + dur;
                // The whole rack goes dark together — truncated so a
                // backbone majority survives even when racks overlap in
                // time.
                for &v in members[z] {
                    if node_until[v] > slot || down_now >= cap {
                        continue;
                    }
                    node_until[v] = slot + dur;
                    down_now += 1;
                    events.push(FaultEvent {
                        time_ms: t,
                        kind: FaultKind::NodeDown { node: v },
                    });
                }
            }
        }
    }
    // Recoveries landing at or past the horizon boundary.
    for &v in &ess {
        if node_until[v] >= slots && node_until[v] != 0 {
            events.push(FaultEvent {
                time_ms: node_until[v] as f64 * slot_ms,
                kind: FaultKind::NodeUp { node: v },
            });
        }
    }
    FaultSchedule::from_events(events)
}

#[allow(clippy::too_many_arguments)]
fn compile_cascading_links(
    topo: &Topology,
    slots: usize,
    slot_ms: f64,
    trigger_per_slot: f64,
    cascade_p: f64,
    max_depth: usize,
    mean_outage_slots: f64,
    seed: u64,
) -> FaultSchedule {
    let mut rng = Xoshiro256::seed_from(seed ^ 0xCA5C_ADE5);
    let links = topo.links();
    let nl = links.len();
    if nl == 0 || trigger_per_slot <= 0.0 {
        return FaultSchedule::none();
    }
    let mut events = Vec::new();
    let mut link_until = vec![0usize; nl];
    for slot in 0..slots {
        let t = slot as f64 * slot_ms;
        for l in 0..nl {
            if slot > 0 && link_until[l] == slot {
                link_until[l] = 0;
                events.push(FaultEvent {
                    time_ms: t,
                    kind: FaultKind::LinkUp { link: l },
                });
            }
        }
        // A link is down in `slot` iff link_until[l] > slot.
        let fail = |li: usize,
                    rng: &mut Xoshiro256,
                    link_until: &mut [usize],
                    events: &mut Vec<FaultEvent>| {
            let dur = geometric_slots(rng, mean_outage_slots);
            link_until[li] = slot + dur;
            events.push(FaultEvent {
                time_ms: t,
                kind: FaultKind::LinkDown { link: li },
            });
        };
        for l in 0..nl {
            if link_until[l] > slot || rng.next_f64() >= trigger_per_slot {
                continue;
            }
            // Spontaneous failure at `l`, then waves of neighbor failures
            // (shared endpoint = shared conduit/switch), all at time t.
            fail(l, &mut rng, &mut link_until, &mut events);
            let mut frontier = vec![l];
            for _depth in 0..max_depth {
                let mut next = Vec::new();
                for cand in 0..nl {
                    if link_until[cand] > slot {
                        continue; // already down (incl. this wave)
                    }
                    let adjacent = frontier.iter().any(|&f| {
                        let (fa, fb) = (links[f].a, links[f].b);
                        let (ca, cb) = (links[cand].a, links[cand].b);
                        fa == ca || fa == cb || fb == ca || fb == cb
                    });
                    if adjacent && rng.next_f64() < cascade_p {
                        fail(cand, &mut rng, &mut link_until, &mut events);
                        next.push(cand);
                    }
                }
                if next.is_empty() {
                    break;
                }
                frontier = next;
            }
        }
    }
    for (l, &until) in link_until.iter().enumerate() {
        if until >= slots && until != 0 {
            events.push(FaultEvent {
                time_ms: until as f64 * slot_ms,
                kind: FaultKind::LinkUp { link: l },
            });
        }
    }
    FaultSchedule::from_events(events)
}

fn compile_load_correlated(
    topo: &Topology,
    slots: usize,
    slot_ms: f64,
    num_core: usize,
    base_rate: f64,
    load_curve: &[f64],
    seed: u64,
) -> FaultSchedule {
    let mut rng = Xoshiro256::seed_from(seed ^ 0x10AD_FA17);
    let ess: Vec<usize> = topo.ess().collect();
    if ess.is_empty() || num_core == 0 || base_rate <= 0.0 {
        return FaultSchedule::none();
    }
    let mut events = Vec::new();
    for slot in 0..slots {
        let mult = load_curve.get(slot).copied().unwrap_or(1.0);
        let p = (base_rate * mult).clamp(0.0, 0.9);
        if rng.next_f64() < p {
            let node = ess[rng.range_usize(0, ess.len() - 1)];
            let core_idx = rng.range_usize(0, num_core - 1);
            events.push(FaultEvent {
                time_ms: slot as f64 * slot_ms,
                kind: FaultKind::CoreReplicaFail { node, core_idx },
            });
        }
    }
    FaultSchedule::from_events(events)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;

    fn topo(seed: u64) -> Topology {
        topo_with_ess(seed, ExperimentConfig::paper_default().network.num_ess).0
    }

    /// The paper-default backbone has 4 ESs, capping concurrent downs at
    /// 1 — zone correlation needs a rack large enough that a whole zone
    /// fits under the backbone-majority cap, so tests build their own.
    fn topo_with_ess(seed: u64, num_ess: usize) -> (Topology, ExperimentConfig) {
        let mut cfg = ExperimentConfig::paper_default();
        cfg.network.num_ess = num_ess;
        let mut rng = Xoshiro256::seed_from(seed);
        let t = Topology::generate(&cfg, &mut rng);
        (t, cfg)
    }

    fn replay_invariants(cfg: &ExperimentConfig, s: &FaultSchedule) {
        let cap = ((cfg.network.num_ess - 1) / 2).max(1);
        let mut last = 0.0;
        let mut down = std::collections::BTreeSet::new();
        for ev in s.events() {
            assert!(ev.time_ms >= last, "time-sorted");
            last = ev.time_ms;
            match ev.kind {
                FaultKind::NodeDown { node } => {
                    assert!(node >= cfg.network.num_eds, "only ESs fault");
                    assert!(down.insert(node), "double-down of {node}");
                    assert!(down.len() <= cap, "backbone majority violated");
                }
                FaultKind::NodeUp { node } => {
                    assert!(down.remove(&node), "recovery without outage");
                }
                _ => {}
            }
        }
        assert!(down.is_empty(), "unrecovered: {down:?}");
    }

    #[test]
    fn zone_outage_is_correlated_and_well_formed() {
        // 12 ESs -> concurrency cap (12-1)/2 = 5, so a 4-server rack can
        // go dark in one instant (4 ESs would cap at 1 and mask the
        // correlation this test exists to observe).
        let (t, cfg) = topo_with_ess(1, 12);
        let tpl = FaultTemplate::ZoneOutage {
            zones: 3,
            zone_outage_per_slot: 0.02,
            mean_outage_slots: 15.0,
        };
        let s = tpl.compile(&t, 400, 1.0, 6, &[], 9);
        assert!(!s.is_empty(), "rate 0.02 over 400 slots must fire");
        replay_invariants(&cfg, &s);
        // Correlation: some instant takes more than one server down at
        // exactly the same timestamp (independent faults almost never do).
        let mut best = 0usize;
        let mut i = 0;
        let evs = s.events();
        while i < evs.len() {
            let t0 = evs[i].time_ms;
            let burst = evs[i..]
                .iter()
                .take_while(|e| e.time_ms == t0)
                .filter(|e| matches!(e.kind, FaultKind::NodeDown { .. }))
                .count();
            best = best.max(burst);
            i += evs[i..].iter().take_while(|e| e.time_ms == t0).count();
        }
        assert!(best >= 2, "no simultaneous rack outage observed");
        // Determinism.
        let s2 = tpl.compile(&t, 400, 1.0, 6, &[], 9);
        assert_eq!(s.events(), s2.events());
        let s3 = tpl.compile(&t, 400, 1.0, 6, &[], 10);
        assert_ne!(s.events(), s3.events(), "seed must matter");
    }

    #[test]
    fn cascading_links_burst_at_one_instant() {
        let t = topo(2);
        let tpl = FaultTemplate::CascadingLinks {
            trigger_per_slot: 0.01,
            cascade_p: 0.5,
            max_depth: 2,
            mean_outage_slots: 10.0,
        };
        let s = tpl.compile(&t, 500, 1.0, 6, &[], 11);
        assert!(!s.is_empty());
        // Every LinkDown has its LinkUp; no double-down.
        let mut down = std::collections::BTreeSet::new();
        let mut best = 0usize;
        let mut cur_t = f64::NEG_INFINITY;
        let mut cur = 0usize;
        for ev in s.events() {
            match ev.kind {
                FaultKind::LinkDown { link } => {
                    assert!(down.insert(link), "double-down of link {link}");
                    if ev.time_ms == cur_t {
                        cur += 1;
                    } else {
                        cur_t = ev.time_ms;
                        cur = 1;
                    }
                    best = best.max(cur);
                }
                FaultKind::LinkUp { link } => {
                    assert!(down.remove(&link));
                }
                other => panic!("unexpected event {other:?}"),
            }
        }
        assert!(down.is_empty(), "unrecovered links: {down:?}");
        assert!(best >= 2, "a cascade must fail >1 link at one instant");
    }

    #[test]
    fn load_correlated_tracks_the_curve() {
        let t = topo(3);
        let tpl = FaultTemplate::LoadCorrelated { base_rate: 0.05 };
        // Quiet first half, 4x overload second half.
        let slots = 2000;
        let curve: Vec<f64> = (0..slots)
            .map(|s| if s < slots / 2 { 0.25 } else { 4.0 })
            .collect();
        let s = tpl.compile(&t, slots, 1.0, 6, &curve, 13);
        let half_t = (slots / 2) as f64;
        let early = s.events().iter().filter(|e| e.time_ms < half_t).count();
        let late = s.events().iter().filter(|e| e.time_ms >= half_t).count();
        assert!(
            late > 3 * early,
            "overload half must fail far more often ({early} vs {late})"
        );
        for ev in s.events() {
            assert!(matches!(ev.kind, FaultKind::CoreReplicaFail { .. }));
        }
    }

    #[test]
    fn none_and_zero_rate_templates_are_empty() {
        let t = topo(4);
        assert!(FaultTemplate::None.compile(&t, 100, 1.0, 6, &[], 1).is_empty());
        assert!(FaultTemplate::Independent { rate: 0.0 }
            .compile(&t, 100, 1.0, 6, &[], 1)
            .is_empty());
        assert!(FaultTemplate::ZoneOutage {
            zones: 3,
            zone_outage_per_slot: 0.0,
            mean_outage_slots: 10.0
        }
        .compile(&t, 100, 1.0, 6, &[], 1)
        .is_empty());
    }
}
