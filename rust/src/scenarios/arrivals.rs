//! Non-stationary arrival processes: per-slot rate multipliers layered on
//! top of the stationary Poisson workload of §II-B.
//!
//! Edge workloads are not stationary — diurnal cycles, bursty on-off
//! sources, and flash crowds are the regimes the robustness claims must
//! survive. Each family realizes a deterministic-per-seed multiplier
//! curve `c[t]`; the scenario compiler then draws slot `t`'s arrivals as
//! `Poisson(rate * load * c[t])` through the unchanged
//! [`crate::workload::WorkloadGenerator`], so both engines ingest the
//! resulting [`crate::workload::Trace`] with no engine changes.

use crate::rng::Rng;

/// A non-stationary arrival-rate modulation family.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ArrivalProcess {
    /// The paper's baseline: constant multiplier 1.
    Stationary,
    /// Diurnal sinusoid: `1 + amplitude * sin(2π (t / period + phase))`,
    /// floored at 0.05 (a quiet hour still trickles).
    Diurnal {
        period_slots: usize,
        /// Peak-to-mean swing, in (0, 1) for a non-degenerate trough.
        amplitude: f64,
        /// Phase offset as a fraction of the period.
        phase: f64,
    },
    /// Two-state Markov-modulated Poisson process (bursty on-off): the
    /// multiplier alternates between `burst_mult` and `quiet_mult`, with
    /// geometric state holding times (means in slots).
    Mmpp {
        burst_mult: f64,
        quiet_mult: f64,
        mean_burst_slots: f64,
        mean_quiet_slots: f64,
    },
    /// Flash crowd: baseline 1 until `start_frac * slots`, linear ramp to
    /// `peak_mult` over `ramp_slots`, hold for `hold_slots`, linear decay
    /// back to 1 over `decay_slots`.
    FlashCrowd {
        start_frac: f64,
        ramp_slots: usize,
        peak_mult: f64,
        hold_slots: usize,
        decay_slots: usize,
    },
}

impl ArrivalProcess {
    /// Realize the multiplier curve for `slots` slots. Stochastic
    /// families (MMPP state path) draw from `rng`; deterministic families
    /// ignore it, so the curve is reproducible per scenario seed either
    /// way.
    pub fn multipliers<R: Rng + ?Sized>(&self, slots: usize, rng: &mut R) -> Vec<f64> {
        match *self {
            ArrivalProcess::Stationary => vec![1.0; slots],
            ArrivalProcess::Diurnal {
                period_slots,
                amplitude,
                phase,
            } => {
                let period = period_slots.max(1) as f64;
                (0..slots)
                    .map(|t| {
                        let x = 2.0 * std::f64::consts::PI * (t as f64 / period + phase);
                        (1.0 + amplitude * x.sin()).max(0.05)
                    })
                    .collect()
            }
            ArrivalProcess::Mmpp {
                burst_mult,
                quiet_mult,
                mean_burst_slots,
                mean_quiet_slots,
            } => {
                let p_leave_burst = 1.0 / mean_burst_slots.max(1.0);
                let p_leave_quiet = 1.0 / mean_quiet_slots.max(1.0);
                let mut bursting = false;
                (0..slots)
                    .map(|_| {
                        let p = if bursting { p_leave_burst } else { p_leave_quiet };
                        if rng.next_f64() < p {
                            bursting = !bursting;
                        }
                        if bursting {
                            burst_mult
                        } else {
                            quiet_mult
                        }
                    })
                    .collect()
            }
            ArrivalProcess::FlashCrowd {
                start_frac,
                ramp_slots,
                peak_mult,
                hold_slots,
                decay_slots,
            } => {
                let start = (start_frac.clamp(0.0, 1.0) * slots as f64) as usize;
                let ramp = ramp_slots.max(1);
                let decay = decay_slots.max(1);
                (0..slots)
                    .map(|t| {
                        if t < start {
                            1.0
                        } else if t < start + ramp {
                            let f = (t - start) as f64 / ramp as f64;
                            1.0 + f * (peak_mult - 1.0)
                        } else if t < start + ramp + hold_slots {
                            peak_mult
                        } else if t < start + ramp + hold_slots + decay {
                            let f = (t - start - ramp - hold_slots) as f64 / decay as f64;
                            peak_mult + f * (1.0 - peak_mult)
                        } else {
                            1.0
                        }
                    })
                    .collect()
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;

    #[test]
    fn stationary_is_flat_unit() {
        let mut rng = Xoshiro256::seed_from(1);
        let c = ArrivalProcess::Stationary.multipliers(50, &mut rng);
        assert_eq!(c, vec![1.0; 50]);
    }

    #[test]
    fn diurnal_oscillates_around_one_with_positive_floor() {
        let mut rng = Xoshiro256::seed_from(2);
        let p = ArrivalProcess::Diurnal {
            period_slots: 100,
            amplitude: 0.6,
            phase: 0.0,
        };
        let c = p.multipliers(200, &mut rng);
        let mean = c.iter().sum::<f64>() / c.len() as f64;
        assert!((mean - 1.0).abs() < 0.05, "mean≈1, got {mean}");
        assert!(c.iter().all(|&x| x > 0.0));
        let max = c.iter().cloned().fold(0.0f64, f64::max);
        let min = c.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(max > 1.5 && min < 0.5, "swing missing: [{min}, {max}]");
    }

    #[test]
    fn mmpp_visits_both_states_and_is_seed_deterministic() {
        let p = ArrivalProcess::Mmpp {
            burst_mult: 2.5,
            quiet_mult: 0.4,
            mean_burst_slots: 10.0,
            mean_quiet_slots: 20.0,
        };
        let c1 = p.multipliers(500, &mut Xoshiro256::seed_from(3));
        let c2 = p.multipliers(500, &mut Xoshiro256::seed_from(3));
        assert_eq!(c1, c2, "same seed must replay the same state path");
        assert!(c1.iter().any(|&x| x == 2.5), "never bursts");
        assert!(c1.iter().any(|&x| x == 0.4), "never quiets");
        let c3 = p.multipliers(500, &mut Xoshiro256::seed_from(4));
        assert_ne!(c1, c3, "seed must matter");
    }

    #[test]
    fn flash_crowd_has_the_expected_shape() {
        let mut rng = Xoshiro256::seed_from(5);
        let p = ArrivalProcess::FlashCrowd {
            start_frac: 0.25,
            ramp_slots: 10,
            peak_mult: 3.0,
            hold_slots: 20,
            decay_slots: 10,
        };
        let c = p.multipliers(200, &mut rng);
        assert_eq!(c[0], 1.0);
        assert_eq!(c[49], 1.0); // just before the 25% mark
        assert_eq!(c[60], 3.0); // inside the hold
        assert_eq!(c[199], 1.0); // long after the decay
        assert!(c[55] > 1.0 && c[55] < 3.0, "mid-ramp");
    }
}
