//! Scenario library: composable, seeded workload/fault ensembles.
//!
//! The paper's headline claims (>84% on-time completion, robustness as
//! load scales) are statements about *ensembles* of conditions, not one
//! hand-built workload/fault pair. A [`ScenarioSpec`] composes three
//! orthogonal axes —
//!
//! * a non-stationary [`ArrivalProcess`] (diurnal sinusoid, MMPP
//!   burstiness, flash crowd) modulating the Poisson workload,
//! * a [`MobilityModel`] (random waypoint, commuter) that re-homes users'
//!   task streams between edge devices mid-trial,
//! * a correlated [`FaultTemplate`] (zone/rack outages, cascading link
//!   failures, load-correlated fail-stop)
//!
//! — and [`ScenarioSpec::compile`]s them into exactly the two artifacts
//! both engines already ingest: a [`Trace`] and a
//! [`crate::faults::FaultSchedule`]. The slotted engine and the DES
//! therefore replay *identical* scenarios with no engine changes, via
//! [`crate::sim::run_trial_faulted`] / [`crate::des::run_des_trial_faulted`].
//!
//! All randomness derives statelessly from the scenario seed through
//! [`crate::rng::stream_seed`], so compiling scenario `k` of a sweep never
//! depends on how many scenarios were compiled before it.

mod arrivals;
mod mobility;
mod outages;

pub use arrivals::ArrivalProcess;
pub use mobility::{MobilityModel, MobilityTimeline, UserMove};
pub use outages::FaultTemplate;

use crate::faults::FaultSchedule;
use crate::rng::{stream_seed, Xoshiro256};
use crate::sim::{SimEnv, SimOptions};
use crate::workload::{Trace, WorkloadGenerator};

/// Stream tags for [`stream_seed`] (arbitrary distinct constants).
const STREAM_CURVE: u64 = 0x01;
const STREAM_ARRIVALS: u64 = 0x02;
const STREAM_MOBILITY: u64 = 0x03;
const STREAM_FAULTS: u64 = 0x04;

/// One member of the scenario library.
#[derive(Clone, Debug, PartialEq)]
pub struct ScenarioSpec {
    /// Library name (kebab-case, stable — CLI and CSV key).
    pub name: String,
    pub arrivals: ArrivalProcess,
    pub mobility: MobilityModel,
    pub faults: FaultTemplate,
}

/// A realized scenario: everything an engine needs to replay it.
#[derive(Clone, Debug)]
pub struct CompiledScenario {
    pub trace: Trace,
    pub faults: FaultSchedule,
    /// Realized per-slot arrival multiplier (full horizon).
    pub load_curve: Vec<f64>,
    /// User re-homings applied while generating the trace.
    pub user_moves: usize,
}

impl ScenarioSpec {
    fn new(
        name: &str,
        arrivals: ArrivalProcess,
        mobility: MobilityModel,
        faults: FaultTemplate,
    ) -> Self {
        ScenarioSpec {
            name: name.to_string(),
            arrivals,
            mobility,
            faults,
        }
    }

    /// Stationary Poisson, static users, no faults — the seed repo's
    /// implicit scenario, kept as the ensemble's control.
    pub fn baseline() -> Self {
        Self::new(
            "baseline",
            ArrivalProcess::Stationary,
            MobilityModel::Static,
            FaultTemplate::None,
        )
    }

    /// Day/night sinusoid (period spans the horizon's order of magnitude).
    pub fn diurnal() -> Self {
        Self::new(
            "diurnal",
            ArrivalProcess::Diurnal {
                period_slots: 200,
                amplitude: 0.6,
                phase: 0.0,
            },
            MobilityModel::Static,
            FaultTemplate::None,
        )
    }

    /// Bursty on-off (MMPP) arrivals.
    pub fn mmpp() -> Self {
        Self::new(
            "mmpp",
            ArrivalProcess::Mmpp {
                burst_mult: 2.5,
                quiet_mult: 0.4,
                mean_burst_slots: 20.0,
                mean_quiet_slots: 40.0,
            },
            MobilityModel::Static,
            FaultTemplate::None,
        )
    }

    /// Sudden 3x flash crowd a quarter into the horizon.
    pub fn flash_crowd() -> Self {
        Self::new(
            "flash-crowd",
            ArrivalProcess::FlashCrowd {
                start_frac: 0.25,
                ramp_slots: 10,
                peak_mult: 3.0,
                hold_slots: 30,
                decay_slots: 20,
            },
            MobilityModel::Static,
            FaultTemplate::None,
        )
    }

    /// Random-waypoint ED churn under stationary load.
    pub fn mobility() -> Self {
        Self::new(
            "mobility",
            ArrivalProcess::Stationary,
            MobilityModel::RandomWaypoint {
                mean_dwell_slots: 40.0,
            },
            FaultTemplate::None,
        )
    }

    /// Lock-step commuter churn (everyone re-homes at once).
    pub fn commuter() -> Self {
        Self::new(
            "commuter",
            ArrivalProcess::Stationary,
            MobilityModel::Commuter {
                half_period_slots: 60,
            },
            FaultTemplate::None,
        )
    }

    /// Rack-correlated server outages under stationary load.
    ///
    /// The engines cap concurrent ES downs at `(num_ess - 1) / 2` (min
    /// 1) so a backbone majority survives; rack *correlation* is only
    /// observable when a whole zone fits under that cap. The paper
    /// default's 4 ESs cap at 1 — there this template degenerates to
    /// independent single-server outages. Run §P5 with a config of
    /// `network.num_ess >= 8` to actually measure correlated damage.
    pub fn zone_outage() -> Self {
        Self::new(
            "zone-outage",
            ArrivalProcess::Stationary,
            MobilityModel::Static,
            FaultTemplate::ZoneOutage {
                zones: 3,
                zone_outage_per_slot: 0.004,
                mean_outage_slots: 20.0,
            },
        )
    }

    /// Cascading link failures under stationary load.
    pub fn cascade() -> Self {
        Self::new(
            "cascade",
            ArrivalProcess::Stationary,
            MobilityModel::Static,
            FaultTemplate::CascadingLinks {
                trigger_per_slot: 0.003,
                cascade_p: 0.35,
                max_depth: 2,
                mean_outage_slots: 15.0,
            },
        )
    }

    /// The composite stress case: diurnal load, commuter churn, and
    /// load-correlated replica fail-stop — failures cluster at rush hour.
    pub fn rush_hour() -> Self {
        Self::new(
            "rush-hour",
            ArrivalProcess::Diurnal {
                period_slots: 200,
                amplitude: 0.6,
                phase: 0.75,
            },
            MobilityModel::Commuter {
                half_period_slots: 100,
            },
            FaultTemplate::LoadCorrelated { base_rate: 0.01 },
        )
    }

    /// Metro-scale composite (§P8): the million-user throughput target.
    /// Diurnal load with commuter churn and rack-correlated outages —
    /// the same composite stress as [`Self::rush_hour`] but built for
    /// scale runs: pair it with a config raising `workload.num_users`
    /// (10^5–10^6) and the DES in streaming-metrics mode. The spec
    /// itself adds no per-user state; compiled size is all in the trace.
    pub fn metro_1m() -> Self {
        Self::new(
            "metro-1m",
            ArrivalProcess::Diurnal {
                period_slots: 400,
                amplitude: 0.5,
                phase: 0.25,
            },
            MobilityModel::Commuter {
                half_period_slots: 120,
            },
            FaultTemplate::ZoneOutage {
                zones: 3,
                zone_outage_per_slot: 0.002,
                mean_outage_slots: 25.0,
            },
        )
    }

    /// The full library, in presentation order.
    pub fn library() -> Vec<ScenarioSpec> {
        vec![
            Self::baseline(),
            Self::diurnal(),
            Self::mmpp(),
            Self::flash_crowd(),
            Self::mobility(),
            Self::commuter(),
            Self::zone_outage(),
            Self::cascade(),
            Self::rush_hour(),
            Self::metro_1m(),
        ]
    }

    /// Look up a library scenario by its stable name.
    pub fn by_name(name: &str) -> Option<ScenarioSpec> {
        Self::library().into_iter().find(|s| s.name == name)
    }

    /// Realize the scenario against an environment: generate the trace
    /// (arrivals stop at `opts.arrival_cutoff`, mirroring
    /// [`crate::sim::record_trace`]) and compile the fault schedule over
    /// the full horizon. Deterministic per `(env, opts, seed)`; every
    /// random sub-stream is derived statelessly from `seed` via
    /// [`stream_seed`].
    pub fn compile(&self, env: &SimEnv, opts: &SimOptions, seed: u64) -> CompiledScenario {
        let mut curve_rng = Xoshiro256::seed_from(stream_seed(seed, STREAM_CURVE, 0));
        let load_curve = self.arrivals.multipliers(opts.slots, &mut curve_rng);

        // Same user population every engine and the placement scorer see.
        let mut gen = WorkloadGenerator::new(
            &env.cfg,
            &env.app,
            &env.topo,
            &mut Xoshiro256::seed_from(env.users_seed),
        );
        let eds: Vec<usize> = env.topo.eds().collect();
        let initial_homes: Vec<usize> = gen.users().iter().map(|u| u.ed).collect();
        let mut mob_rng = Xoshiro256::seed_from(stream_seed(seed, STREAM_MOBILITY, 0));
        let timeline = self
            .mobility
            .compile(&initial_homes, &eds, opts.slots, &mut mob_rng);

        let mut arr_rng = Xoshiro256::seed_from(stream_seed(seed, STREAM_ARRIVALS, 0));
        let cutoff = opts.slots.min(opts.arrival_cutoff);
        let mut arrivals = Vec::new();
        let mut cursor = 0usize;
        let mut applied = 0usize;
        for slot in 0..cutoff {
            while cursor < timeline.len() && timeline.moves()[cursor].slot <= slot {
                let m = timeline.moves()[cursor];
                gen.set_user_ed(m.user, m.new_ed);
                cursor += 1;
                applied += 1;
            }
            let mult = opts.load_multiplier * load_curve[slot];
            arrivals.extend(gen.generate_slot(slot, mult, &mut arr_rng));
        }

        let faults = self.faults.compile(
            &env.topo,
            opts.slots,
            opts.slot_ms,
            env.app.catalog.num_core(),
            &load_curve,
            stream_seed(seed, STREAM_FAULTS, 0),
        );

        CompiledScenario {
            trace: Trace::from_arrivals(arrivals),
            faults,
            load_curve,
            user_moves: applied,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;

    fn small_env(seed: u64) -> (SimEnv, SimOptions) {
        let mut cfg = ExperimentConfig::paper_default();
        cfg.sim.slots = 100;
        cfg.workload.num_users = 8;
        cfg.controller.effcap_samples = 256;
        let env = SimEnv::build(&cfg, seed);
        let opts = SimOptions::from_config(&cfg);
        (env, opts)
    }

    #[test]
    fn library_names_are_unique_and_resolvable() {
        let lib = ScenarioSpec::library();
        let mut names = std::collections::BTreeSet::new();
        for s in &lib {
            assert!(names.insert(s.name.clone()), "duplicate {}", s.name);
            assert_eq!(ScenarioSpec::by_name(&s.name).as_ref(), Some(s));
        }
        assert!(ScenarioSpec::by_name("no-such-scenario").is_none());
    }

    #[test]
    fn compile_is_deterministic_per_seed() {
        let (env, opts) = small_env(1);
        for spec in ScenarioSpec::library() {
            let a = spec.compile(&env, &opts, 42);
            let b = spec.compile(&env, &opts, 42);
            assert_eq!(a.trace.len(), b.trace.len(), "{}", spec.name);
            for (x, y) in a.trace.arrivals().iter().zip(b.trace.arrivals()) {
                assert_eq!(x.id, y.id);
                assert_eq!(x.ed, y.ed);
                assert_eq!(x.slot, y.slot);
                assert_eq!(x.snr.to_bits(), y.snr.to_bits(), "{}", spec.name);
            }
            assert_eq!(a.faults.events(), b.faults.events(), "{}", spec.name);
            assert_eq!(a.user_moves, b.user_moves);
            assert_eq!(a.load_curve, b.load_curve);
        }
    }

    #[test]
    fn seed_matters() {
        let (env, opts) = small_env(2);
        let spec = ScenarioSpec::mmpp();
        let a = spec.compile(&env, &opts, 1);
        let b = spec.compile(&env, &opts, 2);
        let same = a.trace.len() == b.trace.len()
            && a.trace
                .arrivals()
                .iter()
                .zip(b.trace.arrivals())
                .all(|(x, y)| x.slot == y.slot && x.snr == y.snr);
        assert!(!same, "different seeds must realize different traces");
    }

    #[test]
    fn mobility_rehomes_arrivals_mid_trace() {
        let (env, mut opts) = small_env(3);
        // Horizon wide enough that the commuter flips (every 60 slots)
        // land inside the arrival window — at 100 slots the cutoff is 25
        // and no move would ever be applied.
        opts.slots = 300;
        opts.arrival_cutoff = 250;
        let cs = ScenarioSpec::commuter().compile(&env, &opts, 7);
        assert!(cs.user_moves > 0, "commuter must move users");
        // Some user's arrivals must appear at two different EDs.
        let mut seen: std::collections::BTreeMap<usize, std::collections::BTreeSet<usize>> =
            std::collections::BTreeMap::new();
        for a in cs.trace.arrivals() {
            seen.entry(a.user).or_default().insert(a.ed);
        }
        assert!(
            seen.values().any(|eds| eds.len() > 1),
            "no arrival stream actually re-homed"
        );
    }

    #[test]
    fn baseline_matches_stationary_static_faultless() {
        let (env, opts) = small_env(4);
        let cs = ScenarioSpec::baseline().compile(&env, &opts, 9);
        assert!(cs.faults.is_empty());
        assert_eq!(cs.user_moves, 0);
        assert!(cs.load_curve.iter().all(|&c| c == 1.0));
        assert!(!cs.trace.is_empty());
    }

    #[test]
    fn flash_crowd_concentrates_arrivals() {
        let (mut env, mut opts) = small_env(5);
        // Long horizon so the crowd window is wide and inside the cutoff.
        env.cfg.sim.slots = 300;
        opts.slots = 300;
        opts.arrival_cutoff = 280;
        let cs = ScenarioSpec::flash_crowd().compile(&env, &opts, 11);
        // Peak window [75, 115) vs an equal-width quiet window [200, 240).
        let count = |lo: usize, hi: usize| {
            cs.trace
                .arrivals()
                .iter()
                .filter(|a| a.slot >= lo && a.slot < hi)
                .count()
        };
        let peak = count(75, 115);
        let quiet = count(200, 240);
        assert!(
            peak as f64 > 1.6 * quiet as f64,
            "flash crowd must dominate: peak {peak} vs quiet {quiet}"
        );
    }
}
