//! User mobility / ED churn: re-home a user's task stream between edge
//! devices mid-trial.
//!
//! A mobility model compiles to a [`MobilityTimeline`] — a slot-sorted
//! list of `(slot, user, new_ed)` moves — that the scenario compiler
//! applies while generating the trace: each arrival is stamped with the
//! user's *current* ingress ED, so the engines replay churn through the
//! trace alone and need no knowledge of the model.

use crate::faults::geometric_slots;
use crate::network::NodeId;
use crate::rng::Rng;

/// A user-mobility family.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum MobilityModel {
    /// No movement (the paper's implicit baseline).
    Static,
    /// Random waypoint over EDs: each user dwells a geometric number of
    /// slots (given mean), then re-homes to a uniformly random *other*
    /// edge device.
    RandomWaypoint { mean_dwell_slots: f64 },
    /// Commuter oscillation: each user flips between its home ED and one
    /// fixed "work" ED every `half_period_slots` slots (rush-hour churn —
    /// many users re-home at the same instants).
    Commuter { half_period_slots: usize },
}

/// One re-homing event.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct UserMove {
    /// The move takes effect at the start of this slot.
    pub slot: usize,
    pub user: usize,
    pub new_ed: NodeId,
}

/// Slot-sorted, replayable re-homing schedule.
#[derive(Clone, Debug, Default)]
pub struct MobilityTimeline {
    moves: Vec<UserMove>,
}

impl MobilityTimeline {
    pub fn moves(&self) -> &[UserMove] {
        &self.moves
    }

    pub fn len(&self) -> usize {
        self.moves.len()
    }

    pub fn is_empty(&self) -> bool {
        self.moves.is_empty()
    }
}

impl MobilityModel {
    /// Compile the per-user move schedule over `slots` slots.
    /// `initial_homes[u]` is user `u`'s starting ED (the workload
    /// generator's round-robin attachment); `eds` is the ED population.
    /// Deterministic per `rng` state; moves are sorted by `(slot, user)`.
    pub fn compile<R: Rng + ?Sized>(
        &self,
        initial_homes: &[NodeId],
        eds: &[NodeId],
        slots: usize,
        rng: &mut R,
    ) -> MobilityTimeline {
        let mut moves = Vec::new();
        match *self {
            MobilityModel::Static => {}
            MobilityModel::RandomWaypoint { mean_dwell_slots } => {
                if eds.len() < 2 {
                    return MobilityTimeline::default();
                }
                for (u, &home) in initial_homes.iter().enumerate() {
                    let mut cur = home;
                    let mut t = 0usize;
                    loop {
                        let dwell = geometric_slots(rng, mean_dwell_slots);
                        t += dwell;
                        if t >= slots {
                            break;
                        }
                        // Uniform over the *other* EDs.
                        let mut pick = eds[rng.range_usize(0, eds.len() - 1)];
                        while pick == cur {
                            pick = eds[rng.range_usize(0, eds.len() - 1)];
                        }
                        cur = pick;
                        moves.push(UserMove {
                            slot: t,
                            user: u,
                            new_ed: cur,
                        });
                    }
                }
            }
            MobilityModel::Commuter { half_period_slots } => {
                if eds.len() < 2 {
                    return MobilityTimeline::default();
                }
                let half = half_period_slots.max(1);
                // One fixed "work" ED per user, distinct from home.
                let works: Vec<NodeId> = initial_homes
                    .iter()
                    .map(|&home| {
                        let mut pick = eds[rng.range_usize(0, eds.len() - 1)];
                        while pick == home {
                            pick = eds[rng.range_usize(0, eds.len() - 1)];
                        }
                        pick
                    })
                    .collect();
                let mut t = half;
                let mut at_work = false;
                while t < slots {
                    at_work = !at_work;
                    for (u, &home) in initial_homes.iter().enumerate() {
                        moves.push(UserMove {
                            slot: t,
                            user: u,
                            new_ed: if at_work { works[u] } else { home },
                        });
                    }
                    t += half;
                }
            }
        }
        moves.sort_by_key(|m| (m.slot, m.user));
        MobilityTimeline { moves }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;

    fn homes(n: usize, eds: &[NodeId]) -> Vec<NodeId> {
        (0..n).map(|u| eds[u % eds.len()]).collect()
    }

    #[test]
    fn static_model_never_moves() {
        let eds = [0, 1, 2, 3];
        let tl = MobilityModel::Static.compile(
            &homes(6, &eds),
            &eds,
            500,
            &mut Xoshiro256::seed_from(1),
        );
        assert!(tl.is_empty());
    }

    #[test]
    fn random_waypoint_moves_to_other_eds_and_is_deterministic() {
        let eds = [0, 1, 2, 3];
        let h = homes(6, &eds);
        let model = MobilityModel::RandomWaypoint {
            mean_dwell_slots: 20.0,
        };
        let a = model.compile(&h, &eds, 400, &mut Xoshiro256::seed_from(2));
        let b = model.compile(&h, &eds, 400, &mut Xoshiro256::seed_from(2));
        assert_eq!(a.moves(), b.moves(), "same seed ⇒ same timeline");
        assert!(!a.is_empty(), "400 slots at mean dwell 20 must move");
        // Moves are sorted, in-horizon, and each user's chain never
        // "moves" to the ED it is already on.
        let mut cur = h.clone();
        let mut last_slot = 0;
        for m in a.moves() {
            assert!(m.slot >= last_slot);
            last_slot = m.slot;
            assert!(m.slot < 400);
            assert!(eds.contains(&m.new_ed));
            assert_ne!(cur[m.user], m.new_ed, "no-op move for user {}", m.user);
            cur[m.user] = m.new_ed;
        }
    }

    #[test]
    fn commuter_flips_everyone_in_lockstep_and_returns_home() {
        let eds = [0, 1, 2];
        let h = homes(4, &eds);
        let model = MobilityModel::Commuter {
            half_period_slots: 50,
        };
        let tl = model.compile(&h, &eds, 200, &mut Xoshiro256::seed_from(3));
        // Flips at slots 50, 100, 150 — every user each time.
        assert_eq!(tl.len(), 3 * 4);
        let back_home: Vec<&UserMove> =
            tl.moves().iter().filter(|m| m.slot == 100).collect();
        for m in back_home {
            assert_eq!(m.new_ed, h[m.user], "even flips return home");
        }
    }

    #[test]
    fn single_ed_degenerates_to_static() {
        let eds = [0];
        let model = MobilityModel::RandomWaypoint {
            mean_dwell_slots: 5.0,
        };
        let tl = model.compile(&homes(3, &eds), &eds, 100, &mut Xoshiro256::seed_from(4));
        assert!(tl.is_empty(), "nowhere to move");
    }
}
