//! Elastic replica pools with shared-rate contention (EXPERIMENTS §P10).
//!
//! The paper fixes light-service parallelism `y` per decision epoch; a
//! production serving tier scales replica pools elastically instead.
//! This module supplies both halves of that story, shared by the slotted
//! and DES engines behind an `Option`-gated [`PoolConfig`] (off ⇒ every
//! existing number is byte-identical — the pool path is never entered):
//!
//! * [`PoolManager`] — a deterministic desired-instances controller per
//!   (node, light service): grow through a seeded cold-start window
//!   (a warming replica serves nothing until its ready time), shrink via
//!   drain-before-kill (a replica marked for retirement keeps serving
//!   until the in-flight count allows its removal — in-flight work is
//!   never abandoned, mirroring the failover tier's shed-new-only
//!   invariant), and scale-to-zero after a configurable idle window.
//!   Decisions come from a pluggable [`ScalingPolicy`] with hysteresis
//!   and per-station cooldown.
//! * [`SharedRate`] — the contention model: all in-flight executions at a
//!   station share its warm replicas, so the per-execution rate divisor
//!   is the *live* occupancy ratio `max(1, n/R)^α` instead of the static
//!   committed `y`. Occupancy changes stretch or shrink executions that
//!   are already in flight: the DES keeps remaining-work bookkeeping
//!   (struct-of-arrays, reusable across trials like the rest of
//!   [`crate::des::DesArena`]) and reschedules completion events; the
//!   slotted engine divides rates per slot at the previous boundary's
//!   occupancy. [`live_delay_bound`] evaluates the paper's `g_{m,ε}`
//!   machinery ([`EffCapEstimator::delay_bound_contended`]) at that same
//!   live divisor, so the reported bound tracks actual contention.
//! * [`Autoscale`] — a [`Strategy`] that delegates placement and routing
//!   to the paper's Proposal but commits `y = 1` everywhere: parallelism
//!   comes from the pool growing replicas, not from the controller
//!   splitting one instance — the fixed-`y` Lyapunov controller versus
//!   this strategy is the §P10 comparison axis.

use crate::baselines::Proposal;
use crate::config::NUM_RESOURCES;
use crate::controller::{LightDecision, LightRequest};
use crate::effcap::EffCapEstimator;
use crate::metrics::Histogram;
use crate::placement::{CorePlacement, QosScores};
use crate::rng::{Rng, Xoshiro256};
use crate::routing::DistanceMatrix;
use crate::sim::{SimEnv, Strategy};

/// Elastic-pool configuration, `Option`-gated on both engines' options.
#[derive(Clone, Debug)]
pub struct PoolConfig {
    /// The desired-instances policy driving grow/shrink decisions.
    pub policy: ScalingPolicy,
    /// Floor on warm replicas per (node, service); `0` permits
    /// scale-to-zero.
    pub min_replicas: u32,
    /// Ceiling on total (warm + warming) replicas per (node, service).
    pub max_replicas: u32,
    /// Replicas pre-warmed at trial start per (node, service) — no
    /// cold-start window is charged for these.
    pub initial_replicas: u32,
    /// Base cold-start window: a newly grown replica serves nothing for
    /// this long.
    pub cold_start_ms: f64,
    /// Uniform jitter added on top of the base window, drawn from the
    /// pool's own seeded stream (so cold starts never perturb engine RNG).
    pub cold_start_jitter_ms: f64,
    /// Contention exponent of the shared-rate divisor `(n/R)^α` — mirror
    /// of `controller.contention_alpha` so both models agree.
    pub alpha: f64,
}

impl Default for PoolConfig {
    fn default() -> Self {
        PoolConfig {
            policy: ScalingPolicy::default(),
            min_replicas: 0,
            max_replicas: 8,
            initial_replicas: 0,
            cold_start_ms: 40.0,
            cold_start_jitter_ms: 10.0,
            alpha: 1.0,
        }
    }
}

impl PoolConfig {
    /// Default pool tied to the experiment config's contention exponent.
    pub fn from_config(cfg: &crate::config::ExperimentConfig) -> Self {
        PoolConfig {
            alpha: cfg.controller.contention_alpha,
            ..PoolConfig::default()
        }
    }
}

/// Pluggable desired-instances policy. Both variants carry a cooldown
/// (slots to wait after any scaling action) and an idle window after
/// which the station scales to zero (`0` disables scale-to-zero).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ScalingPolicy {
    /// Track a target per-replica utilization: desired = ⌈n / target⌉,
    /// gated by a hysteresis band so the pool doesn't thrash around the
    /// target.
    TargetUtilization {
        target: f64,
        hysteresis: f64,
        cooldown_slots: u32,
        idle_slots_to_zero: u32,
    },
    /// Step growth/shrink on queue pressure (in-flight + backlog)
    /// relative to the current pool size.
    BacklogThreshold {
        grow_above: f64,
        shrink_below: f64,
        cooldown_slots: u32,
        idle_slots_to_zero: u32,
    },
}

impl Default for ScalingPolicy {
    fn default() -> Self {
        ScalingPolicy::TargetUtilization {
            target: 0.7,
            hysteresis: 0.15,
            cooldown_slots: 3,
            idle_slots_to_zero: 12,
        }
    }
}

impl ScalingPolicy {
    /// Parse a CLI policy name.
    pub fn by_name(name: &str) -> Result<Self, String> {
        match name {
            "target-util" => Ok(ScalingPolicy::default()),
            "backlog" => Ok(ScalingPolicy::BacklogThreshold {
                grow_above: 2.0,
                shrink_below: 0.5,
                cooldown_slots: 3,
                idle_slots_to_zero: 12,
            }),
            other => Err(format!(
                "unknown scaling policy '{other}' (target-util|backlog)"
            )),
        }
    }

    pub fn cooldown_slots(&self) -> u32 {
        match *self {
            ScalingPolicy::TargetUtilization { cooldown_slots, .. }
            | ScalingPolicy::BacklogThreshold { cooldown_slots, .. } => cooldown_slots,
        }
    }

    pub fn idle_slots_to_zero(&self) -> u32 {
        match *self {
            ScalingPolicy::TargetUtilization {
                idle_slots_to_zero, ..
            }
            | ScalingPolicy::BacklogThreshold {
                idle_slots_to_zero, ..
            } => idle_slots_to_zero,
        }
    }

    /// Desired warm-replica count given the live signals. Returns the
    /// current count when inside the hysteresis band (no action).
    pub fn desired(&self, active: u32, in_flight: u32, backlog: u32) -> u32 {
        match *self {
            ScalingPolicy::TargetUtilization {
                target, hysteresis, ..
            } => {
                if in_flight == 0 {
                    return active;
                }
                let demand = in_flight as f64;
                let want = (demand / target.max(1e-9)).ceil().max(1.0) as u32;
                let util = demand / active.max(1) as f64;
                if want > active && (active == 0 || util > target + hysteresis) {
                    want
                } else if want < active && util < target - hysteresis {
                    want
                } else {
                    active
                }
            }
            ScalingPolicy::BacklogThreshold {
                grow_above,
                shrink_below,
                ..
            } => {
                let pressure = in_flight as u64 + backlog as u64;
                if pressure == 0 {
                    return active;
                }
                if active == 0 {
                    return 1;
                }
                let p = pressure as f64;
                if p > grow_above * active as f64 {
                    active + 1
                } else if p < shrink_below * active as f64 {
                    active.saturating_sub(1)
                } else {
                    active
                }
            }
        }
    }
}

/// Deterministic elastic replica pools, one per (node, light service).
///
/// Stepped once per slot boundary by both engines, in sorted `(v, m)`
/// order, with its own seeded RNG stream — the pool never consumes
/// engine RNG, so arming it perturbs nothing outside its own state.
#[derive(Clone, Debug)]
pub struct PoolManager {
    nv: usize,
    nl: usize,
    cfg: PoolConfig,
    /// Warm (serving) replicas per station — includes draining ones,
    /// which keep serving until retired.
    active: Vec<u32>,
    /// Of `active`, how many are marked for drain-before-kill retirement.
    draining: Vec<u32>,
    /// Ready times of warming replicas per station, sorted ascending.
    warming: Vec<Vec<f64>>,
    /// Consecutive idle slots per station (no in-flight, no backlog).
    idle: Vec<u32>,
    /// Slots remaining before the policy may act again.
    cooldown: Vec<u32>,
    node_up: Vec<bool>,
    rng: Xoshiro256,
    /// Cold starts initiated (replicas grown through a warmup window).
    pub cold_starts: u64,
    /// Policy actions taken (each grow or shrink initiation counts once).
    pub scale_events: u64,
    /// Scale-to-zero events (a station idling its whole pool away).
    pub scale_to_zero_events: u64,
    /// Deployment-cost accounting: replica-slot-seconds accumulated over
    /// the horizon (warm + warming replicas × slot duration).
    pub replica_slot_seconds: f64,
    /// Total pool size sampled once per slot (for the p95 column).
    pub size_hist: Histogram,
}

impl PoolManager {
    pub fn new(nv: usize, nl: usize, cfg: PoolConfig, seed: u64) -> Self {
        let n = nv * nl;
        let initial = cfg.initial_replicas.min(cfg.max_replicas);
        PoolManager {
            nv,
            nl,
            active: vec![initial; n],
            draining: vec![0; n],
            warming: vec![Vec::new(); n],
            idle: vec![0; n],
            cooldown: vec![0; n],
            node_up: vec![true; nv],
            rng: Xoshiro256::seed_from(seed ^ 0x9001_CAFE),
            cfg,
            cold_starts: 0,
            scale_events: 0,
            scale_to_zero_events: 0,
            replica_slot_seconds: 0.0,
            size_hist: Histogram::linear(0.0, 512.0, 128),
        }
    }

    #[inline]
    fn st(&self, v: usize, m: usize) -> usize {
        v * self.nl + m
    }

    /// Warm replicas currently able to serve at `(v, m)`.
    pub fn active(&self, v: usize, m: usize) -> u32 {
        self.active[self.st(v, m)]
    }

    /// Warm + warming replicas at `(v, m)` (the deployment-cost base).
    pub fn total(&self, v: usize, m: usize) -> u32 {
        let i = self.st(v, m);
        self.active[i] + self.warming[i].len() as u32
    }

    /// Warm + warming replicas across every station.
    pub fn total_all(&self) -> u32 {
        (0..self.active.len())
            .map(|i| self.active[i] + self.warming[i].len() as u32)
            .sum()
    }

    /// Warm replicas across every station (the telemetry gauge).
    pub fn active_total(&self) -> u32 {
        self.active.iter().sum()
    }

    /// Warming (cold-starting) replicas across every station.
    pub fn warming_total(&self) -> u32 {
        self.warming.iter().map(|w| w.len() as u32).sum()
    }

    /// Promote every warming replica whose ready time has passed — the
    /// slotted engine's slot-boundary promotion (the DES promotes at
    /// exact ready times through `PoolWarm` events + [`Self::warm_fire`]).
    pub fn promote_ready_all(&mut self, now: f64) {
        for i in 0..self.warming.len() {
            let mut k = 0;
            while k < self.warming[i].len() && self.warming[i][k] <= now {
                k += 1;
            }
            if k > 0 {
                self.warming[i].drain(..k);
                self.active[i] += k as u32;
            }
        }
    }

    /// A `PoolWarm` event fired: promote the earliest warming replica
    /// whose ready time has passed. Returns `false` for stale events
    /// (the warming entry was cancelled by a node failure or a shrink).
    pub fn warm_fire(&mut self, v: usize, m: usize, now: f64) -> bool {
        let i = self.st(v, m);
        if self.warming[i].first().is_some_and(|&r| r <= now + 1e-9) {
            self.warming[i].remove(0);
            self.active[i] += 1;
            true
        } else {
            false
        }
    }

    /// A node outage destroys its replicas (warm, warming, and draining
    /// alike); the policy regrows them after recovery.
    pub fn fail_node(&mut self, v: usize) {
        self.node_up[v] = false;
        for m in 0..self.nl {
            let i = self.st(v, m);
            self.active[i] = 0;
            self.draining[i] = 0;
            self.warming[i].clear();
            self.idle[i] = 0;
            self.cooldown[i] = 0;
        }
    }

    pub fn node_restored(&mut self, v: usize) {
        self.node_up[v] = true;
    }

    /// One policy step for station `(v, m)`. `in_flight` is the live
    /// execution count there, `backlog` the station-attributed pending
    /// work. Ready times of newly grown (warming) replicas are pushed
    /// into `grown` (for warmup spans / `PoolWarm` events); the return
    /// value is how many draining replicas were retired this step — a
    /// nonzero count changes the shared-rate divisor, so the DES
    /// reschedules the station's in-flight completions.
    pub fn step(
        &mut self,
        v: usize,
        m: usize,
        in_flight: u32,
        backlog: u32,
        now: f64,
        grown: &mut Vec<f64>,
    ) -> u32 {
        grown.clear();
        let i = self.st(v, m);
        // Drain-before-kill: retire marked replicas the in-flight count
        // no longer needs. Never drops below the in-flight level, so a
        // running execution always keeps a replica share.
        let mut retired = self.retire(i, in_flight);
        if !self.node_up[v] {
            return retired;
        }
        if in_flight == 0 && backlog == 0 {
            self.idle[i] += 1;
        } else {
            self.idle[i] = 0;
        }
        if self.cooldown[i] > 0 {
            self.cooldown[i] -= 1;
            return retired;
        }
        let total = self.active[i] + self.warming[i].len() as u32;
        let idle_window = self.cfg.policy.idle_slots_to_zero();
        if idle_window > 0
            && self.idle[i] >= idle_window
            && self.cfg.min_replicas == 0
            && total > 0
        {
            // Scale-to-zero: cancel the warming queue outright (nothing
            // runs on a warming replica) and mark every warm replica for
            // drain — with nothing in flight they all retire immediately.
            self.warming[i].clear();
            self.draining[i] = self.active[i];
            retired += self.retire(i, in_flight);
            self.scale_to_zero_events += 1;
            self.scale_events += 1;
            self.cooldown[i] = self.cfg.policy.cooldown_slots();
            return retired;
        }
        let want = self
            .cfg
            .policy
            .desired(self.active[i], in_flight, backlog)
            .clamp(self.cfg.min_replicas, self.cfg.max_replicas);
        if want > total {
            for _ in 0..(want - total) {
                let jitter = if self.cfg.cold_start_jitter_ms > 0.0 {
                    self.rng.next_f64() * self.cfg.cold_start_jitter_ms
                } else {
                    0.0
                };
                let ready = now + self.cfg.cold_start_ms + jitter;
                self.warming[i].push(ready);
                grown.push(ready);
                self.cold_starts += 1;
            }
            self.warming[i].sort_by(f64::total_cmp);
            self.scale_events += 1;
            self.cooldown[i] = self.cfg.policy.cooldown_slots();
        } else if want < total {
            // Shrink: cancel the youngest warming replicas first (they
            // serve nothing yet, so cancellation abandons no work), then
            // mark warm replicas for drain-before-kill.
            let mut shrink = total - want;
            while shrink > 0 && !self.warming[i].is_empty() {
                self.warming[i].pop();
                shrink -= 1;
            }
            self.draining[i] = (self.draining[i] + shrink).min(self.active[i]);
            retired += self.retire(i, in_flight);
            self.scale_events += 1;
            self.cooldown[i] = self.cfg.policy.cooldown_slots();
        }
        retired
    }

    fn retire(&mut self, i: usize, in_flight: u32) -> u32 {
        let can = self.active[i]
            .saturating_sub(in_flight)
            .min(self.draining[i]);
        self.active[i] -= can;
        self.draining[i] -= can;
        can
    }

    /// End-of-slot accounting: replica-slot-seconds and the pool-size
    /// sample behind the p95 column. Call exactly once per slot.
    pub fn end_slot(&mut self, slot_ms: f64) {
        let total = self.total_all();
        self.replica_slot_seconds += total as f64 * slot_ms / 1000.0;
        self.size_hist.record(total as f64);
    }
}

/// Live shared-rate divisor: `n` in-flight executions over `R` warm
/// replicas contend as `max(1, n/R)^α` (a pool with spare replicas runs
/// at full rate; an empty pool stalls everything).
pub fn shared_divisor(in_flight: u32, replicas: u32, alpha: f64) -> f64 {
    if replicas == 0 {
        return f64::INFINITY;
    }
    let n = in_flight.max(1) as f64;
    (n / replicas as f64).max(1.0).powf(alpha)
}

/// The paper's `g_{m,ε}` delay bound evaluated at the *live* occupancy
/// divisor instead of a static committed `y` — the effective-capacity
/// machinery tracking actual contention. Infinite when the pool is empty
/// (no capacity ⇒ no finite statistical bound).
pub fn live_delay_bound(
    est: &EffCapEstimator,
    rate_samples: &[f64],
    workload_mb: f64,
    epsilon: f64,
    in_flight: u32,
    replicas: u32,
    alpha: f64,
) -> f64 {
    if replicas == 0 {
        return f64::INFINITY;
    }
    est.delay_bound_contended(
        rate_samples,
        shared_divisor(in_flight, replicas, alpha),
        workload_mb,
        epsilon,
    )
}

/// Shared-rate run bookkeeping for the DES engine: remaining *nominal*
/// work per in-flight execution (milliseconds at divisor 1), advanced
/// lazily per station and rescheduled whenever occupancy or the replica
/// count changes. Struct-of-arrays with a free list, reusable across
/// trials inside [`crate::des::DesArena`].
#[derive(Clone, Debug, Default)]
pub struct SharedRate {
    nv: usize,
    nl: usize,
    alpha: f64,
    /// Live run ids per station, in join order.
    members: Vec<Vec<u32>>,
    /// Time the station's members' remaining work was last settled.
    last_ms: Vec<f64>,
    /// Current per-run progress speed at the station (nominal ms per ms;
    /// `0` when the pool there is empty — runs stall).
    speed: Vec<f64>,
    task: Vec<u64>,
    local: Vec<u32>,
    node: Vec<u32>,
    midx: Vec<u32>,
    y: Vec<u32>,
    join_ms: Vec<f64>,
    remaining_ms: Vec<f64>,
    /// Reschedule token: bumped on every completion (re)schedule so a
    /// superseded `PoolDone` event no-ops on an O(1) check.
    rt: Vec<u32>,
    live: Vec<bool>,
    free: Vec<u32>,
}

impl SharedRate {
    /// Reset to an empty table for `nv × nl` stations, retaining
    /// allocations (bit-identical to a fresh table, like the arena).
    pub fn reset(&mut self, nv: usize, nl: usize, alpha: f64) {
        self.nv = nv;
        self.nl = nl;
        self.alpha = alpha;
        self.members.resize(nv * nl, Vec::new());
        for ms in &mut self.members {
            ms.clear();
        }
        self.last_ms.clear();
        self.last_ms.resize(nv * nl, 0.0);
        self.speed.clear();
        self.speed.resize(nv * nl, 0.0);
        self.task.clear();
        self.local.clear();
        self.node.clear();
        self.midx.clear();
        self.y.clear();
        self.join_ms.clear();
        self.remaining_ms.clear();
        self.rt.clear();
        self.live.clear();
        self.free.clear();
    }

    #[inline]
    fn st(&self, v: usize, m: usize) -> usize {
        v * self.nl + m
    }

    /// Advance the station's members' remaining work to `now` at the
    /// current speed. Call before any occupancy or replica change.
    pub fn settle(&mut self, v: usize, m: usize, now: f64) {
        let s = self.st(v, m);
        let sp = self.speed[s];
        let dt = now - self.last_ms[s];
        if sp > 0.0 && dt > 0.0 {
            for &id in &self.members[s] {
                let r = &mut self.remaining_ms[id as usize];
                *r = (*r - dt * sp).max(0.0);
            }
        }
        self.last_ms[s] = now;
    }

    /// Recompute the station speed from its occupancy and `replicas`.
    /// Call after [`Self::settle`] whenever either changed.
    pub fn rebalance(&mut self, v: usize, m: usize, replicas: u32) {
        let s = self.st(v, m);
        let n = self.members[s].len() as u32;
        self.speed[s] = if n == 0 || replicas == 0 {
            0.0
        } else {
            1.0 / shared_divisor(n, replicas, self.alpha)
        };
    }

    /// Register a new in-flight execution (caller settles first). The
    /// run's remaining work starts at its full nominal service time.
    #[allow(clippy::too_many_arguments)]
    pub fn join(
        &mut self,
        task: u64,
        local: usize,
        v: usize,
        m: usize,
        y: u32,
        join_ms: f64,
        proc_ms: f64,
    ) -> u32 {
        let id = match self.free.pop() {
            Some(id) => {
                let i = id as usize;
                self.task[i] = task;
                self.local[i] = local as u32;
                self.node[i] = v as u32;
                self.midx[i] = m as u32;
                self.y[i] = y;
                self.join_ms[i] = join_ms;
                self.remaining_ms[i] = proc_ms;
                self.rt[i] += 1;
                self.live[i] = true;
                id
            }
            None => {
                let id = self.task.len() as u32;
                self.task.push(task);
                self.local.push(local as u32);
                self.node.push(v as u32);
                self.midx.push(m as u32);
                self.y.push(y);
                self.join_ms.push(join_ms);
                self.remaining_ms.push(proc_ms);
                self.rt.push(0);
                self.live.push(true);
                id
            }
        };
        let s = self.st(v, m);
        self.members[s].push(id);
        id
    }

    /// Live run ids at the station, in join order.
    pub fn members(&self, v: usize, m: usize) -> &[u32] {
        &self.members[self.st(v, m)]
    }

    /// The `(node, light_idx)` station run `id` executes at.
    pub fn station_of(&self, id: u32) -> (usize, usize) {
        let i = id as usize;
        (self.node[i] as usize, self.midx[i] as usize)
    }

    /// Time until run `id` completes at the current station speed
    /// (`None` while the station is stalled).
    pub fn eta(&self, id: u32) -> Option<f64> {
        let i = id as usize;
        let s = self.st(self.node[i] as usize, self.midx[i] as usize);
        let sp = self.speed[s];
        (sp > 0.0).then(|| self.remaining_ms[i] / sp)
    }

    /// Bump and return the run's reschedule token (stamps the next
    /// `PoolDone` event; older events go stale).
    pub fn bump(&mut self, id: u32) -> u32 {
        self.rt[id as usize] += 1;
        self.rt[id as usize]
    }

    pub fn is_live(&self, id: u32, rt: u32) -> bool {
        let i = id as usize;
        i < self.live.len() && self.live[i] && self.rt[i] == rt
    }

    /// Complete run `id`: remove it from its station and free the slot.
    /// Returns `(task, local, node, light_idx, y, join_ms)`.
    pub fn complete(&mut self, id: u32) -> (u64, usize, usize, usize, u32, f64) {
        let i = id as usize;
        debug_assert!(self.live[i], "completing a dead run");
        let v = self.node[i] as usize;
        let m = self.midx[i] as usize;
        let s = self.st(v, m);
        self.members[s].retain(|&x| x != id);
        self.live[i] = false;
        self.free.push(id);
        (
            self.task[i],
            self.local[i] as usize,
            v,
            m,
            self.y[i],
            self.join_ms[i],
        )
    }

    /// Kill every run on node `v` (executions die with their node); any
    /// pending `PoolDone` events for them go stale via the live flag.
    pub fn kill_node(&mut self, v: usize) {
        for m in 0..self.nl {
            let s = self.st(v, m);
            for &id in &self.members[s] {
                self.live[id as usize] = false;
                self.free.push(id);
            }
            self.members[s].clear();
            self.speed[s] = 0.0;
        }
    }

    /// In-flight executions at the station.
    pub fn occupancy(&self, v: usize, m: usize) -> u32 {
        self.members[self.st(v, m)].len() as u32
    }

    /// Busy instance-groups per station, `ceil(occupancy / max_y)` —
    /// the same accounting rule the stations use, so strategies see a
    /// comparable busy matrix in pool mode.
    pub fn busy_into(&self, out: &mut Vec<Vec<u32>>, max_y: usize) {
        out.clear();
        out.resize(self.nv, Vec::new());
        for (v, row) in out.iter_mut().enumerate() {
            row.clear();
            row.resize(self.nl, 0);
            for (m, cell) in row.iter_mut().enumerate() {
                *cell = (self.members[v * self.nl + m].len()).div_ceil(max_y.max(1)) as u32;
            }
        }
    }
}

/// The §P10 autoscaling strategy: the paper's Proposal for placement and
/// routing, with parallelism pinned to `y = 1` — capacity comes from the
/// replica pool, and contention from [`SharedRate`]'s live occupancy.
#[derive(Clone, Debug, Default)]
pub struct Autoscale {
    inner: Proposal,
}

impl Autoscale {
    pub fn new() -> Self {
        Autoscale {
            inner: Proposal::new(),
        }
    }
}

impl Strategy for Autoscale {
    fn name(&self) -> &str {
        "Autoscale"
    }

    fn place_core(
        &mut self,
        env: &SimEnv,
        scores: &QosScores,
        rng: &mut Xoshiro256,
    ) -> CorePlacement {
        self.inner.place_core(env, scores, rng)
    }

    fn decide_light(
        &mut self,
        env: &SimEnv,
        slot: usize,
        queue: &[LightRequest],
        busy: &[Vec<u32>],
        residual: &[[f64; NUM_RESOURCES]],
        dm: &DistanceMatrix,
        rng: &mut Xoshiro256,
    ) -> LightDecision {
        let mut d = self
            .inner
            .decide_light(env, slot, queue, busy, residual, dm, rng);
        for a in d.assignments.iter_mut().flatten() {
            a.y = 1;
        }
        let LightDecision { x, y, .. } = &mut d;
        for (xr, yr) in x.iter().zip(y.iter_mut()) {
            for (xc, yc) in xr.iter().zip(yr.iter_mut()) {
                *yc = u32::from(*xc > 0);
            }
        }
        d
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(policy: ScalingPolicy) -> PoolConfig {
        PoolConfig {
            policy,
            cold_start_ms: 20.0,
            cold_start_jitter_ms: 4.0,
            ..PoolConfig::default()
        }
    }

    #[test]
    fn shared_divisor_tracks_occupancy_ratio() {
        assert_eq!(shared_divisor(4, 0, 1.0), f64::INFINITY);
        assert!((shared_divisor(4, 4, 1.0) - 1.0).abs() < 1e-12);
        assert!((shared_divisor(2, 4, 1.0) - 1.0).abs() < 1e-12, "spare capacity never speeds up");
        assert!((shared_divisor(8, 4, 1.0) - 2.0).abs() < 1e-12);
        assert!((shared_divisor(8, 2, 0.5) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn target_utilization_grows_and_respects_hysteresis() {
        let p = ScalingPolicy::TargetUtilization {
            target: 0.5,
            hysteresis: 0.1,
            cooldown_slots: 0,
            idle_slots_to_zero: 0,
        };
        assert_eq!(p.desired(0, 3, 0), 6, "cold station sizes to demand");
        assert_eq!(p.desired(6, 3, 0), 6, "inside the band: no action");
        assert_eq!(p.desired(2, 3, 0), 6, "util 1.5 > 0.6: grow");
        assert_eq!(p.desired(10, 3, 0), 6, "util 0.3 < 0.4: shrink");
        assert_eq!(p.desired(7, 3, 0), 7, "util ~0.43 inside band: hold");
    }

    #[test]
    fn backlog_threshold_steps_by_one() {
        let p = ScalingPolicy::BacklogThreshold {
            grow_above: 2.0,
            shrink_below: 0.5,
            cooldown_slots: 0,
            idle_slots_to_zero: 0,
        };
        assert_eq!(p.desired(0, 1, 5), 1);
        assert_eq!(p.desired(2, 2, 3), 3, "pressure 5 > 4: grow");
        assert_eq!(p.desired(4, 1, 0), 3, "pressure 1 < 2: shrink");
        assert_eq!(p.desired(2, 1, 2), 2, "pressure 3 in [1,4]: hold");
    }

    #[test]
    fn grow_serves_nothing_until_warm() {
        let mut pm = PoolManager::new(1, 1, cfg(ScalingPolicy::default()), 7);
        let mut grown = Vec::new();
        pm.step(0, 0, 3, 0, 100.0, &mut grown);
        assert!(!grown.is_empty());
        assert_eq!(pm.active(0, 0), 0, "warming replicas serve nothing");
        assert!(pm.total(0, 0) > 0);
        for &r in &grown {
            assert!(r >= 120.0 && r <= 124.0, "ready inside the jitter window, got {r}");
        }
        pm.promote_ready_all(110.0);
        assert_eq!(pm.active(0, 0), 0, "still cold");
        pm.promote_ready_all(130.0);
        assert_eq!(pm.active(0, 0) as usize, grown.len(), "warm after the window");
    }

    #[test]
    fn drain_before_kill_never_abandons_in_flight() {
        let pc = PoolConfig {
            initial_replicas: 4,
            policy: ScalingPolicy::TargetUtilization {
                target: 0.7,
                hysteresis: 0.1,
                cooldown_slots: 0,
                idle_slots_to_zero: 0,
            },
            ..PoolConfig::default()
        };
        let mut pm = PoolManager::new(1, 1, pc, 3);
        let mut grown = Vec::new();
        // Demand 1 over 4 replicas: util 0.25 → shrink toward 2, but 3
        // executions are still in flight — only one replica may retire.
        let retired = pm.step(0, 0, 1, 0, 10.0, &mut grown);
        assert!(pm.active(0, 0) >= 1, "in-flight work keeps its replica");
        assert_eq!(retired, pm.scale_events as u32 * 0 + retired); // retired counted
        assert!(pm.active(0, 0) + retired == 4 || pm.active(0, 0) == 4 - retired);
    }

    #[test]
    fn scale_to_zero_after_idle_window_and_counts_event() {
        let pc = PoolConfig {
            initial_replicas: 2,
            policy: ScalingPolicy::TargetUtilization {
                target: 0.7,
                hysteresis: 0.1,
                cooldown_slots: 0,
                idle_slots_to_zero: 3,
            },
            ..PoolConfig::default()
        };
        let mut pm = PoolManager::new(1, 1, pc, 11);
        let mut grown = Vec::new();
        for k in 0..3 {
            pm.step(0, 0, 0, 0, k as f64, &mut grown);
        }
        assert_eq!(pm.active(0, 0), 0, "idle pool scaled to zero");
        assert_eq!(pm.scale_to_zero_events, 1);
    }

    #[test]
    fn manager_is_deterministic_per_seed() {
        let run = |seed: u64| {
            let mut pm = PoolManager::new(2, 2, cfg(ScalingPolicy::default()), seed);
            let mut grown = Vec::new();
            let mut log = Vec::new();
            for slot in 0..40u32 {
                let now = slot as f64 * 10.0;
                pm.promote_ready_all(now);
                for v in 0..2 {
                    for m in 0..2 {
                        let inf = (slot / 4 + v as u32 + m as u32) % 5;
                        pm.step(v, m, inf, 0, now, &mut grown);
                        log.extend(grown.iter().map(|&r| (v, m, r.to_bits())));
                    }
                }
                pm.end_slot(10.0);
            }
            (
                log,
                pm.cold_starts,
                pm.scale_events,
                pm.replica_slot_seconds.to_bits(),
            )
        };
        assert_eq!(run(5), run(5), "same seed replays bit-identically");
        assert_ne!(run(5).0, run(6).0, "jitter stream follows the seed");
    }

    #[test]
    fn fail_node_clears_pool_and_warm_fire_goes_stale() {
        let mut pm = PoolManager::new(2, 1, cfg(ScalingPolicy::default()), 9);
        let mut grown = Vec::new();
        pm.step(0, 0, 2, 0, 0.0, &mut grown);
        assert!(!grown.is_empty());
        let ready = grown[0];
        pm.fail_node(0);
        assert_eq!(pm.total(0, 0), 0);
        assert!(!pm.warm_fire(0, 0, ready), "warmup of a dead node is stale");
    }

    #[test]
    fn shared_rate_stretches_in_flight_work() {
        let mut sr = SharedRate::default();
        sr.reset(1, 1, 1.0);
        // One run over one replica: full speed.
        sr.settle(0, 0, 0.0);
        let a = sr.join(1, 0, 0, 0, 1, 0.0, 100.0);
        sr.rebalance(0, 0, 1);
        assert_eq!(sr.eta(a), Some(100.0));
        // A second run joins at t=50: the first is half done, and both
        // now progress at half speed over the single replica.
        sr.settle(0, 0, 50.0);
        let b = sr.join(2, 0, 0, 0, 1, 50.0, 100.0);
        sr.rebalance(0, 0, 1);
        assert_eq!(sr.eta(a), Some(100.0), "50 nominal ms left at half speed");
        assert_eq!(sr.eta(b), Some(200.0));
        // A second replica warms at t=100: back to full speed.
        sr.settle(0, 0, 100.0);
        sr.rebalance(0, 0, 2);
        assert_eq!(sr.eta(a), Some(25.0));
        let (task, _, v, m, _, _) = sr.complete(a);
        assert_eq!((task, v, m), (1, 0, 0));
        assert_eq!(sr.occupancy(0, 0), 1);
    }

    #[test]
    fn shared_rate_reuse_matches_fresh() {
        let drive = |sr: &mut SharedRate| {
            sr.reset(2, 1, 1.0);
            sr.settle(1, 0, 5.0);
            let a = sr.join(7, 1, 1, 0, 1, 5.0, 40.0);
            sr.rebalance(1, 0, 2);
            let eta = sr.eta(a);
            sr.kill_node(1);
            (eta, sr.occupancy(1, 0))
        };
        let mut fresh = SharedRate::default();
        let want = drive(&mut fresh);
        let mut reused = SharedRate::default();
        reused.reset(2, 1, 1.0);
        for k in 0..5 {
            sr_noise(&mut reused, k);
        }
        assert_eq!(drive(&mut reused), want, "reset erases all prior state");
    }

    fn sr_noise(sr: &mut SharedRate, k: u64) {
        let id = sr.join(k, 0, 0, 0, 1, 0.0, 10.0 + k as f64);
        sr.rebalance(0, 0, 1);
        sr.settle(0, 0, k as f64);
        if k % 2 == 0 {
            sr.complete(id);
        }
    }

    #[test]
    fn stalled_station_reports_no_eta() {
        let mut sr = SharedRate::default();
        sr.reset(1, 1, 1.0);
        let a = sr.join(1, 0, 0, 0, 1, 0.0, 10.0);
        sr.rebalance(0, 0, 0);
        assert_eq!(sr.eta(a), None, "empty pool stalls the run");
        sr.settle(0, 0, 50.0);
        sr.rebalance(0, 0, 1);
        assert_eq!(sr.eta(a), Some(10.0), "no progress while stalled");
    }

    #[test]
    fn live_bound_tracks_contention_and_empty_pool() {
        let est = EffCapEstimator::log_grid(1e-3, 10.0, 16);
        let samples: Vec<f64> = (0..512).map(|i| 2.0 + (i % 7) as f64).collect();
        let relaxed = live_delay_bound(&est, &samples, 1.0, 0.2, 2, 4, 1.0);
        let contended = live_delay_bound(&est, &samples, 1.0, 0.2, 8, 2, 1.0);
        assert!(contended > relaxed, "occupancy 4x replicas must cost delay");
        assert_eq!(
            live_delay_bound(&est, &samples, 1.0, 0.2, 1, 0, 1.0),
            f64::INFINITY
        );
    }
}
