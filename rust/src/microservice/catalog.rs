//! Concrete microservice specs and the application container.

use crate::graph::Dag;
use crate::rng::{Distribution, Gamma, Rng};

/// Global microservice identifier (dense index into the catalog).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct MsId(pub usize);

/// Task-type identifier.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct TaskTypeId(pub usize);

/// Core vs light dichotomy (§II-A).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MsClass {
    /// Heavyweight, stateful, deterministic rate, strict isolation.
    Core,
    /// Stateless, elastic, stochastic rate under contention.
    Light,
}

/// Processing-rate model `f_m` (MB/ms): deterministic for core services,
/// Gamma for light services (Table I).
#[derive(Clone, Copy, Debug)]
pub enum RateModel {
    Deterministic(f64),
    Gamma { shape: f64, scale: f64 },
}

impl RateModel {
    /// Mean rate E[f_m].
    pub fn mean(&self) -> f64 {
        match self {
            RateModel::Deterministic(f) => *f,
            RateModel::Gamma { shape, scale } => shape * scale,
        }
    }

    /// Draw one instantaneous rate.
    pub fn sample<R: Rng + ?Sized>(&self, rng: &mut R) -> f64 {
        match self {
            RateModel::Deterministic(f) => *f,
            RateModel::Gamma { shape, scale } => Gamma::new(*shape, *scale).sample(rng),
        }
    }

    /// Draw `n` rates (used to profile the effective-capacity model).
    pub fn sample_n<R: Rng + ?Sized>(&self, rng: &mut R, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.sample(rng)).collect()
    }
}

/// One microservice's concrete (per-run sampled) specification.
#[derive(Clone, Debug)]
pub struct MsSpec {
    pub id: MsId,
    pub name: String,
    pub class: MsClass,
    /// Resource requirement vector `r_m` (CPU, RAM, GPU, VRAM).
    pub resources: [f64; crate::config::NUM_RESOURCES],
    /// Computational workload `a_m` (MB) per invocation.
    pub workload_mb: f64,
    /// Output payload `b_m` (MB).
    pub output_mb: f64,
    /// Processing rate `f_m`.
    pub rate: RateModel,
    /// One-time deployment cost `c^dp_m`.
    pub cost_deploy: f64,
    /// Per-slot maintenance cost `c^mt_m`.
    pub cost_maint: f64,
    /// Per-parallelism cost `c^pl_m`.
    pub cost_parallel: f64,
}

impl MsSpec {
    /// Mean processing delay `a_m / E[f_m]` (ms), the PropAvg estimate.
    pub fn mean_proc_delay(&self) -> f64 {
        self.workload_mb / self.rate.mean()
    }

    pub fn is_core(&self) -> bool {
        self.class == MsClass::Core
    }
}

/// All microservices of the application.
#[derive(Clone, Debug, Default)]
pub struct Catalog {
    services: Vec<MsSpec>,
    core: Vec<MsId>,
    light: Vec<MsId>,
}

impl Catalog {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register a spec; its `id` must equal the current length.
    pub fn push(&mut self, spec: MsSpec) {
        assert_eq!(spec.id.0, self.services.len(), "MsSpec ids must be dense");
        match spec.class {
            MsClass::Core => self.core.push(spec.id),
            MsClass::Light => self.light.push(spec.id),
        }
        self.services.push(spec);
    }

    pub fn len(&self) -> usize {
        self.services.len()
    }

    pub fn is_empty(&self) -> bool {
        self.services.is_empty()
    }

    pub fn num_core(&self) -> usize {
        self.core.len()
    }

    pub fn num_light(&self) -> usize {
        self.light.len()
    }

    /// Core MS ids (`M^cr`).
    pub fn core_ids(&self) -> &[MsId] {
        &self.core
    }

    /// Light MS ids (`M^lt`).
    pub fn light_ids(&self) -> &[MsId] {
        &self.light
    }

    pub fn spec(&self, id: MsId) -> &MsSpec {
        &self.services[id.0]
    }

    pub fn iter(&self) -> impl Iterator<Item = &MsSpec> {
        self.services.iter()
    }

    /// Position of a light MS id within `light_ids()` (dense light index),
    /// used by the g-table which is indexed by light MS only.
    pub fn light_index(&self, id: MsId) -> Option<usize> {
        self.light.iter().position(|&l| l == id)
    }
}

/// One task type `G_n = (M_n, L_n)` plus its workload constants.
#[derive(Clone, Debug)]
pub struct TaskType {
    pub id: TaskTypeId,
    /// DAG over local node indices; node `i` executes `services[i]`.
    pub dag: Dag,
    /// Local-node → catalog MS mapping (`M_n`).
    pub services: Vec<MsId>,
    /// End-to-end deadline `D_n` (ms).
    pub deadline_ms: f64,
    /// Input payload `A_n` (MB).
    pub input_mb: f64,
}

impl TaskType {
    /// Number of services `I_n`.
    pub fn num_services(&self) -> usize {
        self.services.len()
    }

    /// Local node indices of `ms` within this task DAG (usually one).
    pub fn local_nodes_of(&self, ms: MsId) -> Vec<usize> {
        self.services
            .iter()
            .enumerate()
            .filter(|&(_, &s)| s == ms)
            .map(|(i, _)| i)
            .collect()
    }
}

/// The full FM application: catalog + task types + inverse index.
#[derive(Clone, Debug)]
pub struct Application {
    pub catalog: Catalog,
    pub task_types: Vec<TaskType>,
    /// `types_of[m]` = task types requiring MS `m` (the `N_m` sets of §III-A).
    types_of: Vec<Vec<TaskTypeId>>,
}

impl Application {
    pub fn new(catalog: Catalog, task_types: Vec<TaskType>) -> Self {
        let mut types_of = vec![Vec::new(); catalog.len()];
        for tt in &task_types {
            for &m in &tt.services {
                if !types_of[m.0].contains(&tt.id) {
                    types_of[m.0].push(tt.id);
                }
            }
        }
        Application {
            catalog,
            task_types,
            types_of,
        }
    }

    /// Task types requiring MS `m` — the set `N_m` of eq. (15).
    pub fn types_requiring(&self, m: MsId) -> &[TaskTypeId] {
        &self.types_of[m.0]
    }

    pub fn task_type(&self, id: TaskTypeId) -> &TaskType {
        &self.task_types[id.0]
    }
}
