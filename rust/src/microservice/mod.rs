//! Microservice specification for FM inference (§II-A).
//!
//! An FM application is decomposed into **core** microservices (heavyweight,
//! stateful, deterministic rate, resource-isolated — transformers, vision
//! backbones) and **light** microservices (stateless, small footprint,
//! stochastic rate under contention — pre/post-processing). Task types are
//! inverse-tree DAGs over these services (Fig. 1).

mod catalog;
mod fig1;

pub use catalog::{Application, Catalog, MsClass, MsId, MsSpec, RateModel, TaskType, TaskTypeId};
pub use fig1::{build_application, build_fig1_application};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;
    use crate::rng::Xoshiro256;

    #[test]
    fn fig1_application_shape_matches_paper() {
        let cfg = ExperimentConfig::paper_default();
        let mut rng = Xoshiro256::seed_from(1);
        let app = build_fig1_application(&cfg, &mut rng);
        assert_eq!(app.catalog.num_core(), 6);
        assert_eq!(app.catalog.num_light(), 9);
        assert_eq!(app.task_types.len(), 4);
    }

    #[test]
    fn all_task_dags_are_inverse_trees() {
        let cfg = ExperimentConfig::paper_default();
        let mut rng = Xoshiro256::seed_from(2);
        let app = build_fig1_application(&cfg, &mut rng);
        for tt in &app.task_types {
            assert!(
                tt.dag.is_inverse_tree(),
                "task type {} DAG must be an inverse tree",
                tt.id.0
            );
            assert_eq!(tt.dag.len(), tt.services.len());
        }
    }

    #[test]
    fn task_types_use_both_classes() {
        let cfg = ExperimentConfig::paper_default();
        let mut rng = Xoshiro256::seed_from(3);
        let app = build_fig1_application(&cfg, &mut rng);
        for tt in &app.task_types {
            let has_core = tt
                .services
                .iter()
                .any(|&m| app.catalog.spec(m).class == MsClass::Core);
            let has_light = tt
                .services
                .iter()
                .any(|&m| app.catalog.spec(m).class == MsClass::Light);
            assert!(has_core && has_light);
        }
    }

    #[test]
    fn sink_service_is_core() {
        // The final fusion stage of a multimodal pipeline is a core model.
        let cfg = ExperimentConfig::paper_default();
        let mut rng = Xoshiro256::seed_from(4);
        let app = build_fig1_application(&cfg, &mut rng);
        for tt in &app.task_types {
            let sink = tt.dag.sink().unwrap();
            assert_eq!(app.catalog.spec(tt.services[sink]).class, MsClass::Core);
        }
    }

    #[test]
    fn sampled_parameters_respect_ranges() {
        let cfg = ExperimentConfig::paper_default();
        let mut rng = Xoshiro256::seed_from(5);
        let app = build_fig1_application(&cfg, &mut rng);
        for spec in app.catalog.iter() {
            let class_cfg = match spec.class {
                MsClass::Core => &cfg.core_ms,
                MsClass::Light => &cfg.light_ms,
            };
            for k in 0..crate::config::NUM_RESOURCES {
                assert!(
                    spec.resources[k] >= class_cfg.resources[k].lo
                        && spec.resources[k] <= class_cfg.resources[k].hi
                );
            }
            assert!(spec.workload_mb >= class_cfg.workload_mb.lo);
            assert!(spec.output_mb <= class_cfg.output_mb.hi);
            match (&spec.rate, spec.class) {
                (RateModel::Deterministic(_), MsClass::Core) => {}
                (RateModel::Gamma { .. }, MsClass::Light) => {}
                _ => panic!("rate model/class mismatch"),
            }
        }
    }

    #[test]
    fn mean_rate_is_consistent() {
        let det = RateModel::Deterministic(12.0);
        assert_eq!(det.mean(), 12.0);
        let g = RateModel::Gamma {
            shape: 1.5,
            scale: 10.0,
        };
        assert!((g.mean() - 15.0).abs() < 1e-12);
    }

    #[test]
    fn deterministic_rate_sampling_is_constant() {
        let mut rng = Xoshiro256::seed_from(6);
        let det = RateModel::Deterministic(9.0);
        for _ in 0..10 {
            assert_eq!(det.sample(&mut rng), 9.0);
        }
    }

    #[test]
    fn catalog_lookup_roundtrip() {
        let cfg = ExperimentConfig::paper_default();
        let mut rng = Xoshiro256::seed_from(7);
        let app = build_fig1_application(&cfg, &mut rng);
        for (i, spec) in app.catalog.iter().enumerate() {
            assert_eq!(spec.id.0, i);
            assert_eq!(app.catalog.spec(MsId(i)).id, MsId(i));
        }
        assert_eq!(
            app.catalog.core_ids().len() + app.catalog.light_ids().len(),
            app.catalog.len()
        );
    }

    #[test]
    fn types_requiring_service_inverse_index() {
        let cfg = ExperimentConfig::paper_default();
        let mut rng = Xoshiro256::seed_from(8);
        let app = build_fig1_application(&cfg, &mut rng);
        for m in 0..app.catalog.len() {
            for &tt in app.types_requiring(MsId(m)) {
                assert!(app.task_types[tt.0].services.contains(&MsId(m)));
            }
        }
    }
}
