//! Builders for the Fig. 1 application: 4 task types over 6 core and
//! 9 light microservices, each task type an inverse tree (multimodal
//! fusion: many inputs funnel into core models, one final output).

use crate::config::{ExperimentConfig, MsClassConfig, RateSpec};
use crate::graph::Dag;
use crate::rng::Rng;

use super::catalog::{
    Application, Catalog, MsClass, MsId, MsSpec, RateModel, TaskType, TaskTypeId,
};

fn sample_spec<R: Rng + ?Sized>(
    id: usize,
    class: MsClass,
    cfg: &MsClassConfig,
    rng: &mut R,
) -> MsSpec {
    let mut resources = [0.0; crate::config::NUM_RESOURCES];
    for (k, r) in cfg.resources.iter().enumerate() {
        resources[k] = r.sample(rng);
    }
    let rate = match cfg.rate {
        RateSpec::Deterministic(r) => RateModel::Deterministic(r.sample(rng)),
        RateSpec::Gamma { shape, scale } => RateModel::Gamma {
            shape: shape.sample(rng),
            scale: scale.sample(rng),
        },
    };
    let prefix = match class {
        MsClass::Core => "core",
        MsClass::Light => "light",
    };
    MsSpec {
        id: MsId(id),
        name: format!("{prefix}-{id}"),
        class,
        resources,
        workload_mb: cfg.workload_mb.sample(rng),
        output_mb: cfg.output_mb.sample(rng),
        rate,
        cost_deploy: cfg.cost_deploy,
        cost_maint: cfg.cost_maint,
        cost_parallel: cfg.cost_parallel,
    }
}

/// Sample a catalog of `num_core` + `num_light` services from the config
/// ranges. Core services occupy ids `0..num_core`.
pub fn sample_catalog<R: Rng + ?Sized>(cfg: &ExperimentConfig, rng: &mut R) -> Catalog {
    let mut catalog = Catalog::new();
    for i in 0..cfg.app.num_core_ms {
        catalog.push(sample_spec(i, MsClass::Core, &cfg.core_ms, rng));
    }
    for i in 0..cfg.app.num_light_ms {
        catalog.push(sample_spec(
            cfg.app.num_core_ms + i,
            MsClass::Light,
            &cfg.light_ms,
            rng,
        ));
    }
    catalog
}

/// Build one inverse-tree task type over a chosen service sequence.
///
/// Construction: nodes are ordered `0..n`; every node except the last picks
/// a successor among the later nodes, giving at most one outgoing edge per
/// node, a single sink (node `n-1`) and acyclicity by construction. Light
/// services are biased toward the leaves (pre-processing), core services
/// toward fusion points and the sink — matching Fig. 1's structure.
fn build_inverse_tree<R: Rng + ?Sized>(
    id: usize,
    services: Vec<MsId>,
    deadline_ms: f64,
    input_mb: f64,
    rng: &mut R,
) -> TaskType {
    let n = services.len();
    let mut dag = Dag::new(n);
    for i in 0..n.saturating_sub(1) {
        // Successor biased to be close (chains) but allowed to skip ahead
        // (fusion): choose among the next 1..=3 nodes, clamped to n-1.
        let max_skip = 3.min(n - 1 - i);
        let succ = i + 1 + rng.next_below(max_skip as u64) as usize;
        dag.add_edge(i, succ.min(n - 1)).expect("forward edge is acyclic");
    }
    debug_assert!(dag.is_inverse_tree());
    TaskType {
        id: TaskTypeId(id),
        dag,
        services,
        deadline_ms,
        input_mb,
    }
}

/// Sample the service mix of one task type: light services feed toward
/// core services with the sink always core.
fn sample_task_services<R: Rng + ?Sized>(
    catalog: &Catalog,
    count: usize,
    rng: &mut R,
) -> Vec<MsId> {
    let cores = catalog.core_ids();
    let lights = catalog.light_ids();
    // Roughly 40% core (Fig. 1 has 6 core / 9 light shared by 4 types).
    let num_core = ((count as f64) * 0.4).round().max(1.0) as usize;
    let num_core = num_core.min(count - 1).min(cores.len()).max(1);
    let num_light = (count - num_core).min(lights.len());

    let mut chosen_light: Vec<MsId> = {
        let mut pool = lights.to_vec();
        rng.shuffle(&mut pool);
        pool.truncate(num_light);
        pool
    };
    let mut chosen_core: Vec<MsId> = {
        let mut pool = cores.to_vec();
        rng.shuffle(&mut pool);
        pool.truncate(num_core);
        pool
    };
    // Order: lights first (leaves/pre-processing), cores later, core sink.
    rng.shuffle(&mut chosen_light);
    rng.shuffle(&mut chosen_core);
    let mut services = chosen_light;
    // Interleave non-sink cores into the middle third onward.
    let sink_core = chosen_core.pop().expect("at least one core service");
    for (i, c) in chosen_core.into_iter().enumerate() {
        let pos = (services.len() / 2 + i).min(services.len());
        services.insert(pos, c);
    }
    services.push(sink_core);
    services
}

/// Build the paper's evaluation application (Fig. 1): `num_task_types`
/// inverse-tree DAGs sharing the sampled catalog.
pub fn build_application<R: Rng + ?Sized>(cfg: &ExperimentConfig, rng: &mut R) -> Application {
    let catalog = sample_catalog(cfg, rng);
    let mut task_types = Vec::with_capacity(cfg.app.num_task_types);
    for n in 0..cfg.app.num_task_types {
        let lo = cfg.app.services_per_task.lo.round() as usize;
        let hi = cfg.app.services_per_task.hi.round() as usize;
        let count = rng.range_usize(lo.max(2), hi.max(lo.max(2)));
        let services = sample_task_services(&catalog, count, rng);
        let deadline = cfg.workload.deadline_ms.sample(rng);
        let input = cfg.workload.input_mb.sample(rng);
        task_types.push(build_inverse_tree(n, services, deadline, input, rng));
    }
    Application::new(catalog, task_types)
}

/// Alias with the paper's Fig. 1 name.
pub fn build_fig1_application<R: Rng + ?Sized>(
    cfg: &ExperimentConfig,
    rng: &mut R,
) -> Application {
    build_application(cfg, rng)
}
