//! Poisson task generation over the user population.

use crate::config::ExperimentConfig;
use crate::microservice::{Application, TaskTypeId};
use crate::network::{NodeId, Topology, WirelessChannel};
use crate::rng::{Poisson, Rng};

use super::TaskId;

/// A user: attachment ED, per-type arrival rates, and channel state.
#[derive(Clone, Debug)]
pub struct User {
    pub id: usize,
    /// Associated edge device (ingress node).
    pub ed: NodeId,
    /// Mean arrivals per slot for each task type (`E[z_{u,n,t}]`).
    pub rates: Vec<f64>,
    pub channel: WirelessChannel,
}

/// One realized task arrival `j = (u, n, t)`.
#[derive(Clone, Debug)]
pub struct TaskArrival {
    pub id: TaskId,
    pub user: usize,
    /// Ingress edge device of the user.
    pub ed: NodeId,
    pub task_type: TaskTypeId,
    /// Arrival slot `t`.
    pub slot: usize,
    /// Realized uplink SNR `γ_u` at arrival.
    pub snr: f64,
    /// Realized uplink delay `τ_ul` (ms) — eq. (1).
    pub uplink_delay_ms: f64,
}

/// Stateful generator: draws `z_{u,n,t}` per slot and stamps each arrival
/// with its realized channel state.
#[derive(Clone, Debug)]
pub struct WorkloadGenerator {
    users: Vec<User>,
    input_mb: Vec<f64>,
    next_id: u64,
}

impl WorkloadGenerator {
    /// Sample the user population: users attach to EDs round-robin (uniform
    /// coverage) and draw per-type Poisson rates from the config range.
    pub fn new<R: Rng + ?Sized>(
        cfg: &ExperimentConfig,
        app: &Application,
        topo: &Topology,
        rng: &mut R,
    ) -> Self {
        let eds: Vec<NodeId> = topo.eds().collect();
        assert!(!eds.is_empty(), "topology has no edge devices");
        let users = (0..cfg.workload.num_users)
            .map(|id| User {
                id,
                ed: eds[id % eds.len()],
                rates: (0..cfg.app.num_task_types)
                    .map(|_| cfg.workload.arrival_rate.sample(rng))
                    .collect(),
                channel: WirelessChannel::sample(&cfg.workload, rng),
            })
            .collect();
        let input_mb = app.task_types.iter().map(|tt| tt.input_mb).collect();
        WorkloadGenerator {
            users,
            input_mb,
            next_id: 0,
        }
    }

    pub fn users(&self) -> &[User] {
        &self.users
    }

    /// Re-home a user to a new ingress edge device (user mobility / ED
    /// churn): subsequent arrivals of `user` are stamped with `ed`. The
    /// caller is responsible for passing a valid edge-device node id —
    /// the scenario compiler draws from [`crate::network::Topology::eds`].
    pub fn set_user_ed(&mut self, user: usize, ed: NodeId) {
        self.users[user].ed = ed;
    }

    /// Draw all arrivals for slot `t` at the given load multiplier
    /// (Fig. 4's ×1.0/×1.5/×2.0 escalation scales the Poisson means).
    pub fn generate_slot<R: Rng + ?Sized>(
        &mut self,
        slot: usize,
        load_multiplier: f64,
        rng: &mut R,
    ) -> Vec<TaskArrival> {
        let mut out = Vec::new();
        for u in &self.users {
            for (n, &rate) in u.rates.iter().enumerate() {
                let count = Poisson::new(rate * load_multiplier).sample_count(rng);
                for _ in 0..count {
                    let snr = u.channel.sample_snr(rng);
                    let input = self.input_mb[n];
                    out.push(TaskArrival {
                        id: TaskId(self.next_id),
                        user: u.id,
                        ed: u.ed,
                        task_type: TaskTypeId(n),
                        slot,
                        snr,
                        uplink_delay_ms: u.channel.uplink_delay(input, snr),
                    });
                    self.next_id += 1;
                }
            }
        }
        out
    }

    /// Expected aggregate arrivals per slot (all users, all types) at the
    /// base load — used by the static placement's capacity constraint C2.
    pub fn mean_total_rate(&self) -> f64 {
        self.users.iter().map(|u| u.rates.iter().sum::<f64>()).sum()
    }

    /// Mean arrival rate of (user, type) — `E[z_{u,n,t}]` in eq. (15).
    pub fn mean_rate(&self, user: usize, task_type: TaskTypeId) -> f64 {
        self.users[user].rates[task_type.0]
    }
}
