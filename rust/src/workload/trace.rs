//! Plain-text task traces: record a realized workload once, replay it
//! against every deployment strategy for paired comparisons (Fig. 3/4).

use crate::microservice::TaskTypeId;

use super::generator::TaskArrival;
use super::TaskId;

/// A recorded sequence of task arrivals, slot-indexed.
#[derive(Clone, Debug, Default)]
pub struct Trace {
    arrivals: Vec<TaskArrival>,
    /// arrivals index ranges per slot (dense).
    slot_index: Vec<(usize, usize)>,
}

impl Trace {
    /// Build from arrivals (must be sorted by slot — generator output is).
    pub fn from_arrivals(arrivals: Vec<TaskArrival>) -> Self {
        let max_slot = arrivals.iter().map(|a| a.slot).max().map_or(0, |s| s + 1);
        let mut slot_index = vec![(0usize, 0usize); max_slot];
        let mut i = 0;
        for s in 0..max_slot {
            let start = i;
            while i < arrivals.len() && arrivals[i].slot == s {
                i += 1;
            }
            slot_index[s] = (start, i);
        }
        debug_assert_eq!(i, arrivals.len(), "arrivals must be sorted by slot");
        Trace {
            arrivals,
            slot_index,
        }
    }

    pub fn arrivals(&self) -> &[TaskArrival] {
        &self.arrivals
    }

    pub fn len(&self) -> usize {
        self.arrivals.len()
    }

    pub fn is_empty(&self) -> bool {
        self.arrivals.is_empty()
    }

    pub fn num_slots(&self) -> usize {
        self.slot_index.len()
    }

    /// Arrivals of one slot.
    pub fn slot(&self, t: usize) -> &[TaskArrival] {
        match self.slot_index.get(t) {
            Some(&(a, b)) => &self.arrivals[a..b],
            None => &[],
        }
    }

    /// Serialize to a line-oriented text format:
    /// `task <id> <user> <ed> <type> <slot> <snr> <uplink_ms>`.
    pub fn to_text(&self) -> String {
        let mut s = String::with_capacity(self.arrivals.len() * 48 + 16);
        s.push_str("# fmedge trace v1\n");
        for a in &self.arrivals {
            s.push_str(&format!(
                "task {} {} {} {} {} {:.9} {:.9}\n",
                a.id.0, a.user, a.ed, a.task_type.0, a.slot, a.snr, a.uplink_delay_ms
            ));
        }
        s
    }

    /// Parse the text format produced by [`Self::to_text`].
    pub fn from_text(text: &str) -> Result<Self, String> {
        let mut arrivals = Vec::new();
        let mut saw_header = false;
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            if line.starts_with('#') {
                if line.contains("fmedge trace") {
                    saw_header = true;
                }
                continue;
            }
            let parts: Vec<&str> = line.split_whitespace().collect();
            if parts.len() != 8 || parts[0] != "task" {
                return Err(format!("line {}: malformed record", lineno + 1));
            }
            let parse_u = |s: &str, what: &str| -> Result<u64, String> {
                s.parse()
                    .map_err(|_| format!("line {}: bad {what}", lineno + 1))
            };
            let parse_f = |s: &str, what: &str| -> Result<f64, String> {
                s.parse()
                    .map_err(|_| format!("line {}: bad {what}", lineno + 1))
            };
            arrivals.push(TaskArrival {
                id: TaskId(parse_u(parts[1], "id")?),
                user: parse_u(parts[2], "user")? as usize,
                ed: parse_u(parts[3], "ed")? as usize,
                task_type: TaskTypeId(parse_u(parts[4], "type")? as usize),
                slot: parse_u(parts[5], "slot")? as usize,
                snr: parse_f(parts[6], "snr")?,
                uplink_delay_ms: parse_f(parts[7], "uplink")?,
            });
        }
        if !saw_header {
            return Err("missing trace header".to_string());
        }
        Ok(Trace::from_arrivals(arrivals))
    }

    /// Write to a file.
    pub fn save(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.to_text())
    }

    /// Read from a file.
    pub fn load(path: &str) -> Result<Self, String> {
        let text = std::fs::read_to_string(path).map_err(|e| e.to_string())?;
        Self::from_text(&text)
    }
}
