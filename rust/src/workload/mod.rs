//! Workload model (§II-B): users stochastically generate tasks of each
//! type (`z_{t,u,n} ~ Poisson`), transmitted over fading uplinks to their
//! associated edge device. Includes trace recording/replay so every
//! strategy in a comparison sees the *same* realized workload.

mod generator;
mod trace;

pub use generator::{TaskArrival, User, WorkloadGenerator};
pub use trace::Trace;

/// Globally unique task instance id.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TaskId(pub u64);

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;
    use crate::microservice::build_fig1_application;
    use crate::network::Topology;
    use crate::rng::Xoshiro256;

    fn setup(seed: u64) -> (ExperimentConfig, WorkloadGenerator) {
        let cfg = ExperimentConfig::paper_default();
        let mut rng = Xoshiro256::seed_from(seed);
        let app = build_fig1_application(&cfg, &mut rng);
        let topo = Topology::generate(&cfg, &mut rng);
        let gen = WorkloadGenerator::new(&cfg, &app, &topo, &mut rng);
        (cfg, gen)
    }

    #[test]
    fn users_are_attached_to_eds() {
        let (cfg, gen) = setup(1);
        assert_eq!(gen.users().len(), cfg.workload.num_users);
        for u in gen.users() {
            assert!(u.ed < cfg.network.num_eds, "user attached to non-ED node");
        }
    }

    #[test]
    fn arrival_counts_scale_with_multiplier() {
        let (_, mut g1) = setup(2);
        let (_, mut g2) = setup(2);
        let mut rng1 = Xoshiro256::seed_from(10);
        let mut rng2 = Xoshiro256::seed_from(10);
        let n1: usize = (0..200).map(|t| g1.generate_slot(t, 1.0, &mut rng1).len()).sum();
        let n2: usize = (0..200).map(|t| g2.generate_slot(t, 2.0, &mut rng2).len()).sum();
        assert!(
            n2 as f64 > 1.5 * n1 as f64,
            "2x load should produce ~2x arrivals ({n1} vs {n2})"
        );
    }

    #[test]
    fn task_ids_are_unique_and_monotone() {
        let (_, mut gen) = setup(3);
        let mut rng = Xoshiro256::seed_from(11);
        let mut last = None;
        for t in 0..50 {
            for a in gen.generate_slot(t, 1.0, &mut rng) {
                if let Some(prev) = last {
                    assert!(a.id.0 > prev);
                }
                last = Some(a.id.0);
                assert_eq!(a.slot, t);
            }
        }
    }

    #[test]
    fn arrivals_have_valid_uplink_snr() {
        let (_, mut gen) = setup(4);
        let mut rng = Xoshiro256::seed_from(12);
        for t in 0..100 {
            for a in gen.generate_slot(t, 1.0, &mut rng) {
                assert!(a.snr > 0.0);
            }
        }
    }

    #[test]
    fn mean_arrival_rate_matches_config() {
        let (cfg, mut gen) = setup(5);
        let mut rng = Xoshiro256::seed_from(13);
        let slots = 3000;
        let total: usize = (0..slots)
            .map(|t| gen.generate_slot(t, 1.0, &mut rng).len())
            .sum();
        let per_slot = total as f64 / slots as f64;
        // Expectation: num_users * num_types * mean(arrival_rate).
        let expected = cfg.workload.num_users as f64
            * cfg.app.num_task_types as f64
            * cfg.workload.arrival_rate.mid();
        // Per-run rates are sampled from the range; wide tolerance.
        assert!(
            per_slot > 0.3 * expected && per_slot < 3.0 * expected,
            "per_slot={per_slot} expected≈{expected}"
        );
    }

    #[test]
    fn trace_roundtrip() {
        let (_, mut gen) = setup(6);
        let mut rng = Xoshiro256::seed_from(14);
        let mut arrivals = Vec::new();
        for t in 0..20 {
            arrivals.extend(gen.generate_slot(t, 1.0, &mut rng));
        }
        let trace = Trace::from_arrivals(arrivals.clone());
        let text = trace.to_text();
        let back = Trace::from_text(&text).unwrap();
        assert_eq!(back.arrivals().len(), arrivals.len());
        for (a, b) in arrivals.iter().zip(back.arrivals()) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.user, b.user);
            assert_eq!(a.task_type.0, b.task_type.0);
            assert_eq!(a.slot, b.slot);
            assert!((a.snr - b.snr).abs() < 1e-9);
        }
    }

    #[test]
    fn trace_file_roundtrip() {
        let (_, mut gen) = setup(8);
        let mut rng = Xoshiro256::seed_from(16);
        let mut arrivals = Vec::new();
        for t in 0..15 {
            arrivals.extend(gen.generate_slot(t, 1.0, &mut rng));
        }
        let trace = Trace::from_arrivals(arrivals);
        let path = std::env::temp_dir().join(format!(
            "fmedge_trace_roundtrip_{}.txt",
            std::process::id()
        ));
        let path = path.to_str().unwrap().to_string();
        trace.save(&path).unwrap();
        let back = Trace::load(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        assert_eq!(back.len(), trace.len());
        assert_eq!(back.num_slots(), trace.num_slots());
        for (a, b) in trace.arrivals().iter().zip(back.arrivals()) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.ed, b.ed);
            assert!((a.uplink_delay_ms - b.uplink_delay_ms).abs() < 1e-9);
        }
    }

    #[test]
    fn trace_slot_view() {
        let (_, mut gen) = setup(7);
        let mut rng = Xoshiro256::seed_from(15);
        let mut arrivals = Vec::new();
        for t in 0..10 {
            arrivals.extend(gen.generate_slot(t, 1.0, &mut rng));
        }
        let trace = Trace::from_arrivals(arrivals.clone());
        let mut seen = 0;
        for t in 0..10 {
            for a in trace.slot(t) {
                assert_eq!(a.slot, t);
                seen += 1;
            }
        }
        assert_eq!(seen, arrivals.len());
        assert!(trace.slot(9999).is_empty());
    }

    #[test]
    fn malformed_trace_rejected() {
        assert!(Trace::from_text("not a trace").is_err());
        assert!(Trace::from_text("task 1 2").is_err());
    }
}
