//! Directed-acyclic-graph substrate for task graphs `G_n = (M_n, L_n)`.
//!
//! The paper models each inference task type as a DAG over microservices;
//! "consistent with multimodal data fusion, these graphs typically form
//! inverse-tree structures, where each node may have multiple incoming but
//! at most one outgoing edge" (§II-A). This module provides the generic
//! DAG machinery: topological order, ancestor/descendant sets, inverse-tree
//! validation, and critical paths — used by the latency model (eq. 4), the
//! mean-value analysis (§III-A) and the routers.

mod dag;

pub use dag::{Dag, DagError, NodeId};

#[cfg(test)]
mod tests {
    use super::*;

    fn diamond() -> Dag {
        // 0 -> 2, 1 -> 2, 2 -> 3
        let mut d = Dag::new(4);
        d.add_edge(0, 2).unwrap();
        d.add_edge(1, 2).unwrap();
        d.add_edge(2, 3).unwrap();
        d
    }

    #[test]
    fn topo_order_respects_edges() {
        let d = diamond();
        let order = d.topo_order().unwrap();
        let pos: Vec<usize> = {
            let mut p = vec![0; 4];
            for (i, &n) in order.iter().enumerate() {
                p[n] = i;
            }
            p
        };
        assert!(pos[0] < pos[2] && pos[1] < pos[2] && pos[2] < pos[3]);
    }

    #[test]
    fn cycle_detected() {
        let mut d = Dag::new(3);
        d.add_edge(0, 1).unwrap();
        d.add_edge(1, 2).unwrap();
        d.add_edge(2, 0).unwrap();
        assert!(matches!(d.topo_order(), Err(DagError::Cycle)));
    }

    #[test]
    fn inverse_tree_check() {
        let d = diamond();
        assert!(d.is_inverse_tree());
        let mut bad = Dag::new(3);
        bad.add_edge(0, 1).unwrap();
        bad.add_edge(0, 2).unwrap(); // node 0 has two outgoing edges
        assert!(!bad.is_inverse_tree());
    }

    #[test]
    fn descendants_and_ancestors() {
        let d = diamond();
        assert_eq!(d.descendants(0), vec![2, 3]);
        assert_eq!(d.descendants(3), Vec::<usize>::new());
        assert_eq!(d.ancestors(3), vec![0, 1, 2]);
        assert_eq!(d.ancestors(0), Vec::<usize>::new());
    }

    #[test]
    fn sources_and_sink() {
        let d = diamond();
        assert_eq!(d.sources(), vec![0, 1]);
        assert_eq!(d.sinks(), vec![3]);
        assert_eq!(d.sink().unwrap(), 3);
    }

    #[test]
    fn critical_path_weighted() {
        let d = diamond();
        // node weights: longest path 1(w5) -> 2(w1) -> 3(w2) = 8
        let w = [3.0, 5.0, 1.0, 2.0];
        let (len, path) = d.critical_path(|n| w[n]);
        assert!((len - 8.0).abs() < 1e-12);
        assert_eq!(path, vec![1, 2, 3]);
    }
}
