//! Compact adjacency-list DAG with the traversals the latency model needs.

/// Node identifier within a [`Dag`] (dense `0..n`).
pub type NodeId = usize;

/// Errors from DAG construction / traversal.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum DagError {
    /// Edge endpoint out of range.
    NodeOutOfRange { node: NodeId, len: usize },
    /// A cycle was detected where a DAG was required.
    Cycle,
    /// Duplicate edge insertion.
    DuplicateEdge { from: NodeId, to: NodeId },
}

impl std::fmt::Display for DagError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DagError::NodeOutOfRange { node, len } => {
                write!(f, "node {node} out of range (graph has {len} nodes)")
            }
            DagError::Cycle => write!(f, "graph contains a cycle"),
            DagError::DuplicateEdge { from, to } => {
                write!(f, "duplicate edge {from} -> {to}")
            }
        }
    }
}

impl std::error::Error for DagError {}

/// Directed graph stored as in/out adjacency lists. All public methods that
/// assume acyclicity return [`DagError::Cycle`] when violated.
#[derive(Clone, Debug, Default)]
pub struct Dag {
    out_edges: Vec<Vec<NodeId>>,
    in_edges: Vec<Vec<NodeId>>,
}

impl Dag {
    /// A graph with `n` isolated nodes.
    pub fn new(n: usize) -> Self {
        Dag {
            out_edges: vec![Vec::new(); n],
            in_edges: vec![Vec::new(); n],
        }
    }

    /// Number of nodes.
    pub fn len(&self) -> usize {
        self.out_edges.len()
    }

    /// True when the graph has no nodes.
    pub fn is_empty(&self) -> bool {
        self.out_edges.is_empty()
    }

    /// Number of edges.
    pub fn edge_count(&self) -> usize {
        self.out_edges.iter().map(Vec::len).sum()
    }

    /// Append a new isolated node, returning its id.
    pub fn add_node(&mut self) -> NodeId {
        self.out_edges.push(Vec::new());
        self.in_edges.push(Vec::new());
        self.out_edges.len() - 1
    }

    /// Insert edge `from -> to`.
    pub fn add_edge(&mut self, from: NodeId, to: NodeId) -> Result<(), DagError> {
        let len = self.len();
        if from >= len {
            return Err(DagError::NodeOutOfRange { node: from, len });
        }
        if to >= len {
            return Err(DagError::NodeOutOfRange { node: to, len });
        }
        if self.out_edges[from].contains(&to) {
            return Err(DagError::DuplicateEdge { from, to });
        }
        self.out_edges[from].push(to);
        self.in_edges[to].push(from);
        Ok(())
    }

    /// Direct successors of `n`.
    pub fn children(&self, n: NodeId) -> &[NodeId] {
        &self.out_edges[n]
    }

    /// Direct predecessors of `n` — the `V^pa` sets of eq. (4).
    pub fn parents(&self, n: NodeId) -> &[NodeId] {
        &self.in_edges[n]
    }

    /// Nodes with no incoming edges (task entry points).
    pub fn sources(&self) -> Vec<NodeId> {
        (0..self.len()).filter(|&n| self.in_edges[n].is_empty()).collect()
    }

    /// Nodes with no outgoing edges.
    pub fn sinks(&self) -> Vec<NodeId> {
        (0..self.len()).filter(|&n| self.out_edges[n].is_empty()).collect()
    }

    /// The unique sink of an inverse tree, if it exists.
    pub fn sink(&self) -> Option<NodeId> {
        let s = self.sinks();
        if s.len() == 1 {
            Some(s[0])
        } else {
            None
        }
    }

    /// Kahn topological order; `Err(Cycle)` when the graph is cyclic.
    pub fn topo_order(&self) -> Result<Vec<NodeId>, DagError> {
        let n = self.len();
        let mut indeg: Vec<usize> = (0..n).map(|i| self.in_edges[i].len()).collect();
        let mut queue: Vec<NodeId> = (0..n).filter(|&i| indeg[i] == 0).collect();
        let mut order = Vec::with_capacity(n);
        let mut head = 0;
        while head < queue.len() {
            let u = queue[head];
            head += 1;
            order.push(u);
            for &v in &self.out_edges[u] {
                indeg[v] -= 1;
                if indeg[v] == 0 {
                    queue.push(v);
                }
            }
        }
        if order.len() == n {
            Ok(order)
        } else {
            Err(DagError::Cycle)
        }
    }

    /// True when every node has at most one outgoing edge and the graph is
    /// acyclic with a single sink — the paper's "inverse tree" shape.
    pub fn is_inverse_tree(&self) -> bool {
        self.out_edges.iter().all(|es| es.len() <= 1)
            && self.topo_order().is_ok()
            && self.sinks().len() == 1
    }

    /// All nodes reachable from `n` (exclusive), ascending id order.
    /// This is the `M^de_n(m)` descendant set of §III-A.
    pub fn descendants(&self, n: NodeId) -> Vec<NodeId> {
        self.reach(n, &self.out_edges)
    }

    /// All nodes that reach `n` (exclusive), ascending id order.
    pub fn ancestors(&self, n: NodeId) -> Vec<NodeId> {
        self.reach(n, &self.in_edges)
    }

    fn reach(&self, n: NodeId, adj: &[Vec<NodeId>]) -> Vec<NodeId> {
        let mut seen = vec![false; self.len()];
        let mut stack = vec![n];
        while let Some(u) = stack.pop() {
            for &v in &adj[u] {
                if !seen[v] {
                    seen[v] = true;
                    stack.push(v);
                }
            }
        }
        (0..self.len()).filter(|&i| seen[i]).collect()
    }

    /// Longest node-weighted path; returns `(length, path)`.
    ///
    /// Used to lower-bound end-to-end latency (the critical chain of
    /// processing delays) when profiling task types.
    pub fn critical_path<F: Fn(NodeId) -> f64>(&self, weight: F) -> (f64, Vec<NodeId>) {
        let order = match self.topo_order() {
            Ok(o) => o,
            Err(_) => return (f64::NAN, Vec::new()),
        };
        let n = self.len();
        let mut dist = vec![f64::NEG_INFINITY; n];
        let mut pred: Vec<Option<NodeId>> = vec![None; n];
        for &u in &order {
            if self.in_edges[u].is_empty() {
                dist[u] = weight(u);
            }
            for &v in &self.out_edges[u] {
                let cand = dist[u] + weight(v);
                if cand > dist[v] {
                    dist[v] = cand;
                    pred[v] = Some(u);
                }
            }
        }
        let (best, &len) = dist
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .unwrap_or((0, &0.0));
        let mut path = vec![best];
        let mut cur = best;
        while let Some(p) = pred[cur] {
            path.push(p);
            cur = p;
        }
        path.reverse();
        (len, path)
    }

    /// Stage index of each node: the longest hop-distance from any source.
    /// Stages group microservices that can execute concurrently.
    pub fn stages(&self) -> Result<Vec<usize>, DagError> {
        let order = self.topo_order()?;
        let mut stage = vec![0usize; self.len()];
        for &u in &order {
            for &v in &self.out_edges[u] {
                stage[v] = stage[v].max(stage[u] + 1);
            }
        }
        Ok(stage)
    }
}
