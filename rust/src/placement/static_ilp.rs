//! The sparsity-constrained integer program (14) + C4–C6 and its greedy
//! fallback.

use crate::config::{ExperimentConfig, NUM_RESOURCES};
use crate::ilp::{BnbOptions, BnbStats, IlpModel, IlpStatus, LinExpr, NodeLpMode, VarKind};
use crate::lp::Relation;
use crate::microservice::Application;
use crate::network::Topology;

use super::qos_score::QosScores;

/// Solver parameters.
#[derive(Clone, Debug)]
pub struct PlacementParams {
    /// QoS weight ξ in (14); auto-normalized against the cost scale.
    pub xi: f64,
    /// Minimum distinct (node, MS) deployments κ (C6).
    pub kappa: usize,
    /// Fraction of each node's capacity reserved for core services; the
    /// remainder `R^lt` feeds the dynamic tier (17).
    pub core_capacity_fraction: f64,
    /// Horizon length in slots (maintenance cost multiplier).
    pub slots: usize,
    /// Safety factor on the demand constraint C2.
    pub demand_margin: f64,
    /// Slot length (ms) for the Erlang demand conversion.
    pub slot_ms: f64,
    /// Skip the ILP and use the greedy fallback (tests / degraded mode).
    pub force_fallback: bool,
    /// Solve the integer program exactly by branch-and-bound (warm-started
    /// from the greedy cover). Default is the LP-relaxation + rounding +
    /// κ-repair pipeline, which is orders of magnitude faster and within a
    /// few percent of the exact optimum on paper-scale instances — see
    /// `bench_ilp` for the measured gap.
    pub exact: bool,
    /// Branch-and-bound node budget (exact mode).
    pub max_nodes: usize,
    /// Per-node LP engine for the exact solver: warm-started revised
    /// simplex (default) or the dense-rebuild baseline (benchmarks and
    /// cross-checks only).
    pub node_lp: NodeLpMode,
    /// Restrict core candidates to edge servers (§I: "computationally
    /// lightweight and heavyweight MSs deployed onto edge devices and edge
    /// servers, respectively"). Keeps the integer program at the paper's
    /// scale and exactly solvable.
    pub core_on_es_only: bool,
}

impl PlacementParams {
    pub fn from_config(cfg: &ExperimentConfig, slots: usize) -> Self {
        PlacementParams {
            xi: cfg.controller.xi,
            kappa: cfg.controller.kappa,
            core_capacity_fraction: 0.85,
            slots,
            demand_margin: 1.4,
            slot_ms: cfg.sim.slot_ms,
            force_fallback: false,
            exact: false,
            max_nodes: 5_000,
            node_lp: NodeLpMode::WarmRevised,
            core_on_es_only: true,
        }
    }
}

/// The static core placement `X^cr`.
#[derive(Clone, Debug)]
pub struct CorePlacement {
    /// `instances[v][ci]` — instance count of dense core MS `ci` at node v.
    pub instances: Vec<Vec<u32>>,
    /// Value of objective (14) at the solution.
    pub objective: f64,
    /// Whether the greedy fallback produced this placement.
    pub used_fallback: bool,
    /// Distinct (v, m) deployments (the C6 support).
    pub support: usize,
    /// The (capacity-capped) demand target per core MS that C2 enforced.
    pub demand_target: Vec<f64>,
    /// Branch-and-bound statistics (exact mode only; `None` for the
    /// greedy and LP+rounding pipelines).
    pub stats: Option<BnbStats>,
}

impl CorePlacement {
    /// Residual capacity for the dynamic tier: `R^lt_{v,k}` of (17),
    /// computed against the *full* node capacity.
    pub fn residual_capacity(&self, app: &Application, topo: &Topology) -> Vec<[f64; NUM_RESOURCES]> {
        let core_ids = app.catalog.core_ids();
        topo.nodes()
            .iter()
            .map(|node| {
                let mut res = node.capacity;
                for (ci, &m) in core_ids.iter().enumerate() {
                    let spec = app.catalog.spec(m);
                    let x = self.instances[node.id][ci] as f64;
                    for k in 0..NUM_RESOURCES {
                        res[k] = (res[k] - spec.resources[k] * x).max(0.0);
                    }
                }
                res
            })
            .collect()
    }

    /// Total instance count.
    pub fn total_instances(&self) -> u32 {
        self.instances.iter().flat_map(|r| r.iter()).sum()
    }
}

/// Solve (14) with C4–C6. Falls back to a greedy cover when the MILP is
/// truncated or infeasible (e.g. κ too aggressive for tiny networks).
pub fn solve_static_placement(
    app: &Application,
    topo: &Topology,
    scores: &QosScores,
    params: &PlacementParams,
) -> CorePlacement {
    let core_ids = app.catalog.core_ids();
    let nv = topo.num_nodes();
    let nc = core_ids.len();

    // Per-(v,m) instance upper bound from the reserved capacity (tightens
    // big-M C4 to the physically possible count).
    let mut ub = vec![vec![0u64; nc]; nv];
    let es_only = params.core_on_es_only;
    for v in 0..nv {
        if es_only && topo.node(v).class != crate::network::NodeClass::EdgeServer {
            continue; // EDs host light services only
        }
        for (ci, &m) in core_ids.iter().enumerate() {
            let spec = app.catalog.spec(m);
            let mut cap = u64::MAX;
            for k in 0..NUM_RESOURCES {
                if spec.resources[k] > 0.0 {
                    let fit = (params.core_capacity_fraction * topo.node(v).capacity[k]
                        / spec.resources[k])
                        .floor();
                    cap = cap.min(fit.max(0.0) as u64);
                }
            }
            ub[v][ci] = cap.min(64);
        }
    }

    // Demand per core MS (C2, Erlang form — see QosScores::erlang_demand),
    // capped at what the candidate nodes can physically host so C2 stays
    // feasible under worst-case Table I draws (best-effort provisioning).
    let demand: Vec<f64> = (0..nc)
        .map(|ci| {
            let d = scores.erlang_demand(
                ci,
                app.catalog.spec(core_ids[ci]).mean_proc_delay(),
                params.slot_ms,
            );
            let want = (d * params.demand_margin).ceil().max(1.0);
            // Per-MS deployable bound (ignores cross-MS contention; joint
            // feasibility is handled by the demand-scaling retry below).
            let deployable: f64 = (0..nv).map(|v| ub[v][ci] as f64).sum::<f64>().max(1.0);
            want.min(deployable)
        })
        .collect();

    // Effective horizon cost of one instance: c^dp + |T|·c^mt.
    let unit_cost: Vec<f64> = core_ids
        .iter()
        .map(|&m| {
            let s = app.catalog.spec(m);
            s.cost_deploy + s.cost_maint * params.slots as f64
        })
        .collect();

    // Normalize ξ so every objective coefficient `c_m − ξ·Q_{v,m}` stays
    // positive: the score then steers *where* instances go while the cost
    // still bounds *how many* (a negative coefficient would make the
    // solver pile surplus instances onto high-score slots, starving the
    // capacity needed by other services' demand constraints).
    let mut min_ratio = f64::INFINITY;
    for (v, row) in scores.q.iter().enumerate() {
        for (ci, &q) in row.iter().enumerate() {
            if q > 0.0 && ub[v][ci] > 0 {
                min_ratio = min_ratio.min(unit_cost[ci] / q);
            }
        }
    }
    let xi_eff = if min_ratio.is_finite() {
        (params.xi).min(1.0) * 0.9 * min_ratio
    } else {
        0.0
    };

    let open_slots = ub
        .iter()
        .flat_map(|r| r.iter())
        .filter(|&&u| u > 0)
        .count();
    let kappa = params.kappa.min(open_slots);

    // Greedy cover first: it serves as the fallback, warm-starts the exact
    // branch-and-bound, and backs the rounding pipeline.
    let fallback =
        greedy_fallback(app, topo, scores, params, &ub, &demand, &unit_cost, xi_eff, kappa);
    if params.force_fallback {
        return fallback;
    }
    if params.exact {
        return try_ilp(
            app, topo, scores, params, &ub, &demand, &unit_cost, xi_eff, kappa, &fallback,
        )
        .unwrap_or(fallback);
    }
    lp_round(
        app, topo, scores, params, &ub, &demand, &unit_cost, xi_eff, kappa,
    )
    .unwrap_or(fallback)
}

/// LP relaxation of (14) + rounding + κ repair.
///
/// 1. Solve the continuous relaxation with elastic demand (shortfall
///    slack at 10× unit cost) — one simplex solve, no integer search.
/// 2. Floor the solution; greedily restore any demand shortfall in
///    descending fractional-part-then-score order under the capacity
///    reservation.
/// 3. Open additional best-score slots until the κ-support constraint C6
///    holds (the paper's anti-consolidation diversity rule).
#[allow(clippy::too_many_arguments)]
fn lp_round(
    app: &Application,
    topo: &Topology,
    scores: &QosScores,
    params: &PlacementParams,
    ub: &[Vec<u64>],
    demand: &[f64],
    unit_cost: &[f64],
    xi_eff: f64,
    kappa: usize,
) -> Option<CorePlacement> {
    let core_ids = app.catalog.core_ids();
    let nv = topo.num_nodes();
    let nc = core_ids.len();

    // Variable layout: x[v][ci] for open slots, then one slack per MS.
    let mut idx = vec![vec![None; nc]; nv];
    let mut nvars = 0usize;
    for v in 0..nv {
        for ci in 0..nc {
            if ub[v][ci] > 0 {
                idx[v][ci] = Some(nvars);
                nvars += 1;
            }
        }
    }
    let slack0 = nvars;
    nvars += nc;

    let mut lp = crate::lp::LinProg::minimize(nvars);
    for v in 0..nv {
        for ci in 0..nc {
            if let Some(i) = idx[v][ci] {
                lp.set_objective_coeff(i, unit_cost[ci] - xi_eff * scores.q[v][ci]);
                lp.set_upper_bound(i, ub[v][ci] as f64);
            }
        }
    }
    for ci in 0..nc {
        lp.set_objective_coeff(slack0 + ci, 10.0 * unit_cost[ci]);
        lp.set_upper_bound(slack0 + ci, demand[ci]);
    }
    // C1: reserved capacity per node/resource.
    for v in 0..nv {
        for k in 0..NUM_RESOURCES {
            let mut terms = Vec::new();
            for (ci, &m) in core_ids.iter().enumerate() {
                if let Some(i) = idx[v][ci] {
                    let r = app.catalog.spec(m).resources[k];
                    if r > 0.0 {
                        terms.push((i, r));
                    }
                }
            }
            if !terms.is_empty() {
                lp.add_constraint(
                    &terms,
                    Relation::Le,
                    params.core_capacity_fraction * topo.node(v).capacity[k],
                );
            }
        }
    }
    // C2 elastic.
    for ci in 0..nc {
        let mut terms: Vec<(usize, f64)> = (0..nv)
            .filter_map(|v| idx[v][ci].map(|i| (i, 1.0)))
            .collect();
        if terms.is_empty() {
            return None;
        }
        terms.push((slack0 + ci, 1.0));
        lp.add_constraint(&terms, Relation::Ge, demand[ci]);
    }
    let sol = lp.solve().ok()?;
    if sol.status != crate::lp::LpStatus::Optimal {
        return None;
    }

    // Round down, then repair demand within capacity.
    let mut instances = vec![vec![0u32; nc]; nv];
    let mut residual: Vec<[f64; NUM_RESOURCES]> = topo
        .nodes()
        .iter()
        .map(|n| {
            let mut r = n.capacity;
            for x in &mut r {
                *x *= params.core_capacity_fraction;
            }
            r
        })
        .collect();
    let mut frac = Vec::new(); // (fractional part, v, ci)
    for v in 0..nv {
        for ci in 0..nc {
            if let Some(i) = idx[v][ci] {
                let val = sol.x[i].max(0.0);
                let fl = val.floor();
                instances[v][ci] = fl as u32;
                let spec = app.catalog.spec(core_ids[ci]);
                for k in 0..NUM_RESOURCES {
                    residual[v][k] -= spec.resources[k] * fl;
                }
                if val - fl > 1e-9 {
                    frac.push((val - fl, v, ci));
                }
            }
        }
    }
    frac.sort_by(|a, b| b.0.total_cmp(&a.0));
    let fits = |residual: &[[f64; NUM_RESOURCES]], v: usize, ci: usize| -> bool {
        let spec = app.catalog.spec(core_ids[ci]);
        (0..NUM_RESOURCES).all(|k| residual[v][k] >= spec.resources[k] - 1e-9)
    };
    let shortfall = |instances: &[Vec<u32>], ci: usize| -> f64 {
        demand[ci] - (0..nv).map(|v| instances[v][ci] as f64).sum::<f64>()
    };
    // Pass 1: promote fractional slots where their MS is still short.
    for &(_, v, ci) in &frac {
        if shortfall(&instances, ci) > 0.0
            && instances[v][ci] < ub[v][ci] as u32
            && fits(&residual, v, ci)
        {
            instances[v][ci] += 1;
            let spec = app.catalog.spec(core_ids[ci]);
            for k in 0..NUM_RESOURCES {
                residual[v][k] -= spec.resources[k];
            }
        }
    }
    // Pass 2: any remaining shortfall → best-score feasible slots.
    for ci in 0..nc {
        while shortfall(&instances, ci) > 0.0 {
            let mut best: Option<(usize, f64)> = None;
            for v in 0..nv {
                if idx[v][ci].is_some()
                    && instances[v][ci] < ub[v][ci] as u32
                    && fits(&residual, v, ci)
                {
                    let q = scores.q[v][ci];
                    if best.map_or(true, |(_, b)| q > b) {
                        best = Some((v, q));
                    }
                }
            }
            let Some((v, _)) = best else { break };
            instances[v][ci] += 1;
            let spec = app.catalog.spec(core_ids[ci]);
            for k in 0..NUM_RESOURCES {
                residual[v][k] -= spec.resources[k];
            }
        }
    }
    // Pass 3: κ support repair.
    let mut support = instances
        .iter()
        .flat_map(|r| r.iter())
        .filter(|&&x| x > 0)
        .count();
    if support < kappa {
        let mut empty: Vec<(usize, usize)> = (0..nv)
            .flat_map(|v| (0..nc).map(move |ci| (v, ci)))
            .filter(|&(v, ci)| instances[v][ci] == 0 && ub[v][ci] > 0)
            .collect();
        empty.sort_by(|&(v1, c1), &(v2, c2)| {
            scores.q[v2][c2].total_cmp(&scores.q[v1][c1])
        });
        for (v, ci) in empty {
            if support >= kappa {
                break;
            }
            if fits(&residual, v, ci) {
                instances[v][ci] += 1;
                let spec = app.catalog.spec(core_ids[ci]);
                for k in 0..NUM_RESOURCES {
                    residual[v][k] -= spec.resources[k];
                }
                support += 1;
            }
        }
    }

    let mut objective = 0.0;
    for v in 0..nv {
        for ci in 0..nc {
            objective += instances[v][ci] as f64 * (unit_cost[ci] - xi_eff * scores.q[v][ci]);
        }
    }
    let support = instances
        .iter()
        .flat_map(|r| r.iter())
        .filter(|&&x| x > 0)
        .count();
    Some(CorePlacement {
        instances,
        objective,
        used_fallback: false,
        support,
        demand_target: demand.to_vec(),
        stats: None,
    })
}

#[allow(clippy::too_many_arguments)]
fn try_ilp(
    app: &Application,
    topo: &Topology,
    scores: &QosScores,
    params: &PlacementParams,
    ub: &[Vec<u64>],
    demand: &[f64],
    unit_cost: &[f64],
    xi_eff: f64,
    kappa: usize,
    warm: &CorePlacement,
) -> Option<CorePlacement> {
    let core_ids = app.catalog.core_ids();
    let nv = topo.num_nodes();
    let nc = core_ids.len();

    let mut model = IlpModel::new();
    // x_{v,m} integer.
    let mut x = vec![vec![None; nc]; nv];
    for v in 0..nv {
        for ci in 0..nc {
            if ub[v][ci] == 0 {
                continue;
            }
            let coeff = unit_cost[ci] - xi_eff * scores.q[v][ci];
            x[v][ci] = Some(model.add_var(VarKind::Integer { ub: Some(ub[v][ci]) }, coeff));
        }
    }
    // Indicator x̂_{v,m} (C4/C5).
    let mut xhat = vec![vec![None; nc]; nv];
    for v in 0..nv {
        for ci in 0..nc {
            if x[v][ci].is_some() {
                xhat[v][ci] = Some(model.add_var(VarKind::Binary, 0.0));
            }
        }
    }

    // C1: reserved per-node capacity.
    for v in 0..nv {
        for k in 0..NUM_RESOURCES {
            let mut expr = LinExpr::new();
            for (ci, &m) in core_ids.iter().enumerate() {
                if let Some(var) = x[v][ci] {
                    let r = app.catalog.spec(m).resources[k];
                    if r > 0.0 {
                        expr.add(var, r);
                    }
                }
            }
            if !expr.terms.is_empty() {
                model.add_constraint(
                    expr,
                    Relation::Le,
                    params.core_capacity_fraction * topo.node(v).capacity[k],
                );
            }
        }
    }
    // C2 (elastic): global demand per MS with penalized shortfall slack —
    // keeps the program feasible under worst-case Table I draws where the
    // joint capacity cannot cover every demand (best-effort provisioning),
    // which in turn lets branch-and-bound terminate without exhaustive
    // infeasibility proofs.
    let mut slack_vars = Vec::with_capacity(nc);
    for ci in 0..nc {
        let mut expr = LinExpr::new();
        for v in 0..nv {
            if let Some(var) = x[v][ci] {
                expr.add(var, 1.0);
            }
        }
        if expr.terms.is_empty() {
            return None; // no node can host this MS at all
        }
        let s = model.add_var(
            VarKind::Continuous { ub: Some(demand[ci]) },
            10.0 * unit_cost[ci],
        );
        slack_vars.push(s);
        expr.add(s, 1.0);
        model.add_constraint(expr, Relation::Ge, demand[ci]);
    }
    // C4/C5: indicator coupling; C6: minimum support.
    let mut support = LinExpr::new();
    for v in 0..nv {
        for ci in 0..nc {
            if let (Some(xv), Some(hv)) = (x[v][ci], xhat[v][ci]) {
                let big = ub[v][ci] as f64;
                model.add_constraint(
                    LinExpr::from_terms(&[(xv, 1.0), (hv, -big)]),
                    Relation::Le,
                    0.0,
                );
                model.add_constraint(
                    LinExpr::from_terms(&[(xv, 1.0), (hv, -1.0)]),
                    Relation::Ge,
                    0.0,
                );
                support.add(hv, 1.0);
            }
        }
    }
    model.add_constraint(support, Relation::Ge, kappa as f64);

    // Warm-start incumbent from the greedy fallback solution (x, x̂, s).
    let mut warm_x = vec![0.0; model.num_vars()];
    for v in 0..nv {
        for ci in 0..nc {
            if let Some(var) = x[v][ci] {
                warm_x[var.0] = warm.instances[v][ci] as f64;
            }
            if let Some(h) = xhat[v][ci] {
                warm_x[h.0] = if warm.instances[v][ci] > 0 { 1.0 } else { 0.0 };
            }
        }
    }
    for (ci, &s) in slack_vars.iter().enumerate() {
        let placed: f64 = (0..nv)
            .filter(|&v| x[v][ci].is_some())
            .map(|v| warm.instances[v][ci] as f64)
            .sum();
        warm_x[s.0] = (demand[ci] - placed).max(0.0);
    }
    let initial_incumbent = if model.is_feasible(&warm_x, 1e-6) {
        Some((warm_x.clone(), model.objective_at(&warm_x)))
    } else {
        None
    };

    let opts = BnbOptions {
        max_nodes: params.max_nodes,
        initial_incumbent,
        node_lp: params.node_lp,
        ..Default::default()
    };
    let sol = model.solve(&opts).ok()?;
    if !matches!(sol.status, IlpStatus::Optimal | IlpStatus::Feasible) {
        return None;
    }
    let mut instances = vec![vec![0u32; nc]; nv];
    let mut supp = 0usize;
    for v in 0..nv {
        for ci in 0..nc {
            if let Some(var) = x[v][ci] {
                let c = sol.int_value(var) as u32;
                instances[v][ci] = c;
                if c > 0 {
                    supp += 1;
                }
            }
        }
    }
    Some(CorePlacement {
        instances,
        objective: sol.objective,
        used_fallback: false,
        support: supp,
        demand_target: demand.to_vec(),
        stats: Some(sol.stats),
    })
}

/// Greedy fallback: open (v, m) slots in decreasing score-per-cost order
/// until demand and the κ support are both satisfied.
#[allow(clippy::too_many_arguments)]
fn greedy_fallback(
    app: &Application,
    topo: &Topology,
    scores: &QosScores,
    params: &PlacementParams,
    ub: &[Vec<u64>],
    demand: &[f64],
    unit_cost: &[f64],
    xi_eff: f64,
    kappa: usize,
) -> CorePlacement {
    let core_ids = app.catalog.core_ids();
    let nv = topo.num_nodes();
    let nc = core_ids.len();
    let mut instances = vec![vec![0u32; nc]; nv];
    let mut residual: Vec<[f64; NUM_RESOURCES]> = topo
        .nodes()
        .iter()
        .map(|n| {
            let mut r = n.capacity;
            for v in &mut r {
                *v *= params.core_capacity_fraction;
            }
            r
        })
        .collect();

    let fits = |residual: &[[f64; NUM_RESOURCES]], v: usize, ci: usize| -> bool {
        let spec = app.catalog.spec(core_ids[ci]);
        (0..NUM_RESOURCES).all(|k| residual[v][k] >= spec.resources[k])
    };
    let mut place = |instances: &mut Vec<Vec<u32>>,
                     residual: &mut Vec<[f64; NUM_RESOURCES]>,
                     v: usize,
                     ci: usize| {
        let spec = app.catalog.spec(core_ids[ci]);
        for k in 0..NUM_RESOURCES {
            residual[v][k] -= spec.resources[k];
        }
        instances[v][ci] += 1;
    };

    // 1. Satisfy demand fairly: round-robin across services (one instance
    // per MS per round, best-score node first) so no service is starved by
    // earlier ones consuming the joint capacity.
    let mut orders: Vec<Vec<usize>> = (0..nc)
        .map(|ci| {
            let mut order: Vec<usize> = (0..nv).filter(|&v| ub[v][ci] > 0).collect();
            order.sort_by(|&a, &b| scores.q[b][ci].total_cmp(&scores.q[a][ci]));
            order
        })
        .collect();
    let mut placed = vec![0.0f64; nc];
    loop {
        let mut progressed = false;
        for ci in 0..nc {
            if placed[ci] >= demand[ci] {
                continue;
            }
            for oi in 0..orders[ci].len() {
                let v = orders[ci][oi];
                if instances[v][ci] < ub[v][ci] as u32 && fits(&residual, v, ci) {
                    place(&mut instances, &mut residual, v, ci);
                    placed[ci] += 1.0;
                    progressed = true;
                    break;
                }
            }
        }
        if !progressed {
            break; // every unmet service is capacity-blocked
        }
        if (0..nc).all(|ci| placed[ci] >= demand[ci]) {
            break;
        }
    }
    orders.clear();

    // 2. Ensure κ distinct deployments: open the best-scoring empty slots.
    let mut support: usize = instances
        .iter()
        .flat_map(|r| r.iter())
        .filter(|&&x| x > 0)
        .count();
    if support < kappa {
        let mut empty: Vec<(usize, usize)> = (0..nv)
            .flat_map(|v| (0..nc).map(move |ci| (v, ci)))
            .filter(|&(v, ci)| instances[v][ci] == 0 && ub[v][ci] > 0)
            .collect();
        empty.sort_by(|&(v1, c1), &(v2, c2)| {
            scores.q[v2][c2].total_cmp(&scores.q[v1][c1])
        });
        for (v, ci) in empty {
            if support >= kappa {
                break;
            }
            if fits(&residual, v, ci) {
                place(&mut instances, &mut residual, v, ci);
                support += 1;
            }
        }
    }

    // Objective value for reporting.
    let mut objective = 0.0;
    for v in 0..nv {
        for ci in 0..nc {
            objective +=
                instances[v][ci] as f64 * (unit_cost[ci] - xi_eff * scores.q[v][ci]);
        }
    }
    let support = instances
        .iter()
        .flat_map(|r| r.iter())
        .filter(|&&x| x > 0)
        .count();
    CorePlacement {
        instances,
        objective,
        used_fallback: true,
        support,
        demand_target: demand.to_vec(),
        stats: None,
    }
}
