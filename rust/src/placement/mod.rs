//! Static core-microservice placement (§III-A).
//!
//! A forward-looking, fault-tolerant placement computed once per horizon:
//! a mean-value latency analysis produces the apportioned load `z̃_{v,m}`
//! (eq. 15) and QoS score `Q_{v,m}` (eq. 16); a sparsity-constrained
//! integer program (14) + C4–C6 then trades deployment cost against the
//! score while enforcing at least κ distinct deployments.

mod qos_score;
mod static_ilp;

pub use qos_score::{
    build_rows, placement_under_failure, FailureImpact, QosRowData, QosScores, ScoreParams,
};
pub use static_ilp::{solve_static_placement, CorePlacement, PlacementParams};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;
    use crate::microservice::build_fig1_application;
    use crate::network::Topology;
    use crate::rng::Xoshiro256;
    use crate::routing::DistanceMatrix;
    use crate::workload::WorkloadGenerator;

    fn setup(
        seed: u64,
    ) -> (
        ExperimentConfig,
        crate::microservice::Application,
        Topology,
        WorkloadGenerator,
        DistanceMatrix,
    ) {
        let cfg = ExperimentConfig::paper_default();
        let mut rng = Xoshiro256::seed_from(seed);
        let app = build_fig1_application(&cfg, &mut rng);
        let topo = Topology::generate(&cfg, &mut rng);
        let gen = WorkloadGenerator::new(&cfg, &app, &topo, &mut rng);
        let dm = DistanceMatrix::build(&topo, 1.0);
        (cfg, app, topo, gen, dm)
    }

    #[test]
    fn load_apportionment_conserves_mass() {
        let (cfg, app, topo, gen, dm) = setup(1);
        let scores = QosScores::compute(
            &app,
            &topo,
            &dm,
            gen.users(),
            &ScoreParams::from_config(&cfg.controller),
        );
        // eq. (15): summing z̃ over v recovers the total mean arrival rate
        // of task types requiring m (softmax weights sum to 1 per (u,n)).
        for (ci, &m) in app.catalog.core_ids().iter().enumerate() {
            let total: f64 = (0..topo.num_nodes()).map(|v| scores.z_tilde[v][ci]).sum();
            let mut expect = 0.0;
            for u in gen.users() {
                for tt in app.types_requiring(m) {
                    expect += gen.mean_rate(u.id, *tt);
                }
            }
            assert!(
                (total - expect).abs() < 1e-6,
                "core {ci}: apportioned {total} vs expected {expect}"
            );
        }
    }

    #[test]
    fn closer_nodes_get_more_load() {
        let (cfg, app, topo, gen, dm) = setup(2);
        let mut params = ScoreParams::from_config(&cfg.controller);
        params.delta = 1.0; // strong decay: distance matters a lot
        let scores = QosScores::compute(&app, &topo, &dm, gen.users(), &params);
        // The ED hosting users should not receive less load than the most
        // remote node for at least a majority of core MSs.
        let mut wins = 0;
        let mut total = 0;
        for ci in 0..app.catalog.num_core() {
            let ed_load = scores.z_tilde[0][ci];
            let far_node = topo.num_nodes() - 1;
            let far_load = scores.z_tilde[far_node][ci];
            total += 1;
            if ed_load >= far_load * 0.5 {
                wins += 1;
            }
        }
        assert!(wins * 2 >= total, "{wins}/{total}");
    }

    #[test]
    fn qos_scores_nonnegative_and_bounded() {
        let (cfg, app, topo, gen, dm) = setup(3);
        let params = ScoreParams::from_config(&cfg.controller);
        let scores = QosScores::compute(&app, &topo, &dm, gen.users(), &params);
        for v in 0..topo.num_nodes() {
            for ci in 0..app.catalog.num_core() {
                assert!(scores.q[v][ci] >= 0.0);
                assert!(scores.q[v][ci].is_finite());
            }
        }
    }

    #[test]
    fn placement_meets_demand_and_capacity() {
        let (cfg, app, topo, gen, dm) = setup(4);
        let sp = ScoreParams::from_config(&cfg.controller);
        let scores = QosScores::compute(&app, &topo, &dm, gen.users(), &sp);
        let params = PlacementParams::from_config(&cfg, cfg.sim.slots);
        let placement = solve_static_placement(&app, &topo, &scores, &params);
        // demand: total instances per m cover the (capacity-capped) target
        for ci in 0..app.catalog.num_core() {
            let total: u32 = placement.instances.iter().map(|row| row[ci]).sum();
            let demand = placement.demand_target[ci];
            assert!(
                total as f64 >= demand.floor(),
                "core {ci}: {total} instances for demand {demand}"
            );
        }
        // capacity: per node, core load within the reserved fraction
        for (v, row) in placement.instances.iter().enumerate() {
            for k in 0..crate::config::NUM_RESOURCES {
                let used: f64 = row
                    .iter()
                    .enumerate()
                    .map(|(ci, &x)| {
                        app.catalog.spec(app.catalog.core_ids()[ci]).resources[k] * x as f64
                    })
                    .sum();
                assert!(
                    used <= params.core_capacity_fraction * topo.node(v).capacity[k] + 1e-6,
                    "node {v} resource {k} over capacity"
                );
            }
        }
    }

    #[test]
    fn diversity_constraint_respected() {
        let (cfg, app, topo, gen, dm) = setup(5);
        let sp = ScoreParams::from_config(&cfg.controller);
        let scores = QosScores::compute(&app, &topo, &dm, gen.users(), &sp);
        let mut params = PlacementParams::from_config(&cfg, cfg.sim.slots);
        params.kappa = 10;
        let placement = solve_static_placement(&app, &topo, &scores, &params);
        let distinct = placement
            .instances
            .iter()
            .flat_map(|row| row.iter())
            .filter(|&&x| x > 0)
            .count();
        assert!(
            distinct >= 10,
            "kappa=10 requires >= 10 distinct deployments, got {distinct}"
        );
    }

    #[test]
    fn higher_kappa_never_cheapens_objective() {
        let (cfg, app, topo, gen, dm) = setup(6);
        let sp = ScoreParams::from_config(&cfg.controller);
        let scores = QosScores::compute(&app, &topo, &dm, gen.users(), &sp);
        let mut p1 = PlacementParams::from_config(&cfg, cfg.sim.slots);
        p1.kappa = 2;
        let mut p2 = p1.clone();
        p2.kappa = 12;
        let s1 = solve_static_placement(&app, &topo, &scores, &p1);
        let s2 = solve_static_placement(&app, &topo, &scores, &p2);
        // More diversity constraints can only worsen (raise) the optimum.
        assert!(s2.objective >= s1.objective - 1e-6);
    }

    #[test]
    fn under_failure_scoring_tracks_outages() {
        let (cfg, app, topo, gen, dm) = setup(8);
        let sp = ScoreParams::from_config(&cfg.controller);
        let scores = QosScores::compute(&app, &topo, &dm, gen.users(), &sp);
        let params = PlacementParams::from_config(&cfg, cfg.sim.slots);
        let placement = solve_static_placement(&app, &topo, &scores, &params);
        let nv = topo.num_nodes();

        // Healthy network: full survival.
        let healthy = placement_under_failure(&placement.instances, &scores, &vec![false; nv]);
        assert_eq!(healthy.services_lost, 0);
        assert_eq!(healthy.replicas_lost, 0);
        assert!((healthy.survival_fraction() - 1.0).abs() < 1e-12);

        // Kill the single most loaded node: monotone damage, and with the
        // κ-diversity constraint active no service should vanish.
        let (worst, _) = placement
            .instances
            .iter()
            .enumerate()
            .max_by_key(|(_, row)| row.iter().sum::<u32>())
            .unwrap();
        let mut down = vec![false; nv];
        down[worst] = true;
        let hit = placement_under_failure(&placement.instances, &scores, &down);
        assert!(hit.survival_fraction() <= 1.0 + 1e-12);
        assert!(hit.replicas_lost > 0, "worst node hosts replicas");
        // Cross-check the lost-service count against a direct scan (κ
        // bounds *distinct deployments*, not per-service replicas, so
        // zero losses is likely but not guaranteed — assert consistency,
        // not a stronger property than C6 buys).
        let expected_lost = (0..app.catalog.num_core())
            .filter(|&ci| {
                placement
                    .instances
                    .iter()
                    .enumerate()
                    .all(|(v, row)| down[v] || row[ci] == 0)
            })
            .count();
        assert_eq!(hit.services_lost, expected_lost);

        // Everything down: nothing survives.
        let all = placement_under_failure(&placement.instances, &scores, &vec![true; nv]);
        assert_eq!(all.services_lost, app.catalog.num_core());
        assert!(all.survival_fraction() < 1e-12);
    }

    #[test]
    fn fallback_greedy_produces_feasible_placement() {
        let (cfg, app, topo, gen, dm) = setup(7);
        let sp = ScoreParams::from_config(&cfg.controller);
        let scores = QosScores::compute(&app, &topo, &dm, gen.users(), &sp);
        let mut params = PlacementParams::from_config(&cfg, cfg.sim.slots);
        params.force_fallback = true;
        let placement = solve_static_placement(&app, &topo, &scores, &params);
        assert!(placement.used_fallback);
        for ci in 0..app.catalog.num_core() {
            let total: u32 = placement.instances.iter().map(|row| row[ci]).sum();
            // Best-effort: demand covered unless the joint capacity ran
            // out first, but never zero instances.
            assert!(total >= 1, "every core MS must have at least one instance");
            let _ = placement.demand_target[ci];
        }
    }
}
