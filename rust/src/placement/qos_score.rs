//! Mean-value analysis: apportioned load `z̃` (eq. 15) and QoS score `Q`
//! (eq. 16) for every (node, core MS) pair.
//!
//! For a typical task `j = (u, n, t)` requiring core MS `m` at node `v`,
//! the estimated end-to-end latency splits into
//! * `d_pr(v,m)` — preceding latency: mean uplink, network path from the
//!   user's ED to `v`, and the critical chain of mean processing delays of
//!   `m`'s DAG ancestors;
//! * `d_cu(v,m) = a_m / f_m` — processing at `v`;
//! * `d_su(v,m)` — mean processing of all DAG descendants.
//!
//! Load is apportioned by an exponential decay softmax over nodes (15);
//! the urgency metric is the deadline slack over future work, floored at
//! `C1` and capped for the sink services whose `d_su → 0` (16).
//!
//! This computation is mirrored by the Layer-2 JAX graph
//! (`python/compile/model.py::qos_scores`) compiled to
//! `artifacts/qos.hlo.txt`; `runtime::QosAccel` runs it via PJRT and the
//! integration tests check agreement.

use crate::config::ControllerConfig;
use crate::latency::MeanProfile;
use crate::microservice::Application;
use crate::network::Topology;
use crate::routing::DistanceMatrix;
use crate::workload::User;

/// Numerical floor C1 of the urgency ratio (paper's constant).
pub const URGENCY_FLOOR: f64 = 0.05;
/// Guard for sink services: `d_su` is floored at this value (ms).
pub const SUCC_FLOOR_MS: f64 = 0.05;

/// Parameters of the score computation.
#[derive(Clone, Debug)]
pub struct ScoreParams {
    /// Exponential decay δ of eq. (15).
    pub delta: f64,
    /// Upper cap on the urgency ratio (numerical guard; the paper caps
    /// only from below via C1).
    pub urgency_cap: f64,
    /// Monte-Carlo samples for mean uplink rate estimation.
    pub uplink_samples: usize,
}

impl ScoreParams {
    pub fn from_config(c: &ControllerConfig) -> Self {
        ScoreParams {
            delta: c.delta,
            urgency_cap: c.urgency_cap,
            uplink_samples: 512,
        }
    }
}

/// One (user, task-type, core-MS) row of the mean-value analysis — the
/// shared input of the native computation and the PJRT-accelerated graph
/// (`artifacts/qos.hlo.txt`).
#[derive(Clone, Debug)]
pub struct QosRowData {
    /// Preceding latency `d_pr` at every node.
    pub dpr: Vec<f64>,
    /// Mean arrival rate `E[z_{u,n}]`.
    pub rate: f64,
    pub deadline_ms: f64,
    /// Current-stage mean processing `d_cu`.
    pub dcu_ms: f64,
    /// Successor mean processing `d_su` (floored).
    pub dsu_ms: f64,
    /// Dense core index of the MS this row concerns.
    pub core_idx: usize,
}

/// Build the per-(user, type, core) rows of the mean-value analysis.
pub fn build_rows(
    app: &Application,
    topo: &Topology,
    dm: &DistanceMatrix,
    users: &[User],
    params: &ScoreParams,
) -> Vec<QosRowData> {
    let nv = topo.num_nodes();
    let core_ids = app.catalog.core_ids();

    // Mean-value profiles per task type.
    let profiles: Vec<MeanProfile> = app
        .task_types
        .iter()
        .map(|tt| MeanProfile::of(app, tt))
        .collect();

    // Mean uplink delay per user (deterministic estimate).
    let mut up_rng = crate::rng::Xoshiro256::seed_from(0x5EED_11);
    let uplink_ms: Vec<f64> = users
        .iter()
        .map(|u| {
            let mean_rate = u
                .channel
                .mean_uplink_rate(params.uplink_samples, &mut up_rng);
            let mean_input: f64 = app
                .task_types
                .iter()
                .map(|tt| tt.input_mb)
                .sum::<f64>()
                / app.task_types.len().max(1) as f64;
            mean_input / mean_rate
        })
        .collect();

    // Reference payload for inter-node movement: mean MS output size.
    let mean_out: f64 =
        app.catalog.iter().map(|s| s.output_mb).sum::<f64>() / app.catalog.len().max(1) as f64;

    let mut rows = Vec::new();
    for user in users {
        for tt in &app.task_types {
            let profile = &profiles[tt.id.0];
            let rate = user.rates[tt.id.0];
            for (ci, &m) in core_ids.iter().enumerate() {
                let locals = tt.local_nodes_of(m);
                if locals.is_empty() {
                    continue;
                }
                // If m appears multiple times, use the earliest stage.
                let local = locals[0];
                let dpr: Vec<f64> = (0..nv)
                    .map(|v| {
                        uplink_ms[user.id]
                            + dm.latency(user.ed, v, mean_out)
                            + profile.pred_ms[local]
                    })
                    .collect();
                rows.push(QosRowData {
                    dpr,
                    rate,
                    deadline_ms: tt.deadline_ms,
                    dcu_ms: profile.proc_ms[local],
                    dsu_ms: profile.succ_ms[local].max(SUCC_FLOOR_MS),
                    core_idx: ci,
                });
            }
        }
    }
    rows
}

/// The computed `z̃` and `Q` matrices, `[node][dense core index]`.
#[derive(Clone, Debug)]
pub struct QosScores {
    pub z_tilde: Vec<Vec<f64>>,
    pub q: Vec<Vec<f64>>,
    /// Mean urgency component (diagnostics / the PJRT cross-check).
    pub d_tilde: Vec<Vec<f64>>,
}

impl QosScores {
    /// Compute scores for all (v, core m) pairs.
    pub fn compute(
        app: &Application,
        topo: &Topology,
        dm: &DistanceMatrix,
        users: &[User],
        params: &ScoreParams,
    ) -> Self {
        let rows = build_rows(app, topo, dm, users, params);
        Self::compute_from_rows(
            &rows,
            topo.num_nodes(),
            app.catalog.num_core(),
            params,
        )
    }

    /// Native evaluation of eqs. (15)–(16) over prebuilt rows — the exact
    /// math the `qos.hlo.txt` artifact implements (pytest + the Rust
    /// integration tests check both paths agree).
    pub fn compute_from_rows(
        rows: &[QosRowData],
        nv: usize,
        nc: usize,
        params: &ScoreParams,
    ) -> Self {
        let mut z_tilde = vec![vec![0.0f64; nc]; nv];
        let mut d_tilde = vec![vec![0.0f64; nc]; nv];
        for row in rows {
            debug_assert_eq!(row.dpr.len(), nv);
            let min_d = row.dpr.iter().cloned().fold(f64::INFINITY, f64::min);
            let mut wsum = 0.0;
            let weights: Vec<f64> = row
                .dpr
                .iter()
                .map(|&d| {
                    let w = (-params.delta * (d - min_d)).exp();
                    wsum += w;
                    w
                })
                .collect();
            for v in 0..nv {
                z_tilde[v][row.core_idx] += weights[v] / wsum * row.rate;
                // Urgency — eq. (16): slack over future work.
                let slack = row.deadline_ms - row.dpr[v] - row.dcu_ms;
                let ratio =
                    (slack / row.dsu_ms).clamp(URGENCY_FLOOR, params.urgency_cap);
                d_tilde[v][row.core_idx] += ratio;
            }
        }
        let q = z_tilde
            .iter()
            .zip(&d_tilde)
            .map(|(zr, dr)| zr.iter().zip(dr).map(|(z, d)| z * d).collect())
            .collect();
        QosScores {
            z_tilde,
            q,
            d_tilde,
        }
    }

    /// Demand estimate for the capacity constraint C2: the Erlang load of
    /// core MS `ci` — mean arrivals per slot × service time in slots —
    /// i.e. the minimum number of always-busy instances sustaining the
    /// aggregate load.
    pub fn erlang_demand(&self, ci: usize, mean_proc_ms: f64, slot_ms: f64) -> f64 {
        let total_rate: f64 = self.z_tilde.iter().map(|row| row[ci]).sum();
        total_rate * mean_proc_ms / slot_ms
    }

    pub fn num_nodes(&self) -> usize {
        self.z_tilde.len()
    }

    pub fn num_core(&self) -> usize {
        self.z_tilde.first().map_or(0, Vec::len)
    }
}

/// How much of a core placement's value survives a set of node outages —
/// the quantitative form of the paper's "fault-tolerant backbone" claim.
#[derive(Clone, Debug)]
pub struct FailureImpact {
    /// Σ Q·x over surviving nodes.
    pub surviving_score: f64,
    /// Σ Q·x over all nodes (healthy baseline).
    pub total_score: f64,
    /// Core MSs left with zero live replicas (service outage).
    pub services_lost: usize,
    /// Replica instances lost with the failed nodes.
    pub replicas_lost: u32,
}

impl FailureImpact {
    /// Fraction of the placement's QoS-weighted value still standing in
    /// `[0, 1]`; `1.0` for an empty placement (nothing to lose).
    pub fn survival_fraction(&self) -> f64 {
        if self.total_score <= 0.0 {
            1.0
        } else {
            self.surviving_score / self.total_score
        }
    }
}

/// Evaluate a core placement under failure: `down[v]` marks dead nodes.
/// A κ-diverse placement should keep `services_lost == 0` and a high
/// survival fraction for any minority outage — that is the backbone
/// property the static ILP's C6 constraint buys.
pub fn placement_under_failure(
    instances: &[Vec<u32>],
    scores: &QosScores,
    down: &[bool],
) -> FailureImpact {
    let nc = scores.num_core();
    let mut surviving_score = 0.0;
    let mut total_score = 0.0;
    let mut replicas_lost = 0u32;
    let mut live = vec![0u32; nc];
    for (v, row) in instances.iter().enumerate() {
        let dead = down.get(v).copied().unwrap_or(false);
        for (ci, &x) in row.iter().enumerate() {
            if x == 0 {
                continue;
            }
            let q = scores.q[v][ci] * x as f64;
            total_score += q;
            if dead {
                replicas_lost += x;
            } else {
                surviving_score += q;
                live[ci] += x;
            }
        }
    }
    let services_lost = live.iter().filter(|&&n| n == 0).count();
    FailureImpact {
        surviving_score,
        total_score,
        services_lost,
        replicas_lost,
    }
}
