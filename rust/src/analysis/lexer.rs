//! A minimal hand-rolled Rust lexer for the determinism lint.
//!
//! The lint rules ([`crate::analysis::rules`]) pattern-match token
//! sequences, so the lexer's one job is to never emit a token from inside
//! a comment, string, raw string, byte string, or char literal — a
//! `HashMap` mentioned in a doc comment must not fire `hash-iter`. It is
//! *not* a full Rust lexer: multi-character operators come out as single
//! `Punct` chars (`::` is two `:` tokens) and numeric literals keep their
//! raw text, which is all the rule passes need.
//!
//! Comments are preserved on a side channel (with their line numbers) so
//! the suppression pass can find `// lint: allow(rule): reason`
//! directives.

/// Token classes coarse enough for rule matching.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword (`HashMap`, `unsafe`, `use`, …).
    Ident,
    /// Lifetime (`'a`, `'static`) — distinguished from char literals.
    Lifetime,
    /// Numeric literal, raw text (`42`, `0xBE7C`, `1_000.0e-3`).
    Num,
    /// String literal of any flavor (`"…"`, `r#"…"#`, `b"…"`).
    Str,
    /// Char or byte literal (`'x'`, `b'\n'`).
    Char,
    /// Single punctuation character.
    Punct,
}

/// One token with its 1-indexed source line.
#[derive(Clone, Debug)]
pub struct Token {
    pub kind: TokKind,
    pub text: String,
    pub line: u32,
}

/// One comment (line or block, delimiters included) at its start line.
#[derive(Clone, Debug)]
pub struct Comment {
    pub line: u32,
    pub text: String,
}

/// Lexer output: the token stream plus the comment side channel.
#[derive(Clone, Debug, Default)]
pub struct Lexed {
    pub tokens: Vec<Token>,
    pub comments: Vec<Comment>,
}

fn is_ident_start(c: char) -> bool {
    c.is_alphabetic() || c == '_'
}

fn is_ident_continue(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

struct Scanner {
    chars: Vec<char>,
    pos: usize,
    line: u32,
}

impl Scanner {
    fn peek(&self, ahead: usize) -> Option<char> {
        self.chars.get(self.pos + ahead).copied()
    }

    fn bump(&mut self) -> Option<char> {
        let c = self.chars.get(self.pos).copied();
        if let Some(c) = c {
            self.pos += 1;
            if c == '\n' {
                self.line += 1;
            }
        }
        c
    }

    /// Consume an identifier body starting at the current position.
    fn ident(&mut self) -> String {
        let mut s = String::new();
        while let Some(c) = self.peek(0) {
            if is_ident_continue(c) {
                s.push(c);
                self.bump();
            } else {
                break;
            }
        }
        s
    }

    /// Consume a `"…"` body (opening quote already consumed), honoring
    /// `\"` and `\\` escapes. Returns the raw body text.
    fn string_body(&mut self) -> String {
        let mut s = String::new();
        while let Some(c) = self.bump() {
            match c {
                '\\' => {
                    s.push(c);
                    if let Some(e) = self.bump() {
                        s.push(e);
                    }
                }
                '"' => break,
                _ => s.push(c),
            }
        }
        s
    }

    /// Consume a raw-string body after `r##…"`, terminated by `"` + the
    /// same number of hashes.
    fn raw_string_body(&mut self, hashes: usize) -> String {
        let mut s = String::new();
        while let Some(c) = self.bump() {
            if c == '"' && (0..hashes).all(|k| self.peek(k) == Some('#')) {
                for _ in 0..hashes {
                    self.bump();
                }
                break;
            }
            s.push(c);
        }
        s
    }

    /// Consume a block comment (opening `/*` already consumed), with
    /// nesting. Returns the body including nested delimiters.
    fn block_comment_body(&mut self) -> String {
        let mut s = String::new();
        let mut depth = 1usize;
        while let Some(c) = self.bump() {
            if c == '*' && self.peek(0) == Some('/') {
                self.bump();
                depth -= 1;
                if depth == 0 {
                    break;
                }
                s.push_str("*/");
            } else if c == '/' && self.peek(0) == Some('*') {
                self.bump();
                depth += 1;
                s.push_str("/*");
            } else {
                s.push(c);
            }
        }
        s
    }
}

/// Lex one source file. Never panics on malformed input: unterminated
/// literals simply run to end-of-file (the lint is advisory tooling, not
/// a compiler front end).
pub fn lex(src: &str) -> Lexed {
    let mut sc = Scanner { chars: src.chars().collect(), pos: 0, line: 1 };
    let mut out = Lexed::default();
    while let Some(c) = sc.peek(0) {
        let line = sc.line;
        match c {
            c if c.is_whitespace() => {
                sc.bump();
            }
            '/' if sc.peek(1) == Some('/') => {
                let mut text = String::new();
                while let Some(c) = sc.peek(0) {
                    if c == '\n' {
                        break;
                    }
                    text.push(c);
                    sc.bump();
                }
                out.comments.push(Comment { line, text });
            }
            '/' if sc.peek(1) == Some('*') => {
                sc.bump();
                sc.bump();
                let body = sc.block_comment_body();
                out.comments.push(Comment { line, text: format!("/*{body}*/") });
            }
            '"' => {
                sc.bump();
                let body = sc.string_body();
                out.tokens.push(Token { kind: TokKind::Str, text: body, line });
            }
            '\'' => {
                sc.bump();
                match sc.peek(0) {
                    Some('\\') => {
                        // Escaped char literal: consume escape then the
                        // rest up to the closing quote ('\u{1F600}').
                        sc.bump();
                        sc.bump();
                        let mut text = String::from("\\");
                        while let Some(c) = sc.peek(0) {
                            if c == '\'' {
                                sc.bump();
                                break;
                            }
                            text.push(c);
                            sc.bump();
                        }
                        out.tokens.push(Token { kind: TokKind::Char, text, line });
                    }
                    Some(c0) if is_ident_start(c0) => {
                        let name = sc.ident();
                        if sc.peek(0) == Some('\'') {
                            sc.bump();
                            out.tokens.push(Token { kind: TokKind::Char, text: name, line });
                        } else {
                            out.tokens.push(Token { kind: TokKind::Lifetime, text: name, line });
                        }
                    }
                    Some(c0) => {
                        sc.bump();
                        if sc.peek(0) == Some('\'') {
                            sc.bump();
                        }
                        out.tokens.push(Token {
                            kind: TokKind::Char,
                            text: c0.to_string(),
                            line,
                        });
                    }
                    None => {
                        out.tokens.push(Token {
                            kind: TokKind::Punct,
                            text: "'".to_string(),
                            line,
                        });
                    }
                }
            }
            'r' | 'b' if raw_or_byte_literal(&sc) => {
                // r"…", r#"…"#, b"…", br#"…"#, b'…', or a raw identifier
                // r#ident — disambiguated by `raw_or_byte_literal`.
                lex_raw_or_byte(&mut sc, &mut out, line);
            }
            c if is_ident_start(c) => {
                let text = sc.ident();
                out.tokens.push(Token { kind: TokKind::Ident, text, line });
            }
            c if c.is_ascii_digit() => {
                let text = number(&mut sc);
                out.tokens.push(Token { kind: TokKind::Num, text, line });
            }
            c => {
                sc.bump();
                out.tokens.push(Token { kind: TokKind::Punct, text: c.to_string(), line });
            }
        }
    }
    out
}

/// Does the scanner sit on a raw-string / byte-literal prefix rather
/// than a plain identifier starting with `r` or `b`?
fn raw_or_byte_literal(sc: &Scanner) -> bool {
    match (sc.peek(0), sc.peek(1)) {
        (Some('b'), Some('\'')) | (Some('b'), Some('"')) => true,
        (Some('b'), Some('r')) => {
            matches!(sc.peek(2), Some('"') | Some('#'))
        }
        (Some('r'), Some('"')) => true,
        (Some('r'), Some('#')) => {
            // r#"…"# raw string, or r#ident raw identifier — both leave
            // the plain-ident path; `lex_raw_or_byte` tells them apart.
            true
        }
        _ => false,
    }
}

fn lex_raw_or_byte(sc: &mut Scanner, out: &mut Lexed, line: u32) {
    let byte = sc.peek(0) == Some('b');
    if byte {
        sc.bump(); // consume 'b'
    }
    match sc.peek(0) {
        Some('\'') => {
            // b'…' byte literal: reuse the char path.
            sc.bump();
            let mut text = String::new();
            if sc.peek(0) == Some('\\') {
                text.push('\\');
                sc.bump();
                if let Some(e) = sc.bump() {
                    text.push(e);
                }
            } else if let Some(c) = sc.bump() {
                text.push(c);
            }
            if sc.peek(0) == Some('\'') {
                sc.bump();
            }
            out.tokens.push(Token { kind: TokKind::Char, text, line });
        }
        Some('"') => {
            sc.bump();
            let body = sc.string_body();
            out.tokens.push(Token { kind: TokKind::Str, text: body, line });
        }
        Some('r') => {
            // `r"…"`, `r#"…"#`, `br"…"`, `br#"…"#`, or raw ident `r#x`.
            sc.bump(); // the 'r'
            let mut hashes = 0usize;
            while sc.peek(0) == Some('#') {
                hashes += 1;
                sc.bump();
            }
            if sc.peek(0) == Some('"') {
                sc.bump();
                let body = sc.raw_string_body(hashes);
                out.tokens.push(Token { kind: TokKind::Str, text: body, line });
            } else {
                // Raw identifier `r#match` — emit the name as an Ident.
                let text = sc.ident();
                out.tokens.push(Token { kind: TokKind::Ident, text, line });
            }
        }
        _ => {
            // Guard said literal but the stream disagrees (malformed
            // source): emit what sits here as an identifier.
            let mut text = String::new();
            if byte {
                text.push('b');
            }
            text.push_str(&sc.ident());
            out.tokens.push(Token { kind: TokKind::Ident, text, line });
        }
    }
}

/// Numeric literal: digits, `_`, hex/bin/oct bodies, a fractional part
/// when `.` is followed by a digit, and `e±` exponents. Suffixes
/// (`f64`, `u32`) ride along via the alphanumeric scan.
fn number(sc: &mut Scanner) -> String {
    let mut s = String::new();
    loop {
        match sc.peek(0) {
            Some(c) if is_ident_continue(c) => {
                s.push(c);
                sc.bump();
                // `1e-9` / `2E+5`: a sign directly after the exponent
                // marker belongs to the literal.
                if (c == 'e' || c == 'E')
                    && !s.starts_with("0x")
                    && !s.starts_with("0X")
                    && matches!(sc.peek(0), Some('+') | Some('-'))
                    && sc.peek(1).is_some_and(|d| d.is_ascii_digit())
                {
                    s.push(sc.bump().unwrap());
                }
            }
            Some('.') if sc.peek(1).is_some_and(|d| d.is_ascii_digit()) && !s.contains('.') => {
                s.push('.');
                sc.bump();
            }
            _ => break,
        }
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        lex(src)
            .tokens
            .iter()
            .filter(|t| t.kind == TokKind::Ident)
            .map(|t| t.text.clone())
            .collect()
    }

    #[test]
    fn strings_hide_tokens() {
        let l = lex("let s = \"HashMap::new() // not a comment\"; s.len();");
        assert!(idents("let s = \"HashMap::new()\";").iter().all(|i| i != "HashMap"));
        assert_eq!(l.comments.len(), 0);
        assert!(l.tokens.iter().any(|t| t.kind == TokKind::Str));
    }

    #[test]
    fn escaped_quote_does_not_end_string() {
        let ids = idents(r#"let s = "a\"HashMap\""; let t = 1;"#);
        assert!(!ids.contains(&"HashMap".to_string()));
        assert!(ids.contains(&"t".to_string()));
    }

    #[test]
    fn raw_strings_hide_tokens() {
        let ids = idents(r###"let s = r#"unsafe { Instant::now() }"#; let after = 2;"###);
        assert!(!ids.contains(&"unsafe".to_string()));
        assert!(!ids.contains(&"Instant".to_string()));
        assert!(ids.contains(&"after".to_string()));
    }

    #[test]
    fn line_and_block_comments_are_side_channel() {
        let l = lex("// HashMap here\nlet x = 1; /* SystemTime\n multi */ let y = 2;");
        let ids: Vec<_> =
            l.tokens.iter().filter(|t| t.kind == TokKind::Ident).map(|t| &t.text).collect();
        assert!(!ids.iter().any(|i| *i == "HashMap" || *i == "SystemTime"));
        assert_eq!(l.comments.len(), 2);
        assert_eq!(l.comments[0].line, 1);
        assert_eq!(l.comments[1].line, 2);
        // Line counting continues through the multi-line block comment.
        assert_eq!(
            l.tokens.iter().find(|t| t.text == "y").unwrap().line,
            3
        );
    }

    #[test]
    fn nested_block_comment() {
        let ids = idents("/* outer /* inner unsafe */ still comment */ let ok = 1;");
        assert!(!ids.contains(&"unsafe".to_string()));
        assert!(ids.contains(&"ok".to_string()));
    }

    #[test]
    fn char_literals_and_lifetimes() {
        let l = lex("fn f<'a>(x: &'a str) { let c = 'x'; let nl = '\\n'; }");
        let lifetimes: Vec<_> =
            l.tokens.iter().filter(|t| t.kind == TokKind::Lifetime).map(|t| &t.text).collect();
        assert_eq!(lifetimes, vec!["a", "a"]);
        let chars: Vec<_> =
            l.tokens.iter().filter(|t| t.kind == TokKind::Char).map(|t| &t.text).collect();
        assert_eq!(chars.len(), 2);
    }

    #[test]
    fn static_lifetime_is_not_a_char() {
        let l = lex("const S: &'static str = \"x\";");
        assert!(l.tokens.iter().any(|t| t.kind == TokKind::Lifetime && t.text == "static"));
    }

    #[test]
    fn numbers_keep_raw_text() {
        let l = lex("let a = 0xBE7C; let b = 1_000.5e-3f64; let r = 0..10;");
        let nums: Vec<_> =
            l.tokens.iter().filter(|t| t.kind == TokKind::Num).map(|t| t.text.clone()).collect();
        assert_eq!(nums, vec!["0xBE7C", "1_000.5e-3f64", "0", "10"]);
    }

    #[test]
    fn byte_literals() {
        let ids = idents("let b = b\"unsafe\"; let c = b'x'; let keep = 1;");
        assert!(!ids.contains(&"unsafe".to_string()));
        assert!(ids.contains(&"keep".to_string()));
    }

    #[test]
    fn line_numbers_are_one_indexed() {
        let l = lex("a\nb\nc");
        let lines: Vec<u32> = l.tokens.iter().map(|t| t.line).collect();
        assert_eq!(lines, vec![1, 2, 3]);
    }
}
