//! Lint report aggregation and rendering.

use super::rules::Finding;

/// The result of one full lint run.
#[derive(Clone, Debug, Default)]
pub struct LintReport {
    /// New findings (after allow-directive and baseline suppression),
    /// sorted by (file, line, rule).
    pub findings: Vec<Finding>,
    /// Files scanned.
    pub files: usize,
    /// Findings absorbed by the baseline.
    pub baseline_suppressed: usize,
    /// Baseline entries that matched nothing (warned, never fatal —
    /// deleting them is cleanup, not a gate).
    pub stale_baseline: Vec<String>,
}

impl LintReport {
    /// `file:line: rule: message` lines, one per finding, plus a
    /// trailing summary. This is the CLI output and the CI artifact.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for f in &self.findings {
            out.push_str(&f.render());
            out.push('\n');
        }
        for s in &self.stale_baseline {
            out.push_str(&format!("warning: stale baseline entry ({s}) — remove it\n"));
        }
        out.push_str(&format!(
            "lint: {} finding{} in {} files ({} baselined)\n",
            self.findings.len(),
            if self.findings.len() == 1 { "" } else { "s" },
            self.files,
            self.baseline_suppressed,
        ));
        out
    }

    /// Does the run gate `--deny`?
    pub fn clean(&self) -> bool {
        self.findings.is_empty()
    }
}
