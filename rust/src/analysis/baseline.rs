//! Checked-in lint baselines: CI fails only on *new* findings.
//!
//! A baseline entry keys on `(rule, file, trimmed source line)` — not on
//! the line number — so unrelated edits that shift code do not invalidate
//! it. Every entry must carry a written justification; an entry without
//! one fails to parse, which makes an unjustified suppression a red
//! build rather than silent debt.
//!
//! File format (line-oriented, `#` comments and blank lines ignored):
//!
//! ```text
//! <rule> @ <file> @ <trimmed source line> # <justification>
//! ```

use super::rules::{Finding, Rule};

/// One baseline entry.
#[derive(Clone, Debug, PartialEq)]
pub struct BaselineEntry {
    pub rule: Rule,
    pub file: String,
    pub snippet: String,
    pub justification: String,
}

/// A parsed baseline file.
#[derive(Clone, Debug, Default)]
pub struct Baseline {
    pub entries: Vec<BaselineEntry>,
}

impl Baseline {
    /// Parse the baseline format. Errors carry the offending line number.
    pub fn parse(text: &str) -> Result<Baseline, String> {
        let mut entries = Vec::new();
        for (idx, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let lineno = idx + 1;
            // The justification is everything after the *last* " # "
            // (trimmed source lines are Rust code, whose comments are
            // `//`, so a bare ` # ` cannot appear in the snippet).
            let (head, justification) = match line.rsplit_once(" # ") {
                Some((h, j)) if !j.trim().is_empty() => (h, j.trim().to_string()),
                _ => {
                    return Err(format!(
                        "baseline line {lineno}: missing ` # <justification>` — every \
                         baseline entry must say why it is acceptable"
                    ))
                }
            };
            let mut parts = head.splitn(3, " @ ");
            let (rule, file, snippet) = match (parts.next(), parts.next(), parts.next()) {
                (Some(r), Some(f), Some(s)) => (r.trim(), f.trim(), s.trim()),
                _ => {
                    return Err(format!(
                        "baseline line {lineno}: expected `<rule> @ <file> @ <snippet> # \
                         <justification>`"
                    ))
                }
            };
            let rule = Rule::from_name(rule)
                .ok_or_else(|| format!("baseline line {lineno}: unknown rule `{rule}`"))?;
            if snippet.is_empty() {
                return Err(format!("baseline line {lineno}: empty snippet"));
            }
            entries.push(BaselineEntry {
                rule,
                file: file.to_string(),
                snippet: snippet.to_string(),
                justification,
            });
        }
        Ok(Baseline { entries })
    }

    /// Serialize back to the file format (header comment included).
    pub fn render(&self) -> String {
        let mut out = String::from(
            "# fmedge lint baseline — findings the repo has explicitly accepted.\n\
             # Format: <rule> @ <file> @ <trimmed source line> # <justification>\n\
             # An entry without a justification fails to parse; prefer fixing the\n\
             # finding or an inline `// lint: allow(rule): <why>` over adding here.\n",
        );
        for e in &self.entries {
            out.push_str(&format!(
                "{} @ {} @ {} # {}\n",
                e.rule.name(),
                e.file,
                e.snippet,
                e.justification
            ));
        }
        out
    }

    /// Build a baseline that accepts exactly `findings` (used by
    /// `fmedge lint --write-baseline`). The placeholder justification is
    /// deliberately loud: the file parses, but a reviewer sees TODOs.
    pub fn from_findings(findings: &[Finding]) -> Baseline {
        let mut entries: Vec<BaselineEntry> = findings
            .iter()
            .map(|f| BaselineEntry {
                rule: f.rule,
                file: f.file.clone(),
                snippet: f.snippet.clone(),
                justification: "TODO: justify or fix".to_string(),
            })
            .collect();
        entries.dedup_by(|a, b| a == b);
        Baseline { entries }
    }

    fn matches(&self, f: &Finding) -> Option<usize> {
        self.entries
            .iter()
            .position(|e| e.rule == f.rule && e.file == f.file && e.snippet == f.snippet)
    }

    /// Split findings into (new, suppressed-count) and report baseline
    /// entries that matched nothing (stale — candidates for deletion).
    pub fn filter(&self, findings: Vec<Finding>) -> BaselineResult {
        let mut used = vec![false; self.entries.len()];
        let mut new = Vec::new();
        let mut suppressed = 0usize;
        for f in findings {
            match self.matches(&f) {
                Some(k) => {
                    used[k] = true;
                    suppressed += 1;
                }
                None => new.push(f),
            }
        }
        let stale = self
            .entries
            .iter()
            .zip(&used)
            .filter(|&(_, &u)| !u)
            .map(|(e, _)| format!("{} @ {} @ {}", e.rule.name(), e.file, e.snippet))
            .collect();
        BaselineResult { new, suppressed, stale }
    }
}

/// Outcome of filtering findings through a baseline.
#[derive(Clone, Debug, Default)]
pub struct BaselineResult {
    /// Findings not covered by the baseline — these gate `--deny`.
    pub new: Vec<Finding>,
    /// How many findings the baseline absorbed.
    pub suppressed: usize,
    /// Baseline entries that matched nothing (printed as warnings).
    pub stale: Vec<String>,
}
