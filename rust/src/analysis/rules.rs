//! The determinism rule passes.
//!
//! Every rule is a pattern match over the token stream of one file,
//! keyed by the file's module path. The rules encode the repo's replay
//! invariants — the properties that make seeded trials bit-identical
//! across the slotted engine, the DES, the `ReplayServer`, and the
//! parallel sweep orchestrator:
//!
//! | rule            | invariant                                                        |
//! |-----------------|------------------------------------------------------------------|
//! | `hash-iter`     | no `HashMap`/`HashSet` in deterministic modules (iteration order is randomized per process) |
//! | `wall-clock`    | no `Instant::now`/`SystemTime` outside the wall-clock allowlist  |
//! | `float-cmp`     | no `partial_cmp(..).unwrap()/.expect()` comparators (NaN panics) — use `f64::total_cmp` |
//! | `rng-discipline`| RNG streams derive from `rng::stream_seed`, never bare literals  |
//! | `unsafe-forbid` | no `unsafe` anywhere (backed by `#![forbid(unsafe_code)]`)       |
//!
//! Suppression is explicit: `// lint: allow(<rule>): <reason>` on the
//! finding's line or the line above. A directive without a written
//! reason suppresses nothing, and a directive that suppresses nothing
//! is itself a finding (`stale-allow`) — suppressions stay auditable.

use super::lexer::{Comment, Lexed, TokKind, Token};

/// Modules whose event/RNG streams must replay bit-identically. A
/// randomized iteration order anywhere in these paths can leak into
/// dispatch order, RNG consumption order, or float summation order.
pub const DETERMINISTIC_MODULES: &[&str] =
    &["sim", "des", "faults", "scenarios", "controller", "routing", "exp", "pool"];

/// Modules whose RNG construction must go through
/// [`crate::rng::stream_seed`] so per-cell/per-trial streams never alias.
pub const RNG_DISCIPLINE_MODULES: &[&str] = &["sim", "exp", "scenarios", "pool"];

/// Path prefixes where wall-clock reads are legitimate: the threaded
/// serving path, the bench harness, CLI/experiment cell timing, and the
/// demo binaries.
pub const WALL_CLOCK_ALLOWED_PREFIXES: &[&str] =
    &["rust/benches/", "examples/", "rust/src/coordinator/", "rust/src/exp/"];

/// Single files on the wall-clock allowlist.
pub const WALL_CLOCK_ALLOWED_FILES: &[&str] = &["rust/src/main.rs", "rust/src/benchkit.rs"];

/// The rule identifiers.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Rule {
    HashIter,
    WallClock,
    FloatCmp,
    RngDiscipline,
    UnsafeForbid,
    /// Meta-rule: an allow directive that suppressed nothing (or lacks
    /// a written reason). Keeps the suppression surface auditable.
    StaleAllow,
}

impl Rule {
    pub fn name(self) -> &'static str {
        match self {
            Rule::HashIter => "hash-iter",
            Rule::WallClock => "wall-clock",
            Rule::FloatCmp => "float-cmp",
            Rule::RngDiscipline => "rng-discipline",
            Rule::UnsafeForbid => "unsafe-forbid",
            Rule::StaleAllow => "stale-allow",
        }
    }

    pub fn from_name(name: &str) -> Option<Rule> {
        Some(match name {
            "hash-iter" => Rule::HashIter,
            "wall-clock" => Rule::WallClock,
            "float-cmp" => Rule::FloatCmp,
            "rng-discipline" => Rule::RngDiscipline,
            "unsafe-forbid" => Rule::UnsafeForbid,
            "stale-allow" => Rule::StaleAllow,
            _ => return None,
        })
    }

    /// Every checkable rule (excludes the meta-rule).
    pub fn all() -> &'static [Rule] {
        &[
            Rule::HashIter,
            Rule::WallClock,
            Rule::FloatCmp,
            Rule::RngDiscipline,
            Rule::UnsafeForbid,
        ]
    }
}

/// One lint finding. `snippet` is the trimmed source line — the
/// line-number-independent key baselines match on.
#[derive(Clone, Debug, PartialEq)]
pub struct Finding {
    pub file: String,
    pub line: u32,
    pub rule: Rule,
    pub message: String,
    pub snippet: String,
}

impl Finding {
    /// `file:line: rule: message` — the CLI/CI output format.
    pub fn render(&self) -> String {
        format!("{}:{}: {}: {}", self.file, self.line, self.rule.name(), self.message)
    }
}

/// The module segment of a crate-source path: `rust/src/sim/engine.rs`
/// and `rust/src/benchkit.rs` → `sim` / `benchkit`. Tests, benches, and
/// examples have no module (rules keyed by module skip them).
pub fn module_of(path: &str) -> Option<&str> {
    let rest = path.strip_prefix("rust/src/")?;
    let seg = rest.split('/').next().unwrap_or(rest);
    Some(seg.strip_suffix(".rs").unwrap_or(seg))
}

fn wall_clock_allowed(path: &str) -> bool {
    WALL_CLOCK_ALLOWED_FILES.contains(&path)
        || WALL_CLOCK_ALLOWED_PREFIXES.iter().any(|p| path.starts_with(p))
}

/// A parsed `// lint: allow(rule[, rule]): reason` directive.
#[derive(Clone, Debug)]
pub struct AllowDirective {
    pub line: u32,
    pub rules: Vec<String>,
    pub reason: String,
}

/// Parse allow directives out of the comment side channel. Accepts any
/// comment flavor (`//`, `///`, `//!`, `/* */`); the directive must
/// start the comment body.
pub fn parse_directives(comments: &[Comment]) -> Vec<AllowDirective> {
    let mut out = Vec::new();
    for c in comments {
        let body = c
            .text
            .trim_start_matches(['/', '*', '!'])
            .trim_end_matches(['*', '/'])
            .trim();
        let Some(rest) = body.strip_prefix("lint:") else { continue };
        let rest = rest.trim_start();
        let Some(rest) = rest.strip_prefix("allow") else { continue };
        let rest = rest.trim_start();
        let Some(rest) = rest.strip_prefix('(') else { continue };
        let Some(close) = rest.find(')') else { continue };
        let rules: Vec<String> = rest[..close]
            .split(',')
            .map(|r| r.trim().to_string())
            .filter(|r| !r.is_empty())
            .collect();
        let after = rest[close + 1..].trim_start();
        let reason = after.strip_prefix(':').map(|r| r.trim().to_string()).unwrap_or_default();
        out.push(AllowDirective { line: c.line, rules, reason });
    }
    out
}

/// Token index ranges covered by `#[cfg(test)]` / `#[test]` items,
/// returned as inclusive line spans. Pinned literal seeds are the point
/// of a test, so `rng-discipline` skips these regions.
pub fn test_regions(tokens: &[Token]) -> Vec<(u32, u32)> {
    let mut out = Vec::new();
    let mut i = 0usize;
    while i < tokens.len() {
        if let Some(after_attr) = match_test_attr(tokens, i) {
            if let Some((start, end)) = item_braces(tokens, after_attr) {
                out.push((tokens[start].line, tokens[end].line));
                i = end + 1;
                continue;
            }
            i = after_attr;
            continue;
        }
        i += 1;
    }
    out
}

/// If `tokens[i..]` starts a `#[cfg(test)]` or `#[test]` attribute,
/// return the index just past its closing `]`.
fn match_test_attr(tokens: &[Token], i: usize) -> Option<usize> {
    let texts: Vec<&str> = tokens[i..].iter().take(7).map(|t| t.text.as_str()).collect();
    if texts.len() >= 7
        && texts[..7] == ["#", "[", "cfg", "(", "test", ")", "]"]
    {
        return Some(i + 7);
    }
    if texts.len() >= 4 && texts[..4] == ["#", "[", "test", "]"] {
        return Some(i + 4);
    }
    None
}

/// From just past an attribute, skip any further attributes and find the
/// item's brace block. Returns token indices of `{` and its matching `}`.
fn item_braces(tokens: &[Token], mut i: usize) -> Option<(usize, usize)> {
    // Skip stacked attributes (`#[cfg(test)] #[allow(dead_code)] mod …`).
    while i + 1 < tokens.len() && tokens[i].text == "#" && tokens[i + 1].text == "[" {
        let mut depth = 0usize;
        let mut j = i + 1;
        while j < tokens.len() {
            match tokens[j].text.as_str() {
                "[" => depth += 1,
                "]" => {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                }
                _ => {}
            }
            j += 1;
        }
        i = j + 1;
    }
    // The item body: first `{` before any item-terminating `;`.
    let mut j = i;
    while j < tokens.len() {
        match tokens[j].text.as_str() {
            ";" => return None, // e.g. `#[cfg(test)] use …;` — no region
            "{" => break,
            _ => {}
        }
        j += 1;
    }
    if j >= tokens.len() {
        return None;
    }
    let open = j;
    let mut depth = 0usize;
    while j < tokens.len() {
        match tokens[j].text.as_str() {
            "{" => depth += 1,
            "}" => {
                depth -= 1;
                if depth == 0 {
                    return Some((open, j));
                }
            }
            _ => {}
        }
        j += 1;
    }
    Some((open, tokens.len() - 1))
}

fn in_regions(line: u32, regions: &[(u32, u32)]) -> bool {
    regions.iter().any(|&(a, b)| line >= a && line <= b)
}

/// Is token `i` part of a `use …;` declaration? (The import is not the
/// hazard — the use sites are — so `hash-iter` skips these.)
fn in_use_stmt(tokens: &[Token], i: usize) -> bool {
    // Scan back to the previous statement boundary. `{` is deliberately
    // NOT a boundary: `use std::collections::{BinaryHeap, HashMap};`
    // puts the group brace between `use` and the name being probed. A
    // body brace cannot fool this — the first token after a real `;`/`}`
    // boundary is then `fn`/`if`/`let`/..., never `use`.
    let mut b = i;
    while b > 0 {
        let t = &tokens[b - 1].text;
        if t == ";" || t == "}" {
            break;
        }
        b -= 1;
    }
    tokens[b..i]
        .iter()
        .take(6)
        .any(|t| t.kind == TokKind::Ident && t.text == "use")
}

/// Index of the token matching the `(` at `open` (depth-balanced), or
/// `None` if unbalanced.
fn matching_paren(tokens: &[Token], open: usize) -> Option<usize> {
    let mut depth = 0usize;
    for (k, t) in tokens.iter().enumerate().skip(open) {
        match t.text.as_str() {
            "(" => depth += 1,
            ")" => {
                depth -= 1;
                if depth == 0 {
                    return Some(k);
                }
            }
            _ => {}
        }
    }
    None
}

/// Sort-family identifiers that discharge `hash-iter` when they appear
/// right after the flagged site (the iterate-then-sort idiom).
const SORT_IDENTS: &[&str] =
    &["sort", "sort_by", "sort_unstable", "sort_unstable_by", "sort_by_key", "sort_by_cached_key"];

/// How far ahead (in tokens) the `hash-iter` sorted-nearby heuristic
/// looks for a sort call.
const SORT_LOOKAHEAD: usize = 48;

/// Run every rule over one lexed file. Findings are deduplicated per
/// `(rule, line)` and come back in source order. Allow-directive
/// suppression and baselines are applied by the caller.
pub fn run_rules(path: &str, lexed: &Lexed) -> Vec<Finding> {
    let tokens = &lexed.tokens;
    let module = module_of(path);
    let deterministic = module.is_some_and(|m| DETERMINISTIC_MODULES.contains(&m));
    let rng_scoped = module.is_some_and(|m| RNG_DISCIPLINE_MODULES.contains(&m));
    let regions = test_regions(tokens);
    let mut out: Vec<Finding> = Vec::new();
    let mut push = |out: &mut Vec<Finding>, rule: Rule, line: u32, message: String| {
        if !out.iter().any(|f| f.rule == rule && f.line == line) {
            out.push(Finding {
                file: path.to_string(),
                line,
                rule,
                message,
                snippet: String::new(),
            });
        }
    };

    for (i, t) in tokens.iter().enumerate() {
        if t.kind != TokKind::Ident {
            continue;
        }
        match t.text.as_str() {
            // -- unsafe-forbid -------------------------------------------
            "unsafe" => push(
                &mut out,
                Rule::UnsafeForbid,
                t.line,
                "`unsafe` is forbidden crate-wide (`#![forbid(unsafe_code)]`): every replay \
                 invariant is audited on safe code only"
                    .to_string(),
            ),

            // -- wall-clock ----------------------------------------------
            "SystemTime" if !wall_clock_allowed(path) => push(
                &mut out,
                Rule::WallClock,
                t.line,
                "`SystemTime` outside the wall-clock allowlist — virtual-time paths must take \
                 time as input (slot/event clock), never read it"
                    .to_string(),
            ),
            "Instant"
                if !wall_clock_allowed(path)
                    && tokens.get(i + 1).is_some_and(|t| t.text == ":")
                    && tokens.get(i + 2).is_some_and(|t| t.text == ":")
                    && tokens.get(i + 3).is_some_and(|t| t.text == "now") =>
            {
                push(
                    &mut out,
                    Rule::WallClock,
                    t.line,
                    "`Instant::now()` outside the wall-clock allowlist — a wall-clock read in a \
                     deterministic path makes seeded replays diverge"
                        .to_string(),
                )
            }

            // -- hash-iter -----------------------------------------------
            "HashMap" | "HashSet" if deterministic => {
                if in_use_stmt(tokens, i) {
                    continue;
                }
                let sorted_nearby = tokens[i + 1..]
                    .iter()
                    .take(SORT_LOOKAHEAD)
                    .any(|t| t.kind == TokKind::Ident && SORT_IDENTS.contains(&t.text.as_str()));
                if sorted_nearby {
                    continue;
                }
                push(
                    &mut out,
                    Rule::HashIter,
                    t.line,
                    format!(
                        "`{}` in deterministic module `{}` — iteration order is randomized per \
                         process; use BTreeMap/BTreeSet, sort before iterating, or annotate \
                         `// lint: allow(hash-iter): <why membership-only>`",
                        t.text,
                        module.unwrap_or("?"),
                    ),
                )
            }

            // -- float-cmp -----------------------------------------------
            "partial_cmp" => {
                let Some(open) = tokens.get(i + 1).filter(|t| t.text == "(").map(|_| i + 1)
                else {
                    continue;
                };
                let Some(close) = matching_paren(tokens, open) else { continue };
                let chained_panic = tokens.get(close + 1).is_some_and(|t| t.text == ".")
                    && tokens
                        .get(close + 2)
                        .is_some_and(|t| t.text == "unwrap" || t.text == "expect");
                if chained_panic {
                    push(
                        &mut out,
                        Rule::FloatCmp,
                        t.line,
                        "`partial_cmp(..).unwrap()` comparator panics on NaN and silently \
                         depends on NaN-free data — use `f64::total_cmp`"
                            .to_string(),
                    )
                }
            }

            // -- rng-discipline ------------------------------------------
            "seed_from" if rng_scoped && !in_regions(t.line, &regions) => {
                let Some(open) = tokens.get(i + 1).filter(|t| t.text == "(").map(|_| i + 1)
                else {
                    continue;
                };
                let Some(close) = matching_paren(tokens, open) else { continue };
                let args = &tokens[open + 1..close];
                let literal_only = args.iter().any(|t| t.kind == TokKind::Num)
                    && !args.iter().any(|t| t.kind == TokKind::Ident);
                if literal_only {
                    push(
                        &mut out,
                        Rule::RngDiscipline,
                        t.line,
                        "RNG seeded from a bare literal — derive per-stream seeds with \
                         `rng::stream_seed(root, stream, index)` so streams never alias \
                         across cells/trials"
                            .to_string(),
                    )
                }
            }
            _ => {}
        }
    }
    out.sort_by(|a, b| (a.line, a.rule).cmp(&(b.line, b.rule)));
    out
}

/// Apply allow-directive suppression to `findings` and emit the
/// `stale-allow` meta-findings. Returns surviving findings, in order.
pub fn apply_allows(
    path: &str,
    findings: Vec<Finding>,
    directives: &[AllowDirective],
) -> Vec<Finding> {
    let mut used = vec![false; directives.len()];
    let mut out: Vec<Finding> = Vec::new();
    for f in findings {
        let mut suppressed = false;
        for (k, d) in directives.iter().enumerate() {
            let covers = f.line == d.line || f.line == d.line + 1;
            if covers && !d.reason.is_empty() && d.rules.iter().any(|r| r == f.rule.name()) {
                used[k] = true;
                suppressed = true;
            }
        }
        if !suppressed {
            out.push(f);
        }
    }
    for (k, d) in directives.iter().enumerate() {
        if used[k] {
            continue;
        }
        let msg = if d.reason.is_empty() {
            format!(
                "allow({}) has no written reason — `// lint: allow(rule): <why>` is required \
                 for a suppression to take effect",
                d.rules.join(", ")
            )
        } else {
            format!(
                "allow({}) suppressed nothing — remove the stale directive",
                d.rules.join(", ")
            )
        };
        out.push(Finding {
            file: path.to_string(),
            line: d.line,
            rule: Rule::StaleAllow,
            message: msg,
            snippet: String::new(),
        });
    }
    out.sort_by(|a, b| (a.line, a.rule).cmp(&(b.line, b.rule)));
    out
}
