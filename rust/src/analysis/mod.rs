//! # Determinism lint — machine-checked replay invariants.
//!
//! Every result this reproduction publishes rests on one property:
//! seeded trials are **bit-identical** across the slotted engine, the
//! DES, the `ReplayServer`, and the parallel sweep orchestrator. That is
//! what lets the EXPERIMENTS tables, the `P(delay > g_{m,ε}(y)) ≤ ε`
//! validation, and the fault-replay comparisons be paired at all. This
//! module makes the property a *static gate* instead of a reviewer's
//! memory: a dependency-free analysis pass over the crate's own sources
//! (hand-rolled lexer in [`lexer`], token-stream rule passes in
//! [`rules`], checked-in baselines in [`baseline`]).
//!
//! Run it as `fmedge lint [--deny] [--baseline PATH]` — it walks
//! `rust/src`, `rust/tests`, `rust/benches`, and `examples/`, prints
//! findings as `file:line: rule: message`, and exits nonzero under
//! `--deny` when a finding is not covered by an inline
//! `// lint: allow(<rule>): <reason>` or the baseline file. See
//! EXPERIMENTS.md §P9 for the rule table and workflow.
//!
//! Honors the crate's intentionally empty `[dependencies]`: no syn, no
//! regex — the lexer handles line/block comments, strings, raw strings,
//! and char literals so rules can never fire inside a literal, and the
//! rules are plain scans over the token stream.

pub mod baseline;
pub mod lexer;
pub mod report;
pub mod rules;

pub use baseline::{Baseline, BaselineEntry, BaselineResult};
pub use lexer::{lex, Lexed, TokKind, Token};
pub use report::LintReport;
pub use rules::{
    apply_allows, module_of, parse_directives, run_rules, Finding, Rule,
    DETERMINISTIC_MODULES, RNG_DISCIPLINE_MODULES,
};

use std::path::{Path, PathBuf};

/// Directories scanned by a full run, relative to the repo root.
pub const SCAN_DIRS: &[&str] = &["rust/src", "rust/tests", "rust/benches", "examples"];

/// Default baseline location, relative to the repo root.
pub const DEFAULT_BASELINE: &str = "rust/lint-baseline.txt";

/// Lint one in-memory source file. `path` must be repo-root-relative
/// with `/` separators (it keys the module-path rules and the output).
/// Inline allow directives are applied; baselines are the caller's job.
pub fn lint_source(path: &str, src: &str) -> Vec<Finding> {
    let lexed = lexer::lex(src);
    let findings = rules::run_rules(path, &lexed);
    let directives = rules::parse_directives(&lexed.comments);
    let mut findings = rules::apply_allows(path, findings, &directives);
    let lines: Vec<&str> = src.lines().collect();
    for f in &mut findings {
        f.snippet = lines
            .get(f.line as usize - 1)
            .map(|l| l.trim().to_string())
            .unwrap_or_default();
    }
    findings
}

/// Recursively collect `.rs` files under `dir`, sorted by path so runs
/// are deterministic regardless of directory-entry order.
fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    let mut entries: Vec<PathBuf> =
        std::fs::read_dir(dir)?.filter_map(|e| e.ok().map(|e| e.path())).collect();
    entries.sort();
    for p in entries {
        if p.is_dir() {
            collect_rs_files(&p, out)?;
        } else if p.extension().is_some_and(|e| e == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

/// Find the repo root from the current directory: the first of `.` and
/// `..` containing `rust/src` (so the CLI works from the repo root and
/// from `rust/`, where cargo runs it).
pub fn detect_root() -> Result<PathBuf, String> {
    for cand in [".", ".."] {
        let c = PathBuf::from(cand);
        if c.join("rust/src").is_dir() {
            return Ok(c);
        }
    }
    Err("cannot find `rust/src` from the current directory (pass --root PATH)".to_string())
}

/// Run the full lint over the tree at `root`. `baseline` is applied when
/// given. Missing scan directories are skipped (`examples/` may be
/// absent in a stripped checkout); unreadable files are errors.
pub fn run_lint(root: &Path, baseline: Option<&Baseline>) -> Result<LintReport, String> {
    let mut files = Vec::new();
    for dir in SCAN_DIRS {
        let d = root.join(dir);
        if d.is_dir() {
            collect_rs_files(&d, &mut files)
                .map_err(|e| format!("walking {}: {e}", d.display()))?;
        }
    }
    let mut all = Vec::new();
    for path in &files {
        let src = std::fs::read_to_string(path)
            .map_err(|e| format!("reading {}: {e}", path.display()))?;
        let rel = rel_path(root, path);
        all.extend(lint_source(&rel, &src));
    }
    all.sort_by(|a, b| (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule)));
    let (new, suppressed, stale) = match baseline {
        Some(b) => {
            let r = b.filter(all);
            (r.new, r.suppressed, r.stale)
        }
        None => (all, 0, Vec::new()),
    };
    Ok(LintReport {
        findings: new,
        files: files.len(),
        baseline_suppressed: suppressed,
        stale_baseline: stale,
    })
}

/// Root-relative path with `/` separators (stable across platforms —
/// it is the baseline key and the output format).
fn rel_path(root: &Path, path: &Path) -> String {
    let rel = path.strip_prefix(root).unwrap_or(path);
    rel.components()
        .map(|c| c.as_os_str().to_string_lossy())
        .collect::<Vec<_>>()
        .join("/")
}
