//! `fmedge` binary: the leader entrypoint. See `cli::HELP`.

use std::time::Instant;

use fmedge::analysis::{self, Baseline};
use fmedge::cli::{Args, HELP};
use fmedge::config::ExperimentConfig;
use fmedge::coordinator::{
    parse_fault_spec, BatchPolicy, Coordinator, FailoverConfig, FailoverPolicy, ReplayConfig,
    ReplayServer, Request, ServeConfig, VirtualRequest,
};
use fmedge::benchkit::{bench, fmt_duration, print_data_table, save_json};
use fmedge::des::{
    pool, report, run_des_trial, run_des_trial_faulted, run_des_trial_faulted_in,
    run_des_trial_observed, validate_bounds, DesArena, DesOptions, EventCalendar, EventKind,
    HeapCalendar, RadixCalendar,
};
use fmedge::exp::{run_sweep, strategy_by_name, Experiment, SweepConfig};
use fmedge::faults::{FaultEvent, FaultKind, FaultParams, FaultSchedule};
use fmedge::metrics::Summary;
use fmedge::obs::{analyze, chrome_trace_json, render, spans_jsonl, Observer};
use fmedge::placement::{solve_static_placement, PlacementParams, QosScores, ScoreParams};
use fmedge::rng::{Rng, Xoshiro256};
use fmedge::runtime::{EffCapAccel, Runtime};
use fmedge::sim::{
    record_trace, run_trial, run_trial_faulted, run_trial_observed, SimEnv, SimOptions, Strategy,
};
use fmedge::workload::{Trace, WorkloadGenerator};

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n\n{HELP}");
            std::process::exit(2);
        }
    };
    if args.flag("help") || args.command.is_none() {
        print!("{HELP}");
        return;
    }
    let result = match args.command.as_deref().unwrap() {
        "config" => cmd_config(&args),
        "place" => cmd_place(&args),
        "gtable" => cmd_gtable(&args),
        "simulate" => cmd_simulate(&args),
        "des" => cmd_des(&args),
        "pool" => cmd_pool(&args),
        "faults" => cmd_faults(&args),
        "trace" => cmd_trace(&args),
        "sweep" => cmd_sweep(&args),
        "serve" => cmd_serve(&args),
        "lint" => cmd_lint(&args),
        other => {
            eprintln!("unknown command `{other}`\n\n{HELP}");
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

type AnyError = Box<dyn std::error::Error>;

fn load_config(args: &Args) -> Result<ExperimentConfig, AnyError> {
    Ok(match args.get("config") {
        Some(path) => ExperimentConfig::from_path(path)?,
        None => ExperimentConfig::paper_default(),
    })
}

fn cmd_config(args: &Args) -> Result<(), AnyError> {
    let cfg = load_config(args)?;
    print!("{}", cfg.describe());
    Ok(())
}

fn cmd_place(args: &Args) -> Result<(), AnyError> {
    let mut cfg = load_config(args)?;
    cfg.controller.kappa = args.get_usize("kappa", cfg.controller.kappa)?;
    let seed = args.get_u64("seed", cfg.sim.seed)?;
    let env = SimEnv::build(&cfg, seed);
    let gen = WorkloadGenerator::new(
        &cfg,
        &env.app,
        &env.topo,
        &mut Xoshiro256::seed_from(env.users_seed),
    );
    let scores = QosScores::compute(
        &env.app,
        &env.topo,
        &env.dm,
        gen.users(),
        &ScoreParams::from_config(&cfg.controller),
    );
    let mut params = PlacementParams::from_config(&cfg, cfg.sim.slots);
    params.exact = args.flag("exact");
    params.force_fallback = args.flag("fallback");
    let t0 = Instant::now();
    let placement = solve_static_placement(&env.app, &env.topo, &scores, &params);
    println!(
        "placement solved in {:?} (objective {:.1}, support {}, fallback {})",
        t0.elapsed(),
        placement.objective,
        placement.support,
        placement.used_fallback
    );
    println!("instances[node][core]:");
    for (v, row) in placement.instances.iter().enumerate() {
        if row.iter().any(|&x| x > 0) {
            println!("  node {v:>2}: {row:?}");
        }
    }
    Ok(())
}

fn cmd_gtable(args: &Args) -> Result<(), AnyError> {
    let cfg = load_config(args)?;
    let seed = args.get_u64("seed", cfg.sim.seed)?;
    let env = SimEnv::build(&cfg, seed);
    let gtable = if args.flag("accel") {
        let rt = Runtime::cpu(Runtime::default_dir())?;
        println!("PJRT platform: {}", rt.platform());
        let workloads: Vec<f64> = env
            .app
            .catalog
            .light_ids()
            .iter()
            .map(|&m| env.app.catalog.spec(m).workload_mb)
            .collect();
        EffCapAccel::load(&rt)?.build_gtable(&env.light_rate_samples, &workloads)?
    } else {
        env.gtable.clone()
    };
    println!(
        "g_{{m,eps}}(y) delay bounds (ms), eps={}",
        gtable.params_epsilon
    );
    print!("      ");
    for y in 1..=gtable.max_parallelism() {
        print!("y={y:<7}");
    }
    println!();
    for m in 0..gtable.num_ms() {
        print!("m={m:<3} ");
        for y in 1..=gtable.max_parallelism() {
            print!("{:<8.3}", gtable.delay(m, y));
        }
        println!();
    }
    Ok(())
}

fn make_strategy(name: &str) -> Result<Box<dyn Strategy>, AnyError> {
    strategy_by_name(name).map_err(Into::into)
}

fn cmd_simulate(args: &Args) -> Result<(), AnyError> {
    let mut cfg = load_config(args)?;
    cfg.sim.slots = args.get_usize("slots", cfg.sim.slots)?;
    cfg.sim.trials = args.get_usize("trials", cfg.sim.trials)?;
    cfg.sim.load_multiplier = args.get_f64("load", cfg.sim.load_multiplier)?;
    cfg.sim.seed = args.get_u64("seed", cfg.sim.seed)?;
    let strat_name = args.get("strategy").unwrap_or("proposal").to_string();
    let mut otr = Vec::new();
    let mut cost = Vec::new();
    let t0 = Instant::now();
    for trial in 0..cfg.sim.trials {
        let seed = cfg.sim.seed + trial as u64;
        let env = SimEnv::build(&cfg, seed);
        let mut strategy = make_strategy(&strat_name)?;
        let m = run_trial(&env, strategy.as_mut(), seed, &SimOptions::from_config(&cfg));
        println!(
            "trial {trial:>3}: tasks={:<6} completion={:.3} on_time={:.3} cost={:.0}",
            m.total_tasks,
            m.completion_rate(),
            m.on_time_rate(),
            m.total_cost
        );
        otr.push(m.on_time_rate());
        cost.push(m.total_cost);
    }
    println!(
        "\n{} over {} trials in {:?}:\n  on-time  {}\n  cost     {}",
        strat_name,
        cfg.sim.trials,
        t0.elapsed(),
        Summary::of(&otr).row(),
        Summary::of(&cost).row()
    );
    Ok(())
}

/// `fmedge des`: the discrete-event queueing engine over recorded traces,
/// with optional measured-vs-analytic bound validation.
fn cmd_des(args: &Args) -> Result<(), AnyError> {
    let mut cfg = load_config(args)?;
    cfg.sim.slots = args.get_usize("slots", cfg.sim.slots)?;
    cfg.sim.trials = args.get_usize("trials", cfg.sim.trials)?;
    cfg.sim.load_multiplier = args.get_f64("load", cfg.sim.load_multiplier)?;
    cfg.sim.seed = args.get_u64("seed", cfg.sim.seed)?;
    cfg.workload.num_users = args.get_usize("users", cfg.workload.num_users)?;
    let strat_name = args.get("strategy").unwrap_or("proposal").to_string();
    if args.flag("bench") {
        return cmd_des_bench(&cfg, &strat_name);
    }
    let batch = args.get_usize("batch", 0)?;
    let batch_wait = args.get_f64("batch-wait", 1.0)?;
    let mut otr = Vec::new();
    let mut lat_p95 = Vec::new();
    let mut per_trial_vals = Vec::new();
    // --trace replays one saved realization across every trial
    // (cross-process pairing); parse it once up front. A trace is only
    // meaningful against the environment it was recorded in, so replay
    // pins the env to the base seed and varies only the engine rng —
    // fresh per-trial envs would silently unpair arrivals from their
    // topology, DAGs, and g-table.
    let loaded_trace = match args.get("trace") {
        Some(path) => Some(Trace::load(path)?),
        None => None,
    };
    let paired_env = loaded_trace
        .as_ref()
        .map(|_| SimEnv::build(&cfg, cfg.sim.seed));
    let t0 = Instant::now();
    for trial in 0..cfg.sim.trials {
        let seed = cfg.sim.seed + trial as u64;
        let built_env;
        let env: &SimEnv = match &paired_env {
            Some(e) => e,
            None => {
                built_env = SimEnv::build(&cfg, seed);
                &built_env
            }
        };
        let opts = SimOptions::from_config(&cfg);
        let recorded;
        let trace: &Trace = match &loaded_trace {
            Some(t) => t,
            None => {
                recorded = record_trace(env, seed, &opts);
                &recorded
            }
        };
        if trial == 0 {
            if let Some(path) = args.get("save-trace") {
                trace.save(path)?;
                println!("trace saved to {path} ({} arrivals)", trace.len());
            }
        }
        let mut dopts = DesOptions::from_sim(&opts);
        dopts.streaming = args.flag("streaming");
        if batch > 1 {
            dopts.batching = Some(BatchPolicy::with_wait_ms(batch, batch_wait));
        }
        let mut strategy = make_strategy(&strat_name)?;
        let m = run_des_trial(env, strategy.as_mut(), seed, &dopts, trace);
        // The sojourn histograms are filled in both metric modes;
        // `samples` is empty under --streaming.
        let measured: u64 = m.service_obs.iter().map(|o| o.sojourn.count()).sum();
        println!(
            "trial {trial:>3}: tasks={:<6} completion={:.3} on_time={:.3} cost={:.0} sojourns={measured} queue {}",
            m.total_tasks,
            m.completion_rate(),
            m.on_time_rate(),
            m.total_cost,
            m.queue_depth.row(),
        );
        otr.push(m.on_time_rate());
        lat_p95.push(m.latency_percentile(0.95));
        if args.flag("validate") {
            per_trial_vals.push(validate_bounds(&env.gtable, &m));
        }
    }
    println!(
        "\ndes/{} over {} trials in {:?}:\n  on-time  {}\n  lat p95  {}",
        strat_name,
        cfg.sim.trials,
        t0.elapsed(),
        Summary::of(&otr).row(),
        Summary::of(&lat_p95).row()
    );
    if args.flag("validate") {
        let pooled = pool(&per_trial_vals);
        println!(
            "\nmeasured vs g_{{m,eps}}(y), eps={} (pooled over trials):\n{}",
            cfg.controller.epsilon,
            report(&pooled)
        );
    }
    Ok(())
}

/// `fmedge des --bench`: the DES performance harness (EXPERIMENTS §P8,
/// `benches/bench_des.rs` is the cargo-bench twin). Two microbench rows
/// price the calendar alone — push + pop of a uniform-random event set
/// on the production radix calendar and on the binary-heap reference —
/// and one macro row prices the whole engine: a faulted streaming trial
/// with the arena reused across iterations (the sweep's steady-state
/// shape). Throughput is events/sec, where one event is one schedule +
/// one pop; the acceptance target is >= 1e7 on the radix calendar row.
/// `FMEDGE_BENCH_ITERS` / `FMEDGE_BENCH_EVENTS` scale the run;
/// `FMEDGE_BENCH_JSON=BENCH_des.json` saves the perf-trajectory rows.
fn cmd_des_bench(cfg: &ExperimentConfig, strat_name: &str) -> Result<(), AnyError> {
    let iters: usize = std::env::var("FMEDGE_BENCH_ITERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(5);
    let n: usize = std::env::var("FMEDGE_BENCH_EVENTS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1_000_000);
    let headers = ["bench", "events", "mean", "events/sec"];
    let mut rows = Vec::new();

    // The time stream is generated once up front: the bench prices the
    // calendar, not the RNG.
    let mut rng = Xoshiro256::seed_from(0xBE7C);
    let times: Vec<f64> = (0..n).map(|_| rng.next_f64() * 10_000.0).collect();

    fn churn<C: EventCalendar + Default>(times: &[f64]) -> u64 {
        let mut cal = C::default();
        for &t in times {
            cal.schedule(t, EventKind::Decide);
        }
        let mut last = f64::NEG_INFINITY;
        while let Some(ev) = cal.pop() {
            debug_assert!(ev.time_ms >= last, "calendar must pop in order");
            last = ev.time_ms;
        }
        cal.processed()
    }

    for (name, runner) in [
        ("calendar/radix push+pop", churn::<RadixCalendar> as fn(&[f64]) -> u64),
        ("calendar/heap push+pop", churn::<HeapCalendar> as fn(&[f64]) -> u64),
    ] {
        let r = bench(name, 1, iters, || {
            std::hint::black_box(runner(std::hint::black_box(&times)));
        });
        let evs = n as f64 / (r.mean_ns() / 1e9);
        rows.push(vec![
            name.to_string(),
            n.to_string(),
            fmt_duration(r.mean),
            format!("{evs:.3e}"),
        ]);
    }

    // Engine macro-bench: faulted + streaming, arena reused across
    // iterations so allocation cost amortizes exactly as it does in the
    // sweep orchestrator.
    let seed = cfg.sim.seed;
    let env = SimEnv::build(cfg, seed);
    let opts = SimOptions::from_config(cfg);
    let trace = record_trace(&env, seed, &opts);
    let es = cfg.network.num_eds;
    let schedule = FaultSchedule::from_events(vec![
        FaultEvent { time_ms: 30.0 * opts.slot_ms, kind: FaultKind::NodeDown { node: es } },
        FaultEvent { time_ms: 32.0 * opts.slot_ms, kind: FaultKind::NodeDown { node: es + 1 } },
        FaultEvent { time_ms: 70.0 * opts.slot_ms, kind: FaultKind::NodeUp { node: es } },
        FaultEvent { time_ms: 72.0 * opts.slot_ms, kind: FaultKind::NodeUp { node: es + 1 } },
    ]);
    let mut dopts = DesOptions::from_sim(&opts);
    dopts.streaming = true;
    let mut arena: DesArena = DesArena::new();
    let mut events = 0u64;
    let name = format!("engine/{strat_name} faulted+streaming");
    let r = bench(&name, 1, iters, || {
        let mut strategy = make_strategy(strat_name).expect("bench strategy");
        let m = run_des_trial_faulted_in(
            &mut arena,
            &env,
            strategy.as_mut(),
            seed,
            &dopts,
            &trace,
            &schedule,
        );
        events = m.des_events;
    });
    let evs = events as f64 / (r.mean_ns() / 1e9);
    rows.push(vec![name, events.to_string(), fmt_duration(r.mean), format!("{evs:.3e}")]);

    let title = "DES perf — calendar push/pop and engine throughput";
    print_data_table(title, &headers, &rows);
    if let Ok(path) = std::env::var("FMEDGE_BENCH_JSON") {
        save_json(&path, title, &headers, &rows)?;
        println!("\nbench rows saved to {path}");
    }
    Ok(())
}

/// `fmedge pool`: the elastic-autoscaling demo (EXPERIMENTS §P10). Runs
/// one scenario (default diurnal) through both engines twice — once with
/// the replica-pool tier on (Autoscale strategy, per-instance y pinned
/// to 1, capacity from the shared-rate pools) and once on the pre-pool
/// fixed-parallelism path — on the identical compiled trace + fault
/// schedule, and prints the on-time / deployment-cost trade per row.
fn cmd_pool(args: &Args) -> Result<(), AnyError> {
    let mut cfg = load_config(args)?;
    cfg.sim.slots = args.get_usize("slots", 200)?;
    cfg.sim.load_multiplier = args.get_f64("load", cfg.sim.load_multiplier)?;
    cfg.sim.seed = args.get_u64("seed", cfg.sim.seed)?;
    let scen_name = args.get("scenario").unwrap_or("diurnal").to_string();
    let spec = fmedge::scenarios::ScenarioSpec::by_name(&scen_name)
        .ok_or_else(|| format!("unknown scenario `{scen_name}`"))?;
    let seed = cfg.sim.seed;
    let env = SimEnv::build(&cfg, seed);
    let base_opts = SimOptions::from_config(&cfg);
    let cs = spec.compile(&env, &base_opts, seed ^ 0xA10_0);
    println!(
        "pool: scenario={scen_name} slots={} load={} seed={seed} ({} arrivals, {} fault events)",
        cfg.sim.slots,
        cfg.sim.load_multiplier,
        cs.trace.len(),
        cs.faults.len()
    );
    println!(
        "pool: {:<8} {:<10} {:>8} {:>8} {:>11} {:>12} {:>13} {:>9}",
        "engine", "mode", "tasks", "on-time", "cold-starts", "scale-events", "replica-slots", "pool-p95"
    );
    let t0 = Instant::now();
    let mut arena: DesArena = DesArena::new();
    for engine in ["slotted", "des"] {
        for (mode, pooled) in [("autoscale", true), ("fixed-y", false)] {
            let mut opts = base_opts.clone();
            let mut strategy: Box<dyn Strategy> = if pooled {
                opts.pool = Some(fmedge::pool::PoolConfig::from_config(&cfg));
                Box::new(fmedge::pool::Autoscale::new())
            } else {
                make_strategy("proposal")?
            };
            let m = if engine == "des" {
                run_des_trial_faulted_in(
                    &mut arena,
                    &env,
                    strategy.as_mut(),
                    seed,
                    &DesOptions::from_sim(&opts),
                    &cs.trace,
                    &cs.faults,
                )
            } else {
                run_trial_faulted(&env, strategy.as_mut(), seed, &opts, &cs.trace, &cs.faults)
            };
            let p95 = match m.pool_size.quantile(0.95) {
                Some(q) => format!("{q:.1}"),
                None => "-".to_string(),
            };
            println!(
                "pool: {:<8} {:<10} {:>8} {:>8.3} {:>11} {:>12} {:>13.1} {:>9}",
                engine,
                mode,
                m.total_tasks,
                m.on_time_rate(),
                m.cold_starts,
                m.pool_scale_events,
                m.pool_replica_slot_seconds,
                p95
            );
        }
    }
    println!("pool: finished in {:?}", t0.elapsed());
    Ok(())
}

/// `fmedge faults`: the robustness sweep (EXPERIMENTS §P4). For every
/// (load, failure-rate) grid point, every strategy replays the *same*
/// recorded trace under the *same* seeded fault schedule; rate 0 uses an
/// empty schedule and therefore reproduces the no-fault on-time rate
/// exactly. Reported per strategy: mean on-time rate and the retained
/// fraction of its own rate-0 baseline.
fn cmd_faults(args: &Args) -> Result<(), AnyError> {
    let mut cfg = load_config(args)?;
    cfg.sim.slots = args.get_usize("slots", 200)?;
    cfg.sim.trials = args.get_usize("trials", 3)?;
    cfg.sim.seed = args.get_u64("seed", cfg.sim.seed)?;
    let mut rates = args.get_f64_list("rates", &[0.0, 0.002, 0.01])?;
    // Ascending order puts rate 0 (when present) first, so its baseline
    // exists before any nonzero row needs a "retained" value.
    rates.sort_by(f64::total_cmp);
    let loads = args.get_f64_list("loads", &[1.0, 2.0])?;
    let strategies = args.get_str_list("strategies", &["proposal", "lbrr"]);
    let engine = args.get("engine").unwrap_or("slotted").to_string();
    if engine != "slotted" && engine != "des" {
        return Err(format!("unknown engine `{engine}` (slotted|des)").into());
    }
    println!(
        "fault sweep ({engine} engine): rates {rates:?} x loads {loads:?}, {} trials x {} slots",
        cfg.sim.trials, cfg.sim.slots
    );

    let t0 = Instant::now();
    for &load in &loads {
        cfg.sim.load_multiplier = load;
        // Environment and trace depend only on (load, seed): build once
        // per trial and reuse across every strategy and failure rate —
        // this is also what makes the comparison paired.
        let mut fixtures = Vec::with_capacity(cfg.sim.trials);
        for trial in 0..cfg.sim.trials {
            let seed = cfg.sim.seed + trial as u64;
            let env = SimEnv::build(&cfg, seed);
            let opts = SimOptions::from_config(&cfg);
            let trace = record_trace(&env, seed, &opts);
            fixtures.push((seed, env, opts, trace));
        }
        println!("\n== load x{load} ==");
        println!(
            "{:<10} {:>10}  {:>9}  {:>9}  {:>11}  {:>9}  {:>11}",
            "strategy", "fail rate", "on-time", "retained", "fault drops", "reroutes", "tasks"
        );
        for name in &strategies {
            let mut baseline: Option<f64> = None;
            for &rate in &rates {
                let mut otr = Vec::new();
                let mut drops = 0usize;
                let mut reroutes = 0usize;
                let mut tasks = 0usize;
                for (seed, env, opts, trace) in &fixtures {
                    let schedule = if rate > 0.0 {
                        FaultSchedule::generate(
                            &env.topo,
                            opts.slots,
                            opts.slot_ms,
                            env.app.catalog.num_core(),
                            &FaultParams::from_rate(rate),
                            // Same schedule for every strategy at this
                            // (trial, rate): paired comparison.
                            seed ^ (rate.to_bits().rotate_left(17)),
                        )
                    } else {
                        FaultSchedule::none()
                    };
                    let mut strategy = make_strategy(name)?;
                    let m = if engine == "des" {
                        run_des_trial_faulted(
                            env,
                            strategy.as_mut(),
                            *seed,
                            &DesOptions::from_sim(opts),
                            trace,
                            &schedule,
                        )
                    } else {
                        run_trial_faulted(env, strategy.as_mut(), *seed, opts, trace, &schedule)
                    };
                    otr.push(m.on_time_rate());
                    drops += m.fault_drops;
                    reroutes += m.reroute_recovered;
                    tasks += m.total_tasks;
                }
                let mean = otr.iter().sum::<f64>() / otr.len().max(1) as f64;
                // "retained" is defined against the rate-0 baseline
                // (EXPERIMENTS §P4); without a 0 in the sorted rate list
                // the metric is undefined — print a dash rather than a
                // robustness number measured against the wrong floor.
                if rate == 0.0 {
                    baseline = Some(mean);
                }
                let retained = match baseline {
                    Some(base) if base > 0.0 => format!("{:.3}", mean / base),
                    Some(_) => "1.000".to_string(),
                    None => "-".to_string(),
                };
                println!(
                    "{:<10} {:>10.4}  {:>9.3}  {:>9}  {:>11}  {:>9}  {:>11}",
                    name, rate, mean, retained, drops, reroutes, tasks
                );
            }
        }
    }
    println!("\nsweep finished in {:?}", t0.elapsed());
    Ok(())
}

/// `fmedge trace`: one fully-observed trial (EXPERIMENTS §P7). Runs the
/// chosen engine with span tracing + per-slot telemetry armed, exports
/// Chrome trace-event JSON (`--out`, opens in Perfetto), flat JSONL
/// spans (`--jsonl`) and the telemetry series as CSV (`--telemetry`),
/// and with `--blame` prints the deadline-miss blame decomposition:
/// every miss split into uplink / queue / transfer / exec / disruption
/// components and compared against the `g_{m,eps}(y)` budget. `--rate R`
/// arms the same seeded fault schedule `fmedge faults` would use, so a
/// faulty run can be dissected span by span.
fn cmd_trace(args: &Args) -> Result<(), AnyError> {
    let mut cfg = load_config(args)?;
    cfg.sim.slots = args.get_usize("slots", 120)?;
    cfg.sim.load_multiplier = args.get_f64("load", cfg.sim.load_multiplier)?;
    cfg.sim.seed = args.get_u64("seed", cfg.sim.seed)?;
    let strat_name = args.get("strategy").unwrap_or("proposal").to_string();
    let engine = args.get("engine").unwrap_or("slotted").to_string();
    if engine != "slotted" && engine != "des" {
        return Err(format!("unknown engine `{engine}` (slotted|des)").into());
    }
    let rate = args.get_f64("rate", 0.0)?;
    let seed = cfg.sim.seed;
    let env = SimEnv::build(&cfg, seed);
    let opts = SimOptions::from_config(&cfg);
    let trace = record_trace(&env, seed, &opts);
    // Same schedule derivation as `fmedge faults`: a traced run at
    // (seed, rate) dissects exactly the grid cell the sweep measured.
    let schedule = if rate > 0.0 {
        FaultSchedule::generate(
            &env.topo,
            opts.slots,
            opts.slot_ms,
            env.app.catalog.num_core(),
            &FaultParams::from_rate(rate),
            seed ^ rate.to_bits().rotate_left(17),
        )
    } else {
        FaultSchedule::none()
    };
    let mut strategy = make_strategy(&strat_name)?;
    let mut obs = Observer::new();
    let t0 = Instant::now();
    let m = if engine == "des" {
        run_des_trial_observed(
            &env,
            strategy.as_mut(),
            seed,
            &DesOptions::from_sim(&opts),
            &trace,
            &schedule,
            &mut obs,
        )
    } else {
        run_trial_observed(&env, strategy.as_mut(), seed, &opts, &trace, &schedule, &mut obs)
    };
    let rec = obs.trace.as_ref().expect("Observer::new arms tracing");
    println!(
        "{engine}/{strat_name}: tasks={} completed={} on_time={:.3} spans={} in {:?}",
        m.total_tasks,
        m.completed,
        m.on_time_rate(),
        rec.all_spans().len(),
        t0.elapsed()
    );
    if let Some(path) = args.get("out") {
        std::fs::write(path, chrome_trace_json(rec))?;
        println!("chrome trace written to {path} (open in Perfetto / chrome://tracing)");
    }
    if let Some(path) = args.get("jsonl") {
        std::fs::write(path, spans_jsonl(rec))?;
        println!("spans written to {path}");
    }
    if let Some(path) = args.get("telemetry") {
        let reg = obs.metrics.as_ref().expect("Observer::new arms metrics");
        let table = reg.to_table("telemetry");
        table.save_csv(path)?;
        println!(
            "telemetry series written to {path} ({} samples)",
            reg.num_samples()
        );
    }
    if args.flag("blame") {
        let blame = analyze(rec, Some(&env.gtable))?;
        print!("{}", render(&blame));
    }
    Ok(())
}

/// `fmedge sweep`: the parallel experiment orchestrator. Runs one of the
/// EXPERIMENTS.md grids (p1b/p2/p4/p5/p10) end-to-end over scoped worker
/// threads and writes CSV/JSON artifacts. Every per-cell/per-trial RNG
/// stream is derived statelessly from `--seed` and the grid coordinates,
/// so the output is bit-identical for any `--threads` (wall-clock
/// columns like p1b's `solve_ms` excepted — those vary run to run even
/// serially).
fn cmd_sweep(args: &Args) -> Result<(), AnyError> {
    let cfg = load_config(args)?;
    let experiment = Experiment::parse(args.get("experiment").unwrap_or("p4"))?;
    // Each experiment consumes a subset of the grid axes; an explicitly
    // passed axis outside that subset would otherwise be silently
    // dropped and the user could misattribute the published numbers.
    for axis in experiment.ignored_axes() {
        if args.get(axis).is_some() {
            eprintln!("warning: --{axis} is not an axis of experiment {experiment:?}; ignoring it");
        }
    }
    let mut sc = SweepConfig::for_experiment(experiment);
    sc.trials = args.get_usize("trials", sc.trials)?;
    sc.slots = args.get_usize("slots", sc.slots)?;
    sc.seed = args.get_u64("seed", sc.seed)?;
    let default_threads = std::thread::available_parallelism()
        .map(|n| n.get().min(8))
        .unwrap_or(1);
    sc.threads = args.get_usize("threads", default_threads)?;
    sc.loads = args.get_f64_list("loads", &sc.loads)?;
    sc.rates = args.get_f64_list("rates", &sc.rates)?;
    sc.epsilons = args.get_f64_list("epsilons", &sc.epsilons)?;
    let strat_default: Vec<&str> = sc.strategies.iter().map(String::as_str).collect();
    sc.strategies = args.get_str_list("strategies", &strat_default);
    let engine_default: Vec<&str> = sc.engines.iter().map(String::as_str).collect();
    sc.engines = args.get_str_list("engines", &engine_default);
    // `fmedge faults` takes --engine (singular); accept it here too so
    // the familiar spelling doesn't silently run both engines.
    if args.get("engines").is_none() {
        if let Some(e) = args.get("engine") {
            sc.engines = vec![e.to_string()];
        }
    }
    sc.scenarios = args.get_str_list("scenarios", &[]);

    println!(
        "sweep {experiment:?}: {} trials/cell x {} slots, seed {}, {} threads",
        sc.trials, sc.slots, sc.seed, sc.threads
    );
    let t0 = Instant::now();
    let table = run_sweep(&cfg, &sc)?;
    // The NaN/empty gate: a malformed grid point must fail the run (and
    // CI) rather than publish a hollow table.
    table.validate()?;
    print!("{}", table.render());
    println!("{} rows in {:?}", table.rows.len(), t0.elapsed());
    if let Some(path) = args.get("out") {
        table.save_csv(path)?;
        println!("csv written to {path}");
    }
    if let Some(path) = args.get("json") {
        table.save_json(path)?;
        println!("json written to {path}");
    }
    Ok(())
}

/// `fmedge lint`: the in-tree determinism lint (EXPERIMENTS §P9). Walks
/// `rust/src`, `rust/tests`, `rust/benches`, and `examples/`, runs the
/// replay-invariant rules (hash-iter, wall-clock, float-cmp,
/// rng-discipline, unsafe-forbid), prints findings as
/// `file:line: rule: message`, and under `--deny` exits nonzero when any
/// finding is not covered by an inline `// lint: allow(rule): reason`
/// or the checked-in baseline. `--write-baseline FILE` accepts the
/// current findings (with TODO justifications a reviewer must replace).
fn cmd_lint(args: &Args) -> Result<(), AnyError> {
    let root = match args.get("root") {
        Some(r) => std::path::PathBuf::from(r),
        None => analysis::detect_root()?,
    };
    let baseline_path = match args.get("baseline") {
        Some(p) => Some(std::path::PathBuf::from(p)),
        None => {
            let default = root.join(analysis::DEFAULT_BASELINE);
            default.is_file().then_some(default)
        }
    };
    let baseline = match &baseline_path {
        Some(p) => {
            let text = std::fs::read_to_string(p)
                .map_err(|e| format!("reading baseline {}: {e}", p.display()))?;
            Some(Baseline::parse(&text)?)
        }
        None => None,
    };
    if let Some(path) = args.get("write-baseline") {
        // Accept the current findings (pre-baseline) as the new floor.
        let report = analysis::run_lint(&root, None)?;
        let b = Baseline::from_findings(&report.findings);
        std::fs::write(path, b.render())?;
        println!(
            "baseline with {} entries written to {path} — replace every `TODO: justify or \
             fix` before committing",
            b.entries.len()
        );
        return Ok(());
    }
    let report = analysis::run_lint(&root, baseline.as_ref())?;
    print!("{}", report.render());
    if args.flag("deny") && !report.clean() {
        return Err(format!(
            "{} new lint finding(s) — fix them, annotate `// lint: allow(<rule>): <reason>`, \
             or baseline them with a justification",
            report.findings.len()
        )
        .into());
    }
    Ok(())
}

/// `fmedge serve`: the serving coordinator on a synthetic open-loop
/// workload. `--faults SPEC` arms the failover layer (checkpoint/restart
/// worker outages + retry re-routing); `--virtual` replays the same
/// workload and policy on the deterministic virtual-time server instead
/// of the threaded pool, so the failover counters are bit-stable run to
/// run (the CI smoke and the robustness tests key on this). Without
/// `--faults` the output is unchanged from the fault-oblivious server.
fn cmd_serve(args: &Args) -> Result<(), AnyError> {
    let requests = args.get_usize("requests", 2000)?;
    let rate = args.get_f64("rate", 2000.0)?;
    let workers = args.get_usize("workers", 2)?;
    let deadline_ms = args.get_f64("deadline-ms", 50.0)?;
    let seed = args.get_u64("seed", 7)?;
    let failover = match args.get("faults") {
        Some(spec) => {
            let net = load_config(args)?.network;
            let schedule = parse_fault_spec(spec, net.num_eds, net.num_ess)?;
            Some(FailoverConfig {
                schedule,
                policy: FailoverPolicy::default(),
                num_eds: net.num_eds,
            })
        }
        None => None,
    };

    if args.flag("virtual") {
        // Virtual-time replay: same arrival pattern and failover policy,
        // no wall-clock nondeterminism.
        let fo = failover.unwrap_or_else(|| FailoverConfig {
            schedule: FaultSchedule::none(),
            policy: FailoverPolicy::default(),
            num_eds: 0,
        });
        let rcfg = ReplayConfig {
            workers,
            policy: fo.policy,
            ..Default::default()
        };
        let server = ReplayServer::new(rcfg, &fo.schedule, fo.num_eds);
        let gap_ms = 1000.0 / rate;
        let arrivals: Vec<VirtualRequest> = (0..requests as u64)
            .map(|id| VirtualRequest {
                id,
                arrive_ms: id as f64 * gap_ms,
                deadline_ms,
            })
            .collect();
        let rep = server.run(&arrivals);
        println!(
            "virtual serve: accepted {} served {} on-time {} horizon {:.1} ms",
            rep.accepted, rep.served, rep.on_time, rep.horizon_ms
        );
        let sr = rep.to_serve_report();
        println!("latency (ms): {}", sr.latency_ms.row());
        println!("failover: {}", sr.failover.line());
        return Ok(());
    }

    let has_faults = failover.is_some();
    let cfg = ServeConfig {
        workers,
        real_compute: !args.flag("no-real-compute"),
        failover,
        ..Default::default()
    };
    let slot = fmedge::runtime::shapes::MSBLOCK_L * fmedge::runtime::shapes::MSBLOCK_D;
    let coordinator = Coordinator::start(cfg)?;
    let mut rng = Xoshiro256::seed_from(seed);
    let gap = std::time::Duration::from_secs_f64(1.0 / rate);
    let mut rejected = 0u64;
    for id in 0..requests as u64 {
        let data: Vec<f32> = (0..slot).map(|_| rng.next_f64() as f32).collect();
        let req = Request {
            id,
            data,
            submitted: Instant::now(),
            deadline_ms,
        };
        if coordinator.submit(req).is_err() {
            rejected += 1;
        }
        std::thread::sleep(gap);
    }
    let report = coordinator.shutdown();
    println!(
        "served {} / rejected {} (client-side {rejected}) in {:?}",
        report.served, report.rejected, report.elapsed
    );
    println!(
        "throughput {:.0} rps, on-time {:.3}, batch fill {:.2}",
        report.throughput_rps(),
        report.on_time_rate(),
        report.batch_fill
    );
    println!("latency (ms): {}", report.latency_ms.row());
    if has_faults {
        println!("failover: {}", report.failover.line());
    }
    Ok(())
}
