//! Fault injection and network dynamics: the missing robustness layer.
//!
//! The paper's central claim is that core services form a *fault-tolerant
//! backbone* (κ-diversity, C6) and that the online controller "maintains
//! strong robustness as the system load scales" — yet a static
//! [`crate::network::Topology`] cannot even express a failed link. This
//! subsystem makes the claim measurable:
//!
//! * [`FaultSchedule`] — a seeded, replayable sequence of timed events
//!   (edge-server outage/recovery, link outage/recovery, bandwidth
//!   degradation, core-replica failure). Both the slotted engine and the
//!   DES replay the *identical* schedule, so paired engine-vs-engine and
//!   strategy-vs-strategy comparisons stay apples-to-apples.
//! * [`DynamicTopology`] — a mutable view over the base topology that
//!   applies fault events and re-derives the routing state
//!   ([`crate::routing::HopTable`] / [`crate::routing::DistanceMatrix`])
//!   the engines and the controller consult. Unreachable pairs report
//!   infinite latency, which the greedy controller and the core router
//!   treat as "not a candidate".
//!
//! Failure semantics (shared by both engines, documented here once):
//!
//! * **Node outage** — everything resident on the node dies: light
//!   stations lose queued and in-service work, core replicas go offline,
//!   in-flight executions are cancelled, and *completed stage outputs*
//!   stored on the node are destroyed **permanently** (recovery restores
//!   capacity, not data — a destruction flag, not current liveness,
//!   decides drops, so outage timing relative to sibling stages cannot
//!   resurrect a lost payload). Stages whose inputs survive elsewhere
//!   are re-dispatched (requeue); a stage with a destroyed input loses
//!   the task (drop, virtual-queue entry released,
//!   `TrialMetrics::fault_drops`). The user payload at an edge device
//!   survives outages — the device re-transmits — so ED downtime delays
//!   source stages instead of dropping them.
//! * **Link outage / degradation** — routes are recomputed; transfers
//!   already in flight complete at their committed latency (the payload
//!   left before the event), new transfers see the degraded network.
//! * **Core-replica failure** — fail-stop after finishing current work:
//!   the replica accepts no new tasks. Permanent within a trial; the
//!   κ-diversity constraint is what keeps the service reachable.
//!
//! Entry points: `fmedge faults` (CLI sweep over failure rate × load),
//! `examples/fault_sweep.rs`, and `run_trial_faulted` /
//! `run_des_trial_faulted` on the engines.

mod dynamic;
mod schedule;

pub use dynamic::DynamicTopology;
pub(crate) use schedule::geometric_slots;
pub use schedule::{FaultEvent, FaultKind, FaultParams, FaultSchedule};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;
    use crate::network::Topology;
    use crate::rng::Xoshiro256;

    fn topo(seed: u64) -> Topology {
        let cfg = ExperimentConfig::paper_default();
        let mut rng = Xoshiro256::seed_from(seed);
        Topology::generate(&cfg, &mut rng)
    }

    #[test]
    fn schedule_generation_is_deterministic() {
        let t = topo(1);
        let p = FaultParams::from_rate(0.02);
        let a = FaultSchedule::generate(&t, 200, 1.0, 6, &p, 99);
        let b = FaultSchedule::generate(&t, 200, 1.0, 6, &p, 99);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.events().iter().zip(b.events()) {
            assert_eq!(x.time_ms, y.time_ms);
            assert_eq!(x.kind, y.kind);
        }
        let c = FaultSchedule::generate(&t, 200, 1.0, 6, &p, 100);
        // Different seed: almost surely a different realization.
        let same = a.len() == c.len()
            && a.events()
                .iter()
                .zip(c.events())
                .all(|(x, y)| x.kind == y.kind && x.time_ms == y.time_ms);
        assert!(!same, "seed must matter");
    }

    #[test]
    fn zero_rate_schedule_is_empty() {
        let t = topo(2);
        let p = FaultParams::from_rate(0.0);
        let s = FaultSchedule::generate(&t, 500, 1.0, 6, &p, 7);
        assert!(s.is_empty());
        assert!(FaultSchedule::none().is_empty());
    }

    #[test]
    fn schedule_is_time_sorted_and_outages_recover() {
        let t = topo(3);
        let p = FaultParams::from_rate(0.05);
        let s = FaultSchedule::generate(&t, 300, 1.0, 6, &p, 11);
        assert!(!s.is_empty(), "rate 0.05 over 300 slots must fire");
        let mut last = 0.0;
        let mut down = std::collections::BTreeSet::new();
        for ev in s.events() {
            assert!(ev.time_ms >= last, "events must be time-sorted");
            last = ev.time_ms;
            match ev.kind {
                FaultKind::NodeDown { node } => {
                    assert!(down.insert(node), "double outage of node {node}");
                }
                FaultKind::NodeUp { node } => {
                    assert!(down.remove(&node), "recovery without outage");
                }
                _ => {}
            }
        }
        // Every outage inside the horizon recovers by the schedule's end.
        assert!(down.is_empty(), "unrecovered outages: {down:?}");
    }

    #[test]
    fn node_outages_only_hit_edge_servers() {
        let cfg = ExperimentConfig::paper_default();
        let t = topo(4);
        let p = FaultParams::from_rate(0.1);
        let s = FaultSchedule::generate(&t, 200, 1.0, 6, &p, 13);
        for ev in s.events() {
            if let FaultKind::NodeDown { node } = ev.kind {
                assert!(
                    node >= cfg.network.num_eds,
                    "EDs are user ingress, never faulted by the generator"
                );
            }
        }
    }

    #[test]
    fn outage_cap_keeps_a_backbone_majority() {
        let cfg = ExperimentConfig::paper_default();
        let t = topo(5);
        let mut p = FaultParams::from_rate(0.5); // absurdly aggressive
        p.mean_outage_slots = 50.0;
        let s = FaultSchedule::generate(&t, 400, 1.0, 6, &p, 17);
        let cap = (cfg.network.num_ess - 1) / 2;
        let mut down = 0usize;
        for ev in s.events() {
            match ev.kind {
                FaultKind::NodeDown { .. } => {
                    down += 1;
                    assert!(down <= cap.max(1), "too many concurrent outages");
                }
                FaultKind::NodeUp { .. } => down -= 1,
                _ => {}
            }
        }
    }
}
