//! Seeded, replayable schedules of timed fault events.

use crate::network::{NodeClass, Topology};
use crate::rng::{Rng, Xoshiro256};

/// One kind of network/service fault.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FaultKind {
    /// An edge server goes dark: resident services, queues, and in-flight
    /// executions are lost.
    NodeDown { node: usize },
    /// The server comes back with empty capacity.
    NodeUp { node: usize },
    /// Link `link` (index into [`Topology::links`]) stops carrying
    /// traffic.
    LinkDown { link: usize },
    /// The link is restored at its base bandwidth.
    LinkUp { link: usize },
    /// Bandwidth fluctuation: the link's bandwidth is scaled by `factor`
    /// (`1.0` restores nominal capacity).
    LinkBandwidth { link: usize, factor: f64 },
    /// One replica of dense core MS `core_idx` at `node` fail-stops: it
    /// finishes its current task and accepts no new work. Permanent
    /// within the trial unless a later [`FaultKind::CoreReplicaRestart`]
    /// brings it back. A no-op when no replica is placed there.
    CoreReplicaFail { node: usize, core_idx: usize },
    /// A fail-stopped replica of `core_idx` at `node` restarts: it
    /// rejoins from its last checkpoint (fast restore clock) or cold
    /// (no checkpoint taken). A no-op when nothing failed there or the
    /// node itself is down (it rejoins with the node instead).
    CoreReplicaRestart { node: usize, core_idx: usize },
}

/// A fault event stamped with its absolute simulation time.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultEvent {
    pub time_ms: f64,
    pub kind: FaultKind,
}

/// Generation knobs. All probabilities are per slot; durations are in
/// slots. `from_rate` scales a coherent mix from one headline failure
/// rate, which is what the `fmedge faults` sweep varies.
#[derive(Clone, Copy, Debug)]
pub struct FaultParams {
    /// Per-edge-server outage probability per slot.
    pub node_outage_per_slot: f64,
    /// Per-link outage probability per slot.
    pub link_outage_per_slot: f64,
    /// Per-link bandwidth-fluctuation probability per slot.
    pub degrade_per_slot: f64,
    /// Global core-replica fail-stop probability per slot.
    pub replica_fail_per_slot: f64,
    /// Mean outage/degradation duration (geometric, at least one slot).
    pub mean_outage_slots: f64,
    /// Bandwidth scale drawn uniformly from this range on degradation.
    pub degrade_factor_lo: f64,
    pub degrade_factor_hi: f64,
    /// When `Some(mean)`, every replica fail-stop is paired with a
    /// [`FaultKind::CoreReplicaRestart`] a geometric number of slots
    /// later (checkpoint/restart semantics). `None` keeps fail-stops
    /// permanent — and generated schedules byte-identical to before this
    /// knob existed.
    pub replica_restart_slots: Option<f64>,
}

impl FaultParams {
    /// A coherent fault mix parameterized by one headline rate λ:
    /// node outages at λ, link outages at 2λ, bandwidth fluctuation at
    /// 4λ, replica fail-stop at λ/2. `from_rate(0.0)` generates nothing.
    pub fn from_rate(rate: f64) -> Self {
        FaultParams {
            node_outage_per_slot: rate,
            link_outage_per_slot: 2.0 * rate,
            degrade_per_slot: 4.0 * rate,
            replica_fail_per_slot: 0.5 * rate,
            mean_outage_slots: 20.0,
            degrade_factor_lo: 0.2,
            degrade_factor_hi: 0.7,
            replica_restart_slots: None,
        }
    }

    /// Enable paired replica restarts with the given mean delay (slots).
    pub fn with_replica_restart(mut self, mean_slots: f64) -> Self {
        self.replica_restart_slots = Some(mean_slots);
        self
    }
}

/// Geometric-ish duration draw: ceil of an exponential with the given
/// mean (mean floored at 1), at least one slot. Shared by the
/// independent generator below, the scenarios' correlated fault
/// templates, and the mobility dwell times.
pub(crate) fn geometric_slots<R: Rng + ?Sized>(rng: &mut R, mean: f64) -> usize {
    ((-rng.next_f64_open().ln() * mean.max(1.0)).ceil() as usize).max(1)
}

/// A time-sorted, replayable fault schedule.
#[derive(Clone, Debug, Default)]
pub struct FaultSchedule {
    events: Vec<FaultEvent>,
}

impl FaultSchedule {
    /// The empty schedule: engines running it behave bit-identically to
    /// their fault-free entry points.
    pub fn none() -> Self {
        FaultSchedule::default()
    }

    /// Build from explicit events (tests / handcrafted scenarios); sorts
    /// by time, stable for ties.
    pub fn from_events(mut events: Vec<FaultEvent>) -> Self {
        events.sort_by(|a, b| a.time_ms.total_cmp(&b.time_ms));
        FaultSchedule { events }
    }

    /// Generate a random schedule over `slots × slot_ms` for `topo`.
    ///
    /// Deterministic per seed, independent of any engine RNG stream.
    /// Invariants the engines rely on:
    /// * only edge servers suffer node outages (EDs are user ingress),
    /// * at most `(num_es - 1) / 2` (min 1) servers are down at once, so
    ///   a backbone majority always survives,
    /// * every outage/degradation that starts inside the horizon also
    ///   has its recovery event emitted (possibly past the horizon —
    ///   engines simply never reach it),
    /// * one concurrent fault per node/link (no double-down).
    pub fn generate(
        topo: &Topology,
        slots: usize,
        slot_ms: f64,
        num_core: usize,
        params: &FaultParams,
        seed: u64,
    ) -> Self {
        let mut rng = Xoshiro256::seed_from(seed ^ 0xFA17_5EED);
        let ess: Vec<usize> = topo
            .nodes()
            .iter()
            .filter(|n| n.class == NodeClass::EdgeServer)
            .map(|n| n.id)
            .collect();
        let max_down = ((ess.len().saturating_sub(1)) / 2).max(1);
        let nl = topo.links().len();

        let mut events = Vec::new();
        // Paired replica restarts (merged at the end; only populated when
        // `replica_restart_slots` is set).
        let mut restarts: Vec<FaultEvent> = Vec::new();
        // node -> recovery slot (exclusive) while down.
        let mut node_until = vec![0usize; topo.num_nodes()];
        let mut link_until = vec![0usize; nl];
        let mut degrade_until = vec![0usize; nl];
        let mut down_now = 0usize;

        let duration =
            |rng: &mut Xoshiro256| geometric_slots(rng, params.mean_outage_slots);

        for slot in 0..slots {
            let t = slot as f64 * slot_ms;
            // Node outages.
            for &v in &ess {
                if node_until[v] > slot {
                    continue; // still down
                }
                if down_now >= max_down {
                    break;
                }
                if rng.next_f64() < params.node_outage_per_slot {
                    let dur = duration(&mut rng);
                    node_until[v] = slot + dur;
                    down_now += 1;
                    events.push(FaultEvent {
                        time_ms: t,
                        kind: FaultKind::NodeDown { node: v },
                    });
                }
            }
            // Link outages and bandwidth fluctuation.
            for l in 0..nl {
                if link_until[l] > slot {
                    continue;
                }
                if rng.next_f64() < params.link_outage_per_slot {
                    let dur = duration(&mut rng);
                    link_until[l] = slot + dur;
                    events.push(FaultEvent {
                        time_ms: t,
                        kind: FaultKind::LinkDown { link: l },
                    });
                    continue;
                }
                if degrade_until[l] <= slot && rng.next_f64() < params.degrade_per_slot {
                    let dur = duration(&mut rng);
                    degrade_until[l] = slot + dur;
                    let factor =
                        rng.range_f64(params.degrade_factor_lo, params.degrade_factor_hi);
                    events.push(FaultEvent {
                        time_ms: t,
                        kind: FaultKind::LinkBandwidth { link: l, factor },
                    });
                }
            }
            // Core-replica fail-stop (placement-agnostic: engines no-op
            // when nothing is placed at the drawn location).
            if !ess.is_empty() && num_core > 0 && rng.next_f64() < params.replica_fail_per_slot {
                let node = ess[rng.range_usize(0, ess.len() - 1)];
                let core_idx = rng.range_usize(0, num_core - 1);
                events.push(FaultEvent {
                    time_ms: t,
                    kind: FaultKind::CoreReplicaFail { node, core_idx },
                });
                // Checkpoint/restart: pair the fail-stop with a restart.
                // The extra RNG draw only happens when the knob is on, so
                // schedules generated without it are byte-identical.
                if let Some(mean) = params.replica_restart_slots {
                    let dur = geometric_slots(&mut rng, mean);
                    restarts.push(FaultEvent {
                        time_ms: (slot + dur) as f64 * slot_ms,
                        kind: FaultKind::CoreReplicaRestart { node, core_idx },
                    });
                }
            }
            // Emit recoveries that become due at the next slot boundary.
            let next = slot + 1;
            let tn = next as f64 * slot_ms;
            for &v in &ess {
                if node_until[v] == next {
                    node_until[v] = 0;
                    down_now -= 1;
                    events.push(FaultEvent {
                        time_ms: tn,
                        kind: FaultKind::NodeUp { node: v },
                    });
                }
            }
            for l in 0..nl {
                if link_until[l] == next {
                    link_until[l] = 0;
                    events.push(FaultEvent {
                        time_ms: tn,
                        kind: FaultKind::LinkUp { link: l },
                    });
                }
                if degrade_until[l] == next {
                    degrade_until[l] = 0;
                    events.push(FaultEvent {
                        time_ms: tn,
                        kind: FaultKind::LinkBandwidth { link: l, factor: 1.0 },
                    });
                }
            }
        }
        // Outstanding recoveries past the horizon: emit so replays on a
        // longer horizon stay well-formed.
        let mut tail: Vec<FaultEvent> = Vec::new();
        for &v in &ess {
            if node_until[v] > slots {
                tail.push(FaultEvent {
                    time_ms: node_until[v] as f64 * slot_ms,
                    kind: FaultKind::NodeUp { node: v },
                });
            }
        }
        for l in 0..nl {
            if link_until[l] > slots {
                tail.push(FaultEvent {
                    time_ms: link_until[l] as f64 * slot_ms,
                    kind: FaultKind::LinkUp { link: l },
                });
            }
            if degrade_until[l] > slots {
                tail.push(FaultEvent {
                    time_ms: degrade_until[l] as f64 * slot_ms,
                    kind: FaultKind::LinkBandwidth { link: l, factor: 1.0 },
                });
            }
        }
        tail.sort_by(|a, b| a.time_ms.total_cmp(&b.time_ms));
        events.extend(tail);
        if !restarts.is_empty() {
            // Restarts land mid-stream; a single stable sort restores the
            // time order (skipped entirely when the knob is off, keeping
            // pre-existing schedules byte-identical).
            events.extend(restarts);
            events.sort_by(|a, b| a.time_ms.total_cmp(&b.time_ms));
        }
        FaultSchedule { events }
    }

    pub fn events(&self) -> &[FaultEvent] {
        &self.events
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }
}
