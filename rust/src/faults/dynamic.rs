//! A fault-aware view of the network: applies [`FaultKind`] events and
//! re-derives the routing state every consumer shares.

use crate::network::{Link, Topology};
use crate::routing::{DistanceMatrix, HopTable};

use super::schedule::FaultKind;

/// Mutable network view: base topology + current fault state + the
/// routing tables derived from the *surviving* links.
///
/// Both engines hold one of these and apply the same [`super::FaultSchedule`];
/// `dm()` / `hops()` replace `SimEnv::{dm, hops}` wherever routing is
/// consulted. Pairs with no surviving route report `f64::INFINITY`
/// latency, which the controller and the core router treat as
/// "unreachable" (see [`HopTable`] docs).
#[derive(Clone, Debug)]
pub struct DynamicTopology {
    base: Topology,
    node_up: Vec<bool>,
    link_up: Vec<bool>,
    bw_factor: Vec<f64>,
    ref_mb: f64,
    hops: HopTable,
    dm: DistanceMatrix,
    /// Fault state changed but the routing tables have not been rebuilt
    /// yet (deferred-application batching).
    dirty: bool,
}

impl DynamicTopology {
    /// Start from a fully healthy copy of `topo`. `ref_mb` is the payload
    /// defining the routes (1.0 everywhere in this crate).
    pub fn new(topo: &Topology, ref_mb: f64) -> Self {
        let hops = HopTable::build(topo, ref_mb);
        let dm = DistanceMatrix::from_hops(&hops);
        DynamicTopology {
            base: topo.clone(),
            node_up: vec![true; topo.num_nodes()],
            link_up: vec![true; topo.links().len()],
            bw_factor: vec![1.0; topo.links().len()],
            ref_mb,
            hops,
            dm,
            dirty: false,
        }
    }

    /// Apply one fault event and rebuild the routing tables immediately.
    /// Returns `true` when routing was affected; `CoreReplicaFail` is not
    /// a topology event — the engines forward it to their `CoreRouter`.
    pub fn apply(&mut self, kind: &FaultKind) -> bool {
        let routed = self.apply_deferred(kind);
        self.commit();
        routed
    }

    /// Record one fault event's state change *without* rebuilding routes.
    /// The rebuild is all-pairs Dijkstra, so engines applying a batch of
    /// events with one effective timestamp (a slot boundary, or several
    /// schedule entries at the same instant) call this per event and
    /// [`Self::commit`] once. Reading `dm()`/`hops()` before the commit
    /// returns the pre-batch view.
    pub fn apply_deferred(&mut self, kind: &FaultKind) -> bool {
        match *kind {
            FaultKind::NodeDown { node } => self.node_up[node] = false,
            FaultKind::NodeUp { node } => self.node_up[node] = true,
            FaultKind::LinkDown { link } => self.link_up[link] = false,
            FaultKind::LinkUp { link } => self.link_up[link] = true,
            FaultKind::LinkBandwidth { link, factor } => {
                self.bw_factor[link] = factor.max(1e-6)
            }
            // Replica lifecycle events are router-level, not topology.
            FaultKind::CoreReplicaFail { .. } => return false,
            FaultKind::CoreReplicaRestart { .. } => return false,
        }
        self.dirty = true;
        true
    }

    /// Rebuild the routing tables if any deferred event is outstanding.
    pub fn commit(&mut self) {
        if self.dirty {
            self.dirty = false;
            self.rebuild();
        }
    }

    /// Re-derive routing from the surviving links: a link carries traffic
    /// only when it is up and both endpoints are up; degraded links keep
    /// their distance but scale bandwidth.
    fn rebuild(&mut self) {
        let links: Vec<Link> = self
            .base
            .links()
            .iter()
            .enumerate()
            .filter(|(i, l)| self.link_up[*i] && self.node_up[l.a] && self.node_up[l.b])
            .map(|(i, l)| Link {
                a: l.a,
                b: l.b,
                bandwidth_mb_ms: l.bandwidth_mb_ms * self.bw_factor[i],
                distance_km: l.distance_km,
            })
            .collect();
        let effective = Topology::from_parts(
            self.base.nodes().to_vec(),
            links,
            self.base.prop_speed_km_per_ms,
        );
        self.hops = HopTable::build(&effective, self.ref_mb);
        self.dm = DistanceMatrix::from_hops(&self.hops);
    }

    /// Current routed-latency model (∞ for unreachable pairs).
    pub fn dm(&self) -> &DistanceMatrix {
        &self.dm
    }

    /// Current hop decomposition (empty for unreachable pairs).
    pub fn hops(&self) -> &HopTable {
        &self.hops
    }

    pub fn is_node_up(&self, v: usize) -> bool {
        self.node_up[v]
    }

    pub fn node_up_mask(&self) -> &[bool] {
        &self.node_up
    }

    /// Nodes currently down (diagnostics / under-failure scoring).
    pub fn down_nodes(&self) -> Vec<usize> {
        self.node_up
            .iter()
            .enumerate()
            .filter(|(_, &up)| !up)
            .map(|(v, _)| v)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;
    use crate::rng::Xoshiro256;

    fn topo(seed: u64) -> Topology {
        let cfg = ExperimentConfig::paper_default();
        let mut rng = Xoshiro256::seed_from(seed);
        Topology::generate(&cfg, &mut rng)
    }

    #[test]
    fn healthy_view_matches_static_tables() {
        let t = topo(1);
        let d = DynamicTopology::new(&t, 1.0);
        let dm = DistanceMatrix::build(&t, 1.0);
        for a in 0..t.num_nodes() {
            for b in 0..t.num_nodes() {
                assert!((d.dm().latency(a, b, 1.5) - dm.latency(a, b, 1.5)).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn node_outage_makes_node_unreachable_and_recovers() {
        let cfg = ExperimentConfig::paper_default();
        let t = topo(2);
        let mut d = DynamicTopology::new(&t, 1.0);
        let es = cfg.network.num_eds; // first edge server
        let before = d.dm().latency(0, es, 1.0);
        assert!(before.is_finite());
        assert!(d.apply(&FaultKind::NodeDown { node: es }));
        assert!(!d.is_node_up(es));
        assert!(d.dm().latency(0, es, 1.0).is_infinite());
        assert!(d.hops().hops(0, es).is_empty());
        assert_eq!(d.down_nodes(), vec![es]);
        d.apply(&FaultKind::NodeUp { node: es });
        assert!((d.dm().latency(0, es, 1.0) - before).abs() < 1e-12);
    }

    #[test]
    fn link_outage_reroutes_or_disconnects() {
        let t = topo(3);
        let mut d = DynamicTopology::new(&t, 1.0);
        let (a, b) = (t.links()[0].a, t.links()[0].b);
        let before = d.dm().latency(a, b, 1.0);
        d.apply(&FaultKind::LinkDown { link: 0 });
        let after = d.dm().latency(a, b, 1.0);
        // Either a detour (strictly worse or equal via another parallel
        // link) or a disconnect — never a speedup.
        assert!(after >= before - 1e-12, "link loss cannot speed up routes");
        d.apply(&FaultKind::LinkUp { link: 0 });
        assert!((d.dm().latency(a, b, 1.0) - before).abs() < 1e-12);
    }

    #[test]
    fn bandwidth_degradation_slows_only_transmission() {
        let t = topo(4);
        let mut d = DynamicTopology::new(&t, 1.0);
        let nv = t.num_nodes();
        // Compare at the reference payload, where route optimality makes
        // "every link weakly slower" imply "every pair weakly slower".
        let snapshot: Vec<f64> = (0..nv).map(|b| d.dm().latency(0, b, 1.0)).collect();
        d.apply(&FaultKind::LinkBandwidth { link: 2, factor: 0.25 });
        for b in 0..nv {
            assert!(
                d.dm().latency(0, b, 1.0) >= snapshot[b] - 1e-12,
                "degradation cannot speed up routes"
            );
        }
        d.apply(&FaultKind::LinkBandwidth { link: 2, factor: 1.0 });
        for (b, &s) in snapshot.iter().enumerate() {
            assert!((d.dm().latency(0, b, 1.0) - s).abs() < 1e-12);
        }
    }

    #[test]
    fn replica_fail_is_not_a_topology_event() {
        let t = topo(5);
        let mut d = DynamicTopology::new(&t, 1.0);
        assert!(!d.apply(&FaultKind::CoreReplicaFail { node: 12, core_idx: 0 }));
    }
}
