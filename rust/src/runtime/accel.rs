//! Typed wrappers over the compiled artifacts: padding, execution, and
//! unpadding for each of the three AOT graphs.

use crate::effcap::GTable;
use crate::placement::QosScores;

use super::client::{ArtifactError, Executable, Runtime};
use super::shapes;

/// PJRT-accelerated g-table construction (`effcap.hlo.txt`).
///
/// The AOT graph is compiled for fixed shapes `[M=16, S=4096]`; fewer
/// microservices/samples are padded with neutral rows (rate 1.0) that are
/// dropped on unpadding.
pub struct EffCapAccel {
    exe: Executable,
}

impl EffCapAccel {
    pub fn load(rt: &Runtime) -> Result<Self, ArtifactError> {
        Ok(EffCapAccel {
            exe: rt.load("effcap")?,
        })
    }

    /// Build the `(g, g_mean)` rows for `rate_samples.len()` light MSs.
    ///
    /// The θ-grid and ε are baked into the artifact
    /// (`shapes::EFFCAP_EPSILON`, 32-point log grid) — callers needing
    /// other values use the native `GTable::build`.
    pub fn build_gtable(
        &self,
        rate_samples: &[Vec<f64>],
        workload_mb: &[f64],
    ) -> Result<GTable, ArtifactError> {
        let m_real = rate_samples.len();
        if m_real > shapes::EFFCAP_M {
            return Err(ArtifactError::ShapeMismatch {
                what: format!(
                    "{m_real} light MSs exceed the compiled capacity {}",
                    shapes::EFFCAP_M
                ),
            });
        }
        if m_real != workload_mb.len() {
            return Err(ArtifactError::ShapeMismatch {
                what: "rate_samples and workload_mb lengths differ".into(),
            });
        }
        let mut samples = vec![1.0f32; shapes::EFFCAP_M * shapes::EFFCAP_S];
        for (mi, row) in rate_samples.iter().enumerate() {
            if row.is_empty() {
                return Err(ArtifactError::ShapeMismatch {
                    what: format!("light MS {mi} has no rate samples"),
                });
            }
            for s in 0..shapes::EFFCAP_S {
                // Cycle when fewer samples were drawn than the slot count.
                samples[mi * shapes::EFFCAP_S + s] = row[s % row.len()] as f32;
            }
        }
        let thetas: Vec<f32> = log_grid(1e-3, 10.0, shapes::EFFCAP_T);
        let mut workload = vec![1.0f32; shapes::EFFCAP_M];
        for (mi, &w) in workload_mb.iter().enumerate() {
            workload[mi] = w as f32;
        }

        let outs = self.exe.run_f32(&[
            (&samples, &[shapes::EFFCAP_M, shapes::EFFCAP_S]),
            (&thetas, &[shapes::EFFCAP_T]),
            (&workload, &[shapes::EFFCAP_M]),
        ])?;
        let g = &outs[0];
        let gm = &outs[1];
        let mut delays = Vec::with_capacity(m_real);
        let mut mean_delays = Vec::with_capacity(m_real);
        for mi in 0..m_real {
            let row =
                g[mi * shapes::EFFCAP_Y..(mi + 1) * shapes::EFFCAP_Y].to_vec();
            let mrow =
                gm[mi * shapes::EFFCAP_Y..(mi + 1) * shapes::EFFCAP_Y].to_vec();
            delays.push(row.into_iter().map(|x| x as f64).collect());
            mean_delays.push(mrow.into_iter().map(|x| x as f64).collect());
        }
        Ok(GTable::from_rows(
            delays,
            mean_delays,
            shapes::EFFCAP_EPSILON,
            shapes::EFFCAP_ALPHA,
        ))
    }
}

/// PJRT-accelerated QoS-score apportionment (`qos.hlo.txt`).
pub struct QosAccel {
    exe: Executable,
}

/// Row type shared with the native path.
pub use crate::placement::QosRowData as QosRow;

impl QosAccel {
    pub fn load(rt: &Runtime) -> Result<Self, ArtifactError> {
        Ok(QosAccel { exe: rt.load("qos")? })
    }

    /// Compute `(z̃, d̃, Q)` for `num_nodes × num_core` from row data.
    pub fn scores(
        &self,
        rows: &[QosRow],
        num_nodes: usize,
        num_core: usize,
    ) -> Result<QosScores, ArtifactError> {
        if rows.len() > shapes::QOS_R {
            return Err(ArtifactError::ShapeMismatch {
                what: format!("{} rows exceed compiled capacity {}", rows.len(), shapes::QOS_R),
            });
        }
        if num_nodes > shapes::QOS_V || num_core > shapes::QOS_C {
            return Err(ArtifactError::ShapeMismatch {
                what: "network larger than the compiled QoS shape".into(),
            });
        }
        let (r, v, c) = (shapes::QOS_R, shapes::QOS_V, shapes::QOS_C);
        // Padding: huge dpr on fake nodes keeps softmax mass ≈ 0 there;
        // zero rate + zero group rows are fully inert (pytest-verified).
        let mut dpr = vec![1e9f32; r * v];
        let mut z = vec![0f32; r];
        let mut dd = vec![1f32; r];
        let mut dcu = vec![0f32; r];
        let mut dsu = vec![1f32; r];
        let mut group = vec![0f32; r * c];
        for (ri, row) in rows.iter().enumerate() {
            debug_assert_eq!(row.dpr.len(), num_nodes);
            for (vi, &d) in row.dpr.iter().enumerate() {
                dpr[ri * v + vi] = d as f32;
            }
            z[ri] = row.rate as f32;
            dd[ri] = row.deadline_ms as f32;
            dcu[ri] = row.dcu_ms as f32;
            dsu[ri] = row.dsu_ms.max(1e-3) as f32;
            group[ri * c + row.core_idx] = 1.0;
        }
        let outs = self.exe.run_f32(&[
            (&dpr, &[r, v]),
            (&z, &[r]),
            (&dd, &[r]),
            (&dcu, &[r]),
            (&dsu, &[r]),
            (&group, &[r, c]),
        ])?;
        let unpad = |flat: &[f32]| -> Vec<Vec<f64>> {
            (0..num_nodes)
                .map(|vi| {
                    (0..num_core)
                        .map(|ci| flat[vi * c + ci] as f64)
                        .collect()
                })
                .collect()
        };
        Ok(QosScores {
            z_tilde: unpad(&outs[0]),
            d_tilde: unpad(&outs[1]),
            q: unpad(&outs[2]),
        })
    }
}

/// PJRT-executed core-MS compute (`msblock.hlo.txt`): the serving demo
/// runs one transformer block per request batch. Weights travel in the
/// sidecar `msblock.weights.bin` (raw little-endian f32, order
/// wq,wk,wv,wo,w1,w2) because `as_hlo_text` elides large constants.
pub struct MsBlockAccel {
    exe: Executable,
    /// `(data, dims)` per weight, in artifact argument order.
    weights: Vec<(Vec<f32>, Vec<usize>)>,
}

impl MsBlockAccel {
    /// `load` with bounded compile retries — serving workers all compile
    /// the artifact at startup and transient PJRT races must not take a
    /// replica out of the pool before it ever serves.
    pub fn load_with_retry(rt: &Runtime, attempts: u32) -> Result<Self, ArtifactError> {
        let mut last = None;
        for _ in 0..attempts.max(1) {
            match Self::load(rt) {
                Ok(a) => return Ok(a),
                Err(e @ ArtifactError::Missing(_)) => return Err(e),
                Err(e) => last = Some(e),
            }
        }
        Err(last.expect("at least one attempt"))
    }

    pub fn load(rt: &Runtime) -> Result<Self, ArtifactError> {
        let exe = rt.load("msblock")?;
        let d = shapes::MSBLOCK_D;
        let ff = 2 * d;
        let dims: Vec<Vec<usize>> = vec![
            vec![d, d],
            vec![d, d],
            vec![d, d],
            vec![d, d],
            vec![d, ff],
            vec![ff, d],
        ];
        let path = rt.artifact_dir().join("msblock.weights.bin");
        let bytes = std::fs::read(&path).map_err(|_| ArtifactError::Missing(path.clone()))?;
        let total: usize = dims.iter().map(|d| d.iter().product::<usize>()).sum();
        if bytes.len() != total * 4 {
            return Err(ArtifactError::ShapeMismatch {
                what: format!(
                    "weights file holds {} bytes, expected {}",
                    bytes.len(),
                    total * 4
                ),
            });
        }
        let mut weights = Vec::with_capacity(dims.len());
        let mut off = 0usize;
        for dim in dims {
            let n: usize = dim.iter().product();
            let mut data = Vec::with_capacity(n);
            for i in 0..n {
                let b = &bytes[(off + i) * 4..(off + i) * 4 + 4];
                data.push(f32::from_le_bytes([b[0], b[1], b[2], b[3]]));
            }
            off += n;
            weights.push((data, dim));
        }
        Ok(MsBlockAccel { exe, weights })
    }

    /// Number of requests per compiled batch.
    pub fn batch_size(&self) -> usize {
        shapes::MSBLOCK_B
    }

    /// Run the block on a `[B, L, D]` activations buffer (flattened).
    pub fn forward(&self, x: &[f32]) -> Result<Vec<f32>, ArtifactError> {
        let want = shapes::MSBLOCK_B * shapes::MSBLOCK_L * shapes::MSBLOCK_D;
        if x.len() != want {
            return Err(ArtifactError::ShapeMismatch {
                what: format!("msblock input length {} != {want}", x.len()),
            });
        }
        let mut inputs: Vec<(&[f32], &[usize])> = self
            .weights
            .iter()
            .map(|(d, s)| (d.as_slice(), s.as_slice()))
            .collect();
        let xdims = [shapes::MSBLOCK_B, shapes::MSBLOCK_L, shapes::MSBLOCK_D];
        inputs.push((x, &xdims));
        let outs = self.exe.run_f32(&inputs)?;
        Ok(outs.into_iter().next().expect("one output"))
    }
}

/// Log-spaced grid matching `EffCapEstimator::log_grid` and `aot.py`.
fn log_grid(lo: f64, hi: f64, n: usize) -> Vec<f32> {
    let llo = lo.ln();
    let lhi = hi.ln();
    (0..n)
        .map(|i| ((llo + (lhi - llo) * i as f64 / (n - 1) as f64).exp()) as f32)
        .collect()
}
