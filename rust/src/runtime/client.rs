//! Thin wrapper over the `xla` crate: PJRT CPU client + HLO-text loading.

use std::path::{Path, PathBuf};

/// Artifact loading/compilation errors.
#[derive(Debug)]
pub enum ArtifactError {
    Missing(PathBuf),
    Xla(xla::Error),
    ShapeMismatch { what: String },
}

impl std::fmt::Display for ArtifactError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ArtifactError::Missing(p) => write!(
                f,
                "artifact {} not found — run `make artifacts` first",
                p.display()
            ),
            ArtifactError::Xla(e) => write!(f, "XLA error: {e:?}"),
            ArtifactError::ShapeMismatch { what } => write!(f, "shape mismatch: {what}"),
        }
    }
}

impl std::error::Error for ArtifactError {}

impl From<xla::Error> for ArtifactError {
    fn from(e: xla::Error) -> Self {
        ArtifactError::Xla(e)
    }
}

/// A compiled artifact ready to execute.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    name: String,
}

impl Executable {
    /// Execute with f32 input buffers (shapes must match the AOT manifest);
    /// returns the flattened f32 outputs of the result tuple.
    pub fn run_f32(
        &self,
        inputs: &[(&[f32], &[usize])],
    ) -> Result<Vec<Vec<f32>>, ArtifactError> {
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, dims) in inputs {
            let lit = xla::Literal::vec1(data);
            let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
            literals.push(lit.reshape(&dims_i64)?);
        }
        let mut result = self.exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True.
        let tuple = result.decompose_tuple()?;
        let mut out = Vec::with_capacity(tuple.len());
        for lit in tuple {
            out.push(lit.to_vec::<f32>()?);
        }
        Ok(out)
    }

    pub fn name(&self) -> &str {
        &self.name
    }
}

/// PJRT CPU runtime holding the client and compiled artifacts.
pub struct Runtime {
    client: xla::PjRtClient,
    artifact_dir: PathBuf,
}

impl Runtime {
    /// Create a CPU PJRT client rooted at an artifact directory.
    pub fn cpu(artifact_dir: impl AsRef<Path>) -> Result<Self, ArtifactError> {
        Ok(Runtime {
            client: xla::PjRtClient::cpu()?,
            artifact_dir: artifact_dir.as_ref().to_path_buf(),
        })
    }

    /// Platform string (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load and compile `<name>.hlo.txt` from the artifact directory.
    pub fn load(&self, name: &str) -> Result<Executable, ArtifactError> {
        let path = self.artifact_dir.join(format!("{name}.hlo.txt"));
        if !path.exists() {
            return Err(ArtifactError::Missing(path));
        }
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().expect("utf-8 artifact path"),
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        Ok(Executable {
            exe,
            name: name.to_string(),
        })
    }

    /// `load` with bounded retries for transient PJRT compile failures
    /// (many workers compiling the same artifact concurrently can race on
    /// plugin init). A `Missing` artifact is permanent and not retried;
    /// the last error is returned once attempts are exhausted.
    pub fn load_with_retry(
        &self,
        name: &str,
        attempts: u32,
    ) -> Result<Executable, ArtifactError> {
        let mut last = None;
        for i in 0..attempts.max(1) {
            match self.load(name) {
                Ok(exe) => return Ok(exe),
                Err(e @ ArtifactError::Missing(_)) => return Err(e),
                Err(e) => last = Some(e),
            }
            std::thread::sleep(std::time::Duration::from_millis(2u64 << i));
        }
        Err(last.expect("at least one attempt"))
    }

    /// The directory this runtime loads artifacts from.
    pub fn artifact_dir(&self) -> &Path {
        &self.artifact_dir
    }

    /// Default artifact directory relative to the repo root, overridable
    /// via `FMEDGE_ARTIFACTS`.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("FMEDGE_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }
}
