//! PJRT runtime: loads the AOT-compiled HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the request path. Python
//! never runs at serving time — the `xla` crate's PJRT CPU client compiles
//! the HLO once at startup and the executables are called from Rust.
//!
//! Artifacts (shapes in `artifacts/manifest.txt`, kept in sync with
//! `aot.py`):
//! * `effcap.hlo.txt`  — the g-table builder ([`EffCapAccel`]).
//! * `qos.hlo.txt`     — the QoS-score apportionment ([`QosAccel`]).
//! * `msblock.hlo.txt` — a transformer block standing in for core-MS
//!   compute in the serving demo ([`MsBlockAccel`]).

mod accel;
mod client;

pub use accel::{EffCapAccel, MsBlockAccel, QosAccel};
pub use client::{ArtifactError, Executable, Runtime};

/// Compile-time shape constants mirrored from `python/compile/aot.py`.
pub mod shapes {
    pub const EFFCAP_M: usize = 16;
    pub const EFFCAP_S: usize = 4096;
    pub const EFFCAP_T: usize = 32;
    pub const EFFCAP_Y: usize = 16;
    pub const EFFCAP_ALPHA: f64 = 1.0;
    pub const EFFCAP_EPSILON: f64 = 0.2;

    pub const QOS_R: usize = 512;
    pub const QOS_V: usize = 32;
    pub const QOS_C: usize = 8;
    pub const QOS_DELTA: f64 = 0.05;
    pub const QOS_LO: f64 = 0.05;
    pub const QOS_HI: f64 = 4.0;

    pub const MSBLOCK_B: usize = 4;
    pub const MSBLOCK_L: usize = 16;
    pub const MSBLOCK_D: usize = 256;
}
