//! Command-line interface (clap is unavailable offline; this is a small
//! purpose-built parser). Subcommands:
//!
//! * `config --show` — print the Table I parameter set in use.
//! * `place` — run the static core placement and print the matrix.
//! * `simulate` — run trials of a strategy and print metrics.
//! * `des` — run the discrete-event queueing engine on a recorded trace
//!   and (optionally) validate measured sojourns against `g_{m,ε}(y)`.
//! * `gtable` — build and print the effective-capacity delay table
//!   (native or PJRT-accelerated with `--accel`).
//! * `pool` — the elastic-autoscaling demo: replica pools + shared-rate
//!   contention (autoscale) vs the fixed-parallelism path on one paired
//!   scenario, both engines.
//! * `faults` — sweep failure rate × load grids under fault injection
//!   and report degradation vs the no-fault baseline.
//! * `sweep` — parallel experiment orchestrator for the EXPERIMENTS.md
//!   grids (p1b/p2/p4/p5/p10) with CSV/JSON artifacts.
//! * `trace` — run one observed trial with span tracing enabled and
//!   export Chrome trace JSON / JSONL spans / per-slot telemetry CSV,
//!   with `--blame` for deadline-miss attribution.
//! * `serve` — start the serving coordinator on a synthetic open-loop
//!   workload and print the latency/throughput report.
//! * `lint` — the in-tree determinism lint: machine-check the replay
//!   invariants (hash-iter, wall-clock, float-cmp, rng-discipline,
//!   unsafe-forbid) over the crate's own sources.

use std::collections::HashMap;

/// Parsed command line: subcommand + `--key value` / `--flag` options.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub command: Option<String>,
    opts: HashMap<String, String>,
    flags: Vec<String>,
}

/// Argument errors.
#[derive(Debug, PartialEq)]
pub enum ArgError {
    MissingValue(String),
    Invalid { key: String, value: String, want: &'static str },
    UnknownCommand(String),
}

impl std::fmt::Display for ArgError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ArgError::MissingValue(k) => write!(f, "option --{k} needs a value"),
            ArgError::Invalid { key, value, want } => {
                write!(f, "--{key}={value} is not a valid {want}")
            }
            ArgError::UnknownCommand(c) => write!(f, "unknown command `{c}` (try --help)"),
        }
    }
}

impl std::error::Error for ArgError {}

/// Known boolean flags (everything else with `--` expects a value).
const FLAGS: &[&str] = &[
    "show",
    "accel",
    "help",
    "exact",
    "fallback",
    "no-real-compute",
    "validate",
    "virtual",
    "blame",
    "bench",
    "streaming",
    "deny",
];

impl Args {
    /// Parse from an iterator of arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Self, ArgError> {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                if FLAGS.contains(&key) {
                    out.flags.push(key.to_string());
                } else {
                    let v = it
                        .next()
                        .ok_or_else(|| ArgError::MissingValue(key.to_string()))?;
                    out.opts.insert(key.to_string(), v);
                }
            } else if out.command.is_none() {
                out.command = Some(a);
            } else {
                return Err(ArgError::UnknownCommand(a));
            }
        }
        Ok(out)
    }

    /// From the process arguments.
    pub fn from_env() -> Result<Self, ArgError> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(String::as_str)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize, ArgError> {
        match self.opts.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| ArgError::Invalid {
                key: name.to_string(),
                value: v.clone(),
                want: "integer",
            }),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64, ArgError> {
        match self.opts.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| ArgError::Invalid {
                key: name.to_string(),
                value: v.clone(),
                want: "number",
            }),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64, ArgError> {
        match self.opts.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| ArgError::Invalid {
                key: name.to_string(),
                value: v.clone(),
                want: "integer",
            }),
        }
    }

    /// Comma-separated number list, e.g. `--rates 0,0.002,0.01`.
    pub fn get_f64_list(&self, name: &str, default: &[f64]) -> Result<Vec<f64>, ArgError> {
        match self.opts.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .filter(|s| !s.trim().is_empty())
                .map(|s| {
                    s.trim().parse().map_err(|_| ArgError::Invalid {
                        key: name.to_string(),
                        value: v.clone(),
                        want: "comma-separated numbers",
                    })
                })
                .collect(),
        }
    }

    /// Comma-separated string list, e.g. `--strategies proposal,lbrr`.
    pub fn get_str_list(&self, name: &str, default: &[&str]) -> Vec<String> {
        match self.opts.get(name) {
            None => default.iter().map(|s| s.to_string()).collect(),
            Some(v) => v
                .split(',')
                .map(str::trim)
                .filter(|s| !s.is_empty())
                .map(str::to_string)
                .collect(),
        }
    }
}

/// The `--help` text.
pub const HELP: &str = "\
fmedge — modular foundation-model inference at the edge

USAGE: fmedge <COMMAND> [OPTIONS]

COMMANDS:
  config    print the experiment configuration (Table I)
  place     run the static core placement (--seed N, --kappa K, --exact,
            --fallback, --config FILE)
  gtable    print the g_{m,eps}(y) delay table (--seed N, --accel for the
            PJRT path, --config FILE)
  simulate  run trials (--strategy proposal|propavg|lbrr|ga, --trials N,
            --slots N, --load X, --seed N, --config FILE)
  des       run the discrete-event queueing engine on a recorded trace
            (--strategy ..., --trials N, --slots N, --load X, --seed N,
            --users N overrides the population size, --trace FILE to
            replay, --save-trace FILE, --validate for the
            measured-vs-g_{m,eps} bound report, --batch N --batch-wait MS
            for sim-time station batching, --streaming for flat-memory
            streaming metrics at large N, --bench for the calendar
            push/pop microbench + engine events/sec report
            [FMEDGE_BENCH_JSON=FILE to save])
  pool      elastic-autoscaling demo (EXPERIMENTS P10): run one compiled
            scenario through both engines with the replica-pool tier on
            (autoscale: grow/shrink/scale-to-zero, seeded cold starts,
            shared-rate contention) and off (fixed-y proposal) on the
            identical trace + fault schedule, and print the on-time vs
            deployment-cost trade (--scenario NAME [default diurnal],
            --slots N, --load X, --seed N, --config FILE)
  faults    robustness sweep: replay seeded fault schedules (server
            outages, link outages/degradation, replica fail-stop) over a
            failure-rate x load grid and compare strategies' on-time
            degradation vs the no-fault baseline (--rates R1,R2,...,
            --loads L1,L2,..., --strategies s1,s2,..., --trials N,
            --slots N, --seed N, --engine slotted|des, --config FILE)
  sweep     parallel experiment orchestrator: run an EXPERIMENTS.md grid
            end-to-end and write CSV/JSON artifacts
            (--experiment p1b|p2|p4|p5|p10, --threads N [bit-identical
            for any N], --trials N, --slots N, --seed N, --out FILE.csv,
            --json FILE.json; grid axes: --loads, --rates, --strategies,
            --engines slotted,des, --epsilons, --scenarios; p5 scenario
            names: baseline, diurnal, mmpp, flash-crowd, mobility,
            commuter, zone-outage, cascade, rush-hour, metro-1m;
            p10 runs autoscale-vs-fixed-y on paired traces over
            --scenarios [default diurnal,flash-crowd] x --loads)
  trace     run one observed trial with per-task span tracing and slot
            telemetry (--engine slotted|des, --strategy ..., --slots N,
            --load X, --seed N, --rate R arms a seeded fault schedule,
            --out FILE.json writes Chrome trace-event JSON [Perfetto],
            --jsonl FILE.jsonl writes flat spans, --telemetry FILE.csv
            writes the per-slot metric series, --blame prints the
            deadline-miss blame decomposition vs the g_{m,eps} budget,
            --config FILE)
  serve     run the serving coordinator on a synthetic open-loop workload
            (--requests N, --rate RPS, --workers N, --no-real-compute;
            failover: --faults SPEC with SPEC = `zone@START+DUR` or
            `esK@START+DUR[,...]` (ms) arms checkpoint/restart + retry
            re-routing, --virtual replays the same workload + policy on
            the deterministic virtual-time server [bit-stable counters],
            --deadline-ms X, --seed N)
  lint      determinism lint over rust/src, rust/tests, rust/benches and
            examples/: hash-iter (HashMap/HashSet in deterministic
            modules), wall-clock (Instant::now/SystemTime outside the
            allowlist), float-cmp (partial_cmp().unwrap() comparators),
            rng-discipline (ad-hoc literal seeds), unsafe-forbid
            (--deny exits nonzero on any new finding, --baseline FILE
            [default rust/lint-baseline.txt if present],
            --write-baseline FILE accepts the current findings,
            --root PATH overrides repo-root autodetection; suppress a
            site with `// lint: allow(<rule>): <reason>`)

GLOBAL OPTIONS:
  --config FILE   TOML overrides on top of the paper defaults
  --help          this text
";

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|x| x.to_string())).unwrap()
    }

    #[test]
    fn parses_command_options_flags() {
        let a = parse(&["simulate", "--trials", "7", "--strategy", "lbrr", "--accel"]);
        assert_eq!(a.command.as_deref(), Some("simulate"));
        assert_eq!(a.get_usize("trials", 0).unwrap(), 7);
        assert_eq!(a.get("strategy"), Some("lbrr"));
        assert!(a.flag("accel"));
        assert!(!a.flag("show"));
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&["place"]);
        assert_eq!(a.get_usize("kappa", 8).unwrap(), 8);
        assert_eq!(a.get_f64("load", 1.0).unwrap(), 1.0);
    }

    #[test]
    fn list_options_parse() {
        let a = parse(&["faults", "--rates", "0,0.002, 0.01", "--strategies", "proposal,lbrr"]);
        assert_eq!(a.get_f64_list("rates", &[1.0]).unwrap(), vec![0.0, 0.002, 0.01]);
        assert_eq!(a.get_str_list("strategies", &["proposal"]), vec!["proposal", "lbrr"]);
        assert_eq!(a.get_f64_list("loads", &[1.0, 2.0]).unwrap(), vec![1.0, 2.0]);
        assert_eq!(a.get_str_list("engine", &["slotted"]), vec!["slotted"]);
        let bad = parse(&["faults", "--rates", "0,x"]);
        assert!(bad.get_f64_list("rates", &[]).is_err());
    }

    #[test]
    fn missing_value_is_error() {
        let e = Args::parse(["place".to_string(), "--seed".to_string()]).unwrap_err();
        assert_eq!(e, ArgError::MissingValue("seed".into()));
    }

    #[test]
    fn bad_number_is_error() {
        let a = parse(&["place", "--seed", "abc"]);
        assert!(a.get_u64("seed", 0).is_err());
    }

    #[test]
    fn extra_positional_rejected() {
        assert!(Args::parse(["a".to_string(), "b".to_string()]).is_err());
    }
}
