//! Pseudo-random number generation and the stochastic models of Table I.
//!
//! Built from scratch (no `rand` crate offline): a xoshiro256++ generator
//! seeded via splitmix64, plus the distributions the paper's evaluation
//! draws from — Uniform, Exponential, Normal, Poisson (task arrivals
//! `z_{u,n,t}`), Gamma (light-MS service rates `f_m`), and Nakagami-m
//! (wireless fading for the uplink SNR `γ_u`).

mod xoshiro;
mod distributions;

pub use distributions::{Exponential, Gamma, LogNormal, Nakagami, Normal, Poisson, Uniform};
pub use xoshiro::{stream_seed, Xoshiro256};

/// Minimal RNG interface used across the crate.
pub trait Rng {
    /// Next raw 64 random bits.
    fn next_u64(&mut self) -> u64;

    /// Uniform f64 in `[0, 1)` with 53-bit resolution.
    #[inline]
    fn next_f64(&mut self) -> f64 {
        // Take the top 53 bits -> uniform double in [0,1).
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in `(0, 1]` — safe as a log() argument.
    #[inline]
    fn next_f64_open(&mut self) -> f64 {
        1.0 - self.next_f64()
    }

    /// Uniform integer in `[0, n)`. `n` must be > 0.
    #[inline]
    fn next_below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Lemire-style rejection to remove modulo bias.
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut lo = m as u64;
        if lo < n {
            let t = n.wrapping_neg() % n;
            while lo < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform f64 in `[lo, hi)`.
    #[inline]
    fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform usize in `[lo, hi]` (inclusive).
    #[inline]
    fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi >= lo);
        lo + self.next_below((hi - lo + 1) as u64) as usize
    }

    /// Fisher–Yates shuffle.
    fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element index, or None if empty.
    #[inline]
    fn choose_index(&mut self, len: usize) -> Option<usize> {
        if len == 0 {
            None
        } else {
            Some(self.next_below(len as u64) as usize)
        }
    }
}

/// A distribution that can be sampled with any [`Rng`].
pub trait Distribution {
    /// Draw one sample.
    fn sample<R: Rng + ?Sized>(&self, rng: &mut R) -> f64;

    /// Draw `n` samples into a fresh vector.
    fn sample_n<R: Rng + ?Sized>(&self, rng: &mut R, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.sample(rng)).collect()
    }

    /// The distribution mean, used by mean-value baselines (PropAvg).
    fn mean(&self) -> f64;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn next_below_is_in_range_and_covers() {
        let mut rng = Xoshiro256::seed_from(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = rng.next_below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues should appear");
    }

    #[test]
    fn next_f64_unit_interval() {
        let mut rng = Xoshiro256::seed_from(3);
        for _ in 0..10_000 {
            let v = rng.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn next_f64_open_never_zero() {
        let mut rng = Xoshiro256::seed_from(11);
        for _ in 0..10_000 {
            let v = rng.next_f64_open();
            assert!(v > 0.0 && v <= 1.0);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Xoshiro256::seed_from(5);
        let mut xs: Vec<u32> = (0..50).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn range_usize_inclusive_bounds() {
        let mut rng = Xoshiro256::seed_from(9);
        let mut lo_seen = false;
        let mut hi_seen = false;
        for _ in 0..2000 {
            let v = rng.range_usize(3, 6);
            assert!((3..=6).contains(&v));
            lo_seen |= v == 3;
            hi_seen |= v == 6;
        }
        assert!(lo_seen && hi_seen);
    }
}
