//! The stochastic models of Table I.
//!
//! * `Poisson` — task arrivals `z_{u,n,t}` (mean in [0.15, 1.5] per ms).
//! * `Gamma` — light-MS processing rates `f_m ~ Gamma(k∈[1,2], θ∈[1,20])`.
//! * `Nakagami` — wireless fading; the uplink SNR `γ_u` follows the power
//!   of a Nakagami-m envelope, i.e. `Gamma(m, Ω/m)`.
//! * `Normal`, `Exponential`, `LogNormal`, `Uniform` — support/utility.

use super::{Distribution, Rng};

/// Uniform on `[lo, hi)`.
#[derive(Clone, Copy, Debug)]
pub struct Uniform {
    pub lo: f64,
    pub hi: f64,
}

impl Uniform {
    pub fn new(lo: f64, hi: f64) -> Self {
        assert!(hi >= lo, "Uniform requires hi >= lo, got [{lo}, {hi})");
        Uniform { lo, hi }
    }
}

impl Distribution for Uniform {
    #[inline]
    fn sample<R: Rng + ?Sized>(&self, rng: &mut R) -> f64 {
        rng.range_f64(self.lo, self.hi)
    }
    fn mean(&self) -> f64 {
        0.5 * (self.lo + self.hi)
    }
}

/// Exponential with rate `lambda` (mean `1/lambda`).
#[derive(Clone, Copy, Debug)]
pub struct Exponential {
    pub lambda: f64,
}

impl Exponential {
    pub fn new(lambda: f64) -> Self {
        assert!(lambda > 0.0, "Exponential rate must be positive");
        Exponential { lambda }
    }
}

impl Distribution for Exponential {
    #[inline]
    fn sample<R: Rng + ?Sized>(&self, rng: &mut R) -> f64 {
        -rng.next_f64_open().ln() / self.lambda
    }
    fn mean(&self) -> f64 {
        1.0 / self.lambda
    }
}

/// Normal(mu, sigma) via Marsaglia polar method.
#[derive(Clone, Copy, Debug)]
pub struct Normal {
    pub mu: f64,
    pub sigma: f64,
}

impl Normal {
    pub fn new(mu: f64, sigma: f64) -> Self {
        assert!(sigma >= 0.0, "Normal sigma must be non-negative");
        Normal { mu, sigma }
    }

    /// One standard-normal variate.
    #[inline]
    pub fn standard<R: Rng + ?Sized>(rng: &mut R) -> f64 {
        loop {
            let u = 2.0 * rng.next_f64() - 1.0;
            let v = 2.0 * rng.next_f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                return u * (-2.0 * s.ln() / s).sqrt();
            }
        }
    }
}

impl Distribution for Normal {
    #[inline]
    fn sample<R: Rng + ?Sized>(&self, rng: &mut R) -> f64 {
        self.mu + self.sigma * Normal::standard(rng)
    }
    fn mean(&self) -> f64 {
        self.mu
    }
}

/// LogNormal: exp(Normal(mu, sigma)).
#[derive(Clone, Copy, Debug)]
pub struct LogNormal {
    pub mu: f64,
    pub sigma: f64,
}

impl LogNormal {
    pub fn new(mu: f64, sigma: f64) -> Self {
        LogNormal { mu, sigma }
    }
}

impl Distribution for LogNormal {
    #[inline]
    fn sample<R: Rng + ?Sized>(&self, rng: &mut R) -> f64 {
        (self.mu + self.sigma * Normal::standard(rng)).exp()
    }
    fn mean(&self) -> f64 {
        (self.mu + 0.5 * self.sigma * self.sigma).exp()
    }
}

/// Gamma with shape `k` and scale `theta` (mean `k*theta`).
///
/// Marsaglia–Tsang squeeze method; for k < 1 uses the boost
/// `Gamma(k) = Gamma(k+1) * U^{1/k}`.
#[derive(Clone, Copy, Debug)]
pub struct Gamma {
    pub shape: f64,
    pub scale: f64,
}

impl Gamma {
    pub fn new(shape: f64, scale: f64) -> Self {
        assert!(shape > 0.0 && scale > 0.0, "Gamma parameters must be positive");
        Gamma { shape, scale }
    }

    fn sample_standard<R: Rng + ?Sized>(k: f64, rng: &mut R) -> f64 {
        if k < 1.0 {
            let x = Self::sample_standard(k + 1.0, rng);
            let u = rng.next_f64_open();
            return x * u.powf(1.0 / k);
        }
        let d = k - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = Normal::standard(rng);
            let v = 1.0 + c * x;
            if v <= 0.0 {
                continue;
            }
            let v3 = v * v * v;
            let u = rng.next_f64_open();
            let x2 = x * x;
            // Squeeze check then full acceptance check.
            if u < 1.0 - 0.0331 * x2 * x2 {
                return d * v3;
            }
            if u.ln() < 0.5 * x2 + d * (1.0 - v3 + v3.ln()) {
                return d * v3;
            }
        }
    }

    /// Closed-form effective capacity of an iid Gamma service process
    /// (rate units per slot of length `dt`):
    /// `E^c(θ) = k·ln(1 + θ·s·dt) / (θ·dt)`.
    ///
    /// Used as the analytic oracle for the sampled estimator and the
    /// Pallas kernel (DESIGN.md §5).
    pub fn effective_capacity(&self, theta: f64, dt: f64) -> f64 {
        assert!(theta > 0.0 && dt > 0.0);
        self.shape * (1.0 + theta * self.scale * dt).ln() / (theta * dt)
    }

    pub fn variance(&self) -> f64 {
        self.shape * self.scale * self.scale
    }
}

impl Distribution for Gamma {
    #[inline]
    fn sample<R: Rng + ?Sized>(&self, rng: &mut R) -> f64 {
        self.scale * Self::sample_standard(self.shape, rng)
    }
    fn mean(&self) -> f64 {
        self.shape * self.scale
    }
}

/// Poisson with mean `lambda` per slot.
///
/// Knuth multiplication for small lambda, PTRS transformed rejection
/// (Hörmann 1993) for lambda >= 10.
#[derive(Clone, Copy, Debug)]
pub struct Poisson {
    pub lambda: f64,
}

impl Poisson {
    pub fn new(lambda: f64) -> Self {
        assert!(lambda >= 0.0, "Poisson mean must be non-negative");
        Poisson { lambda }
    }

    /// Draw one integer count.
    pub fn sample_count<R: Rng + ?Sized>(&self, rng: &mut R) -> u64 {
        if self.lambda == 0.0 {
            return 0;
        }
        if self.lambda < 10.0 {
            // Knuth: multiply uniforms until below e^-lambda.
            let l = (-self.lambda).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= rng.next_f64_open();
                if p <= l {
                    return k;
                }
                k += 1;
                // Numerical guard: for lambda < 10 this loop terminates
                // long before k reaches 1000.
                if k > 1000 {
                    return k;
                }
            }
        }
        self.sample_ptrs(rng)
    }

    /// PTRS transformed-rejection sampler for large lambda.
    fn sample_ptrs<R: Rng + ?Sized>(&self, rng: &mut R) -> u64 {
        let lam = self.lambda;
        let slam = lam.sqrt();
        let loglam = lam.ln();
        let b = 0.931 + 2.53 * slam;
        let a = -0.059 + 0.02483 * b;
        let inv_alpha = 1.1239 + 1.1328 / (b - 3.4);
        let v_r = 0.9277 - 3.6224 / (b - 2.0);
        loop {
            let u = rng.next_f64() - 0.5;
            let v = rng.next_f64_open();
            let us = 0.5 - u.abs();
            let k = ((2.0 * a / us + b) * u + lam + 0.43).floor();
            if us >= 0.07 && v <= v_r && k >= 0.0 {
                return k as u64;
            }
            if k < 0.0 || (us < 0.013 && v > us) {
                continue;
            }
            if v.ln() + inv_alpha.ln() - (a / (us * us) + b).ln()
                <= k * loglam - lam - ln_factorial(k as u64)
            {
                if k >= 0.0 {
                    return k as u64;
                }
            }
        }
    }
}

impl Distribution for Poisson {
    #[inline]
    fn sample<R: Rng + ?Sized>(&self, rng: &mut R) -> f64 {
        self.sample_count(rng) as f64
    }
    fn mean(&self) -> f64 {
        self.lambda
    }
}

/// Nakagami-m fading. `sample()` returns the instantaneous channel *power*
/// (envelope squared), i.e. `Gamma(m, omega/m)`, which scales the SNR in
/// eq. (1). `sample_envelope()` returns the amplitude.
#[derive(Clone, Copy, Debug)]
pub struct Nakagami {
    /// Shape (fading severity); m >= 0.5. Table I uses m in [1.5, 3].
    pub m: f64,
    /// Spread: average power Ω. Table I uses Ω in [0.5, 1].
    pub omega: f64,
}

impl Nakagami {
    pub fn new(m: f64, omega: f64) -> Self {
        assert!(m >= 0.5, "Nakagami shape must be >= 0.5");
        assert!(omega > 0.0, "Nakagami spread must be positive");
        Nakagami { m, omega }
    }

    fn power_gamma(&self) -> Gamma {
        Gamma::new(self.m, self.omega / self.m)
    }

    /// Envelope (amplitude) sample: sqrt of the power sample.
    pub fn sample_envelope<R: Rng + ?Sized>(&self, rng: &mut R) -> f64 {
        self.power_gamma().sample(rng).sqrt()
    }
}

impl Distribution for Nakagami {
    /// Instantaneous power sample (mean Ω).
    #[inline]
    fn sample<R: Rng + ?Sized>(&self, rng: &mut R) -> f64 {
        self.power_gamma().sample(rng)
    }
    fn mean(&self) -> f64 {
        self.omega
    }
}

/// ln(k!) via Stirling series for large k, table for small.
pub fn ln_factorial(k: u64) -> f64 {
    const TABLE: [f64; 16] = [
        0.0,
        0.0,
        0.6931471805599453,
        1.791759469228055,
        3.1780538303479458,
        4.787491742782046,
        6.579251212010101,
        8.525161361065415,
        10.60460290274525,
        12.801827480081469,
        15.104412573075516,
        17.502307845873887,
        19.987214495661885,
        22.552163853123425,
        25.19122118273868,
        27.89927138384089,
    ];
    if (k as usize) < TABLE.len() {
        return TABLE[k as usize];
    }
    let x = (k + 1) as f64;
    // Stirling: ln Γ(x) ≈ (x-.5)ln x - x + .5 ln 2π + 1/(12x) - 1/(360x^3)
    (x - 0.5) * x.ln() - x + 0.5 * (2.0 * std::f64::consts::PI).ln() + 1.0 / (12.0 * x)
        - 1.0 / (360.0 * x * x * x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;

    fn mean_var(xs: &[f64]) -> (f64, f64) {
        let n = xs.len() as f64;
        let m = xs.iter().sum::<f64>() / n;
        let v = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / n;
        (m, v)
    }

    #[test]
    fn exponential_moments() {
        let mut rng = Xoshiro256::seed_from(1);
        let d = Exponential::new(2.0);
        let xs = d.sample_n(&mut rng, 200_000);
        let (m, v) = mean_var(&xs);
        assert!((m - 0.5).abs() < 0.01, "mean={m}");
        assert!((v - 0.25).abs() < 0.02, "var={v}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Xoshiro256::seed_from(2);
        let d = Normal::new(3.0, 2.0);
        let xs = d.sample_n(&mut rng, 200_000);
        let (m, v) = mean_var(&xs);
        assert!((m - 3.0).abs() < 0.03, "mean={m}");
        assert!((v - 4.0).abs() < 0.1, "var={v}");
    }

    #[test]
    fn gamma_moments_shape_above_one() {
        let mut rng = Xoshiro256::seed_from(3);
        let d = Gamma::new(1.7, 8.0);
        let xs = d.sample_n(&mut rng, 200_000);
        let (m, v) = mean_var(&xs);
        assert!((m - d.mean()).abs() / d.mean() < 0.01, "mean={m}");
        assert!((v - d.variance()).abs() / d.variance() < 0.05, "var={v}");
    }

    #[test]
    fn gamma_moments_shape_below_one() {
        let mut rng = Xoshiro256::seed_from(4);
        let d = Gamma::new(0.5, 2.0);
        let xs = d.sample_n(&mut rng, 200_000);
        let (m, _) = mean_var(&xs);
        assert!((m - 1.0).abs() < 0.02, "mean={m}");
        assert!(xs.iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn poisson_small_lambda_moments() {
        let mut rng = Xoshiro256::seed_from(5);
        let d = Poisson::new(0.8);
        let xs = d.sample_n(&mut rng, 200_000);
        let (m, v) = mean_var(&xs);
        assert!((m - 0.8).abs() < 0.01, "mean={m}");
        assert!((v - 0.8).abs() < 0.02, "var={v}");
    }

    #[test]
    fn poisson_large_lambda_moments() {
        let mut rng = Xoshiro256::seed_from(6);
        let d = Poisson::new(45.0);
        let xs = d.sample_n(&mut rng, 100_000);
        let (m, v) = mean_var(&xs);
        assert!((m - 45.0).abs() < 0.2, "mean={m}");
        assert!((v - 45.0).abs() < 1.5, "var={v}");
    }

    #[test]
    fn poisson_zero_lambda() {
        let mut rng = Xoshiro256::seed_from(7);
        assert_eq!(Poisson::new(0.0).sample_count(&mut rng), 0);
    }

    #[test]
    fn nakagami_power_mean_is_omega() {
        let mut rng = Xoshiro256::seed_from(8);
        let d = Nakagami::new(2.0, 0.75);
        let xs = d.sample_n(&mut rng, 200_000);
        let (m, _) = mean_var(&xs);
        assert!((m - 0.75).abs() < 0.01, "mean={m}");
    }

    #[test]
    fn nakagami_envelope_squared_matches_power_mean() {
        let mut rng = Xoshiro256::seed_from(9);
        let d = Nakagami::new(1.5, 1.0);
        let n = 100_000;
        let m: f64 = (0..n)
            .map(|_| {
                let e = d.sample_envelope(&mut rng);
                e * e
            })
            .sum::<f64>()
            / n as f64;
        assert!((m - 1.0).abs() < 0.02, "mean={m}");
    }

    #[test]
    fn ln_factorial_matches_direct() {
        let mut acc = 0.0f64;
        for k in 1..30u64 {
            acc += (k as f64).ln();
            assert!(
                (ln_factorial(k) - acc).abs() < 1e-8,
                "k={k} got={} want={acc}",
                ln_factorial(k)
            );
        }
    }

    #[test]
    fn gamma_effective_capacity_closed_form_properties() {
        // E^c(θ) decreases in θ and tends to the mean as θ -> 0.
        let g = Gamma::new(1.5, 10.0);
        let dt = 1.0;
        let e_small = g.effective_capacity(1e-9, dt);
        assert!((e_small - g.mean()).abs() / g.mean() < 1e-6);
        let mut prev = f64::INFINITY;
        for i in 1..50 {
            let th = i as f64 * 0.05;
            let e = g.effective_capacity(th, dt);
            assert!(e <= prev + 1e-12, "E^c must be non-increasing in θ");
            assert!(e > 0.0);
            prev = e;
        }
    }
}
