//! xoshiro256++ — the crate-wide deterministic PRNG.
//!
//! Reference: Blackman & Vigna, "Scrambled linear pseudorandom number
//! generators" (2018). Seeded through splitmix64 so that any u64 seed
//! yields a well-mixed state. Deterministic across platforms, which the
//! benches rely on for reproducible trials.

use super::Rng;

/// xoshiro256++ state.
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Derive an independent seed for a `(root, stream, index)` coordinate —
/// two rounds of splitmix64 finalization over golden-ratio-spaced inputs.
///
/// The derivation is *stateless*: the seed of `(root, s, i)` never depends
/// on which other coordinates were derived before it, which is what lets
/// the sweep orchestrator hand every grid cell and every trial its own
/// reproducible stream regardless of execution order or thread count
/// (sequentially reseeding one generator would make trial `k`'s draw
/// depend on how many trials preceded it).
pub fn stream_seed(root: u64, stream: u64, index: u64) -> u64 {
    let mut z = root ^ 0x9E37_79B9_7F4A_7C15u64.wrapping_mul(stream.wrapping_add(1));
    z = splitmix64(&mut z);
    let mut z = z ^ 0xD1B5_4A32_D192_ED03u64.wrapping_mul(index.wrapping_add(1));
    splitmix64(&mut z)
}

impl Xoshiro256 {
    /// Build from a 64-bit seed via splitmix64 expansion.
    pub fn seed_from(seed: u64) -> Self {
        let mut sm = seed;
        let mut s = [0u64; 4];
        for slot in &mut s {
            *slot = splitmix64(&mut sm);
        }
        // All-zero state is invalid for xoshiro; splitmix64 cannot produce
        // four zeros from any seed, but guard anyway.
        if s.iter().all(|&x| x == 0) {
            s[0] = 0xDEAD_BEEF_CAFE_F00D;
        }
        Xoshiro256 { s }
    }

    /// Derive an independent stream for a sub-component (e.g. per-user,
    /// per-trial) without correlating with the parent stream.
    pub fn fork(&mut self, tag: u64) -> Self {
        let mixed = self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        Xoshiro256::seed_from(mixed)
    }

    /// The jump function: advances 2^128 steps; used to create
    /// non-overlapping parallel streams.
    pub fn jump(&mut self) {
        const JUMP: [u64; 4] = [
            0x180ec6d33cfd0aba,
            0xd5a61266f0c9392c,
            0xa9582618e03fc9aa,
            0x39abdc4529b1661c,
        ];
        let mut s0 = 0u64;
        let mut s1 = 0u64;
        let mut s2 = 0u64;
        let mut s3 = 0u64;
        for j in JUMP {
            for b in 0..64 {
                if (j & (1u64 << b)) != 0 {
                    s0 ^= self.s[0];
                    s1 ^= self.s[1];
                    s2 ^= self.s[2];
                    s3 ^= self.s[3];
                }
                self.next_u64();
            }
        }
        self.s = [s0, s1, s2, s3];
    }
}

impl Rng for Xoshiro256 {
    #[inline]
    fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Xoshiro256::seed_from(42);
        let mut b = Xoshiro256::seed_from(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Xoshiro256::seed_from(1);
        let mut b = Xoshiro256::seed_from(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn fork_streams_are_uncorrelated_enough() {
        let mut parent = Xoshiro256::seed_from(7);
        let mut c1 = parent.fork(1);
        let mut c2 = parent.fork(2);
        let same = (0..64).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn jump_changes_state() {
        let mut a = Xoshiro256::seed_from(5);
        let mut b = a.clone();
        b.jump();
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn stream_seed_is_stateless_and_distinct() {
        // Stateless: the same coordinate always yields the same seed.
        assert_eq!(stream_seed(7, 3, 5), stream_seed(7, 3, 5));
        // Distinct across each coordinate axis.
        let mut seen = std::collections::HashSet::new();
        for root in 0..4u64 {
            for stream in 0..8u64 {
                for index in 0..8u64 {
                    assert!(
                        seen.insert(stream_seed(root, stream, index)),
                        "collision at ({root},{stream},{index})"
                    );
                }
            }
        }
    }

    #[test]
    fn stream_seed_neighbors_decorrelate() {
        // Adjacent trial indices must not produce correlated generators.
        let mut a = Xoshiro256::seed_from(stream_seed(1, 0, 0));
        let mut b = Xoshiro256::seed_from(stream_seed(1, 0, 1));
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn mean_of_uniform_is_half() {
        let mut rng = Xoshiro256::seed_from(123);
        let n = 100_000;
        let sum: f64 = (0..n).map(|_| rng.next_f64()).sum();
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }
}
