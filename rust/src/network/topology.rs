//! Edge-network topology: nodes, links, and latency-metric shortest paths.

use crate::config::{ExperimentConfig, NUM_RESOURCES};
use crate::rng::Rng;

/// Dense node index.
pub type NodeId = usize;

/// Node class (§II): resource-poor user-facing EDs vs resource-rich ESs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NodeClass {
    EdgeDevice,
    EdgeServer,
}

/// A network node with capacity vector `R_v`.
#[derive(Clone, Debug)]
pub struct Node {
    pub id: NodeId,
    pub class: NodeClass,
    pub capacity: [f64; NUM_RESOURCES],
}

/// An undirected communication link with bandwidth `w_(i1,i2)` (MB/ms) and
/// physical distance `W_(i1,i2)` (km).
#[derive(Clone, Debug)]
pub struct Link {
    pub a: NodeId,
    pub b: NodeId,
    pub bandwidth_mb_ms: f64,
    pub distance_km: f64,
}

/// Shortest-path tree from one source under the latency metric.
#[derive(Clone, Debug)]
pub struct ShortestPaths {
    pub src: NodeId,
    pub dist: Vec<f64>,
    prev: Vec<Option<NodeId>>,
}

impl ShortestPaths {
    /// Node sequence `src -> ... -> dst` (both inclusive).
    pub fn path_to(&self, dst: NodeId) -> Vec<NodeId> {
        let mut path = vec![dst];
        let mut cur = dst;
        while let Some(p) = self.prev[cur] {
            path.push(p);
            cur = p;
        }
        path.reverse();
        debug_assert_eq!(path[0], self.src);
        path
    }
}

/// The edge network.
#[derive(Clone, Debug)]
pub struct Topology {
    nodes: Vec<Node>,
    links: Vec<Link>,
    /// adjacency: node -> [(neighbor, link index)]
    adj: Vec<Vec<(NodeId, usize)>>,
    pub prop_speed_km_per_ms: f64,
}

impl Topology {
    /// Build from explicit parts (tests / custom scenarios).
    pub fn from_parts(nodes: Vec<Node>, links: Vec<Link>, prop_speed_km_per_ms: f64) -> Self {
        let mut adj = vec![Vec::new(); nodes.len()];
        for (i, l) in links.iter().enumerate() {
            adj[l.a].push((l.b, i));
            adj[l.b].push((l.a, i));
        }
        Topology {
            nodes,
            links,
            adj,
            prop_speed_km_per_ms,
        }
    }

    /// Generate the evaluation topology: ESs in a full mesh (backbone),
    /// each ED attached to a primary ES plus `ed_extra_links` extra ESs
    /// (fault-tolerant multihoming), per Fig. 2.
    pub fn generate<R: Rng + ?Sized>(cfg: &ExperimentConfig, rng: &mut R) -> Self {
        let n_ed = cfg.network.num_eds;
        let n_es = cfg.network.num_ess;
        let mut nodes = Vec::with_capacity(n_ed + n_es);
        for i in 0..n_ed {
            let mut capacity = [0.0; NUM_RESOURCES];
            for (k, r) in cfg.ed.resources.iter().enumerate() {
                capacity[k] = r.sample(rng);
            }
            nodes.push(Node {
                id: i,
                class: NodeClass::EdgeDevice,
                capacity,
            });
        }
        for j in 0..n_es {
            let mut capacity = [0.0; NUM_RESOURCES];
            for (k, r) in cfg.es.resources.iter().enumerate() {
                capacity[k] = r.sample(rng);
            }
            nodes.push(Node {
                id: n_ed + j,
                class: NodeClass::EdgeServer,
                capacity,
            });
        }

        let mut links = Vec::new();
        let sample_link = |a: NodeId, b: NodeId, rng: &mut R| Link {
            a,
            b,
            bandwidth_mb_ms: cfg.network.link_bandwidth.sample(rng),
            distance_km: cfg.network.link_distance_km.sample(rng),
        };
        // ES full mesh.
        for j1 in 0..n_es {
            for j2 in (j1 + 1)..n_es {
                links.push(sample_link(n_ed + j1, n_ed + j2, rng));
            }
        }
        // Each ED: primary ES (round-robin for coverage) + extra random ESs.
        for i in 0..n_ed {
            let primary = n_ed + (i % n_es);
            links.push(sample_link(i, primary, rng));
            let mut extras: Vec<usize> = (0..n_es)
                .map(|j| n_ed + j)
                .filter(|&e| e != primary)
                .collect();
            rng.shuffle(&mut extras);
            for &e in extras.iter().take(cfg.network.ed_extra_links) {
                links.push(sample_link(i, e, rng));
            }
        }
        Self::from_parts(nodes, links, cfg.network.prop_speed_km_per_ms)
    }

    pub fn num_nodes(&self) -> usize {
        self.nodes.len()
    }

    pub fn node(&self, id: NodeId) -> &Node {
        &self.nodes[id]
    }

    pub fn nodes(&self) -> &[Node] {
        &self.nodes
    }

    pub fn links(&self) -> &[Link] {
        &self.links
    }

    /// Edge devices (user-facing ingress nodes).
    pub fn eds(&self) -> impl Iterator<Item = NodeId> + '_ {
        self.nodes
            .iter()
            .filter(|n| n.class == NodeClass::EdgeDevice)
            .map(|n| n.id)
    }

    /// Edge servers.
    pub fn ess(&self) -> impl Iterator<Item = NodeId> + '_ {
        self.nodes
            .iter()
            .filter(|n| n.class == NodeClass::EdgeServer)
            .map(|n| n.id)
    }

    pub fn are_adjacent(&self, a: NodeId, b: NodeId) -> bool {
        self.adj[a].iter().any(|&(n, _)| n == b)
    }

    /// One-hop latency for a payload of `mb` megabytes over `link`:
    /// transmission `mb/w` plus propagation `W/l` — eq. (2).
    pub fn link_latency(&self, link: &Link, mb: f64) -> f64 {
        mb / link.bandwidth_mb_ms + link.distance_km / self.prop_speed_km_per_ms
    }

    /// Latency of moving `mb` from `a` to an adjacent `b`; `None` when not
    /// adjacent. Zero when `a == b` (co-located services).
    pub fn hop_latency(&self, a: NodeId, b: NodeId, mb: f64) -> Option<f64> {
        if a == b {
            return Some(0.0);
        }
        self.adj[a]
            .iter()
            .filter(|&&(n, _)| n == b)
            .map(|&(_, li)| self.link_latency(&self.links[li], mb))
            .fold(None, |acc: Option<f64>, lat| {
                Some(acc.map_or(lat, |a| a.min(lat)))
            })
    }

    /// Dijkstra under the latency metric for payload `mb`.
    pub fn shortest_paths(&self, src: NodeId, mb: f64) -> ShortestPaths {
        let n = self.num_nodes();
        let mut dist = vec![f64::INFINITY; n];
        let mut prev = vec![None; n];
        let mut visited = vec![false; n];
        dist[src] = 0.0;
        // O(n^2) Dijkstra: n <= a few hundred, dense-ish graphs.
        for _ in 0..n {
            let mut u = usize::MAX;
            let mut best = f64::INFINITY;
            for v in 0..n {
                if !visited[v] && dist[v] < best {
                    best = dist[v];
                    u = v;
                }
            }
            if u == usize::MAX {
                break;
            }
            visited[u] = true;
            for &(v, li) in &self.adj[u] {
                let w = self.link_latency(&self.links[li], mb);
                if dist[u] + w < dist[v] {
                    dist[v] = dist[u] + w;
                    prev[v] = Some(u);
                }
            }
        }
        ShortestPaths { src, dist, prev }
    }

    /// Multi-hop transfer latency along the metric-shortest route.
    pub fn route_latency(&self, a: NodeId, b: NodeId, mb: f64) -> f64 {
        if a == b {
            return 0.0;
        }
        self.shortest_paths(a, mb).dist[b]
    }

    /// Total capacity across nodes for resource `k` (used by validators).
    pub fn total_capacity(&self, k: usize) -> f64 {
        self.nodes.iter().map(|n| n.capacity[k]).sum()
    }
}
