//! Heterogeneous edge network model (§II): edge devices (EDs) and edge
//! servers (ESs) with per-resource capacities `R_v`, interconnected by
//! links with bandwidth `w` and distance `W`, plus the wireless uplink
//! channel (Nakagami fading) between users and their associated ED.

mod channel;
mod topology;

pub use channel::WirelessChannel;
pub use topology::{Link, NodeClass, NodeId, Topology};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;
    use crate::rng::Xoshiro256;

    fn topo(seed: u64) -> Topology {
        let cfg = ExperimentConfig::paper_default();
        let mut rng = Xoshiro256::seed_from(seed);
        Topology::generate(&cfg, &mut rng)
    }

    #[test]
    fn generated_topology_shape() {
        let t = topo(1);
        assert_eq!(t.num_nodes(), 16);
        assert_eq!(t.eds().count(), 12);
        assert_eq!(t.ess().count(), 4);
    }

    #[test]
    fn topology_is_connected() {
        for seed in 1..6 {
            let t = topo(seed);
            let dist = t.shortest_paths(0, 1.0);
            assert!(
                dist.dist.iter().all(|d| d.is_finite()),
                "seed {seed}: disconnected topology"
            );
        }
    }

    #[test]
    fn es_capacities_dominate_ed() {
        let t = topo(2);
        let max_ed_cpu = t
            .eds()
            .map(|n| t.node(n).capacity[0])
            .fold(0.0f64, f64::max);
        let min_es_cpu = t
            .ess()
            .map(|n| t.node(n).capacity[0])
            .fold(f64::INFINITY, f64::min);
        assert!(min_es_cpu > max_ed_cpu);
    }

    #[test]
    fn shortest_path_triangle_inequality_on_metric() {
        let t = topo(3);
        let mb = 1.0;
        for src in 0..t.num_nodes() {
            let d = t.shortest_paths(src, mb);
            for l in t.links() {
                let w = t.link_latency(l, mb);
                assert!(
                    d.dist[l.b] <= d.dist[l.a] + w + 1e-9,
                    "relaxed edge violates optimality"
                );
                assert!(d.dist[l.a] <= d.dist[l.b] + w + 1e-9);
            }
        }
    }

    #[test]
    fn path_reconstruction_reaches_source() {
        let t = topo(4);
        let d = t.shortest_paths(2, 1.0);
        for dst in 0..t.num_nodes() {
            let p = d.path_to(dst);
            assert_eq!(*p.first().unwrap(), 2);
            assert_eq!(*p.last().unwrap(), dst);
            // consecutive hops are adjacent
            for w in p.windows(2) {
                assert!(
                    t.are_adjacent(w[0], w[1]) || w[0] == w[1],
                    "hop {w:?} not adjacent"
                );
            }
        }
    }

    #[test]
    fn transfer_latency_scales_with_payload() {
        let t = topo(5);
        let l = &t.links()[0];
        let lat1 = t.link_latency(l, 1.0);
        let lat2 = t.link_latency(l, 2.0);
        assert!(lat2 > lat1);
        // propagation component is payload-independent
        let prop = l.distance_km / t.prop_speed_km_per_ms;
        assert!((lat2 - lat1 - 1.0 / l.bandwidth_mb_ms).abs() < 1e-9);
        assert!(lat1 > prop);
    }

    #[test]
    fn wireless_uplink_rate_positive_and_fading_varies() {
        let cfg = ExperimentConfig::paper_default();
        let mut rng = Xoshiro256::seed_from(6);
        let ch = WirelessChannel::sample(&cfg.workload, &mut rng);
        let mut rates = Vec::new();
        for _ in 0..100 {
            let r = ch.sample_uplink_rate(&mut rng);
            assert!(r > 0.0);
            rates.push(r);
        }
        let first = rates[0];
        assert!(rates.iter().any(|&r| (r - first).abs() > 1e-9));
    }

    #[test]
    fn uplink_delay_matches_eq1() {
        let cfg = ExperimentConfig::paper_default();
        let mut rng = Xoshiro256::seed_from(7);
        let ch = WirelessChannel::sample(&cfg.workload, &mut rng);
        let snr: f64 = 10.0;
        let rate = ch.rate_for_snr(snr);
        assert!((rate - ch.bandwidth_mb_ms * (1.0 + snr).log2()).abs() < 1e-12);
        let a_n = 2.0;
        assert!((ch.uplink_delay(a_n, snr) - a_n / rate).abs() < 1e-12);
    }

    #[test]
    fn mean_rate_estimate_converges() {
        let cfg = ExperimentConfig::paper_default();
        let mut rng = Xoshiro256::seed_from(8);
        let ch = WirelessChannel::sample(&cfg.workload, &mut rng);
        let est = ch.mean_uplink_rate(4000, &mut Xoshiro256::seed_from(9));
        let emp: f64 = (0..20_000)
            .map(|_| ch.sample_uplink_rate(&mut rng))
            .sum::<f64>()
            / 20_000.0;
        assert!(
            (est - emp).abs() / emp < 0.05,
            "estimate {est} vs empirical {emp}"
        );
    }
}
