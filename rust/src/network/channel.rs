//! Wireless uplink channel (eq. 1): `τ_ul = A_n / (b_u · log(1 + γ_u))`
//! with the SNR `γ_u` fading according to a Nakagami-m envelope (Table I).

use crate::config::WorkloadConfig;
use crate::rng::{Distribution, Nakagami, Rng};

/// Per-user channel parameters, sampled once per run.
#[derive(Clone, Copy, Debug)]
pub struct WirelessChannel {
    /// Allocated uplink bandwidth `b_u` (MB/ms at unit spectral efficiency).
    pub bandwidth_mb_ms: f64,
    /// Nakagami fading of the channel power.
    pub fading: Nakagami,
    /// Mean SNR (linear) scaling the fading power.
    pub mean_snr: f64,
}

impl WirelessChannel {
    /// Sample a user's channel from the workload config ranges.
    pub fn sample<R: Rng + ?Sized>(cfg: &WorkloadConfig, rng: &mut R) -> Self {
        WirelessChannel {
            bandwidth_mb_ms: cfg.uplink_bandwidth.sample(rng),
            fading: Nakagami::new(cfg.nakagami_m.sample(rng), cfg.nakagami_omega.sample(rng)),
            mean_snr: cfg.mean_snr.sample(rng),
        }
    }

    /// Instantaneous SNR `γ_u`: mean SNR scaled by Nakagami channel power.
    pub fn sample_snr<R: Rng + ?Sized>(&self, rng: &mut R) -> f64 {
        self.mean_snr * self.fading.sample(rng)
    }

    /// Achievable uplink rate for a given SNR: `b_u · log2(1 + γ)` (MB/ms).
    pub fn rate_for_snr(&self, snr: f64) -> f64 {
        self.bandwidth_mb_ms * (1.0 + snr).log2()
    }

    /// Draw an instantaneous uplink rate.
    pub fn sample_uplink_rate<R: Rng + ?Sized>(&self, rng: &mut R) -> f64 {
        self.rate_for_snr(self.sample_snr(rng))
    }

    /// Uplink delay (ms) for payload `A_n` (MB) at SNR `γ` — eq. (1).
    pub fn uplink_delay(&self, input_mb: f64, snr: f64) -> f64 {
        input_mb / self.rate_for_snr(snr)
    }

    /// Monte-Carlo mean uplink rate (for the mean-value latency profiles
    /// of §III-A).
    pub fn mean_uplink_rate<R: Rng + ?Sized>(&self, samples: usize, rng: &mut R) -> f64 {
        let sum: f64 = (0..samples)
            .map(|_| self.sample_uplink_rate(rng))
            .sum();
        sum / samples as f64
    }
}
