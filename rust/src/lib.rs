//! # fmedge — Modular Foundation-Model Inference at the Edge
//!
//! Production-quality reproduction of *"Modular Foundation Model Inference
//! at the Edge: Network-Aware Microservice Optimization"* (Zhu et al.,
//! HKUST, CS.DC 2026): a two-tier deployment framework for foundation
//! models decomposed into **core** (heavyweight, stateful) and **light**
//! (stateless, contention-prone) microservices on a heterogeneous edge
//! network.
//!
//! * **Static tier** — core microservices placed once per horizon by a
//!   sparsity-constrained integer program over a network-aware QoS score
//!   ([`placement`]).
//! * **Dynamic tier** — light microservices deployed every slot by a
//!   Lyapunov drift-plus-penalty controller whose latency bounds come from
//!   effective-capacity theory ([`controller`], [`effcap`]).
//! * **Ground truth** — a continuous-time discrete-event queueing
//!   simulator replays the same traces with real per-replica FIFO queues
//!   and validates the measured delay-violation rates against the
//!   analytic `g_{m,ε}(y)` bounds ([`des`]).
//!
//! The crate is the Layer-3 Rust coordinator of a three-layer stack: JAX
//! (Layer 2) and Pallas kernels (Layer 1) are compiled ahead of time to
//! HLO-text artifacts that [`runtime`] loads and executes through PJRT —
//! Python never runs on the request path.
//!
//! Substrates (PRNG, DAG, LP/MILP solver, config, CLI, property-test and
//! bench harnesses) are implemented in-tree; see `DESIGN.md` for the full
//! inventory and the experiment index.

// The determinism lint's `unsafe-forbid` rule ([`analysis`]) is backed by
// the compiler: replay invariants are audited on safe code only.
#![forbid(unsafe_code)]

pub mod analysis;
pub mod benchkit;
pub mod graph;
pub mod ilp;
pub mod lp;
pub mod rng;
pub mod testkit;

pub mod config;
pub mod effcap;
pub mod latency;
pub mod metrics;
pub mod microservice;
pub mod network;
pub mod workload;

pub mod baselines;
pub mod controller;
pub mod des;
pub mod faults;
pub mod placement;
pub mod pool;
pub mod routing;
pub mod sim;

pub mod exp;
pub mod obs;
pub mod scenarios;

pub mod coordinator;
pub mod runtime;

pub mod cli;

/// Crate version string, reported by the CLI.
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
