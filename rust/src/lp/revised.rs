//! Bounded-variable revised simplex with warm starts.
//!
//! The engine keeps the constraint matrix in sparse column form and works
//! on the computational standard form `A x + s = b`, one slack column per
//! row (`<=`: `s in [0, inf)`, `>=`: `s in (-inf, 0]`, `=`: `s` fixed at
//! zero). Variable bounds `l <= x <= u` are handled *natively* by the
//! ratio tests — nonbasic variables rest at one of their bounds and may
//! "bound-flip" without a basis change — so tightening a bound (the
//! branch-and-bound case) never adds a row.
//!
//! Three solve paths:
//!
//! * **Cold** ([`RevisedSimplex::solve_cold`]) — slack basis, phase-1
//!   artificials on rows whose residual the slack cannot absorb, then
//!   phase 2 with the true costs. Dantzig pricing with a Bland fallback
//!   after a run of degenerate pivots (anti-cycling).
//! * **Warm** ([`RevisedSimplex::solve_warm`]) — restore a parent
//!   [`WarmBasis`], refactorize `B^{-1}`, and run the *dual* simplex:
//!   after a bound tightening the parent basis stays dual-feasible, so a
//!   handful of dual pivots restore primal feasibility. A primal cleanup
//!   loop then certifies optimality (it is a no-op in the common case).
//! * Bound edits ([`RevisedSimplex::reset_bounds`] /
//!   [`RevisedSimplex::tighten_var_bounds`]) — per-node deltas applied on
//!   top of the root bounds; the matrix and its factorization are reused
//!   across the whole branch-and-bound tree.
//!
//! `B^{-1}` is kept explicitly (dense, row-major) and updated by
//! product-form pivots with a periodic full refactorization — the paper's
//! placement LPs have at most a few hundred rows, where an explicit
//! inverse is both simple and fast.

use super::simplex::{LinProg, LpError, LpSolution, LpStatus, Relation};

const FEAS_TOL: f64 = 1e-7;
const DUAL_TOL: f64 = 1e-7;
const PIV_TOL: f64 = 1e-8;
const REFACTOR_EVERY: usize = 64;
/// Consecutive (near-)degenerate pivots before switching to Bland's rule.
const DEGEN_SWITCH: usize = 100;

/// Opaque snapshot of an optimal basis: the basic column of every row plus
/// the bound each nonbasic column rests at. Cheap to clone; stored on
/// branch-and-bound nodes to warm-start children.
#[derive(Clone, Debug)]
pub struct WarmBasis {
    pub(super) basis: Vec<usize>,
    pub(super) at_upper: Vec<bool>,
}

/// Iteration counters, aggregated across all solves on one engine.
#[derive(Clone, Copy, Debug, Default)]
pub struct RevisedStats {
    pub primal_iters: usize,
    pub dual_iters: usize,
    pub refactorizations: usize,
}

/// Reusable bounded-variable revised simplex over one constraint matrix.
pub struct RevisedSimplex {
    m: usize,
    nstruct: usize,
    /// Total columns: structural, then `m` slacks, then `m` artificials.
    ncols: usize,
    art_start: usize,
    /// Sparse columns of `[A | I | I_art]` (artificial signs set per cold
    /// solve).
    cols: Vec<Vec<(usize, f64)>>,
    b: Vec<f64>,
    /// Phase-2 costs (structural = objective, slack/artificial = 0).
    cost: Vec<f64>,
    lower: Vec<f64>,
    upper: Vec<f64>,
    /// Root bounds, restored by [`Self::reset_bounds`]. Artificial columns
    /// are fixed `[0, 0]` here; cold solves re-open them transiently.
    root_lower: Vec<f64>,
    root_upper: Vec<f64>,
    // ---- working state -------------------------------------------------
    basis: Vec<usize>,
    in_basis: Vec<bool>,
    at_upper: Vec<bool>,
    /// Explicit `B^{-1}`, row-major `m x m`.
    binv: Vec<f64>,
    /// Values of the basic variables, `xb[r]` belongs to `basis[r]`.
    xb: Vec<f64>,
    pivots_since_refactor: usize,
    stats: RevisedStats,
}

impl RevisedSimplex {
    /// Build the engine from a model. Fails on out-of-range variable
    /// references; requires at least one structural variable.
    pub fn new(lp: &LinProg) -> Result<Self, LpError> {
        let n = lp.nvars;
        let m = lp.rows.len();
        for row in &lp.rows {
            for &(v, _) in &row.coeffs {
                if v >= n {
                    return Err(LpError::VarOutOfRange { var: v, nvars: n });
                }
            }
        }
        let art_start = n + m;
        let ncols = n + 2 * m;

        let mut cols: Vec<Vec<(usize, f64)>> = vec![Vec::new(); ncols];
        let mut b = vec![0.0; m];
        let mut lower = vec![0.0; ncols];
        let mut upper = vec![f64::INFINITY; ncols];
        for (j, (&lo, up)) in lp.lower.iter().zip(lp.upper.iter()).enumerate() {
            lower[j] = lo;
            upper[j] = up.unwrap_or(f64::INFINITY);
        }
        for (r, row) in lp.rows.iter().enumerate() {
            b[r] = row.rhs;
            for &(v, c) in &row.coeffs {
                // Merge duplicate (row, var) coefficients: entries for the
                // same row are pushed consecutively into the column.
                if let Some(last) = cols[v].last_mut() {
                    if last.0 == r {
                        last.1 += c;
                        continue;
                    }
                }
                cols[v].push((r, c));
            }
            let s = n + r;
            cols[s].push((r, 1.0));
            let (slo, sup) = match row.rel {
                Relation::Le => (0.0, f64::INFINITY),
                Relation::Ge => (f64::NEG_INFINITY, 0.0),
                Relation::Eq => (0.0, 0.0),
            };
            lower[s] = slo;
            upper[s] = sup;
            // Artificial: entry sign assigned at cold-solve time; fixed at
            // zero until then.
            lower[art_start + r] = 0.0;
            upper[art_start + r] = 0.0;
        }

        let mut cost = vec![0.0; ncols];
        cost[..n].copy_from_slice(&lp.objective);

        Ok(RevisedSimplex {
            m,
            nstruct: n,
            ncols,
            art_start,
            cols,
            b,
            cost,
            root_lower: lower.clone(),
            root_upper: upper.clone(),
            lower,
            upper,
            basis: vec![0; m],
            in_basis: vec![false; ncols],
            at_upper: vec![false; ncols],
            binv: vec![0.0; m * m],
            xb: vec![0.0; m],
            pivots_since_refactor: 0,
            stats: RevisedStats::default(),
        })
    }

    /// Restore all variable bounds to the root model's.
    pub fn reset_bounds(&mut self) {
        self.lower.copy_from_slice(&self.root_lower);
        self.upper.copy_from_slice(&self.root_upper);
    }

    /// Intersect the bounds of structural variable `var` with `[lo, hi]`.
    pub fn tighten_var_bounds(&mut self, var: usize, lo: f64, hi: f64) {
        debug_assert!(var < self.nstruct);
        if lo > self.lower[var] {
            self.lower[var] = lo;
        }
        if hi < self.upper[var] {
            self.upper[var] = hi;
        }
    }

    /// Aggregate iteration counters.
    pub fn stats(&self) -> RevisedStats {
        self.stats
    }

    // ------------------------------------------------------------ values --

    /// Rest value of a nonbasic column under the current bounds.
    fn nonbasic_value(&self, j: usize) -> f64 {
        let (lo, up) = (self.lower[j], self.upper[j]);
        if lo == up {
            return lo;
        }
        if self.at_upper[j] {
            if up.is_finite() {
                up
            } else if lo.is_finite() {
                lo
            } else {
                0.0
            }
        } else if lo.is_finite() {
            lo
        } else if up.is_finite() {
            up
        } else {
            0.0
        }
    }

    /// Make a nonbasic column's bound status consistent with its bounds
    /// (used when warm bounds differ from the ones the status was saved
    /// under).
    fn normalize_status(&mut self, j: usize) {
        if self.lower[j] == self.upper[j] {
            self.at_upper[j] = false;
            return;
        }
        if self.at_upper[j] && !self.upper[j].is_finite() {
            self.at_upper[j] = false;
        }
        if !self.at_upper[j] && !self.lower[j].is_finite() && self.upper[j].is_finite() {
            self.at_upper[j] = true;
        }
    }

    // ---------------------------------------------------- linear algebra --

    /// `y = c_B^T B^{-1}` (simplex duals for the given cost vector).
    fn duals(&self, cost: &[f64], y: &mut [f64]) {
        for v in y.iter_mut() {
            *v = 0.0;
        }
        for r in 0..self.m {
            let cb = cost[self.basis[r]];
            if cb != 0.0 {
                let row = &self.binv[r * self.m..(r + 1) * self.m];
                for (yi, &bi) in y.iter_mut().zip(row) {
                    *yi += cb * bi;
                }
            }
        }
    }

    fn reduced_cost(&self, cost: &[f64], y: &[f64], j: usize) -> f64 {
        let mut d = cost[j];
        for &(i, a) in &self.cols[j] {
            d -= y[i] * a;
        }
        d
    }

    /// `w = B^{-1} A_j`.
    fn ftran(&self, j: usize, w: &mut [f64]) {
        for v in w.iter_mut() {
            *v = 0.0;
        }
        for &(i, a) in &self.cols[j] {
            if a == 0.0 {
                continue;
            }
            for r in 0..self.m {
                w[r] += self.binv[r * self.m + i] * a;
            }
        }
    }

    /// Product-form update of `B^{-1}` after `basis[r]` is replaced by the
    /// column whose basis representation is `w` (so `w[r]` is the pivot).
    fn update_binv(&mut self, r: usize, w: &[f64]) {
        let m = self.m;
        let inv = 1.0 / w[r];
        let mut prow = vec![0.0; m];
        for k in 0..m {
            prow[k] = self.binv[r * m + k] * inv;
        }
        for i in 0..m {
            let f = if i == r { 0.0 } else { w[i] };
            if f.abs() > 1e-13 {
                for k in 0..m {
                    self.binv[i * m + k] -= f * prow[k];
                }
            }
        }
        self.binv[r * m..(r + 1) * m].copy_from_slice(&prow);
        self.pivots_since_refactor += 1;
    }

    /// Rebuild `B^{-1}` from scratch (Gauss-Jordan with partial pivoting).
    fn refactorize(&mut self) -> Result<(), LpError> {
        let m = self.m;
        if m == 0 {
            return Ok(());
        }
        // aug = [B | I], row-major with width 2m.
        let w = 2 * m;
        let mut aug = vec![0.0; m * w];
        for (c, &bj) in self.basis.iter().enumerate() {
            for &(i, a) in &self.cols[bj] {
                aug[i * w + c] = a;
            }
        }
        for r in 0..m {
            aug[r * w + m + r] = 1.0;
        }
        for c in 0..m {
            // Partial pivot.
            let mut p = c;
            let mut best = aug[c * w + c].abs();
            for r in c + 1..m {
                let v = aug[r * w + c].abs();
                if v > best {
                    best = v;
                    p = r;
                }
            }
            if best < 1e-11 {
                return Err(LpError::SingularBasis);
            }
            if p != c {
                for k in 0..w {
                    aug.swap(c * w + k, p * w + k);
                }
            }
            let inv = 1.0 / aug[c * w + c];
            for k in 0..w {
                aug[c * w + k] *= inv;
            }
            for r in 0..m {
                if r == c {
                    continue;
                }
                let f = aug[r * w + c];
                if f.abs() > 1e-13 {
                    for k in 0..w {
                        aug[r * w + k] -= f * aug[c * w + k];
                    }
                }
            }
        }
        for r in 0..m {
            self.binv[r * m..(r + 1) * m].copy_from_slice(&aug[r * w + m..r * w + 2 * m]);
        }
        self.pivots_since_refactor = 0;
        self.stats.refactorizations += 1;
        Ok(())
    }

    /// `xb = B^{-1} (b - N x_N)` from the current nonbasic rest values.
    fn compute_xb(&mut self) {
        let m = self.m;
        let mut rhs = self.b.clone();
        for j in 0..self.ncols {
            if self.in_basis[j] {
                continue;
            }
            let v = self.nonbasic_value(j);
            if v != 0.0 {
                for &(i, a) in &self.cols[j] {
                    rhs[i] -= a * v;
                }
            }
        }
        for r in 0..m {
            let row = &self.binv[r * m..(r + 1) * m];
            self.xb[r] = row.iter().zip(&rhs).map(|(&bi, &ri)| bi * ri).sum();
        }
    }

    fn maybe_refactor(&mut self) -> Result<(), LpError> {
        if self.pivots_since_refactor >= REFACTOR_EVERY {
            self.refactorize()?;
            self.compute_xb();
        }
        Ok(())
    }

    // -------------------------------------------------------- primal loop --

    /// Primal bounded simplex under `cost`, from a primal-feasible basis.
    /// When `fix_leaving_artificials` is set (phase 1), any artificial that
    /// leaves the basis is fixed at zero so it can never re-enter.
    fn primal_loop(
        &mut self,
        cost: &[f64],
        fix_leaving_artificials: bool,
    ) -> Result<LpStatus, LpError> {
        let m = self.m;
        let max_iter = 1000 + 100 * (m + self.ncols);
        let mut y = vec![0.0; m];
        let mut w = vec![0.0; m];
        let mut bland = false;
        let mut degen_streak = 0usize;

        for _ in 0..max_iter {
            self.duals(cost, &mut y);

            // Pricing: nonbasic at lower may increase (d < 0 improves), at
            // upper may decrease (d > 0 improves). Fixed columns never move.
            let mut entering: Option<(usize, f64)> = None; // (col, |d|)
            for j in 0..self.ncols {
                if self.in_basis[j] || self.lower[j] == self.upper[j] {
                    continue;
                }
                let d = self.reduced_cost(cost, &y, j);
                let eligible = if self.at_upper[j] {
                    d > DUAL_TOL
                } else {
                    d < -DUAL_TOL
                };
                if !eligible {
                    continue;
                }
                if bland {
                    entering = Some((j, d.abs()));
                    break; // smallest index
                }
                match entering {
                    Some((_, best)) if d.abs() <= best => {}
                    _ => entering = Some((j, d.abs())),
                }
            }
            let Some((j, _)) = entering else {
                return Ok(LpStatus::Optimal);
            };
            self.stats.primal_iters += 1;

            let dir = if self.at_upper[j] { -1.0 } else { 1.0 };
            self.ftran(j, &mut w);

            // Bounded ratio test: the entering step is limited by its own
            // bound range (flip) and by every basic variable hitting one of
            // its bounds.
            let mut t_best = self.upper[j] - self.lower[j]; // may be +inf
            let mut leaving: Option<(usize, bool, f64)> = None; // (row, at_upper, |delta|)
            for r in 0..m {
                let delta = -w[r] * dir; // d xb[r] / d t
                let bv = self.basis[r];
                let (t_r, hits_upper) = if delta > PIV_TOL {
                    let room = self.upper[bv] - self.xb[r];
                    if !room.is_finite() {
                        continue;
                    }
                    ((room / delta).max(0.0), true)
                } else if delta < -PIV_TOL {
                    let room = self.xb[r] - self.lower[bv];
                    if !room.is_finite() {
                        continue;
                    }
                    ((room / -delta).max(0.0), false)
                } else {
                    continue;
                };
                // Monotone: never accept a larger step; among (near-)ties
                // prefer the larger pivot magnitude for stability.
                let take = match leaving {
                    None => t_r < t_best - 1e-12,
                    Some((_, _, best_mag)) => {
                        t_r < t_best - 1e-10 || (t_r <= t_best && delta.abs() > best_mag)
                    }
                };
                if take {
                    t_best = t_r.min(t_best);
                    leaving = Some((r, hits_upper, delta.abs()));
                }
            }

            if !t_best.is_finite() {
                return Ok(LpStatus::Unbounded);
            }
            if t_best <= 1e-10 {
                degen_streak += 1;
                if degen_streak > DEGEN_SWITCH {
                    bland = true;
                }
            } else {
                degen_streak = 0;
                bland = false;
            }

            match leaving {
                None => {
                    // Bound flip: no basis change.
                    for r in 0..m {
                        self.xb[r] -= w[r] * dir * t_best;
                    }
                    self.at_upper[j] = !self.at_upper[j];
                }
                Some((r, hits_upper, _)) => {
                    let enter_val = self.nonbasic_value(j) + dir * t_best;
                    for i in 0..m {
                        self.xb[i] -= w[i] * dir * t_best;
                    }
                    let lv = self.basis[r];
                    self.basis[r] = j;
                    self.in_basis[j] = true;
                    self.in_basis[lv] = false;
                    self.at_upper[lv] = hits_upper;
                    self.xb[r] = enter_val;
                    self.update_binv(r, &w);
                    if fix_leaving_artificials && lv >= self.art_start {
                        self.lower[lv] = 0.0;
                        self.upper[lv] = 0.0;
                        self.at_upper[lv] = false;
                    }
                    self.maybe_refactor()?;
                }
            }
        }
        Err(LpError::IterationLimit)
    }

    // ---------------------------------------------------------- dual loop --

    /// Dual bounded simplex under the phase-2 costs, from a dual-feasible
    /// basis. Returns `Ok(true)` when primal feasibility is restored and
    /// `Ok(false)` on a primal-infeasibility certificate (a row whose basic
    /// variable cannot be brought inside its bounds by any admissible
    /// column — independent of the costs, so always sound).
    fn dual_loop(&mut self) -> Result<bool, LpError> {
        let m = self.m;
        let max_iter = 1000 + 100 * (m + self.ncols);
        let cost = self.cost.clone();
        let mut y = vec![0.0; m];
        let mut w = vec![0.0; m];
        let mut bland = false;
        let mut degen_streak = 0usize;

        for _ in 0..max_iter {
            // Leaving row: most violated basic variable.
            let mut leave: Option<(usize, bool)> = None; // (row, below_lower)
            let mut worst = 0.0;
            for r in 0..m {
                let bv = self.basis[r];
                let v = self.xb[r];
                let tol = FEAS_TOL * (1.0 + v.abs());
                if v < self.lower[bv] - tol {
                    let viol = self.lower[bv] - v;
                    if viol > worst {
                        worst = viol;
                        leave = Some((r, true));
                    }
                } else if v > self.upper[bv] + tol {
                    let viol = v - self.upper[bv];
                    if viol > worst {
                        worst = viol;
                        leave = Some((r, false));
                    }
                }
            }
            let Some((r, below)) = leave else {
                return Ok(true);
            };
            self.stats.dual_iters += 1;

            self.duals(&cost, &mut y);
            let rho = self.binv[r * m..(r + 1) * m].to_vec();

            // Dual ratio test: pick the admissible entering column with the
            // smallest |d_j / alpha_j| (preserves dual feasibility).
            let mut best: Option<(usize, f64, f64)> = None; // (col, ratio, |alpha|)
            for j in 0..self.ncols {
                if self.in_basis[j] || self.lower[j] == self.upper[j] {
                    continue;
                }
                let mut alpha = 0.0;
                for &(i, a) in &self.cols[j] {
                    alpha += rho[i] * a;
                }
                if alpha.abs() <= PIV_TOL {
                    continue;
                }
                let at_up = self.at_upper[j];
                let admissible = if below {
                    (!at_up && alpha < 0.0) || (at_up && alpha > 0.0)
                } else {
                    (!at_up && alpha > 0.0) || (at_up && alpha < 0.0)
                };
                if !admissible {
                    continue;
                }
                let d = self.reduced_cost(&cost, &y, j);
                let num = if at_up { (-d).max(0.0) } else { d.max(0.0) };
                let ratio = num / alpha.abs();
                let take = match best {
                    None => true,
                    Some(_) if bland => false, // first (smallest) index wins
                    Some((_, br, ba)) => {
                        ratio < br - 1e-9 || (ratio < br + 1e-9 && alpha.abs() > ba)
                    }
                };
                if take {
                    best = Some((j, ratio, alpha.abs()));
                }
            }
            let Some((j, _, _)) = best else {
                return Ok(false);
            };

            self.ftran(j, &mut w);
            let piv = w[r];
            if piv.abs() <= PIV_TOL * 0.5 {
                // Factorization drift: rebuild and retry the iteration.
                self.refactorize()?;
                self.compute_xb();
                continue;
            }
            let lv = self.basis[r];
            let target = if below {
                self.lower[lv]
            } else {
                self.upper[lv]
            };
            let dx_j = (self.xb[r] - target) / piv;
            if dx_j.abs() <= 1e-10 {
                degen_streak += 1;
                if degen_streak > DEGEN_SWITCH {
                    bland = true;
                }
            } else {
                degen_streak = 0;
                bland = false;
            }

            let enter_val = self.nonbasic_value(j) + dx_j;
            for i in 0..m {
                self.xb[i] -= w[i] * dx_j;
            }
            self.basis[r] = j;
            self.in_basis[j] = true;
            self.in_basis[lv] = false;
            // The leaving variable exits at the bound it violated.
            self.at_upper[lv] = !below;
            self.normalize_status(lv);
            self.xb[r] = enter_val;
            self.update_binv(r, &w);
            self.maybe_refactor()?;
        }
        Err(LpError::IterationLimit)
    }

    // -------------------------------------------------------------- solves --

    fn bounds_consistent(&self) -> bool {
        (0..self.ncols).all(|j| self.lower[j] <= self.upper[j] + FEAS_TOL)
    }

    fn infeasible_solution(&self) -> LpSolution {
        LpSolution {
            status: LpStatus::Infeasible,
            x: vec![0.0; self.nstruct],
            objective: 0.0,
            basis: None,
        }
    }

    fn extract(&self, status: LpStatus) -> LpSolution {
        let mut x = vec![0.0; self.nstruct];
        for (j, xj) in x.iter_mut().enumerate() {
            if !self.in_basis[j] {
                *xj = self.nonbasic_value(j);
            }
        }
        for r in 0..self.m {
            if self.basis[r] < self.nstruct {
                x[self.basis[r]] = self.xb[r];
            }
        }
        let objective = x
            .iter()
            .zip(&self.cost[..self.nstruct])
            .map(|(xi, ci)| xi * ci)
            .sum();
        let basis = if status == LpStatus::Optimal {
            Some(WarmBasis {
                basis: self.basis.clone(),
                at_upper: self.at_upper.clone(),
            })
        } else {
            None
        };
        LpSolution {
            status,
            x,
            objective,
            basis,
        }
    }

    /// Two-phase cold solve from the slack basis.
    pub fn solve_cold(&mut self) -> Result<LpSolution, LpError> {
        if !self.bounds_consistent() {
            return Ok(self.infeasible_solution());
        }
        let m = self.m;
        let n = self.nstruct;

        // Close any artificials left open by a previous aborted solve and
        // reset the nonbasic rest state.
        for a in self.art_start..self.ncols {
            self.lower[a] = 0.0;
            self.upper[a] = 0.0;
        }
        for j in 0..self.ncols {
            self.in_basis[j] = false;
            self.at_upper[j] = false;
            self.normalize_status(j);
        }
        // Slacks of `>=` rows rest at their upper bound (zero).
        // (normalize_status already moved -inf-lower columns to upper.)

        // Residuals with every column nonbasic.
        let mut r_vec = self.b.clone();
        for j in 0..n {
            let v = self.nonbasic_value(j);
            if v != 0.0 {
                for &(i, a) in &self.cols[j] {
                    r_vec[i] -= a * v;
                }
            }
        }

        // Initial basis: the slack where it can absorb the residual, else
        // an artificial carrying |residual|.
        let mut any_artificial = false;
        let mut phase1_cost = vec![0.0; self.ncols];
        for i in 0..m {
            let s = n + i;
            let ri = r_vec[i];
            let tol = FEAS_TOL * (1.0 + ri.abs());
            if ri >= self.lower[s] - tol && ri <= self.upper[s] + tol {
                self.basis[i] = s;
                self.in_basis[s] = true;
                self.xb[i] = ri;
            } else {
                let a = self.art_start + i;
                let sign = if ri >= 0.0 { 1.0 } else { -1.0 };
                self.cols[a] = vec![(i, sign)];
                self.lower[a] = 0.0;
                self.upper[a] = f64::INFINITY;
                self.basis[i] = a;
                self.in_basis[a] = true;
                self.xb[i] = ri.abs();
                phase1_cost[a] = 1.0;
                any_artificial = true;
            }
        }
        // Diagonal B^{-1}: +1 for slacks, the artificial's sign otherwise.
        for v in self.binv.iter_mut() {
            *v = 0.0;
        }
        for i in 0..m {
            let bj = self.basis[i];
            let diag = if bj >= self.art_start {
                self.cols[bj][0].1
            } else {
                1.0
            };
            self.binv[i * m + i] = diag;
        }
        self.pivots_since_refactor = 0;

        if any_artificial {
            let status = self.primal_loop(&phase1_cost, true)?;
            debug_assert!(
                status != LpStatus::Unbounded,
                "phase-1 objective is bounded below"
            );
            let mut infeas = 0.0;
            for r in 0..m {
                if phase1_cost[self.basis[r]] != 0.0 {
                    infeas += self.xb[r].max(0.0);
                }
            }
            let bscale = self.b.iter().fold(0.0f64, |acc, &v| acc.max(v.abs()));
            if infeas > 1e-7 * (1.0 + bscale) {
                return Ok(self.infeasible_solution());
            }
            // Phase 2 must not touch the artificials again.
            for a in self.art_start..self.ncols {
                self.lower[a] = 0.0;
                self.upper[a] = 0.0;
                if !self.in_basis[a] {
                    self.at_upper[a] = false;
                }
            }
        }

        let cost = self.cost.clone();
        let status = self.primal_loop(&cost, false)?;
        Ok(self.extract(status))
    }

    /// Warm re-solve from a saved basis after bound edits: dual simplex to
    /// restore primal feasibility, then a primal cleanup pass.
    pub fn solve_warm(&mut self, warm: &WarmBasis) -> Result<LpSolution, LpError> {
        if warm.basis.len() != self.m || warm.at_upper.len() != self.ncols {
            return Err(LpError::SingularBasis);
        }
        if !self.bounds_consistent() {
            return Ok(self.infeasible_solution());
        }
        for f in self.in_basis.iter_mut() {
            *f = false;
        }
        for (r, &bj) in warm.basis.iter().enumerate() {
            if bj >= self.ncols || self.in_basis[bj] {
                return Err(LpError::SingularBasis);
            }
            self.basis[r] = bj;
            self.in_basis[bj] = true;
        }
        self.at_upper.copy_from_slice(&warm.at_upper);
        for j in 0..self.ncols {
            if !self.in_basis[j] {
                self.normalize_status(j);
            }
        }
        self.refactorize()?;
        self.compute_xb();

        if !self.dual_loop()? {
            return Ok(self.infeasible_solution());
        }
        // Dual feasibility was maintained, so this is usually a no-op; it
        // also certifies optimality after numerical drift.
        let cost = self.cost.clone();
        let status = self.primal_loop(&cost, false)?;
        Ok(self.extract(status))
    }
}
