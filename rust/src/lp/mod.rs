//! Linear-programming substrate.
//!
//! The paper solves the static core-placement problem (14) with
//! "off-the-shelf tools"; nothing off-the-shelf is available offline, so
//! this module provides the LP relaxation engine underneath the in-tree
//! branch-and-bound MILP solver (`crate::ilp`).
//!
//! Two interchangeable backends implement [`LpBackend`]:
//!
//! * [`RevisedBackend`] (default, used by [`LinProg::solve`]) — a
//!   bounded-variable **revised simplex** ([`revised`]): variable bounds
//!   are handled natively by the ratio tests (no synthetic `x <= u` rows)
//!   and an optimal [`WarmBasis`] is returned for warm restarts; after a
//!   bound tightening a **dual simplex** pass re-optimizes in a handful of
//!   pivots. This is what makes the branch-and-bound incremental.
//! * [`DenseBackend`] ([`LinProg::solve_dense`]) — the original dense
//!   two-phase tableau, kept as an independent reference implementation;
//!   `tests/properties.rs` cross-checks the two on random LPs.

mod revised;
mod simplex;

pub use revised::{RevisedSimplex, RevisedStats, WarmBasis};
pub use simplex::{LinProg, LpError, LpSolution, LpStatus, Relation};

/// A pluggable LP solver backend over the shared [`LinProg`] model.
pub trait LpBackend {
    fn solve(&self, lp: &LinProg) -> Result<LpSolution, LpError>;
}

/// The dense two-phase tableau simplex (reference implementation).
#[derive(Clone, Copy, Debug, Default)]
pub struct DenseBackend;

impl LpBackend for DenseBackend {
    fn solve(&self, lp: &LinProg) -> Result<LpSolution, LpError> {
        lp.solve_dense()
    }
}

/// The bounded-variable revised simplex (default).
#[derive(Clone, Copy, Debug, Default)]
pub struct RevisedBackend;

impl LpBackend for RevisedBackend {
    fn solve(&self, lp: &LinProg) -> Result<LpSolution, LpError> {
        lp.solve()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn both_backends(lp: &LinProg) -> (LpSolution, LpSolution) {
        let fast = lp.solve().expect("revised solve");
        let dense = lp.solve_dense().expect("dense solve");
        assert_eq!(fast.status, dense.status, "backend status mismatch");
        if fast.status == LpStatus::Optimal {
            assert!(
                (fast.objective - dense.objective).abs()
                    <= 1e-6 * (1.0 + dense.objective.abs()),
                "objective mismatch: revised={} dense={}",
                fast.objective,
                dense.objective
            );
        }
        (fast, dense)
    }

    #[test]
    fn simple_max_problem() {
        // max 3x + 5y s.t. x <= 4, 2y <= 12, 3x + 2y <= 18  -> (2, 6), obj 36
        let mut lp = LinProg::minimize(2);
        lp.set_objective(&[-3.0, -5.0]);
        lp.add_constraint(&[(0, 1.0)], Relation::Le, 4.0);
        lp.add_constraint(&[(1, 2.0)], Relation::Le, 12.0);
        lp.add_constraint(&[(0, 3.0), (1, 2.0)], Relation::Le, 18.0);
        let (sol, _) = both_backends(&lp);
        assert_eq!(sol.status, LpStatus::Optimal);
        assert!((sol.objective + 36.0).abs() < 1e-7);
        assert!((sol.x[0] - 2.0).abs() < 1e-7);
        assert!((sol.x[1] - 6.0).abs() < 1e-7);
    }

    #[test]
    fn equality_and_ge_constraints() {
        // min x + y s.t. x + y = 10, x >= 3, y >= 2 -> obj 10
        let mut lp = LinProg::minimize(2);
        lp.set_objective(&[1.0, 1.0]);
        lp.add_constraint(&[(0, 1.0), (1, 1.0)], Relation::Eq, 10.0);
        lp.add_constraint(&[(0, 1.0)], Relation::Ge, 3.0);
        lp.add_constraint(&[(1, 1.0)], Relation::Ge, 2.0);
        let (sol, _) = both_backends(&lp);
        assert_eq!(sol.status, LpStatus::Optimal);
        assert!((sol.objective - 10.0).abs() < 1e-7);
        assert!((sol.x[0] + sol.x[1] - 10.0).abs() < 1e-7);
    }

    #[test]
    fn infeasible_detected() {
        let mut lp = LinProg::minimize(1);
        lp.set_objective(&[1.0]);
        lp.add_constraint(&[(0, 1.0)], Relation::Le, 1.0);
        lp.add_constraint(&[(0, 1.0)], Relation::Ge, 2.0);
        let (sol, _) = both_backends(&lp);
        assert_eq!(sol.status, LpStatus::Infeasible);
    }

    #[test]
    fn unbounded_detected() {
        // min -x with x >= 0 and no upper bound.
        let mut lp = LinProg::minimize(1);
        lp.set_objective(&[-1.0]);
        lp.add_constraint(&[(0, 1.0)], Relation::Ge, 0.0);
        let (sol, _) = both_backends(&lp);
        assert_eq!(sol.status, LpStatus::Unbounded);
    }

    #[test]
    fn upper_bounds_respected() {
        // min -x - y with x <= 2.5, y <= 1.5 via variable bounds.
        let mut lp = LinProg::minimize(2);
        lp.set_objective(&[-1.0, -1.0]);
        lp.set_upper_bound(0, 2.5);
        lp.set_upper_bound(1, 1.5);
        let (sol, _) = both_backends(&lp);
        assert_eq!(sol.status, LpStatus::Optimal);
        assert!((sol.x[0] - 2.5).abs() < 1e-7);
        assert!((sol.x[1] - 1.5).abs() < 1e-7);
    }

    #[test]
    fn lower_bounds_respected() {
        // min x + 2y with x >= 1.5, y >= 0.5, x + y >= 3 -> (2.5, 0.5).
        let mut lp = LinProg::minimize(2);
        lp.set_objective(&[1.0, 2.0]);
        lp.set_lower_bound(0, 1.5);
        lp.set_lower_bound(1, 0.5);
        lp.add_constraint(&[(0, 1.0), (1, 1.0)], Relation::Ge, 3.0);
        let (sol, _) = both_backends(&lp);
        assert_eq!(sol.status, LpStatus::Optimal);
        assert!((sol.objective - 3.5).abs() < 1e-7, "obj={}", sol.objective);
        assert!(sol.x[0] >= 1.5 - 1e-7 && sol.x[1] >= 0.5 - 1e-7);
    }

    #[test]
    fn crossed_bounds_are_infeasible() {
        let mut lp = LinProg::minimize(1);
        lp.set_objective(&[1.0]);
        lp.set_lower_bound(0, 2.0);
        lp.set_upper_bound(0, 1.0);
        let sol = lp.solve().unwrap();
        assert_eq!(sol.status, LpStatus::Infeasible);
        let dense = lp.solve_dense().unwrap();
        assert_eq!(dense.status, LpStatus::Infeasible);
    }

    #[test]
    fn degenerate_problem_terminates() {
        // Klee-Minty-ish degeneracy: many redundant constraints.
        let mut lp = LinProg::minimize(3);
        lp.set_objective(&[-1.0, -1.0, -1.0]);
        for i in 0..3 {
            lp.add_constraint(&[(i, 1.0)], Relation::Le, 1.0);
            lp.add_constraint(&[(i, 1.0)], Relation::Le, 1.0); // duplicate
        }
        lp.add_constraint(&[(0, 1.0), (1, 1.0), (2, 1.0)], Relation::Le, 3.0);
        let (sol, _) = both_backends(&lp);
        assert_eq!(sol.status, LpStatus::Optimal);
        assert!((sol.objective + 3.0).abs() < 1e-7);
    }

    #[test]
    fn zero_variable_problem() {
        let lp = LinProg::minimize(0);
        let sol = lp.solve().unwrap();
        assert_eq!(sol.status, LpStatus::Optimal);
        assert_eq!(sol.objective, 0.0);
    }

    #[test]
    fn warm_basis_reoptimizes_after_bound_tightening() {
        // max x + y s.t. x + 2y <= 4, 3x + y <= 6, x,y in [0, 3]:
        // optimum at the row intersection (1.6, 1.2), obj -2.8.
        let mut lp = LinProg::minimize(2);
        lp.set_objective(&[-1.0, -1.0]);
        lp.add_constraint(&[(0, 1.0), (1, 2.0)], Relation::Le, 4.0);
        lp.add_constraint(&[(0, 3.0), (1, 1.0)], Relation::Le, 6.0);
        lp.set_upper_bound(0, 3.0);
        lp.set_upper_bound(1, 3.0);
        let mut eng = RevisedSimplex::new(&lp).unwrap();
        let root = eng.solve_cold().unwrap();
        assert_eq!(root.status, LpStatus::Optimal);
        assert!((root.objective + 2.8).abs() < 1e-7, "obj={}", root.objective);
        let warm = root.basis.clone().expect("optimal root must carry a basis");

        // Tighten x <= 1 (a branch-down step) and warm re-solve: the LP
        // optimum moves to (1, 1.5), obj -2.5.
        eng.reset_bounds();
        eng.tighten_var_bounds(0, 0.0, 1.0);
        let child = eng.solve_warm(&warm).unwrap();
        assert_eq!(child.status, LpStatus::Optimal);
        assert!(
            (child.objective + 2.5).abs() < 1e-7,
            "obj={}",
            child.objective
        );
        assert!(child.x[0] <= 1.0 + 1e-7);

        // And against the dense backend on the same tightened model.
        let mut tight = LinProg::minimize(2);
        tight.set_objective(&[-1.0, -1.0]);
        tight.add_constraint(&[(0, 1.0), (1, 2.0)], Relation::Le, 4.0);
        tight.add_constraint(&[(0, 3.0), (1, 1.0)], Relation::Le, 6.0);
        tight.set_upper_bound(0, 1.0);
        tight.set_upper_bound(1, 3.0);
        let dense = tight.solve_dense().unwrap();
        assert!((dense.objective - child.objective).abs() < 1e-7);

        // Raising a lower bound re-optimizes too: x >= 1.8 forces
        // (1.8, 0.6) via row 2, obj -2.4.
        eng.reset_bounds();
        eng.tighten_var_bounds(0, 1.8, f64::INFINITY);
        let up = eng.solve_warm(&warm).unwrap();
        assert_eq!(up.status, LpStatus::Optimal);
        assert!(up.x[0] >= 1.8 - 1e-7);
        assert!((up.objective + 2.4).abs() < 1e-6, "obj={}", up.objective);
    }

    #[test]
    fn warm_infeasible_bound_combination_detected() {
        // x + y >= 4 with both variables boxed to [0, 1] after tightening.
        let mut lp = LinProg::minimize(2);
        lp.set_objective(&[1.0, 1.0]);
        lp.add_constraint(&[(0, 1.0), (1, 1.0)], Relation::Ge, 4.0);
        lp.set_upper_bound(0, 3.0);
        lp.set_upper_bound(1, 3.0);
        let mut eng = RevisedSimplex::new(&lp).unwrap();
        let root = eng.solve_cold().unwrap();
        assert_eq!(root.status, LpStatus::Optimal);
        let warm = root.basis.clone().unwrap();
        eng.reset_bounds();
        eng.tighten_var_bounds(0, 0.0, 1.0);
        eng.tighten_var_bounds(1, 0.0, 1.0);
        let child = eng.solve_warm(&warm).unwrap();
        assert_eq!(child.status, LpStatus::Infeasible);
    }

    #[test]
    fn backend_trait_objects_agree() {
        let mut lp = LinProg::minimize(2);
        lp.set_objective(&[2.0, 3.0]);
        lp.add_constraint(&[(0, 1.0), (1, 1.0)], Relation::Ge, 5.0);
        lp.set_upper_bound(0, 10.0);
        lp.set_upper_bound(1, 10.0);
        let backends: [&dyn LpBackend; 2] = [&DenseBackend, &RevisedBackend];
        let objs: Vec<f64> = backends
            .iter()
            .map(|b| b.solve(&lp).unwrap().objective)
            .collect();
        assert!((objs[0] - objs[1]).abs() < 1e-7);
        assert!((objs[0] - 10.0).abs() < 1e-7);
    }
}
