//! Linear-programming substrate: a dense two-phase primal simplex solver.
//!
//! The paper solves the static core-placement problem (14) with
//! "off-the-shelf tools"; nothing off-the-shelf is available offline, so
//! this module provides the LP relaxation engine underneath the in-tree
//! branch-and-bound MILP solver (`crate::ilp`). Problem sizes are small
//! (|V|·|Mcr| + |V|·|Mcr| binaries ≈ a few hundred variables), well within
//! dense-simplex territory.

mod simplex;

pub use simplex::{LinProg, LpError, LpSolution, LpStatus, Relation};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn simple_max_problem() {
        // max 3x + 5y s.t. x <= 4, 2y <= 12, 3x + 2y <= 18  -> (2, 6), obj 36
        let mut lp = LinProg::minimize(2);
        lp.set_objective(&[-3.0, -5.0]);
        lp.add_constraint(&[(0, 1.0)], Relation::Le, 4.0);
        lp.add_constraint(&[(1, 2.0)], Relation::Le, 12.0);
        lp.add_constraint(&[(0, 3.0), (1, 2.0)], Relation::Le, 18.0);
        let sol = lp.solve().unwrap();
        assert_eq!(sol.status, LpStatus::Optimal);
        assert!((sol.objective + 36.0).abs() < 1e-7);
        assert!((sol.x[0] - 2.0).abs() < 1e-7);
        assert!((sol.x[1] - 6.0).abs() < 1e-7);
    }

    #[test]
    fn equality_and_ge_constraints() {
        // min x + y s.t. x + y = 10, x >= 3, y >= 2 -> obj 10
        let mut lp = LinProg::minimize(2);
        lp.set_objective(&[1.0, 1.0]);
        lp.add_constraint(&[(0, 1.0), (1, 1.0)], Relation::Eq, 10.0);
        lp.add_constraint(&[(0, 1.0)], Relation::Ge, 3.0);
        lp.add_constraint(&[(1, 1.0)], Relation::Ge, 2.0);
        let sol = lp.solve().unwrap();
        assert_eq!(sol.status, LpStatus::Optimal);
        assert!((sol.objective - 10.0).abs() < 1e-7);
        assert!((sol.x[0] + sol.x[1] - 10.0).abs() < 1e-7);
    }

    #[test]
    fn infeasible_detected() {
        let mut lp = LinProg::minimize(1);
        lp.set_objective(&[1.0]);
        lp.add_constraint(&[(0, 1.0)], Relation::Le, 1.0);
        lp.add_constraint(&[(0, 1.0)], Relation::Ge, 2.0);
        let sol = lp.solve().unwrap();
        assert_eq!(sol.status, LpStatus::Infeasible);
    }

    #[test]
    fn unbounded_detected() {
        // min -x with x >= 0 and no upper bound.
        let mut lp = LinProg::minimize(1);
        lp.set_objective(&[-1.0]);
        lp.add_constraint(&[(0, 1.0)], Relation::Ge, 0.0);
        let sol = lp.solve().unwrap();
        assert_eq!(sol.status, LpStatus::Unbounded);
    }

    #[test]
    fn upper_bounds_respected() {
        // min -x - y with x <= 2.5, y <= 1.5 via variable bounds.
        let mut lp = LinProg::minimize(2);
        lp.set_objective(&[-1.0, -1.0]);
        lp.set_upper_bound(0, 2.5);
        lp.set_upper_bound(1, 1.5);
        let sol = lp.solve().unwrap();
        assert_eq!(sol.status, LpStatus::Optimal);
        assert!((sol.x[0] - 2.5).abs() < 1e-7);
        assert!((sol.x[1] - 1.5).abs() < 1e-7);
    }

    #[test]
    fn degenerate_problem_terminates() {
        // Klee-Minty-ish degeneracy: many redundant constraints.
        let mut lp = LinProg::minimize(3);
        lp.set_objective(&[-1.0, -1.0, -1.0]);
        for i in 0..3 {
            lp.add_constraint(&[(i, 1.0)], Relation::Le, 1.0);
            lp.add_constraint(&[(i, 1.0)], Relation::Le, 1.0); // duplicate
        }
        lp.add_constraint(&[(0, 1.0), (1, 1.0), (2, 1.0)], Relation::Le, 3.0);
        let sol = lp.solve().unwrap();
        assert_eq!(sol.status, LpStatus::Optimal);
        assert!((sol.objective + 3.0).abs() < 1e-7);
    }

    #[test]
    fn zero_variable_problem() {
        let lp = LinProg::minimize(0);
        let sol = lp.solve().unwrap();
        assert_eq!(sol.status, LpStatus::Optimal);
        assert_eq!(sol.objective, 0.0);
    }
}
