//! The LP model container plus the dense two-phase primal simplex
//! (Bland's anti-cycling rule), kept as the reference backend.
//!
//! Model: `min c·x` subject to row constraints `a·x {<=,=,>=} b` and
//! variable bounds `l_j <= x_j <= u_j` (`l_j >= 0`).
//!
//! [`LinProg::solve`] dispatches to the bounded-variable *revised* simplex
//! in [`super::revised`], which treats the bounds natively and supports
//! warm starts. The dense tableau here materializes bounds as extra
//! constraint rows; it is retained behind [`LinProg::solve_dense`] (and the
//! `DenseBackend` of the [`super::LpBackend`] trait) so property tests can
//! cross-check the two implementations.

/// Constraint relation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Relation {
    Le,
    Eq,
    Ge,
}

/// Solver status.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LpStatus {
    Optimal,
    Infeasible,
    Unbounded,
}

/// Errors (malformed model).
#[derive(Debug, Clone, PartialEq)]
pub enum LpError {
    /// A coefficient referenced a variable index out of range.
    VarOutOfRange { var: usize, nvars: usize },
    /// Iteration limit hit (anti-cycling failed — should not happen with
    /// Bland's rule; kept as a hard safety net).
    IterationLimit,
    /// A (warm-start) basis matrix was numerically singular; callers
    /// should fall back to a cold solve.
    SingularBasis,
}

impl std::fmt::Display for LpError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            LpError::VarOutOfRange { var, nvars } => {
                write!(f, "variable {var} out of range ({nvars} vars)")
            }
            LpError::IterationLimit => write!(f, "simplex iteration limit exceeded"),
            LpError::SingularBasis => write!(f, "singular (warm-start) basis"),
        }
    }
}

impl std::error::Error for LpError {}

/// Solution container.
#[derive(Clone, Debug)]
pub struct LpSolution {
    pub status: LpStatus,
    /// Primal values (length = number of structural variables).
    pub x: Vec<f64>,
    /// Objective value at `x` (undefined unless `status == Optimal`).
    pub objective: f64,
    /// Optimal basis snapshot for warm restarts (revised backend only;
    /// `None` from the dense backend or on non-optimal statuses).
    pub basis: Option<super::revised::WarmBasis>,
}

pub(super) struct Row {
    pub(super) coeffs: Vec<(usize, f64)>,
    pub(super) rel: Relation,
    pub(super) rhs: f64,
}

/// A linear program under construction.
pub struct LinProg {
    pub(super) nvars: usize,
    pub(super) objective: Vec<f64>,
    pub(super) rows: Vec<Row>,
    pub(super) lower: Vec<f64>,
    pub(super) upper: Vec<Option<f64>>,
}

const EPS: f64 = 1e-9;

impl LinProg {
    /// A minimization problem over `nvars` non-negative variables.
    pub fn minimize(nvars: usize) -> Self {
        LinProg {
            nvars,
            objective: vec![0.0; nvars],
            rows: Vec::new(),
            lower: vec![0.0; nvars],
            upper: vec![None; nvars],
        }
    }

    /// Number of structural variables.
    pub fn num_vars(&self) -> usize {
        self.nvars
    }

    /// Number of constraint rows added so far.
    pub fn num_rows(&self) -> usize {
        self.rows.len()
    }

    /// Set the full objective vector.
    pub fn set_objective(&mut self, c: &[f64]) {
        assert_eq!(c.len(), self.nvars, "objective length mismatch");
        self.objective.copy_from_slice(c);
    }

    /// Set one objective coefficient.
    pub fn set_objective_coeff(&mut self, var: usize, c: f64) {
        self.objective[var] = c;
    }

    /// Add `sum coeffs {rel} rhs`. Coefficients are `(var, value)` pairs.
    pub fn add_constraint(&mut self, coeffs: &[(usize, f64)], rel: Relation, rhs: f64) {
        self.rows.push(Row {
            coeffs: coeffs.to_vec(),
            rel,
            rhs,
        });
    }

    /// Impose `x_var <= ub` (in addition to the default `x >= 0`).
    pub fn set_upper_bound(&mut self, var: usize, ub: f64) {
        self.upper[var] = Some(ub);
    }

    /// Impose `x_var >= lb` (replacing the default `x >= 0`). Must be
    /// non-negative: the dense backend keeps the implicit `x >= 0` domain.
    pub fn set_lower_bound(&mut self, var: usize, lb: f64) {
        assert!(lb >= 0.0 && lb.is_finite(), "lower bound must be finite and >= 0");
        self.lower[var] = lb;
    }

    fn validate(&self) -> Result<(), LpError> {
        for row in &self.rows {
            for &(v, _) in &row.coeffs {
                if v >= self.nvars {
                    return Err(LpError::VarOutOfRange {
                        var: v,
                        nvars: self.nvars,
                    });
                }
            }
        }
        Ok(())
    }

    /// Constant problem (no variables): feasible iff every row holds at 0.
    fn solve_empty(&self) -> LpSolution {
        for row in &self.rows {
            let lhs = 0.0;
            let ok = match row.rel {
                Relation::Le => lhs <= row.rhs + EPS,
                Relation::Eq => (lhs - row.rhs).abs() <= EPS,
                Relation::Ge => lhs >= row.rhs - EPS,
            };
            if !ok {
                return LpSolution {
                    status: LpStatus::Infeasible,
                    x: vec![],
                    objective: 0.0,
                    basis: None,
                };
            }
        }
        LpSolution {
            status: LpStatus::Optimal,
            x: vec![],
            objective: 0.0,
            basis: None,
        }
    }

    /// Solve with the bounded-variable revised simplex (default backend).
    pub fn solve(&self) -> Result<LpSolution, LpError> {
        self.validate()?;
        if self.nvars == 0 {
            return Ok(self.solve_empty());
        }
        super::revised::RevisedSimplex::new(self)?.solve_cold()
    }

    /// Solve with the dense two-phase tableau simplex (reference backend;
    /// variable bounds are materialized as constraint rows).
    pub fn solve_dense(&self) -> Result<LpSolution, LpError> {
        self.validate()?;
        if self.nvars == 0 {
            return Ok(self.solve_empty());
        }
        Tableau::build(self).solve()
    }
}

/// Dense simplex tableau. Columns: structural vars, then slack/surplus,
/// then artificials. Rows: one per constraint, plus the objective row.
struct Tableau {
    /// a[r][c] for r in 0..m, c in 0..total_cols; rhs stored separately.
    a: Vec<Vec<f64>>,
    rhs: Vec<f64>,
    /// basis[r] = column basic in row r.
    basis: Vec<usize>,
    nstruct: usize,
    total: usize,
    art_start: usize,
    cost: Vec<f64>,
}

impl Tableau {
    fn build(lp: &LinProg) -> Self {
        // Materialize upper bounds as <= rows.
        let mut rows: Vec<(Vec<f64>, Relation, f64)> = Vec::new();
        for row in &lp.rows {
            let mut dense = vec![0.0; lp.nvars];
            for &(v, c) in &row.coeffs {
                dense[v] += c;
            }
            rows.push((dense, row.rel, row.rhs));
        }
        for (v, ub) in lp.upper.iter().enumerate() {
            if let Some(u) = ub {
                let mut dense = vec![0.0; lp.nvars];
                dense[v] = 1.0;
                rows.push((dense, Relation::Le, *u));
            }
        }
        for (v, &lb) in lp.lower.iter().enumerate() {
            if lb > 0.0 {
                let mut dense = vec![0.0; lp.nvars];
                dense[v] = 1.0;
                rows.push((dense, Relation::Ge, lb));
            }
        }
        // Normalize: rhs >= 0.
        for (dense, rel, rhs) in &mut rows {
            if *rhs < 0.0 {
                for c in dense.iter_mut() {
                    *c = -*c;
                }
                *rhs = -*rhs;
                *rel = match *rel {
                    Relation::Le => Relation::Ge,
                    Relation::Eq => Relation::Eq,
                    Relation::Ge => Relation::Le,
                };
            }
        }

        let m = rows.len();
        let n = lp.nvars;
        // Count slack columns (Le: +slack basic; Ge: -surplus + artificial;
        // Eq: artificial).
        let mut nslack = 0;
        for (_, rel, _) in &rows {
            if matches!(rel, Relation::Le | Relation::Ge) {
                nslack += 1;
            }
        }
        let mut nart = 0;
        for (_, rel, _) in &rows {
            if matches!(rel, Relation::Ge | Relation::Eq) {
                nart += 1;
            }
        }
        let art_start = n + nslack;
        let total = n + nslack + nart;

        let mut a = vec![vec![0.0; total]; m];
        let mut rhs = vec![0.0; m];
        let mut basis = vec![0usize; m];
        let mut s = n;
        let mut t = art_start;
        for (r, (dense, rel, b)) in rows.iter().enumerate() {
            a[r][..n].copy_from_slice(dense);
            rhs[r] = *b;
            match rel {
                Relation::Le => {
                    a[r][s] = 1.0;
                    basis[r] = s;
                    s += 1;
                }
                Relation::Ge => {
                    a[r][s] = -1.0;
                    s += 1;
                    a[r][t] = 1.0;
                    basis[r] = t;
                    t += 1;
                }
                Relation::Eq => {
                    a[r][t] = 1.0;
                    basis[r] = t;
                    t += 1;
                }
            }
        }

        let mut cost = vec![0.0; total];
        cost[..n].copy_from_slice(&lp.objective);

        Tableau {
            a,
            rhs,
            basis,
            nstruct: n,
            total,
            art_start,
            cost,
        }
    }

    fn solve(mut self) -> Result<LpSolution, LpError> {
        let m = self.a.len();
        // Phase 1: minimize sum of artificials (when any exist).
        if self.art_start < self.total {
            let mut p1 = vec![0.0; self.total];
            for c in self.art_start..self.total {
                p1[c] = 1.0;
            }
            let status = self.run(&p1)?;
            if status == LpStatus::Unbounded {
                // Phase-1 objective is bounded below by 0; cannot happen.
                unreachable!("phase-1 simplex cannot be unbounded");
            }
            let p1_obj = self.objective_value(&p1);
            if p1_obj > 1e-7 {
                return Ok(LpSolution {
                    status: LpStatus::Infeasible,
                    x: vec![0.0; self.nstruct],
                    objective: 0.0,
                    basis: None,
                });
            }
            // Drive any artificial still basic (at zero) out of the basis.
            for r in 0..m {
                if self.basis[r] >= self.art_start {
                    if let Some(c) =
                        (0..self.art_start).find(|&c| self.a[r][c].abs() > EPS)
                    {
                        self.pivot(r, c);
                    }
                    // If the entire row is zero, it is redundant; the
                    // artificial stays basic at value 0 harmlessly.
                }
            }
        }

        // Phase 2: original objective, artificial columns frozen.
        let frozen_from = self.art_start;
        let cost = std::mem::take(&mut self.cost);
        let status = self.run_frozen(&cost, frozen_from)?;
        let obj = self.objective_value(&cost);
        let mut x = vec![0.0; self.nstruct];
        for r in 0..m {
            if self.basis[r] < self.nstruct {
                x[self.basis[r]] = self.rhs[r];
            }
        }
        Ok(LpSolution {
            status,
            x,
            objective: obj,
            basis: None,
        })
    }

    fn objective_value(&self, cost: &[f64]) -> f64 {
        let mut obj = 0.0;
        for (r, &b) in self.basis.iter().enumerate() {
            obj += cost[b] * self.rhs[r];
        }
        obj
    }

    fn run(&mut self, cost: &[f64]) -> Result<LpStatus, LpError> {
        self.run_frozen(cost, self.total)
    }

    /// Simplex iterations over columns `< frozen_from` only.
    fn run_frozen(&mut self, cost: &[f64], frozen_from: usize) -> Result<LpStatus, LpError> {
        let m = self.a.len();
        let max_iter = 50 * (m + self.total + 16);
        for _ in 0..max_iter {
            // Reduced costs: cj - cB . B^-1 Aj (tableau is kept in
            // canonical form, so reduced cost = cost[j] - sum over rows of
            // cost[basis[r]] * a[r][j]).
            let mut entering = None;
            let mut best_rc = -1e-9; // Dantzig with Bland fallback
            let mut bland: Option<usize> = None;
            for j in 0..frozen_from {
                if self.basis.contains(&j) {
                    continue;
                }
                let mut rc = cost[j];
                for r in 0..m {
                    let cb = cost[self.basis[r]];
                    if cb != 0.0 {
                        rc -= cb * self.a[r][j];
                    }
                }
                if rc < -1e-7 {
                    if bland.is_none() {
                        bland = Some(j);
                    }
                    if rc < best_rc {
                        best_rc = rc;
                        entering = Some(j);
                    }
                }
            }
            let Some(mut j) = entering else {
                return Ok(LpStatus::Optimal);
            };
            // Degeneracy guard: if we are cycling (many zero-ratio pivots),
            // Bland's rule guarantees termination. Cheap heuristic: always
            // prefer Bland's column when the best ratio is zero.
            // Ratio test.
            let ratio_row = |col: usize, a: &Vec<Vec<f64>>, rhs: &Vec<f64>| -> Option<(usize, f64)> {
                let mut best: Option<(usize, f64)> = None;
                for r in 0..m {
                    let arj = a[r][col];
                    if arj > EPS {
                        let ratio = rhs[r] / arj;
                        match best {
                            None => best = Some((r, ratio)),
                            Some((_, br)) if ratio < br - EPS => best = Some((r, ratio)),
                            _ => {}
                        }
                    }
                }
                best
            };
            let mut leave = ratio_row(j, &self.a, &self.rhs);
            if leave.is_none() {
                // Unbounded along j — but in phase 2 only if rc < 0 (it is).
                return Ok(LpStatus::Unbounded);
            }
            if let Some((_, ratio)) = leave {
                if ratio <= EPS {
                    if let Some(bj) = bland {
                        // Switch to Bland's entering column on degenerate step.
                        if bj != j {
                            if let Some(l2) = ratio_row(bj, &self.a, &self.rhs) {
                                j = bj;
                                leave = Some(l2);
                            }
                        }
                    }
                }
            }
            let (r, _) = leave.unwrap();
            self.pivot(r, j);
        }
        Err(LpError::IterationLimit)
    }

    /// Gauss-Jordan pivot on (row, col).
    fn pivot(&mut self, row: usize, col: usize) {
        let m = self.a.len();
        let piv = self.a[row][col];
        debug_assert!(piv.abs() > EPS, "pivot on (near-)zero element");
        let inv = 1.0 / piv;
        for c in 0..self.total {
            self.a[row][c] *= inv;
        }
        self.rhs[row] *= inv;
        let pivot_row = self.a[row].clone();
        let pivot_rhs = self.rhs[row];
        for r in 0..m {
            if r == row {
                continue;
            }
            let factor = self.a[r][col];
            if factor.abs() <= EPS {
                continue;
            }
            for c in 0..self.total {
                self.a[r][c] -= factor * pivot_row[c];
            }
            self.rhs[r] -= factor * pivot_rhs;
            if self.rhs[r].abs() < EPS {
                self.rhs[r] = 0.0;
            }
        }
        self.basis[row] = col;
    }
}
