//! Micro/macro benchmark harness (criterion is unavailable offline).
//!
//! Provides warmup + timed iterations with robust statistics, and markdown
//! table emission so every `cargo bench` target prints the rows of the
//! paper table/figure it regenerates.

use std::time::{Duration, Instant};

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub median: Duration,
    pub p95: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl BenchResult {
    pub fn mean_ns(&self) -> f64 {
        self.mean.as_nanos() as f64
    }
}

/// Time `f` for `iters` iterations after `warmup` iterations.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
    }
    summarize(name, &mut samples)
}

/// Auto-calibrating variant: choose an iteration count so the total timed
/// region is roughly `budget`.
pub fn bench_budget<F: FnMut()>(name: &str, budget: Duration, mut f: F) -> BenchResult {
    // Calibrate with one run.
    let t0 = Instant::now();
    f();
    let one = t0.elapsed().max(Duration::from_nanos(100));
    let iters = (budget.as_nanos() / one.as_nanos()).clamp(5, 10_000) as usize;
    bench(name, (iters / 10).max(1), iters, f)
}

fn summarize(name: &str, samples: &mut [Duration]) -> BenchResult {
    samples.sort_unstable();
    let n = samples.len();
    let total: Duration = samples.iter().sum();
    BenchResult {
        name: name.to_string(),
        iters: n,
        mean: total / n as u32,
        median: samples[n / 2],
        p95: samples[((n as f64 * 0.95) as usize).min(n - 1)],
        min: samples[0],
        max: samples[n - 1],
    }
}

/// Format a duration human-readably.
pub fn fmt_duration(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.3} s", ns as f64 / 1e9)
    }
}

/// Print a markdown table of results.
pub fn print_table(title: &str, results: &[BenchResult]) {
    println!("\n### {title}\n");
    println!("| case | iters | mean | median | p95 | min | max |");
    println!("|---|---|---|---|---|---|---|");
    for r in results {
        println!(
            "| {} | {} | {} | {} | {} | {} | {} |",
            r.name,
            r.iters,
            fmt_duration(r.mean),
            fmt_duration(r.median),
            fmt_duration(r.p95),
            fmt_duration(r.min),
            fmt_duration(r.max),
        );
    }
}

/// Print an arbitrary markdown table (for figure-style data rows).
pub fn print_data_table(title: &str, headers: &[&str], rows: &[Vec<String>]) {
    println!("\n### {title}\n");
    println!("| {} |", headers.join(" | "));
    println!("|{}|", headers.iter().map(|_| "---").collect::<Vec<_>>().join("|"));
    for row in rows {
        println!("| {} |", row.join(" | "));
    }
}

fn json_escape(s: &str) -> String {
    s.chars()
        .flat_map(|c| match c {
            '"' => "\\\"".chars().collect::<Vec<_>>(),
            '\\' => "\\\\".chars().collect(),
            '\n' => "\\n".chars().collect(),
            c if (c as u32) < 0x20 => format!("\\u{:04x}", c as u32).chars().collect(),
            c => vec![c],
        })
        .collect()
}

/// Persist a bench table as a `BENCH_*.json` artifact so later PRs have a
/// perf trajectory to compare against. The schema is one object per data
/// row keyed by the table headers; numeric-looking cells are emitted as
/// numbers. Benches opt in by calling this when the environment variable
/// named by `env_var` (conventionally `FMEDGE_BENCH_JSON`) is set to the
/// output path.
pub fn save_json(
    path: &str,
    title: &str,
    headers: &[&str],
    rows: &[Vec<String>],
) -> std::io::Result<()> {
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str(&format!("  \"title\": \"{}\",\n", json_escape(title)));
    out.push_str("  \"rows\": [\n");
    for (i, row) in rows.iter().enumerate() {
        out.push_str("    {");
        for (j, (h, cell)) in headers.iter().zip(row).enumerate() {
            if j > 0 {
                out.push_str(", ");
            }
            let is_num = !cell.is_empty() && cell.parse::<f64>().is_ok();
            if is_num {
                out.push_str(&format!("\"{}\": {}", json_escape(h), cell.trim()));
            } else {
                out.push_str(&format!(
                    "\"{}\": \"{}\"",
                    json_escape(h),
                    json_escape(cell)
                ));
            }
        }
        out.push('}');
        out.push_str(if i + 1 < rows.len() { ",\n" } else { "\n" });
    }
    out.push_str("  ]\n}\n");
    std::fs::write(path, out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_stats() {
        let r = bench("noop-ish", 2, 50, || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        assert_eq!(r.iters, 50);
        assert!(r.min <= r.median && r.median <= r.max);
        assert!(r.mean.as_nanos() > 0);
    }

    #[test]
    fn fmt_duration_units() {
        assert!(fmt_duration(Duration::from_nanos(500)).ends_with("ns"));
        assert!(fmt_duration(Duration::from_micros(50)).ends_with("µs"));
        assert!(fmt_duration(Duration::from_millis(50)).ends_with("ms"));
        assert!(fmt_duration(Duration::from_secs(2)).ends_with("s"));
    }

    #[test]
    fn save_json_emits_typed_cells() {
        let dir = std::env::temp_dir().join("fmedge_benchkit_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_test.json");
        let rows = vec![vec![
            "case \"a\"".to_string(),
            "12.5".to_string(),
            "n/a".to_string(),
        ]];
        save_json(
            path.to_str().unwrap(),
            "t",
            &["case", "rps", "note"],
            &rows,
        )
        .unwrap();
        let got = std::fs::read_to_string(&path).unwrap();
        assert!(got.contains("\"rps\": 12.5"), "numeric cell unquoted: {got}");
        assert!(got.contains("\"note\": \"n/a\""), "text cell quoted: {got}");
        assert!(got.contains("case \\\"a\\\""), "escaping: {got}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn bench_budget_calibrates() {
        let r = bench_budget("calibrated", Duration::from_millis(20), || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(r.iters >= 5);
    }
}
