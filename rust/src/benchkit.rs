//! Micro/macro benchmark harness (criterion is unavailable offline).
//!
//! Provides warmup + timed iterations with robust statistics, and markdown
//! table emission so every `cargo bench` target prints the rows of the
//! paper table/figure it regenerates.

use std::time::{Duration, Instant};

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub median: Duration,
    pub p95: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl BenchResult {
    pub fn mean_ns(&self) -> f64 {
        self.mean.as_nanos() as f64
    }
}

/// Time `f` for `iters` iterations after `warmup` iterations.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
    }
    summarize(name, &mut samples)
}

/// Auto-calibrating variant: choose an iteration count so the total timed
/// region is roughly `budget`.
pub fn bench_budget<F: FnMut()>(name: &str, budget: Duration, mut f: F) -> BenchResult {
    // Calibrate with one run.
    let t0 = Instant::now();
    f();
    let one = t0.elapsed().max(Duration::from_nanos(100));
    let iters = (budget.as_nanos() / one.as_nanos()).clamp(5, 10_000) as usize;
    bench(name, (iters / 10).max(1), iters, f)
}

fn summarize(name: &str, samples: &mut [Duration]) -> BenchResult {
    samples.sort_unstable();
    let n = samples.len();
    let total: Duration = samples.iter().sum();
    BenchResult {
        name: name.to_string(),
        iters: n,
        mean: total / n as u32,
        median: samples[n / 2],
        p95: samples[((n as f64 * 0.95) as usize).min(n - 1)],
        min: samples[0],
        max: samples[n - 1],
    }
}

/// Format a duration human-readably.
pub fn fmt_duration(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.3} s", ns as f64 / 1e9)
    }
}

/// Print a markdown table of results.
pub fn print_table(title: &str, results: &[BenchResult]) {
    println!("\n### {title}\n");
    println!("| case | iters | mean | median | p95 | min | max |");
    println!("|---|---|---|---|---|---|---|");
    for r in results {
        println!(
            "| {} | {} | {} | {} | {} | {} | {} |",
            r.name,
            r.iters,
            fmt_duration(r.mean),
            fmt_duration(r.median),
            fmt_duration(r.p95),
            fmt_duration(r.min),
            fmt_duration(r.max),
        );
    }
}

/// Print an arbitrary markdown table (for figure-style data rows).
pub fn print_data_table(title: &str, headers: &[&str], rows: &[Vec<String>]) {
    println!("\n### {title}\n");
    println!("| {} |", headers.join(" | "));
    println!("|{}|", headers.iter().map(|_| "---").collect::<Vec<_>>().join("|"));
    for row in rows {
        println!("| {} |", row.join(" | "));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_stats() {
        let r = bench("noop-ish", 2, 50, || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        assert_eq!(r.iters, 50);
        assert!(r.min <= r.median && r.median <= r.max);
        assert!(r.mean.as_nanos() > 0);
    }

    #[test]
    fn fmt_duration_units() {
        assert!(fmt_duration(Duration::from_nanos(500)).ends_with("ns"));
        assert!(fmt_duration(Duration::from_micros(50)).ends_with("µs"));
        assert!(fmt_duration(Duration::from_millis(50)).ends_with("ms"));
        assert!(fmt_duration(Duration::from_secs(2)).ends_with("s"));
    }

    #[test]
    fn bench_budget_calibrates() {
        let r = bench_budget("calibrated", Duration::from_millis(20), || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(r.iters >= 5);
    }
}
