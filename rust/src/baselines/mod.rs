//! The evaluated strategies (§IV): the paper's **Proposal**, the
//! **PropAvg** ablation (mean-value delays instead of effective capacity),
//! **LBRR** (least-loaded placement + round-robin dispatch), and **GA**
//! (metaheuristic deployment minimizing cost + violation penalty).

mod ga;
mod lbrr;
mod proposal;

pub use ga::{GaParams, GaStrategy};
pub use lbrr::LbrrStrategy;
pub use proposal::{Proposal, PropAvg};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;
    use crate::placement::{QosScores, ScoreParams};
    use crate::rng::Xoshiro256;
    use crate::sim::{SimEnv, Strategy};
    use crate::workload::WorkloadGenerator;

    fn env_and_scores(seed: u64) -> (SimEnv, QosScores) {
        let mut cfg = ExperimentConfig::paper_default();
        cfg.controller.effcap_samples = 512;
        let env = SimEnv::build(&cfg, seed);
        let gen = WorkloadGenerator::new(
            &env.cfg,
            &env.app,
            &env.topo,
            &mut Xoshiro256::seed_from(env.users_seed),
        );
        let scores = QosScores::compute(
            &env.app,
            &env.topo,
            &env.dm,
            gen.users(),
            &ScoreParams::from_config(&env.cfg.controller),
        );
        (env, scores)
    }

    #[test]
    fn proposal_and_propavg_share_static_tier() {
        let (env, scores) = env_and_scores(3);
        let mut rng1 = Xoshiro256::seed_from(1);
        let mut rng2 = Xoshiro256::seed_from(1);
        let p1 = Proposal::new().place_core(&env, &scores, &mut rng1);
        let p2 = PropAvg::new().place_core(&env, &scores, &mut rng2);
        assert_eq!(p1.instances, p2.instances, "ablation differs only online");
    }

    #[test]
    fn lbrr_places_all_core_services() {
        let (env, scores) = env_and_scores(4);
        let mut rng = Xoshiro256::seed_from(2);
        let p = LbrrStrategy::new().place_core(&env, &scores, &mut rng);
        for ci in 0..env.app.catalog.num_core() {
            let total: u32 = p.instances.iter().map(|r| r[ci]).sum();
            assert!(total >= 1, "core MS {ci} unplaced");
        }
    }

    #[test]
    fn lbrr_respects_capacity() {
        let (env, scores) = env_and_scores(5);
        let mut rng = Xoshiro256::seed_from(3);
        let p = LbrrStrategy::new().place_core(&env, &scores, &mut rng);
        for (v, row) in p.instances.iter().enumerate() {
            for k in 0..crate::config::NUM_RESOURCES {
                let used: f64 = row
                    .iter()
                    .enumerate()
                    .map(|(ci, &x)| {
                        env.app
                            .catalog
                            .spec(env.app.catalog.core_ids()[ci])
                            .resources[k]
                            * x as f64
                    })
                    .sum();
                assert!(used <= env.topo.node(v).capacity[k] + 1e-9);
            }
        }
    }

    #[test]
    fn ga_improves_over_random_start() {
        let (env, scores) = env_and_scores(6);
        let mut rng = Xoshiro256::seed_from(4);
        let mut ga = GaStrategy::new(10, 6);
        let p = ga.place_core(&env, &scores, &mut rng);
        // GA must at least cover every service and end with finite fitness.
        for ci in 0..env.app.catalog.num_core() {
            let total: u32 = p.instances.iter().map(|r| r[ci]).sum();
            assert!(total >= 1);
        }
        let (first, best) = ga.fitness_trajectory();
        assert!(best <= first, "GA fitness should not regress");
    }

    #[test]
    fn strategy_names_are_distinct() {
        let names = [
            Proposal::new().name().to_string(),
            PropAvg::new().name().to_string(),
            LbrrStrategy::new().name().to_string(),
            GaStrategy::new(4, 4).name().to_string(),
        ];
        for i in 0..names.len() {
            for j in (i + 1)..names.len() {
                assert_ne!(names[i], names[j]);
            }
        }
    }
}
