//! GA baseline (§IV): a genetic algorithm searching deployment matrices
//! with a fitness combining total system cost and QoS-violation penalties.
//!
//! Chromosome: the flattened core instance matrix `x[v][ci]` (and, for the
//! dynamic tier, a static light provisioning matrix reused every slot).
//! Fitness: horizon cost + shortfall penalty (unserved Erlang demand) +
//! capacity-violation penalty − QoS-score reward. Tournament selection,
//! uniform crossover, ±1 mutation with repair. The paper observes this
//! search is high-variance in the stochastic deployment space — exactly
//! what `bench_fig3` shows.

use crate::config::NUM_RESOURCES;
use crate::controller::{Assignment, LightDecision, LightRequest};
use crate::placement::{CorePlacement, QosScores};
use crate::rng::{Rng, Xoshiro256};
use crate::sim::SimEnv;

/// GA hyper-parameters.
#[derive(Clone, Debug)]
pub struct GaParams {
    pub population: usize,
    pub generations: usize,
    pub tournament: usize,
    pub crossover_rate: f64,
    pub mutation_rate: f64,
    /// Penalty per unit of unserved demand.
    pub shortfall_penalty: f64,
    /// Penalty per unit of capacity excess.
    pub capacity_penalty: f64,
}

impl Default for GaParams {
    fn default() -> Self {
        GaParams {
            population: 24,
            generations: 30,
            tournament: 3,
            crossover_rate: 0.8,
            mutation_rate: 0.15,
            shortfall_penalty: 200.0,
            capacity_penalty: 100.0,
        }
    }
}

pub struct GaStrategy {
    params: GaParams,
    /// Static light provisioning chosen at slot 0, reused every slot.
    light_plan: Option<Vec<Vec<u32>>>,
    rr: usize,
    first_fitness: f64,
    best_fitness: f64,
}

impl GaStrategy {
    pub fn new(population: usize, generations: usize) -> Self {
        GaStrategy {
            params: GaParams {
                population,
                generations,
                ..Default::default()
            },
            light_plan: None,
            rr: 0,
            first_fitness: f64::NAN,
            best_fitness: f64::NAN,
        }
    }

    /// `(initial best, final best)` fitness — convergence diagnostic.
    pub fn fitness_trajectory(&self) -> (f64, f64) {
        (self.first_fitness, self.best_fitness)
    }

    fn evolve<F: Fn(&[u32]) -> f64>(
        &mut self,
        len: usize,
        max_gene: u32,
        fitness: F,
        rng: &mut Xoshiro256,
    ) -> Vec<u32> {
        let p = &self.params;
        // Random initial population (sparse: most genes 0).
        let mut pop: Vec<Vec<u32>> = (0..p.population)
            .map(|_| {
                (0..len)
                    .map(|_| {
                        if rng.next_f64() < 0.15 {
                            rng.next_below(max_gene as u64 + 1) as u32
                        } else {
                            0
                        }
                    })
                    .collect()
            })
            .collect();
        let mut fit: Vec<f64> = pop.iter().map(|g| fitness(g)).collect();
        let best0 = fit.iter().cloned().fold(f64::INFINITY, f64::min);
        if self.first_fitness.is_nan() {
            self.first_fitness = best0;
        }

        for _gen in 0..p.generations {
            let mut next = Vec::with_capacity(p.population);
            // Elitism: carry the best genome.
            let best_idx = (0..pop.len())
                .min_by(|&a, &b| fit[a].total_cmp(&fit[b]))
                .unwrap();
            next.push(pop[best_idx].clone());
            while next.len() < p.population {
                let pick = |rng: &mut Xoshiro256| -> usize {
                    let mut best = rng.next_below(pop.len() as u64) as usize;
                    for _ in 1..p.tournament {
                        let c = rng.next_below(pop.len() as u64) as usize;
                        if fit[c] < fit[best] {
                            best = c;
                        }
                    }
                    best
                };
                let a = pick(rng);
                let b = pick(rng);
                let mut child: Vec<u32> = if rng.next_f64() < p.crossover_rate {
                    (0..len)
                        .map(|i| if rng.next_f64() < 0.5 { pop[a][i] } else { pop[b][i] })
                        .collect()
                } else {
                    pop[a].clone()
                };
                for g in child.iter_mut() {
                    if rng.next_f64() < p.mutation_rate {
                        if rng.next_f64() < 0.5 {
                            *g = g.saturating_sub(1);
                        } else {
                            *g = (*g + 1).min(max_gene);
                        }
                    }
                }
                next.push(child);
            }
            pop = next;
            fit = pop.iter().map(|g| fitness(g)).collect();
        }
        let best_idx = (0..pop.len())
            .min_by(|&a, &b| fit[a].total_cmp(&fit[b]))
            .unwrap();
        self.best_fitness = fit[best_idx];
        pop.swap_remove(best_idx)
    }
}

impl crate::sim::Strategy for GaStrategy {
    fn name(&self) -> &str {
        "GA"
    }

    fn place_core(
        &mut self,
        env: &SimEnv,
        scores: &QosScores,
        rng: &mut Xoshiro256,
    ) -> CorePlacement {
        let app = &env.app;
        let nv = env.topo.num_nodes();
        let nc = app.catalog.num_core();
        let demand: Vec<f64> = (0..nc)
            .map(|ci| {
                scores
                    .erlang_demand(
                        ci,
                        app.catalog.spec(app.catalog.core_ids()[ci]).mean_proc_delay(),
                        env.cfg.sim.slot_ms,
                    )
                    .ceil()
                    .max(1.0)
            })
            .collect();
        // Genome ranges over edge servers only (cores live on ESs, §I).
        let es_nodes: Vec<usize> = env.topo.ess().collect();
        let genome = {
            let params = self.params.clone();
            let demand_f = demand.clone();
            let es = es_nodes.clone();
            let f = move |g: &[u32]| fitness_core(g, &es, env, scores, &demand_f, &params);
            self.evolve(es_nodes.len() * nc, 4, f, rng)
        };
        // Repair: enforce per-node capacity by decrementing greedily, then
        // cover any shortfall on feasible nodes.
        let mut instances = vec![vec![0u32; nc]; nv];
        for (ei, &v) in es_nodes.iter().enumerate() {
            for ci in 0..nc {
                instances[v][ci] = genome[ei * nc + ci];
            }
        }
        repair_capacity(&mut instances, env);
        cover_shortfall(&mut instances, env, &demand);
        let support = instances
            .iter()
            .flat_map(|r| r.iter())
            .filter(|&&x| x > 0)
            .count();
        CorePlacement {
            instances,
            objective: self.best_fitness,
            used_fallback: false,
            support,
            demand_target: demand,
            stats: None,
        }
    }

    fn decide_light(
        &mut self,
        env: &SimEnv,
        _slot: usize,
        queue: &[LightRequest],
        busy: &[Vec<u32>],
        residual: &[[f64; NUM_RESOURCES]],
        dm: &crate::routing::DistanceMatrix,
        rng: &mut Xoshiro256,
    ) -> LightDecision {
        let nv = busy.len();
        let nl = env.light_resources.len();
        let max_y = env.gtable.max_parallelism().max(1);

        // One-time GA provisioning of the light tier against the average
        // per-slot demand (queue length as proxy at first decision).
        if self.light_plan.is_none() {
            let mut demand = vec![0.0f64; nl];
            for r in queue {
                demand[r.light_idx] += 1.0;
            }
            for d in demand.iter_mut() {
                *d = (*d / max_y as f64).ceil().max(1.0);
            }
            let costs = env.light_costs.clone();
            let resources = env.light_resources.clone();
            let caps: Vec<[f64; NUM_RESOURCES]> = residual.to_vec();
            let shortfall_penalty = self.params.shortfall_penalty;
            let capacity_penalty = self.params.capacity_penalty;
            let f = move |g: &[u32]| -> f64 {
                let mut cost = 0.0;
                let mut shortfall = 0.0;
                let mut excess = 0.0;
                for m in 0..nl {
                    let total: u32 = (0..nv).map(|v| g[v * nl + m]).sum();
                    cost += (costs[m].1 + costs[m].2) * total as f64;
                    shortfall += (demand[m] - total as f64).max(0.0);
                }
                for v in 0..nv {
                    for k in 0..NUM_RESOURCES {
                        let used: f64 = (0..nl)
                            .map(|m| resources[m][k] * g[v * nl + m] as f64)
                            .sum();
                        excess += (used - caps[v][k]).max(0.0);
                    }
                }
                cost + shortfall_penalty * shortfall + capacity_penalty * excess
            };
            let genome = self.evolve(nv * nl, 3, f, rng);
            let mut plan = vec![vec![0u32; nl]; nv];
            for v in 0..nv {
                for m in 0..nl {
                    plan[v][m] = genome[v * nl + m];
                }
            }
            self.light_plan = Some(plan);
        }
        let plan = self.light_plan.as_ref().unwrap();

        // x = busy ∪ plan, clamped by residual capacity.
        let mut x = busy.to_vec();
        let mut residual = residual.to_vec();
        for v in 0..nv {
            for m in 0..nl {
                while x[v][m] < plan[v][m] {
                    let fits = (0..NUM_RESOURCES)
                        .all(|k| residual[v][k] >= env.light_resources[m][k]);
                    if !fits {
                        break;
                    }
                    for k in 0..NUM_RESOURCES {
                        residual[v][k] -= env.light_resources[m][k];
                    }
                    x[v][m] += 1;
                }
            }
        }

        // Round-robin dispatch over the provisioned instances.
        let mut y = vec![vec![0u32; nl]; nv];
        let mut assignments: Vec<Option<Assignment>> = vec![None; queue.len()];
        for (qi, r) in queue.iter().enumerate() {
            let m = r.light_idx;
            let hosts: Vec<usize> = (0..nv).filter(|&v| x[v][m] > 0).collect();
            if hosts.is_empty() {
                continue;
            }
            let mut chosen = None;
            for off in 0..hosts.len() {
                let v = hosts[(self.rr + off) % hosts.len()];
                if y[v][m] < x[v][m] * max_y as u32 {
                    chosen = Some(v);
                    break;
                }
            }
            self.rr = self.rr.wrapping_add(1);
            let Some(v) = chosen else { continue };
            let per_inst = ((y[v][m] + 1) as usize).div_ceil(x[v][m] as usize);
            y[v][m] += 1;
            assignments[qi] = Some(Assignment {
                node: v,
                light_idx: m,
                y: per_inst as u32,
                transfer_ms: dm.latency(r.from_node, v, r.payload_mb),
                est_proc_ms: env.gtable.mean_delay(m, per_inst),
            });
        }
        LightDecision {
            x,
            y,
            assignments,
            stats: Default::default(),
        }
    }
}

/// Core-placement fitness: horizon cost + shortfall & capacity penalties
/// − QoS-score reward (shares the ILP's objective structure). `es_nodes`
/// maps genome rows to network node ids.
fn fitness_core(
    genome: &[u32],
    es_nodes: &[usize],
    env: &SimEnv,
    scores: &QosScores,
    demand: &[f64],
    params: &GaParams,
) -> f64 {
    let app = &env.app;
    let topo = &env.topo;
    let core_ids = app.catalog.core_ids();
    let nc = core_ids.len();
    let ne = es_nodes.len();
    let mut cost = 0.0;
    let mut reward = 0.0;
    let mut shortfall = 0.0;
    let mut cap_excess = 0.0;
    for ci in 0..nc {
        let spec = app.catalog.spec(core_ids[ci]);
        let unit = spec.cost_deploy + spec.cost_maint * env.cfg.sim.slots as f64;
        let total: u32 = (0..ne).map(|ei| genome[ei * nc + ci]).sum();
        cost += unit * total as f64;
        shortfall += (demand[ci] - total as f64).max(0.0);
        for (ei, &v) in es_nodes.iter().enumerate() {
            reward += scores.q[v][ci] * genome[ei * nc + ci].min(1) as f64;
        }
    }
    for (ei, &v) in es_nodes.iter().enumerate() {
        for k in 0..NUM_RESOURCES {
            let used: f64 = (0..nc)
                .map(|ci| app.catalog.spec(core_ids[ci]).resources[k] * genome[ei * nc + ci] as f64)
                .sum();
            cap_excess += (used - topo.node(v).capacity[k]).max(0.0);
        }
    }
    cost + params.shortfall_penalty * shortfall + params.capacity_penalty * cap_excess - reward
}

/// Decrement genes until every node fits its capacity.
fn repair_capacity(instances: &mut [Vec<u32>], env: &SimEnv) {
    let app = &env.app;
    let core_ids = app.catalog.core_ids();
    for (v, row) in instances.iter_mut().enumerate() {
        loop {
            let mut worst: Option<(usize, f64)> = None;
            for k in 0..NUM_RESOURCES {
                let used: f64 = row
                    .iter()
                    .enumerate()
                    .map(|(ci, &x)| app.catalog.spec(core_ids[ci]).resources[k] * x as f64)
                    .sum();
                let cap = env.topo.node(v).capacity[k];
                if used > cap {
                    let over = used - cap;
                    if worst.map_or(true, |(_, w)| over > w) {
                        worst = Some((k, over));
                    }
                }
            }
            let Some((k, _)) = worst else { break };
            // Remove the instance contributing most to resource k.
            let ci = (0..row.len())
                .filter(|&ci| row[ci] > 0)
                .max_by(|&a, &b| {
                    app.catalog.spec(core_ids[a]).resources[k]
                        .total_cmp(&app.catalog.spec(core_ids[b]).resources[k])
                });
            match ci {
                Some(ci) => row[ci] -= 1,
                None => break,
            }
        }
    }
}

/// Add instances on any feasible edge server until each MS covers demand.
fn cover_shortfall(instances: &mut Vec<Vec<u32>>, env: &SimEnv, demand: &[f64]) {
    let app = &env.app;
    let core_ids = app.catalog.core_ids();
    let nv = env.topo.num_nodes();
    let es_nodes: Vec<usize> = env.topo.ess().collect();
    for ci in 0..core_ids.len() {
        let spec = app.catalog.spec(core_ids[ci]);
        loop {
            let total: u32 = (0..nv).map(|v| instances[v][ci]).sum();
            if (total as f64) >= demand[ci] {
                break;
            }
            // First edge server with room.
            let mut placed = false;
            for &v in &es_nodes {
                let fits = (0..NUM_RESOURCES).all(|k| {
                    let used: f64 = instances[v]
                        .iter()
                        .enumerate()
                        .map(|(cj, &x)| app.catalog.spec(core_ids[cj]).resources[k] * x as f64)
                        .sum();
                    used + spec.resources[k] <= env.topo.node(v).capacity[k]
                });
                if fits {
                    instances[v][ci] += 1;
                    placed = true;
                    break;
                }
            }
            if !placed {
                break;
            }
        }
    }
    // Coverage guarantee: a service with zero instances is starvation, not
    // a cost saving. Evict surplus instances of other services until one
    // instance of the starved MS fits somewhere.
    for ci in 0..core_ids.len() {
        let total: u32 = (0..nv).map(|v| instances[v][ci]).sum();
        if total > 0 {
            continue;
        }
        let spec = app.catalog.spec(core_ids[ci]);
        'evict: for &v in &es_nodes {
            loop {
                let fits = (0..NUM_RESOURCES).all(|k| {
                    let used: f64 = instances[v]
                        .iter()
                        .enumerate()
                        .map(|(cj, &x)| app.catalog.spec(core_ids[cj]).resources[k] * x as f64)
                        .sum();
                    used + spec.resources[k] <= env.topo.node(v).capacity[k]
                });
                if fits {
                    instances[v][ci] += 1;
                    break 'evict;
                }
                // Evict from the most over-provisioned other MS here.
                let victim = (0..core_ids.len())
                    .filter(|&cj| cj != ci && instances[v][cj] > 0)
                    .max_by_key(|&cj| {
                        let tot: u32 = (0..nv).map(|vv| instances[vv][cj]).sum();
                        (tot as i64) - (demand[cj].ceil() as i64)
                    });
                match victim {
                    Some(cj) => instances[v][cj] -= 1,
                    None => break,
                }
            }
        }
    }
}
