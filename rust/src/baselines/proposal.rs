//! The paper's two-tier proposal, and its PropAvg ablation.

use crate::config::NUM_RESOURCES;
use crate::controller::{greedy_light_deployment, LightDecision, LightRequest, OnlineParams};
use crate::placement::{solve_static_placement, CorePlacement, PlacementParams, QosScores};
use crate::rng::Xoshiro256;
use crate::sim::SimEnv;

/// Full proposal: static ILP placement + effective-capacity Lyapunov
/// greedy controller.
pub struct Proposal {
    online: Option<OnlineParams>,
}

impl Proposal {
    pub fn new() -> Self {
        Proposal { online: None }
    }
}

impl Default for Proposal {
    fn default() -> Self {
        Self::new()
    }
}

impl crate::sim::Strategy for Proposal {
    fn name(&self) -> &str {
        "Proposal"
    }

    fn place_core(
        &mut self,
        env: &SimEnv,
        scores: &QosScores,
        _rng: &mut Xoshiro256,
    ) -> CorePlacement {
        let params = PlacementParams::from_config(&env.cfg, env.cfg.sim.slots);
        solve_static_placement(&env.app, &env.topo, scores, &params)
    }

    fn decide_light(
        &mut self,
        env: &SimEnv,
        _slot: usize,
        queue: &[LightRequest],
        busy: &[Vec<u32>],
        residual: &[[f64; NUM_RESOURCES]],
        dm: &crate::routing::DistanceMatrix,
        _rng: &mut Xoshiro256,
    ) -> LightDecision {
        let params = self
            .online
            .get_or_insert_with(|| OnlineParams::from_config(&env.cfg.controller));
        greedy_light_deployment(
            queue,
            busy,
            residual,
            &env.light_resources,
            &env.light_costs,
            &env.gtable,
            dm,
            params,
        )
    }
}

/// PropAvg ablation: identical two-tier logic but mean-value delay
/// estimates replace the effective-capacity map (§IV).
pub struct PropAvg {
    online: Option<OnlineParams>,
}

impl PropAvg {
    pub fn new() -> Self {
        PropAvg { online: None }
    }
}

impl Default for PropAvg {
    fn default() -> Self {
        Self::new()
    }
}

impl crate::sim::Strategy for PropAvg {
    fn name(&self) -> &str {
        "PropAvg"
    }

    fn place_core(
        &mut self,
        env: &SimEnv,
        scores: &QosScores,
        _rng: &mut Xoshiro256,
    ) -> CorePlacement {
        let params = PlacementParams::from_config(&env.cfg, env.cfg.sim.slots);
        solve_static_placement(&env.app, &env.topo, scores, &params)
    }

    fn decide_light(
        &mut self,
        env: &SimEnv,
        _slot: usize,
        queue: &[LightRequest],
        busy: &[Vec<u32>],
        residual: &[[f64; NUM_RESOURCES]],
        dm: &crate::routing::DistanceMatrix,
        _rng: &mut Xoshiro256,
    ) -> LightDecision {
        let params = self.online.get_or_insert_with(|| {
            let mut p = OnlineParams::from_config(&env.cfg.controller);
            p.use_mean_delay = true;
            p
        });
        greedy_light_deployment(
            queue,
            busy,
            residual,
            &env.light_resources,
            &env.light_costs,
            &env.gtable,
            dm,
            params,
        )
    }
}
