//! LBRR baseline: least-loaded placement + round-robin dispatch (§IV).
//!
//! Core services go to the currently least-loaded node (by normalized
//! residual capacity) until the demand estimate is met; light demand is
//! served by instantiating on the least-loaded feasible node and routing
//! queued tasks round-robin over deployed instances — deadline-agnostic
//! by design.

use crate::config::NUM_RESOURCES;
use crate::controller::{Assignment, LightDecision, LightRequest};
use crate::placement::{CorePlacement, QosScores};
use crate::rng::Xoshiro256;
use crate::sim::SimEnv;

pub struct LbrrStrategy {
    rr_counter: usize,
}

impl LbrrStrategy {
    pub fn new() -> Self {
        LbrrStrategy { rr_counter: 0 }
    }
}

impl Default for LbrrStrategy {
    fn default() -> Self {
        Self::new()
    }
}

/// Normalized load of a node: max over resources of used/capacity.
fn norm_load(used: &[f64; NUM_RESOURCES], cap: &[f64; NUM_RESOURCES]) -> f64 {
    (0..NUM_RESOURCES)
        .map(|k| if cap[k] > 0.0 { used[k] / cap[k] } else { 0.0 })
        .fold(0.0, f64::max)
}

impl crate::sim::Strategy for LbrrStrategy {
    fn name(&self) -> &str {
        "LBRR"
    }

    fn place_core(
        &mut self,
        env: &SimEnv,
        scores: &QosScores,
        _rng: &mut Xoshiro256,
    ) -> CorePlacement {
        let app = &env.app;
        let topo = &env.topo;
        let core_ids = app.catalog.core_ids();
        let nv = topo.num_nodes();
        let nc = core_ids.len();
        let mut instances = vec![vec![0u32; nc]; nv];
        let mut used = vec![[0.0f64; NUM_RESOURCES]; nv];

        // Coverage first: one instance of every MS so no service is
        // starved, then scale toward the demand estimate least-loaded.
        for round in 0..2 {
            for ci in 0..nc {
            let spec = app.catalog.spec(core_ids[ci]);
            let demand = if round == 0 {
                1
            } else {
                scores
                    .erlang_demand(ci, spec.mean_proc_delay(), env.cfg.sim.slot_ms)
                    .ceil()
                    .max(1.0) as usize
            };
            let have: u32 = (0..nv).map(|v| instances[v][ci]).sum();
            for _ in (have as usize)..demand {
                // Least-loaded edge server that fits the instance (core
                // services live on ESs; see §I and PlacementParams).
                let mut best: Option<(usize, f64)> = None;
                for v in topo.ess() {
                    let cap = topo.node(v).capacity;
                    let fits = (0..NUM_RESOURCES)
                        .all(|k| used[v][k] + spec.resources[k] <= cap[k]);
                    if !fits {
                        continue;
                    }
                    let load = norm_load(&used[v], &cap);
                    if best.map_or(true, |(_, b)| load < b) {
                        best = Some((v, load));
                    }
                }
                let Some((v, _)) = best else { break };
                for k in 0..NUM_RESOURCES {
                    used[v][k] += spec.resources[k];
                }
                instances[v][ci] += 1;
            }
            }
        }
        let support = instances
            .iter()
            .flat_map(|r| r.iter())
            .filter(|&&x| x > 0)
            .count();
        CorePlacement {
            instances,
            objective: 0.0,
            used_fallback: false,
            support,
            demand_target: Vec::new(),
            stats: None,
        }
    }

    fn decide_light(
        &mut self,
        env: &SimEnv,
        _slot: usize,
        queue: &[LightRequest],
        busy: &[Vec<u32>],
        residual: &[[f64; NUM_RESOURCES]],
        dm: &crate::routing::DistanceMatrix,
        _rng: &mut Xoshiro256,
    ) -> LightDecision {
        let nv = busy.len();
        let nl = env.light_resources.len();
        let max_y = env.gtable.max_parallelism().max(1);
        let mut x = busy.to_vec();
        let mut residual = residual.to_vec();
        let mut y = vec![vec![0u32; nl]; nv];
        let mut assignments: Vec<Option<Assignment>> = vec![None; queue.len()];

        // Demand per MS; ensure enough instances exist (least-loaded
        // placement), then round-robin tasks over them.
        let mut demand = vec![0usize; nl];
        for r in queue {
            demand[r.light_idx] += 1;
        }
        for m in 0..nl {
            let have: usize = x.iter().map(|r| r[m] as usize).sum::<usize>() * max_y;
            let mut need = demand[m].saturating_sub(have);
            while need > 0 {
                // Least-loaded feasible node by residual CPU fraction.
                let mut best: Option<(usize, f64)> = None;
                for v in 0..nv {
                    let fits = (0..NUM_RESOURCES)
                        .all(|k| residual[v][k] >= env.light_resources[m][k]);
                    if !fits {
                        continue;
                    }
                    let cap = env.topo.node(v).capacity;
                    let free: f64 = (0..NUM_RESOURCES)
                        .map(|k| if cap[k] > 0.0 { residual[v][k] / cap[k] } else { 1.0 })
                        .sum();
                    if best.map_or(true, |(_, b)| free > b) {
                        best = Some((v, free));
                    }
                }
                let Some((v, _)) = best else { break };
                for k in 0..NUM_RESOURCES {
                    residual[v][k] -= env.light_resources[m][k];
                }
                x[v][m] += 1;
                need = need.saturating_sub(max_y);
            }
        }

        // Round-robin dispatch (deadline-agnostic).
        for (qi, r) in queue.iter().enumerate() {
            let m = r.light_idx;
            let hosts: Vec<usize> = (0..nv).filter(|&v| x[v][m] > 0).collect();
            if hosts.is_empty() {
                continue;
            }
            // Try each host starting at the RR pointer until one has room.
            let mut chosen = None;
            for off in 0..hosts.len() {
                let v = hosts[(self.rr_counter + off) % hosts.len()];
                if y[v][m] < x[v][m] * max_y as u32 {
                    chosen = Some(v);
                    break;
                }
            }
            self.rr_counter = self.rr_counter.wrapping_add(1);
            let Some(v) = chosen else { continue };
            let per_inst = ((y[v][m] + 1) as usize).div_ceil(x[v][m] as usize);
            y[v][m] += 1;
            assignments[qi] = Some(Assignment {
                node: v,
                light_idx: m,
                y: per_inst as u32,
                transfer_ms: dm.latency(r.from_node, v, r.payload_mb),
                est_proc_ms: env.gtable.mean_delay(m, per_inst),
            });
        }

        LightDecision {
            x,
            y,
            assignments,
            stats: Default::default(),
        }
    }
}
