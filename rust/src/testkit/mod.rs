//! Minimal property-based testing framework (proptest is unavailable
//! offline). Provides composable generators over the crate's deterministic
//! RNG, a runner that reports the failing case, and greedy shrinking for
//! integers and vectors.
//!
//! Usage (`no_run`: doctest binaries don't inherit the libxla rpath):
//! ```no_run
//! use fmedge::testkit::{self, Gen};
//! testkit::check(100, testkit::vec_of(testkit::u64_up_to(50), 0..20), |xs| {
//!     let mut s = xs.clone();
//!     s.sort_unstable();
//!     s.windows(2).all(|w| w[0] <= w[1])
//! });
//! ```

use crate::rng::{Rng, Xoshiro256};

/// A value generator with an attached shrinker.
pub trait Gen {
    type Value: Clone + std::fmt::Debug;

    /// Produce a random value.
    fn generate<R: Rng + ?Sized>(&self, rng: &mut R) -> Self::Value;

    /// Candidate smaller values (tried in order during shrinking).
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let _ = v;
        Vec::new()
    }
}

/// Run `cases` random cases of `prop` over `gen`; panic with the smallest
/// failing input found by greedy shrinking.
pub fn check<G, F>(cases: usize, gen: G, prop: F)
where
    G: Gen,
    F: Fn(&G::Value) -> bool,
{
    check_seeded(0xF00D_CAFE, cases, gen, prop)
}

/// `check` with an explicit seed (tests that want distinct streams).
pub fn check_seeded<G, F>(seed: u64, cases: usize, gen: G, prop: F)
where
    G: Gen,
    F: Fn(&G::Value) -> bool,
{
    let mut rng = Xoshiro256::seed_from(seed);
    for case in 0..cases {
        let v = gen.generate(&mut rng);
        if !prop(&v) {
            let minimal = shrink_to_minimal(&gen, v, &prop);
            panic!(
                "property falsified at case {case}/{cases}; minimal counterexample: {minimal:?}"
            );
        }
    }
}

fn shrink_to_minimal<G, F>(gen: &G, mut failing: G::Value, prop: &F) -> G::Value
where
    G: Gen,
    F: Fn(&G::Value) -> bool,
{
    // Greedy descent, bounded to avoid pathological loops.
    for _ in 0..10_000 {
        let mut improved = false;
        for cand in gen.shrink(&failing) {
            if !prop(&cand) {
                failing = cand;
                improved = true;
                break;
            }
        }
        if !improved {
            break;
        }
    }
    failing
}

// ---------------------------------------------------------------- generators

/// Uniform u64 in `[0, max]`, shrinking toward 0.
pub fn u64_up_to(max: u64) -> U64UpTo {
    U64UpTo { max }
}

#[derive(Clone, Copy)]
pub struct U64UpTo {
    max: u64,
}

impl Gen for U64UpTo {
    type Value = u64;
    fn generate<R: Rng + ?Sized>(&self, rng: &mut R) -> u64 {
        if self.max == u64::MAX {
            rng.next_u64()
        } else {
            rng.next_below(self.max + 1)
        }
    }
    fn shrink(&self, v: &u64) -> Vec<u64> {
        let mut out = Vec::new();
        if *v > 0 {
            out.push(0);
            out.push(v / 2);
            out.push(v - 1);
        }
        out.dedup();
        out
    }
}

/// usize in `[lo, hi]`, shrinking toward `lo`.
pub fn usize_in(lo: usize, hi: usize) -> UsizeIn {
    UsizeIn { lo, hi }
}

#[derive(Clone, Copy)]
pub struct UsizeIn {
    lo: usize,
    hi: usize,
}

impl Gen for UsizeIn {
    type Value = usize;
    fn generate<R: Rng + ?Sized>(&self, rng: &mut R) -> usize {
        rng.range_usize(self.lo, self.hi)
    }
    fn shrink(&self, v: &usize) -> Vec<usize> {
        let mut out = Vec::new();
        if *v > self.lo {
            out.push(self.lo);
            out.push(self.lo + (v - self.lo) / 2);
            out.push(v - 1);
        }
        out.dedup();
        out
    }
}

/// f64 in `[lo, hi)`, shrinking toward `lo`.
pub fn f64_in(lo: f64, hi: f64) -> F64In {
    F64In { lo, hi }
}

#[derive(Clone, Copy)]
pub struct F64In {
    lo: f64,
    hi: f64,
}

impl Gen for F64In {
    type Value = f64;
    fn generate<R: Rng + ?Sized>(&self, rng: &mut R) -> f64 {
        rng.range_f64(self.lo, self.hi)
    }
    fn shrink(&self, v: &f64) -> Vec<f64> {
        let mut out = Vec::new();
        if *v > self.lo {
            out.push(self.lo);
            out.push(self.lo + (v - self.lo) / 2.0);
        }
        out
    }
}

/// Vector of `inner` with length drawn from `len_range`, shrinking by
/// removing elements then shrinking elements.
pub fn vec_of<G: Gen>(inner: G, len_range: std::ops::Range<usize>) -> VecOf<G> {
    VecOf { inner, len_range }
}

pub struct VecOf<G: Gen> {
    inner: G,
    len_range: std::ops::Range<usize>,
}

impl<G: Gen> Gen for VecOf<G> {
    type Value = Vec<G::Value>;
    fn generate<R: Rng + ?Sized>(&self, rng: &mut R) -> Vec<G::Value> {
        let lo = self.len_range.start;
        let hi = self.len_range.end.max(lo + 1) - 1;
        let n = rng.range_usize(lo, hi);
        (0..n).map(|_| self.inner.generate(rng)).collect()
    }
    fn shrink(&self, v: &Vec<G::Value>) -> Vec<Vec<G::Value>> {
        let mut out = Vec::new();
        let min_len = self.len_range.start;
        // Remove halves, then single elements.
        if v.len() > min_len {
            let half = (v.len() + min_len) / 2;
            out.push(v[..half.max(min_len)].to_vec());
            for i in 0..v.len() {
                if v.len() - 1 >= min_len {
                    let mut c = v.clone();
                    c.remove(i);
                    out.push(c);
                }
            }
        }
        // Shrink each element in place.
        for (i, elem) in v.iter().enumerate() {
            for smaller in self.inner.shrink(elem) {
                let mut c = v.clone();
                c[i] = smaller;
                out.push(c);
            }
        }
        out
    }
}

/// Pair generator.
pub fn pair_of<A: Gen, B: Gen>(a: A, b: B) -> PairOf<A, B> {
    PairOf { a, b }
}

pub struct PairOf<A: Gen, B: Gen> {
    a: A,
    b: B,
}

impl<A: Gen, B: Gen> Gen for PairOf<A, B> {
    type Value = (A::Value, B::Value);
    fn generate<R: Rng + ?Sized>(&self, rng: &mut R) -> Self::Value {
        (self.a.generate(rng), self.b.generate(rng))
    }
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out: Vec<Self::Value> = self
            .a
            .shrink(&v.0)
            .into_iter()
            .map(|a| (a, v.1.clone()))
            .collect();
        out.extend(self.b.shrink(&v.1).into_iter().map(|b| (v.0.clone(), b)));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        check(200, u64_up_to(1000), |&v| v <= 1000);
    }

    #[test]
    #[should_panic(expected = "minimal counterexample")]
    fn failing_property_panics_with_counterexample() {
        check(200, u64_up_to(1000), |&v| v < 500);
    }

    #[test]
    fn shrinking_finds_small_counterexample() {
        // Catch the panic and verify the shrunk value is the boundary.
        let result = std::panic::catch_unwind(|| {
            check(500, u64_up_to(100_000), |&v| v < 777);
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        assert!(msg.contains("777"), "expected shrink to 777, got: {msg}");
    }

    #[test]
    fn vec_shrinking_reduces_length() {
        let result = std::panic::catch_unwind(|| {
            check(300, vec_of(u64_up_to(10), 0..30), |xs| xs.len() < 5);
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        // Minimal failing vector has exactly 5 elements.
        let count = msg.matches(',').count() + 1;
        assert!(count <= 6, "shrunk vec should be near-minimal: {msg}");
    }

    #[test]
    fn pair_generator_shrinks_both_sides() {
        let result = std::panic::catch_unwind(|| {
            check(
                300,
                pair_of(u64_up_to(100), u64_up_to(100)),
                |&(a, b)| a + b < 50,
            );
        });
        assert!(result.is_err());
    }
}
