//! Core-service routing: pick the placed instance minimizing next-hop
//! completion time (transfer + queueing wait + deterministic processing).
//!
//! Core instances run under strict isolation (§II-A), each serving one
//! task at a time; a per-instance `busy_until` clock models the queue.

use super::DistanceMatrix;

/// Routing decision for one core-stage execution.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CoreAssignment {
    pub node: usize,
    /// Instance slot index on that node.
    pub instance: usize,
    /// When the instance starts the task (ms, absolute).
    pub start_ms: f64,
    /// Completion time (ms, absolute).
    pub done_ms: f64,
    /// Transfer latency component (ms).
    pub transfer_ms: f64,
}

/// Tracks per-instance availability for every placed core instance.
#[derive(Clone, Debug)]
pub struct CoreRouter {
    /// `busy_until[v][m]` = sorted clock per instance of core MS `m` at `v`.
    busy_until: Vec<Vec<Vec<f64>>>,
    /// Instance counts stashed while a node is down (fault injection);
    /// restored — with fresh clocks — on recovery.
    offline: Vec<Vec<u32>>,
    /// Replicas fail-stopped by `kill_instance`, eligible to `rejoin`
    /// from their last checkpoint (or a cold start if none was taken).
    failed: Vec<Vec<u32>>,
    /// Last checkpoint time per `(v, m)` service state; `None` until the
    /// first `checkpoint` call covers that pair.
    checkpoint_ms: Vec<Vec<Option<f64>>>,
    /// Completed checkpoint-restores (telemetry for `TrialMetrics`).
    restores: u64,
    num_core: usize,
}

impl CoreRouter {
    /// Build from a core placement matrix `instances[v][m]`.
    pub fn new(instances: &[Vec<u32>]) -> Self {
        let num_core = instances.first().map_or(0, Vec::len);
        let busy_until: Vec<Vec<Vec<f64>>> = instances
            .iter()
            .map(|row| row.iter().map(|&c| vec![0.0f64; c as usize]).collect())
            .collect();
        let offline = vec![vec![0u32; num_core]; busy_until.len()];
        let failed = offline.clone();
        let checkpoint_ms = vec![vec![None; num_core]; busy_until.len()];
        CoreRouter {
            busy_until,
            offline,
            failed,
            checkpoint_ms,
            restores: 0,
            num_core,
        }
    }

    /// Periodic lightweight snapshot: stamp every `(v, m)` pair that has
    /// at least one live replica. A later `rejoin` at that pair restores
    /// from this stamp on the fast clock instead of cold-starting.
    /// Returns how many pairs were stamped.
    pub fn checkpoint(&mut self, now_ms: f64) -> usize {
        let mut stamped = 0;
        for (v, row) in self.busy_until.iter().enumerate() {
            for m in 0..self.num_core {
                if !row[m].is_empty() {
                    self.checkpoint_ms[v][m] = Some(now_ms);
                    stamped += 1;
                }
            }
        }
        stamped
    }

    /// Bring one fail-stopped replica of `(v, m)` back into service with
    /// its clock free from `ready_ms`. Returns `false` when nothing is
    /// waiting to be restored there (a schedule no-op).
    pub fn restore(&mut self, v: usize, m: usize, ready_ms: f64) -> bool {
        if m >= self.num_core || self.failed[v][m] == 0 {
            return false;
        }
        self.failed[v][m] -= 1;
        self.busy_until[v][m].push(ready_ms);
        self.restores += 1;
        true
    }

    /// Checkpoint/restart: a fail-stopped replica of `(v, m)` rejoins at
    /// `now_ms + restore_ms` when a checkpoint covers the pair, or
    /// `now_ms + cold_start_ms` when it must rebuild state from scratch.
    /// Returns the readiness time, or `None` when no replica is waiting.
    pub fn rejoin(
        &mut self,
        v: usize,
        m: usize,
        now_ms: f64,
        restore_ms: f64,
        cold_start_ms: f64,
    ) -> Option<f64> {
        if m >= self.num_core || self.failed[v][m] == 0 {
            return None;
        }
        let delay = if self.checkpoint_ms[v][m].is_some() {
            restore_ms
        } else {
            cold_start_ms
        };
        let ready = now_ms + delay;
        self.restore(v, m, ready).then_some(ready)
    }

    /// Checkpoint-restores completed so far.
    pub fn restores(&self) -> u64 {
        self.restores
    }

    /// Fault injection: the node went dark. Resident replicas go offline
    /// (their in-flight work is cancelled by the engine) and are stashed
    /// for recovery.
    pub fn set_node_down(&mut self, v: usize) {
        for m in 0..self.num_core {
            self.offline[v][m] += self.busy_until[v][m].len() as u32;
            self.busy_until[v][m].clear();
        }
    }

    /// Fault injection: the node recovered — replicas come back idle from
    /// `now_ms` (restart semantics: no pre-outage queue state survives).
    pub fn set_node_up(&mut self, v: usize, now_ms: f64) {
        for m in 0..self.num_core {
            let count = self.offline[v][m] as usize;
            self.offline[v][m] = 0;
            self.busy_until[v][m].extend(std::iter::repeat(now_ms).take(count));
        }
    }

    /// Fault injection: one replica of core MS `m` at `v` fail-stops (it
    /// finishes its current task but accepts no new work). Returns whether
    /// a replica was actually present — a miss is a schedule no-op.
    pub fn kill_instance(&mut self, v: usize, m: usize) -> bool {
        if m >= self.num_core {
            return false;
        }
        if self.busy_until[v][m].pop().is_some() {
            self.failed[v][m] += 1;
            return true;
        }
        // Node currently down: decommission one stashed replica instead.
        if self.offline[v][m] > 0 {
            self.offline[v][m] -= 1;
            self.failed[v][m] += 1;
            return true;
        }
        false
    }

    /// Nodes hosting at least one instance of core MS `m` (dense core idx).
    pub fn nodes_hosting(&self, m: usize) -> impl Iterator<Item = usize> + '_ {
        self.busy_until
            .iter()
            .enumerate()
            .filter(move |(_, row)| !row[m].is_empty())
            .map(|(v, _)| v)
    }

    /// Total placed instances of core MS `m`.
    pub fn total_instances(&self, m: usize) -> usize {
        self.busy_until.iter().map(|row| row[m].len()).sum()
    }

    /// Route a core stage whose input payloads come from multiple DAG
    /// parents: `parents` holds `(node, ready_ms, payload_mb)` triples and
    /// the arrival at a candidate node is the max over parents of
    /// `ready + transfer` (eq. 4's inner max). `now_ms` lower-bounds the
    /// start (decisions take effect from the current slot).
    pub fn route_multi(
        &mut self,
        m: usize,
        parents: &[(usize, f64, f64)],
        proc_ms: f64,
        now_ms: f64,
        dm: &DistanceMatrix,
    ) -> Option<CoreAssignment> {
        debug_assert!(m < self.num_core);
        let mut best: Option<CoreAssignment> = None;
        for (v, row) in self.busy_until.iter().enumerate() {
            if row[m].is_empty() {
                continue;
            }
            let mut arrive = now_ms;
            let mut transfer = 0.0f64;
            for &(pn, ready, mb) in parents {
                let tr = dm.latency(pn, v, mb);
                transfer = transfer.max(tr);
                arrive = arrive.max(ready + tr);
            }
            // Unreachable under the current fault state: not a candidate.
            if !arrive.is_finite() {
                continue;
            }
            let (idx, &free) = row[m]
                .iter()
                .enumerate()
                .min_by(|a, b| a.1.total_cmp(b.1))
                .expect("non-empty");
            let start = arrive.max(free);
            let done = start + proc_ms;
            if best.as_ref().map_or(true, |b| done < b.done_ms) {
                best = Some(CoreAssignment {
                    node: v,
                    instance: idx,
                    start_ms: start,
                    done_ms: done,
                    transfer_ms: transfer,
                });
            }
        }
        if let Some(a) = &best {
            self.busy_until[a.node][m][a.instance] = a.done_ms;
        }
        best
    }

    /// Route one execution of core MS `m` (dense core index):
    ///
    /// * `from` — node holding the input payload,
    /// * `ready_ms` — when the payload is ready there,
    /// * `payload_mb` — size to move,
    /// * `proc_ms` — deterministic processing delay `a_m / f_m`.
    ///
    /// Greedy ΔT rule: minimize completion = max(ready + transfer,
    /// instance-free) + proc over all placed instances; commits the chosen
    /// instance's clock. Returns `None` when the MS has no instance.
    pub fn route(
        &mut self,
        m: usize,
        from: usize,
        ready_ms: f64,
        payload_mb: f64,
        proc_ms: f64,
        dm: &DistanceMatrix,
    ) -> Option<CoreAssignment> {
        debug_assert!(m < self.num_core);
        let mut best: Option<CoreAssignment> = None;
        for (v, row) in self.busy_until.iter().enumerate() {
            if row[m].is_empty() {
                continue;
            }
            let transfer = dm.latency(from, v, payload_mb);
            let arrive = ready_ms + transfer;
            // Unreachable under the current fault state: not a candidate.
            if !arrive.is_finite() {
                continue;
            }
            // Earliest-free instance on this node.
            let (idx, &free) = row[m]
                .iter()
                .enumerate()
                .min_by(|a, b| a.1.total_cmp(b.1))
                .expect("non-empty");
            let start = arrive.max(free);
            let done = start + proc_ms;
            let better = best.as_ref().map_or(true, |b| done < b.done_ms);
            if better {
                best = Some(CoreAssignment {
                    node: v,
                    instance: idx,
                    start_ms: start,
                    done_ms: done,
                    transfer_ms: transfer,
                });
            }
        }
        if let Some(a) = &best {
            self.busy_until[a.node][m][a.instance] = a.done_ms;
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;
    use crate::network::Topology;
    use crate::rng::Xoshiro256;

    fn setup() -> (Topology, DistanceMatrix) {
        let cfg = ExperimentConfig::paper_default();
        let mut rng = Xoshiro256::seed_from(1);
        let t = Topology::generate(&cfg, &mut rng);
        let dm = DistanceMatrix::build(&t, 1.0);
        (t, dm)
    }

    #[test]
    fn routes_to_only_available_instance() {
        let (t, dm) = setup();
        let mut inst = vec![vec![0u32; 2]; t.num_nodes()];
        inst[13][0] = 1;
        let mut router = CoreRouter::new(&inst);
        let a = router.route(0, 0, 5.0, 1.0, 2.0, &dm).unwrap();
        assert_eq!(a.node, 13);
        assert!((a.done_ms - (5.0 + a.transfer_ms + 2.0)).abs() < 1e-12);
    }

    #[test]
    fn missing_service_returns_none() {
        let (t, dm) = setup();
        let inst = vec![vec![0u32; 2]; t.num_nodes()];
        let mut router = CoreRouter::new(&inst);
        assert!(router.route(1, 0, 0.0, 1.0, 1.0, &dm).is_none());
    }

    #[test]
    fn queueing_serializes_on_one_instance() {
        let (t, dm) = setup();
        let mut inst = vec![vec![0u32; 1]; t.num_nodes()];
        inst[12][0] = 1;
        let mut router = CoreRouter::new(&inst);
        let a1 = router.route(0, 12, 0.0, 1.0, 3.0, &dm).unwrap();
        let a2 = router.route(0, 12, 0.0, 1.0, 3.0, &dm).unwrap();
        assert_eq!(a1.start_ms, 0.0);
        assert!((a2.start_ms - 3.0).abs() < 1e-12, "second task must wait");
        assert!((a2.done_ms - 6.0).abs() < 1e-12);
    }

    #[test]
    fn prefers_idle_replica_over_busy_nearer_one() {
        let (t, dm) = setup();
        let mut inst = vec![vec![0u32; 1]; t.num_nodes()];
        inst[12][0] = 1;
        inst[15][0] = 1;
        let mut router = CoreRouter::new(&inst);
        // Saturate node 12 (co-located with the source).
        for _ in 0..5 {
            router.route(0, 12, 0.0, 0.1, 10.0, &dm).unwrap();
        }
        let a = router.route(0, 12, 0.0, 0.1, 10.0, &dm).unwrap();
        assert_eq!(
            a.node, 15,
            "busy local replica should lose to an idle remote one"
        );
    }

    #[test]
    fn two_instances_on_same_node_parallelize() {
        let (t, dm) = setup();
        let mut inst = vec![vec![0u32; 1]; t.num_nodes()];
        inst[14][0] = 2;
        let mut router = CoreRouter::new(&inst);
        let a1 = router.route(0, 14, 0.0, 1.0, 4.0, &dm).unwrap();
        let a2 = router.route(0, 14, 0.0, 1.0, 4.0, &dm).unwrap();
        assert_eq!(a1.start_ms, 0.0);
        assert_eq!(a2.start_ms, 0.0, "second instance serves in parallel");
        assert_ne!(a1.instance, a2.instance);
    }

    #[test]
    fn node_down_diverts_and_recovery_restores() {
        let (t, dm) = setup();
        let mut inst = vec![vec![0u32; 1]; t.num_nodes()];
        inst[12][0] = 1;
        inst[15][0] = 1;
        let mut router = CoreRouter::new(&inst);
        router.set_node_down(12);
        assert_eq!(router.total_instances(0), 1);
        let a = router.route(0, 12, 0.0, 1.0, 2.0, &dm).unwrap();
        assert_eq!(a.node, 15, "dead node must not be routed to");
        router.set_node_up(12, 100.0);
        assert_eq!(router.total_instances(0), 2);
        // The recovered replica is idle from its restart time.
        let b = router.route(0, 12, 200.0, 0.01, 2.0, &dm).unwrap();
        assert_eq!(b.node, 12);
    }

    #[test]
    fn kill_instance_decommissions_one_replica() {
        let (t, dm) = setup();
        let mut inst = vec![vec![0u32; 1]; t.num_nodes()];
        inst[13][0] = 2;
        let mut router = CoreRouter::new(&inst);
        assert!(router.kill_instance(13, 0));
        assert_eq!(router.total_instances(0), 1);
        assert!(router.route(0, 13, 0.0, 1.0, 1.0, &dm).is_some());
        assert!(router.kill_instance(13, 0));
        assert!(!router.kill_instance(13, 0), "nothing left to kill");
        assert!(router.route(0, 13, 0.0, 1.0, 1.0, &dm).is_none());
        assert!(!router.kill_instance(13, 9), "bad core idx is a no-op");
    }

    #[test]
    fn unreachable_candidates_are_skipped() {
        let (t, _) = setup();
        let mut inst = vec![vec![0u32; 1]; t.num_nodes()];
        inst[12][0] = 1;
        let mut router = CoreRouter::new(&inst);
        // A distance matrix where node 12 is unreachable from everywhere.
        let topo_links: Vec<crate::network::Link> = t
            .links()
            .iter()
            .filter(|l| l.a != 12 && l.b != 12)
            .cloned()
            .collect();
        let cut = crate::network::Topology::from_parts(
            t.nodes().to_vec(),
            topo_links,
            t.prop_speed_km_per_ms,
        );
        let dm_cut = DistanceMatrix::build(&cut, 1.0);
        assert!(dm_cut.latency(0, 12, 1.0).is_infinite());
        assert!(
            router.route(0, 0, 0.0, 1.0, 1.0, &dm_cut).is_none(),
            "only instance is unreachable: no route"
        );
    }

    #[test]
    fn rejoin_uses_checkpoint_clock_when_available() {
        let (t, dm) = setup();
        let mut inst = vec![vec![0u32; 1]; t.num_nodes()];
        inst[13][0] = 1;
        let mut router = CoreRouter::new(&inst);
        // No checkpoint yet: a killed replica rejoins on the cold clock.
        assert!(router.kill_instance(13, 0));
        assert_eq!(router.total_instances(0), 0);
        let ready = router.rejoin(13, 0, 100.0, 5.0, 25.0).unwrap();
        assert!((ready - 125.0).abs() < 1e-12, "cold start: {ready}");
        assert_eq!(router.total_instances(0), 1);
        assert_eq!(router.restores(), 1);
        // With a checkpoint covering (13, 0), rejoin is fast.
        assert_eq!(router.checkpoint(150.0), 1);
        assert!(router.kill_instance(13, 0));
        let ready = router.rejoin(13, 0, 200.0, 5.0, 25.0).unwrap();
        assert!((ready - 205.0).abs() < 1e-12, "restore: {ready}");
        assert_eq!(router.restores(), 2);
        // The rejoined replica is routable and free from its ready time.
        let a = router.route(0, 13, 0.0, 0.01, 2.0, &dm).unwrap();
        assert!(a.start_ms >= 205.0, "busy until rejoin completes");
    }

    #[test]
    fn rejoin_without_failed_replica_is_noop() {
        let (t, _) = setup();
        let mut inst = vec![vec![0u32; 1]; t.num_nodes()];
        inst[12][0] = 1;
        let mut router = CoreRouter::new(&inst);
        assert!(router.rejoin(12, 0, 0.0, 5.0, 25.0).is_none());
        assert!(!router.restore(12, 0, 0.0));
        assert!(router.rejoin(12, 9, 0.0, 5.0, 25.0).is_none(), "bad idx");
        assert_eq!(router.restores(), 0);
        assert_eq!(router.total_instances(0), 1, "nothing double-added");
    }

    #[test]
    fn kill_while_node_down_still_rejoins() {
        let (t, _) = setup();
        let mut inst = vec![vec![0u32; 1]; t.num_nodes()];
        inst[14][0] = 2;
        let mut router = CoreRouter::new(&inst);
        router.checkpoint(10.0);
        router.set_node_down(14);
        assert!(router.kill_instance(14, 0), "kills a stashed replica");
        router.set_node_up(14, 50.0);
        assert_eq!(router.total_instances(0), 1, "one survived the outage");
        let ready = router.rejoin(14, 0, 60.0, 5.0, 25.0).unwrap();
        assert!((ready - 65.0).abs() < 1e-12, "checkpointed fast restore");
        assert_eq!(router.total_instances(0), 2);
    }

    #[test]
    fn total_instances_counts() {
        let (t, _) = setup();
        let mut inst = vec![vec![0u32; 3]; t.num_nodes()];
        inst[1][2] = 2;
        inst[5][2] = 1;
        let router = CoreRouter::new(&inst);
        assert_eq!(router.total_instances(2), 3);
        assert_eq!(router.nodes_hosting(2).collect::<Vec<_>>(), vec![1, 5]);
        assert_eq!(router.total_instances(0), 0);
    }
}
