//! Task routing: precomputed network distances and next-hop instance
//! selection (the `ΔT_j` machinery of §III-B).
//!
//! The online controller evaluates `τ_tr + τ_pp` between every (current
//! node, candidate node) pair inside its greedy loop; doing a Dijkstra per
//! evaluation would dominate the per-slot budget, so [`DistanceMatrix`]
//! linearizes routed latency as `base(a,b) + mb · per_mb(a,b)` along the
//! reference-payload shortest route — exact when the route is payload-
//! independent, and within a few percent otherwise (see `bench_alg1`).

mod core_router;

pub use core_router::{CoreAssignment, CoreRouter};

use crate::network::Topology;

/// All-pairs routed-latency model, decomposed into a payload-independent
/// propagation component and a per-MB transmission component.
#[derive(Clone, Debug)]
pub struct DistanceMatrix {
    n: usize,
    /// Propagation (ms): Σ distance/l along the route.
    base: Vec<f64>,
    /// Transmission (ms/MB): Σ 1/w along the route.
    per_mb: Vec<f64>,
}

impl DistanceMatrix {
    /// Build from a topology using `ref_mb` as the payload that defines
    /// the routes (1 MB by default in callers).
    pub fn build(topo: &Topology, ref_mb: f64) -> Self {
        let n = topo.num_nodes();
        let mut base = vec![0.0; n * n];
        let mut per_mb = vec![0.0; n * n];
        for src in 0..n {
            let sp = topo.shortest_paths(src, ref_mb);
            for dst in 0..n {
                if src == dst {
                    continue;
                }
                let path = sp.path_to(dst);
                let mut b = 0.0;
                let mut p = 0.0;
                for w in path.windows(2) {
                    // Find the best link between consecutive hops.
                    let mut best: Option<(f64, f64)> = None;
                    for l in topo.links() {
                        if (l.a == w[0] && l.b == w[1]) || (l.a == w[1] && l.b == w[0]) {
                            let cand = (
                                l.distance_km / topo.prop_speed_km_per_ms,
                                1.0 / l.bandwidth_mb_ms,
                            );
                            let cand_lat = cand.0 + ref_mb * cand.1;
                            match best {
                                None => best = Some(cand),
                                Some(cur) if cand_lat < cur.0 + ref_mb * cur.1 => {
                                    best = Some(cand)
                                }
                                _ => {}
                            }
                        }
                    }
                    let (db, dp) = best.expect("path hops are adjacent");
                    b += db;
                    p += dp;
                }
                base[src * n + dst] = b;
                per_mb[src * n + dst] = p;
            }
        }
        DistanceMatrix { n, base, per_mb }
    }

    /// Routed latency for payload `mb` from `a` to `b` (ms). Zero when
    /// `a == b`.
    #[inline]
    pub fn latency(&self, a: usize, b: usize, mb: f64) -> f64 {
        self.base[a * self.n + b] + mb * self.per_mb[a * self.n + b]
    }

    /// Propagation-only component (payload-independent).
    #[inline]
    pub fn propagation(&self, a: usize, b: usize) -> f64 {
        self.base[a * self.n + b]
    }

    pub fn num_nodes(&self) -> usize {
        self.n
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;
    use crate::rng::Xoshiro256;

    fn topo(seed: u64) -> Topology {
        let cfg = ExperimentConfig::paper_default();
        let mut rng = Xoshiro256::seed_from(seed);
        Topology::generate(&cfg, &mut rng)
    }

    #[test]
    fn matrix_matches_dijkstra_at_reference_payload() {
        let t = topo(1);
        let dm = DistanceMatrix::build(&t, 1.0);
        for src in 0..t.num_nodes() {
            let sp = t.shortest_paths(src, 1.0);
            for dst in 0..t.num_nodes() {
                assert!(
                    (dm.latency(src, dst, 1.0) - sp.dist[dst]).abs() < 1e-9,
                    "({src},{dst}): {} vs {}",
                    dm.latency(src, dst, 1.0),
                    sp.dist[dst]
                );
            }
        }
    }

    #[test]
    fn latency_linear_in_payload() {
        let t = topo(2);
        let dm = DistanceMatrix::build(&t, 1.0);
        let l1 = dm.latency(0, 14, 1.0);
        let l2 = dm.latency(0, 14, 3.0);
        let slope = dm.latency(0, 14, 2.0) - l1;
        assert!((l2 - l1 - 2.0 * slope).abs() < 1e-9);
    }

    #[test]
    fn self_latency_is_zero() {
        let t = topo(3);
        let dm = DistanceMatrix::build(&t, 1.0);
        for v in 0..t.num_nodes() {
            assert_eq!(dm.latency(v, v, 5.0), 0.0);
        }
    }

    #[test]
    fn symmetric_for_undirected_links() {
        let t = topo(4);
        let dm = DistanceMatrix::build(&t, 1.0);
        for a in 0..t.num_nodes() {
            for b in 0..t.num_nodes() {
                assert!(
                    (dm.latency(a, b, 1.0) - dm.latency(b, a, 1.0)).abs() < 1e-9,
                    "asymmetric routed latency ({a},{b})"
                );
            }
        }
    }
}
