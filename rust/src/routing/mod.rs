//! Task routing: precomputed network distances and next-hop instance
//! selection (the `ΔT_j` machinery of §III-B).
//!
//! The online controller evaluates `τ_tr + τ_pp` between every (current
//! node, candidate node) pair inside its greedy loop; doing a Dijkstra per
//! evaluation would dominate the per-slot budget, so [`DistanceMatrix`]
//! linearizes routed latency as `base(a,b) + mb · per_mb(a,b)` along the
//! reference-payload shortest route — exact when the route is payload-
//! independent, and within a few percent otherwise (see `bench_alg1`).

mod core_router;

pub use core_router::{CoreAssignment, CoreRouter};

use crate::network::Topology;

/// One hop of a routed path: destination node plus the latency split into
/// a payload-independent propagation part and a per-MB transmission part.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Hop {
    /// Node reached after traversing this hop.
    pub to: usize,
    /// Propagation delay (ms): distance / l.
    pub base_ms: f64,
    /// Transmission delay per MB (ms/MB): 1 / w.
    pub per_mb_ms: f64,
}

impl Hop {
    /// Latency of this hop for a `mb`-sized payload.
    #[inline]
    pub fn latency(&self, mb: f64) -> f64 {
        self.base_ms + mb * self.per_mb_ms
    }
}

/// All-pairs hop-level routing table: for each (src, dst) pair the sequence
/// of hops along the reference-payload shortest route. [`DistanceMatrix`]
/// is the summed view of this table, so hop-by-hop replay (the DES
/// transfer chain) lands on exactly the same total latency the analytic
/// engines use.
#[derive(Clone, Debug)]
pub struct HopTable {
    n: usize,
    hops: Vec<Vec<Hop>>,
}

impl HopTable {
    /// Build from a topology using `ref_mb` as the payload that defines
    /// the routes (1 MB by default in callers).
    pub fn build(topo: &Topology, ref_mb: f64) -> Self {
        let n = topo.num_nodes();
        let mut hops = vec![Vec::new(); n * n];
        for src in 0..n {
            let sp = topo.shortest_paths(src, ref_mb);
            for dst in 0..n {
                if src == dst {
                    continue;
                }
                // Disconnected pairs (possible under fault injection, never
                // in the generated healthy mesh) keep an empty hop list;
                // the summed views report infinite latency for them.
                if !sp.dist[dst].is_finite() {
                    continue;
                }
                let path = sp.path_to(dst);
                let mut seq = Vec::with_capacity(path.len().saturating_sub(1));
                for w in path.windows(2) {
                    // Find the best link between consecutive hops.
                    let mut best: Option<(f64, f64)> = None;
                    for l in topo.links() {
                        if (l.a == w[0] && l.b == w[1]) || (l.a == w[1] && l.b == w[0]) {
                            let cand = (
                                l.distance_km / topo.prop_speed_km_per_ms,
                                1.0 / l.bandwidth_mb_ms,
                            );
                            let cand_lat = cand.0 + ref_mb * cand.1;
                            match best {
                                None => best = Some(cand),
                                Some(cur) if cand_lat < cur.0 + ref_mb * cur.1 => {
                                    best = Some(cand)
                                }
                                _ => {}
                            }
                        }
                    }
                    let (base_ms, per_mb_ms) = best.expect("path hops are adjacent");
                    seq.push(Hop {
                        to: w[1],
                        base_ms,
                        per_mb_ms,
                    });
                }
                hops[src * n + dst] = seq;
            }
        }
        HopTable { n, hops }
    }

    /// Hop sequence from `a` to `b` (empty when `a == b` or when no
    /// route survives the current fault state).
    #[inline]
    pub fn hops(&self, a: usize, b: usize) -> &[Hop] {
        &self.hops[a * self.n + b]
    }

    /// Whether a route exists (trivially true for `a == b`).
    #[inline]
    pub fn is_reachable(&self, a: usize, b: usize) -> bool {
        a == b || !self.hops(a, b).is_empty()
    }

    /// Total routed latency for payload `mb` — identical to the summed
    /// [`DistanceMatrix::latency`]; `f64::INFINITY` when unreachable.
    pub fn latency(&self, a: usize, b: usize, mb: f64) -> f64 {
        if !self.is_reachable(a, b) {
            return f64::INFINITY;
        }
        self.hops(a, b).iter().map(|h| h.latency(mb)).sum()
    }

    pub fn num_nodes(&self) -> usize {
        self.n
    }
}

/// All-pairs routed-latency model, decomposed into a payload-independent
/// propagation component and a per-MB transmission component.
#[derive(Clone, Debug)]
pub struct DistanceMatrix {
    n: usize,
    /// Propagation (ms): Σ distance/l along the route.
    base: Vec<f64>,
    /// Transmission (ms/MB): Σ 1/w along the route.
    per_mb: Vec<f64>,
}

impl DistanceMatrix {
    /// Build from a topology using `ref_mb` as the payload that defines
    /// the routes (1 MB by default in callers).
    pub fn build(topo: &Topology, ref_mb: f64) -> Self {
        Self::from_hops(&HopTable::build(topo, ref_mb))
    }

    /// Summed view of a hop table: `latency(a, b, mb)` equals the sum of
    /// the per-hop latencies, term for term. Pairs without a route (fault
    /// injection) get an infinite base so every latency query reports
    /// unreachability instead of a silent zero.
    pub fn from_hops(ht: &HopTable) -> Self {
        let n = ht.num_nodes();
        let mut base = vec![0.0; n * n];
        let mut per_mb = vec![0.0; n * n];
        for src in 0..n {
            for dst in 0..n {
                if src != dst && !ht.is_reachable(src, dst) {
                    base[src * n + dst] = f64::INFINITY;
                    continue;
                }
                for h in ht.hops(src, dst) {
                    base[src * n + dst] += h.base_ms;
                    per_mb[src * n + dst] += h.per_mb_ms;
                }
            }
        }
        DistanceMatrix { n, base, per_mb }
    }

    /// Routed latency for payload `mb` from `a` to `b` (ms). Zero when
    /// `a == b`.
    #[inline]
    pub fn latency(&self, a: usize, b: usize, mb: f64) -> f64 {
        self.base[a * self.n + b] + mb * self.per_mb[a * self.n + b]
    }

    /// Propagation-only component (payload-independent).
    #[inline]
    pub fn propagation(&self, a: usize, b: usize) -> f64 {
        self.base[a * self.n + b]
    }

    pub fn num_nodes(&self) -> usize {
        self.n
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;
    use crate::rng::Xoshiro256;

    fn topo(seed: u64) -> Topology {
        let cfg = ExperimentConfig::paper_default();
        let mut rng = Xoshiro256::seed_from(seed);
        Topology::generate(&cfg, &mut rng)
    }

    #[test]
    fn matrix_matches_dijkstra_at_reference_payload() {
        let t = topo(1);
        let dm = DistanceMatrix::build(&t, 1.0);
        for src in 0..t.num_nodes() {
            let sp = t.shortest_paths(src, 1.0);
            for dst in 0..t.num_nodes() {
                assert!(
                    (dm.latency(src, dst, 1.0) - sp.dist[dst]).abs() < 1e-9,
                    "({src},{dst}): {} vs {}",
                    dm.latency(src, dst, 1.0),
                    sp.dist[dst]
                );
            }
        }
    }

    #[test]
    fn latency_linear_in_payload() {
        let t = topo(2);
        let dm = DistanceMatrix::build(&t, 1.0);
        let l1 = dm.latency(0, 14, 1.0);
        let l2 = dm.latency(0, 14, 3.0);
        let slope = dm.latency(0, 14, 2.0) - l1;
        assert!((l2 - l1 - 2.0 * slope).abs() < 1e-9);
    }

    #[test]
    fn self_latency_is_zero() {
        let t = topo(3);
        let dm = DistanceMatrix::build(&t, 1.0);
        for v in 0..t.num_nodes() {
            assert_eq!(dm.latency(v, v, 5.0), 0.0);
        }
    }

    #[test]
    fn hop_table_sums_to_distance_matrix() {
        let t = topo(5);
        let ht = HopTable::build(&t, 1.0);
        let dm = DistanceMatrix::from_hops(&ht);
        for a in 0..t.num_nodes() {
            for b in 0..t.num_nodes() {
                for &mb in &[0.25, 1.0, 4.0] {
                    assert!(
                        (ht.latency(a, b, mb) - dm.latency(a, b, mb)).abs() < 1e-12,
                        "hop-by-hop total must equal the summed matrix ({a},{b})"
                    );
                }
            }
        }
    }

    #[test]
    fn hop_paths_end_at_destination() {
        let t = topo(6);
        let ht = HopTable::build(&t, 1.0);
        for a in 0..t.num_nodes() {
            for b in 0..t.num_nodes() {
                let hops = ht.hops(a, b);
                if a == b {
                    assert!(hops.is_empty());
                } else {
                    assert_eq!(hops.last().expect("connected").to, b);
                }
            }
        }
    }

    #[test]
    fn symmetric_for_undirected_links() {
        let t = topo(4);
        let dm = DistanceMatrix::build(&t, 1.0);
        for a in 0..t.num_nodes() {
            for b in 0..t.num_nodes() {
                assert!(
                    (dm.latency(a, b, 1.0) - dm.latency(b, a, 1.0)).abs() < 1e-9,
                    "asymmetric routed latency ({a},{b})"
                );
            }
        }
    }
}
