//! Effective-capacity theory (§III-B, eqs. 20–21): the statistical link
//! between a light microservice's parallelism level `y` and a processing
//! delay bound that holds with violation probability ε.
//!
//! For the paper's iid stationary service process, the effective capacity
//! of MS `m` at QoS exponent θ reduces to the per-slot form
//! `E^c_m(θ) = -ln E[e^{-θ f_m}] / θ`, estimated here from Monte-Carlo
//! samples (and cross-checked against the Gamma closed form
//! `k·ln(1+θs)/θ` in tests). The tail approximation (21),
//! `P{d > D} ≈ (E^c(θ)/E[f]) · e^{-θ·E^c(θ)·D/a_m}`, inverted at ε over a
//! θ-grid, yields the deterministic mapping `d = g_{m,ε}(y)` that the
//! online controller uses in place of the intractable stochastic latency.
//!
//! This exact computation is also implemented as the Layer-1/2 Pallas/JAX
//! graph (`python/compile/kernels/effcap.py`) and AOT-compiled to
//! `artifacts/effcap.hlo.txt`; `crate::runtime::EffCapAccel` executes it
//! via PJRT and integration tests check both paths agree.

mod estimator;
mod gtable;

pub use estimator::{
    effective_capacity, effective_capacity_contended, log_mean_exp, log_mean_exp_scaled,
    EffCapEstimator,
};
pub use gtable::{GTable, GTableParams};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{Distribution, Gamma, Xoshiro256};

    #[test]
    fn log_mean_exp_is_stable_and_correct() {
        // Against a direct computation on moderate values.
        let xs: [f64; 4] = [0.1, -0.3, 0.7, 0.2];
        let direct = (xs.iter().map(|x| x.exp()).sum::<f64>() / 4.0).ln();
        assert!((log_mean_exp(&xs) - direct).abs() < 1e-12);
        // Large negatives must not underflow to -inf incorrectly.
        let big = [-800.0, -802.0];
        let v = log_mean_exp(&big);
        assert!(v.is_finite());
        assert!((v - (-800.0 + ((1.0 + (-2.0f64).exp()) / 2.0).ln())).abs() < 1e-9);
    }

    #[test]
    fn sampled_effcap_matches_gamma_closed_form() {
        let g = Gamma::new(1.5, 10.0);
        let mut rng = Xoshiro256::seed_from(7);
        let samples = g.sample_n(&mut rng, 200_000);
        for theta in [0.01, 0.1, 0.5, 1.0, 3.0] {
            let est = effective_capacity(&samples, theta);
            let exact = g.effective_capacity(theta, 1.0);
            assert!(
                (est - exact).abs() / exact < 0.02,
                "theta={theta}: est={est} exact={exact}"
            );
        }
    }

    #[test]
    fn effcap_below_mean_and_decreasing() {
        let g = Gamma::new(2.0, 5.0);
        let mut rng = Xoshiro256::seed_from(8);
        let samples = g.sample_n(&mut rng, 50_000);
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        let mut prev = f64::INFINITY;
        for i in 1..=20 {
            let theta = i as f64 * 0.25;
            let e = effective_capacity(&samples, theta);
            assert!(e <= mean + 1e-9, "E^c must not exceed the mean rate");
            assert!(e <= prev + 1e-9, "E^c must be non-increasing in theta");
            assert!(e > 0.0);
            prev = e;
        }
    }

    #[test]
    fn gtable_monotone_in_parallelism() {
        let params = GTableParams::default_paper();
        let g = Gamma::new(1.5, 8.0);
        let mut rng = Xoshiro256::seed_from(9);
        let samples = g.sample_n(&mut rng, 8192);
        let table = GTable::build(&[samples], &[1.2], &params);
        for y in 1..params.max_parallelism {
            assert!(
                table.delay(0, y + 1) >= table.delay(0, y) - 1e-12,
                "more contention cannot reduce the delay bound"
            );
        }
    }

    #[test]
    fn gtable_bound_dominates_mean_delay() {
        let params = GTableParams::default_paper();
        let g = Gamma::new(1.5, 8.0);
        let mut rng = Xoshiro256::seed_from(10);
        let samples = g.sample_n(&mut rng, 8192);
        let a_m = 1.2;
        let mean_rate = samples.iter().sum::<f64>() / samples.len() as f64;
        let table = GTable::build(&[samples], &[a_m], &params);
        for y in 1..=params.max_parallelism {
            let mean_delay = a_m * (y as f64).powf(params.contention_alpha) / mean_rate;
            assert!(
                table.delay(0, y) >= mean_delay - 1e-9,
                "QoS bound must not undercut the mean-value delay (y={y})"
            );
        }
    }

    #[test]
    fn gtable_tightens_with_larger_epsilon() {
        // Larger tolerated violation probability => smaller delay bound.
        let g = Gamma::new(1.3, 6.0);
        let mut rng = Xoshiro256::seed_from(11);
        let samples = g.sample_n(&mut rng, 8192);
        let mut strict = GTableParams::default_paper();
        strict.epsilon = 0.05;
        let mut loose = GTableParams::default_paper();
        loose.epsilon = 0.5;
        let t_strict = GTable::build(&[samples.clone()], &[1.0], &strict);
        let t_loose = GTable::build(&[samples], &[1.0], &loose);
        for y in 1..=strict.max_parallelism {
            assert!(
                t_strict.delay(0, y) >= t_loose.delay(0, y) - 1e-12,
                "stricter epsilon must give a looser (larger) bound"
            );
        }
    }

    #[test]
    fn gtable_bound_actually_controls_violations() {
        // Empirical check of (21): realized delay a/(f/y) exceeds g(y) with
        // probability <= ~epsilon (approximation slack allowed).
        let g = Gamma::new(1.5, 10.0);
        let mut rng = Xoshiro256::seed_from(12);
        let samples = g.sample_n(&mut rng, 16384);
        let mut params = GTableParams::default_paper();
        params.epsilon = 0.2;
        let a_m = 1.0;
        let table = GTable::build(&[samples], &[a_m], &params);
        for y in [1usize, 4, 8] {
            let bound = table.delay(0, y);
            let mut violations = 0usize;
            let trials = 20_000;
            for _ in 0..trials {
                let f = g.sample(&mut rng) / (y as f64).powf(params.contention_alpha);
                if a_m / f > bound {
                    violations += 1;
                }
            }
            let rate = violations as f64 / trials as f64;
            assert!(
                rate <= params.epsilon * 1.5 + 0.02,
                "y={y}: violation rate {rate} should be ≲ ε={}",
                params.epsilon
            );
        }
    }

    #[test]
    fn mean_delay_table_matches_direct_computation() {
        let g = Gamma::new(2.0, 4.0);
        let mut rng = Xoshiro256::seed_from(13);
        let samples = g.sample_n(&mut rng, 4096);
        let params = GTableParams::default_paper();
        let a = 1.7;
        let table = GTable::build(&[samples.clone()], &[a], &params);
        let mean_rate = samples.iter().sum::<f64>() / samples.len() as f64;
        for y in [1usize, 3, 16] {
            let expect = a * (y as f64).powf(params.contention_alpha) / mean_rate;
            assert!((table.mean_delay(0, y) - expect).abs() < 1e-9);
        }
    }
}
