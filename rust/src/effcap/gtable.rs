//! The `g_{m,ε}(y)` delay-bound table: per light MS × parallelism level.

use super::estimator::EffCapEstimator;

/// Parameters of g-table construction.
#[derive(Clone, Debug)]
pub struct GTableParams {
    /// Latency-violation probability ε.
    pub epsilon: f64,
    /// Maximum tabulated parallelism level.
    pub max_parallelism: usize,
    /// θ-grid bounds and size.
    pub theta_lo: f64,
    pub theta_hi: f64,
    pub theta_n: usize,
    /// Contention model: per-task rate is `f / y^alpha`.
    pub contention_alpha: f64,
}

impl GTableParams {
    /// Paper defaults: ε = 0.2, y up to 16, 32-point log θ-grid.
    pub fn default_paper() -> Self {
        GTableParams {
            epsilon: 0.2,
            max_parallelism: 16,
            theta_lo: 1e-3,
            theta_hi: 10.0,
            theta_n: 32,
            contention_alpha: 1.0,
        }
    }

    /// Derive from the experiment controller config.
    pub fn from_config(c: &crate::config::ControllerConfig) -> Self {
        GTableParams {
            epsilon: c.epsilon,
            max_parallelism: c.max_parallelism,
            theta_lo: c.theta_lo,
            theta_hi: c.theta_hi,
            theta_n: c.theta_n,
            contention_alpha: c.contention_alpha,
        }
    }
}

/// Precomputed deterministic mapping `g_{m,ε}(y)` (and the mean-value
/// variant used by the PropAvg ablation), indexed by **light-MS dense
/// index** (position in `Catalog::light_ids`) and parallelism `y ∈ [1, Y]`.
#[derive(Clone, Debug)]
pub struct GTable {
    /// `delays[m][y-1]` = ε-quantile delay bound (ms).
    delays: Vec<Vec<f64>>,
    /// `mean_delays[m][y-1]` = mean-value delay (ms) — PropAvg's estimate.
    mean_delays: Vec<Vec<f64>>,
    pub params_epsilon: f64,
    pub contention_alpha: f64,
}

impl GTable {
    /// Build from per-MS service-rate samples and workloads `a_m`.
    ///
    /// `rate_samples[m]` are iid draws of the *uncontended* rate `f_m`;
    /// parallelism `y` scales each draw by `1/y^alpha` before estimation.
    pub fn build(rate_samples: &[Vec<f64>], workload_mb: &[f64], params: &GTableParams) -> Self {
        assert_eq!(rate_samples.len(), workload_mb.len());
        let est = EffCapEstimator::log_grid(params.theta_lo, params.theta_hi, params.theta_n);
        let mut delays = Vec::with_capacity(rate_samples.len());
        let mut mean_delays = Vec::with_capacity(rate_samples.len());
        for (samples, &a_m) in rate_samples.iter().zip(workload_mb) {
            assert!(!samples.is_empty(), "need rate samples per light MS");
            let mu: f64 = samples.iter().sum::<f64>() / samples.len() as f64;
            let mut row = Vec::with_capacity(params.max_parallelism);
            let mut mean_row = Vec::with_capacity(params.max_parallelism);
            for y in 1..=params.max_parallelism {
                let scale = (y as f64).powf(params.contention_alpha);
                // Allocation-free inner loop: the contention divisor is
                // fused into the streaming log-mean-exp.
                let bound = est.delay_bound_contended(samples, scale, a_m, params.epsilon);
                row.push(bound);
                mean_row.push(a_m * scale / mu);
            }
            // Clamp: at extreme contention the Chernoff inversion can blow
            // up (no θ in the grid yields a positive denominator). The
            // controller still needs a finite, ordered cost signal, so cap
            // each bound at 20× the mean-value delay for that level.
            for (y, b) in row.iter_mut().enumerate() {
                let cap = 20.0 * mean_row[y];
                if !b.is_finite() || *b > cap {
                    *b = cap;
                }
            }
            // Monotonize: contention can only increase the bound. (The raw
            // estimates are already near-monotone; this removes Monte-Carlo
            // jitter so the controller sees a consistent cost structure.)
            for y in 1..row.len() {
                if row[y] < row[y - 1] {
                    row[y] = row[y - 1];
                }
            }
            delays.push(row);
            mean_delays.push(mean_row);
        }
        GTable {
            delays,
            mean_delays,
            params_epsilon: params.epsilon,
            contention_alpha: params.contention_alpha,
        }
    }

    /// Construct directly from precomputed delay rows (the PJRT-accelerated
    /// path: rows come out of `artifacts/effcap.hlo.txt`).
    pub fn from_rows(
        delays: Vec<Vec<f64>>,
        mean_delays: Vec<Vec<f64>>,
        epsilon: f64,
        contention_alpha: f64,
    ) -> Self {
        assert_eq!(delays.len(), mean_delays.len());
        GTable {
            delays,
            mean_delays,
            params_epsilon: epsilon,
            contention_alpha,
        }
    }

    /// Number of light microservices tabulated.
    pub fn num_ms(&self) -> usize {
        self.delays.len()
    }

    /// Maximum parallelism level tabulated.
    pub fn max_parallelism(&self) -> usize {
        self.delays.first().map_or(0, Vec::len)
    }

    /// QoS-aware delay bound `g_{m,ε}(y)` (ms). `y` is clamped to the
    /// tabulated range; `y = 0` is treated as 1 (an instance processing a
    /// single task).
    pub fn delay(&self, light_idx: usize, y: usize) -> f64 {
        let row = &self.delays[light_idx];
        let y = y.clamp(1, row.len());
        row[y - 1]
    }

    /// Mean-value delay (PropAvg ablation).
    pub fn mean_delay(&self, light_idx: usize, y: usize) -> f64 {
        let row = &self.mean_delays[light_idx];
        let y = y.clamp(1, row.len());
        row[y - 1]
    }

    /// Full row access for benches/diagnostics.
    pub fn row(&self, light_idx: usize) -> &[f64] {
        &self.delays[light_idx]
    }
}
